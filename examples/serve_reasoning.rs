//! END-TO-END serving driver (the DESIGN.md validation run): serve batched
//! requests drawn from the paper's Figure-1 reasoning-length distribution
//! against a real (trained) small target model, with BOTH drafting methods,
//! and report latency/throughput — the full three-layer stack composing:
//! Pallas kernel (L1, inside the drafter HLO) -> JAX models (L2, AOT
//! artifacts) -> Rust coordinator (L3, this binary).
//!
//!     cargo run --release --example serve_reasoning -- [artifacts] [--quick]
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use p_eagle::coordinator::{run_closed_loop, EngineConfig, SpecPolicy};
use p_eagle::runtime::ModelRuntime;
use p_eagle::util::bench::Table;
use p_eagle::util::rng::Rng;
use p_eagle::workload::{LengthModel, Request};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let root = args.iter().find(|a| !a.starts_with("--")).cloned()
        .unwrap_or_else(|| "artifacts".into());
    let quick = args.iter().any(|a| a == "--quick");
    let (total, conc) = if quick { (4, 2) } else { (12, 4) };

    let mut mr = ModelRuntime::load(&root)?;
    let target = "target-m";
    let regime = mr.manifest.regimes["mtbench"].clone();
    let lens = LengthModel::testbed(mr.manifest.s_max - mr.manifest.prompt_pad - 8);

    println!("=== P-EAGLE end-to-end serving: reasoning-length workload ===");
    println!("target={target}  concurrency={conc}  requests={total}");
    println!("generation lengths ~ paper Fig.1 distribution (scaled 1/32)");
    println!("(stepped engine: short requests evict early, freed slots re-admit mid-flight)\n");

    let mut table = Table::new(&[
        "method", "K", "OTPS", "AL", "occ", "p50 TTFT", "p99 latency", "tokens",
    ]);

    for (method, k) in [("ar", 3), ("ar", 5), ("pe4", 5), ("pe4", 7)] {
        let drafter = format!("{target}-{method}");
        let cfg = EngineConfig::new(target, SpecPolicy::chain(&drafter, k), conc, 96)
            .with_seed(1234);
        // identical request stream for both methods (seeded)
        let mut rng = Rng::new(777);
        let mut lrng = Rng::new(778);
        let regime = regime.clone();
        let mut id = 0u64;
        let lens = lens.clone();
        let (results, metrics) = run_closed_loop(&mut mr, &cfg, conc, total, || {
            id += 1;
            Request::new(
                id,
                regime.sample_seq(16, &mut rng),
                lens.sample(&mut lrng).clamp(8, 96),
            )
        })?;
        let toks: usize = results.iter().map(|r| r.tokens.len()).sum();
        table.row(vec![
            method.into(),
            k.to_string(),
            format!("{:.0}", metrics.otps()),
            format!("{:.2}", metrics.acceptance_length()),
            format!("{:.2}", metrics.mean_occupancy()),
            format!("{:?}", metrics.ttft_quantile(0.5)),
            format!("{:?}", metrics.latency_quantile(0.99)),
            toks.to_string(),
        ]);
    }
    table.print();
    println!("\n(paper Table 10 shape: AR peaks at K=3; P-EAGLE keeps gaining to K=5-7)");
    Ok(())
}
