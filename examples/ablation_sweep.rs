//! Ablation sweep — regenerates the paper's §4 ablation tables (3-8) and
//! Table 11 from the trained variant artifacts.
//!
//!     cargo run --release --example ablation_sweep -- [artifacts] [--axis X] [--quick]
//!
//! Axes: hidden (Table 3), layers (Table 4), embed (Table 5), ktrain
//! (Table 6), epochs (Table 7), seqlen (Table 8), layers2v4 (Table 11),
//! all (default).

use anyhow::Result;
use p_eagle::report::eval_acceptance;
use p_eagle::runtime::ModelRuntime;
use p_eagle::util::bench::Table;
use p_eagle::util::cli::Args;

struct Sweep<'a> {
    title: &'a str,
    paper: &'a str,
    rows: Vec<(&'a str, &'a str)>, // (label, drafter)
    datasets: Vec<&'a str>,
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let root = args.positional.first().cloned().unwrap_or_else(|| "artifacts".into());
    let axis = args.get_or("axis", "all");
    let quick = args.flag("quick");
    let (n_req, max_new) = if quick { (3, 48) } else { (8, 80) };

    let mut mr = ModelRuntime::load(&root)?;
    let k = mr.manifest.default_k;

    let sweeps = vec![
        Sweep {
            title: "Table 3 — hidden-state design (4L, GPT-OSS-20B analog)",
            paper: "paper: shared 3.16 beats all variants by 7-15% on HumanEval",
            rows: vec![
                ("baseline (learnable shared)", "target-m-pe4-40ep"),
                ("+ depth-specific encoding", "target-m-hs-depth"),
                ("+ NTP hidden + depth encoding", "target-m-hs-ntp-depth"),
                ("+ NTP hidden only", "target-m-hs-ntp"),
                ("+ regularized NTP hidden", "target-m-hs-reg"),
            ],
            datasets: vec!["humaneval"],
        },
        Sweep {
            title: "Table 4 — decoder layers",
            paper: "paper: 1L 2.69/2.41, 2L +33%/+14%, 4L +46%/+26% (HE/MT)",
            rows: vec![
                ("1 layer", "target-m-pe1"),
                ("2 layers", "target-m-pe2"),
                ("4 layers", "target-m-pe4"),
            ],
            datasets: vec!["humaneval", "mtbench"],
        },
        Sweep {
            title: "Table 5 — embedding freezing (1L)",
            paper: "paper: trainable +5.1%/+5.2%",
            rows: vec![
                ("frozen", "target-m-frozen"),
                ("trainable", "target-m-pe1"),
            ],
            datasets: vec!["humaneval", "mtbench"],
        },
        Sweep {
            title: "Table 6 — training speculation depth (1L)",
            paper: "paper: K_tr=8 over K_tr=5: +4.1%/+2.7%",
            rows: vec![
                ("K_train=5", "target-m-ktr5"),
                ("K_train=8", "target-m-pe1"),
            ],
            datasets: vec!["humaneval", "mtbench"],
        },
        Sweep {
            title: "Table 7 — training duration (4L)",
            paper: "paper: 20ep 3.92/3.04 -> 60ep +2.0%/+4.6%",
            rows: vec![
                ("20 epochs", "target-m-pe4-20ep"),
                ("40 epochs", "target-m-pe4-40ep"),
                ("60 epochs", "target-m-pe4-60ep"),
            ],
            datasets: vec!["humaneval", "mtbench"],
        },
        Sweep {
            title: "Table 8 — max training sequence length (1L)",
            paper: "paper: 512 2.51/2.26 -> 2048 +2.0%/+1.3%",
            rows: vec![
                ("short (48 = paper 512)", "target-m-seq48"),
                ("long (96 = paper 2048)", "target-m-pe1"),
            ],
            datasets: vec!["humaneval", "mtbench"],
        },
        Sweep {
            title: "Table 11 — 2L vs 4L P-EAGLE (all targets)",
            paper: "paper: 2L reaches 93-97% of AR baseline; 4L matches/exceeds",
            rows: vec![
                ("target-l AR", "target-l-ar"),
                ("target-l 2L", "target-l-pe2"),
                ("target-l 4L", "target-l-pe4"),
                ("target-m AR", "target-m-ar"),
                ("target-m 2L", "target-m-pe2"),
                ("target-m 4L", "target-m-pe4"),
                ("target-s AR", "target-s-ar"),
                ("target-s 2L", "target-s-pe2"),
                ("target-s 4L", "target-s-pe4"),
            ],
            datasets: vec!["humaneval"],
        },
    ];

    let pick = |name: &str| match axis.as_str() {
        "all" => true,
        "hidden" => name.contains("Table 3"),
        "layers" => name.contains("Table 4"),
        "embed" => name.contains("Table 5"),
        "ktrain" => name.contains("Table 6"),
        "epochs" => name.contains("Table 7"),
        "seqlen" => name.contains("Table 8"),
        "layers2v4" => name.contains("Table 11"),
        other => panic!("unknown axis {other}"),
    };

    for sweep in sweeps.iter().filter(|s| pick(s.title)) {
        println!("\n=== {} ===", sweep.title);
        println!("{}", sweep.paper);
        let mut header = vec!["variant"];
        header.extend(sweep.datasets.iter().copied());
        header.push("Δ% vs first row");
        let mut table = Table::new(&header);
        let mut baseline: Option<Vec<f64>> = None;
        for (label, drafter) in &sweep.rows {
            let mut als = Vec::new();
            for ds in &sweep.datasets {
                let e = eval_acceptance(&mut mr, drafter, ds, k, n_req, max_new)?;
                als.push(e.acceptance_length);
            }
            let delta = match &baseline {
                None => {
                    baseline = Some(als.clone());
                    "—".to_string()
                }
                Some(b) => als
                    .iter()
                    .zip(b)
                    .map(|(a, b)| format!("{:+.1}%", (a - b) / b * 100.0))
                    .collect::<Vec<_>>()
                    .join(" / "),
            };
            let mut row = vec![label.to_string()];
            row.extend(als.iter().map(|a| format!("{a:.2}")));
            row.push(delta);
            table.row(row);
        }
        table.print();
    }
    Ok(())
}
