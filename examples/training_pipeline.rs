//! The paper's §3 scalable-training framework at PAPER scale, walked
//! end-to-end on the Rust substrates (no GPU needed — these are the exact
//! algorithms the Python trainer runs, mirrored for the Table 1/2 benches):
//!
//!   1. amortized mask construction (build once, O(1) slice per example)
//!   2. COD nested-anchor sampling (geometric retention, r = 0.8)
//!   3. Algorithm 1 sequence partitioning + invariant validation
//!   4. H200 memory-model feasibility classification (Table 1's OOM cells)
//!
//!     cargo run --release --example training_pipeline

use p_eagle::masking::{cod_sample_nested, rows_from_anchors, PrecomputedMask};
use p_eagle::memmodel::{self, TrainSetup};
use p_eagle::partition::{partition_rows, validate};
use p_eagle::util::rng::Rng;
use std::time::Instant;

fn main() {
    let (n_max, k, r) = (2048usize, 8usize, 0.8f64);
    println!("=== P-EAGLE scalable training framework (paper §3) ===\n");

    // 1. amortized mask: one-time build, then O(1) views
    let t0 = Instant::now();
    let pm = PrecomputedMask::build(n_max, k);
    println!(
        "1. precomputed mask for n_max={n_max}, K={k}: built once in {:?} \
         ({} MB, amortized across the whole run)",
        t0.elapsed(),
        pm.memory_bytes() / 1_000_000
    );
    let t1 = Instant::now();
    for n in [256usize, 512, 1024, 2048] {
        let v = pm.slice_view(n);
        assert!(v.get(0, 0));
    }
    println!("   4 per-example mask views: {:?} total (constant-time slices)\n", t1.elapsed());

    // 2. COD sampling
    let mut rng = Rng::new(42);
    let anchors = cod_sample_nested(n_max, k, r, &mut rng);
    let rows = rows_from_anchors(&anchors, n_max, k);
    println!(
        "2. COD sampling: {} rows over {} depths (closed form predicts {:.0}; \
         full n*K would be {})",
        rows.len(),
        k,
        memmodel::total_rows(n_max, k, r),
        n_max * k
    );
    for (d, a) in anchors.iter().enumerate().take(4) {
        println!("   depth {d}: {} anchors", a.len());
    }
    println!();

    // 3. Algorithm 1
    for s in [1usize, 2, 4, 8] {
        let part = partition_rows(&anchors, n_max, k, s);
        let errs = validate(&part, &anchors, n_max, k);
        assert!(errs.is_empty(), "{errs:?}");
        println!(
            "3. Algorithm 1, S={s}: peak attention cells {:>12} (validated: all \
             chain + context dependencies preserved)",
            part.peak_attention_cells()
        );
    }
    println!();

    // 4. paper-scale feasibility (Table 1's OOM / Infeas. cells)
    println!("4. H200 feasibility model (paper Table 1):");
    println!("   ctx    ParallelSpec  PARD      P-EAGLE");
    for (label, n) in [("1K", 1024usize), ("4K", 4096), ("8K", 8192), ("20K", 20480)] {
        let f = |s: TrainSetup| memmodel::classify(&s, memmodel::EPOCH_EXAMPLES);
        println!(
            "   {label:<5}  {:<12}  {:<8}  {:<8}",
            f(TrainSetup::parallelspec(n, k)).label(),
            f(TrainSetup::pard(n, k)).label(),
            f(TrainSetup::peagle(n, k)).label()
        );
    }
    println!("\n(compare: paper Table 1 — ParallelSpec OOM at 8K+, PARD infeasible at 4K, OOM at 8K+)");
}
