//! Quickstart: load the AOT artifacts, smoke-test the runtime, and serve a
//! handful of requests with P-EAGLE parallel drafting.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Walks the full public API surface: Manifest -> ModelRuntime -> engine
//! config -> stepped EngineCore serving -> streamed events -> metrics.

use anyhow::Result;
use p_eagle::coordinator::{EngineConfig, EngineCore, EngineEvent, SpecPolicy};
use p_eagle::report::{bench_otps, eval_acceptance};
use p_eagle::runtime::{Arg, HostTensor, ModelRuntime};

fn main() -> Result<()> {
    let root = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // 1. load artifacts + PJRT runtime
    let mut mr = ModelRuntime::load(&root)?;
    println!(
        "loaded manifest: {} targets, {} drafters, {} executables",
        mr.manifest.targets.len(),
        mr.manifest.drafters.len(),
        mr.manifest.executables.len()
    );

    // 2. runtime smoke test (2x2 matmul HLO round-trip)
    let st = mr.manifest.find_exec("selftest", None, None, None, None)?.clone();
    mr.rt.load(&st.name, &mr.manifest.abs(&st.path))?;
    let x = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
    let y = HostTensor::f32(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
    let out = mr.rt.call(&st.name, &[Arg::Host(&x), Arg::Host(&y)])?;
    let t = mr.rt.download(&out[0])?;
    println!("selftest matmul+2 = {:?} (want [5,5,9,9])", t.as_f32()?);

    // 3. acceptance-length spot check: P-EAGLE 4L on the code regime
    let al = eval_acceptance(&mut mr, "target-m-pe4", "humaneval", 5, 4, 64)?;
    println!(
        "P-EAGLE(4L) acceptance length on humaneval (K=5): {:.2}",
        al.acceptance_length
    );

    // 4. serve a small closed-loop batch and report throughput + occupancy
    let run = bench_otps(&mut mr, "target-m-pe4", "mtbench", 5, 2, 4, 64, 7, false, None, None, None)?;
    println!(
        "served 4 requests @ C=2: OTPS {:.0}, AL {:.2}, occupancy {:.2}, p50 latency {:?}",
        run.otps,
        run.acceptance_length,
        run.mean_occupancy,
        run.metrics.latency_quantile(0.5)
    );

    // 5. drive the stepped engine core by hand and stream one generation:
    //    add_request -> step until the Finished event arrives
    // the speculation policy is per-request data: this engine defaults every
    // request to P-EAGLE chain drafting at K=5 (requests may carry their own
    // SpecPolicy — see the serve CLI's --drafters/--policy)
    let cfg = EngineConfig::new("target-m", SpecPolicy::chain("target-m-pe4", 5), 1, 24)
        .with_seed(3);
    let mut core = EngineCore::new(&mut mr, cfg)?;
    let regime = mr.manifest.regimes["humaneval"].clone();
    let mut arr = p_eagle::workload::ArrivalProcess::closed_loop(regime, 16, 24, 9);
    core.add_request(arr.next())?;
    let mut streamed: Vec<i32> = Vec::new();
    'outer: while !core.is_idle() {
        for ev in core.step(&mut mr)?.events {
            match ev {
                EngineEvent::Admitted { id, slot } => println!("admitted req {id} to slot {slot}"),
                EngineEvent::Tokens { tokens, .. } => streamed.extend(tokens),
                EngineEvent::Finished(r) => {
                    println!(
                        "sample generation ({} tokens, finish {:?}): {:?}",
                        r.tokens.len(),
                        r.finish,
                        &r.tokens
                    );
                    assert_eq!(streamed, r.tokens, "streamed tokens match the final result");
                    break 'outer;
                }
            }
        }
    }
    Ok(())
}
