//! Runtime integration tests — require `make artifacts`.
//!
//! These exercise the real PJRT path: HLO text parsing, compilation,
//! weight upload, KV-cache buffer threading.

use p_eagle::runtime::{Arg, HostTensor, ModelRuntime, Runtime};

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(r) => r,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn selftest_executable_roundtrip() {
    let root = require_artifacts!();
    let m = p_eagle::config::Manifest::load(&root).unwrap();
    let mut rt = Runtime::cpu().unwrap();
    let e = m.find_exec("selftest", None, None, None, None).unwrap();
    rt.load(&e.name, &m.abs(&e.path)).unwrap();
    let x = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
    let y = HostTensor::f32(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
    let out = rt.call(&e.name, &[Arg::Host(&x), Arg::Host(&y)]).unwrap();
    let t = rt.download(&out[0]).unwrap();
    assert_eq!(t.as_f32().unwrap(), &[5.0, 5.0, 9.0, 9.0]);
}

#[test]
fn prefill_is_deterministic_and_padding_insensitive() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let te = mr.ensure_target("target-m", 1, 5).unwrap();
    let p = mr.manifest.prompt_pad;

    let mut toks = vec![0i32; p];
    for (i, t) in toks.iter_mut().enumerate().take(16) {
        *t = 4 + (i as i32 * 7) % 200;
    }
    let lens = HostTensor::i32(&[1], vec![16]);
    let kv = mr.zero_kv("target-m", 1).unwrap();
    let a = mr
        .prefill(&te, &HostTensor::i32(&[1, p], toks.clone()), &lens, &kv)
        .unwrap();

    // same prompt, different garbage in the padding region
    let mut toks2 = toks.clone();
    for t in toks2.iter_mut().skip(16) {
        *t = 99;
    }
    let kv2 = mr.zero_kv("target-m", 1).unwrap();
    let b = mr
        .prefill(&te, &HostTensor::i32(&[1, p], toks2), &lens, &kv2)
        .unwrap();

    let (la, lb) = (a.last_logits.as_f32().unwrap(), b.last_logits.as_f32().unwrap());
    for (x, y) in la.iter().zip(lb) {
        assert!((x - y).abs() < 1e-4, "padding affected last logits");
    }
    // features of REAL positions must match too
    let fdim = mr.manifest.target("target-m").unwrap().feature_dim;
    let (fa, fb) = (a.feats.as_f32().unwrap(), b.feats.as_f32().unwrap());
    for i in 0..16 * fdim {
        assert!((fa[i] - fb[i]).abs() < 1e-4, "padding affected real feats");
    }
}

#[test]
fn verify_kv_threading_consistent() {
    // verifying [a,b,c,d,e,f] in one chunk must equal verifying it after a
    // longer cached prefix — chunk positions line up through cache_len.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let te = mr.ensure_target("target-m", 1, 5).unwrap();
    let p = mr.manifest.prompt_pad;
    let vocab = mr.manifest.vocab;

    let prompt: Vec<i32> = (0..16).map(|i| 4 + (i * 11) % 200).collect();
    let mut padded = vec![0i32; p];
    padded[..16].copy_from_slice(&prompt);
    let kv = mr.zero_kv("target-m", 1).unwrap();
    let pre = mr
        .prefill(&te, &HostTensor::i32(&[1, p], padded), &HostTensor::i32(&[1], vec![16]), &kv)
        .unwrap();

    let chunk: Vec<i32> = (0..6).map(|i| 30 + i * 3).collect();
    let v1 = mr
        .verify(&te, &HostTensor::i32(&[1, 6], chunk.clone()),
                &HostTensor::i32(&[1], vec![16]), &pre.kv)
        .unwrap();

    // now verify the same chunk in two halves, threading kv + cache_len
    let v2a = mr
        .verify(&te, &HostTensor::i32(&[1, 6], {
            let mut c = chunk.clone();
            c[3..].iter_mut().for_each(|x| *x = 7); // junk tail, will be overwritten
            c
        }), &HostTensor::i32(&[1], vec![16]), &pre.kv)
        .unwrap();
    // accept 2 tokens (positions 16,17 cached) then re-verify the rest
    let v2b = mr
        .verify(&te, &HostTensor::i32(&[1, 6], chunk[2..].iter().copied().chain([5, 6]).collect()),
                &HostTensor::i32(&[1], vec![18]), &v2a.kv)
        .unwrap();

    // v2b row i corresponds to v1 row i+2 for the overlapping positions
    let (l1, l2) = (v1.logits.as_f32().unwrap(), v2b.logits.as_f32().unwrap());
    for i in 0..4 {
        for v in 0..vocab {
            let a = l1[(i + 2) * vocab + v];
            let b = l2[i * vocab + v];
            assert!((a - b).abs() < 1e-3, "row {i} logit {v}: {a} vs {b}");
        }
    }
}

#[test]
fn draft_shapes_and_determinism() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let de = mr.ensure_drafter("target-m-pe4", 1, 5).unwrap();
    let c = mr.manifest.ctx_window;
    let fdim = mr.manifest.target("target-m").unwrap().feature_dim;

    let ct = HostTensor::i32(&[1, c], (0..c as i32).map(|i| 10 + i).collect());
    let cf = HostTensor::f32(&[1, c, fdim], vec![0.1; c * fdim]);
    let p0 = HostTensor::i32(&[1], vec![20]);
    let a = mr.draft(&de, &ct, &cf, &p0).unwrap();
    let b = mr.draft(&de, &ct, &cf, &p0).unwrap();
    assert_eq!(a.dims, vec![1, 5]);
    assert_eq!(a.as_i32().unwrap(), b.as_i32().unwrap());
    let vocab = mr.manifest.vocab as i32;
    assert!(a.as_i32().unwrap().iter().all(|&t| t >= 0 && t < vocab));
}

#[test]
fn weight_order_validation_catches_mismatch() {
    let root = require_artifacts!();
    let m = p_eagle::config::Manifest::load(&root).unwrap();
    let t = m.target("target-m").unwrap();
    let tensors = p_eagle::runtime::weights::read_pew(&m.abs(&t.weights)).unwrap();
    // correct order passes
    p_eagle::runtime::weights::check_order(&tensors, &t.param_order).unwrap();
    // shuffled order fails
    let mut wrong = t.param_order.clone();
    wrong.reverse();
    assert!(p_eagle::runtime::weights::check_order(&tensors, &wrong).is_err());
}
