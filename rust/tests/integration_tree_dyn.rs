//! Dynamic-tree (max-shape envelope) integration tests — require
//! `make artifacts`.
//!
//! The headline property is degenerate-case parity: a dynamic engine whose
//! node budget equals its envelope's node count selects every node every
//! step, so it must produce byte-identical tokens AND acceptance lengths to
//! the static-topology engine for the same envelope — chain and branching,
//! dense and paged. That is what licenses shipping dynamic trees as a
//! budget knob rather than a fork.
//!
//! Also pinned: dynamic greedy speculation stays LOSSLESS at any budget,
//! dense-vs-paged byte parity holds for non-degenerate budgets, dynamic AL
//! matches or beats the static tree's at an equal verified-node budget on
//! the bundled target-m workload, and paged admission charges blocks by the
//! node budget (not the envelope) — the over-reservation fix, observed at
//! the engine level.

use p_eagle::coordinator::{
    run_closed_loop, EngineConfig, EngineCore, EngineMetrics, PagedKvConfig, Request,
    SpecPolicy,
};
use p_eagle::masking::{DynamicTreeConfig, TreeTopology};
use p_eagle::runtime::{HostTensor, ModelRuntime};

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(r) => r,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn policy_cfg(policy: SpecPolicy, batch: usize, max_new: usize) -> EngineConfig {
    EngineConfig::new("target-m", policy, batch, max_new).with_seed(5)
}

fn tree_cfg(t: TreeTopology, batch: usize, max_new: usize) -> EngineConfig {
    policy_cfg(SpecPolicy::tree("target-m-pe4", t), batch, max_new)
}

fn dyn_policy(envelope: &str, budget: usize) -> SpecPolicy {
    let d = DynamicTreeConfig::parse(envelope, budget).unwrap();
    SpecPolicy::from_dynamic_config("target-m-pe4", &d)
}

fn dyn_cfg2(envelope: &str, budget: usize, batch: usize, max_new: usize) -> EngineConfig {
    policy_cfg(dyn_policy(envelope, budget), batch, max_new)
}

fn test_prompt(mr: &ModelRuntime, seed: u64) -> Vec<i32> {
    let regime = mr.manifest.regimes["humaneval"].clone();
    let mut rng = p_eagle::util::rng::Rng::new(seed);
    regime.sample_seq(16, &mut rng)
}

fn spec(id: u64, prompt: &[i32], max_new: usize) -> Request {
    Request::new(id, prompt.to_vec(), max_new)
}

/// Run one closed-loop request; returns (tokens, accepted_sum, iterations)
/// plus the engine metrics.
fn run_one(
    mr: &mut ModelRuntime,
    cfg: EngineConfig,
    prompt: &[i32],
    max_new: usize,
) -> ((Vec<i32>, usize, usize), EngineMetrics) {
    let mut g = Some(spec(0, prompt, max_new));
    let (results, metrics) = run_closed_loop(mr, &cfg, 1, 1, || g.take().unwrap()).unwrap();
    let r = results.into_iter().next().unwrap();
    ((r.tokens, r.accepted_sum, r.iterations), metrics)
}

/// Reference greedy decode using only the target executables (no drafter).
fn reference_greedy(
    mr: &mut ModelRuntime,
    target: &str,
    prompt: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let k = mr.manifest.default_k;
    let te = mr.ensure_target(target, 1, k).unwrap();
    let p = mr.manifest.prompt_pad;
    let vocab = mr.manifest.vocab;
    let mut padded = vec![mr.manifest.pad_id; p];
    padded[..prompt.len()].copy_from_slice(prompt);
    let kv = mr.zero_kv(target, 1).unwrap();
    let pre = mr
        .prefill(
            &te,
            &HostTensor::i32(&[1, p], padded),
            &HostTensor::i32(&[1], vec![prompt.len() as i32]),
            &kv,
        )
        .unwrap();
    let argmax = |row: &[f32]| -> i32 {
        let mut bi = 0;
        for (i, &x) in row.iter().enumerate() {
            if x > row[bi] {
                bi = i;
            }
        }
        bi as i32
    };
    let mut out = vec![argmax(pre.last_logits.as_f32().unwrap())];
    let mut kv = pre.kv;
    let mut cache_len = prompt.len();
    while out.len() < max_new && *out.last().unwrap() != mr.manifest.eos_id {
        let mut chunk = vec![0i32; k + 1];
        chunk[0] = *out.last().unwrap();
        let v = mr
            .verify(
                &te,
                &HostTensor::i32(&[1, k + 1], chunk),
                &HostTensor::i32(&[1], vec![cache_len as i32]),
                &kv,
            )
            .unwrap();
        kv = v.kv;
        let logits = v.logits.as_f32().unwrap();
        out.push(argmax(&logits[..vocab]));
        cache_len += 1;
    }
    out
}

#[test]
fn degenerate_budget_matches_static_tree_dense_and_paged() {
    // THE acceptance criterion: budget == envelope nodes ⇒ byte-identical
    // tokens, accepted sums, and iteration counts vs the static-topology
    // engine — for the chain AND a branching profile, dense AND paged.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    for (envelope, widths) in
        [("chain:5", vec![1usize, 1, 1, 1, 1]), ("w:3,2,1,1,1", vec![3, 2, 1, 1, 1])]
    {
        let tree = TreeTopology::from_widths(&widths);
        let budget = tree.len();
        for paged in [None, Some(PagedKvConfig::default())] {
            for seed in [151u64, 152] {
                let prompt = test_prompt(&mr, seed);
                let cs = tree_cfg(tree.clone(), 1, 32).with_paged(paged);
                let cd = dyn_cfg2(envelope, budget, 1, 32).with_paged(paged);
                let (stat, _) = run_one(&mut mr, cs, &prompt, 32);
                let (dynr, _) = run_one(&mut mr, cd, &prompt, 32);
                assert_eq!(
                    dynr.0, stat.0,
                    "tokens diverged ({envelope}, paged={}, seed {seed})",
                    paged.is_some()
                );
                assert_eq!(
                    dynr.1, stat.1,
                    "accepted_sum diverged ({envelope}, paged={}, seed {seed})",
                    paged.is_some()
                );
                assert_eq!(
                    dynr.2, stat.2,
                    "iterations diverged ({envelope}, paged={}, seed {seed})",
                    paged.is_some()
                );
            }
        }
    }
}

#[test]
fn dynamic_budgets_stay_lossless() {
    // greedy dynamic speculation emits exactly the target's own greedy
    // continuation at every budget (selection changes which nodes are
    // VERIFIED, never what gets accepted wrongly)
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    for seed in [161u64, 162] {
        let prompt = test_prompt(&mr, seed);
        let want = reference_greedy(&mut mr, "target-m", &prompt, 32);
        for budget in [1usize, 4, 8, 13] {
            let c = dyn_cfg2("w:4,4,2,2,1", budget, 1, 32);
            let (got, _) = run_one(&mut mr, c, &prompt, 32);
            assert_eq!(
                got.0, want,
                "dynamic engine diverged from greedy (budget {budget}, seed {seed})"
            );
        }
    }
}

#[test]
fn dense_and_paged_dynamic_are_byte_identical_at_partial_budget() {
    // non-degenerate budgets exercise the compacted-chunk + null-block tail
    // path; dense vs fully provisioned paged must still agree byte-for-byte
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    for seed in [171u64, 172] {
        let prompt = test_prompt(&mr, seed);
        let cd = dyn_cfg2("w:4,4,2,2,1", 6, 1, 32);
        let cp = cd.clone().with_paged(Some(PagedKvConfig::default()));
        let (dense, _) = run_one(&mut mr, cd, &prompt, 32);
        let (paged, pm) = run_one(&mut mr, cp, &prompt, 32);
        assert_eq!(paged.0, dense.0, "tokens diverged (seed {seed})");
        assert_eq!(paged.1, dense.1, "accepted_sum diverged (seed {seed})");
        assert_eq!(paged.2, dense.2, "iterations diverged (seed {seed})");
        assert_eq!(pm.dense_compactions, 0, "paged engine used dense compaction");
    }
}

#[test]
fn dynamic_al_matches_or_beats_static_at_equal_verified_node_budget() {
    // the bench-otps acceptance criterion: an 8-node budget inside the
    // w:4,4,2,2,1 envelope, spent where the drafter is confident, matches
    // or beats the static 8-node w:3,2,1,1,1 tree's acceptance length on
    // the bundled target-m workload (summed over seeds so single-request
    // noise cannot flip the sign)
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let tree = TreeTopology::from_widths(&[3, 2, 1, 1, 1]);
    let mut static_al = 0.0;
    let mut dyn_al = 0.0;
    for seed in [181u64, 182, 183, 184] {
        let prompt = test_prompt(&mr, seed);
        let cs = tree_cfg(tree.clone(), 1, 32);
        let cd = dyn_cfg2("w:4,4,2,2,1", tree.len(), 1, 32);
        let (_, sm) = run_one(&mut mr, cs, &prompt, 32);
        let (_, dm) = run_one(&mut mr, cd, &prompt, 32);
        static_al += sm.acceptance_length();
        dyn_al += dm.acceptance_length();
        assert!((dm.mean_active_nodes() - tree.len() as f64).abs() < 1e-9);
    }
    assert!(
        dyn_al + 1e-9 >= static_al,
        "dynamic AL {dyn_al:.3} < static AL {static_al:.3} at equal verified-node budget"
    );
}

#[test]
fn paged_admission_charges_by_budget_not_envelope() {
    // over-reservation regression at the engine level: with block_size 16,
    // a 19-token prompt plus the budget chunk (8 + 1 = 9 positions) covers
    // 28 positions = 2 blocks, while envelope charging (13 + 1 = 14 ->
    // 33 positions) would demand 3. A 2-block budget must ADMIT and finish
    // correctly.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let bs = mr.manifest.kv_block_size;
    let regime = mr.manifest.regimes["humaneval"].clone();
    let mut rng = p_eagle::util::rng::Rng::new(191);
    let prompt = regime.sample_seq(bs + 3, &mut rng); // 19 tokens at bs=16
    let need_budget = (prompt.len() + 9).div_ceil(bs); // 2 at bs=16
    let need_envelope = (prompt.len() + 14).div_ceil(bs); // 3 at bs=16
    assert!(need_budget < need_envelope, "pick a prompt length that splits the two");

    // solo unconstrained reference
    let c0 = dyn_cfg2("w:4,4,2,2,1", 8, 1, 16);
    let (solo, _) = run_one(&mut mr, c0.clone(), &prompt, 16);

    let cb = c0.with_paged(Some(PagedKvConfig {
        block_size: None,
        num_blocks: Some(need_budget),
        prefix_cache: false,
    }));
    let mut core = EngineCore::new(&mut mr, cb).unwrap();
    core.add_request(spec(0, &prompt, 16))
        .expect("budget-charged admission must accept what envelope charging would refuse");
    let mut results = Vec::new();
    while !core.is_idle() {
        results.extend(core.step(&mut mr).unwrap().into_finished());
    }
    // the tight budget may end the request early (CacheFull once the slot
    // outgrows its 2 blocks), but every token emitted before that must be a
    // prefix of the unconstrained run — greedy decoding is prefix-stable
    assert_eq!(results.len(), 1);
    let got = &results[0].tokens;
    assert!(!got.is_empty(), "constrained run emitted nothing");
    assert_eq!(
        got[..],
        solo.0[..got.len()],
        "block-constrained dynamic run corrupted tokens"
    );
    let metrics = core.into_metrics();
    assert!(metrics.blocks_peak <= need_budget, "allocator exceeded its block budget");
}
