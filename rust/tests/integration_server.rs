//! Threaded streaming-server integration tests. The spawn-failure handshake
//! test runs everywhere; the round-trip tests require `make artifacts`.

use std::collections::HashMap;

use p_eagle::coordinator::server::spawn;
use p_eagle::coordinator::{EngineConfig, FinishReason, Request, ServerEvent, SpecPolicy};

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

fn cfg(batch: usize, max_new: usize) -> EngineConfig {
    // PEAGLE_TREE_DYN=1 (the CI tree-dyn job) runs this suite in dynamic
    // tree mode; PEAGLE_PAGED=1 (the paged job) on the paged KV cache;
    // PEAGLE_PREFIX_CACHE=1 (the prefix-cache job) additionally turns on
    // the automatic prefix cache; PEAGLE_MULTI_DRAFTER=1 widens the
    // allowlist (requests stay default); PEAGLE_ADAPTIVE=1 (the adaptive
    // job) routes policy-free admissions through the controller
    let default = match p_eagle::coordinator::tree_dyn_from_env() {
        Some(d) => SpecPolicy::from_dynamic_config("target-m-pe4", &d),
        None => SpecPolicy::chain("target-m-pe4", 5),
    };
    let extras = if p_eagle::coordinator::multi_drafter_from_env() {
        vec![SpecPolicy::chain("target-m-ar", 5)]
    } else {
        Vec::new()
    };
    EngineConfig::new("target-m", default, batch, max_new)
        .with_policies(extras)
        .with_seed(1)
        .with_paged(p_eagle::coordinator::device_commit_from_env())
        .with_adaptive(p_eagle::coordinator::adaptive_from_env())
}

fn prompt(i: u64) -> Vec<i32> {
    std::iter::once(1)
        .chain((0..15).map(|j| 4 + ((i as i32) * 31 + j) % 200))
        .collect()
}

#[test]
fn spawn_propagates_artifact_load_failure() {
    // the ready/error handshake: a missing artifacts root must surface as an
    // error from spawn() itself, not a stderr line + default metrics.
    // (No artifacts needed — this exercises the failure path.)
    let err = spawn("definitely/not/an/artifacts/root".into(), cfg(2, 8))
        .err()
        .expect("spawn must fail for a missing artifacts root");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("failed to start"),
        "error should come from the readiness handshake: {msg}"
    );
}

#[test]
fn server_streams_ordered_events() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let handle = spawn(root, cfg(2, 16)).unwrap();
    // submit from a separate producer thread (the server contract)
    let tx = handle.tx.clone();
    let producer = std::thread::spawn(move || {
        for i in 0..3u64 {
            let _ = tx.send(p_eagle::coordinator::ServerMsg::Submit(Request::new(
                i,
                prompt(i),
                4 + 4 * i as usize,
            )));
        }
    });
    producer.join().unwrap();

    // results stream out as requests finish — no Drain round-trip
    #[derive(Default)]
    struct Seen {
        admitted: usize,
        streamed: Vec<i32>,
        finished: Option<Vec<i32>>,
    }
    let mut seen: HashMap<u64, Seen> = HashMap::new();
    let mut finished = 0usize;
    while finished < 3 {
        let ev = handle
            .events_rx
            .recv_timeout(std::time::Duration::from_secs(300))
            .expect("server event");
        match ev {
            ServerEvent::Admitted { id, slot } => {
                let s = seen.entry(id).or_default();
                assert_eq!(s.admitted, 0);
                assert!(slot < 2);
                s.admitted += 1;
            }
            ServerEvent::Tokens { id, tokens } => {
                let s = seen.entry(id).or_default();
                assert_eq!(s.admitted, 1, "req {id} tokens before admission");
                assert!(s.finished.is_none());
                s.streamed.extend(tokens);
            }
            ServerEvent::Finished(r) => {
                assert!(!r.tokens.is_empty());
                assert!(r.tokens.len() <= 16);
                let s = seen.entry(r.id).or_default();
                assert_eq!(s.admitted, 1);
                s.finished = Some(r.tokens);
                finished += 1;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    let mut ids: Vec<u64> = seen.keys().copied().collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2]);
    for (id, s) in &seen {
        let fin = s.finished.as_ref().unwrap();
        assert_eq!(&s.streamed, fin, "req {id}: streamed != final tokens");
    }

    let metrics = handle.shutdown();
    assert!(metrics.requests_finished >= 3);
    assert!(metrics.tokens_emitted >= 3);
    assert!(metrics.mean_occupancy() > 0.0);
    assert_eq!(metrics.ttfts.len(), 3);
}

#[test]
fn server_abort_and_reject() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let handle = spawn(root, cfg(1, 64)).unwrap();

    // a prompt below the drafter context window is rejected at validation
    handle.submit(Request::new(50, vec![1, 2], 8));
    // a long request we abort mid-stream
    handle.submit(Request::new(51, prompt(0), 64));

    let mut finish: Option<FinishReason> = None;
    let mut rejected = false;
    let mut sent_abort = false;
    while !(finish.is_some() && rejected) {
        let ev = handle
            .events_rx
            .recv_timeout(std::time::Duration::from_secs(300))
            .expect("server event");
        match ev {
            ServerEvent::Rejected { id, .. } => {
                assert_eq!(id, 50);
                rejected = true;
            }
            ServerEvent::Tokens { id, .. } => {
                assert_eq!(id, 51);
                if !sent_abort {
                    handle.abort(51);
                    sent_abort = true;
                }
            }
            ServerEvent::Finished(r) => {
                assert_eq!(r.id, 51);
                finish = Some(r.finish);
            }
            ServerEvent::Admitted { .. } => {}
            ServerEvent::EngineError(e) => panic!("engine error: {e}"),
        }
    }
    assert!(sent_abort, "request 51 never streamed a token");
    let metrics = handle.shutdown();
    // the abort usually lands mid-flight; if the request finished in the
    // race window the abort becomes a no-op, which is also correct
    if finish == Some(FinishReason::Aborted) {
        assert_eq!(metrics.requests_aborted, 1);
    }
}
