//! Threaded server front-end integration test — requires `make artifacts`.

use p_eagle::coordinator::server::spawn;
use p_eagle::coordinator::{EngineConfig, RequestSpec, Sampling};

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

#[test]
fn server_round_trip() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let cfg = EngineConfig {
        target: "target-m".into(),
        drafter: "target-m-pe4".into(),
        k: 5,
        batch: 2,
        max_new_tokens: 16,
        sampling: Sampling::Greedy,
        seed: 1,
    };
    let handle = spawn(root, cfg, vec![1, 2]).unwrap();
    // submit from a separate producer thread (the server contract)
    let tx = handle.tx.clone();
    let producer = std::thread::spawn(move || {
        for i in 0..3u64 {
            let prompt: Vec<i32> = std::iter::once(1)
                .chain((0..15).map(|j| 4 + ((i as i32) * 31 + j) % 200))
                .collect();
            let _ = tx.send(p_eagle::coordinator::server::ServerMsg::Submit(RequestSpec {
                id: i,
                prompt,
                max_new_tokens: 16,
                arrival_s: 0.0,
            }));
        }
    });
    producer.join().unwrap();
    handle.drain();

    let mut got = Vec::new();
    for _ in 0..3 {
        let r = handle
            .results_rx
            .recv_timeout(std::time::Duration::from_secs(300))
            .expect("server result");
        assert!(!r.tokens.is_empty());
        assert!(r.tokens.len() <= 16);
        got.push(r.id);
    }
    got.sort_unstable();
    assert_eq!(got, vec![0, 1, 2]);

    let metrics = handle.shutdown();
    assert!(metrics.requests_finished >= 3);
    assert!(metrics.tokens_emitted >= 3);
}
