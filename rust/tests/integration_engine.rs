//! Engine integration tests — require `make artifacts`.
//!
//! The headline property: greedy speculative decoding is LOSSLESS — the
//! engine's output must be byte-identical to the target model's own greedy
//! continuation, for BOTH drafting methods. This is the invariant that makes
//! the paper's OTPS comparison an apples-to-apples one.

use p_eagle::coordinator::{run_closed_loop, EngineConfig, FinishReason, Sampling};
use p_eagle::runtime::{HostTensor, ModelRuntime};
use p_eagle::workload::RequestSpec;

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(r) => r,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

/// Reference greedy decode using only the target executables (no drafter):
/// chunk = [last, PAD...], take row 0's argmax each iteration.
fn reference_greedy(
    mr: &mut ModelRuntime,
    target: &str,
    prompt: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let k = mr.manifest.default_k;
    let te = mr.ensure_target(target, 1, k).unwrap();
    let p = mr.manifest.prompt_pad;
    let vocab = mr.manifest.vocab;
    let mut padded = vec![mr.manifest.pad_id; p];
    padded[..prompt.len()].copy_from_slice(prompt);
    let kv = mr.zero_kv(target, 1).unwrap();
    let pre = mr
        .prefill(
            &te,
            &HostTensor::i32(&[1, p], padded),
            &HostTensor::i32(&[1], vec![prompt.len() as i32]),
            &kv,
        )
        .unwrap();
    let argmax = |row: &[f32]| -> i32 {
        let mut bi = 0;
        for (i, &x) in row.iter().enumerate() {
            if x > row[bi] {
                bi = i;
            }
        }
        bi as i32
    };
    let mut out = vec![argmax(pre.last_logits.as_f32().unwrap())];
    let mut kv = pre.kv;
    let mut cache_len = prompt.len();
    while out.len() < max_new && *out.last().unwrap() != mr.manifest.eos_id {
        let mut chunk = vec![0i32; k + 1];
        chunk[0] = *out.last().unwrap();
        let v = mr
            .verify(
                &te,
                &HostTensor::i32(&[1, k + 1], chunk),
                &HostTensor::i32(&[1], vec![cache_len as i32]),
                &kv,
            )
            .unwrap();
        kv = v.kv;
        let logits = v.logits.as_f32().unwrap();
        out.push(argmax(&logits[..vocab]));
        cache_len += 1;
    }
    out
}

fn engine_greedy(mr: &mut ModelRuntime, drafter: &str, prompt: &[i32], max_new: usize) -> Vec<i32> {
    let target = mr.manifest.drafter(drafter).unwrap().target.clone();
    let cfg = EngineConfig {
        target,
        drafter: drafter.into(),
        k: mr.manifest.default_k,
        batch: 1,
        max_new_tokens: max_new,
        sampling: Sampling::Greedy,
        seed: 5,
    };
    let spec = RequestSpec { id: 0, prompt: prompt.to_vec(), max_new_tokens: max_new, arrival_s: 0.0 };
    let mut given = Some(spec);
    let (results, _) = run_closed_loop(mr, &cfg, 1, 1, || given.take().unwrap()).unwrap();
    results.into_iter().next().unwrap().tokens
}

fn test_prompt(mr: &ModelRuntime, seed: u64) -> Vec<i32> {
    let regime = mr.manifest.regimes["humaneval"].clone();
    let mut rng = p_eagle::util::rng::Rng::new(seed);
    regime.sample_seq(16, &mut rng)
}

#[test]
fn spec_decoding_is_lossless_peagle() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    for seed in [1u64, 2, 3] {
        let prompt = test_prompt(&mr, seed);
        let want = reference_greedy(&mut mr, "target-m", &prompt, 40);
        let got = engine_greedy(&mut mr, "target-m-pe4", &prompt, 40);
        assert_eq!(got, want, "P-EAGLE engine diverged from greedy (seed {seed})");
    }
}

#[test]
fn spec_decoding_is_lossless_ar() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    for seed in [4u64, 5] {
        let prompt = test_prompt(&mr, seed);
        let want = reference_greedy(&mut mr, "target-m", &prompt, 40);
        let got = engine_greedy(&mut mr, "target-m-ar", &prompt, 40);
        assert_eq!(got, want, "AR engine diverged from greedy (seed {seed})");
    }
}

#[test]
fn both_methods_emit_identical_tokens() {
    // corollary of losslessness, checked directly across methods + batch>1
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompt = test_prompt(&mr, 9);
    let a = engine_greedy(&mut mr, "target-m-pe4", &prompt, 32);
    let b = engine_greedy(&mut mr, "target-m-ar", &prompt, 32);
    assert_eq!(a, b);
}

#[test]
fn batched_wave_matches_single() {
    // each request in a C=2 wave must produce the same tokens as alone
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let p1 = test_prompt(&mr, 11);
    let p2 = test_prompt(&mr, 12);
    let solo1 = engine_greedy(&mut mr, "target-m-pe4", &p1, 24);
    let solo2 = engine_greedy(&mut mr, "target-m-pe4", &p2, 24);

    let cfg = EngineConfig {
        target: "target-m".into(),
        drafter: "target-m-pe4".into(),
        k: 5,
        batch: 2,
        max_new_tokens: 24,
        sampling: Sampling::Greedy,
        seed: 5,
    };
    let mut reqs = vec![
        RequestSpec { id: 0, prompt: p1, max_new_tokens: 24, arrival_s: 0.0 },
        RequestSpec { id: 1, prompt: p2, max_new_tokens: 24, arrival_s: 0.0 },
    ]
    .into_iter();
    let (mut results, _) = run_closed_loop(&mut mr, &cfg, 2, 2, || reqs.next().unwrap()).unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results[0].tokens, solo1);
    assert_eq!(results[1].tokens, solo2);
}

#[test]
fn acceptance_length_in_valid_range() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompt = test_prompt(&mr, 21);
    let cfg = EngineConfig {
        target: "target-m".into(),
        drafter: "target-m-pe4".into(),
        k: 5,
        batch: 1,
        max_new_tokens: 40,
        sampling: Sampling::Greedy,
        seed: 5,
    };
    let spec = RequestSpec { id: 0, prompt, max_new_tokens: 40, arrival_s: 0.0 };
    let mut given = Some(spec);
    let (results, metrics) = run_closed_loop(&mut mr, &cfg, 1, 1, || given.take().unwrap()).unwrap();
    let al = results[0].acceptance_length();
    assert!(al >= 1.0 && al <= 6.0, "AL {al} outside [1, K+1]");
    assert!(metrics.acceptance_length() >= 1.0);
    assert_eq!(results[0].finish, FinishReason::Length);
}

#[test]
fn max_new_tokens_respected() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompt = test_prompt(&mr, 31);
    for max_new in [1usize, 7, 23] {
        let got = engine_greedy(&mut mr, "target-m-pe4", &prompt, max_new);
        assert!(got.len() <= max_new, "{} > {max_new}", got.len());
    }
}
