//! Engine integration tests — require `make artifacts`.
//!
//! The headline property: greedy speculative decoding is LOSSLESS — the
//! engine's output must be byte-identical to the target model's own greedy
//! continuation, for BOTH drafting methods. This is the invariant that makes
//! the paper's OTPS comparison an apples-to-apples one. The stepped
//! `EngineCore` additionally has to preserve it under continuous batching:
//! mid-flight admission into a freed slot must not perturb the rows that
//! stayed live.

use p_eagle::coordinator::{
    adaptive_from_env, multi_drafter_from_env, device_commit_from_env, run_closed_loop,
    tree_dyn_from_env,
    EngineConfig,
    EngineCore, EngineEvent, FinishReason, Request, SamplingParams, SpecPolicy,
};
use p_eagle::masking::TreeTopology;
use p_eagle::runtime::{HostTensor, ModelRuntime};

/// Default policy for the env-driven CI modes: PEAGLE_TREE_DYN=1 flips the
/// suite into dynamic tree speculation, otherwise chain at `k`.
fn default_policy(drafter: &str, k: usize) -> SpecPolicy {
    match tree_dyn_from_env() {
        Some(d) => SpecPolicy::from_dynamic_config(drafter, &d),
        None => SpecPolicy::chain(drafter, k),
    }
}

/// PEAGLE_MULTI_DRAFTER=1 (the CI rust-multidrafter job) widens every
/// engine's allowlist with a second drafter + a second speculation mode:
/// the whole suite then runs with the multi-policy surface active (widened
/// write width, per-slot chunk accounting) while requests still use the
/// default policy — output must stay byte-identical.
fn env_extra_policies() -> Vec<SpecPolicy> {
    if multi_drafter_from_env() {
        vec![
            SpecPolicy::chain("target-m-ar", 5),
            SpecPolicy::tree("target-m-pe4", TreeTopology::from_widths(&[3, 2, 1, 1, 1])),
        ]
    } else {
        Vec::new()
    }
}

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(r) => r,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

/// Reference greedy decode using only the target executables (no drafter):
/// chunk = [last, PAD...], take row 0's argmax each iteration.
fn reference_greedy(
    mr: &mut ModelRuntime,
    target: &str,
    prompt: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let k = mr.manifest.default_k;
    let te = mr.ensure_target(target, 1, k).unwrap();
    let p = mr.manifest.prompt_pad;
    let vocab = mr.manifest.vocab;
    let mut padded = vec![mr.manifest.pad_id; p];
    padded[..prompt.len()].copy_from_slice(prompt);
    let kv = mr.zero_kv(target, 1).unwrap();
    let pre = mr
        .prefill(
            &te,
            &HostTensor::i32(&[1, p], padded),
            &HostTensor::i32(&[1], vec![prompt.len() as i32]),
            &kv,
        )
        .unwrap();
    let argmax = |row: &[f32]| -> i32 {
        let mut bi = 0;
        for (i, &x) in row.iter().enumerate() {
            if x > row[bi] {
                bi = i;
            }
        }
        bi as i32
    };
    let mut out = vec![argmax(pre.last_logits.as_f32().unwrap())];
    let mut kv = pre.kv;
    let mut cache_len = prompt.len();
    while out.len() < max_new && *out.last().unwrap() != mr.manifest.eos_id {
        let mut chunk = vec![0i32; k + 1];
        chunk[0] = *out.last().unwrap();
        let v = mr
            .verify(
                &te,
                &HostTensor::i32(&[1, k + 1], chunk),
                &HostTensor::i32(&[1], vec![cache_len as i32]),
                &kv,
            )
            .unwrap();
        kv = v.kv;
        let logits = v.logits.as_f32().unwrap();
        out.push(argmax(&logits[..vocab]));
        cache_len += 1;
    }
    out
}

fn engine_greedy(mr: &mut ModelRuntime, drafter: &str, prompt: &[i32], max_new: usize) -> Vec<i32> {
    let target = mr.manifest.drafter(drafter).unwrap().target.clone();
    // PEAGLE_TREE_DYN=1 (the CI tree-dyn job) runs this suite in dynamic
    // tree mode; PEAGLE_PAGED=1 (the paged job) on the paged KV cache;
    // PEAGLE_MULTI_DRAFTER=1 widens the allowlist (requests stay default);
    // PEAGLE_ADAPTIVE=1 (the adaptive job) routes policy-free admissions
    // through the controller — with this single-candidate allowlist it must
    // keep assigning the default policy, so output stays byte-identical
    let cfg = EngineConfig::new(target, default_policy(drafter, mr.manifest.default_k), 1, max_new)
        .with_policies(env_extra_policies())
        .with_seed(5)
        .with_paged(device_commit_from_env())
        .with_adaptive(adaptive_from_env());
    let mut given = Some(Request::new(0, prompt.to_vec(), max_new));
    let (results, _) = run_closed_loop(mr, &cfg, 1, 1, || given.take().unwrap()).unwrap();
    results.into_iter().next().unwrap().tokens
}

fn test_prompt(mr: &ModelRuntime, seed: u64) -> Vec<i32> {
    let regime = mr.manifest.regimes["humaneval"].clone();
    let mut rng = p_eagle::util::rng::Rng::new(seed);
    regime.sample_seq(16, &mut rng)
}

#[test]
fn spec_decoding_is_lossless_peagle() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    for seed in [1u64, 2, 3] {
        let prompt = test_prompt(&mr, seed);
        let want = reference_greedy(&mut mr, "target-m", &prompt, 40);
        let got = engine_greedy(&mut mr, "target-m-pe4", &prompt, 40);
        assert_eq!(got, want, "P-EAGLE engine diverged from greedy (seed {seed})");
    }
}

#[test]
fn spec_decoding_is_lossless_ar() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    for seed in [4u64, 5] {
        let prompt = test_prompt(&mr, seed);
        let want = reference_greedy(&mut mr, "target-m", &prompt, 40);
        let got = engine_greedy(&mut mr, "target-m-ar", &prompt, 40);
        assert_eq!(got, want, "AR engine diverged from greedy (seed {seed})");
    }
}

#[test]
fn both_methods_emit_identical_tokens() {
    // corollary of losslessness, checked directly across methods + batch>1
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompt = test_prompt(&mr, 9);
    let a = engine_greedy(&mut mr, "target-m-pe4", &prompt, 32);
    let b = engine_greedy(&mut mr, "target-m-ar", &prompt, 32);
    assert_eq!(a, b);
}

#[test]
fn batched_core_matches_single() {
    // each request in a width-2 core must produce the same tokens as alone
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let p1 = test_prompt(&mr, 11);
    let p2 = test_prompt(&mr, 12);
    let solo1 = engine_greedy(&mut mr, "target-m-pe4", &p1, 24);
    let solo2 = engine_greedy(&mut mr, "target-m-pe4", &p2, 24);

    let cfg = core_cfg(2, 24);
    let mut reqs =
        vec![Request::new(0, p1, 24), Request::new(1, p2, 24)].into_iter();
    let (mut results, _) = run_closed_loop(&mut mr, &cfg, 2, 2, || reqs.next().unwrap()).unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results[0].tokens, solo1);
    assert_eq!(results[1].tokens, solo2);
}

fn core_cfg(batch: usize, max_new: usize) -> EngineConfig {
    // PEAGLE_TREE_DYN=1 (the CI tree-dyn job) runs this suite in dynamic
    // tree mode; PEAGLE_PAGED=1 (the paged job) on the paged KV cache;
    // PEAGLE_MULTI_DRAFTER=1 widens the allowlist (requests stay default);
    // PEAGLE_ADAPTIVE=1 (the adaptive job) routes policy-free admissions
    // through the controller
    EngineConfig::new("target-m", default_policy("target-m-pe4", 5), batch, max_new)
        .with_policies(env_extra_policies())
        .with_seed(5)
        .with_paged(device_commit_from_env())
        .with_adaptive(adaptive_from_env())
}

fn spec(id: u64, prompt: &[i32], max_new: usize) -> Request {
    Request::new(id, prompt.to_vec(), max_new)
}

#[test]
fn midflight_admission_matches_solo() {
    // 3 requests through a width-2 core: the short one evicts early and the
    // queued third request is admitted into the freed slot while the second
    // is still decoding. Every request's tokens must match its solo greedy
    // run — per-slot prefill + KV splice must not perturb live rows.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompts: Vec<Vec<i32>> =
        [41u64, 42, 43].iter().map(|&s| test_prompt(&mr, s)).collect();
    let solo: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| engine_greedy(&mut mr, "target-m-pe4", p, 24))
        .collect();

    let budgets = [6usize, 24, 24]; // request 0 finishes first
    let mut core = EngineCore::new(&mut mr, core_cfg(2, 24)).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        core.add_request(spec(i as u64, p, budgets[i])).unwrap();
    }
    assert_eq!(core.queued(), 3);

    let mut results = Vec::new();
    let mut saw_midflight = false;
    while !core.is_idle() {
        let report = core.step(&mut mr).unwrap();
        if report.admitted > 0 && !results.is_empty() {
            saw_midflight = true; // an admission happened after an eviction
        }
        results.extend(report.into_finished());
    }
    assert!(saw_midflight, "request 2 was never admitted mid-flight");
    assert_eq!(results.len(), 3);
    results.sort_by_key(|r| r.id);
    // truncated request: prefix of its solo run (greedy => prefix-stable)
    assert_eq!(results[0].tokens[..], solo[0][..results[0].tokens.len()]);
    assert_eq!(results[0].tokens.len(), 6);
    assert_eq!(results[1].tokens, solo[1], "live row perturbed by admission");
    assert_eq!(results[2].tokens, solo[2], "mid-flight admitted row diverged");
    assert!(core.metrics.mean_occupancy() > 0.0);
    assert_eq!(core.metrics.admissions, 3);
}

#[test]
fn abort_frees_slot_for_reuse() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompt = test_prompt(&mr, 51);
    let mut core = EngineCore::new(&mut mr, core_cfg(1, 40)).unwrap();

    // abort while queued: empty partial result
    core.add_request(spec(6, &prompt, 40)).unwrap();
    core.add_request(spec(9, &prompt, 40)).unwrap();
    let queued = core.abort(9).expect("queued abort");
    assert_eq!(queued.finish, FinishReason::Aborted);
    assert!(queued.tokens.is_empty());

    // abort in-flight: partial tokens, slot freed immediately
    core.step(&mut mr).unwrap();
    core.step(&mut mr).unwrap();
    let res = core.abort(6).expect("in-flight abort");
    assert_eq!(res.finish, FinishReason::Aborted);
    assert!(!res.tokens.is_empty(), "in-flight abort returns partial tokens");
    assert!(core.is_idle());
    assert!(core.abort(6).is_none(), "double abort");

    // the freed slot admits a fresh request
    core.add_request(spec(8, &prompt, 8)).unwrap();
    let out = core.run_until_idle(&mut mr).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].id, 8);
    assert!(!out[0].tokens.is_empty() && out[0].tokens.len() <= 8);
    assert_eq!(core.metrics.requests_aborted, 2);
}

#[test]
fn single_request_deterministic_vs_seed() {
    // identical config + seed => identical token stream, twice over, for
    // both greedy and temperature sampling (the engine has no hidden
    // wall-clock or ordering dependence)
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompt = test_prompt(&mr, 61);
    for sampling in [SamplingParams::greedy(), SamplingParams::temperature(0.8, 13)] {
        let mut run = |mr: &mut ModelRuntime| {
            let cfg = core_cfg(1, 24);
            let mut g = Some(spec(0, &prompt, 24).with_sampling(sampling));
            let (results, _) =
                run_closed_loop(mr, &cfg, 1, 1, || g.take().unwrap()).unwrap();
            results.into_iter().next().unwrap().tokens
        };
        let a = run(&mut mr);
        let b = run(&mut mr);
        assert!(!a.is_empty());
        assert_eq!(a, b, "nondeterministic under {sampling:?}");
    }
}

#[test]
fn step_events_are_ordered_and_complete() {
    // per request: exactly one Admitted, then Tokens chunks, then one
    // Finished; concatenated Tokens == the final result's tokens
    use std::collections::HashMap;
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let mut core = EngineCore::new(&mut mr, core_cfg(2, 16)).unwrap();
    for i in 0..4u64 {
        let p = test_prompt(&mr, 70 + i);
        core.add_request(spec(i, &p, 4 + 4 * i as usize)).unwrap();
    }
    #[derive(Default)]
    struct Seen {
        admitted: usize,
        streamed: Vec<i32>,
        finished: Option<Vec<i32>>,
    }
    let mut seen: HashMap<u64, Seen> = HashMap::new();
    while !core.is_idle() {
        for ev in core.step(&mut mr).unwrap().events {
            match ev {
                EngineEvent::Admitted { id, slot } => {
                    let s = seen.entry(id).or_default();
                    assert_eq!(s.admitted, 0, "req {id} admitted twice");
                    assert!(s.streamed.is_empty(), "req {id} tokens before admission");
                    assert!(slot < 2);
                    s.admitted += 1;
                }
                EngineEvent::Tokens { id, tokens } => {
                    let s = seen.entry(id).or_default();
                    assert_eq!(s.admitted, 1, "req {id} tokens without admission");
                    assert!(s.finished.is_none(), "req {id} tokens after finish");
                    s.streamed.extend(tokens);
                }
                EngineEvent::Finished(r) => {
                    let s = seen.entry(r.id).or_default();
                    assert_eq!(s.admitted, 1, "req {} finished without admission", r.id);
                    assert!(s.finished.is_none(), "req {} finished twice", r.id);
                    s.finished = Some(r.tokens);
                }
            }
        }
    }
    assert_eq!(seen.len(), 4);
    for (id, s) in seen {
        let fin = s.finished.unwrap_or_else(|| panic!("req {id} never finished"));
        assert_eq!(s.streamed, fin, "req {id}: streamed tokens != result tokens");
    }
}

#[test]
fn acceptance_length_in_valid_range() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompt = test_prompt(&mr, 21);
    let cfg = core_cfg(1, 40);
    let mut given = Some(Request::new(0, prompt, 40));
    let (results, metrics) = run_closed_loop(&mut mr, &cfg, 1, 1, || given.take().unwrap()).unwrap();
    let al = results[0].acceptance_length();
    assert!(al >= 1.0 && al <= 6.0, "AL {al} outside [1, K+1]");
    assert!(metrics.acceptance_length() >= 1.0);
    assert_eq!(results[0].finish, FinishReason::Length);
}

#[test]
fn chain_topology_tree_is_byte_identical_to_chain() {
    // THE degenerate-tree parity criterion: an engine configured with the
    // linear chain-5 topology (tree executables, tree acceptance, tree KV
    // commit) must produce byte-identical tokens AND acceptance lengths to
    // the classic chain path, on the same seeds. This is what licenses
    // shipping tree speculation as a topology choice rather than a fork.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    for seed in [81u64, 82, 83] {
        let prompt = test_prompt(&mr, seed);
        let run = |mr: &mut ModelRuntime, tree: Option<TreeTopology>| {
            // explicit static tree: the env-driven dynamic mode must yield
            let policy = match tree {
                Some(t) => SpecPolicy::tree("target-m-pe4", t),
                None => SpecPolicy::chain("target-m-pe4", 5),
            };
            let mut cfg = core_cfg(1, 32);
            cfg.default_policy = policy;
            let mut g =
                Some(spec(0, &prompt, 32));
            let (results, metrics) =
                run_closed_loop(mr, &cfg, 1, 1, || g.take().unwrap()).unwrap();
            let r = results.into_iter().next().unwrap();
            (r.tokens, r.accepted_sum, r.iterations, metrics.acceptance_length())
        };
        let chain = run(&mut mr, None);
        let tree = run(&mut mr, Some(TreeTopology::chain(5)));
        assert_eq!(tree.0, chain.0, "tokens diverged (seed {seed})");
        assert_eq!(tree.1, chain.1, "accepted_sum diverged (seed {seed})");
        assert_eq!(tree.2, chain.2, "iterations diverged (seed {seed})");
        assert!((tree.3 - chain.3).abs() < 1e-12, "AL diverged (seed {seed})");
    }
}

#[test]
fn branching_tree_is_lossless_and_al_dominates_chain() {
    // A branching tree must (a) stay lossless — greedy tree speculation
    // still emits exactly the target's own greedy continuation — and
    // (b) match or beat the chain's acceptance length on the same workload
    // (it embeds the rank-0 chain, so it accepts at least as deep).
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let tree = TreeTopology::from_widths(&[3, 2, 1, 1, 1]);
    let mut chain_al = 0.0;
    let mut tree_al = 0.0;
    for seed in [91u64, 92] {
        let prompt = test_prompt(&mr, seed);
        let want = reference_greedy(&mut mr, "target-m", &prompt, 32);
        let run = |mr: &mut ModelRuntime, t: Option<TreeTopology>| {
            let policy = match t {
                Some(t) => SpecPolicy::tree("target-m-pe4", t),
                None => SpecPolicy::chain("target-m-pe4", 5),
            };
            let mut cfg = core_cfg(1, 32);
            cfg.default_policy = policy;
            let mut g = Some(spec(0, &prompt, 32));
            let (results, _) =
                run_closed_loop(mr, &cfg, 1, 1, || g.take().unwrap()).unwrap();
            results.into_iter().next().unwrap()
        };
        let rc = run(&mut mr, None);
        let rt = run(&mut mr, Some(tree.clone()));
        assert_eq!(rt.tokens, want, "tree engine diverged from greedy (seed {seed})");
        chain_al += rc.acceptance_length();
        tree_al += rt.acceptance_length();
    }
    assert!(
        tree_al + 1e-9 >= chain_al,
        "tree AL {tree_al:.3} < chain AL {chain_al:.3} on the same seeds"
    );
}

#[test]
fn max_new_tokens_respected() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompt = test_prompt(&mr, 31);
    for max_new in [1usize, 7, 23] {
        let got = engine_greedy(&mut mr, "target-m-pe4", &prompt, max_new);
        assert!(got.len() <= max_new, "{} > {max_new}", got.len());
    }
}
