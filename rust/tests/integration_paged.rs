//! Dense-vs-paged KV cache parity — requires `make artifacts`.
//!
//! The headline property: a fully provisioned block-paged engine is
//! byte-identical to the dense engine — same seed, same corpus, same token
//! streams AND acceptance lengths, for chain and tree speculation. The
//! indirection (pool gather → identical chunk forward → block scatter-back,
//! python/tests/test_paged.py pins the bitwise-logits half) plus the
//! lockstep allocator accounting (kv_cache.rs property tests pin that half)
//! make paged serving a deployment choice, not a fork.
//!
//! Also pinned here: paged tree commits never call the dense
//! `compact_kv_path` (`dense_compactions == 0`; accepted paths go through
//! the block planner), and a constrained block budget serializes admissions
//! without corrupting anyone's tokens.

use p_eagle::coordinator::{
    run_closed_loop, EngineConfig, EngineCore, EngineMetrics, PagedKvConfig, Request,
    SpecPolicy,
};
use p_eagle::masking::TreeTopology;
use p_eagle::runtime::ModelRuntime;

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(r) => r,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn cfg(batch: usize, max_new: usize, paged: Option<PagedKvConfig>) -> EngineConfig {
    // dense-vs-paged parity is asserted per explicit speculation mode below,
    // so the env-driven dynamic/multi-drafter defaults are NOT wired here
    // (several tests pin the default policy directly)
    EngineConfig::new("target-m", SpecPolicy::chain("target-m-pe4", 5), batch, max_new)
        .with_seed(5)
        .with_paged(paged)
}

fn tree_cfg(batch: usize, max_new: usize, paged: Option<PagedKvConfig>, t: TreeTopology) -> EngineConfig {
    EngineConfig::new("target-m", SpecPolicy::tree("target-m-pe4", t), batch, max_new)
        .with_seed(5)
        .with_paged(paged)
}

fn test_prompt(mr: &ModelRuntime, seed: u64) -> Vec<i32> {
    let regime = mr.manifest.regimes["humaneval"].clone();
    let mut rng = p_eagle::util::rng::Rng::new(seed);
    regime.sample_seq(16, &mut rng)
}

fn spec(id: u64, prompt: &[i32], max_new: usize) -> Request {
    Request::new(id, prompt.to_vec(), max_new)
}

/// Run one closed-loop request; returns (tokens, accepted_sum, iterations)
/// plus the engine metrics.
fn run_one(
    mr: &mut ModelRuntime,
    cfg: EngineConfig,
    prompt: &[i32],
    max_new: usize,
) -> ((Vec<i32>, usize, usize), EngineMetrics) {
    let mut g = Some(spec(0, prompt, max_new));
    let (results, metrics) = run_closed_loop(mr, &cfg, 1, 1, || g.take().unwrap()).unwrap();
    let r = results.into_iter().next().unwrap();
    ((r.tokens, r.accepted_sum, r.iterations), metrics)
}

#[test]
fn dense_and_paged_chain_are_byte_identical() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    for seed in [101u64, 102, 103] {
        let prompt = test_prompt(&mr, seed);
        let (dense, _) = run_one(&mut mr, cfg(1, 32, None), &prompt, 32);
        let (paged, pm) =
            run_one(&mut mr, cfg(1, 32, Some(PagedKvConfig::default())), &prompt, 32);
        assert_eq!(paged.0, dense.0, "tokens diverged (seed {seed})");
        assert_eq!(paged.1, dense.1, "accepted_sum diverged (seed {seed})");
        assert_eq!(paged.2, dense.2, "iterations diverged (seed {seed})");
        assert!(pm.mean_block_occupancy() > 0.0, "paged run reported no block occupancy");
        assert_eq!(pm.dense_compactions, 0);
    }
}

#[test]
fn dense_and_paged_tree_are_byte_identical() {
    // tree mode is the stress case: speculative scratch + non-contiguous
    // accepted-path commits. Byte parity must hold AND the paged engine must
    // commit through the block planner, never compact_kv_path.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let tree = TreeTopology::from_widths(&[3, 2, 1, 1, 1]);
    let mut dense_commits = 0usize;
    let mut paged_commits = 0usize;
    for seed in [111u64, 112, 113] {
        let prompt = test_prompt(&mr, seed);
        let cd = tree_cfg(1, 32, None, tree.clone());
        let cp = tree_cfg(1, 32, Some(PagedKvConfig::default()), tree.clone());
        let (dense, dm) = run_one(&mut mr, cd, &prompt, 32);
        let (paged, pm) = run_one(&mut mr, cp, &prompt, 32);
        assert_eq!(paged.0, dense.0, "tree tokens diverged (seed {seed})");
        assert_eq!(paged.1, dense.1, "tree accepted_sum diverged (seed {seed})");
        assert_eq!(paged.2, dense.2, "tree iterations diverged (seed {seed})");
        // the acceptance criterion: paged tree commits bypass compact_kv_path
        assert_eq!(pm.dense_compactions, 0, "paged engine used dense compaction");
        // both engines see the same accepted paths, so they must agree on
        // how many needed a non-contiguous commit
        assert_eq!(pm.paged_path_commits, dm.dense_compactions, "commit counts diverged");
        dense_commits += dm.dense_compactions;
        paged_commits += pm.paged_path_commits;
    }
    assert_eq!(paged_commits, dense_commits);
}

#[test]
fn chain_topology_tree_paged_matches_dense_chain() {
    // transitivity check across BOTH axes at once: paged + chain-shaped tree
    // == dense + classic chain, byte for byte
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompt = test_prompt(&mr, 121);
    let (dense, _) = run_one(&mut mr, cfg(1, 24, None), &prompt, 24);
    let cp = tree_cfg(1, 24, Some(PagedKvConfig::default()), TreeTopology::chain(5));
    let (paged, pm) = run_one(&mut mr, cp, &prompt, 24);
    assert_eq!(paged.0, dense.0);
    assert_eq!(paged.1, dense.1);
    // chain paths are contiguous: nothing to commit on either path
    assert_eq!(pm.paged_path_commits, 0);
    assert_eq!(pm.block_rewires, 0);
}

#[test]
fn constrained_block_budget_serializes_without_corruption() {
    // A width-2 engine with a 3-block budget: prompt 16 + chunk 6 needs 2
    // blocks, so only one request fits at a time. The second must queue on
    // free blocks (admissions_blocked pressure), then run to completion —
    // and BOTH token streams must equal their unconstrained solo runs.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let p1 = test_prompt(&mr, 131);
    let p2 = test_prompt(&mr, 132);
    let (solo1, _) = run_one(&mut mr, cfg(1, 24, None), &p1, 24);
    let (solo2, _) = run_one(&mut mr, cfg(1, 24, None), &p2, 24);

    let paged = PagedKvConfig { block_size: None, num_blocks: Some(3), prefix_cache: false };
    let mut core = EngineCore::new(&mut mr, cfg(2, 24, Some(paged))).unwrap();
    core.add_request(spec(0, &p1, 24)).unwrap();
    core.add_request(spec(1, &p2, 24)).unwrap();
    let mut results = Vec::new();
    while !core.is_idle() {
        results.extend(core.step(&mut mr).unwrap().into_finished());
    }
    let metrics = core.into_metrics();
    assert_eq!(results.len(), 2);
    results.sort_by_key(|r| r.id);
    assert_eq!(results[0].tokens, solo1.0, "constrained run corrupted request 0");
    assert_eq!(results[1].tokens, solo2.0, "constrained run corrupted request 1");
    assert!(
        metrics.admissions_blocked > 0,
        "3-block budget never blocked an admission — gating is not engaged"
    );
    assert!(metrics.blocks_peak <= 3, "allocator exceeded its block budget");
}

#[test]
fn oversized_request_rejected_at_add_under_tight_budget() {
    // a request whose prompt + chunk can NEVER fit the block budget must be
    // rejected at add_request (not deadlock the admission queue)
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let paged = PagedKvConfig { block_size: None, num_blocks: Some(1), prefix_cache: false };
    let mut core = EngineCore::new(&mut mr, cfg(1, 8, Some(paged))).unwrap();
    let prompt = test_prompt(&mr, 141);
    let err = core.add_request(spec(0, &prompt, 8)).unwrap_err();
    assert!(err.to_string().contains("KV blocks"), "undescriptive error: {err}");
}
