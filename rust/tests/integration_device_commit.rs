//! Device-resident accepted-path commit — requires `make artifacts`.
//!
//! The headline property: a paged engine committing accepted paths ON
//! DEVICE (the `commit-path-paged` executable over the block pool) is
//! byte-identical to the same engine forced onto the host fallback
//! (download → apply_path_copies → upload) — same tokens, same acceptance
//! lengths, same iteration counts — for chain, static-tree, and
//! dynamic-tree speculation.
//!
//! Also pinned here, via the engine's transfer accounting
//! (EngineMetrics::kv_downloads counts engine KV-state round trips during
//! decode steps):
//! - steady-state paged decode performs ZERO host cache transfers — the
//!   device-commit engine holds `kv_downloads == 0` even in tree mode,
//!   where non-block-aligned accepted paths commit every few steps;
//! - the dense engine's commit arm makes at most ONE cache download per
//!   step (all of a bucket's compactions share one round trip).

use p_eagle::coordinator::{
    EngineConfig, EngineCore, EngineMetrics, PagedKvConfig, Request, RequestResult,
    SpecPolicy,
};
use p_eagle::masking::{DynamicTreeConfig, TreeTopology};
use p_eagle::runtime::ModelRuntime;

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(r) => r,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn cfg(policy: SpecPolicy, batch: usize, max_new: usize) -> EngineConfig {
    EngineConfig::new("target-m", policy, batch, max_new)
        .with_seed(5)
        .with_paged(Some(PagedKvConfig::default()))
}

fn test_prompt(mr: &ModelRuntime, seed: u64) -> Vec<i32> {
    let regime = mr.manifest.regimes["humaneval"].clone();
    let mut rng = p_eagle::util::rng::Rng::new(seed);
    regime.sample_seq(16, &mut rng)
}

/// Drive a core to idle; `host_commit` forces the host fallback arm.
fn run_core(
    mr: &mut ModelRuntime,
    cfg: EngineConfig,
    host_commit: bool,
    reqs: Vec<Request>,
) -> (Vec<RequestResult>, EngineMetrics) {
    let mut core = EngineCore::new(mr, cfg).unwrap();
    if host_commit {
        core.force_host_commit();
    }
    for r in reqs {
        core.add_request(r).unwrap();
    }
    let mut results = Vec::new();
    while !core.is_idle() {
        results.extend(core.step(mr).unwrap().into_finished());
    }
    results.sort_by_key(|r| r.id);
    (results, core.into_metrics())
}

/// Manifests lowered before `commit-path-paged` have no device arm to test.
fn device_commit_available(mr: &mut ModelRuntime) -> bool {
    let armed = EngineCore::new(mr, cfg(SpecPolicy::chain("target-m-pe4", 5), 1, 4))
        .unwrap()
        .device_commit_armed();
    if !armed {
        eprintln!("skipping: artifacts predate commit-path-paged (re-run `make artifacts`)");
    }
    armed
}

fn policies() -> Vec<(&'static str, SpecPolicy)> {
    vec![
        ("chain", SpecPolicy::chain("target-m-pe4", 5)),
        (
            "tree",
            SpecPolicy::tree("target-m-pe4", TreeTopology::from_widths(&[3, 2, 1, 1, 1])),
        ),
        (
            "dyn",
            SpecPolicy::from_dynamic_config(
                "target-m-pe4",
                &DynamicTreeConfig::serving_default(),
            ),
        ),
    ]
}

#[test]
fn device_commit_is_byte_identical_to_host_commit() {
    // chain / static-tree / dynamic-tree, three seeds each: the device and
    // host commit arms must agree on every token, acceptance sum, and
    // iteration count — and the tree modes must actually exercise the
    // device executable somewhere in the sweep (chain paths are contiguous,
    // so chain legitimately commits nothing).
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    if !device_commit_available(&mut mr) {
        return;
    }
    let mut tree_device_commits = 0usize;
    for (mode, policy) in policies() {
        for seed in [201u64, 202, 203] {
            let prompt = test_prompt(&mr, seed);
            let reqs = || vec![Request::new(0, prompt.clone(), 32)];
            let (host, hm) = run_core(&mut mr, cfg(policy.clone(), 1, 32), true, reqs());
            let (dev, dm) = run_core(&mut mr, cfg(policy.clone(), 1, 32), false, reqs());
            assert_eq!(dev[0].tokens, host[0].tokens, "{mode} tokens diverged (seed {seed})");
            assert_eq!(
                dev[0].accepted_sum, host[0].accepted_sum,
                "{mode} accepted_sum diverged (seed {seed})"
            );
            assert_eq!(
                dev[0].iterations, host[0].iterations,
                "{mode} iterations diverged (seed {seed})"
            );
            // both arms see the same accepted paths
            assert_eq!(dm.paged_path_commits, hm.paged_path_commits, "{mode} seed {seed}");
            assert_eq!(hm.device_path_commits, 0, "forced-host engine used the device arm");
            // the device engine NEVER round-trips the pool through the host
            assert_eq!(dm.kv_downloads, 0, "{mode} device engine downloaded KV (seed {seed})");
            assert_eq!(dm.kv_uploads, 0, "{mode} device engine uploaded KV (seed {seed})");
            if mode != "chain" {
                tree_device_commits += dm.device_path_commits;
                // whenever the host arm needed a pool round trip, the device
                // arm must have replaced it with a device commit
                assert_eq!(
                    dm.device_path_commits, hm.kv_downloads as usize,
                    "{mode} device commits != host round trips (seed {seed})"
                );
            }
        }
    }
    assert!(
        tree_device_commits > 0,
        "tree sweeps never hit the device commit arm — the parity check is vacuous"
    );
}

#[test]
fn steady_state_paged_decode_makes_zero_kv_downloads() {
    // THE tentpole invariant: once a request is admitted, paged decode keeps
    // the KV state device-resident — verify attends the pool in place
    // through the block table, accepted paths commit on device. Tree mode is
    // the hard case (non-aligned path commits every few steps) and must
    // still hold the counter at zero across a multi-request run.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    if !device_commit_available(&mut mr) {
        return;
    }
    let tree = SpecPolicy::tree("target-m-pe4", TreeTopology::from_widths(&[3, 2, 1, 1, 1]));
    let reqs = vec![
        Request::new(0, test_prompt(&mr, 211), 40),
        Request::new(1, test_prompt(&mr, 212), 40),
    ];
    let (results, m) = run_core(&mut mr, cfg(tree, 2, 40), false, reqs);
    assert_eq!(results.len(), 2);
    assert!(m.transfer_steps > 0, "run recorded no decode steps");
    assert_eq!(m.kv_downloads, 0, "steady-state paged decode downloaded the KV pool");
    assert_eq!(m.kv_uploads, 0, "steady-state paged decode uploaded the KV pool");
    assert!(
        m.paged_path_commits > 0,
        "tree run never committed a non-contiguous path — the invariant is vacuous"
    );
}

#[test]
fn dense_commit_arm_downloads_at_most_once_per_step() {
    // the dense regression pin: all of a step's compactions share ONE cache
    // round trip (single-bucket engines — one policy — make at most one
    // download per step, however many slots committed).
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let tree = SpecPolicy::tree("target-m-pe4", TreeTopology::from_widths(&[3, 2, 1, 1, 1]));
    let dense = EngineConfig::new("target-m", tree, 2, 40).with_seed(5);
    let reqs = vec![
        Request::new(0, test_prompt(&mr, 221), 40),
        Request::new(1, test_prompt(&mr, 222), 40),
    ];
    let (results, m) = run_core(&mut mr, dense, false, reqs);
    assert_eq!(results.len(), 2);
    assert!(m.dense_compactions > 0, "tree run never compacted — the pin is vacuous");
    assert!(
        m.kv_downloads <= m.transfer_steps as u64,
        "dense commit arm downloaded the cache more than once per step \
         ({} downloads over {} steps)",
        m.kv_downloads,
        m.transfer_steps
    );
    assert_eq!(m.kv_downloads, m.kv_uploads, "unpaired cache round trips");
    assert!(
        m.kv_downloads <= m.dense_compactions as u64,
        "more downloads than compaction events — the shared round trip regressed"
    );
}
