//! Multi-drafter / per-request-policy integration tests — require
//! `make artifacts`.
//!
//! The headline property (the PR's acceptance criterion): a SINGLE
//! `EngineCore` batch concurrently serves two distinct drafters under two
//! distinct speculation modes — an AR chain drafter, a P-EAGLE static-tree
//! drafter, and a P-EAGLE dynamic-envelope drafter in the same step loop —
//! and every request stays LOSSLESS (byte-identical to the target's own
//! greedy continuation). Also pinned:
//!
//! * homogeneous-policy engines are byte-identical whether the policy
//!   arrives as the engine default (the old engine-wide configuration
//!   path), as an explicit per-request policy, or with a widened allowlist
//!   sitting unused next to it — for chain, static tree, and dynamic
//!   modes, dense and paged;
//! * mixed-policy isolation: two slots with different drafters produce the
//!   same tokens as two single-policy engines run separately;
//! * per-slot adaptive dynamic budgets: one batch mixes budgets on shared
//!   executables, each slot charged (paged blocks) by its own budget;
//! * unsupported/unlisted policies fail with descriptive errors at
//!   construction or admission, never mid-flight;
//! * greedy requests stay byte-identical to the solo default engine even
//!   when batched next to a temperature-sampling neighbor — for chain,
//!   static tree, and dynamic modes, dense and paged (greedy acceptance
//!   consumes zero rng draws, so the neighbor's stream cannot leak in).

use p_eagle::coordinator::{
    run_closed_loop, EngineConfig, EngineCore, EngineMetrics, PagedKvConfig, Request,
    SamplingParams, SpecPolicy,
};
use p_eagle::masking::TreeTopology;
use p_eagle::runtime::{HostTensor, ModelRuntime};

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(r) => r,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn test_prompt(mr: &ModelRuntime, seed: u64) -> Vec<i32> {
    let regime = mr.manifest.regimes["humaneval"].clone();
    let mut rng = p_eagle::util::rng::Rng::new(seed);
    regime.sample_seq(16, &mut rng)
}

fn serving_tree() -> TreeTopology {
    TreeTopology::from_widths(&[3, 2, 1, 1, 1])
}

fn serving_envelope() -> TreeTopology {
    TreeTopology::from_widths(&[4, 4, 2, 2, 1])
}

/// Reference greedy decode using only the target executables (no drafter).
fn reference_greedy(
    mr: &mut ModelRuntime,
    target: &str,
    prompt: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let k = mr.manifest.default_k;
    let te = mr.ensure_target(target, 1, k).unwrap();
    let p = mr.manifest.prompt_pad;
    let vocab = mr.manifest.vocab;
    let mut padded = vec![mr.manifest.pad_id; p];
    padded[..prompt.len()].copy_from_slice(prompt);
    let kv = mr.zero_kv(target, 1).unwrap();
    let pre = mr
        .prefill(
            &te,
            &HostTensor::i32(&[1, p], padded),
            &HostTensor::i32(&[1], vec![prompt.len() as i32]),
            &kv,
        )
        .unwrap();
    let argmax = |row: &[f32]| -> i32 {
        let mut bi = 0;
        for (i, &x) in row.iter().enumerate() {
            if x > row[bi] {
                bi = i;
            }
        }
        bi as i32
    };
    let mut out = vec![argmax(pre.last_logits.as_f32().unwrap())];
    let mut kv = pre.kv;
    let mut cache_len = prompt.len();
    while out.len() < max_new && *out.last().unwrap() != mr.manifest.eos_id {
        let mut chunk = vec![0i32; k + 1];
        chunk[0] = *out.last().unwrap();
        let v = mr
            .verify(
                &te,
                &HostTensor::i32(&[1, k + 1], chunk),
                &HostTensor::i32(&[1], vec![cache_len as i32]),
                &kv,
            )
            .unwrap();
        kv = v.kv;
        let logits = v.logits.as_f32().unwrap();
        out.push(argmax(&logits[..vocab]));
        cache_len += 1;
    }
    out
}

/// One request through an engine whose DEFAULT policy is `policy` (the old
/// engine-wide configuration path).
fn run_default(
    mr: &mut ModelRuntime,
    policy: SpecPolicy,
    paged: Option<PagedKvConfig>,
    prompt: &[i32],
    max_new: usize,
) -> (Vec<i32>, usize, usize, EngineMetrics) {
    let cfg = EngineConfig::new("target-m", policy, 1, max_new)
        .with_seed(5)
        .with_paged(paged);
    let mut g = Some(Request::new(0, prompt.to_vec(), max_new));
    let (results, metrics) = run_closed_loop(mr, &cfg, 1, 1, || g.take().unwrap()).unwrap();
    let r = results.into_iter().next().unwrap();
    (r.tokens, r.accepted_sum, r.iterations, metrics)
}

#[test]
fn one_batch_serves_two_drafters_and_three_modes_losslessly() {
    // THE acceptance criterion. One width-4 engine; three concurrent
    // requests: AR chain drafting, P-EAGLE static-tree drafting, and
    // P-EAGLE dynamic-envelope drafting — 2 drafters, 3 speculation modes,
    // one shared target KV cache. Every request's tokens must equal the
    // target's own greedy continuation (losslessness is per-slot, so the
    // policy-grouped step must keep every bucket's writes out of everyone
    // else's committed cache).
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let policies = [
        SpecPolicy::chain("target-m-ar", 5),
        SpecPolicy::tree("target-m-pe4", serving_tree()),
        SpecPolicy::dynamic("target-m-pe4", serving_envelope(), 8),
    ];
    let prompts: Vec<Vec<i32>> =
        [201u64, 202, 203].iter().map(|&s| test_prompt(&mr, s)).collect();
    let want: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| reference_greedy(&mut mr, "target-m", p, 24))
        .collect();

    let cfg = EngineConfig::new("target-m", policies[0].clone(), 4, 24)
        .with_policies(policies[1..].to_vec())
        .with_seed(5);
    let mut core = EngineCore::new(&mut mr, cfg).unwrap();
    for (i, (p, pol)) in prompts.iter().zip(&policies).enumerate() {
        core.add_request(Request::new(i as u64, p.clone(), 24).with_policy(pol.clone()))
            .unwrap();
    }
    let first = core.step(&mut mr).unwrap();
    assert_eq!(first.admitted, 3, "all three policies must admit together");
    assert_eq!(first.occupied, 3, "the batch must actually mix the policies");
    let mut results = first.into_finished();
    while !core.is_idle() {
        results.extend(core.step(&mut mr).unwrap().into_finished());
    }
    assert_eq!(results.len(), 3);
    results.sort_by_key(|r| r.id);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(
            r.tokens, want[i],
            "request {i} ({}) diverged from target greedy in the mixed batch",
            policies[i].id()
        );
    }
    // per-drafter metrics split the batch: both drafters iterated
    let metrics = core.into_metrics();
    assert_eq!(metrics.per_policy.len(), 2, "expected 2 drafter keys");
    assert!(metrics.per_policy["target-m-ar"].iterations > 0);
    assert!(metrics.per_policy["target-m-pe4"].iterations > 0);
    assert!(
        metrics.per_policy["target-m-pe4"].steps
            > metrics.per_policy["target-m-pe4"].iterations / 2,
        "pe4 served two buckets (tree + dynamic) per step"
    );
}

#[test]
fn homogeneous_policy_matches_engine_wide_config_dense_and_paged() {
    // satellite: for chain, static tree, and dynamic modes, the SAME tokens
    // + AL must come out of (a) the engine-wide default-policy path (the
    // legacy configuration, requests carry no policy), (b) explicit
    // per-request policies routed through the allowlist, and (c) the
    // default path with a widened (unused) allowlist — dense and paged.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let modes = [
        SpecPolicy::chain("target-m-pe4", 5),
        SpecPolicy::tree("target-m-pe4", serving_tree()),
        SpecPolicy::dynamic("target-m-pe4", serving_envelope(), 8),
    ];
    let prompt = test_prompt(&mr, 211);
    for policy in &modes {
        for paged in [None, Some(PagedKvConfig::default())] {
            let (legacy_toks, legacy_acc, legacy_iters, lm) =
                run_default(&mut mr, policy.clone(), paged, &prompt, 24);

            // (b) explicit per-request policy on an engine whose default is
            // something ELSE entirely (the allowlist routes it)
            let cfg = EngineConfig::new("target-m", SpecPolicy::chain("target-m-ar", 5), 1, 24)
                .with_policies(vec![policy.clone()])
                .with_seed(5)
                .with_paged(paged);
            let mut g =
                Some(Request::new(0, prompt.clone(), 24).with_policy(policy.clone()));
            let (results, em) =
                run_closed_loop(&mut mr, &cfg, 1, 1, || g.take().unwrap()).unwrap();
            let r = results.into_iter().next().unwrap();
            assert_eq!(
                r.tokens, legacy_toks,
                "explicit {} diverged from the default-policy path (paged={})",
                policy.id(),
                paged.is_some()
            );
            assert_eq!(r.accepted_sum, legacy_acc);
            assert_eq!(r.iterations, legacy_iters);
            assert!(
                (em.acceptance_length() - lm.acceptance_length()).abs() < 1e-12,
                "AL diverged for {} (paged={})",
                policy.id(),
                paged.is_some()
            );

            // (c) widened allowlist, requests stay default: byte-identical
            let cfg = EngineConfig::new("target-m", policy.clone(), 1, 24)
                .with_policies(vec![
                    SpecPolicy::chain("target-m-ar", 5),
                    SpecPolicy::tree("target-m-pe4", serving_tree()),
                ])
                .with_seed(5)
                .with_paged(paged);
            let mut g = Some(Request::new(0, prompt.clone(), 24));
            let (results, _) =
                run_closed_loop(&mut mr, &cfg, 1, 1, || g.take().unwrap()).unwrap();
            assert_eq!(
                results[0].tokens, legacy_toks,
                "widened allowlist perturbed {} (paged={})",
                policy.id(),
                paged.is_some()
            );
        }
    }
}

#[test]
fn mixed_policy_slots_match_single_policy_engines() {
    // satellite: two slots with DIFFERENT drafters in one engine produce
    // exactly the tokens each produces alone in a single-policy engine —
    // the bucket passes are isolated.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let p1 = test_prompt(&mr, 221);
    let p2 = test_prompt(&mr, 222);
    let pe4 = SpecPolicy::chain("target-m-pe4", 5);
    let ar = SpecPolicy::chain("target-m-ar", 5);
    let (solo1, ..) = run_default(&mut mr, pe4.clone(), None, &p1, 24);
    let (solo2, ..) = run_default(&mut mr, ar.clone(), None, &p2, 24);

    let cfg = EngineConfig::new("target-m", pe4.clone(), 2, 24)
        .with_policies(vec![ar.clone()])
        .with_seed(5);
    let mut core = EngineCore::new(&mut mr, cfg).unwrap();
    core.add_request(Request::new(0, p1, 24).with_policy(pe4)).unwrap();
    core.add_request(Request::new(1, p2, 24).with_policy(ar)).unwrap();
    let mut results = Vec::new();
    while !core.is_idle() {
        results.extend(core.step(&mut mr).unwrap().into_finished());
    }
    assert_eq!(results.len(), 2);
    results.sort_by_key(|r| r.id);
    assert_eq!(results[0].tokens, solo1, "pe4 slot perturbed by the ar bucket");
    assert_eq!(results[1].tokens, solo2, "ar slot perturbed by the pe4 bucket");
}

#[test]
fn per_slot_dynamic_budgets_share_executables_and_charge_blocks_per_slot() {
    // satellite (per-slot adaptive budgets): two dynamic requests with
    // DIFFERENT node budgets share one exec key (no extra allowlist entry
    // needed), run in one bucket, and each emits exactly its solo-budget
    // tokens; in paged mode each slot reserves scratch coverage by its own
    // budget + 1 (mixed-budget admission charging).
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let p1 = test_prompt(&mr, 231);
    let p2 = test_prompt(&mr, 232);
    let small = SpecPolicy::dynamic("target-m-pe4", serving_envelope(), 3);
    let big = SpecPolicy::dynamic("target-m-pe4", serving_envelope(), 8);
    let (solo_small, ..) = run_default(&mut mr, small.clone(), None, &p1, 20);
    let (solo_big, ..) = run_default(&mut mr, big.clone(), None, &p2, 20);

    for paged in [None, Some(PagedKvConfig::default())] {
        let cfg = EngineConfig::new("target-m", big.clone(), 2, 20)
            .with_seed(5)
            .with_paged(paged);
        let mut core = EngineCore::new(&mut mr, cfg).unwrap();
        // `small` differs from the default only in budget: same exec key,
        // admitted without an allowlist entry
        core.add_request(Request::new(0, p1.clone(), 20).with_policy(small.clone()))
            .unwrap();
        core.add_request(Request::new(1, p2.clone(), 20).with_policy(big.clone())).unwrap();
        let mut results = Vec::new();
        while !core.is_idle() {
            results.extend(core.step(&mut mr).unwrap().into_finished());
        }
        assert_eq!(results.len(), 2);
        results.sort_by_key(|r| r.id);
        assert_eq!(
            results[0].tokens, solo_small,
            "budget-3 slot diverged in the mixed-budget batch (paged={})",
            paged.is_some()
        );
        assert_eq!(
            results[1].tokens, solo_big,
            "budget-8 slot diverged in the mixed-budget batch (paged={})",
            paged.is_some()
        );
    }
}

#[test]
fn greedy_requests_are_byte_identical_next_to_temperature_neighbors() {
    // satellite (greedy regression): a greedy request — even one stamped
    // with a non-default sampling seed, as serve/bench now stamp every
    // request — must emit byte-identical tokens whether it runs alone in a
    // default engine or shares a batch with a temperature-sampling
    // neighbor, across chain/static-tree/dynamic modes, dense and paged.
    // Greedy dispatch takes the legacy exact-match path and consumes ZERO
    // rng draws, so the neighbor's rejection-sampling stream has no channel
    // into this slot.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let modes = [
        SpecPolicy::chain("target-m-pe4", 5),
        SpecPolicy::tree("target-m-pe4", serving_tree()),
        SpecPolicy::dynamic("target-m-pe4", serving_envelope(), 8),
    ];
    let greedy_prompt = test_prompt(&mr, 251);
    let temp_prompt = test_prompt(&mr, 252);
    for policy in &modes {
        for paged in [None, Some(PagedKvConfig::default())] {
            let (solo, ..) = run_default(&mut mr, policy.clone(), paged, &greedy_prompt, 24);

            let cfg = EngineConfig::new("target-m", policy.clone(), 2, 24)
                .with_seed(5)
                .with_paged(paged);
            let mut core = EngineCore::new(&mut mr, cfg).unwrap();
            core.add_request(
                Request::new(0, greedy_prompt.clone(), 24).with_sampling(SamplingParams {
                    seed: 0x5EED,
                    ..SamplingParams::greedy()
                }),
            )
            .unwrap();
            core.add_request(
                Request::new(1, temp_prompt.clone(), 24)
                    .with_sampling(SamplingParams::temperature(0.8, 42).with_top_k(40)),
            )
            .unwrap();
            let mut results = Vec::new();
            while !core.is_idle() {
                results.extend(core.step(&mut mr).unwrap().into_finished());
            }
            assert_eq!(results.len(), 2);
            results.sort_by_key(|r| r.id);
            assert_eq!(
                results[0].tokens, solo,
                "greedy slot diverged next to a temperature neighbor under {} (paged={})",
                policy.id(),
                paged.is_some()
            );
            assert!(
                !results[1].tokens.is_empty(),
                "temperature neighbor produced no tokens under {}",
                policy.id()
            );
        }
    }
}

#[test]
fn unsupported_and_unlisted_policies_fail_descriptively() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();

    // capability gate at construction: the AR scan cannot tree-draft
    let cfg = EngineConfig::new("target-m", SpecPolicy::tree("target-m-ar", serving_tree()), 1, 8);
    let err = EngineCore::new(&mut mr, cfg).unwrap_err().to_string();
    assert!(
        err.contains("does not support tree"),
        "undescriptive capability error: {err}"
    );

    // unknown drafter at construction
    let cfg = EngineConfig::new("target-m", SpecPolicy::chain("no-such-drafter", 5), 1, 8);
    let err = EngineCore::new(&mut mr, cfg).unwrap_err().to_string();
    assert!(err.contains("unknown drafter"), "undescriptive error: {err}");

    // drafter serving a different target
    let cfg = EngineConfig::new("target-m", SpecPolicy::chain("target-l-pe4", 5), 1, 8);
    let err = EngineCore::new(&mut mr, cfg).unwrap_err().to_string();
    assert!(err.contains("serves target"), "undescriptive error: {err}");

    // allowlist gate at admission: a valid policy the engine wasn't
    // configured to serve is rejected at add_request, naming the allowlist
    let cfg = EngineConfig::new("target-m", SpecPolicy::chain("target-m-pe4", 5), 1, 8);
    let mut core = EngineCore::new(&mut mr, cfg).unwrap();
    let prompt = test_prompt(&mr, 241);
    let req = Request::new(0, prompt, 8).with_policy(SpecPolicy::chain("target-m-ar", 5));
    let err = core.add_request(req).unwrap_err().to_string();
    assert!(err.contains("not serveable"), "undescriptive allowlist error: {err}");
    assert!(err.contains("target-m-pe4/chain:5"), "error should name the allowlist: {err}");
}
