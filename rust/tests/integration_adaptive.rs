//! Adaptive-controller integration tests — require `make artifacts`.
//!
//! Two properties anchor the subsystem:
//!
//! 1. LOSSLESSNESS UNDER POLICY MIXING: with the controller assigning
//!    policies at admission and re-tuning in-flight dynamic budgets every
//!    step, greedy output must stay byte-identical to the target model's
//!    own greedy continuation — speculation policy is a throughput knob,
//!    never a quality knob.
//! 2. STATIC-ROW DOMINANCE: on the same workload seed, the adaptive run's
//!    OTPS must meet or beat every static `sweep_drafters` row — the
//!    controller's whole justification is that it lands on (at least) the
//!    best static configuration without being told which one that is.

use p_eagle::coordinator::{run_closed_loop, ControllerConfig, EngineConfig, Request};
use p_eagle::report;
use p_eagle::runtime::{HostTensor, ModelRuntime};

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(r) => r,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

/// Reference greedy decode using only the target executables (no drafter):
/// chunk = [last, PAD...], take row 0's argmax each iteration.
fn reference_greedy(
    mr: &mut ModelRuntime,
    target: &str,
    prompt: &[i32],
    max_new: usize,
) -> Vec<i32> {
    let k = mr.manifest.default_k;
    let te = mr.ensure_target(target, 1, k).unwrap();
    let p = mr.manifest.prompt_pad;
    let vocab = mr.manifest.vocab;
    let mut padded = vec![mr.manifest.pad_id; p];
    padded[..prompt.len()].copy_from_slice(prompt);
    let kv = mr.zero_kv(target, 1).unwrap();
    let pre = mr
        .prefill(
            &te,
            &HostTensor::i32(&[1, p], padded),
            &HostTensor::i32(&[1], vec![prompt.len() as i32]),
            &kv,
        )
        .unwrap();
    let argmax = |row: &[f32]| -> i32 {
        let mut bi = 0;
        for (i, &x) in row.iter().enumerate() {
            if x > row[bi] {
                bi = i;
            }
        }
        bi as i32
    };
    let mut out = vec![argmax(pre.last_logits.as_f32().unwrap())];
    let mut kv = pre.kv;
    let mut cache_len = prompt.len();
    while out.len() < max_new && *out.last().unwrap() != mr.manifest.eos_id {
        let mut chunk = vec![0i32; k + 1];
        chunk[0] = *out.last().unwrap();
        let v = mr
            .verify(
                &te,
                &HostTensor::i32(&[1, k + 1], chunk),
                &HostTensor::i32(&[1], vec![cache_len as i32]),
                &kv,
            )
            .unwrap();
        kv = v.kv;
        let logits = v.logits.as_f32().unwrap();
        out.push(argmax(&logits[..vocab]));
        cache_len += 1;
    }
    out
}

fn test_prompt(mr: &ModelRuntime, seed: u64) -> Vec<i32> {
    let regime = mr.manifest.regimes["humaneval"].clone();
    let mut rng = p_eagle::util::rng::Rng::new(seed);
    regime.sample_seq(16, &mut rng)
}

/// Adaptive engine config over the full controller allowlist (every
/// serveable drafter × shape, strongest first), hysteresis/cooldown cut
/// down so short test runs actually see controller actions.
fn adaptive_cfg(mr: &ModelRuntime, batch: usize, max_new: usize) -> EngineConfig {
    let mut allow =
        report::adaptive_allowlist(mr, "target-m", batch, mr.manifest.default_k, false);
    assert!(!allow.is_empty(), "testbed manifest must serve target-m");
    let default = allow.remove(0);
    let adaptive = ControllerConfig {
        window: 8,
        hysteresis_steps: 2,
        cooldown_steps: 2,
        ..ControllerConfig::default()
    };
    EngineConfig::new("target-m", default, batch, max_new)
        .with_policies(allow)
        .with_seed(5)
        .with_adaptive(Some(adaptive))
}

#[test]
fn adaptive_decoding_is_lossless() {
    // policy-free requests through a controller-fronted width-2 core: every
    // request's tokens must match its solo reference greedy run, whatever
    // mix of drafters/shapes/budgets the controller served them with
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompts: Vec<Vec<i32>> = (21u64..27).map(|s| test_prompt(&mr, s)).collect();
    let want: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| reference_greedy(&mut mr, "target-m", p, 32))
        .collect();

    let cfg = adaptive_cfg(&mr, 2, 32);
    let mut iter = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p.clone(), 32))
        .collect::<Vec<_>>()
        .into_iter();
    let (mut results, metrics) =
        run_closed_loop(&mut mr, &cfg, 2, prompts.len(), || iter.next().unwrap()).unwrap();
    results.sort_by_key(|r| r.id);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.tokens, want[i], "adaptive engine diverged from greedy (request {i})");
    }
    // the per-policy breakdown is keyed by policy identity, and every key
    // the controller served must be an allowlisted executable group
    let allowed: Vec<String> = std::iter::once(&cfg.default_policy)
        .chain(cfg.policies.iter())
        .map(|p| p.exec_key())
        .collect();
    assert!(!metrics.per_policy.is_empty());
    for key in metrics.per_policy.keys() {
        assert!(allowed.contains(key), "controller served un-allowlisted policy {key}");
    }
}

#[test]
fn adaptive_meets_or_beats_every_static_sweep_row() {
    // the subsystem's acceptance criterion: on the same workload seed, the
    // adaptive run's OTPS >= every static per-drafter sweep row (2% slack
    // absorbs wall-clock timer jitter — OTPS is a timed quantity even in
    // the closed loop)
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let k = mr.manifest.default_k;
    let (conc, total, max_new, seed) = (2, 10, 48, 11u64);
    let sampling = p_eagle::coordinator::SamplingParams::greedy();
    let rows = report::sweep_drafters(
        &mut mr, "target-m", "mtbench", k, conc, total, max_new, seed, true, None, sampling,
    )
    .unwrap();
    assert!(!rows.is_empty());
    let adaptive = report::bench_otps_adaptive(
        &mut mr, "target-m", "mtbench", k, conc, total, max_new, seed, true, None, sampling,
        None, ControllerConfig::default(),
    )
    .unwrap();
    for row in &rows {
        assert!(
            adaptive.otps >= row.otps * 0.98,
            "adaptive OTPS {:.0} fell below static row {} at {:.0}",
            adaptive.otps,
            row.drafter,
            row.otps,
        );
    }
}
