//! Prefix-cache parity — requires `make artifacts`.
//!
//! The headline property: the automatic prefix cache is INVISIBLE in the
//! output. On a shared-prefix workload (every prompt opens with the same
//! header, think system prompt / few-shot examples), the paged engine with
//! `prefix_cache` on must emit byte-identical token streams AND acceptance
//! lengths to the same engine with it off — for chain, static-tree, and
//! dynamic-tree speculation — while the metrics prove the cache actually
//! engaged (hits on every admission after the first, prompt tokens served
//! from cache, shared physical blocks at peak).
//!
//! Also pinned: a workload with NO sharing runs through the cache as pure
//! misses and stays byte-identical (the miss path is the old admission path),
//! and divergent tails after a shared header never cross-contaminate
//! (copy-on-write isolates the first divergent block).

use p_eagle::coordinator::{
    run_closed_loop, EngineConfig, EngineMetrics, PagedKvConfig, Request, RequestResult,
    SpecPolicy,
};
use p_eagle::masking::{DynamicTreeConfig, TreeTopology};
use p_eagle::runtime::ModelRuntime;
use p_eagle::util::rng::Rng;

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(r) => r,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

/// The three speculation shapes the parity claim covers.
fn policies() -> Vec<(&'static str, SpecPolicy)> {
    vec![
        ("chain", SpecPolicy::chain("target-m-pe4", 5)),
        (
            "tree",
            SpecPolicy::tree("target-m-pe4", TreeTopology::from_widths(&[3, 2, 1, 1, 1])),
        ),
        (
            "dyn",
            SpecPolicy::from_dynamic_config(
                "target-m-pe4",
                &DynamicTreeConfig::serving_default(),
            ),
        ),
    ]
}

fn cfg(policy: SpecPolicy, batch: usize, max_new: usize, prefix: bool) -> EngineConfig {
    EngineConfig::new("target-m", policy, batch, max_new)
        .with_seed(5)
        .with_paged(Some(PagedKvConfig {
            block_size: None,
            num_blocks: None,
            prefix_cache: prefix,
        }))
}

/// A shared-prefix workload: every prompt opens with the same 40-token
/// header (2.5 blocks at block size 16 — exercises whole-block sharing AND
/// the partial-tail copy-on-write claim) followed by a per-request tail.
fn shared_prefix_prompts(mr: &ModelRuntime, n: usize) -> Vec<Vec<i32>> {
    let mut hr = Rng::new(0x5A12);
    let header: Vec<i32> = (0..40).map(|_| (hr.below(246) + 4) as i32).collect();
    let regime = mr.manifest.regimes["humaneval"].clone();
    (0..n as u64)
        .map(|i| {
            let mut rng = Rng::new(900 + i);
            let mut p = header.clone();
            p.extend(regime.sample_seq(16, &mut rng));
            p
        })
        .collect()
}

/// Run `prompts` through a closed loop at the given concurrency; results
/// sorted by request id.
fn run_workload(
    mr: &mut ModelRuntime,
    cfg: &EngineConfig,
    prompts: &[Vec<i32>],
    concurrency: usize,
    max_new: usize,
) -> (Vec<RequestResult>, EngineMetrics) {
    let mut next_id = 0u64;
    let (mut results, metrics) = run_closed_loop(mr, cfg, concurrency, prompts.len(), || {
        let id = next_id;
        next_id += 1;
        Request::new(id, prompts[id as usize].clone(), max_new)
    })
    .unwrap();
    results.sort_by_key(|r| r.id);
    (results, metrics)
}

#[test]
fn prefix_cache_is_byte_identical_across_policies() {
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompts = shared_prefix_prompts(&mr, 4);
    for (name, policy) in policies() {
        let (off, _) =
            run_workload(&mut mr, &cfg(policy.clone(), 2, 24, false), &prompts, 2, 24);
        let (on, m) = run_workload(&mut mr, &cfg(policy, 2, 24, true), &prompts, 2, 24);
        for (a, b) in off.iter().zip(on.iter()) {
            assert_eq!(b.tokens, a.tokens, "{name}: tokens diverged (request {})", a.id);
            assert_eq!(
                b.accepted_sum, a.accepted_sum,
                "{name}: accepted_sum diverged (request {})",
                a.id
            );
        }
        // the cache engaged: only the first admission of the header misses
        assert!(m.prefix_hits >= 1, "{name}: shared-prefix workload never hit the cache");
        assert_eq!(
            m.prefix_hits + m.prefix_misses,
            prompts.len(),
            "{name}: every admission is a hit or a miss"
        );
        assert!(m.prefix_tokens_cached > 0, "{name}: hits served no cached prompt tokens");
        assert!(
            m.shared_blocks_peak >= 1,
            "{name}: no physical block was ever mapped by two slots"
        );
    }
}

#[test]
fn unshared_workload_is_all_misses_and_byte_identical() {
    // no common header: the cache sees only misses and must change nothing
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    // distinct FIRST tokens by construction: the index also offers root-level
    // sub-block matches, so a coincidental shared first token would be a
    // legitimate (if tiny) hit and make the all-misses assertion flaky
    let regime = mr.manifest.regimes["humaneval"].clone();
    let prompts: Vec<Vec<i32>> = (0..3u64)
        .map(|i| {
            let mut p = vec![4 + i as i32];
            p.extend(regime.sample_seq(15, &mut Rng::new(300 + i)));
            p
        })
        .collect();
    let policy = SpecPolicy::chain("target-m-pe4", 5);
    let (off, _) = run_workload(&mut mr, &cfg(policy.clone(), 2, 24, false), &prompts, 2, 24);
    let (on, m) = run_workload(&mut mr, &cfg(policy, 2, 24, true), &prompts, 2, 24);
    for (a, b) in off.iter().zip(on.iter()) {
        assert_eq!(b.tokens, a.tokens, "miss-path tokens diverged (request {})", a.id);
        assert_eq!(b.accepted_sum, a.accepted_sum);
    }
    // 16-token prompts share no block-aligned prefix across distinct seeds
    assert_eq!(m.prefix_hits, 0, "distinct prompts must not hit");
    assert_eq!(m.prefix_misses, prompts.len());
    assert_eq!(m.cow_copies, 0);
}

#[test]
fn divergent_tails_after_shared_header_do_not_cross_contaminate() {
    // the copy-on-write case distilled: identical 40-token header, tails that
    // differ in the FIRST tail token (so divergence lands inside the shared
    // partial block). Each stream must equal its own solo uncached run.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let mut hr = Rng::new(0x7E11);
    let header: Vec<i32> = (0..40).map(|_| (hr.below(246) + 4) as i32).collect();
    let prompts: Vec<Vec<i32>> = [7i32, 11]
        .iter()
        .map(|&t| {
            let mut p = header.clone();
            p.extend((0..8).map(|j| 4 + (t + 31 * j) % 200));
            p
        })
        .collect();
    let policy = SpecPolicy::chain("target-m-pe4", 5);
    let mut solos = Vec::new();
    for p in &prompts {
        let (r, _) =
            run_workload(&mut mr, &cfg(policy.clone(), 1, 24, false), &[p.clone()], 1, 24);
        solos.push(r.into_iter().next().unwrap());
    }
    let (on, m) = run_workload(&mut mr, &cfg(policy, 2, 24, true), &prompts, 2, 24);
    for (got, want) in on.iter().zip(solos.iter()) {
        assert_eq!(got.tokens, want.tokens, "COW leaked across requests");
        assert_eq!(got.accepted_sum, want.accepted_sum);
    }
    assert_eq!(m.prefix_hits, 1, "second admission must hit the first's header");
    // divergence inside the shared partial block forces a private copy
    assert!(m.cow_copies >= 1, "divergent tail in a shared block never copied");
}

#[test]
fn shared_prefix_ttft_smoke() {
    // TTFT sanity on the workload the cache exists for: both runs measure a
    // real first-token latency; the report cell (BENCH_<pr>.json `prefix`
    // column) tracks the collapse itself — wall-clock ratios are too noisy
    // to hard-gate in a unit test.
    let root = require_artifacts!();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let prompts = shared_prefix_prompts(&mr, 4);
    let policy = SpecPolicy::chain("target-m-pe4", 5);
    let (_, off) = run_workload(&mut mr, &cfg(policy.clone(), 2, 16, false), &prompts, 2, 16);
    let (_, on) = run_workload(&mut mr, &cfg(policy, 2, 16, true), &prompts, 2, 16);
    assert!(off.ttft_quantile(0.5) > std::time::Duration::ZERO);
    assert!(on.ttft_quantile(0.5) > std::time::Duration::ZERO);
    assert!(on.prefix_tokens_cached > 0, "cached run never served prompt tokens from cache");
}
