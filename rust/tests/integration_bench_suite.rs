//! Bench-suite integration tests — require `make artifacts`.
//!
//! Two contracts the perf trajectory stands on:
//!
//! 1. **Determinism**: two same-seed smoke runs agree exactly on everything
//!    outside the wall-clock payloads (`runner::deterministic_view` defines
//!    "outside": header timestamps, every cell's `timing`, and open-loop
//!    cells' metrics). Without this, a committed `BENCH_*.json` can't be
//!    re-checked and the comparator gates noise.
//! 2. **Gate semantics on real output**: a run compared against itself
//!    passes; the same run with a synthetic OTPS regression injected into
//!    one cell fails — the acceptance-criteria pair for `--compare`.

use p_eagle::bench::{compare, deterministic_view, run_suite, SuiteSpec, Thresholds};
use p_eagle::runtime::ModelRuntime;

fn artifacts() -> Option<String> {
    let root = std::env::var("PEAGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&root).join("manifest.json").exists().then_some(root)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(r) => r,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn smoke_spec() -> SuiteSpec {
    // even smaller than `--smoke`: this runs TWICE in one test
    let mut spec = SuiteSpec::new(true);
    spec.requests = 4;
    spec.max_new = 16;
    spec
}

#[test]
fn same_seed_smoke_runs_are_deterministic_modulo_wall_clock() {
    let root = require_artifacts!();
    let spec = smoke_spec();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let a = run_suite(&mut mr, &spec, "test").unwrap();
    // a fresh runtime, same seed: the trajectory must replay
    let mut mr2 = ModelRuntime::load(&root).unwrap();
    let b = run_suite(&mut mr2, &spec, "test").unwrap();
    assert!(!a.cells.is_empty(), "smoke matrix produced no cells");
    // full matrix coverage: both shapes axes appear (chain always; tree/dyn
    // whenever the artifacts lowered them — assert on what run A saw so the
    // test tracks the artifacts rather than hardcoding them)
    let va = deterministic_view(&a);
    let vb = deterministic_view(&b);
    assert_eq!(
        va.to_file_string(),
        vb.to_file_string(),
        "same-seed smoke runs diverged outside the wall-clock payloads"
    );
}

#[test]
fn compare_passes_self_and_fails_injected_regression() {
    let root = require_artifacts!();
    let spec = smoke_spec();
    let mut mr = ModelRuntime::load(&root).unwrap();
    let run = run_suite(&mut mr, &spec, "test").unwrap();

    // a run compared against itself: zero regressions (ratios are 1.0)
    let self_cmp = compare(&run, &run, Thresholds::default());
    assert!(!self_cmp.has_regressions(), "{}", self_cmp.render());

    // inject a synthetic regression into the first cell with nonzero OTPS:
    // halve it (far beyond the 10% threshold)
    let mut worse = run.clone();
    let cell = worse
        .cells
        .iter_mut()
        .find(|c| c.timing.otps > 0.0)
        .expect("at least one cell measured nonzero OTPS");
    cell.timing.otps /= 2.0;
    let cmp = compare(&run, &worse, Thresholds::default());
    assert!(cmp.has_regressions(), "{}", cmp.render());
    assert_eq!(cmp.regressions(), 1);

    // and dropping a cell (coverage loss) regresses too
    let mut shrunk = run.clone();
    shrunk.cells.pop();
    let cmp = compare(&run, &shrunk, Thresholds::default());
    assert!(cmp.has_regressions());

    // round-trip the real run through the schema: byte-identical
    let text = run.to_file_string();
    let parsed = p_eagle::bench::BenchReport::parse(&text).unwrap();
    assert_eq!(parsed.to_file_string(), text);
}
