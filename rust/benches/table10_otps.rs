//! Table 10 — Output Tokens Per Second across speculation depths K ∈ {3,5,7}
//! and concurrency C ∈ {2,4}, AR EAGLE-3 vs P-EAGLE, chain drafting.
//!
//! Paper shape to reproduce: AR throughput peaks at small K (drafting cost
//! grows ~K); P-EAGLE keeps gaining to K=5-7 (one pass regardless of K);
//! speedups ~1.1-1.36x at the best K; deeper drafter can lose at K=3.
//!
//!     cargo bench --bench table10_otps [-- --all-targets --quick --mixed]
//!
//! `--mixed` draws per-request generation budgets from the Fig.1 length
//! model instead of a fixed max_new — the workload where the stepped
//! engine's mid-flight admission shows up as high slot occupancy.

use p_eagle::coordinator::{paged_from_env, SamplingParams};
use p_eagle::report::bench_otps;
use p_eagle::runtime::ModelRuntime;
use p_eagle::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let all = args.iter().any(|a| a == "--all-targets");
    let quick = args.iter().any(|a| a == "--quick");
    let mixed = args.iter().any(|a| a == "--mixed");
    let (reqs_per_c, max_new) = if quick { (2usize, 48) } else { (2usize, 64) };

    let mut mr = ModelRuntime::load("artifacts")?;
    let targets: Vec<&str> = if all {
        vec!["target-l", "target-m", "target-s"]
    } else {
        vec!["target-m"]
    };
    let datasets = ["humaneval", "mtbench", "gsm8k"];

    for target in targets {
        println!("\n=== Table 10: OTPS — {target} ===");
        for c in [2usize, 4] {
            let total = reqs_per_c * c;
            let mut tab =
                Table::new(&["method", "K", "HE", "MT", "GSM", "HE AL", "MT AL", "GSM AL", "occ"]);
            let mut ar_best = [0f64; 3];
            for method in ["ar", "pe4"] {
                for k in [3usize, 5, 7] {
                    let mut cells = Vec::new();
                    let mut als = Vec::new();
                    let mut occ = 0f64;
                    for (di, ds) in datasets.iter().enumerate() {
                        let run = bench_otps(&mut mr, &format!("{target}-{method}"),
                                             ds, k, c, total, max_new, 99, mixed, None,
                                             None, paged_from_env(),
                                             SamplingParams::greedy())?;
                        if method == "ar" {
                            ar_best[di] = ar_best[di].max(run.otps);
                        }
                        cells.push(run.otps);
                        als.push(run.acceptance_length);
                        occ += run.mean_occupancy / datasets.len() as f64;
                    }
                    let fmt_cell = |di: usize| {
                        if method == "ar" {
                            format!("{:.0}", cells[di])
                        } else {
                            format!("{:.0} ({:.2}x)", cells[di],
                                    cells[di] / ar_best[di].max(1e-9))
                        }
                    };
                    tab.row(vec![
                        method.into(), k.to_string(),
                        fmt_cell(0), fmt_cell(1), fmt_cell(2),
                        format!("{:.2}", als[0]), format!("{:.2}", als[1]),
                        format!("{:.2}", als[2]), format!("{:.2}", occ),
                    ]);
                }
            }
            println!("\nC={c} ({total} requests/cell, max_new={max_new}):");
            tab.print();
        }
    }
    println!("\npaper shape: AR optimal at K=3; P-EAGLE scales to K=5-7; speedup 1.04-1.36x");
    Ok(())
}
