//! Table 1 — acceptance length vs TRAINING context length, plus the OOM /
//! Infeasible cells from the paper-scale memory model.
//!
//! Mini-testbed contexts {64,128,256,512} map to the paper's {1K,4K,8K,20K}
//! (DESIGN.md scale table). ParallelSpec/PARD acceptance is measured where
//! the paper could train them; infeasible/OOM cells are classified by
//! rust/src/memmodel (calibrated to the paper's own Table 2 measurement).
//!
//!     cargo bench --bench table1_context_scaling [-- --quick]

use p_eagle::memmodel::{classify, TrainSetup, EPOCH_EXAMPLES};
use p_eagle::report::eval_acceptance;
use p_eagle::runtime::ModelRuntime;
use p_eagle::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n_req, max_new) = if quick { (3, 48) } else { (6, 80) };
    let mut mr = ModelRuntime::load("artifacts")?;
    let k = mr.manifest.default_k;

    println!("=== Table 1: AL vs training context (target-l = GPT-OSS 120B analog, MT-Bench, K={k}) ===\n");
    let contexts = [(64usize, "1K", 1024usize), (128, "4K", 4096),
                    (256, "8K", 8192), (512, "20K", 20480)];

    let mut tab = Table::new(&["method", "layers", "1K", "4K", "8K", "20K"]);

    // ParallelSpec row: measured where feasible, OOM where the model says so
    let mut row = vec!["ParallelSpec + EAGLE-3".to_string(), "1".to_string()];
    for (n, _lbl, paper_n) in contexts {
        let cls = classify(&TrainSetup::parallelspec(paper_n, 8), EPOCH_EXAMPLES);
        row.push(match cls {
            p_eagle::memmodel::Feasibility::Ok => {
                let name = format!("target-l-ps-n{n}");
                if mr.manifest.drafters.contains_key(&name) {
                    let e = eval_acceptance(&mut mr, &name, "mtbench", k, n_req, max_new)?;
                    format!("{:.2}", e.acceptance_length)
                } else {
                    "-".into()
                }
            }
            other => other.label().to_string(),
        });
    }
    tab.row(row);

    // PARD row
    let mut row = vec!["PARD + EAGLE-3".to_string(), "4".to_string()];
    for (n, _lbl, paper_n) in contexts {
        let cls = classify(&TrainSetup::pard(paper_n, 8), EPOCH_EXAMPLES);
        row.push(match cls {
            p_eagle::memmodel::Feasibility::Ok => {
                let name = format!("target-l-pard-n{n}");
                if mr.manifest.drafters.contains_key(&name) {
                    let e = eval_acceptance(&mut mr, &name, "mtbench", k, n_req, max_new)?;
                    format!("{:.2}", e.acceptance_length)
                } else {
                    "-".into()
                }
            }
            other => other.label().to_string(),
        });
    }
    tab.row(row);

    // P-EAGLE row: measured at every context
    let mut row = vec!["Ours (P-EAGLE)".to_string(), "4".to_string()];
    for (n, _lbl, paper_n) in contexts {
        assert_eq!(
            classify(&TrainSetup::peagle(paper_n, 8), EPOCH_EXAMPLES),
            p_eagle::memmodel::Feasibility::Ok
        );
        let e = eval_acceptance(&mut mr, &format!("target-l-pe-n{n}"), "mtbench",
                                k, n_req, max_new)?;
        row.push(format!("{:.2}", e.acceptance_length));
    }
    tab.row(row);

    tab.print();
    println!("\npaper: ParallelSpec 1.5/1.6/OOM/OOM; PARD 2.4/Infeas./OOM/OOM; Ours 2.4/2.8/2.9/3.0");
    Ok(())
}
