//! Figure 3 — position-invariance of the cross-depth causal mask: the mask
//! for a shorter sequence is the top-left submatrix of a longer sequence's
//! mask, so per-example retrieval is a constant-time view.
//!
//! The bench demonstrates (a) the invariance property at several lengths,
//! (b) that slice_view cost is O(1) and independent of n, while from-scratch
//! construction grows ~O((nK)^2).
//!
//!     cargo bench --bench fig3_mask_slicing

use p_eagle::masking::{pard_full_mask, PrecomputedMask};
use p_eagle::util::bench::{bench, Table};

fn main() {
    let (n_max, k) = (2048usize, 8usize);
    println!("=== Figure 3: amortized mask slicing ===\n");
    let pm = PrecomputedMask::build(n_max, k);
    println!("built n_max={n_max} K={k} once ({} MB)\n", pm.memory_bytes() / 1_000_000);

    // (a) invariance check
    for n in [16usize, 64, 256, 1024] {
        let small = PrecomputedMask::build(n, k);
        let view = pm.slice_view(n);
        let sv = small.slice_view(n);
        for r in (0..n * k).step_by((n * k / 64).max(1)) {
            for c in (0..n * k).step_by((n * k / 64).max(1)) {
                assert_eq!(view.get(r, c), sv.get(r, c), "invariance ({r},{c}) n={n}");
            }
        }
    }
    println!("position-invariance verified for n ∈ {{16, 64, 256, 1024}} vs n_max\n");

    // (b) O(1) slicing vs O((nK)^2) construction
    let mut tab = Table::new(&["n", "slice_view (ours)", "from-scratch build"]);
    for n in [128usize, 512, 2048] {
        let s1 = bench(&format!("slice_view n={n}"), 3, 200, || {
            let v = pm.slice_view(n);
            std::hint::black_box(v.get(n * k - 1, 0));
        });
        let s2 = bench(&format!("full build n={n}"), 1, 3, || {
            std::hint::black_box(pard_full_mask(n, k));
        });
        tab.row(vec![
            n.to_string(),
            p_eagle::util::bench::fmt_ns(s1.mean_ns),
            p_eagle::util::bench::fmt_ns(s2.mean_ns),
        ]);
    }
    println!();
    tab.print();
}
