//! Algorithm 1 — sequence partitioning: cost of the algorithm itself and
//! the paper's O(L^2) -> O(L^2/S^2) peak-attention-memory claim (§3.2).
//!
//!     cargo bench --bench alg1_partitioning

use p_eagle::masking::cod_sample_nested;
use p_eagle::partition::{partition_rows, validate};
use p_eagle::util::bench::{bench, Table};
use p_eagle::util::rng::Rng;

fn main() {
    println!("=== Algorithm 1: sequence partitioning ===\n");
    let (n, k, r) = (8192usize, 8usize, 0.8);
    let mut rng = Rng::new(3);
    let anchors = cod_sample_nested(n, k, r, &mut rng);

    // partitioning cost
    for s in [2usize, 4, 8] {
        bench(&format!("partition n={n} K={k} S={s}"), 2, 20, || {
            std::hint::black_box(partition_rows(&anchors, n, k, s));
        });
    }
    println!();

    // peak attention cells vs S (the memory claim) + validation
    let mut tab = Table::new(&["S", "peak attn cells", "vs S=1", "paper model"]);
    let base = partition_rows(&anchors, n, k, 1).peak_attention_cells();
    for s in [1usize, 2, 4, 8, 16] {
        let part = partition_rows(&anchors, n, k, s);
        assert!(validate(&part, &anchors, n, k).is_empty());
        let peak = part.peak_attention_cells();
        tab.row(vec![
            s.to_string(),
            peak.to_string(),
            format!("{:.1}%", peak as f64 / base as f64 * 100.0),
            format!("O(L²/S²) → {:.1}%", 100.0 / (s * s) as f64),
        ]);
    }
    tab.print();
    println!("\n(the linear cumulative-key term makes large-S fall off slower than 1/S²,\n exactly as §3.2's 'plus cumulative depth-0 keys' notes)");
}
