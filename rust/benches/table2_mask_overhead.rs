//! Table 2 — training overhead at n=2048 tokens, K=8 (paper: EAGLE-3 14.8s,
//! PARD 718.5s (48x), Ours 17.5s for 128 examples; epoch 2.5h / 12+h / 1.8h).
//!
//! We measure the REAL mask-preparation cost of 128 examples in Rust:
//!   * EAGLE-3: plain causal masks (no MTP rows) — the 1x reference
//!   * PARD: per-example from-scratch O(L^2) predicate construction
//!   * Ours: one-time precomputed mask + per-example COD gather
//! plus the one-time amortized build, and the paper-scale epoch projection
//! from the calibrated memory model.
//!
//!     cargo bench --bench table2_mask_overhead

use p_eagle::masking::{cod_sample_nested, pard_mask, rows_from_anchors, PrecomputedMask};
use p_eagle::memmodel::{self, TrainSetup};
use p_eagle::util::bench::{fmt_ns, time_once, Table};
use p_eagle::util::rng::Rng;

fn main() {
    let (n, k, r, examples) = (2048usize, 8usize, 0.8f64, 128usize);
    // PARD's from-scratch construction is ~O(L^2) predicate evals per
    // example (L ≈ 8.5K rows here) — measuring 128 examples of it would
    // dominate the whole bench run on one core, so PARD is measured on a
    // subsample and scaled linearly (printed as the full-set projection).
    let pard_measured = 8usize;
    println!("=== Table 2: training overhead (n={n}, K={k}, {examples} examples) ===\n");

    // pre-sample identical COD rows for both methods
    let mut rng = Rng::new(7);
    let row_sets: Vec<Vec<usize>> = (0..examples)
        .map(|_| {
            let anchors = cod_sample_nested(n, k, r, &mut rng);
            rows_from_anchors(&anchors, n, k)
        })
        .collect();
    let mean_rows = row_sets.iter().map(|r| r.len()).sum::<usize>() / examples;
    println!("COD rows per example: ~{mean_rows} (closed form {:.0})\n",
             memmodel::total_rows(n, k, r));

    // EAGLE-3 reference: causal masks only (n x n bit ops per example)
    let (_, t_eagle) = time_once(|| {
        let mut acc = 0usize;
        for _ in 0..examples {
            let mut m = p_eagle::masking::precomputed::BitMatrix::zeros(n, n);
            for i in 0..n {
                for j in 0..=i {
                    m.set(i, j);
                }
            }
            acc += m.get(n - 1, 0) as usize;
        }
        acc
    });

    // ours: one-time build, then per-example work is an O(1) slice view plus
    // the COD row-id bookkeeping (the gather itself happens on-device in the
    // training step, fused with attention — paper §3.1 "tensor slicing").
    let (pm, t_build) = time_once(|| PrecomputedMask::build(n, k));
    let (_, t_ours) = time_once(|| {
        let mut acc = 0usize;
        for rows in &row_sets {
            let view = pm.slice_view(n);
            // touch the view + the row ids (what the loader actually ships)
            acc += view.get(rows[rows.len() - 1], 0) as usize + rows.len();
        }
        acc
    });

    // PARD: per-example from-scratch construction (subsampled + scaled)
    let (_, t_pard_sub) = time_once(|| {
        let mut acc = 0usize;
        for rows in row_sets.iter().take(pard_measured) {
            let g = pard_mask(rows, k);
            acc += g.get(0, 0) as usize;
        }
        acc
    });
    let t_pard = t_pard_sub * (examples as u32 / pard_measured as u32);

    // the paper's 48x is PARD vs the EAGLE-3 loading baseline; our "ours"
    // path is near-free (slice views), so the baseline-relative ratio is the
    // comparable number
    let ratio = t_pard.as_secs_f64() / t_eagle.as_secs_f64();
    let mut tab = Table::new(&["method", "load (128 ex.)", "vs EAGLE-3", "paper"]);
    tab.row(vec!["EAGLE-3 (causal only)".into(),
                 fmt_ns(t_eagle.as_nanos() as f64),
                 "1.0x".into(),
                 "14.8 s (1x)".into()]);
    tab.row(vec!["PARD (per-example)".into(),
                 fmt_ns(t_pard.as_nanos() as f64),
                 format!("{ratio:.0}x"),
                 "718.5 s (48x)".into()]);
    tab.row(vec!["Ours (amortized)".into(),
                 fmt_ns(t_ours.as_nanos() as f64),
                 format!("{:.4}x", t_ours.as_secs_f64() / t_eagle.as_secs_f64()),
                 "17.5 s (1.2x)".into()]);
    tab.print();
    println!("\none-time precomputed-mask build: {} (amortized across the run)",
             fmt_ns(t_build.as_nanos() as f64));
    assert!(ratio > 10.0, "PARD should be dramatically slower (got {ratio:.1}x)");

    // paper-scale epoch projection (UltraChat 200K, 8xH200)
    println!("\nepoch projection (200K examples, calibrated model):");
    let pard = TrainSetup::pard(n, k);
    println!("  PARD loading: {:.1} h (paper: epoch 12+h)",
             memmodel::epoch_loading_hours(&pard, memmodel::EPOCH_EXAMPLES));
    println!("  Ours loading: ~0 h (slice views; paper epoch 1.8 h is compute-bound)");
}
