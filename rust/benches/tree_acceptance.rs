//! Chain-vs-tree acceptance length — the EAGLE-3 argument at mini scale.
//!
//! Same drafter, same workload seed, same per-step depth budget: a K-chain
//! verifies one candidate continuation per step, a static draft tree
//! verifies every sibling branch of the same depth in the SAME single
//! target pass (cross-node ancestor mask, masking/tree.rs). Because every
//! lowered tree embeds the rank-0 chain, its acceptance length can only
//! match or beat the chain's — the delta column is the speed headroom tree
//! speculation buys before any kernel work.
//!
//!     cargo bench --bench tree_acceptance [-- --quick]
//!
//! Topologies must exist in the manifest (configs.TREE_TOPOLOGIES — rebuild
//! artifacts after adding profiles). Reports AL, OTPS, and the tree's
//! accepted-path KV commit overhead.

use p_eagle::coordinator::{paged_from_env, tree_dyn_from_env, SamplingParams};
use p_eagle::masking::TreeTopology;
use p_eagle::report::compare_chain_tree;
use p_eagle::runtime::ModelRuntime;
use p_eagle::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (reqs, max_new) = if quick { (4usize, 48) } else { (8usize, 64) };
    let mut mr = ModelRuntime::load("artifacts")?;
    let drafter = "target-m-pe4";
    let datasets = ["humaneval", "mtbench", "gsm8k"];
    let tree = TreeTopology::from_widths(&[3, 2, 1, 1, 1]);
    // PEAGLE_TREE_DYN=1 (the CI tree-dyn job) adds a dynamic-envelope run at
    // the same verified-node budget to every cell
    let dynamic = tree_dyn_from_env();

    println!(
        "=== chain vs tree acceptance — {drafter}, {} ({} nodes, depth {}), \
         C=2, {reqs} requests/cell ===\n",
        tree.id(),
        tree.len(),
        tree.max_depth()
    );
    let mut tab = Table::new(&[
        "dataset", "chain AL", "tree AL", "dyn AL", "ΔAL", "chain OTPS", "tree OTPS", "commit",
    ]);
    for ds in datasets {
        let (chain, treed, dyned) = compare_chain_tree(
            &mut mr, drafter, ds, &tree, dynamic.as_ref(), 2, reqs, max_new, 99, false,
            paged_from_env(), SamplingParams::greedy(),
        )?;
        assert!(
            treed.acceptance_length + 1e-9 >= chain.acceptance_length,
            "{ds}: tree AL {:.3} < chain AL {:.3} — the rank-0 chain embedding \
             guarantee is broken",
            treed.acceptance_length,
            chain.acceptance_length
        );
        tab.row(vec![
            ds.into(),
            format!("{:.2}", chain.acceptance_length),
            format!("{:.2}", treed.acceptance_length),
            dyned
                .as_ref()
                .map(|d| format!("{:.2}", d.acceptance_length))
                .unwrap_or_else(|| "-".into()),
            format!("{:+.2}", treed.acceptance_length - chain.acceptance_length),
            format!("{:.0}", chain.otps),
            format!("{:.0}", treed.otps),
            format!("{:?}", treed.metrics.commit_time),
        ]);
    }
    tab.print();
    println!(
        "\ntree verifies {}x the candidates of the chain per step at one extra \
         mask input; AL >= chain is asserted per cell",
        tree.len() as f64 / tree.max_depth() as f64
    );
    Ok(())
}
