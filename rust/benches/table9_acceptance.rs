//! Table 9 — acceptance length: AR EAGLE-3 vs P-EAGLE (4L) across the three
//! target models and three OOD benchmarks (K=5).
//!
//! Paper shape to reproduce: P-EAGLE(4L) matches or exceeds AR EAGLE-3 on
//! all 9 model x dataset cells (avg +2.0% to +4.5%); absolute values differ
//! (mini testbed).
//!
//!     cargo bench --bench table9_acceptance [-- --quick]

use p_eagle::report::eval_acceptance;
use p_eagle::runtime::ModelRuntime;
use p_eagle::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n_req, max_new) = if quick { (3, 48) } else { (6, 80) };
    let mut mr = ModelRuntime::load("artifacts")?;
    let k = mr.manifest.default_k;
    let datasets = ["humaneval", "mtbench", "gsm8k"];
    let paper_name = [("target-l", "GPT-OSS 120B"), ("target-m", "GPT-OSS 20B"),
                      ("target-s", "Qwen3-Coder 30B")];

    println!("=== Table 9: acceptance length, K={k}, {n_req} requests/cell ===\n");
    let mut tab = Table::new(&["model (paper analog)", "dataset", "AR EAGLE-3",
                               "P-EAGLE (4L)", "Δ%"]);
    for (target, paper) in paper_name {
        let mut avg = (0.0, 0.0);
        for ds in datasets {
            let ar = eval_acceptance(&mut mr, &format!("{target}-ar"), ds, k, n_req, max_new)?;
            let pe = eval_acceptance(&mut mr, &format!("{target}-pe4"), ds, k, n_req, max_new)?;
            avg.0 += ar.acceptance_length;
            avg.1 += pe.acceptance_length;
            tab.row(vec![
                format!("{target} ({paper})"),
                ds.into(),
                format!("{:.2}", ar.acceptance_length),
                format!("{:.2}", pe.acceptance_length),
                format!("{:+.1}%", (pe.acceptance_length - ar.acceptance_length)
                        / ar.acceptance_length * 100.0),
            ]);
        }
        tab.row(vec![
            format!("{target} ({paper})"),
            "Average".into(),
            format!("{:.2}", avg.0 / 3.0),
            format!("{:.2}", avg.1 / 3.0),
            format!("{:+.1}%", (avg.1 - avg.0) / avg.0 * 100.0),
        ]);
    }
    tab.print();
    println!("\npaper: averages AR 3.1/3.7/3.5 vs P-EAGLE 3.3/3.7/3.6 (+4.5%/+2.5%/+2.0%)");
    Ok(())
}
