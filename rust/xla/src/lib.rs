//! API-compatible **stub** of the patched vendored `xla-rs` PJRT bindings.
//!
//! The real crate (xla-rs with the on-device untuple patch and the synced
//! `buffer_from_host_literal` — see rust/src/runtime/executable.rs) links
//! against a PJRT CPU plugin that is not available in every build
//! environment, so this in-tree stub carries the exact API surface the
//! coordinator uses and keeps the workspace compiling and unit-testable
//! anywhere. Host-side pieces (`Literal` packing/unpacking) are fully
//! functional; every device entry point (`PjRtClient::cpu` onward) returns
//! [`Error::Unavailable`]. Integration tests skip themselves when
//! `artifacts/manifest.json` is missing, which is exactly the situation in
//! which this stub is in play.
//!
//! To serve with a real runtime, point the `xla` path dependency in
//! rust/Cargo.toml at the vendored crate; no coordinator code changes.

use std::error::Error as StdError;
use std::fmt;

#[derive(Debug)]
pub enum Error {
    /// A device/PJRT entry point was called through the stub.
    Unavailable(&'static str),
    /// Host-side literal shape/byte-length mismatch.
    Literal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real PJRT runtime (vendored xla-rs); \
                 this build uses the in-tree API stub"
            ),
            Error::Literal(msg) => write!(f, "xla stub literal error: {msg}"),
        }
    }
}

impl StdError for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Non-exhaustive to match the real crate (which carries the full PJRT dtype
/// lattice), so downstream matches keep their wildcard arm.
#[non_exhaustive]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
        }
    }
}

/// Element types that can round-trip through a [`Literal`].
pub trait NativeType: Sized + Copy {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes.try_into().unwrap())
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes(bytes.try_into().unwrap())
    }
}

/// Host-side array literal: fully functional in the stub.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want = dims.iter().product::<usize>() * ty.byte_size();
        if data.len() != want {
            return Err(Error::Literal(format!(
                "shape {dims:?} of {ty:?} needs {want} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { ty: self.ty, dims: self.dims.iter().map(|&d| d as i64).collect() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error::Literal(format!("literal is {:?}, asked for {:?}", self.ty, T::TY)));
        }
        let sz = self.ty.byte_size();
        Ok(self.bytes.chunks_exact(sz).map(T::from_le).collect())
    }

    /// Stub literals are always arrays (tuples only arise from on-device
    /// multi-result execution, which the stub cannot perform).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }
}

#[derive(Clone, Debug)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// On-device shape view (tuple-ness is all the coordinator asks of it).
#[derive(Clone, Debug)]
pub struct Shape {
    tuple: bool,
}

impl Shape {
    pub fn is_tuple(&self) -> bool {
        self.tuple
    }
}

/// Parsed HLO module (text form). The stub only records the source path.
#[derive(Debug)]
pub struct HloModuleProto {
    _path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let _ = path;
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer. Uninstantiable through the stub (all producers
/// return [`Error::Unavailable`]), but the type keeps signatures compiling.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn on_device_shape(&self) -> Result<Shape> {
        Err(Error::Unavailable("PjRtBuffer::on_device_shape"))
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f32() {
        let data: Vec<u8> = [1.0f32, 2.5, -3.0].iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &data).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(lit.to_vec::<i32>().is_err());
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
    }

    #[test]
    fn literal_checks_byte_length() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 7]).is_err()
        );
    }

    #[test]
    fn device_paths_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("stub"));
    }
}
