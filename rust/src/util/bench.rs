//! Criterion-style measurement harness (offline env: no criterion crate).
//!
//! Benches are `harness = false` binaries; this module provides warmup +
//! timed iterations + robust statistics and a stable textual output format
//! that EXPERIMENTS.md quotes directly.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p90_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub std_ns: f64,
}

impl Stats {
    /// Total on empty input: a zero-sample bench (a smoke-sized matrix cell
    /// with no iterations) yields all-zero stats rather than panicking.
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        if ns.is_empty() {
            return Stats {
                iters: 0,
                mean_ns: 0.0,
                p50_ns: 0.0,
                p90_ns: 0.0,
                p99_ns: 0.0,
                min_ns: 0.0,
                max_ns: 0.0,
                std_ns: 0.0,
            };
        }
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let q = |p: f64| ns[((p * n as f64) as usize).min(n - 1)];
        Stats {
            iters: n,
            mean_ns: mean,
            p50_ns: q(0.50),
            p90_ns: q(0.90),
            p99_ns: q(0.99),
            min_ns: ns[0],
            max_ns: ns[n - 1],
            std_ns: var.sqrt(),
        }
    }

    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` with warmup, then measure `iters` samples (one call per sample).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let s = Stats::from_samples(samples);
    println!(
        "{name:<48} mean {:>10}  p50 {:>10}  p99 {:>10}  (n={})",
        fmt_ns(s.mean_ns),
        fmt_ns(s.p50_ns),
        fmt_ns(s.p99_ns),
        s.iters
    );
    s
}

/// Time a single invocation (for long end-to-end measurements).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

/// Markdown-ish table printer shared by bench binaries.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Render to a string (one trailing newline) — the comparator embeds
    /// tables in error output, so rendering can't be print-only.
    pub fn render(&self) -> String {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<width$} | ", c, width = w[i]));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&format!(
            "|{}|\n",
            w.iter().map(|n| "-".repeat(n + 2)).collect::<Vec<_>>().join("|")
        ));
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_quantiles() {
        let s = Stats::from_samples((1..=100).map(|x| x as f64).collect());
        assert_eq!(s.iters, 100);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 100.0);
        assert!(s.p50_ns >= 50.0 && s.p50_ns <= 52.0);
        assert!(s.p99_ns >= 99.0);
    }

    #[test]
    fn stats_empty_is_zeroed_not_panic() {
        let s = Stats::from_samples(vec![]);
        assert_eq!(s.iters, 0);
        assert_eq!(s.mean_ns, 0.0);
        assert_eq!(s.p99_ns, 0.0);
        assert_eq!(s.mean(), Duration::ZERO);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["x".into(), "y".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("bb"));
        assert!(lines[1].starts_with("|-"));
        assert!(lines[2].contains("| x"));
        assert!(s.ends_with('\n'));
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(1.5e3).contains("µs"));
        assert!(fmt_ns(2.5e6).contains("ms"));
        assert!(fmt_ns(3.0e9).contains(" s"));
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
    }
}
