//! Statistical goodness-of-fit checks for seeded sampling tests.
//!
//! The sampler's statistical acceptance suite (and anything else that wants
//! to pin an empirical distribution) compares observed category counts
//! against an expected probability vector with two pre-registered gauges:
//!
//! * **Total variation distance** — `0.5 * Σ |obs/n − exp|`: an absolute
//!   effect-size bound, immune to the "huge n makes chi-square reject
//!   everything" failure mode.
//! * **Pearson chi-square** — `Σ (obs − n·exp)² / (n·exp)` over the bins
//!   with positive expected mass, against a critical value at a
//!   pre-registered alpha (Wilson–Hilferty approximation — accurate to
//!   well under 1% for the df this repo uses, validated in the tests
//!   below). Any observation in a zero-expected bin (an *impossible* token,
//!   e.g. outside a top-k filter's support) is an automatic fail — that is
//!   a correctness bug, not sampling noise.
//!
//! Everything here is deterministic: seeded trials in, fixed PASS/FAIL
//! out. There is no runtime dependency — the z-quantiles are a small
//! pre-registered table and the chi-square critical value is closed-form.
//!
//! The module also carries the **windowed-signal primitives** the adaptive
//! speculation controller (and future schedulers) smooth live engine
//! counters with: [`Ewma`] (half-life-parameterized exponential average)
//! and [`RingWindow`] (fixed-capacity sliding window with mean/quantile).
//! Both are empty-safe: before the first observation they answer `None`,
//! never a fabricated zero a control loop would act on.

/// Exponentially weighted moving average with the smoothing factor given as
/// a **half-life in observations**: after `half_life` pushes of a new
/// steady value, the average has closed half the distance to it
/// (`alpha = 1 − 2^(−1/half_life)`). The first push seeds the average
/// directly — no bias toward a phantom zero history.
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn with_half_life(half_life: f64) -> Ewma {
        assert!(half_life > 0.0, "half-life must be positive, got {half_life}");
        Ewma { alpha: 1.0 - 2f64.powf(-1.0 / half_life), value: None }
    }

    pub fn push(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        });
    }

    /// `None` until the first observation — a controller must treat "no
    /// signal yet" as cold start, not as a zero reading.
    pub fn value(&self) -> Option<f64> {
        self.value
    }

    pub fn value_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }

    pub fn is_empty(&self) -> bool {
        self.value.is_none()
    }
}

/// Fixed-capacity sliding window over the last `capacity` observations,
/// stored as a ring buffer. `mean`/`quantile` answer over exactly the
/// retained suffix and are `None` on an empty window (same empty-safety
/// contract as the latency quantiles in `coordinator::metrics`).
#[derive(Clone, Debug)]
pub struct RingWindow {
    buf: Vec<f64>,
    capacity: usize,
    /// next write position once the buffer has wrapped
    head: usize,
}

impl RingWindow {
    pub fn new(capacity: usize) -> RingWindow {
        assert!(capacity > 0, "window capacity must be positive");
        RingWindow { buf: Vec::with_capacity(capacity), capacity, head: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() < self.capacity {
            self.buf.push(x);
        } else {
            self.buf[self.head] = x;
        }
        self.head = (self.head + 1) % self.capacity;
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn mean(&self) -> Option<f64> {
        if self.buf.is_empty() {
            return None;
        }
        Some(self.buf.iter().sum::<f64>() / self.buf.len() as f64)
    }

    /// Nearest-rank quantile over the retained window (`q` clamped to
    /// [0, 1]): `q = 0.0` is the min, `q = 1.0` the max.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.buf.is_empty() {
            return None;
        }
        let mut sorted = self.buf.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let idx = (q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize;
        Some(sorted[idx.min(sorted.len() - 1)])
    }
}

/// Total variation distance between observed counts and an expected
/// probability vector: `0.5 * Σ |obs_i/n − exp_i|`. Returns 1.0 for an
/// empty sample (maximally wrong, never a silent pass).
pub fn tvd(counts: &[u64], expected_probs: &[f64]) -> f64 {
    assert_eq!(counts.len(), expected_probs.len());
    let n: u64 = counts.iter().sum();
    if n == 0 {
        return 1.0;
    }
    let n = n as f64;
    counts
        .iter()
        .zip(expected_probs.iter())
        .map(|(&c, &p)| (c as f64 / n - p).abs())
        .sum::<f64>()
        * 0.5
}

/// Standard-normal upper quantile z such that P(Z > z) = alpha, from the
/// pre-registered table. Statistical tests must pick one of these levels up
/// front; asking for anything else panics — no p-hacking by threshold
/// shopping.
pub fn z_quantile(alpha: f64) -> f64 {
    const TABLE: [(f64, f64); 4] =
        [(0.05, 1.6449), (0.01, 2.3263), (0.001, 3.0902), (1e-4, 3.7190)];
    for (a, z) in TABLE {
        if (alpha - a).abs() < a * 1e-6 {
            return z;
        }
    }
    panic!("alpha {alpha} is not pre-registered; pick one of 0.05, 0.01, 0.001, 1e-4");
}

/// Upper critical value of the chi-square distribution with `df` degrees of
/// freedom at level `alpha`, via the Wilson–Hilferty cube approximation:
/// `df * (1 − 2/(9 df) + z_alpha * sqrt(2/(9 df)))³`.
pub fn chi_square_critical(df: usize, alpha: f64) -> f64 {
    assert!(df > 0);
    let d = df as f64;
    let b = 2.0 / (9.0 * d);
    d * (1.0 - b + z_quantile(alpha) * b.sqrt()).powi(3)
}

/// One goodness-of-fit verdict; built by [`goodness_of_fit`], judged by
/// [`GofReport::passes`].
#[derive(Clone, Debug)]
pub struct GofReport {
    /// total observations
    pub n: u64,
    /// chi-square degrees of freedom: (bins with expected mass) − 1
    pub df: usize,
    /// Pearson statistic over the bins with expected mass
    pub chi2: f64,
    /// critical value at the pre-registered alpha
    pub chi2_crit: f64,
    /// total variation distance, observed vs expected
    pub tvd: f64,
    /// observations that landed in zero-expected bins — any > 0 is an
    /// automatic fail (tokens outside the filtered support)
    pub impossible_bins: u64,
}

impl GofReport {
    /// PASS iff: no impossible-bin mass, chi-square under the critical
    /// value, and TVD within the caller's pre-registered tolerance.
    pub fn passes(&self, tvd_tol: f64) -> bool {
        self.impossible_bins == 0 && self.chi2 <= self.chi2_crit && self.tvd <= tvd_tol
    }
}

/// Compare observed counts against expected probabilities at a
/// pre-registered alpha. Bins with `expected == 0` are excluded from the
/// chi-square sum (df shrinks accordingly) but any mass observed in them is
/// recorded as `impossible_bins`.
pub fn goodness_of_fit(counts: &[u64], expected_probs: &[f64], alpha: f64) -> GofReport {
    assert_eq!(counts.len(), expected_probs.len());
    let n: u64 = counts.iter().sum();
    let nf = n as f64;
    let mut chi2 = 0.0;
    let mut live_bins = 0usize;
    let mut impossible = 0u64;
    for (&c, &p) in counts.iter().zip(expected_probs.iter()) {
        if p > 0.0 {
            live_bins += 1;
            let e = nf * p;
            if e > 0.0 {
                let d = c as f64 - e;
                chi2 += d * d / e;
            }
        } else {
            impossible += c;
        }
    }
    let df = live_bins.saturating_sub(1).max(1);
    GofReport {
        n,
        df,
        chi2,
        chi2_crit: chi_square_critical(df, alpha),
        tvd: tvd(counts, expected_probs),
        impossible_bins: impossible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tvd_basics() {
        assert_eq!(tvd(&[50, 50], &[0.5, 0.5]), 0.0);
        assert!((tvd(&[100, 0], &[0.5, 0.5]) - 0.5).abs() < 1e-12);
        assert_eq!(tvd(&[0, 0], &[0.5, 0.5]), 1.0, "empty sample is maximally wrong");
    }

    #[test]
    fn critical_values_match_tables() {
        // textbook chi-square quantiles vs Wilson–Hilferty, 2% tolerance —
        // the approximation is far better than that at these df
        let cases = [
            (9usize, 0.05, 16.919),
            (11, 0.001, 31.264),
            (7, 0.01, 18.475),
            (1, 0.05, 3.841),
        ];
        for (df, alpha, want) in cases {
            let got = chi_square_critical(df, alpha);
            assert!(
                (got - want).abs() / want < 0.02,
                "df={df} alpha={alpha}: got {got:.3}, table {want:.3}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "not pre-registered")]
    fn unregistered_alpha_panics() {
        z_quantile(0.2);
    }

    #[test]
    fn impossible_bin_mass_fails_regardless_of_fit() {
        // perfect fit on the live bins, but one count in a zero-expected
        // bin — automatic fail
        let rep = goodness_of_fit(&[500, 500, 1], &[0.5, 0.5, 0.0], 0.001);
        assert_eq!(rep.impossible_bins, 1);
        assert!(!rep.passes(0.05));
        let rep = goodness_of_fit(&[500, 500, 0], &[0.5, 0.5, 0.0], 0.001);
        assert_eq!(rep.df, 1, "zero-expected bins don't count toward df");
        assert!(rep.passes(0.05));
    }

    #[test]
    fn ewma_is_empty_safe_and_seeds_on_first_push() {
        let mut e = Ewma::with_half_life(4.0);
        assert!(e.is_empty());
        assert_eq!(e.value(), None, "no fabricated zero before the first observation");
        assert_eq!(e.value_or(7.5), 7.5);
        e.push(3.0);
        assert_eq!(e.value(), Some(3.0), "first push seeds the average directly");
    }

    #[test]
    fn ewma_half_life_closes_half_the_distance() {
        // the definition of the parameterization: starting at 1.0, pushing
        // a steady 0.0 for exactly `half_life` steps lands at 0.5
        for half_life in [1usize, 4, 16] {
            let mut e = Ewma::with_half_life(half_life as f64);
            e.push(1.0);
            for _ in 0..half_life {
                e.push(0.0);
            }
            let v = e.value().unwrap();
            assert!(
                (v - 0.5).abs() < 1e-12,
                "half-life {half_life}: expected 0.5, got {v}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "half-life must be positive")]
    fn ewma_rejects_nonpositive_half_life() {
        Ewma::with_half_life(0.0);
    }

    #[test]
    fn ring_window_empty_safety_and_mean() {
        let mut w = RingWindow::new(4);
        assert!(w.is_empty());
        assert_eq!(w.mean(), None);
        assert_eq!(w.quantile(0.5), None);
        w.push(2.0);
        w.push(4.0);
        assert_eq!(w.len(), 2);
        assert_eq!(w.mean(), Some(3.0));
    }

    #[test]
    fn ring_window_evicts_oldest_at_capacity() {
        let mut w = RingWindow::new(3);
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            w.push(x);
        }
        // retained suffix is the last 3 observations: {3, 4, 5}
        assert_eq!(w.len(), 3);
        assert_eq!(w.mean(), Some(4.0));
        assert_eq!(w.quantile(0.0), Some(3.0));
        assert_eq!(w.quantile(1.0), Some(5.0));
    }

    #[test]
    fn ring_window_quantiles_nearest_rank() {
        let mut w = RingWindow::new(8);
        // pushed out of order — quantile sorts the retained window
        for x in [9.0, 1.0, 5.0, 3.0, 7.0] {
            w.push(x);
        }
        assert_eq!(w.quantile(0.5), Some(5.0));
        assert_eq!(w.quantile(0.0), Some(1.0));
        assert_eq!(w.quantile(1.0), Some(9.0));
        // out-of-range q clamps instead of panicking
        assert_eq!(w.quantile(2.0), Some(9.0));
        assert_eq!(w.quantile(-1.0), Some(1.0));
    }

    #[test]
    #[should_panic(expected = "capacity must be positive")]
    fn ring_window_rejects_zero_capacity() {
        RingWindow::new(0);
    }

    #[test]
    fn categorical_self_check_passes_and_shifted_fails() {
        // end-to-end sanity on the harness itself: 10k draws from
        // rng.categorical against their own weights must pass; the same
        // counts against a visibly different distribution must fail
        let probs = [0.4f64, 0.3, 0.2, 0.1];
        let weights: Vec<f32> = probs.iter().map(|&p| p as f32).collect();
        let mut rng = Rng::new(0x57A7_57A7);
        let mut counts = [0u64; 4];
        for _ in 0..10_000 {
            counts[rng.categorical(&weights)] += 1;
        }
        let rep = goodness_of_fit(&counts, &probs, 0.001);
        assert!(rep.passes(0.03), "self-check: tvd {:.4} chi2 {:.1}/{:.1}", rep.tvd, rep.chi2, rep.chi2_crit);
        let shifted = [0.1f64, 0.2, 0.3, 0.4];
        let rep = goodness_of_fit(&counts, &shifted, 0.001);
        assert!(!rep.passes(0.03), "power: shifted expectation must fail");
    }
}
