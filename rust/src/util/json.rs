//! Minimal JSON parser/serializer (offline env: no serde).
//!
//! Covers the full JSON grammar we exchange with the Python build path
//! (artifacts/manifest.json, eval prompt sets, report files). Numbers are
//! f64; object order is preserved for stable report output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing key {key:?} in {}", self.kind()))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv),
            _ => None,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_of(&self, key: &str) -> usize {
        self.req(key).as_usize().unwrap_or_else(|| panic!("{key} not a number"))
    }

    pub fn f64_of(&self, key: &str) -> f64 {
        self.req(key).as_f64().unwrap_or_else(|| panic!("{key} not a number"))
    }

    pub fn str_of(&self, key: &str) -> String {
        self.req(key)
            .as_str()
            .unwrap_or_else(|| panic!("{key} not a string"))
            .to_string()
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // ---- construction helpers -------------------------------------------
    pub fn obj(kv: Vec<(&str, Json)>) -> Json {
        Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn s(x: &str) -> Json {
        Json::Str(x.to_string())
    }

    // ---- serialization ---------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |o: &mut String, n: usize| {
            if pretty {
                o.push('\n');
                for _ in 0..n {
                    o.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !kv.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|e| e.to_string())?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("bad array sep {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("bad object sep {other:?} at {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Convenience: numeric map -> Json object.
pub fn num_obj(map: &BTreeMap<String, f64>) -> Json {
    Json::Obj(map.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").as_f64(), Some(1.0));
        assert_eq!(v.req("b").as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").req("d").as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café → ok""#).unwrap();
        assert_eq!(v.as_str(), Some("café → ok"));
        let round = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn integers_serialize_without_dot() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn nested_deep() {
        let mut s = String::new();
        for _ in 0..50 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..50 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }
}
