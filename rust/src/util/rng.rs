//! Seeded PRNG (SplitMix64 core + helpers). Deterministic across runs and
//! platforms — every stochastic component in the coordinator (workloads,
//! COD sampling mirrors, property tests) threads one of these explicitly.

/// SplitMix64: tiny, high-quality-enough, and trivially reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent stream (stable under call-site reordering).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut r = Rng::new(self.state ^ stream.wrapping_mul(0xA24B_AED4_963E_E407));
        r.next_u64();
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // multiply-shift; bias negligible for our n << 2^32
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal with the given mu/sigma (paper Fig. 1 length model).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential inter-arrival with rate `lambda` (requests/sec).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut x = self.f64() as f32 * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// `count` distinct values from [0, n), sorted ascending.
    pub fn sample_without_replacement(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n);
        // Floyd's algorithm
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - count)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.below(13);
            assert!(x < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn swr_distinct_sorted() {
        let mut r = Rng::new(5);
        for _ in 0..200 {
            let n = 1 + r.below(50);
            let c = r.below(n + 1);
            let s = r.sample_without_replacement(n, c);
            assert_eq!(s.len(), c);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn categorical_prefers_heavy() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..9000 {
            counts[r.categorical(&[0.1, 0.1, 0.8])] += 1;
        }
        assert!(counts[2] > counts[0] + counts[1]);
    }

    #[test]
    fn fork_streams_differ() {
        let r = Rng::new(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
