//! In-tree utility layer. The build environment is fully offline with only
//! the `xla` + `anyhow` crates vendored, so the pieces a serving framework
//! normally pulls from the ecosystem live here instead:
//!
//! * [`rng`]   — seeded SplitMix64 PRNG (rand replacement)
//! * [`json`]  — JSON parse/serialize (serde_json replacement)
//! * [`cli`]   — argument parsing (clap replacement)
//! * [`bench`] — measurement harness + stats (criterion replacement)
//! * [`prop`]  — property-testing loop (proptest replacement)
//! * [`stats`] — statistical goodness-of-fit checks (TVD, chi-square)

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
