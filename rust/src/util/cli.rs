//! Micro CLI argument parser (offline env: no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional arguments;
//! the binary defines subcommands by matching on the first positional.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Args {
        let mut out = Args::default();
        let argv: Vec<String> = argv.collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float")))
            .unwrap_or(default)
    }

    /// Comma-separated list option: `--key a,b,c` → `["a","b","c"]`.
    /// Empty segments are dropped; a missing key is an empty list.
    pub fn str_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .map(|v| {
                v.split(',')
                    .map(|s| s.trim())
                    .filter(|s| !s.is_empty())
                    .map(|s| s.to_string())
                    .collect()
            })
            .unwrap_or_default()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_forms() {
        // NOTE: `--flag value` is ambiguous without a declaration table —
        // a bare `--x` consumes a following non-dash token as its value, so
        // boolean flags go last or use `--x=1`.
        let a = parse(&["serve", "extra", "--model", "target-m", "--k=5", "--verbose"]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.get("model"), Some("target-m"));
        assert_eq!(a.usize_or("k", 0), 5);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.subcommand(), None);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.f64_or("rate", 1.5), 1.5);
        assert!(!a.flag("x"));
    }

    #[test]
    fn str_lists() {
        let a = parse(&["--drafters", "a, b,,c"]);
        assert_eq!(a.str_list("drafters"), vec!["a", "b", "c"]);
        assert!(a.str_list("missing").is_empty());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
