//! Tiny property-testing harness (offline env: no proptest crate).
//!
//! `check(name, cases, |rng| ...)` runs a seeded-random property many times
//! and panics with the *smallest* failing case (by the size metric the
//! property reports), which approximates proptest's shrinking.

use super::rng::Rng;

const P_SEED: u64 = 0x5EED_CAFE_F00D_1234;

/// Outcome of a single property case.
pub enum Case {
    Pass,
    /// Failure with a human-readable description and a size metric used to
    /// keep the smallest counterexample.
    Fail { desc: String, size: usize },
}

pub fn check<F: FnMut(&mut Rng) -> Case>(name: &str, cases: usize, mut prop: F) {
    let mut smallest: Option<(usize, String, usize)> = None;
    for i in 0..cases {
        let mut rng = Rng::new(P_SEED ^ (i as u64).wrapping_mul(0x9E37_79B9));
        if let Case::Fail { desc, size } = prop(&mut rng) {
            let better = smallest.as_ref().map(|(s, _, _)| size < *s).unwrap_or(true);
            if better {
                smallest = Some((size, desc, i));
            }
        }
    }
    if let Some((size, desc, case)) = smallest {
        panic!("property {name} failed (smallest size {size}, case #{case}): {desc}");
    }
}

/// Assert-style helper for use inside properties.
pub fn ensure(cond: bool, desc: impl Into<String>, size: usize) -> Case {
    if cond {
        Case::Pass
    } else {
        Case::Fail { desc: desc.into(), size }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially() {
        check("always-true", 50, |_| Case::Pass);
    }

    #[test]
    #[should_panic(expected = "property sometimes-false failed")]
    fn reports_failure() {
        check("sometimes-false", 50, |rng| {
            let x = rng.below(10);
            ensure(x < 9, format!("x={x}"), x)
        });
    }

    #[test]
    fn keeps_smallest() {
        let result = std::panic::catch_unwind(|| {
            check("always-false", 20, |rng| {
                let x = rng.below(100);
                Case::Fail { desc: format!("x={x}"), size: x }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // the reported size must be the minimum over all 20 cases
        let reported: usize = msg
            .split("smallest size ")
            .nth(1)
            .unwrap()
            .split(',')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(reported <= 20, "unlikely large minimum: {msg}");
    }
}
