//! PARD baseline — per-example from-scratch mask construction.
//!
//! PARD (An et al., 2025) samples a fresh COD row subset per training
//! example and rebuilds the cross-depth causal mask by evaluating the
//! attention predicate over every row pair: O((nK)²) work *per example*,
//! inside the data loader. The paper's Table 2 measures this as a 48× data
//! loading slowdown at n = 2048, K = 8; `benches/table2_mask_overhead.rs`
//! reproduces the comparison against `PrecomputedMask::gather`.

use super::{attend_allowed, precomputed::BitMatrix};

/// Build the attention mask over `rows` (interleaved ids) from scratch.
pub fn pard_mask(rows: &[usize], k: usize) -> BitMatrix {
    let m = rows.len();
    let mut out = BitMatrix::zeros(m, m);
    for i in 0..m {
        let (p, d) = (rows[i] / k, rows[i] % k);
        for j in 0..m {
            let (q, e) = (rows[j] / k, rows[j] % k);
            // deliberate scalar predicate per pair — the baseline's cost
            if attend_allowed(p, d, q, e) {
                out.set(i, j);
            }
        }
    }
    out
}

/// The full-mask variant (no COD): all n*K rows, O((nK)²).
pub fn pard_full_mask(n: usize, k: usize) -> BitMatrix {
    let rows: Vec<usize> = (0..n * k).collect();
    pard_mask(&rows, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::masking::PrecomputedMask;
    use crate::util::prop::{check, Case};
    use crate::util::rng::Rng;

    fn random_rows(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
        let total = n * k;
        let count = 1 + rng.below(total);
        rng.sample_without_replacement(total, count)
    }

    #[test]
    fn equals_amortized_gather() {
        // PARD's from-scratch mask and our precomputed-gather mask must be
        // identical — the paper's point is cost, not semantics.
        check("pard-vs-amortized", 40, |rng| {
            let k = 1 + rng.below(8);
            let n = 2 + rng.below(24);
            let rows = random_rows(rng, n, k);
            let pm = PrecomputedMask::build(n, k);
            let a = pm.gather(&rows);
            let b = pard_mask(&rows, k);
            for i in 0..rows.len() {
                for j in 0..rows.len() {
                    if a.get(i, j) != b.get(i, j) {
                        return Case::Fail {
                            desc: format!("({i},{j}) rows={rows:?} k={k}"),
                            size: n * k,
                        };
                    }
                }
            }
            Case::Pass
        });
    }

    #[test]
    fn full_mask_density_sane() {
        // depth-0 rows form a causal triangle; total ones must be at least
        // that and at most the full causal triangle over all rows.
        let (n, k) = (16, 4);
        let m = pard_full_mask(n, k);
        let ones = m.count_ones();
        let tri0 = n * (n + 1) / 2;
        let tri_all = (n * k) * (n * k + 1) / 2;
        assert!(ones >= tri0, "{ones} < {tri0}");
        assert!(ones <= tri_all, "{ones} > {tri_all}");
    }
}
