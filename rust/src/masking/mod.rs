//! Attention-mask machinery for parallel-prediction training (paper §3) —
//! the Rust mirror of `python/compile/masks.py`, used by the Table 2 / Fig 3
//! benches at *paper scale* (n = 2048, K = 8) where the Python baseline is
//! exactly the bottleneck the paper measures.
//!
//! Row coordinates: a training row (p, d) = sequence position p, prediction
//! depth d (PARD "group" G_d). Under the position-major interleaved layout
//! `row_id = p*K + d`, the attention predicate depends only on (p,d,q,e), so
//! the mask for any n is the top-left submatrix of the max-length mask
//! (paper Fig. 3) — `PrecomputedMask::slice_view` is O(1).

//!
//! Serve-time masking lives here too: [`tree`] builds the cross-node
//! ancestor mask for tree-structured speculation once per topology (the same
//! build-once / gather-per-use discipline, applied to the verify chunk
//! instead of the training batch), and [`dynamic`] selects a per-step
//! confidence-driven node subset inside a max-shape envelope and derives its
//! compacted subset mask from the envelope mask via the same gather.

pub mod cod;
pub mod dynamic;
pub mod pard;
pub mod precomputed;
pub mod tree;

pub use cod::{cod_counts, cod_sample_nested, rows_from_anchors};
pub use dynamic::{
    compacted_depths_i32, compacted_parents, select_nodes, subset_mask_i32, DynamicTreeConfig,
};
pub use pard::{pard_full_mask, pard_mask};
pub use precomputed::PrecomputedMask;
pub use tree::{TreeMask, TreeTopology};

/// The attention predicate shared by every construction path.
///
/// Row (p, d) may attend row (q, e) iff
///   * `e == 0 && q <= p - d`           — the real NTP context, or
///   * `q - e == p - d && e <= d`       — its own mask chain (incl. self).
/// Rows with p < d (or q < e) never arise in training (their anchor would
/// precede the sequence) — the predicate reports false for them so every
/// construction path agrees bit-for-bit.
#[inline]
pub fn attend_allowed(p: usize, d: usize, q: usize, e: usize) -> bool {
    if d > p || e > q {
        return false;
    }
    let anchor = (p - d) as isize;
    (e == 0 && (q as isize) <= anchor)
        || ((q - e) as isize == anchor && e <= d)
}

/// Decompose an interleaved row id.
#[inline]
pub fn row_pd(row: usize, k: usize) -> (usize, usize) {
    (row / k, row % k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predicate_matches_inference_chain() {
        // At inference, the MTP slot at depth d anchored at position a
        // attends the context (depth-0 rows <= a) and every earlier chain
        // slot — i.e. full causal over the window (DESIGN.md).
        let (a, d) = (10usize, 3usize);
        let p = a + d;
        for e in 0..=d {
            let q = a + e;
            assert!(attend_allowed(p, d, q, e), "chain ({q},{e})");
        }
        for q in 0..=a {
            assert!(attend_allowed(p, d, q, 0), "ctx ({q},0)");
        }
        // no attending the future or foreign chains
        assert!(!attend_allowed(p, d, a + 1, 0));
        assert!(!attend_allowed(p, d, a + 1, 2));
        assert!(!attend_allowed(p, d, p, d + 1));
    }

    #[test]
    fn depth0_is_plain_causal() {
        for p in 0..20 {
            for q in 0..20 {
                assert_eq!(attend_allowed(p, 0, q, 0), q <= p);
            }
        }
    }

    #[test]
    fn self_attention_always_allowed() {
        for p in 0..16 {
            for d in 0..=p.min(7) {
                assert!(attend_allowed(p, d, p, d));
            }
        }
    }
}
