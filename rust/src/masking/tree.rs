//! Static draft-tree topologies and their cross-node attention masks — the
//! serve-time twin of the training-side precomputed-mask machinery
//! ([`super::precomputed`]).
//!
//! Tree-structured speculation (EAGLE-3-style) verifies a *tree* of draft
//! tokens in one target pass instead of a single K-chain: the chunk is
//! `[root, node_1 .. node_N]` where the root is the last committed token and
//! each node continues its parent's branch. The target may only let node `i`
//! attend the committed context plus node `i`'s own ancestors — a cross-node
//! causal mask that depends only on the topology, so (exactly like the
//! Table-2 training trick) it is built ONCE per engine as a bit-packed
//! [`BitMatrix`] and re-used every step; per-step work is a cheap gather of
//! the rows actually in play.
//!
//! Topologies here are **width profiles**: `widths[d]` nodes at depth `d+1`,
//! level-major (BFS) node numbering, children attached round-robin to the
//! previous level so lower-rank (better) parents fill first. The K-chain is
//! the degenerate profile `[1; K]` — [`TreeTopology::is_chain`] lets the
//! engine keep that path byte-identical to classic chain decoding.

use super::precomputed::BitMatrix;

/// A static draft-tree topology: N draft nodes below an implicit root.
///
/// Node ids are 1..=N in level-major order (the root is id 0 and is not
/// stored); `parent[i - 1]` is the id of node `i`'s parent. Invariant:
/// `parent[i - 1] < i`, so any prefix of the id range is closed under
/// parents.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TreeTopology {
    widths: Vec<usize>,
    parent: Vec<usize>,
    depth: Vec<usize>,
    /// rank of each node within its level (0 = best) — the drafter assigns
    /// the level's rank-r node the (r+1)-th most likely token of that depth
    level_rank: Vec<usize>,
}

impl TreeTopology {
    /// Linear K-chain: the degenerate tree that reproduces classic chain
    /// speculation exactly.
    pub fn chain(k: usize) -> TreeTopology {
        TreeTopology::from_widths(&vec![1; k])
    }

    /// Build from a width profile: `widths[d]` nodes at depth `d + 1`.
    /// Children attach round-robin over the previous level, so the rank-0
    /// chain (every level's best node) is always a root path of the tree.
    ///
    /// Panics on an empty profile or a zero-width level; widths may
    /// otherwise grow or shrink freely (round-robin revisits parents as
    /// needed).
    pub fn from_widths(widths: &[usize]) -> TreeTopology {
        assert!(!widths.is_empty(), "tree needs at least one level");
        assert!(widths.iter().all(|&w| w > 0), "zero-width tree level");
        let mut parent = Vec::new();
        let mut depth = Vec::new();
        let mut level_rank = Vec::new();
        let mut prev_level_start = 0usize; // id of previous level's first node
        let mut prev_w = 1usize; // level 0 is the root alone
        for (d, &w) in widths.iter().enumerate() {
            let level_start = parent.len() + 1;
            for j in 0..w {
                // round-robin: best parents get children first
                let p = if d == 0 { 0 } else { prev_level_start + (j % prev_w) };
                parent.push(p);
                depth.push(d + 1);
                level_rank.push(j);
            }
            prev_level_start = level_start;
            prev_w = w;
        }
        TreeTopology { widths: widths.to_vec(), parent, depth, level_rank }
    }

    /// Largest depth [`parse`](Self::parse) accepts. The verify chunk is
    /// N+1 wide and must fit a KV slot with room to decode — depths past
    /// this are always a typo, not a topology.
    pub const MAX_PARSE_DEPTH: usize = 64;
    /// Largest node count [`parse`](Self::parse) accepts — caps the
    /// per-step verify width (and what a malformed spec can allocate).
    pub const MAX_PARSE_NODES: usize = 1024;

    /// Parse a CLI/config spec: `"chain:5"` or a width profile `"w:3,2,1"`.
    ///
    /// Untrusted-input safe (fuzz-tested): never panics, never allocates
    /// proportionally to a hostile spec (depth/node ceilings
    /// [`MAX_PARSE_DEPTH`](Self::MAX_PARSE_DEPTH) /
    /// [`MAX_PARSE_NODES`](Self::MAX_PARSE_NODES) are checked before
    /// construction), and every rejection names the offending spec.
    pub fn parse(spec: &str) -> Result<TreeTopology, String> {
        if let Some(k) = spec.strip_prefix("chain:") {
            let k: usize = k
                .trim()
                .parse()
                .map_err(|_| format!("bad chain depth in {spec:?} (want chain:<K>)"))?;
            if k == 0 {
                return Err(format!("chain depth must be >= 1 in {spec:?}"));
            }
            if k > Self::MAX_PARSE_DEPTH {
                return Err(format!(
                    "chain depth {k} exceeds the maximum {} in {spec:?}",
                    Self::MAX_PARSE_DEPTH
                ));
            }
            return Ok(TreeTopology::chain(k));
        }
        if let Some(ws) = spec.strip_prefix("w:") {
            let widths: Result<Vec<usize>, _> =
                ws.split(',').map(|x| x.trim().parse::<usize>()).collect();
            let widths = widths
                .map_err(|_| format!("bad width profile in {spec:?} (want w:<w1,w2,..>)"))?;
            if widths.is_empty() || widths.iter().any(|&w| w == 0) {
                return Err(format!("empty/zero width level in {spec:?}"));
            }
            if widths.len() > Self::MAX_PARSE_DEPTH {
                return Err(format!(
                    "{} levels exceed the maximum depth {} in {spec:?}",
                    widths.len(),
                    Self::MAX_PARSE_DEPTH
                ));
            }
            let nodes = widths
                .iter()
                .try_fold(0usize, |a, &w| a.checked_add(w))
                .filter(|&n| n <= Self::MAX_PARSE_NODES);
            if nodes.is_none() {
                return Err(format!(
                    "width profile totals more than {} nodes in {spec:?}",
                    Self::MAX_PARSE_NODES
                ));
            }
            return Ok(TreeTopology::from_widths(&widths));
        }
        Err(format!("unknown tree spec {spec:?} (want chain:<K> or w:<w1,w2,..>)"))
    }

    /// Canonical id used in executable names and the manifest `topology`
    /// field: `chain<K>` for chains, `w<w1>x<w2>x..` otherwise.
    pub fn id(&self) -> String {
        match self.is_chain() {
            Some(k) => format!("chain{k}"),
            None => {
                let parts: Vec<String> =
                    self.widths.iter().map(|w| w.to_string()).collect();
                format!("w{}", parts.join("x"))
            }
        }
    }

    /// The [`parse`](Self::parse)-syntax spelling of this topology
    /// (`chain:K` / `w:w1,w2,..`) — the inverse of [`parse`](Self::parse),
    /// round-trip tested. Used wherever a topology must be re-embedded in a
    /// spec (e.g. `SpecPolicy` mode strings).
    pub fn spec_string(&self) -> String {
        match self.is_chain() {
            Some(k) => format!("chain:{k}"),
            None => {
                let parts: Vec<String> =
                    self.widths.iter().map(|w| w.to_string()).collect();
                format!("w:{}", parts.join(","))
            }
        }
    }

    /// Number of draft nodes N (the verify chunk is N + 1 wide).
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// `Some(K)` iff this is the degenerate linear chain of depth K.
    pub fn is_chain(&self) -> Option<usize> {
        self.widths.iter().all(|&w| w == 1).then_some(self.widths.len())
    }

    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    pub fn max_depth(&self) -> usize {
        self.widths.len()
    }

    /// Depth of node `i` (1..=N); the root (id 0) has depth 0.
    pub fn depth(&self, i: usize) -> usize {
        if i == 0 {
            0
        } else {
            self.depth[i - 1]
        }
    }

    /// Parent id of node `i` (1..=N).
    pub fn parent(&self, i: usize) -> usize {
        self.parent[i - 1]
    }

    /// Rank of node `i` within its level (0 = that depth's most likely
    /// token).
    pub fn level_rank(&self, i: usize) -> usize {
        self.level_rank[i - 1]
    }

    /// Children of node `i` (0 = root) in ascending id (= ascending rank)
    /// order.
    pub fn children(&self, i: usize) -> Vec<usize> {
        (1..=self.len()).filter(|&c| self.parent(c) == i).collect()
    }

    /// Ancestor chain of node `i`, root-first, ending at `i` itself.
    pub fn path_to(&self, i: usize) -> Vec<usize> {
        let mut path = Vec::new();
        let mut cur = i;
        while cur != 0 {
            path.push(cur);
            cur = self.parent(cur);
        }
        path.push(0);
        path.reverse();
        path
    }

    /// Per-node depth offsets for the whole chunk (`[0, depth_1 .. depth_N]`)
    /// — the RoPE position of chunk slot `j` is `cache_len + depth_offsets[j]`.
    pub fn depth_offsets(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.len() + 1);
        out.push(0);
        out.extend(self.depth.iter().map(|&d| d as i32));
        out
    }

    /// Build the (N+1)² cross-node attention mask ONCE: chunk slot `i` may
    /// attend chunk slot `j` iff `j` is an ancestor-or-self of `i`. Row/col 0
    /// is the root. Bit-packed; per-step use is [`TreeMask::gather`] or the
    /// dense export [`TreeMask::to_i32`].
    pub fn build_mask(&self) -> TreeMask {
        let n = self.len() + 1;
        let mut bits = BitMatrix::zeros(n, n);
        for i in 0..n {
            for &a in &self.path_to(i) {
                bits.set(i, a);
            }
        }
        TreeMask { bits, n }
    }
}

/// Precomputed ancestor mask for one topology (chunk-internal attention).
pub struct TreeMask {
    bits: BitMatrix,
    pub n: usize,
}

impl TreeMask {
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        self.bits.get(i, j)
    }

    /// Dense row-major i32 export ([N+1, N+1], 1 = may attend) — the runtime
    /// input format of the tree-verify executable (the stub dtype lattice has
    /// no bool).
    pub fn to_i32(&self) -> Vec<i32> {
        let mut out = vec![0i32; self.n * self.n];
        for i in 0..self.n {
            for j in 0..self.n {
                if self.bits.get(i, j) {
                    out[i * self.n + j] = 1;
                }
            }
        }
        out
    }

    /// Gather the mask over a chunk-slot subset (e.g. the slots still in
    /// play after partial acceptance). Cost proportional to the output, like
    /// [`super::PrecomputedMask::gather`].
    pub fn gather(&self, slots: &[usize]) -> BitMatrix {
        let m = slots.len();
        let mut out = BitMatrix::zeros(m, m);
        for (i, &r) in slots.iter().enumerate() {
            for (j, &c) in slots.iter().enumerate() {
                if self.bits.get(r, c) {
                    out.set(i, j);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, Case};

    #[test]
    fn chain_shape() {
        let t = TreeTopology::chain(5);
        assert_eq!(t.len(), 5);
        assert_eq!(t.is_chain(), Some(5));
        assert_eq!(t.id(), "chain5");
        for i in 1..=5 {
            assert_eq!(t.parent(i), i - 1);
            assert_eq!(t.depth(i), i);
            assert_eq!(t.level_rank(i), 0);
        }
        assert_eq!(t.path_to(5), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(t.depth_offsets(), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn widths_level_major_round_robin() {
        // widths [3, 2]: nodes 1,2,3 at depth 1; nodes 4,5 at depth 2
        // attached round-robin to parents 1 and 2.
        let t = TreeTopology::from_widths(&[3, 2]);
        assert_eq!(t.len(), 5);
        assert_eq!(t.is_chain(), None);
        assert_eq!(t.id(), "w3x2");
        assert_eq!((t.parent(1), t.parent(2), t.parent(3)), (0, 0, 0));
        assert_eq!((t.parent(4), t.parent(5)), (1, 2));
        assert_eq!((t.depth(4), t.level_rank(4)), (2, 0));
        assert_eq!((t.depth(5), t.level_rank(5)), (2, 1));
        assert_eq!(t.children(0), vec![1, 2, 3]);
        assert_eq!(t.children(1), vec![4]);
        assert_eq!(t.path_to(5), vec![0, 2, 5]);
    }

    #[test]
    fn rank0_chain_is_always_embedded() {
        // every level's rank-0 node parents the next level's rank-0 node, so
        // the pure argmax chain is a root path of any profile
        let t = TreeTopology::from_widths(&[3, 2, 2, 1]);
        let mut cur = 0usize;
        for d in 1..=t.max_depth() {
            let next = t
                .children(cur)
                .into_iter()
                .find(|&c| t.level_rank(c) == 0)
                .expect("rank-0 child missing");
            assert_eq!(t.depth(next), d);
            cur = next;
        }
    }

    #[test]
    fn parse_round_trips() {
        assert_eq!(TreeTopology::parse("chain:7").unwrap(), TreeTopology::chain(7));
        assert_eq!(
            TreeTopology::parse("w:3,2,1").unwrap(),
            TreeTopology::from_widths(&[3, 2, 1])
        );
        // a w: profile of all-1s normalizes to the chain id
        assert_eq!(TreeTopology::parse("w:1,1,1").unwrap().id(), "chain3");
        assert!(TreeTopology::parse("chain:0").is_err());
        assert!(TreeTopology::parse("w:2,0").is_err());
        assert!(TreeTopology::parse("ring:4").is_err());
    }

    #[test]
    fn spec_string_is_the_parse_inverse() {
        for spec in ["chain:5", "w:3,2,1,1,1", "w:4,4,2,2,1"] {
            let t = TreeTopology::parse(spec).unwrap();
            assert_eq!(TreeTopology::parse(&t.spec_string()).unwrap(), t, "{spec}");
        }
        assert_eq!(TreeTopology::chain(3).spec_string(), "chain:3");
        // all-1s profiles normalize to the chain spelling, like id()
        assert_eq!(TreeTopology::parse("w:1,1").unwrap().spec_string(), "chain:2");
    }

    #[test]
    fn parse_rejects_malformed_specs_descriptively() {
        // every rejection must be an Err (never a panic) whose message names
        // the offending spec or constraint — these feed straight back to CLI
        // users via `--tree-topo`
        for spec in [
            "", "w:", "w:,", "w:1,", "w:1,,2", "w:-1", "w:1.5", "w: ", "chain:",
            "chain:abc", "chain:-3", "chain:1e3", "w:0", "w:3,0,1", "tree:3",
            "w:18446744073709551616", "chain:18446744073709551616", "🌲", "w:🌲",
        ] {
            let err = TreeTopology::parse(spec).unwrap_err();
            assert!(!err.is_empty(), "empty error for {spec:?}");
            assert!(
                err.contains("spec") || err.contains('"') || err.contains(">="),
                "error for {spec:?} lacks context: {err}"
            );
        }
    }

    #[test]
    fn parse_caps_oversized_profiles() {
        // zero-width levels and oversized profiles error instead of
        // allocating (the satellite's DoS-shaped inputs)
        assert!(TreeTopology::parse("chain:64").is_ok());
        let err = TreeTopology::parse("chain:65").unwrap_err();
        assert!(err.contains("maximum"), "{err}");
        assert!(TreeTopology::parse("w:1024").is_ok());
        let err = TreeTopology::parse("w:1025").unwrap_err();
        assert!(err.contains("1024"), "{err}");
        // sum overflow must not wrap into a small accepted profile
        let err =
            TreeTopology::parse("w:9223372036854775807,9223372036854775807").unwrap_err();
        assert!(err.contains("nodes"), "{err}");
        let deep = format!("w:{}", vec!["1"; 65].join(","));
        let err = TreeTopology::parse(&deep).unwrap_err();
        assert!(err.contains("depth"), "{err}");
    }

    #[test]
    fn parse_fuzz_never_panics() {
        // proptest-style fuzz: structured mutations around the grammar plus
        // raw printable noise. parse must return Ok or a non-empty Err —
        // never panic, never hang, never allocate past the caps.
        let fragments = [
            "chain", "w", ":", ",", "0", "1", "9", "99999999999999999999", "-",
            " ", ".", "x", "🌲", "chain:", "w:", "\0", "\n",
        ];
        check("tree-parse-fuzz", 500, |rng| {
            let mut spec = String::new();
            for _ in 0..rng.below(8) {
                spec.push_str(fragments[rng.below(fragments.len())]);
            }
            let result = std::panic::catch_unwind(|| TreeTopology::parse(&spec));
            match result {
                Ok(Ok(t)) => ensure(
                    !t.is_empty() && t.len() <= TreeTopology::MAX_PARSE_NODES,
                    format!("accepted {spec:?} with {} nodes", t.len()),
                    spec.len(),
                ),
                Ok(Err(e)) => ensure(
                    !e.is_empty(),
                    format!("empty error for {spec:?}"),
                    spec.len(),
                ),
                Err(_) => Case::Fail {
                    desc: format!("parse PANICKED on {spec:?}"),
                    size: spec.len(),
                },
            }
        });
    }

    #[test]
    fn mask_is_ancestor_closure() {
        let t = TreeTopology::from_widths(&[2, 2, 1]);
        let m = t.build_mask();
        for i in 0..=t.len() {
            let path: Vec<usize> = t.path_to(i);
            for j in 0..=t.len() {
                assert_eq!(m.get(i, j), path.contains(&j), "({i},{j})");
            }
        }
        // everyone attends the root; nobody (but the root) is attended by it
        for i in 0..=t.len() {
            assert!(m.get(i, 0));
        }
        assert!(!m.get(0, 1));
    }

    #[test]
    fn chain_mask_is_lower_triangular() {
        let t = TreeTopology::chain(4);
        let m = t.build_mask();
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(m.get(i, j), j <= i, "({i},{j})");
            }
        }
    }

    #[test]
    fn mask_export_and_gather_agree() {
        let t = TreeTopology::from_widths(&[2, 3]);
        let m = t.build_mask();
        let dense = m.to_i32();
        for i in 0..m.n {
            for j in 0..m.n {
                assert_eq!(dense[i * m.n + j] == 1, m.get(i, j));
            }
        }
        let slots = vec![0, 2, 4];
        let g = m.gather(&slots);
        for (i, &r) in slots.iter().enumerate() {
            for (j, &c) in slots.iter().enumerate() {
                assert_eq!(g.get(i, j), m.get(r, c));
            }
        }
    }

    #[test]
    fn topology_invariants_property() {
        // parents precede children; depths are parent depth + 1; level-major
        // ids are depth-sorted — for random width profiles
        check("tree-topology", 80, |rng| {
            let levels = 1 + rng.below(5);
            let widths: Vec<usize> = (0..levels).map(|_| 1 + rng.below(4)).collect();
            let t = TreeTopology::from_widths(&widths);
            for i in 1..=t.len() {
                let p = t.parent(i);
                if p >= i {
                    return Case::Fail {
                        desc: format!("parent {p} !< node {i} ({widths:?})"),
                        size: t.len(),
                    };
                }
                if t.depth(i) != t.depth(p) + 1 {
                    return Case::Fail {
                        desc: format!("depth chain broken at {i} ({widths:?})"),
                        size: t.len(),
                    };
                }
                if i > 1 && t.depth(i) < t.depth(i - 1) {
                    return Case::Fail {
                        desc: format!("ids not level-major at {i} ({widths:?})"),
                        size: t.len(),
                    };
                }
            }
            Case::Pass
        });
    }
}
