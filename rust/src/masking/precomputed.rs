//! Paper §3.1 — amortized mask construction.
//!
//! The full (n_max*K)² mask is built ONCE (vectorized, bit-packed rows);
//! per-example masks are O(1) slice views and COD row subsets are cheap
//! gathers. This is "ours" in Table 2; `pard.rs` is the 48×-slower baseline.

#[cfg(test)]
use super::attend_allowed;

/// Bit-packed boolean matrix (row-major, 64 cells per word).
pub struct BitMatrix {
    pub rows: usize,
    pub cols: usize,
    words_per_row: usize,
    data: Vec<u64>,
}

impl BitMatrix {
    pub fn zeros(rows: usize, cols: usize) -> BitMatrix {
        let wpr = cols.div_ceil(64);
        BitMatrix { rows, cols, words_per_row: wpr, data: vec![0; wpr * rows] }
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize) {
        self.data[r * self.words_per_row + c / 64] |= 1u64 << (c % 64);
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        (self.data[r * self.words_per_row + c / 64] >> (c % 64)) & 1 == 1
    }

    pub fn count_ones(&self) -> usize {
        self.data.iter().map(|w| w.count_ones() as usize).sum()
    }
}

/// Zero-copy view over the top-left square of a `PrecomputedMask`.
pub struct MaskView<'a> {
    mask: &'a BitMatrix,
    pub size: usize,
}

impl<'a> MaskView<'a> {
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.size && c < self.size);
        self.mask.get(r, c)
    }
}

pub struct PrecomputedMask {
    pub n_max: usize,
    pub k: usize,
    mask: BitMatrix,
    pub build_time: std::time::Duration,
}

impl PrecomputedMask {
    /// One-time construction for the maximum sequence length (amortized
    /// across the whole training run — paper §3.1).
    pub fn build(n_max: usize, k: usize) -> PrecomputedMask {
        let t0 = std::time::Instant::now();
        let m = n_max * k;
        let mut mask = BitMatrix::zeros(m, m);
        for row in 0..m {
            let (p, d) = (row / k, row % k);
            let anchor = p as isize - d as isize;
            if anchor < 0 {
                continue;
            }
            let a = anchor as usize;
            // context cells: (q, 0) for q <= anchor
            for q in 0..=a {
                mask.set(row, q * k);
            }
            // chain cells: (a + e, e) for 1 <= e <= d
            for e in 1..=d {
                let q = a + e;
                if q < n_max {
                    mask.set(row, q * k + e);
                }
            }
        }
        PrecomputedMask { n_max, k, mask, build_time: t0.elapsed() }
    }

    /// O(1) per-example mask: the top-left (n*K)² submatrix (paper Fig. 3).
    pub fn slice_view(&self, n: usize) -> MaskView<'_> {
        assert!(n <= self.n_max, "n={n} exceeds n_max={}", self.n_max);
        MaskView { mask: &self.mask, size: n * self.k }
    }

    /// Gather the mask over a sampled row subset (COD). Cost is proportional
    /// to the OUTPUT size, not (nK)² predicate evaluations.
    pub fn gather(&self, rows: &[usize]) -> BitMatrix {
        let m = rows.len();
        let mut out = BitMatrix::zeros(m, m);
        for (i, &r) in rows.iter().enumerate() {
            for (j, &c) in rows.iter().enumerate() {
                if self.mask.get(r, c) {
                    out.set(i, j);
                }
            }
        }
        out
    }

    /// Bytes held by the precomputed mask (fixed, dataset-size independent).
    pub fn memory_bytes(&self) -> usize {
        self.mask.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, Case};

    #[test]
    fn matches_predicate_exhaustively() {
        let pm = PrecomputedMask::build(12, 4);
        let v = pm.slice_view(12);
        for r in 0..v.size {
            for c in 0..v.size {
                let (p, d) = (r / 4, r % 4);
                let (q, e) = (c / 4, c % 4);
                assert_eq!(
                    v.get(r, c),
                    attend_allowed(p, d, q, e),
                    "({p},{d}) -> ({q},{e})"
                );
            }
        }
    }

    #[test]
    fn fig3_position_invariance() {
        // The mask for a shorter sequence is exactly the top-left submatrix
        // of a longer sequence's mask (paper Figure 3).
        let long = PrecomputedMask::build(32, 4);
        let short = PrecomputedMask::build(9, 4);
        let lv = long.slice_view(9);
        let sv = short.slice_view(9);
        for r in 0..sv.size {
            for c in 0..sv.size {
                assert_eq!(lv.get(r, c), sv.get(r, c), "({r},{c})");
            }
        }
    }

    #[test]
    fn fig3_invariance_property() {
        check("fig3-submatrix", 60, |rng| {
            let k = 1 + rng.below(8);
            let n_long = 2 + rng.below(40);
            let n_short = 1 + rng.below(n_long);
            let long = PrecomputedMask::build(n_long, k);
            let short = PrecomputedMask::build(n_short, k);
            let lv = long.slice_view(n_short);
            let sv = short.slice_view(n_short);
            for r in 0..sv.size {
                for c in 0..sv.size {
                    if lv.get(r, c) != sv.get(r, c) {
                        return Case::Fail {
                            desc: format!("mismatch at ({r},{c}) n={n_short}/{n_long} k={k}"),
                            size: n_long,
                        };
                    }
                }
            }
            ensure(true, "", n_long)
        });
    }

    #[test]
    fn gather_matches_direct() {
        let pm = PrecomputedMask::build(16, 4);
        let rows = vec![0, 4, 5, 9, 14, 21, 30];
        let g = pm.gather(&rows);
        for (i, &r) in rows.iter().enumerate() {
            for (j, &c) in rows.iter().enumerate() {
                assert_eq!(g.get(i, j), pm.slice_view(16).get(r, c));
            }
        }
    }

    #[test]
    fn memory_is_fixed() {
        let pm = PrecomputedMask::build(64, 8);
        let m: usize = 64 * 8;
        assert_eq!(pm.memory_bytes(), m.div_ceil(64) * 8 * m);
    }

    #[test]
    fn bitmatrix_basics() {
        let mut b = BitMatrix::zeros(3, 130);
        assert!(!b.get(2, 129));
        b.set(2, 129);
        b.set(0, 0);
        assert!(b.get(2, 129));
        assert!(b.get(0, 0));
        assert!(!b.get(1, 64));
        assert_eq!(b.count_ones(), 2);
    }
}
