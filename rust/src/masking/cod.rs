//! COD (Conditional Drop-token) sampling — geometric retention per depth.
//!
//! Depth d keeps round(n·r^d) anchors, sampled NESTED (A_d ⊆ A_{d-1}) so
//! every kept row's chain parent exists — the property Algorithm 1's Phase 2
//! requires, and which the paper's own Figure 4 example satisfies
//! (see python/compile/masks.py for the derivation).

use crate::util::rng::Rng;

/// Expected anchor count per depth (paper §3.2: n·(1-r^K)/(1-r) total).
pub fn cod_counts(n: usize, k: usize, ratio: f64) -> Vec<usize> {
    (0..k)
        .map(|d| ((n as f64) * ratio.powi(d as i32)).round() as usize)
        .collect()
}

/// Nested anchor sets: `anchors[d] ⊆ anchors[d-1]`, `|anchors[d]|` = round(n·r^d).
pub fn cod_sample_nested(n: usize, k: usize, ratio: f64, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut anchors: Vec<Vec<usize>> = vec![(0..n).collect()];
    let counts = cod_counts(n, k, ratio);
    for d in 1..k {
        let prev = &anchors[d - 1];
        let want = counts[d].min(prev.len());
        let idx = rng.sample_without_replacement(prev.len(), want);
        anchors.push(idx.into_iter().map(|i| prev[i]).collect());
    }
    anchors
}

/// Interleaved row ids for the sampled anchors, sorted; drops rows whose
/// label would fall outside the sequence (p > n-2).
pub fn rows_from_anchors(anchors: &[Vec<usize>], n: usize, k: usize) -> Vec<usize> {
    let mut ids = Vec::new();
    for (d, anc) in anchors.iter().enumerate() {
        for &a in anc {
            let p = a + d;
            if n >= 2 && p <= n - 2 {
                ids.push(p * k + d);
            }
        }
    }
    ids.sort_unstable();
    ids
}

/// Total row count estimate (paper §3.2 closed form).
pub fn expected_total_rows(n: usize, k: usize, ratio: f64) -> f64 {
    n as f64 * (1.0 - ratio.powi(k as i32)) / (1.0 - ratio)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Case};

    #[test]
    fn nested_and_sized() {
        check("cod-nested", 60, |rng| {
            let n = 4 + rng.below(200);
            let k = 1 + rng.below(8);
            let r = 0.5 + rng.f64() * 0.45;
            let anchors = cod_sample_nested(n, k, r, rng);
            let counts = cod_counts(n, k, r);
            for d in 1..k {
                let prev: std::collections::HashSet<_> =
                    anchors[d - 1].iter().collect();
                if anchors[d].len() != counts[d].min(anchors[d - 1].len()) {
                    return Case::Fail {
                        desc: format!("depth {d} size {}", anchors[d].len()),
                        size: n,
                    };
                }
                for a in &anchors[d] {
                    if !prev.contains(a) {
                        return Case::Fail {
                            desc: format!("anchor {a} at depth {d} not nested"),
                            size: n,
                        };
                    }
                }
            }
            Case::Pass
        });
    }

    #[test]
    fn rows_sorted_distinct_in_range() {
        check("cod-rows", 60, |rng| {
            let n = 4 + rng.below(120);
            let k = 1 + rng.below(8);
            let anchors = cod_sample_nested(n, k, 0.8, rng);
            let rows = rows_from_anchors(&anchors, n, k);
            for w in rows.windows(2) {
                if w[0] >= w[1] {
                    return Case::Fail { desc: format!("{w:?}"), size: n };
                }
            }
            for &r in &rows {
                let (p, d) = (r / k, r % k);
                if p > n - 2 || d >= k || p < d {
                    return Case::Fail { desc: format!("row ({p},{d})"), size: n };
                }
            }
            Case::Pass
        });
    }

    #[test]
    fn paper_fig4_example_is_nested() {
        // The paper's Figure 4 example: n=16, K=4, r=0.7 —
        // depth1 {1,3,4,6,7,9,10,12,14,15}, depth2 {2,5,7,8,11,13,15},
        // depth3 {3,6,9,12,14}; in anchor coordinates (p - d):
        let d1: Vec<usize> = vec![1, 3, 4, 6, 7, 9, 10, 12, 14, 15]
            .into_iter().map(|p| p - 1).collect();
        let d2: Vec<usize> = vec![2, 5, 7, 8, 11, 13, 15]
            .into_iter().map(|p| p - 2).collect();
        let d3: Vec<usize> = vec![3, 6, 9, 12, 14]
            .into_iter().map(|p| p - 3).collect();
        let s1: std::collections::HashSet<_> = d1.iter().collect();
        let s2: std::collections::HashSet<_> = d2.iter().collect();
        assert!(d2.iter().all(|a| s1.contains(a)), "depth2 ⊆ depth1");
        assert!(d3.iter().all(|a| s2.contains(a)), "depth3 ⊆ depth2");
    }

    #[test]
    fn total_rows_formula() {
        // paper's example: 8192 tokens, K=8, r=0.8 -> ~34K positions
        let t = expected_total_rows(8192, 8, 0.8);
        assert!((t - 34000.0).abs() < 1500.0, "{t}");
    }
}
