//! Dynamic confidence-driven tree topologies over a max-shape envelope
//! (EAGLE-2 style), the serve-time twin of [`super::tree`]'s static
//! profiles.
//!
//! The static tree path lowers one executable per topology; this module
//! turns the topology into per-step *data*. One executable pair is lowered
//! for a **max-shape envelope** (e.g. `w:4,4,2,2,1`) whose cross-node
//! ancestor mask and per-slot RoPE depth offsets are RUNTIME inputs. Each
//! step, the drafter's per-node joint log-probabilities pick the
//! `node_budget` most promising envelope nodes (greedy frontier expansion —
//! provably the top-budget joint-scored ancestor-closed subset, because a
//! child's joint log-probability never exceeds its parent's), and the
//! selected subtree is **compacted** into the first `m + 1` chunk slots:
//!
//! * chunk slot 0 stays the root (last committed token), slots `1..=m` hold
//!   the selected nodes in ascending envelope-id (= level-major) order, the
//!   tail is PAD;
//! * the runtime mask is the envelope ancestor mask gathered over
//!   `[root] + selected` ([`TreeMask::gather`] — the subset machinery
//!   `masking/tree.rs` was built for) embedded top-left in the envelope
//!   shape, inactive rows/cols all-zero (inert: tail slots attend only the
//!   committed cache and are never attended);
//! * the runtime depth offsets carry each selected node's envelope depth,
//!   so RoPE positions — and therefore the accepted-path KV compaction
//!   story — are identical to the static path.
//!
//! Compaction is what lets the allocator charge speculative scratch by the
//! node **budget** instead of the envelope size: every position a step can
//! commit lives in the first `budget + 1` chunk slots, so paged admission
//! reserves `budget + 1` covered positions while the (wider) envelope
//! scatter's tail harmlessly lands in the null block (see
//! [`SlotManager`](crate::coordinator::kv_cache::SlotManager)'s
//! `write_width` vs `chunk` split).
//!
//! Static topologies fall out as the degenerate case: with
//! `node_budget >= envelope.len()` every node is selected, the compacted
//! chunk is the envelope chunk, the subset mask is the full ancestor mask,
//! and the engine is byte-identical to the static-topology path
//! (integration-tested).

use super::tree::{TreeMask, TreeTopology};

/// Configuration of dynamic tree speculation
/// ([`EngineConfig::tree_dynamic`](crate::coordinator::EngineConfig::tree_dynamic)).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DynamicTreeConfig {
    /// The max-shape envelope the executables were lowered with; per-step
    /// selection happens inside it.
    pub envelope: TreeTopology,
    /// Nodes activated per step. [`new`](Self::new)/[`parse`](Self::parse)
    /// reject budgets above the envelope's node count (a larger budget buys
    /// nothing); [`active_nodes`](Self::active_nodes) additionally clamps,
    /// so a hand-built oversized config degrades to the degenerate case
    /// instead of overrunning. `node_budget == envelope.len()` reproduces
    /// the static topology byte-for-byte.
    pub node_budget: usize,
}

impl DynamicTreeConfig {
    /// The serving-default envelope spec — the ONE place the Rust side
    /// states it. Must stay in lockstep with python
    /// `configs.TREE_DYN_ENVELOPE` (the lowering that makes the default
    /// resolvable at executable lookup).
    pub const DEFAULT_ENVELOPE_SPEC: &'static str = "w:4,4,2,2,1";
    /// Serving-default node budget: the static serving tree's node count
    /// (`w:3,2,1,1,1` = 8), so default comparisons spend an equal
    /// verified-node budget. Mirrors python `configs.DEFAULT_TREE_BUDGET`.
    pub const DEFAULT_NODE_BUDGET: usize = 8;

    /// The serving-default configuration (envelope
    /// [`DEFAULT_ENVELOPE_SPEC`](Self::DEFAULT_ENVELOPE_SPEC) at budget
    /// [`DEFAULT_NODE_BUDGET`](Self::DEFAULT_NODE_BUDGET)).
    pub fn serving_default() -> DynamicTreeConfig {
        DynamicTreeConfig::parse(Self::DEFAULT_ENVELOPE_SPEC, Self::DEFAULT_NODE_BUDGET)
            .expect("serving-default dynamic tree config")
    }

    /// Validated constructor. Reuses the [`TreeTopology::parse`] ceilings
    /// ([`TreeTopology::MAX_PARSE_DEPTH`] / [`TreeTopology::MAX_PARSE_NODES`])
    /// so an oversized envelope from the CLI fails with a descriptive error
    /// instead of a panic deeper in the engine.
    pub fn new(envelope: TreeTopology, node_budget: usize) -> Result<DynamicTreeConfig, String> {
        if node_budget == 0 {
            return Err("dynamic tree node budget must be >= 1".into());
        }
        if envelope.len() > TreeTopology::MAX_PARSE_NODES {
            return Err(format!(
                "envelope has {} nodes, exceeding the maximum {}",
                envelope.len(),
                TreeTopology::MAX_PARSE_NODES
            ));
        }
        if envelope.max_depth() > TreeTopology::MAX_PARSE_DEPTH {
            return Err(format!(
                "envelope depth {} exceeds the maximum {}",
                envelope.max_depth(),
                TreeTopology::MAX_PARSE_DEPTH
            ));
        }
        if node_budget > envelope.len() {
            return Err(format!(
                "node budget {} exceeds the envelope's {} nodes (budget == nodes is \
                 the static degenerate case; larger buys nothing)",
                node_budget,
                envelope.len()
            ));
        }
        Ok(DynamicTreeConfig { envelope, node_budget })
    }

    /// Parse a CLI pair: envelope spec (`"w:4,4,2,2,1"` / `"chain:5"`) plus
    /// a node budget. Untrusted-input safe like [`TreeTopology::parse`].
    pub fn parse(envelope_spec: &str, node_budget: usize) -> Result<DynamicTreeConfig, String> {
        let envelope = TreeTopology::parse(envelope_spec)?;
        DynamicTreeConfig::new(envelope, node_budget)
    }

    /// Nodes actually activated per step.
    pub fn active_nodes(&self) -> usize {
        self.node_budget.min(self.envelope.len())
    }

    /// Whether every envelope node is activated every step (the static
    /// byte-parity case).
    pub fn is_degenerate(&self) -> bool {
        self.node_budget >= self.envelope.len()
    }

    /// Canonical id for display: `dyn:<envelope>@<budget>`.
    pub fn id(&self) -> String {
        format!("dyn:{}@{}", self.envelope.id(), self.node_budget)
    }
}

/// Select the `budget` envelope nodes with the highest joint (cumulative)
/// draft log-probability, as an ancestor-closed set.
///
/// `joint_logp[i - 1]` is node `i`'s joint log-probability: the sum of the
/// drafter's per-level log-probabilities along node `i`'s root path (the
/// `draft-tree-logp` executable's second output). Greedy frontier
/// expansion: start from the root's children and repeatedly take the
/// highest-scoring node whose parent is already selected (ties broken by
/// ascending id, NaN treated as -inf). Because `joint(child) = joint(parent)
/// + level_logp(child) <= joint(parent)`, this IS the global top-`budget`
/// by joint score — and closure holds by construction even if device floats
/// misbehave.
///
/// Returns the selected envelope ids sorted ascending (level-major order is
/// preserved, so parents precede children and the compacted chunk keeps the
/// `path[m-1] >= m` invariant the KV compaction relies on).
pub fn select_nodes(envelope: &TreeTopology, joint_logp: &[f32], budget: usize) -> Vec<usize> {
    let n = envelope.len();
    assert_eq!(joint_logp.len(), n, "joint_logp must cover every envelope node");
    let budget = budget.min(n);
    let score = |i: usize| -> f32 {
        let s = joint_logp[i - 1];
        if s.is_nan() {
            f32::NEG_INFINITY
        } else {
            s
        }
    };
    let mut selected = vec![false; n + 1];
    selected[0] = true; // the root is implicit, always active
    let mut out = Vec::with_capacity(budget);
    for _ in 0..budget {
        let mut best: Option<usize> = None;
        for i in 1..=n {
            if selected[i] || !selected[envelope.parent(i)] {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => score(i) > score(b), // ties keep the smaller id
            };
            if better {
                best = Some(i);
            }
        }
        // the frontier is never empty before all n nodes are selected:
        // every unselected id-minimal node has a selected parent
        let pick = best.expect("frontier exhausted before budget");
        selected[pick] = true;
        out.push(pick);
    }
    out.sort_unstable();
    out
}

/// Per-selected-node CONDITIONAL draft probability: `q_j =
/// exp(joint(node) - joint(parent))` — the drafter's own model confidence
/// in node `j`'s token given its parent (the root's joint is 0, so depth-1
/// nodes report `exp(joint)` directly). Clamped to [0, 1] against device
/// float drift; NaN reports 0.
///
/// This is CALIBRATION SIGNAL, not an acceptance input: the engine drafts
/// deterministically (each node is a fixed top-k rank), so the true
/// proposal distribution is a point mass and feeding this model-confidence
/// `q` into the `min(1, p/q)` rejection rule would bias the output — the
/// sampler's statistical suite demonstrates the bias. The engine records
/// `q` against acceptance outcomes in
/// [`PolicyMetrics`](crate::coordinator::metrics::PolicyMetrics) so
/// over/under-confidence is observable per drafter.
pub fn conditional_q(envelope: &TreeTopology, joint_logp: &[f32], nodes: &[usize]) -> Vec<f32> {
    assert_eq!(joint_logp.len(), envelope.len(), "joint_logp must cover every envelope node");
    nodes
        .iter()
        .map(|&id| {
            let parent = envelope.parent(id);
            let pj = if parent == 0 { 0.0 } else { joint_logp[parent - 1] };
            let q = (joint_logp[id - 1] - pj).exp();
            if q.is_nan() {
                0.0
            } else {
                q.clamp(0.0, 1.0)
            }
        })
        .collect()
}

/// Compacted chunk-slot parents for a selected subtree: entry `j - 1` is
/// the compacted slot of compacted node `j`'s parent (0 = root). `nodes`
/// must be ascending and ancestor-closed (the [`select_nodes`] contract).
pub fn compacted_parents(envelope: &TreeTopology, nodes: &[usize]) -> Vec<usize> {
    nodes
        .iter()
        .map(|&id| {
            let p = envelope.parent(id);
            if p == 0 {
                0
            } else {
                1 + nodes
                    .iter()
                    .position(|&s| s == p)
                    .expect("selection not ancestor-closed")
            }
        })
        .collect()
}

/// Per-chunk-slot RoPE depth offsets in the compacted layout, padded to
/// `width` slots: slot 0 is the root (depth 0), slot `j` carries
/// `envelope.depth(nodes[j - 1])`, tail slots (inert PAD) report 0.
pub fn compacted_depths_i32(envelope: &TreeTopology, nodes: &[usize], width: usize) -> Vec<i32> {
    let mut out = vec![0i32; width];
    for (j, &id) in nodes.iter().enumerate() {
        out[j + 1] = envelope.depth(id) as i32;
    }
    out
}

/// The per-step subset mask in the compacted layout, padded to
/// `width x width` (the envelope chunk shape the executable was lowered
/// with): the envelope ancestor mask gathered over `[root] + nodes`
/// ([`TreeMask::gather`]) occupies the top-left, everything else is 0 —
/// inactive tail slots attend nothing in the chunk (only the committed
/// cache) and are attended by nobody.
pub fn subset_mask_i32(mask: &TreeMask, nodes: &[usize], width: usize) -> Vec<i32> {
    let mut slots = Vec::with_capacity(nodes.len() + 1);
    slots.push(0);
    slots.extend_from_slice(nodes);
    let g = mask.gather(&slots);
    let m = slots.len();
    let mut out = vec![0i32; width * width];
    for i in 0..m {
        for j in 0..m {
            if g.get(i, j) {
                out[i * width + j] = 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Case};

    fn env(widths: &[usize]) -> TreeTopology {
        TreeTopology::from_widths(widths)
    }

    /// Joint log-probs consistent with a drafter: child = parent + level
    /// term (<= 0), randomized.
    fn random_joint(t: &TreeTopology, rng: &mut crate::util::rng::Rng) -> Vec<f32> {
        let mut joint = vec![0f32; t.len()];
        for i in 1..=t.len() {
            let level = -(rng.below(1000) as f32) / 250.0; // [-4, 0]
            let parent = t.parent(i);
            joint[i - 1] = level + if parent == 0 { 0.0 } else { joint[parent - 1] };
        }
        joint
    }

    #[test]
    fn config_validates_with_descriptive_errors() {
        let e = env(&[3, 2, 1]);
        assert!(DynamicTreeConfig::new(e.clone(), 6).is_ok());
        let err = DynamicTreeConfig::new(e.clone(), 0).unwrap_err();
        assert!(err.contains(">= 1"), "{err}");
        let err = DynamicTreeConfig::new(e.clone(), 7).unwrap_err();
        assert!(err.contains("budget 7"), "{err}");
        // the parse caps are reused, so CLI errors stay descriptive
        let err = DynamicTreeConfig::parse("w:1025", 4).unwrap_err();
        assert!(err.contains("1024"), "{err}");
        let deep = format!("w:{}", vec!["1"; 65].join(","));
        let err = DynamicTreeConfig::parse(&deep, 4).unwrap_err();
        assert!(err.contains("depth"), "{err}");
        // oversized envelopes built programmatically hit the same ceilings
        let wide = TreeTopology::from_widths(&[TreeTopology::MAX_PARSE_NODES + 1]);
        let err = DynamicTreeConfig::new(wide, 4).unwrap_err();
        assert!(err.contains("maximum"), "{err}");
        let cfg = DynamicTreeConfig::parse("w:4,4,2,2,1", 8).unwrap();
        assert_eq!(cfg.active_nodes(), 8);
        assert!(!cfg.is_degenerate());
        assert_eq!(cfg.id(), "dyn:w4x4x2x2x1@8");
        assert!(DynamicTreeConfig::parse("chain:5", 5).unwrap().is_degenerate());
    }

    #[test]
    fn chain_envelope_selects_prefix() {
        // a chain envelope's top-b selection is always the first b nodes —
        // the chain-of-depth-b degenerate case
        let t = TreeTopology::chain(6);
        let joint: Vec<f32> = (1..=6).map(|i| -(i as f32)).collect();
        for b in 1..=6 {
            assert_eq!(select_nodes(&t, &joint, b), (1..=b).collect::<Vec<_>>());
        }
    }

    #[test]
    fn selection_picks_confident_branch() {
        // widths [2, 2]: nodes 1,2 at depth 1; 3,4 at depth 2 (parents 1,2).
        // Node 2's branch is far more confident: budget 2 must take {2, 4}.
        let t = env(&[2, 2]);
        let joint = [-5.0f32, -0.1, -9.0, -0.2];
        assert_eq!(select_nodes(&t, &joint, 2), vec![2, 4]);
        // budget 3 adds the next best frontier node (node 1)
        assert_eq!(select_nodes(&t, &joint, 3), vec![1, 2, 4]);
    }

    #[test]
    fn selection_is_ancestor_closed_and_root_anchored() {
        // THE satellite property: whatever the scores (even adversarial,
        // non-monotone, or NaN), the selection is ancestor-closed, sized to
        // the budget, and ascending
        check("dyn-selection-closed", 150, |rng| {
            let levels = 1 + rng.below(5);
            let widths: Vec<usize> = (0..levels).map(|_| 1 + rng.below(4)).collect();
            let t = TreeTopology::from_widths(&widths);
            let joint: Vec<f32> = (0..t.len())
                .map(|_| match rng.below(12) {
                    0 => f32::NAN,
                    1 => f32::NEG_INFINITY,
                    _ => -(rng.below(2000) as f32) / 100.0,
                })
                .collect();
            let budget = 1 + rng.below(t.len() + 2);
            let sel = select_nodes(&t, &joint, budget);
            if sel.len() != budget.min(t.len()) {
                return Case::Fail {
                    desc: format!("selected {} of budget {budget}", sel.len()),
                    size: t.len(),
                };
            }
            if !sel.windows(2).all(|w| w[0] < w[1]) {
                return Case::Fail { desc: format!("not ascending: {sel:?}"), size: t.len() };
            }
            for &id in &sel {
                let p = t.parent(id);
                if p != 0 && !sel.contains(&p) {
                    return Case::Fail {
                        desc: format!("node {id}'s parent {p} missing from {sel:?} ({widths:?})"),
                        size: t.len(),
                    };
                }
            }
            Case::Pass
        });
    }

    #[test]
    fn selection_is_global_top_budget_under_monotone_scores() {
        // with drafter-shaped (monotone) joints, frontier-greedy == the
        // global top-budget by score (tie-break: smaller id)
        check("dyn-selection-topn", 120, |rng| {
            let levels = 1 + rng.below(4);
            let widths: Vec<usize> = (0..levels).map(|_| 1 + rng.below(4)).collect();
            let t = TreeTopology::from_widths(&widths);
            let joint = random_joint(&t, rng);
            let budget = 1 + rng.below(t.len());
            let sel = select_nodes(&t, &joint, budget);
            let mut order: Vec<usize> = (1..=t.len()).collect();
            order.sort_by(|&a, &b| {
                joint[b - 1].partial_cmp(&joint[a - 1]).unwrap().then(a.cmp(&b))
            });
            let mut want: Vec<usize> = order[..budget].to_vec();
            want.sort_unstable();
            if sel != want {
                return Case::Fail {
                    desc: format!("greedy {sel:?} != top-{budget} {want:?} ({joint:?})"),
                    size: t.len(),
                };
            }
            Case::Pass
        });
    }

    #[test]
    fn degenerate_budget_selects_everything() {
        let t = env(&[3, 2, 1]);
        let joint = random_joint(&t, &mut crate::util::rng::Rng::new(7));
        assert_eq!(select_nodes(&t, &joint, 6), (1..=6).collect::<Vec<_>>());
        assert_eq!(select_nodes(&t, &joint, 99), (1..=6).collect::<Vec<_>>());
    }

    #[test]
    fn conditional_q_recovers_level_terms() {
        // joint = parent joint + level logp by construction, so q must be
        // exp(level term) exactly — in (0, 1] for drafter-shaped scores
        check("dyn-conditional-q", 100, |rng| {
            let levels = 1 + rng.below(4);
            let widths: Vec<usize> = (0..levels).map(|_| 1 + rng.below(4)).collect();
            let t = TreeTopology::from_widths(&widths);
            let mut level_terms = vec![0f32; t.len()];
            let mut joint = vec![0f32; t.len()];
            for i in 1..=t.len() {
                level_terms[i - 1] = -(rng.below(1000) as f32) / 250.0; // [-4, 0]
                let p = t.parent(i);
                joint[i - 1] =
                    level_terms[i - 1] + if p == 0 { 0.0 } else { joint[p - 1] };
            }
            let budget = 1 + rng.below(t.len());
            let sel = select_nodes(&t, &joint, budget);
            let qs = conditional_q(&t, &joint, &sel);
            for (j, (&id, &q)) in sel.iter().zip(qs.iter()).enumerate() {
                let want = level_terms[id - 1].exp();
                if !(q > 0.0 && q <= 1.0) || (q - want).abs() > 1e-4 {
                    return Case::Fail {
                        desc: format!("node {id} (slot {j}): q {q} want {want}"),
                        size: t.len(),
                    };
                }
            }
            Case::Pass
        });
    }

    #[test]
    fn conditional_q_handles_degenerate_scores() {
        let t = env(&[2, 1]);
        // node 2's joint above its (root) baseline -> clamped to 1;
        // NaN joint -> q 0 for the node AND its child (NaN propagates)
        let qs = conditional_q(&t, &[0.5, f32::NAN, f32::NAN], &[1, 2, 3]);
        assert_eq!(qs, vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn compacted_parents_relabel_the_subtree() {
        // widths [2, 2]: selecting {2, 4} compacts node 2 -> slot 1,
        // node 4 -> slot 2 with parent chain 0 -> 1 -> 2
        let t = env(&[2, 2]);
        assert_eq!(compacted_parents(&t, &[2, 4]), vec![0, 1]);
        assert_eq!(compacted_parents(&t, &[1, 2, 4]), vec![0, 0, 2]);
        // full selection is the identity relabeling
        let all: Vec<usize> = (1..=t.len()).collect();
        let parents: Vec<usize> = (1..=t.len()).map(|i| t.parent(i)).collect();
        assert_eq!(compacted_parents(&t, &all), parents);
    }

    #[test]
    fn compacted_depths_follow_envelope_depths() {
        let t = env(&[2, 2]);
        assert_eq!(compacted_depths_i32(&t, &[2, 4], 5), vec![0, 1, 2, 0, 0]);
        assert_eq!(compacted_depths_i32(&t, &[1, 2, 3, 4], 5), vec![0, 1, 1, 2, 2]);
    }

    #[test]
    fn subset_mask_matches_envelope_gather() {
        // the satellite reference property (mirrored in numpy as
        // masks.tree_subset_mask): row i / col j of the subset mask equal
        // the envelope ancestor mask at the selected slots, and everything
        // outside the active block is zero
        check("dyn-subset-mask", 100, |rng| {
            let levels = 1 + rng.below(4);
            let widths: Vec<usize> = (0..levels).map(|_| 1 + rng.below(3)).collect();
            let t = TreeTopology::from_widths(&widths);
            let mask = t.build_mask();
            let joint = random_joint(&t, rng);
            let budget = 1 + rng.below(t.len());
            let sel = select_nodes(&t, &joint, budget);
            let width = t.len() + 1;
            let out = subset_mask_i32(&mask, &sel, width);
            let mut slots = vec![0usize];
            slots.extend_from_slice(&sel);
            for i in 0..width {
                for j in 0..width {
                    let want = if i < slots.len() && j < slots.len() {
                        mask.get(slots[i], slots[j]) as i32
                    } else {
                        0
                    };
                    if out[i * width + j] != want {
                        return Case::Fail {
                            desc: format!("({i},{j}) = {} want {want} sel {sel:?}", out[i * width + j]),
                            size: t.len(),
                        };
                    }
                }
            }
            Case::Pass
        });
    }

    #[test]
    fn full_selection_subset_mask_equals_envelope_mask() {
        // degenerate case: the subset mask must be byte-identical to the
        // static path's full ancestor mask export
        let t = env(&[3, 2, 1, 1, 1]);
        let mask = t.build_mask();
        let all: Vec<usize> = (1..=t.len()).collect();
        assert_eq!(subset_mask_i32(&mask, &all, t.len() + 1), mask.to_i32());
    }
}
