//! # P-EAGLE — Parallel-Drafting EAGLE with Scalable Training
//!
//! Rust + JAX + Pallas reproduction of the paper (see README.md / DESIGN.md).
//! Three layers:
//!
//! * **L1** (`python/compile/kernels/`): the Pallas fused draft-attention
//!   kernel (interpret mode, lowered into the HLO artifacts).
//! * **L2** (`python/compile/`): JAX target + drafter models, the scalable
//!   long-context training framework (amortized masks, COD, Algorithm 1),
//!   AOT lowering to HLO text.
//! * **L3** (this crate): the serving coordinator — PJRT runtime,
//!   wave-batched speculative decoding engine, schedulers, workload
//!   generation, the paper-scale mask/partition/memory substrates, and the
//!   bench harnesses that regenerate every table and figure.

pub mod config;
pub mod coordinator;
pub mod masking;
pub mod memmodel;
pub mod partition;
pub mod report;
pub mod runtime;
pub mod util;
pub mod workload;
