//! # P-EAGLE — Parallel-Drafting EAGLE with Scalable Training
//!
//! Rust + JAX + Pallas reproduction of the paper (front door: README.md;
//! layer map + step lifecycle: ARCHITECTURE.md). Three layers:
//!
//! * **L1** (`python/compile/kernels/`): the Pallas fused draft-attention
//!   kernel (interpret mode, lowered into the HLO artifacts).
//! * **L2** (`python/compile/`): JAX target + drafter models, the scalable
//!   long-context training framework (amortized masks, COD, Algorithm 1),
//!   AOT lowering to HLO text.
//! * **L3** (this crate): the serving coordinator — PJRT runtime and a
//!   stepped, continuously batched speculative-decoding core. `EngineCore`
//!   exposes `add_request` / `step` / `abort`: every `step()` is one
//!   {draft -> verify -> accept} iteration over all occupied KV slots,
//!   finished requests are evicted immediately, and queued requests are
//!   admitted into freed slots mid-flight via per-slot batch-1 prefill
//!   spliced into the shared KV buffer (empty rows are masked, never padded
//!   with fake requests). Speculation is per-REQUEST data: each request
//!   resolves to a [`coordinator::SpecPolicy`] — a manifest drafter plus a
//!   linear K-chain, a static draft tree verified in one pass against a
//!   precomputed cross-node mask ([`masking::tree`]), or a dynamic
//!   confidence-selected subtree of a max-shape envelope
//!   ([`masking::dynamic`]) — and `step()` groups slots by policy, one
//!   executable-pass per bucket over shared target weights. A thin bucket
//!   scheduler picks engine widths, a threaded server streams per-token
//!   events, and the workload + mask/partition/memory substrates feed the
//!   bench harnesses that regenerate every table and figure.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod masking;
pub mod memmodel;
pub mod partition;
pub mod report;
pub mod runtime;
pub mod util;
pub mod workload;
