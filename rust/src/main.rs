//! p-eagle CLI — leader entrypoint for the serving engine and the
//! paper-experiment reports.
//!
//! Subcommands:
//!   selftest                          runtime smoke test (loads artifacts)
//!   serve      --target --method --k --concurrency --requests [--dataset]
//!   eval-acceptance --drafter --dataset [--k --requests --max-new]
//!   bench-otps --target --method --k --concurrency [--dataset ...]
//!   report     --fig1 | --fig5 | --memmodel
//!   info                              manifest summary

use anyhow::{anyhow, Result};

use p_eagle::config::Manifest;
use p_eagle::memmodel;
use p_eagle::report;
use p_eagle::runtime::{Arg, HostTensor, ModelRuntime, Runtime};
use p_eagle::util::cli::Args;

fn artifacts_root(args: &Args) -> String {
    args.get_or("artifacts", "artifacts")
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("selftest") => selftest(&args),
        Some("info") => info(&args),
        Some("serve") => serve(&args),
        Some("eval-acceptance") => eval_acceptance(&args),
        Some("bench-otps") => bench_otps(&args),
        Some("report") => run_report(&args),
        other => {
            eprintln!("unknown subcommand {other:?}");
            eprintln!("usage: p-eagle <selftest|info|serve|eval-acceptance|bench-otps|report> [options]");
            std::process::exit(2);
        }
    }
}

/// Load the selftest HLO (2x2 matmul) and check the numbers end-to-end.
fn selftest(args: &Args) -> Result<()> {
    let manifest = Manifest::load(artifacts_root(args))?;
    let mut rt = Runtime::cpu()?;
    println!("platform: {}", rt.client.platform_name());
    let e = manifest.find_exec("selftest", None, None, None, None)?;
    rt.load(&e.name, &manifest.abs(&e.path))?;
    let x = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
    let y = HostTensor::f32(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
    let out = rt.call("selftest", &[Arg::Host(&x), Arg::Host(&y)])?;
    let t = rt.download(&out[0])?;
    let got = t.as_f32()?;
    anyhow::ensure!(got == [5.0, 5.0, 9.0, 9.0], "selftest numerics: {got:?}");
    println!("selftest OK: {got:?}");
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let m = Manifest::load(artifacts_root(args))?;
    println!("P-EAGLE artifacts @ {:?}", m.root);
    println!("vocab={} s_max={} prompt_pad={} ctx_window={}", m.vocab, m.s_max, m.prompt_pad, m.ctx_window);
    println!("targets:");
    for (n, t) in &m.targets {
        println!("  {n}: d={} L={} H={} feat={}", t.d_model, t.n_layers, t.n_heads, t.feature_dim);
    }
    println!("drafters ({}):", m.drafters.len());
    for (n, d) in &m.drafters {
        println!("  {n}: kind={} L={} hidden={} target={}", d.kind, d.n_layers, d.hidden_mode, d.target);
    }
    println!("executables: {}", m.executables.len());
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let mut mr = ModelRuntime::load(artifacts_root(args))?;
    let target = args.get_or("target", "target-m");
    let method = args.get_or("method", "pe4");
    let drafter = mr.manifest.serving_drafter(&target, &method);
    let k = args.usize_or("k", mr.manifest.default_k);
    let conc = args.usize_or("concurrency", 2);
    let total = args.usize_or("requests", 8);
    let max_new = args.usize_or("max-new", 96);
    let dataset = args.get_or("dataset", "mtbench");

    let run = report::bench_otps(&mut mr, &drafter, &dataset, k, conc, total, max_new, 7)?;
    println!(
        "served {total} requests  target={target} method={method} K={k} C={conc} dataset={dataset}"
    );
    println!(
        "OTPS {:.0}  AL {:.2}  p50 latency {:?}  p99 latency {:?}",
        run.otps,
        run.acceptance_length,
        run.metrics.latency_quantile(0.5),
        run.metrics.latency_quantile(0.99),
    );
    println!("{}", run.metrics.summary());
    Ok(())
}

fn eval_acceptance(args: &Args) -> Result<()> {
    let mut mr = ModelRuntime::load(artifacts_root(args))?;
    let drafter = args
        .get("drafter")
        .ok_or_else(|| anyhow!("--drafter required"))?
        .to_string();
    let dataset = args.get_or("dataset", "humaneval");
    let k = args.usize_or("k", mr.manifest.default_k);
    let n = args.usize_or("requests", 16);
    let max_new = args.usize_or("max-new", 96);
    let e = report::eval_acceptance(&mut mr, &drafter, &dataset, k, n, max_new)?;
    println!(
        "AL({}, {}, K={}) = {:.3}  [{} requests]",
        e.drafter, e.dataset, e.k, e.acceptance_length, e.requests
    );
    Ok(())
}

fn bench_otps(args: &Args) -> Result<()> {
    let mut mr = ModelRuntime::load(artifacts_root(args))?;
    let target = args.get_or("target", "target-m");
    let method = args.get_or("method", "pe4");
    let drafter = mr.manifest.serving_drafter(&target, &method);
    let k = args.usize_or("k", mr.manifest.default_k);
    let conc = args.usize_or("concurrency", 2);
    let total = args.usize_or("requests", 8);
    let max_new = args.usize_or("max-new", 96);
    let dataset = args.get_or("dataset", "gsm8k");
    let run = report::bench_otps(&mut mr, &drafter, &dataset, k, conc, total, max_new, 11)?;
    println!(
        "OTPS[{target}/{method} K={k} C={conc} {dataset}] = {:.0} (AL {:.2})",
        run.otps, run.acceptance_length
    );
    if args.flag("profile") {
        let m = &run.metrics;
        println!(
            "breakdown: prefill {:?}  draft {:?}  verify {:?}  host {:?}  \
             (engine wall {:?}, {} iterations)",
            m.prefill_time, m.draft_time, m.verify_time, m.host_time,
            m.wall_time, m.iterations
        );
        println!(
            "runtime: {} exec calls, exec {:?}, untuple {:?}, compile {:?}",
            mr.rt.exec_calls, mr.rt.exec_time, mr.rt.untuple_time, mr.rt.compile_time
        );
    }
    Ok(())
}

fn run_report(args: &Args) -> Result<()> {
    if args.flag("fig1") {
        println!("{}", report::fig1_report(40_000));
        return Ok(());
    }
    if args.flag("fig5") {
        let mr = ModelRuntime::load(artifacts_root(args))?;
        println!("{}", report::fig5_report(&mr));
        return Ok(());
    }
    if args.flag("memmodel") {
        println!("Table 1 feasibility classification (paper-scale memory model)");
        for (label, n) in [("1K", 1024usize), ("4K", 4096), ("8K", 8192), ("20K", 20480)] {
            let ps = memmodel::classify(&memmodel::TrainSetup::parallelspec(n, 8), 200_000);
            let pd = memmodel::classify(&memmodel::TrainSetup::pard(n, 8), 200_000);
            let pe = memmodel::classify(&memmodel::TrainSetup::peagle(n, 8), 200_000);
            println!(
                "  {label:>4}: ParallelSpec={:<8} PARD={:<8} P-EAGLE={:<8}",
                ps.label(),
                pd.label(),
                pe.label()
            );
        }
        return Ok(());
    }
    Err(anyhow!("report: pass --fig1, --fig5, or --memmodel"))
}
