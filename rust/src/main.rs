//! p-eagle CLI — leader entrypoint for the serving engine and the
//! paper-experiment reports.
//!
//! Subcommands:
//!   selftest                          runtime smoke test (loads artifacts)
//!   serve      --target --method --k --concurrency --requests
//!              [--dataset --max-new --quiet]   (streams engine step events)
//!              [--drafters a,b,..]    (serve several drafters from ONE
//!                                      engine: requests round-robin across
//!                                      the list; the first is the default)
//!              [--policy chain:K|tree:TOPO|dyn:ENV@B]
//!                                     (speculation shape for every listed
//!                                      drafter; default chain:K)
//!              [--paged [--kv-blocks N]]       (block-paged KV cache;
//!                                      --kv-blocks caps the block budget)
//!              [--prefix-cache]       (automatic prefix caching: shared
//!                                      prompt-prefix blocks, copy-on-write;
//!                                      implies --paged)
//!              [--tree-dyn [--tree-envelope w:..] [--tree-budget N]]
//!                                     (legacy spelling of --policy dyn:..)
//!              [--temperature T [--top-p P] [--top-k N]]
//!                                     (per-request sampling: filtered-softmax
//!                                      target, lossless rejection-sampling
//!                                      acceptance; --top-p/--top-k imply
//!                                      --temperature 1.0; default greedy)
//!              [--adaptive [--adaptive-budget-min N]]
//!                                     (feedback-driven speculation
//!                                      controller: policy-free requests are
//!                                      assigned from live signal; in-flight
//!                                      Dynamic budgets re-tune each step)
//!   eval-acceptance --drafter --dataset [--k --requests --max-new]
//!   bench-otps --target --method --k --concurrency
//!              [--dataset --mixed --profile]
//!              [--sweep-drafters]     (one run per serveable drafter of the
//!                                      target, shared runtime/weights, and
//!                                      a comparison table)
//!              [--paged [--kv-blocks N]] [--prefix-cache]
//!              [--shared-prefix N]     (every prompt opens with the same
//!                                      N-token header — the workload where
//!                                      --prefix-cache collapses TTFT)
//!              [--tree [--tree-topo chain:K|w:w1,w2,..]]
//!                                     (--tree runs a chain-vs-tree pair on
//!                                      the same workload seed and reports
//!                                      the acceptance-length delta)
//!              [--tree-dyn [--tree-envelope w:..] [--tree-budget N]]
//!                                     (adds a dynamic-tree run at an equal
//!                                      verified-node budget — default
//!                                      budget = the static tree's node
//!                                      count — plus the accepted-by-depth
//!                                      tuning histogram)
//!              [--temperature T [--top-p P] [--top-k N]]
//!                                     (benchmark under temperature serving —
//!                                      rejection-sampling acceptance; the
//!                                      default stays greedy/bit-reproducible)
//!              [--adaptive [--adaptive-budget-min N]]
//!                                     (adaptive-controller run; with
//!                                      --sweep-drafters, appends an adaptive
//!                                      row to the comparison table on the
//!                                      same workload seed)
//!   bench-suite                       perf-trajectory matrix -> BENCH_<pr>.json
//!              [--smoke]              (CI-sized matrix: fewer loads, tiny budgets)
//!              [--pr N --out FILE]    (default BENCH_<CURRENT_PR>.json)
//!              [--target --dataset --requests --max-new --seed --kv-blocks N]
//!              [--compare OLD.json]   (run, then gate vs OLD: exit 1 when a
//!                                      cell regresses beyond thresholds)
//!              [--compare OLD.json --new NEW.json]
//!                                     (pure file-vs-file gate — no runtime,
//!                                      no artifacts needed)
//!              [--validate FILE]      (schema-check one file, no runtime)
//!              [--threshold-otps F --threshold-ttft F]
//!                                     (relative regression limits; default
//!                                      0.10 OTPS drop, 0.20 p99 TTFT growth)
//!              [--advisory]           (report regressions, exit 0 anyway)
//!   report     --fig1 | --fig5 | --memmodel
//!   info                              manifest summary

use anyhow::{anyhow, Result};

use p_eagle::config::Manifest;
use p_eagle::coordinator::server::spawn;
use p_eagle::coordinator::{
    adaptive_from_env, device_commit_from_env, tree_dyn_from_env, ControllerConfig,
    EngineConfig, EngineMetrics, PagedKvConfig, SamplingParams, ServerEvent, SpecPolicy,
};
use p_eagle::masking::{DynamicTreeConfig, TreeTopology};
use p_eagle::memmodel;
use p_eagle::report;
use p_eagle::runtime::{Arg, HostTensor, ModelRuntime, Runtime};
use p_eagle::util::cli::Args;

fn artifacts_root(args: &Args) -> String {
    args.get_or("artifacts", "artifacts")
}

/// `--paged [--kv-blocks N]` (or the `PEAGLE_PAGED=1` env the CI paged job
/// sets): serve from the block-paged KV cache; `--kv-blocks` budgets the
/// allocator below full provisioning (admission then queues on free blocks)
/// and implies `--paged` — a block budget on the dense cache would be
/// silently meaningless. Block size always comes from the manifest.
/// `--prefix-cache` (or `PEAGLE_PREFIX_CACHE=1`) additionally enables the
/// automatic prefix cache — content-addressed prompt blocks shared
/// copy-on-write across requests — and implies `--paged`, since the cache
/// lives in the block allocator. `PEAGLE_DEVICE_COMMIT=1` (the CI
/// device-commit job) also implies `--paged`; the device commit arm itself
/// is on whenever the manifest carries the commit executables.
fn paged_opts(args: &Args) -> Option<PagedKvConfig> {
    let kv_blocks = args
        .get("kv-blocks")
        .map(|n| n.parse().unwrap_or_else(|_| panic!("--kv-blocks expects a number")));
    let env = device_commit_from_env();
    let prefix = args.flag("prefix-cache") || env.is_some_and(|p| p.prefix_cache);
    let on = args.flag("paged") || kv_blocks.is_some() || prefix || env.is_some();
    on.then(|| PagedKvConfig { block_size: None, num_blocks: kv_blocks, prefix_cache: prefix })
}

/// `--tree-dyn [--tree-envelope w:..] [--tree-budget N]` (or the
/// `PEAGLE_TREE_DYN=1` env the CI tree-dyn job sets): dynamic
/// confidence-driven tree speculation inside a max-shape envelope. The
/// envelope defaults to the lowered serving envelope
/// (`DynamicTreeConfig::DEFAULT_ENVELOPE_SPEC`); the budget defaults to
/// `default_budget` (bench-otps passes the static comparison tree's node
/// count, so the three-way comparison spends an equal verified-node
/// budget), clamped to the envelope's node count so a small
/// `--tree-envelope` without an explicit budget just degrades to its own
/// degenerate case. `--tree-budget`/`--tree-envelope` imply `--tree-dyn`.
/// Oversized or malformed specs fail here with the descriptive
/// `TreeTopology::parse` errors, never a panic downstream.
fn tree_dyn_opts(args: &Args, default_budget: usize) -> Result<Option<DynamicTreeConfig>> {
    let budget = args.get("tree-budget").map(|n| {
        n.parse::<usize>()
            .unwrap_or_else(|_| panic!("--tree-budget expects a number"))
    });
    let envelope = args.get("tree-envelope").map(String::from);
    let on = args.flag("tree-dyn") || budget.is_some() || envelope.is_some()
        || tree_dyn_from_env().is_some();
    if !on {
        return Ok(None);
    }
    let spec = envelope.unwrap_or_else(|| DynamicTreeConfig::DEFAULT_ENVELOPE_SPEC.into());
    let envelope = TreeTopology::parse(&spec).map_err(|e| anyhow!(e))?;
    let budget = budget.unwrap_or_else(|| default_budget.min(envelope.len()));
    let cfg = DynamicTreeConfig::new(envelope, budget).map_err(|e| anyhow!(e))?;
    Ok(Some(cfg))
}

/// `--adaptive [--adaptive-budget-min N]` (or the `PEAGLE_ADAPTIVE=1` env
/// the CI adaptive job sets): the feedback-driven speculation controller.
/// Policy-free requests are assigned a `SpecPolicy` from live windowed
/// engine signal instead of the static default, and in-flight Dynamic
/// node budgets are re-tuned every step within
/// `[budget_min, admitted width]`. `--adaptive-budget-min` lowers (or
/// raises) the floor the throttle ladder can shrink budgets to and
/// implies `--adaptive`. Explicit `--policy`/round-robin assignments
/// bypass the controller — it only decides for requests that arrive
/// without a policy.
fn adaptive_opts(args: &Args) -> Option<ControllerConfig> {
    let budget_min = args.get("adaptive-budget-min").map(|n| {
        n.parse::<usize>()
            .unwrap_or_else(|_| panic!("--adaptive-budget-min expects a number"))
    });
    let on = args.flag("adaptive") || budget_min.is_some() || adaptive_from_env().is_some();
    on.then(|| {
        let mut cfg = ControllerConfig::default();
        if let Some(b) = budget_min {
            cfg.budget_min = b.max(1);
        }
        cfg
    })
}

/// `--temperature T [--top-p P] [--top-k N]`: per-request sampling for
/// serve/bench-otps. The target distribution is the filtered softmax
/// (temperature, then top-k, then top-p nucleus) and acceptance switches
/// from greedy exact-match to lossless rejection sampling against that
/// distribution. `--top-p`/`--top-k` imply `--temperature 1.0` — a filter
/// without a temperature means "sample from the filtered raw softmax", not
/// greedy (greedy ignores filters entirely). With none of the flags the
/// default stays greedy and the output is bit-reproducible.
fn sampling_opts(args: &Args) -> Result<SamplingParams> {
    let temperature = args.get("temperature").map(|t| {
        t.parse::<f32>()
            .unwrap_or_else(|_| panic!("--temperature expects a number"))
    });
    let top_p = args.get("top-p").map(|p| {
        p.parse::<f32>().unwrap_or_else(|_| panic!("--top-p expects a number"))
    });
    let top_k = args.get("top-k").map(|k| {
        k.parse::<usize>().unwrap_or_else(|_| panic!("--top-k expects a number"))
    });
    let temperature = match (temperature, top_p.is_some() || top_k.is_some()) {
        (Some(t), _) => t,
        (None, true) => 1.0,
        (None, false) => return Ok(SamplingParams::greedy()),
    };
    let mut sp = SamplingParams::temperature(temperature, 11);
    if let Some(p) = top_p {
        sp = sp.with_top_p(p);
    }
    if let Some(k) = top_k {
        sp = sp.with_top_k(k);
    }
    sp.validate().map_err(|e| anyhow!(e))?;
    Ok(sp)
}

/// Per-policy metrics breakdown (multi-policy engines; a single row for a
/// homogeneous batch): AL, per-depth acceptance, bucket passes, keyed by
/// POLICY IDENTITY (`drafter/mode:shape` — distinct shapes on one drafter
/// get distinct rows, which is what the adaptive controller's ladder moves
/// produce). A second table rolls the rows back up to drafter names when
/// more than one drafter contributed.
fn print_policy_breakdown(metrics: &EngineMetrics) {
    if metrics.per_policy.len() <= 1 {
        return;
    }
    println!("per-policy breakdown:");
    for (name, pm) in &metrics.per_policy {
        let rates: Vec<String> =
            pm.depth_acceptance_rates().iter().map(|r| format!("{r:.2}")).collect();
        // drafter-calibration readout (dynamic-tree policies only): mean
        // drafter-estimated conditional q among accepted vs rejected nodes —
        // a well-calibrated drafter shows q̄acc well above q̄rej
        let calib = if pm.q_accepted_n + pm.q_rejected_n > 0 {
            format!("  q̄acc {:.2} q̄rej {:.2}", pm.mean_q_accepted(), pm.mean_q_rejected())
        } else {
            String::new()
        };
        println!(
            "  {name:<34} AL {:.2}  iters {}  passes {}  accepted-by-depth [{}]{calib}",
            pm.acceptance_length(),
            pm.iterations,
            pm.steps,
            rates.join(" "),
        );
    }
    let rollup = metrics.per_drafter();
    if rollup.len() > 1 {
        println!("per-drafter rollup:");
        for (name, pm) in &rollup {
            println!(
                "  {name:<34} AL {:.2}  iters {}  passes {}",
                pm.acceptance_length(),
                pm.iterations,
                pm.steps,
            );
        }
    }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("selftest") => selftest(&args),
        Some("info") => info(&args),
        Some("serve") => serve(&args),
        Some("eval-acceptance") => eval_acceptance(&args),
        Some("bench-otps") => bench_otps(&args),
        Some("bench-suite") => bench_suite(&args),
        Some("report") => run_report(&args),
        other => {
            eprintln!("unknown subcommand {other:?}");
            eprintln!("usage: p-eagle <selftest|info|serve|eval-acceptance|bench-otps|bench-suite|report> [options]");
            std::process::exit(2);
        }
    }
}

/// Load the selftest HLO (2x2 matmul) and check the numbers end-to-end.
fn selftest(args: &Args) -> Result<()> {
    let manifest = Manifest::load(artifacts_root(args))?;
    let mut rt = Runtime::cpu()?;
    println!("platform: {}", rt.client.platform_name());
    let e = manifest.find_exec("selftest", None, None, None, None)?;
    rt.load(&e.name, &manifest.abs(&e.path))?;
    let x = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
    let y = HostTensor::f32(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
    let out = rt.call("selftest", &[Arg::Host(&x), Arg::Host(&y)])?;
    let t = rt.download(&out[0])?;
    let got = t.as_f32()?;
    anyhow::ensure!(got == [5.0, 5.0, 9.0, 9.0], "selftest numerics: {got:?}");
    println!("selftest OK: {got:?}");
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let m = Manifest::load(artifacts_root(args))?;
    println!("P-EAGLE artifacts @ {:?}", m.root);
    println!("vocab={} s_max={} prompt_pad={} ctx_window={}", m.vocab, m.s_max, m.prompt_pad, m.ctx_window);
    println!("targets:");
    for (n, t) in &m.targets {
        println!("  {n}: d={} L={} H={} feat={}", t.d_model, t.n_layers, t.n_heads, t.feature_dim);
    }
    println!("drafters ({}):", m.drafters.len());
    for (n, d) in &m.drafters {
        println!(
            "  {n}: kind={} L={} hidden={} target={} modes=[{}]",
            d.kind,
            d.n_layers,
            d.hidden_mode,
            d.target,
            d.modes.join(",")
        );
    }
    println!("executables: {}", m.executables.len());
    Ok(())
}

/// Drive the threaded streaming server: submit `requests` and print events
/// as they arrive from the step loop (admissions, per-step token chunks,
/// finishes), then shut down and report occupancy/TTFT/latency.
///
/// With `--drafters a,b,..` one engine serves every listed drafter:
/// requests are assigned round-robin across the list (each with the
/// `--policy` speculation shape), so a single batch concurrently mixes
/// drafters — the multi-drafter manifest in action.
fn serve(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let manifest = Manifest::load(&root)?;
    let target = args.get_or("target", "target-m");
    let method = args.get_or("method", "pe4");
    let k = args.usize_or("k", manifest.default_k);
    let conc = args.usize_or("concurrency", 2);
    let total = args.usize_or("requests", 8);
    let max_new = args.usize_or("max-new", 96);
    let dataset = args.get_or("dataset", "mtbench");
    let quiet = args.flag("quiet");

    let mut drafters = args.str_list("drafters");
    if drafters.is_empty() && args.get("drafters").is_none() {
        drafters = vec![manifest.serving_drafter(&target, &method)];
    }
    anyhow::ensure!(!drafters.is_empty(), "--drafters needs at least one name");

    // the speculation shape: --policy wins; otherwise the legacy --tree-dyn
    // family; otherwise chain at --k
    let tree_dynamic = tree_dyn_opts(args, DynamicTreeConfig::DEFAULT_NODE_BUDGET)?;
    let mode_spec = match args.get("policy") {
        Some(s) => s.to_string(),
        None => match &tree_dynamic {
            Some(d) => format!("dyn:{}@{}", d.envelope.spec_string(), d.node_budget),
            None => format!("chain:{k}"),
        },
    };
    let policies: Vec<SpecPolicy> = drafters
        .iter()
        .map(|d| SpecPolicy::parse(d, &mode_spec).map_err(|e| anyhow!(e)))
        .collect::<Result<_>>()?;
    for p in &policies {
        println!("serving policy: {}", p.id());
    }

    let sampling = sampling_opts(args)?;
    if !sampling.config().is_greedy() {
        println!("serving sampling: {sampling:?}");
    }
    let adaptive = adaptive_opts(args);
    if let Some(a) = &adaptive {
        println!("serving adaptive controller: budget_min={} window={}", a.budget_min, a.window);
    }
    let mut arr = report::closed_loop_arrivals(&manifest, &dataset, max_new, 7)?;
    let cfg = EngineConfig::new(&target, policies[0].clone(), conc, max_new)
        .with_policies(policies[1..].to_vec())
        .with_seed(7)
        .with_paged(paged_opts(args))
        .with_adaptive(adaptive.clone());
    // ready/error handshake: a bad artifacts root fails here, not in a log
    let handle = spawn(root, cfg)?;
    for i in 0..total {
        let mut req = arr.next();
        if policies.len() > 1 && adaptive.is_none() {
            // round-robin: one batch concurrently serves every drafter.
            // Under --adaptive requests stay policy-free so the controller
            // assigns from live signal instead.
            req = req.with_policy(policies[i % policies.len()].clone());
        }
        // per-request private rng stream: shared mode/filters, the seed
        // derived from (server seed, request id)
        let seed = 7 ^ req.id;
        req = req.with_sampling(SamplingParams { seed, ..sampling });
        handle.submit(req);
    }
    let mut finished = 0usize;
    while finished < total {
        match handle.events_rx.recv() {
            Ok(ServerEvent::Admitted { id, slot }) => {
                if !quiet {
                    println!("[admit]  req {id} -> slot {slot}");
                }
            }
            Ok(ServerEvent::Tokens { id, tokens }) => {
                if !quiet {
                    println!("[tokens] req {id} += {tokens:?}");
                }
            }
            Ok(ServerEvent::Finished(r)) => {
                finished += 1;
                println!(
                    "[done]   req {} ({} tokens, {:?}, AL {:.2}, {:?})",
                    r.id,
                    r.tokens.len(),
                    r.finish,
                    r.acceptance_length(),
                    r.latency
                );
            }
            Ok(ServerEvent::Rejected { id, error }) => {
                finished += 1;
                println!("[reject] req {id}: {error}");
            }
            Ok(ServerEvent::EngineError(e)) => return Err(anyhow!("engine error: {e}")),
            Err(_) => return Err(anyhow!("server died with {finished}/{total} finished")),
        }
    }
    let metrics = handle.shutdown();
    println!(
        "served {total} requests  target={target} drafters={} C={conc} dataset={dataset}",
        drafters.join(",")
    );
    println!(
        "OTPS {:.0}  AL {:.2}  occupancy {:.2}  p50 TTFT {:?}  p50 TPOT {:?}  \
         p50 latency {:?}  p99 latency {:?}",
        metrics.otps(),
        metrics.acceptance_length(),
        metrics.mean_occupancy(),
        metrics.ttft_quantile(0.5),
        metrics.tpot_quantile(0.5),
        metrics.latency_quantile(0.5),
        metrics.latency_quantile(0.99),
    );
    print_policy_breakdown(&metrics);
    println!("{}", metrics.summary());
    Ok(())
}

fn eval_acceptance(args: &Args) -> Result<()> {
    let mut mr = ModelRuntime::load(artifacts_root(args))?;
    let drafter = args
        .get("drafter")
        .ok_or_else(|| anyhow!("--drafter required"))?
        .to_string();
    let dataset = args.get_or("dataset", "humaneval");
    let k = args.usize_or("k", mr.manifest.default_k);
    let n = args.usize_or("requests", 16);
    let max_new = args.usize_or("max-new", 96);
    let e = report::eval_acceptance(&mut mr, &drafter, &dataset, k, n, max_new)?;
    println!(
        "AL({}, {}, K={}) = {:.3}  [{} requests]",
        e.drafter, e.dataset, e.k, e.acceptance_length, e.requests
    );
    Ok(())
}

fn bench_otps(args: &Args) -> Result<()> {
    let mut mr = ModelRuntime::load(artifacts_root(args))?;
    let target = args.get_or("target", "target-m");
    let method = args.get_or("method", "pe4");
    let drafter = mr.manifest.serving_drafter(&target, &method);
    let k = args.usize_or("k", mr.manifest.default_k);
    let conc = args.usize_or("concurrency", 2);
    let total = args.usize_or("requests", 8);
    let max_new = args.usize_or("max-new", 96);
    let dataset = args.get_or("dataset", "gsm8k");
    // --mixed: per-request generation budgets from the Fig.1 length model —
    // the head-of-line workload the stepped engine exists for
    let mixed = args.flag("mixed");
    let sampling = sampling_opts(args)?;

    // --sweep-drafters: one run per serveable drafter of the target,
    // in-process (ONE runtime: shared target weights, shared executable
    // registry), printed as a comparison table on the same workload seed.
    if args.flag("sweep-drafters") {
        let runs = report::sweep_drafters(
            &mut mr, &target, &dataset, k, conc, total, max_new, 11, mixed,
            paged_opts(args), sampling,
        )?;
        println!(
            "drafter sweep [{target} K={k} C={conc} {dataset}{}] — {} drafters, shared runtime",
            if mixed { " mixed" } else { "" },
            runs.len(),
        );
        println!("{:<22} {:>8} {:>6} {:>6} {:>8}", "drafter", "OTPS", "AL", "occ", "iters");
        for run in &runs {
            println!(
                "{:<22} {:>8.0} {:>6.2} {:>6.2} {:>8}",
                run.drafter,
                run.otps,
                run.acceptance_length,
                run.mean_occupancy,
                run.metrics.iterations,
            );
        }
        // --adaptive appends the controller on the SAME workload seed as a
        // final comparison row: the adaptive run should meet or beat every
        // static row above (the integration gate asserts exactly that).
        if let Some(cfg) = adaptive_opts(args) {
            let run = report::bench_otps_adaptive(
                &mut mr, &target, &dataset, k, conc, total, max_new, 11, mixed,
                paged_opts(args), sampling, None, cfg,
            )?;
            println!(
                "{:<22} {:>8.0} {:>6.2} {:>6.2} {:>8}",
                "adaptive (auto)",
                run.otps,
                run.acceptance_length,
                run.mean_occupancy,
                run.metrics.iterations,
            );
            print_policy_breakdown(&run.metrics);
        }
        return Ok(());
    }

    // --adaptive without --sweep-drafters: one adaptive run — the
    // controller picks drafter/shape/budget per request from live signal.
    if let Some(cfg) = adaptive_opts(args) {
        let run = report::bench_otps_adaptive(
            &mut mr, &target, &dataset, k, conc, total, max_new, 11, mixed,
            paged_opts(args), sampling, None, cfg,
        )?;
        println!(
            "OTPS[{target} adaptive C={conc} {dataset}{}] = {:.0} \
             (AL {:.2}, occupancy {:.2}, p50 TPOT {:?})",
            if mixed { " mixed" } else { "" },
            run.otps,
            run.acceptance_length,
            run.mean_occupancy,
            run.metrics.tpot_quantile(0.5),
        );
        print_policy_breakdown(&run.metrics);
        return Ok(());
    }

    // --tree: chain / static-tree / (with --tree-dyn) dynamic-tree runs on
    // the same workload seed. The static topology defaults to the serving
    // profile the artifacts lower (w:3,2,1,1,1 — configs.TREE_TOPOLOGIES);
    // --tree-topo overrides it. --tree-dyn (or --tree-budget /
    // --tree-envelope / PEAGLE_TREE_DYN=1, which imply it — tree_dyn_opts
    // is the single source of that rule) adds the dynamic run, its node
    // budget defaulting to the static tree's node count so the comparison
    // spends an equal verified-node budget.
    let spec = args.get_or("tree-topo", "w:3,2,1,1,1");
    let tree = TreeTopology::parse(&spec).map_err(|e| anyhow!(e))?;
    let dynamic = tree_dyn_opts(args, tree.len())?;
    if args.flag("tree") || dynamic.is_some() {
        if args.get("k").is_some() {
            eprintln!(
                "note: --tree compares at the tree's own depth budget \
                 (K = {}); --k is ignored",
                tree.max_depth()
            );
        }
        let (chain, treed, dyned) = report::compare_chain_tree(
            &mut mr, &drafter, &dataset, &tree, dynamic.as_ref(), conc, total, max_new,
            11, mixed, paged_opts(args), sampling,
        )?;
        println!(
            "chain[{target}/{method} K={} C={conc} {dataset}{}] OTPS {:.0}  AL {:.2}  occ {:.2}",
            tree.max_depth(),
            if mixed { " mixed" } else { "" },
            chain.otps,
            chain.acceptance_length,
            chain.mean_occupancy,
        );
        println!(
            "tree [{} = {} nodes, depth {}]      OTPS {:.0}  AL {:.2}  occ {:.2}  commit {:?}",
            tree.id(),
            tree.len(),
            tree.max_depth(),
            treed.otps,
            treed.acceptance_length,
            treed.mean_occupancy,
            treed.metrics.commit_time,
        );
        println!(
            "AL delta: {:+.2} ({:+.1}%)  — tree accepts every chain path plus deeper \
             sibling paths, so AL >= chain on the same seed",
            treed.acceptance_length - chain.acceptance_length,
            (treed.acceptance_length / chain.acceptance_length.max(1e-9) - 1.0) * 100.0,
        );
        if let (Some(d), Some(dr)) = (&dynamic, &dyned) {
            println!(
                "dyn  [{} envelope {} nodes, budget {}] OTPS {:.0}  AL {:.2}  occ {:.2}  commit {:?}",
                d.envelope.id(),
                d.envelope.len(),
                d.active_nodes(),
                dr.otps,
                dr.acceptance_length,
                dr.mean_occupancy,
                dr.metrics.commit_time,
            );
            println!(
                "AL delta vs static tree: {:+.2} ({:+.1}%) at {} verified nodes/step \
                 (static spends {})",
                dr.acceptance_length - treed.acceptance_length,
                (dr.acceptance_length / treed.acceptance_length.max(1e-9) - 1.0) * 100.0,
                d.active_nodes(),
                tree.len(),
            );
        }
        // the envelope/budget tuning printout: which depths actually accept,
        // and how many nodes each mode spends to get them
        for (label, run) in std::iter::once(("tree", &treed))
            .chain(dyned.as_ref().map(|d| ("dyn ", d)))
        {
            let rates: Vec<String> = run
                .metrics
                .depth_acceptance_rates()
                .iter()
                .map(|r| format!("{r:.2}"))
                .collect();
            println!(
                "{label} accepted-by-depth [{}]  mean active nodes {:.1}",
                rates.join(" "),
                run.metrics.mean_active_nodes(),
            );
        }
        if args.flag("profile") {
            let mut rows = vec![("chain", &chain.metrics), ("tree ", &treed.metrics)];
            if let Some(dr) = &dyned {
                rows.push(("dyn  ", &dr.metrics));
            }
            for (label, m) in rows {
                println!(
                    "{label} breakdown: admission {:?} ({} admits)  draft {:?}  \
                     verify {:?}  commit {:?}  host {:?}  ({} iterations)",
                    m.admission_time, m.admissions, m.draft_time, m.verify_time,
                    m.commit_time, m.host_time, m.iterations
                );
            }
        }
        return Ok(());
    }

    // --shared-prefix N: stamp the same N-token header onto every prompt.
    // Pair a run with and without --prefix-cache on this workload: tokens
    // must match byte-for-byte while TTFT collapses toward the tail cost.
    let shared_prefix = args.usize_or("shared-prefix", 0);
    let run = if shared_prefix > 0 {
        report::bench_otps_prefix(
            &mut mr, &drafter, &dataset, k, conc, total, max_new, 11, None, None,
            paged_opts(args), sampling, shared_prefix,
        )?
    } else {
        report::bench_otps(
            &mut mr, &drafter, &dataset, k, conc, total, max_new, 11, mixed, None, None,
            paged_opts(args), sampling,
        )?
    };
    println!(
        "OTPS[{target}/{method} K={k} C={conc} {dataset}{}] = {:.0} \
         (AL {:.2}, occupancy {:.2}, p50 TPOT {:?})",
        if mixed { " mixed" } else { "" },
        run.otps,
        run.acceptance_length,
        run.mean_occupancy,
        run.metrics.tpot_quantile(0.5),
    );
    if run.metrics.block_steps_total > 0 {
        println!(
            "paged: block occupancy {:.2} (peak {} blocks), admissions blocked {}, rewires {}",
            run.metrics.mean_block_occupancy(),
            run.metrics.blocks_peak,
            run.metrics.admissions_blocked,
            run.metrics.block_rewires,
        );
    }
    if run.metrics.prefix_hits + run.metrics.prefix_misses > 0 {
        println!(
            "prefix cache: hits {}/{} admissions, {} prompt tokens served from cache, \
             cow copies {}, evictions {}, shared-block peak {}, p50 TTFT {:?}",
            run.metrics.prefix_hits,
            run.metrics.prefix_hits + run.metrics.prefix_misses,
            run.metrics.prefix_tokens_cached,
            run.metrics.cow_copies,
            run.metrics.prefix_evictions,
            run.metrics.shared_blocks_peak,
            run.metrics.ttft_quantile(0.5),
        );
    }
    if run.metrics.transfer_steps > 0 {
        println!(
            "transfers: {:.1} downloads/step ({:.2} MB), {:.1} uploads/step ({:.2} MB), \
             kv downloads {}, kv uploads {}, device commits {}",
            run.metrics.downloads_per_step(),
            run.metrics.download_bytes as f64 / 1e6,
            run.metrics.uploads_per_step(),
            run.metrics.upload_bytes as f64 / 1e6,
            run.metrics.kv_downloads,
            run.metrics.kv_uploads,
            run.metrics.device_path_commits,
        );
    }
    print_policy_breakdown(&run.metrics);
    if args.flag("profile") {
        let m = &run.metrics;
        println!(
            "breakdown: admission {:?} ({} admits)  draft {:?}  verify {:?}  host {:?}  \
             (engine wall {:?}, {} iterations, p50 TTFT {:?}, p50 TPOT {:?})",
            m.admission_time, m.admissions, m.draft_time, m.verify_time, m.host_time,
            m.wall_time, m.iterations, m.ttft_quantile(0.5), m.tpot_quantile(0.5)
        );
        println!(
            "runtime: {} exec calls, exec {:?}, untuple {:?}, compile {:?}",
            mr.rt.exec_calls, mr.rt.exec_time, mr.rt.untuple_time, mr.rt.compile_time
        );
    }
    Ok(())
}

/// The perf-trajectory harness: run the workload matrix into
/// `BENCH_<pr>.json` and/or gate two trajectory files against each other.
/// `--validate` and the file-vs-file `--compare OLD --new NEW` modes are
/// PURE file operations — CI runs them with no artifacts and no PJRT.
/// Regressions beyond the thresholds exit nonzero unless `--advisory`.
fn bench_suite(args: &Args) -> Result<()> {
    use p_eagle::bench::{self, BenchReport, SuiteSpec, Thresholds};

    let load_file = |path: &str| -> Result<BenchReport> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path}: {e}"))?;
        BenchReport::parse(&text).map_err(|e| anyhow!("{path}: {e}"))
    };
    if let Some(f) = args.get("validate") {
        let r = load_file(f)?;
        println!(
            "{f}: schema v{} OK — {} cells ({} suite, pr {}, git {})",
            r.schema_version,
            r.cells.len(),
            r.suite,
            r.pr,
            r.git_rev,
        );
        return Ok(());
    }
    let th = Thresholds {
        otps_frac: args.f64_or("threshold-otps", Thresholds::default().otps_frac),
        ttft_frac: args.f64_or("threshold-ttft", Thresholds::default().ttft_frac),
    };
    let gate = |old: &BenchReport, new: &BenchReport| {
        let rep = bench::compare(old, new, th);
        print!("{}", rep.render());
        if rep.has_regressions() && !args.flag("advisory") {
            std::process::exit(1);
        }
    };
    if let (Some(oldf), Some(newf)) = (args.get("compare"), args.get("new")) {
        gate(&load_file(oldf)?, &load_file(newf)?);
        return Ok(());
    }

    let mut spec = SuiteSpec::new(args.flag("smoke"));
    spec.target = args.get_or("target", &spec.target);
    spec.dataset = args.get_or("dataset", &spec.dataset);
    spec.requests = args.usize_or("requests", spec.requests);
    spec.max_new = args.usize_or("max-new", spec.max_new);
    spec.seed = args.usize_or("seed", spec.seed as usize) as u64;
    spec.kv_blocks = args.get("kv-blocks").map(|n| {
        n.parse().unwrap_or_else(|_| panic!("--kv-blocks expects a number"))
    });
    let pr = args.get_or("pr", bench::CURRENT_PR);
    let mut mr = ModelRuntime::load(artifacts_root(args))?;
    let report = bench::run_suite(&mut mr, &spec, &pr)?;
    let out = args.get_or("out", &format!("BENCH_{pr}.json"));
    std::fs::write(&out, report.to_file_string())
        .map_err(|e| anyhow!("writing {out}: {e}"))?;
    println!(
        "wrote {out}: {} cells ({} suite, target {}, seed {}){}",
        report.cells.len(),
        report.suite,
        report.target,
        report.seed,
        if report.note.is_empty() { String::new() } else { format!(" — {}", report.note) },
    );
    if let Some(oldf) = args.get("compare") {
        gate(&load_file(oldf)?, &report);
    }
    Ok(())
}

fn run_report(args: &Args) -> Result<()> {
    if args.flag("fig1") {
        println!("{}", report::fig1_report(40_000));
        return Ok(());
    }
    if args.flag("fig5") {
        let mr = ModelRuntime::load(artifacts_root(args))?;
        println!("{}", report::fig5_report(&mr));
        return Ok(());
    }
    if args.flag("memmodel") {
        println!("Table 1 feasibility classification (paper-scale memory model)");
        for (label, n) in [("1K", 1024usize), ("4K", 4096), ("8K", 8192), ("20K", 20480)] {
            let ps = memmodel::classify(&memmodel::TrainSetup::parallelspec(n, 8), 200_000);
            let pd = memmodel::classify(&memmodel::TrainSetup::pard(n, 8), 200_000);
            let pe = memmodel::classify(&memmodel::TrainSetup::peagle(n, 8), 200_000);
            println!(
                "  {label:>4}: ParallelSpec={:<8} PARD={:<8} P-EAGLE={:<8}",
                ps.label(),
                pd.label(),
                pe.label()
            );
        }
        return Ok(());
    }
    Err(anyhow!("report: pass --fig1, --fig5, or --memmodel"))
}
