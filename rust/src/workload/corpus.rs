//! Phrase-bank corpus mirror — reconstructs the Python regimes from the
//! tables exported in artifacts/manifest.json so the Rust engine can sample
//! an unbounded stream of in-distribution prompts (serving benches) beyond
//! the fixed eval prompt sets.

use crate::util::{json::Json, rng::Rng};

pub const BOS_ID: i32 = 1;
pub const EOS_ID: i32 = 2;

#[derive(Clone, Debug)]
pub struct PhraseRegime {
    pub name: String,
    pub phrases: Vec<Vec<i32>>,
    /// `[n_phrases][branch]` successor phrase ids
    pub succ: Vec<Vec<usize>>,
    /// `[n_phrases][branch]` transition probabilities
    pub probs: Vec<Vec<f32>>,
}

impl PhraseRegime {
    pub fn from_json(v: &Json) -> PhraseRegime {
        let arr_i32 = |x: &Json| -> Vec<i32> {
            x.as_arr().unwrap().iter().map(|t| t.as_i64().unwrap() as i32).collect()
        };
        PhraseRegime {
            name: v.str_of("name"),
            phrases: v.req("phrases").as_arr().unwrap().iter().map(arr_i32).collect(),
            succ: v
                .req("succ")
                .as_arr()
                .unwrap()
                .iter()
                .map(|r| r.as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect())
                .collect(),
            probs: v
                .req("probs")
                .as_arr()
                .unwrap()
                .iter()
                .map(|r| r.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as f32).collect())
                .collect(),
        }
    }

    /// Sample `[BOS, tokens...]` of exactly `length` tokens — the same
    /// process as python/compile/data.py PhraseRegime::sample_seq.
    pub fn sample_seq(&self, length: usize, rng: &mut Rng) -> Vec<i32> {
        assert!(length >= 1);
        let mut out = Vec::with_capacity(length);
        out.push(BOS_ID);
        let mut pid = rng.below(self.phrases.len());
        while out.len() < length {
            let ph = &self.phrases[pid];
            let take = ph.len().min(length - out.len());
            out.extend_from_slice(&ph[..take]);
            pid = self.succ[pid][rng.categorical(&self.probs[pid])];
        }
        out
    }

    /// Mean within-phrase determinism — higher values mean a drafter can
    /// predict longer runs (regime-entropy diagnostic used by tests).
    pub fn mean_phrase_len(&self) -> f64 {
        self.phrases.iter().map(|p| p.len() as f64).sum::<f64>() / self.phrases.len() as f64
    }
}

/// Fixed eval prompt set loaded from artifacts/eval/<regime>.json.
pub fn load_eval_prompts(path: &std::path::Path) -> anyhow::Result<Vec<Vec<i32>>> {
    let text = std::fs::read_to_string(path)?;
    let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    Ok(v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("prompts not an array"))?
        .iter()
        .map(|row| row.as_arr().unwrap().iter().map(|t| t.as_i64().unwrap() as i32).collect())
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_regime() -> PhraseRegime {
        PhraseRegime {
            name: "toy".into(),
            phrases: vec![vec![10, 11, 12], vec![20, 21], vec![30, 31, 32, 33]],
            succ: vec![vec![1, 2], vec![0, 2], vec![0, 1]],
            probs: vec![vec![0.9, 0.1], vec![0.5, 0.5], vec![0.2, 0.8]],
        }
    }

    #[test]
    fn exact_length_and_bos() {
        let r = toy_regime();
        let mut rng = Rng::new(1);
        for len in [1usize, 2, 5, 17, 64] {
            let s = r.sample_seq(len, &mut rng);
            assert_eq!(s.len(), len);
            assert_eq!(s[0], BOS_ID);
        }
    }

    #[test]
    fn tokens_come_from_phrases() {
        let r = toy_regime();
        let mut rng = Rng::new(2);
        let s = r.sample_seq(50, &mut rng);
        let valid: std::collections::HashSet<i32> =
            r.phrases.iter().flatten().copied().collect();
        for &t in &s[1..] {
            assert!(valid.contains(&t), "token {t}");
        }
    }

    #[test]
    fn phrases_appear_contiguously() {
        // any maximal run starting at a phrase anchor must match the phrase
        let r = toy_regime();
        let mut rng = Rng::new(3);
        let s = r.sample_seq(60, &mut rng);
        let mut i = 1;
        while i < s.len() {
            let ph = r
                .phrases
                .iter()
                .find(|p| p[0] == s[i])
                .unwrap_or_else(|| panic!("no phrase starts with {}", s[i]));
            let take = ph.len().min(s.len() - i);
            assert_eq!(&s[i..i + take], &ph[..take]);
            i += take;
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let r = toy_regime();
        let a = r.sample_seq(40, &mut Rng::new(9));
        let b = r.sample_seq(40, &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn json_roundtrip() {
        let src = r#"{"name":"x","phrases":[[4,5],[6]],"succ":[[1],[0]],"probs":[[1.0],[1.0]]}"#;
        let r = PhraseRegime::from_json(&Json::parse(src).unwrap());
        assert_eq!(r.phrases.len(), 2);
        assert_eq!(r.succ[0][0], 1);
        let mut rng = Rng::new(4);
        let s = r.sample_seq(10, &mut rng);
        assert_eq!(s.len(), 10);
    }
}
