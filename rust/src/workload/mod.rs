//! Workload generation: synthetic corpora (the Python phrase-bank regimes,
//! reconstructed from the exported manifest tables), the paper's Figure-1
//! sequence-length distribution, and request arrival processes for the
//! serving benches.

pub mod arrivals;
pub mod corpus;
pub mod lengths;

pub use arrivals::{ArrivalProcess, Request, RequestSpec};
pub use corpus::PhraseRegime;
pub use lengths::LengthModel;
