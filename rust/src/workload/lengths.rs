//! Figure 1 — sequence-length (prompt + generation) distribution.
//!
//! The paper reports UltraChat × GPT-OSS-120B (reasoning: medium) lengths of
//! median 3,891 / P90 10,800 / P99 20,000 tokens. We model this as a
//! two-mode lognormal mixture fit to those quantiles (same constants as
//! python/compile/data.py) and expose both the paper-scale sampler (the
//! Fig 1 report) and the testbed-scaled sampler the serving workload uses.

use crate::util::rng::Rng;

/// (weight, mu, sigma) over paper-scale token counts.
pub const MODES: [(f64, f64, f64); 2] = [
    (0.80, 8.10, 0.60), // main reasoning mass (~median 3.3K)
    (0.20, 9.20, 0.40), // long-tail reasoning traces
];

/// Paper-scale -> testbed scale (max_new_tokens 160 vs ~20K tail).
pub const LEN_SCALE: f64 = 1.0 / 32.0;

#[derive(Clone, Debug)]
pub struct LengthModel {
    pub scale: f64,
    pub min_len: usize,
    pub max_len: usize,
}

impl LengthModel {
    pub fn paper() -> LengthModel {
        LengthModel { scale: 1.0, min_len: 16, max_len: 120_000 }
    }

    pub fn testbed(max_len: usize) -> LengthModel {
        LengthModel { scale: LEN_SCALE, min_len: 4, max_len }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let w = rng.f64();
        let mut acc = 0.0;
        let mut pick = MODES[MODES.len() - 1];
        for m in MODES {
            acc += m.0;
            if w <= acc {
                pick = m;
                break;
            }
        }
        let x = rng.lognormal(pick.1, pick.2) * self.scale;
        (x as usize).clamp(self.min_len, self.max_len)
    }

    pub fn quantiles(&self, samples: usize, rng: &mut Rng) -> Quantiles {
        let mut xs: Vec<usize> = (0..samples).map(|_| self.sample(rng)).collect();
        xs.sort_unstable();
        let q = |p: f64| xs[((p * samples as f64) as usize).min(samples - 1)];
        Quantiles { median: q(0.50), p90: q(0.90), p99: q(0.99) }
    }

    /// Histogram over log-spaced bins (the Fig 1 shape).
    pub fn histogram(&self, samples: usize, bins: usize, rng: &mut Rng) -> Vec<(usize, usize)> {
        let xs: Vec<usize> = (0..samples).map(|_| self.sample(rng)).collect();
        let lo = (*xs.iter().min().unwrap() as f64).ln();
        let hi = (*xs.iter().max().unwrap() as f64 + 1.0).ln();
        let mut hist = vec![0usize; bins];
        for &x in &xs {
            let b = (((x as f64).ln() - lo) / (hi - lo) * bins as f64) as usize;
            hist[b.min(bins - 1)] += 1;
        }
        hist.iter()
            .enumerate()
            .map(|(i, &c)| ((lo + (i as f64 + 0.5) / bins as f64 * (hi - lo)).exp() as usize, c))
            .collect()
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Quantiles {
    pub median: usize,
    pub p90: usize,
    pub p99: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_quantiles_match() {
        // the fit must land near the paper's reported quantiles
        let m = LengthModel::paper();
        let q = m.quantiles(60_000, &mut Rng::new(1));
        let close = |got: usize, want: f64, tol: f64| {
            (got as f64 - want).abs() / want < tol
        };
        assert!(close(q.median, 3891.0, 0.20), "median {}", q.median);
        assert!(close(q.p90, 10_800.0, 0.25), "p90 {}", q.p90);
        assert!(close(q.p99, 20_000.0, 0.30), "p99 {}", q.p99);
    }

    #[test]
    fn testbed_respects_bounds() {
        let m = LengthModel::testbed(160);
        let mut rng = Rng::new(2);
        for _ in 0..5000 {
            let x = m.sample(&mut rng);
            assert!((4..=160).contains(&x));
        }
    }

    #[test]
    fn histogram_mass_conserved() {
        let m = LengthModel::paper();
        let h = m.histogram(5000, 24, &mut Rng::new(3));
        assert_eq!(h.iter().map(|(_, c)| c).sum::<usize>(), 5000);
        assert_eq!(h.len(), 24);
    }
}
