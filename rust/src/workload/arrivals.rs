//! Request arrival generation for the serving benches.
//!
//! Table 10 measures OTPS at fixed concurrency C ∈ {2, 4}: a closed-loop
//! driver keeps exactly C requests in flight (each completion immediately
//! admits the next), which is how the paper's vLLM benchmark client behaves.
//! An open-loop Poisson mode exists for latency-under-load experiments.
//!
//! Generated [`Request`]s carry greedy [`SamplingParams`] and no policy
//! (`policy: None` → the engine default); callers that want per-request
//! policies attach them with [`Request::with_policy`] — see
//! `report::sweep_drafters` and the `serve --drafters` round-robin.

pub use crate::coordinator::request::{Request, RequestSpec, SamplingParams, SpecPolicy};

use super::corpus::PhraseRegime;
use crate::util::rng::Rng;

pub struct ArrivalProcess {
    pub regime: PhraseRegime,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    rng: Rng,
    next_id: u64,
    clock_s: f64,
}

impl ArrivalProcess {
    pub fn closed_loop(
        regime: PhraseRegime,
        prompt_len: usize,
        max_new_tokens: usize,
        seed: u64,
    ) -> ArrivalProcess {
        ArrivalProcess {
            regime,
            prompt_len,
            max_new_tokens,
            rng: Rng::new(seed),
            next_id: 0,
            clock_s: 0.0,
        }
    }

    /// Next request, immediately available (closed loop).
    pub fn next(&mut self) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        Request::new(
            id,
            self.regime.sample_seq(self.prompt_len, &mut self.rng),
            self.max_new_tokens,
        )
        .with_arrival(self.clock_s)
    }

    /// Next request under Poisson arrivals at `rate` req/s (open loop).
    pub fn next_poisson(&mut self, rate: f64) -> Request {
        self.clock_s += self.rng.exponential(rate);
        self.next()
    }

    /// Pre-draw an open-loop schedule: `count` requests with Poisson arrival
    /// stamps at `rate` req/s, sorted by construction (the exponential gaps
    /// accumulate on the process clock). This is the input shape
    /// `coordinator::run_open_loop` wants — drawing the whole schedule up
    /// front keeps it a pure function of the seed, independent of how the
    /// engine interleaves admissions.
    pub fn take_poisson(&mut self, count: usize, rate: f64) -> Vec<Request> {
        assert!(rate > 0.0, "open-loop arrivals need a positive rate");
        (0..count).map(|_| self.next_poisson(rate)).collect()
    }

    /// Fixed prompt pool variant used by acceptance evals (prompts come from
    /// the exported OOD eval sets instead of fresh sampling).
    pub fn from_pool(pool: &[Vec<i32>], count: usize, max_new: usize) -> Vec<Request> {
        (0..count)
            .map(|i| Request::new(i as u64, pool[i % pool.len()].clone(), max_new))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn regime() -> PhraseRegime {
        PhraseRegime {
            name: "toy".into(),
            phrases: vec![vec![10, 11], vec![20]],
            succ: vec![vec![1], vec![0]],
            probs: vec![vec![1.0], vec![1.0]],
        }
    }

    #[test]
    fn ids_monotone_prompts_sized() {
        let mut ap = ArrivalProcess::closed_loop(regime(), 12, 32, 7);
        for i in 0..10 {
            let r = ap.next();
            assert_eq!(r.id, i);
            assert_eq!(r.prompt.len(), 12);
            assert_eq!(r.max_new_tokens, 32);
            assert!(r.policy.is_none(), "generated requests use the engine default");
            assert_eq!(r.sampling, SamplingParams::greedy());
        }
    }

    #[test]
    fn poisson_clock_advances() {
        let mut ap = ArrivalProcess::closed_loop(regime(), 8, 16, 3);
        let a = ap.next_poisson(10.0);
        let b = ap.next_poisson(10.0);
        assert!(b.arrival_s > a.arrival_s);
    }

    #[test]
    fn take_poisson_is_sorted_and_seed_deterministic() {
        let mut a = ArrivalProcess::closed_loop(regime(), 8, 16, 3);
        let mut b = ArrivalProcess::closed_loop(regime(), 8, 16, 3);
        let ra = a.take_poisson(6, 4.0);
        let rb = b.take_poisson(6, 4.0);
        assert_eq!(ra.len(), 6);
        assert!(ra.windows(2).all(|w| w[0].arrival_s < w[1].arrival_s));
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt, y.prompt);
        }
    }

    #[test]
    fn pool_cycles() {
        let pool = vec![vec![1, 2, 3], vec![1, 4, 5]];
        let reqs = ArrivalProcess::from_pool(&pool, 5, 64);
        assert_eq!(reqs.len(), 5);
        assert_eq!(reqs[0].prompt, reqs[2].prompt);
        assert_eq!(reqs[1].prompt, reqs[3].prompt);
    }
}
