//! Algorithm 1 — dependency-preserving sequence partitioning (paper §3.2).
//!
//! Splits one example's COD-sampled rows into S segments for within-sequence
//! gradient accumulation while preserving every attention dependency:
//! Phase 1 assigns depths 0-1 by position, Phase 2 propagates each row's
//! chain-parent assignment ((p,d) inherits from (p-1,d-1)), Phase 3 adds the
//! cumulative depth-0 rows to each segment as extra keys. Mirror of
//! `python/compile/partition.py` (which carries the gradient-equivalence
//! property test against actual JAX gradients).

use std::collections::HashMap;

/// Result of Algorithm 1 over one example.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Per segment: interleaved row ids that OWN their loss here (sorted).
    pub segment_rows: Vec<Vec<usize>>,
    /// Per segment: depth-0 row ids included as attention keys only
    /// (cumulative context; disjoint from `segment_rows`).
    pub segment_extra_keys: Vec<Vec<usize>>,
    /// Position-space boundaries, length S+1.
    pub boundaries: Vec<usize>,
}

impl Partition {
    pub fn n_segments(&self) -> usize {
        self.segment_rows.len()
    }

    /// Peak "attention cells" across segments: rows × keys per segment —
    /// the quantity the paper's O(L²/S²) memory claim is about.
    pub fn peak_attention_cells(&self) -> usize {
        self.segment_rows
            .iter()
            .zip(&self.segment_extra_keys)
            .map(|(own, extra)| {
                let keys = own.len() + extra.len();
                own.len() * keys
            })
            .max()
            .unwrap_or(0)
    }
}

/// Algorithm 1 (paper pseudocode). `anchors` are the nested COD anchor sets;
/// `n` is the sequence length (row space); `k` the depth count; `s` segments.
pub fn partition_rows(anchors: &[Vec<usize>], n: usize, k: usize, s: usize) -> Partition {
    assert!(s >= 1 && n >= 2);
    // lines 1-2: segment boundaries
    let boundaries: Vec<usize> = (0..=s).map(|i| i * n / s).collect();
    let seg_of = |p: usize| -> usize {
        // max { s : B_s <= p }
        match boundaries.binary_search(&p) {
            Ok(i) => i.min(s - 1),
            Err(i) => (i - 1).min(s - 1),
        }
    };

    let mut assign: HashMap<(usize, usize), usize> = HashMap::new();

    // Phase 1: depths 0 and 1 by position
    for d in 0..2.min(k) {
        for &a in &anchors[d] {
            let p = a + d;
            if p <= n - 2 {
                assign.insert((p, d), seg_of(p));
            }
        }
    }

    // Phase 2: depths >= 2 inherit from the chain parent (p-1, d-1)
    for d in 2..k {
        for &a in &anchors[d] {
            let p = a + d;
            if p > n - 2 {
                continue;
            }
            let seg = assign
                .get(&(p - 1, d - 1))
                .copied()
                .unwrap_or_else(|| seg_of(p)); // guarded: nested COD ⇒ parent exists
            assign.insert((p, d), seg);
        }
    }

    let mut segment_rows: Vec<Vec<usize>> = vec![Vec::new(); s];
    for (&(p, d), &seg) in &assign {
        segment_rows[seg].push(p * k + d);
    }
    for rows in &mut segment_rows {
        rows.sort_unstable();
    }

    // Phase 3: cumulative depth-0 keys up to each segment's upper boundary
    let mut d0: Vec<usize> = anchors[0]
        .iter()
        .filter(|&&p| p <= n - 2)
        .map(|&p| p * k)
        .collect();
    d0.sort_unstable();
    let mut segment_extra_keys = Vec::with_capacity(s);
    for seg in 0..s {
        let own: std::collections::HashSet<usize> =
            segment_rows[seg].iter().copied().collect();
        let upto = boundaries[seg + 1];
        let keys: Vec<usize> = d0
            .iter()
            .copied()
            .filter(|r| r / k < upto && !own.contains(r))
            .collect();
        segment_extra_keys.push(keys);
    }

    Partition { segment_rows, segment_extra_keys, boundaries }
}

/// Validate the paper's invariants; returns violations (empty = valid).
pub fn validate(part: &Partition, anchors: &[Vec<usize>], n: usize, k: usize) -> Vec<String> {
    use crate::masking::rows_from_anchors;
    let mut errs = Vec::new();
    let all_rows: std::collections::HashSet<usize> =
        rows_from_anchors(anchors, n, k).into_iter().collect();

    // each row owned exactly once, ownership covers all rows
    let mut owner: HashMap<usize, usize> = HashMap::new();
    for (s, rows) in part.segment_rows.iter().enumerate() {
        for &r in rows {
            if let Some(prev) = owner.insert(r, s) {
                errs.push(format!("row {r} owned by segments {prev} and {s}"));
            }
        }
    }
    if owner.len() != all_rows.len() || !all_rows.iter().all(|r| owner.contains_key(r)) {
        errs.push(format!(
            "ownership mismatch: {} owned vs {} rows",
            owner.len(),
            all_rows.len()
        ));
    }

    // every owned row's attention set present in its segment
    for (s, rows) in part.segment_rows.iter().enumerate() {
        let keys: std::collections::HashSet<usize> = rows
            .iter()
            .chain(part.segment_extra_keys[s].iter())
            .copied()
            .collect();
        for &r in rows {
            let (p, d) = (r / k, r % k);
            let anchor = p - d;
            for e in 1..=d {
                let rid = (anchor + e) * k + e;
                if all_rows.contains(&rid) && !keys.contains(&rid) {
                    errs.push(format!("seg {s}: row ({p},{d}) missing chain depth {e}"));
                }
            }
            for q in 0..=anchor {
                let rid = q * k;
                if all_rows.contains(&rid) && !keys.contains(&rid) {
                    errs.push(format!("seg {s}: row ({p},{d}) missing ctx ({q},0)"));
                    break;
                }
            }
        }
    }
    errs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::masking::cod_sample_nested;
    use crate::util::prop::{check, Case};

    #[test]
    fn paper_fig4_example() {
        // n=16, K=4, r=0.7, the paper's exact sampled sets, 2 segments.
        let anchors = vec![
            (0..16).collect::<Vec<_>>(),
            vec![0, 2, 3, 5, 6, 8, 9, 11, 13, 14],
            vec![0, 3, 5, 6, 9, 11, 13],
            vec![0, 3, 6, 9, 11],
        ];
        let part = partition_rows(&anchors, 16, 4, 2);
        let errs = validate(&part, &anchors, 16, 4);
        assert!(errs.is_empty(), "{errs:?}");
        // the paper's highlighted violation case: position 8 at depth 2
        // (anchor 6) must share a segment with its chain parent (7, 1)
        let k = 4;
        let row_82 = 8 * k + 2;
        let row_71 = 7 * k + 1;
        let seg_of = |row| {
            part.segment_rows.iter().position(|rs| rs.contains(&row)).unwrap()
        };
        assert_eq!(seg_of(row_82), seg_of(row_71));
    }

    #[test]
    fn invariants_hold_randomly() {
        check("alg1-invariants", 80, |rng| {
            let n = 4 + rng.below(160);
            let k = 1 + rng.below(8);
            let s = 1 + rng.below(6);
            let r = 0.5 + rng.f64() * 0.45;
            let anchors = cod_sample_nested(n, k, r, rng);
            let part = partition_rows(&anchors, n, k, s);
            let errs = validate(&part, &anchors, n, k);
            if errs.is_empty() {
                Case::Pass
            } else {
                Case::Fail { desc: format!("n={n} k={k} s={s}: {}", errs[0]), size: n }
            }
        });
    }

    #[test]
    fn memory_shrinks_with_segments() {
        // paper §3.2: peak attention memory drops ~O(1/S²) in the owned-row
        // quadratic term (cumulative keys add a linear term).
        let mut rng = crate::util::rng::Rng::new(1);
        let anchors = cod_sample_nested(512, 8, 0.8, &mut rng);
        let p1 = partition_rows(&anchors, 512, 8, 1).peak_attention_cells();
        let p4 = partition_rows(&anchors, 512, 8, 4).peak_attention_cells();
        assert!(
            (p4 as f64) < (p1 as f64) * 0.45,
            "S=4 peak {p4} not ≪ S=1 peak {p1}"
        );
    }

    #[test]
    fn single_segment_owns_everything() {
        let mut rng = crate::util::rng::Rng::new(2);
        let anchors = cod_sample_nested(64, 4, 0.8, &mut rng);
        let part = partition_rows(&anchors, 64, 4, 1);
        assert_eq!(part.n_segments(), 1);
        assert!(part.segment_extra_keys[0].is_empty());
        let rows = crate::masking::rows_from_anchors(&anchors, 64, 4);
        assert_eq!(part.segment_rows[0], rows);
    }

    #[test]
    fn boundaries_cover_sequence() {
        let mut rng = crate::util::rng::Rng::new(3);
        for s in 1..6 {
            let anchors = cod_sample_nested(50, 4, 0.8, &mut rng);
            let part = partition_rows(&anchors, 50, 4, s);
            assert_eq!(part.boundaries.first(), Some(&0));
            assert_eq!(part.boundaries.last(), Some(&50));
            assert_eq!(part.boundaries.len(), s + 1);
        }
    }
}
