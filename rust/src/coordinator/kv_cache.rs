//! KV-cache slot accounting.
//!
//! The dense engine-wide cache buffer (shape `[L, 2, B, S_MAX, H, Dh]`)
//! lives on the PJRT device and is threaded through verify calls; this
//! module owns the *accounting*: per-slot valid lengths with independent
//! claim/release lifecycles (slots are claimed at different prefill lengths
//! as the stepped engine admits mid-flight), capacity admission (a slot must
//! always fit prompt + chunk writes), a speculative scratch region with an
//! explicit commit/rollback lifecycle (tree verification keeps only the
//! accepted root path of each chunk — see
//! [`EngineCore::step`](super::engine::EngineCore::step)), and a vLLM-style
//! paged utilization view (BLOCK_SIZE-token blocks) used by metrics and
//! admission policy.

pub const BLOCK_SIZE: usize = 16;

#[derive(Clone, Debug)]
pub struct SlotManager {
    pub s_max: usize,
    pub chunk: usize, // N+1: widest write a verify step performs
    lens: Vec<usize>,
    active: Vec<bool>,
    /// slots with an open speculative scratch region (positions
    /// len .. len+chunk freshly written by a verify call, not yet committed)
    specing: Vec<bool>,
}

impl SlotManager {
    pub fn new(batch: usize, s_max: usize, chunk: usize) -> SlotManager {
        SlotManager {
            s_max,
            chunk,
            lens: vec![0; batch],
            active: vec![false; batch],
            specing: vec![false; batch],
        }
    }

    pub fn batch(&self) -> usize {
        self.lens.len()
    }

    /// Claim slot `i` for a request with `prompt_len` tokens. Fails if the
    /// prompt plus one full speculation chunk cannot fit.
    pub fn claim(&mut self, i: usize, prompt_len: usize) -> Result<(), String> {
        if self.active[i] {
            return Err(format!("slot {i} already active"));
        }
        if prompt_len + self.chunk > self.s_max {
            return Err(format!("prompt {prompt_len} + chunk {} > s_max {}", self.chunk, self.s_max));
        }
        self.active[i] = true;
        self.lens[i] = prompt_len;
        Ok(())
    }

    /// Record `accepted + 1` new cached positions after a verify step.
    /// Returns false when the slot can no longer fit another chunk (the
    /// engine must finish the request — FinishReason::CacheFull).
    /// Shorthand for [`begin_spec`](Self::begin_spec) +
    /// [`commit_spec`](Self::commit_spec) (the chain path, where the chunk
    /// prefix is the accepted path by construction).
    pub fn advance(&mut self, i: usize, emitted: usize) -> bool {
        self.begin_spec(i);
        self.commit_spec(i, emitted)
    }

    /// Open the speculative scratch region of slot `i`: a verify call is
    /// about to write `chunk` fresh positions at `len .. len + chunk`. The
    /// region is invisible to [`len`](Self::len)/[`cache_len_i32`](Self::cache_len_i32)
    /// until committed — attention masks everything at or beyond `cache_len`,
    /// so an uncommitted (or rolled-back) region is inert garbage.
    pub fn begin_spec(&mut self, i: usize) {
        debug_assert!(self.active[i]);
        debug_assert!(!self.specing[i], "slot {i}: speculation already open");
        debug_assert!(self.lens[i] + self.chunk <= self.s_max);
        self.specing[i] = true;
    }

    /// Commit the accepted prefix of slot `i`'s scratch region: `kept`
    /// positions (root + accepted draft nodes, already compacted to be
    /// contiguous) become part of the valid cache. Returns false when the
    /// slot can no longer fit another chunk (the engine must finish the
    /// request — FinishReason::CacheFull).
    pub fn commit_spec(&mut self, i: usize, kept: usize) -> bool {
        debug_assert!(self.specing[i], "slot {i}: commit without begin_spec");
        debug_assert!(kept <= self.chunk);
        self.specing[i] = false;
        self.lens[i] += kept;
        self.lens[i] + self.chunk <= self.s_max
    }

    /// Abandon slot `i`'s scratch region entirely (commit nothing). The
    /// written positions stay masked and are overwritten by the next chunk.
    pub fn rollback_spec(&mut self, i: usize) {
        debug_assert!(self.specing[i], "slot {i}: rollback without begin_spec");
        self.specing[i] = false;
    }

    /// Whether slot `i` has an open (uncommitted) scratch region.
    pub fn is_specing(&self, i: usize) -> bool {
        self.specing[i]
    }

    pub fn release(&mut self, i: usize) {
        self.active[i] = false;
        self.specing[i] = false;
        self.lens[i] = 0;
    }

    pub fn len(&self, i: usize) -> usize {
        self.lens[i]
    }

    pub fn is_active(&self, i: usize) -> bool {
        self.active[i]
    }

    /// Paged-accounting view: blocks in use across all slots.
    pub fn blocks_used(&self) -> usize {
        self.lens
            .iter()
            .zip(&self.active)
            .filter(|(_, &a)| a)
            .map(|(&l, _)| l.div_ceil(BLOCK_SIZE))
            .sum()
    }

    pub fn blocks_total(&self) -> usize {
        self.batch() * self.s_max.div_ceil(BLOCK_SIZE)
    }

    pub fn utilization(&self) -> f64 {
        self.blocks_used() as f64 / self.blocks_total() as f64
    }

    /// cache_len vector for the verify executable (`[B]` i32). Inactive slots
    /// report 1 (a harmless minimal prefix) so padded rows stay in-bounds.
    pub fn cache_len_i32(&self) -> Vec<i32> {
        self.lens
            .iter()
            .zip(&self.active)
            .map(|(&l, &a)| if a { l as i32 } else { 1 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Case};

    #[test]
    fn claim_advance_release() {
        let mut m = SlotManager::new(2, 64, 6);
        m.claim(0, 20).unwrap();
        assert!(m.is_active(0));
        assert_eq!(m.len(0), 20);
        assert!(m.advance(0, 4));
        assert_eq!(m.len(0), 24);
        m.release(0);
        assert!(!m.is_active(0));
        assert_eq!(m.cache_len_i32(), vec![1, 1]);
    }

    #[test]
    fn rejects_oversized_prompt() {
        let mut m = SlotManager::new(1, 32, 6);
        assert!(m.claim(0, 27).is_err());
        assert!(m.claim(0, 26).is_ok());
    }

    #[test]
    fn rejects_double_claim() {
        let mut m = SlotManager::new(1, 64, 6);
        m.claim(0, 8).unwrap();
        assert!(m.claim(0, 8).is_err());
    }

    #[test]
    fn advance_signals_capacity() {
        let mut m = SlotManager::new(1, 32, 6);
        m.claim(0, 20).unwrap();
        assert!(m.advance(0, 6)); // 26 + 6 = 32 <= 32 ✓
        assert!(!m.advance(0, 1)); // 27 + 6 > 32
    }

    #[test]
    fn paged_accounting() {
        let mut m = SlotManager::new(2, 64, 6);
        m.claim(0, 17).unwrap(); // 2 blocks
        m.claim(1, 16).unwrap(); // 1 block
        assert_eq!(m.blocks_used(), 3);
        assert_eq!(m.blocks_total(), 8);
        assert!((m.utilization() - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn spec_commit_advances_by_kept_prefix() {
        let mut m = SlotManager::new(2, 64, 6);
        m.claim(0, 20).unwrap();
        m.begin_spec(0);
        assert!(m.is_specing(0));
        // scratch region is invisible until committed
        assert_eq!(m.len(0), 20);
        assert_eq!(m.cache_len_i32(), vec![20, 1]);
        assert!(m.commit_spec(0, 4));
        assert!(!m.is_specing(0));
        assert_eq!(m.len(0), 24);
    }

    #[test]
    fn spec_rollback_commits_nothing() {
        let mut m = SlotManager::new(1, 64, 6);
        m.claim(0, 20).unwrap();
        m.begin_spec(0);
        m.rollback_spec(0);
        assert!(!m.is_specing(0));
        assert_eq!(m.len(0), 20);
        // the slot is immediately reusable for the next chunk
        m.begin_spec(0);
        assert!(m.commit_spec(0, 6));
        assert_eq!(m.len(0), 26);
    }

    #[test]
    fn spec_commit_signals_capacity_like_advance() {
        let mut m = SlotManager::new(1, 32, 6);
        m.claim(0, 20).unwrap();
        m.begin_spec(0);
        assert!(m.commit_spec(0, 6)); // 26 + 6 = 32 <= 32 ✓
        m.begin_spec(0);
        assert!(!m.commit_spec(0, 1)); // 27 + 6 > 32
    }

    #[test]
    fn release_clears_open_speculation() {
        let mut m = SlotManager::new(1, 64, 6);
        m.claim(0, 8).unwrap();
        m.begin_spec(0);
        m.release(0);
        assert!(!m.is_specing(0));
        // a fresh claim starts with a clean scratch lifecycle
        m.claim(0, 8).unwrap();
        m.begin_spec(0);
        assert!(m.commit_spec(0, 2));
    }

    #[test]
    fn capacity_invariant_property() {
        // a slot that claims + advances while advance() returns true can
        // always fit one more chunk write
        check("kv-capacity", 100, |rng| {
            let s_max = 16 + rng.below(240);
            let chunk = 2 + rng.below(8);
            let mut m = SlotManager::new(1, s_max, chunk);
            let prompt = 1 + rng.below(s_max);
            if m.claim(0, prompt).is_err() {
                return Case::Pass; // correctly rejected
            }
            loop {
                if m.len(0) + chunk > s_max {
                    return Case::Fail {
                        desc: format!("len {} + chunk {chunk} > {s_max}", m.len(0)),
                        size: s_max,
                    };
                }
                let emitted = 1 + rng.below(chunk);
                if !m.advance(0, emitted) {
                    return Case::Pass;
                }
            }
        });
    }
}
