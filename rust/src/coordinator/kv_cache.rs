//! KV-cache slot accounting and (in paged mode) a real block allocator.
//!
//! Dense mode: the engine-wide cache buffer (shape `[L, 2, B, S_MAX, H, Dh]`)
//! lives on the PJRT device and is threaded through verify calls; this module
//! owns the *accounting*: per-slot valid lengths with independent
//! claim/release lifecycles (slots are claimed at different prefill lengths
//! as the stepped engine admits mid-flight), capacity admission (a slot must
//! always fit prompt + chunk writes), and a speculative scratch region with
//! an explicit commit/rollback lifecycle (tree verification keeps only the
//! accepted root path of each chunk — see
//! [`EngineCore::step`](super::engine::EngineCore::step)).
//!
//! Paged mode ([`SlotManager::new_paged`]): the physical cache is a block
//! pool `[L, 2, NB, BLOCK, H, Dh]` and this module becomes a vLLM-style
//! allocator — a free list of `block_size`-token blocks plus a per-slot
//! block table mapping logical position `q` to pool block `table[q / bs]`
//! at offset `q % bs`. Block id 0 is the reserved *null block*: it is never
//! allocated, and [`SlotManager::block_table_i32`] pads inactive rows and
//! unused table entries with it so the lowered gather/scatter stays inert
//! there. Invariant kept at all times: an active slot's table covers
//! `len + chunk` positions, so the next verify's speculative scratch is
//! *pre-reserved* — `begin_spec` never allocates, `commit_spec` extends the
//! reservation for the following chunk (returning `false`, i.e. CacheFull,
//! when the free list cannot supply it), and `rollback_spec` keeps the
//! scratch blocks for reuse. Frees happen only at [`SlotManager::release`]
//! and are idempotent. Admission is gated on free-*block* headroom
//! ([`SlotManager::can_admit`]), not just free slots.
//!
//! Prefix cache ([`SlotManager::with_prefix_cache`], paged mode only): the
//! allocator additionally keeps a per-block mapping *refcount* and a
//! content-addressed index of fully-committed prompt blocks (a chained hash
//! over each block's token ids, verified by token equality so hash collisions
//! can never alias). A new admission walks its prompt through the index
//! ([`SlotManager::claim_with_prefix`]): full-block hits are mapped *shared*
//! (refcount bumped, no allocation, no prefill needed for those positions),
//! and a sub-block hit under the same parent hash is claimed copy-on-write —
//! the claim hands the engine a `(src, dst)` pool-block copy to apply before
//! any write, so a shared block is never mutated while another table maps it.
//! `release` decrefs instead of freeing; a registered block whose refcount
//! drops to 0 stays *cached-idle* (off the free list, still indexed) until
//! an allocation finds the free list dry and evicts cached-idle blocks LRU.
//! Every block is therefore in exactly one of three states — free, mapped
//! (refcount ≥ 1), or cached-idle — and the three partition the id range.

/// Dense-mode utilization granularity, and the default paged block size
/// (must match the Python lowering's `configs.KV_BLOCK_SIZE`).
pub const BLOCK_SIZE: usize = 16;

use std::collections::HashMap;

/// Root parent for the chained block hash (the FNV-1a offset basis; any
/// fixed constant works — collisions are guarded by token equality, the
/// hash is only an index).
const CHAIN_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Chained content hash: `h_k = chain_hash(h_{k-1}, block_k_tokens)`, so a
/// block's identity pins the entire token prefix up to and including it.
/// FNV-1a over the tokens' little-endian bytes, seeded by the parent hash.
fn chain_hash(parent: u64, tokens: &[i32]) -> u64 {
    let mut h = parent ^ 0x9e37_79b9_7f4a_7c15;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Index entry for one registered (fully-committed, content-addressed) block.
#[derive(Clone, Debug)]
struct BlockMeta {
    hash: u64,
    parent: u64,
    /// the exact `block_size` token ids the block's KV was computed from —
    /// the collision guard and the sub-block-match comparand
    tokens: Vec<i32>,
    last_used: u64,
}

#[derive(Clone, Debug, Default)]
struct PrefixCache {
    /// chained hash -> registered block (unique: first writer wins)
    by_hash: HashMap<u64, usize>,
    /// parent hash -> registered blocks directly extending it (the
    /// sub-block partial-match candidates)
    by_parent: HashMap<u64, Vec<usize>>,
    /// per-block registration record; `None` = not cached
    meta: Vec<Option<BlockMeta>>,
    /// logical LRU clock (bumped on every touch/register)
    tick: u64,
    evictions: usize,
}

impl PrefixCache {
    fn sized(capacity: usize) -> PrefixCache {
        PrefixCache { meta: vec![None; capacity + 1], ..PrefixCache::default() }
    }

    fn touch(&mut self, b: usize) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(m) = self.meta[b].as_mut() {
            m.last_used = tick;
        }
    }

    fn register(&mut self, b: usize, parent: u64, hash: u64, tokens: Vec<i32>) {
        debug_assert!(self.meta[b].is_none(), "block {b} registered twice");
        debug_assert!(!self.by_hash.contains_key(&hash), "hash {hash:#x} already indexed");
        self.tick += 1;
        self.meta[b] = Some(BlockMeta { hash, parent, tokens, last_used: self.tick });
        self.by_hash.insert(hash, b);
        self.by_parent.entry(parent).or_default().push(b);
    }

    fn unregister(&mut self, b: usize) {
        let Some(m) = self.meta[b].take() else { return };
        self.by_hash.remove(&m.hash);
        if let Some(v) = self.by_parent.get_mut(&m.parent) {
            v.retain(|&x| x != b);
            if v.is_empty() {
                self.by_parent.remove(&m.parent);
            }
        }
    }
}

/// Result of walking a prompt through the prefix index.
#[derive(Clone, Debug, Default)]
struct PrefixMatch {
    /// registered blocks covering the longest full-block prompt prefix
    full: Vec<usize>,
    /// best sub-block extension under the last matched hash:
    /// `(source block, matched token count ≥ 1)`
    partial: Option<(usize, usize)>,
}

#[derive(Clone, Debug)]
struct PagedState {
    block_size: usize,
    /// allocatable blocks (ids `1..=capacity`; 0 is the null block)
    capacity: usize,
    /// LIFO free list; initialized descending so pops hand out ascending ids
    free: Vec<usize>,
    tables: Vec<Vec<usize>>,
    /// per-block mapping refcount (`refcount[b]` == number of slot tables
    /// currently containing `b`); index 0 is the null block, always 0
    refcount: Vec<u32>,
    /// the content-addressed prefix index; `None` = prefix caching off
    /// (every mapped block then has refcount exactly 1)
    prefix: Option<PrefixCache>,
}

impl PagedState {
    /// Whether `b` is registered in the prefix index.
    fn is_cached(&self, b: usize) -> bool {
        self.prefix.as_ref().is_some_and(|c| c.meta[b].is_some())
    }

    /// Registered blocks no table maps (refcount 0) — evictable on demand.
    fn idle_cached(&self) -> usize {
        let refcount = &self.refcount;
        match &self.prefix {
            Some(c) => c
                .meta
                .iter()
                .enumerate()
                .filter(|(b, m)| m.is_some() && refcount[*b] == 0)
                .count(),
            None => 0,
        }
    }

    /// Blocks an allocation can obtain right now: free + evictable idle.
    fn available(&self) -> usize {
        self.free.len() + self.idle_cached()
    }

    fn incref(&mut self, b: usize) {
        self.refcount[b] += 1;
    }

    /// Drop one mapping of `b`. At refcount 0 an *uncached* block returns to
    /// the free list; a cached block stays idle (indexed, evictable) so a
    /// later admission can still hit it.
    fn decref(&mut self, b: usize) {
        debug_assert!(self.refcount[b] > 0, "decref of unmapped block {b}");
        self.refcount[b] -= 1;
        if self.refcount[b] == 0 && !self.is_cached(b) {
            self.free.push(b);
        }
    }

    /// Hand out one block with refcount 1: from the free list, else by
    /// evicting the least-recently-used cached-idle block. `None` only when
    /// every block is mapped.
    fn alloc(&mut self) -> Option<usize> {
        let b = match self.free.pop() {
            Some(b) => b,
            None => self.evict_lru()?,
        };
        debug_assert_eq!(self.refcount[b], 0, "allocated block {b} still mapped");
        self.refcount[b] = 1;
        Some(b)
    }

    /// Unregister and return the LRU refcount-0 cached block.
    fn evict_lru(&mut self) -> Option<usize> {
        let refcount = &self.refcount;
        let cache = self.prefix.as_mut()?;
        let victim = cache
            .meta
            .iter()
            .enumerate()
            .filter(|(b, m)| m.is_some() && refcount[*b] == 0)
            .min_by_key(|(_, m)| m.as_ref().unwrap().last_used)
            .map(|(b, _)| b)?;
        cache.unregister(victim);
        cache.evictions += 1;
        Some(victim)
    }

    /// Longest cached cover of `prompt`, structurally capped at
    /// `prompt.len() - 1` positions (the full-block walk requires
    /// `(k+1)*bs < plen`) so a hit always leaves at least one token to
    /// prefill — the sampler needs a fresh last-logit row and the drafter
    /// fresh features. The sub-block arm matches a *strict token prefix* of
    /// a registered sibling under the same parent hash, which is sound
    /// because KV at position `p` depends only on tokens `≤ p`.
    fn match_prefix(&self, prompt: &[i32]) -> PrefixMatch {
        let mut out = PrefixMatch::default();
        let Some(cache) = &self.prefix else { return out };
        let bs = self.block_size;
        let plen = prompt.len();
        let mut h = CHAIN_SEED;
        let mut k = 0usize;
        while (k + 1) * bs < plen {
            let toks = &prompt[k * bs..(k + 1) * bs];
            let nh = chain_hash(h, toks);
            match cache.by_hash.get(&nh) {
                Some(&b) if cache.meta[b].as_ref().is_some_and(|m| m.tokens == toks) => {
                    out.full.push(b);
                    h = nh;
                    k += 1;
                }
                _ => break,
            }
        }
        let base = k * bs;
        let want = &prompt[base..plen.saturating_sub(1).min(base + bs)];
        if !want.is_empty() {
            if let Some(cands) = cache.by_parent.get(&h) {
                let mut best = 0usize;
                for &b in cands {
                    if let Some(m) = &cache.meta[b] {
                        let common =
                            m.tokens.iter().zip(want).take_while(|(a, c)| a == c).count();
                        if common > best {
                            best = common;
                            out.partial = Some((b, common));
                        }
                    }
                }
            }
        }
        out
    }
}

/// What [`SlotManager::claim_with_prefix`] handed the slot from the cache.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PrefixClaim {
    /// prompt positions already materialized in the slot's mapped blocks
    /// (always `≤ prompt_len - 1`: at least one token is freshly prefilled)
    pub cached_len: usize,
    /// `(src, dst)` pool-block copies the engine must apply to the physical
    /// pool BEFORE any write into the slot: sub-block partial matches are
    /// claimed copy-on-write into a private block, never mutated in place
    pub copies: Vec<(usize, usize)>,
}

#[derive(Clone, Debug)]
pub struct SlotManager {
    pub s_max: usize,
    /// DEFAULT positions a verify step can COMMIT (accepted path + bonus
    /// root): static modes N+1; dynamic tree mode `node_budget + 1` — the
    /// charge unit for paged block coverage and admission headroom. With
    /// per-request speculation policies each slot may carry its OWN commit
    /// chunk ([`claim_with_chunk`](Self::claim_with_chunk)); this field is
    /// the default used by [`claim`](Self::claim) and by admission checks
    /// that predate knowing the request's policy.
    pub chunk: usize,
    /// Per-slot commit chunk (the slot's policy commit width); equals
    /// `chunk` unless the slot was claimed with its own.
    chunks: Vec<usize>,
    /// Positions a verify step physically WRITES (the lowered scatter
    /// width). At least the widest `chunk` any serveable policy commits:
    /// dynamic tree envelopes scatter `envelope + 1` slots but commit only
    /// `budget + 1`, and in a multi-policy engine EVERY policy bucket's
    /// verify scatters (masked garbage) into every live row, so the `s_max`
    /// fit must honor the engine-wide maximum scatter width (a dense scatter
    /// past `s_max` would clamp and corrupt committed positions) while
    /// blocks are still charged by each slot's own `chunk`.
    write_width: usize,
    lens: Vec<usize>,
    active: Vec<bool>,
    /// slots with an open speculative scratch region (positions
    /// len .. len+chunk freshly written by a verify call, not yet committed)
    specing: Vec<bool>,
    paged: Option<PagedState>,
}

impl SlotManager {
    pub fn new(batch: usize, s_max: usize, chunk: usize) -> SlotManager {
        SlotManager {
            s_max,
            chunk,
            chunks: vec![chunk; batch],
            write_width: chunk,
            lens: vec![0; batch],
            active: vec![false; batch],
            specing: vec![false; batch],
            paged: None,
        }
    }

    /// Widen the physical scatter width past the commit/charge width
    /// (dynamic tree mode: `chunk = budget + 1`, `write_width = envelope
    /// nodes + 1`). The `s_max` fit checks switch to the wider value; block
    /// charging stays on `chunk`.
    pub fn with_write_width(mut self, write_width: usize) -> SlotManager {
        assert!(
            write_width >= self.chunk,
            "write width {write_width} below commit chunk {}",
            self.chunk
        );
        self.write_width = write_width;
        self
    }

    /// Positions a verify step physically writes (>= `chunk`).
    pub fn write_width(&self) -> usize {
        self.write_width
    }

    /// Paged allocator over `capacity` blocks of `block_size` tokens.
    /// `s_max` stays the per-slot logical ceiling (the lowered table width is
    /// `s_max / block_size`); a capacity below `batch * s_max / block_size`
    /// is a real memory budget — admission and growth then compete for
    /// blocks instead of each slot owning a dense `s_max` stripe.
    pub fn new_paged(
        batch: usize,
        s_max: usize,
        chunk: usize,
        block_size: usize,
        capacity: usize,
    ) -> SlotManager {
        assert!(block_size >= 1, "block_size must be >= 1");
        assert!(
            s_max % block_size == 0,
            "s_max {s_max} not divisible by block_size {block_size}"
        );
        SlotManager {
            s_max,
            chunk,
            chunks: vec![chunk; batch],
            write_width: chunk,
            lens: vec![0; batch],
            active: vec![false; batch],
            specing: vec![false; batch],
            paged: Some(PagedState {
                block_size,
                capacity,
                free: (1..=capacity).rev().collect(),
                tables: vec![Vec::new(); batch],
                refcount: vec![0; capacity + 1],
                prefix: None,
            }),
        }
    }

    /// Enable the content-addressed prefix cache (paged mode only): blocks
    /// released at refcount 0 stay indexed for reuse instead of freeing, and
    /// [`claim_with_prefix`](Self::claim_with_prefix) maps cache hits shared.
    pub fn with_prefix_cache(mut self) -> SlotManager {
        let p = self.paged.as_mut().expect("prefix cache requires the paged allocator");
        p.prefix = Some(PrefixCache::sized(p.capacity));
        self
    }

    /// Whether the prefix cache is on (always false in dense mode).
    pub fn prefix_cache_enabled(&self) -> bool {
        self.paged.as_ref().is_some_and(|p| p.prefix.is_some())
    }

    pub fn batch(&self) -> usize {
        self.lens.len()
    }

    pub fn is_paged(&self) -> bool {
        self.paged.is_some()
    }

    /// Paged block size; `None` in dense mode.
    pub fn block_size(&self) -> Option<usize> {
        self.paged.as_ref().map(|p| p.block_size)
    }

    /// Blocks needed to cover `tokens` logical positions (paged mode).
    fn blocks_for(&self, tokens: usize) -> usize {
        let bs = self.paged.as_ref().map(|p| p.block_size).unwrap_or(BLOCK_SIZE);
        tokens.div_ceil(bs)
    }

    /// Whether a request of `prompt_len` tokens could EVER be admitted (the
    /// full scatter fits the logical window and, in paged mode, the
    /// committable chunk fits the total block capacity). Uses the default
    /// commit chunk; policy-aware callers use
    /// [`request_fits_chunk`](Self::request_fits_chunk).
    pub fn request_fits(&self, prompt_len: usize) -> bool {
        self.request_fits_chunk(prompt_len, self.chunk)
    }

    /// [`request_fits`](Self::request_fits) with the request's own commit
    /// chunk (its policy's commit width).
    pub fn request_fits_chunk(&self, prompt_len: usize, chunk: usize) -> bool {
        prompt_len + self.write_width <= self.s_max
            && self
                .paged
                .as_ref()
                .is_none_or(|p| self.blocks_for(prompt_len + chunk) <= p.capacity)
    }

    /// Whether a request of `prompt_len` tokens can be admitted NOW: dense
    /// mode only needs the logical window; paged mode additionally needs
    /// enough free blocks to cover prompt + one committable speculation
    /// chunk (dynamic tree mode charges the node BUDGET here, not the
    /// envelope — the over-reservation fix). Uses the default commit chunk;
    /// policy-aware callers use [`can_admit_chunk`](Self::can_admit_chunk).
    pub fn can_admit(&self, prompt_len: usize) -> bool {
        self.can_admit_chunk(prompt_len, self.chunk)
    }

    /// [`can_admit`](Self::can_admit) with the request's own commit chunk.
    /// Paged headroom counts *available* blocks (free + evictable
    /// cached-idle), not just the free list — identical without a prefix
    /// cache, where no block is ever cached-idle.
    pub fn can_admit_chunk(&self, prompt_len: usize, chunk: usize) -> bool {
        prompt_len + self.write_width <= self.s_max
            && self
                .paged
                .as_ref()
                .is_none_or(|p| p.available() >= self.blocks_for(prompt_len + chunk))
    }

    /// Prompt-aware admission headroom: full-block prefix hits are mapped
    /// shared (no allocation), so they reduce the fresh-block need; hits
    /// themselves are protected from eviction at claim time, so an idle hit
    /// cannot double as eviction supply. Falls back to
    /// [`can_admit_chunk`](Self::can_admit_chunk) semantics when the prefix
    /// cache is off.
    pub fn can_admit_prompt(&self, prompt: &[i32], chunk: usize) -> bool {
        let plen = prompt.len();
        if plen + self.write_width > self.s_max {
            return false;
        }
        let Some(p) = &self.paged else { return true };
        let need = self.blocks_for(plen + chunk);
        let m = p.match_prefix(prompt);
        let hits = m.full.len();
        let idle_hits = m.full.iter().filter(|&&b| p.refcount[b] == 0).count();
        p.available() - idle_hits + hits >= need
    }

    /// Claim slot `i` for a request with `prompt_len` tokens at the default
    /// commit chunk. Fails if the prompt plus one full speculation chunk
    /// cannot fit — in paged mode that includes claiming the covering blocks
    /// from the free list.
    pub fn claim(&mut self, i: usize, prompt_len: usize) -> Result<(), String> {
        self.claim_with_chunk(i, prompt_len, self.chunk)
    }

    /// [`claim`](Self::claim) with the request's OWN commit chunk: the slot
    /// is charged (block coverage, commit ceiling, CacheFull signaling) by
    /// its policy's commit width for its whole lifetime — two slots with
    /// different node budgets reserve different scratch coverage in the same
    /// pool (the per-slot adaptive-budget accounting).
    pub fn claim_with_chunk(
        &mut self,
        i: usize,
        prompt_len: usize,
        chunk: usize,
    ) -> Result<(), String> {
        if self.active[i] {
            return Err(format!("slot {i} already active"));
        }
        if chunk == 0 || chunk > self.write_width {
            return Err(format!(
                "slot {i}: commit chunk {chunk} outside 1..={} (the engine write width)",
                self.write_width
            ));
        }
        if prompt_len + self.write_width > self.s_max {
            return Err(format!(
                "prompt {prompt_len} + write width {} > s_max {}",
                self.write_width, self.s_max
            ));
        }
        let need = self.blocks_for(prompt_len + chunk);
        if let Some(p) = &mut self.paged {
            if p.available() < need {
                return Err(format!(
                    "slot {i}: need {need} KV blocks, {} free (capacity {})",
                    p.available(),
                    p.capacity
                ));
            }
            debug_assert!(p.tables[i].is_empty(), "slot {i}: stale block table");
            for _ in 0..need {
                let b = p.alloc().expect("available() promised headroom");
                p.tables[i].push(b);
            }
        }
        self.active[i] = true;
        self.lens[i] = prompt_len;
        self.chunks[i] = chunk;
        Ok(())
    }

    /// [`claim_with_chunk`](Self::claim_with_chunk) through the prefix cache:
    /// walk `prompt` through the index, map full-block hits *shared*
    /// (incref, no allocation), claim the best sub-block hit copy-on-write
    /// (a private block plus a `(src, dst)` pool copy for the engine), then
    /// allocate the remaining coverage fresh. Matched blocks are increfed
    /// BEFORE any allocation so on-demand eviction can never reclaim the
    /// very blocks being hit. On failure everything is rolled back and the
    /// allocator is untouched. With the cache off this is exactly
    /// `claim_with_chunk` (a zero-length hit).
    pub fn claim_with_prefix(
        &mut self,
        i: usize,
        prompt: &[i32],
        chunk: usize,
    ) -> Result<PrefixClaim, String> {
        if !self.prefix_cache_enabled() {
            return self.claim_with_chunk(i, prompt.len(), chunk).map(|()| PrefixClaim::default());
        }
        let prompt_len = prompt.len();
        if self.active[i] {
            return Err(format!("slot {i} already active"));
        }
        if chunk == 0 || chunk > self.write_width {
            return Err(format!(
                "slot {i}: commit chunk {chunk} outside 1..={} (the engine write width)",
                self.write_width
            ));
        }
        if prompt_len + self.write_width > self.s_max {
            return Err(format!(
                "prompt {prompt_len} + write width {} > s_max {}",
                self.write_width, self.s_max
            ));
        }
        let need = self.blocks_for(prompt_len + chunk);
        let p = self.paged.as_mut().expect("prefix cache implies paged");
        debug_assert!(p.tables[i].is_empty(), "slot {i}: stale block table");
        let m = p.match_prefix(prompt);
        let bs = p.block_size;
        // Protect every hit before the first alloc(): alloc may evict
        // refcount-0 cached blocks — including the hits themselves.
        let mut table: Vec<usize> = Vec::with_capacity(need);
        for &b in &m.full {
            p.incref(b);
            table.push(b);
        }
        let guard = m.partial.map(|(src, _)| {
            p.incref(src);
            src
        });
        let mut claim = PrefixClaim { cached_len: table.len() * bs, copies: Vec::new() };
        if let Some((src, matched)) = m.partial {
            if let Some(dst) = p.alloc() {
                claim.copies.push((src, dst));
                claim.cached_len += matched;
                table.push(dst);
            }
        }
        let mut exhausted = false;
        while table.len() < need {
            match p.alloc() {
                Some(b) => table.push(b),
                None => {
                    exhausted = true;
                    break;
                }
            }
        }
        if let Some(src) = guard {
            p.decref(src);
        }
        if exhausted {
            // the COW copy was never applied to the pool, so its destination
            // simply frees; hits fall back to their prior state
            for &b in &table {
                p.decref(b);
            }
            return Err(format!(
                "slot {i}: need {need} KV blocks, {} available (capacity {})",
                p.available(),
                p.capacity
            ));
        }
        if let Some(cache) = p.prefix.as_mut() {
            for &b in &m.full {
                cache.touch(b);
            }
        }
        p.tables[i] = table;
        self.active[i] = true;
        self.lens[i] = prompt_len;
        self.chunks[i] = chunk;
        Ok(claim)
    }

    /// Register slot `i`'s fully-committed prompt blocks — those whose every
    /// position is `< prompt.len()` and will never be written again — in the
    /// prefix index, so later admissions can share them. Call AFTER the
    /// block contents physically exist in the pool (post-splice). Blocks
    /// whose hash is already indexed (including the slot's own shared hits)
    /// are skipped; no-op without the cache. Generated blocks are never
    /// registered: only prompt-derived KV is bit-reproducible across the
    /// prefill paths.
    pub fn register_prefix(&mut self, i: usize, prompt: &[i32]) {
        let Some(p) = self.paged.as_mut() else { return };
        if p.prefix.is_none() {
            return;
        }
        debug_assert!(self.active[i], "register_prefix on an inactive slot");
        let bs = p.block_size;
        let plen = prompt.len();
        let mut h = CHAIN_SEED;
        let mut k = 0usize;
        while (k + 1) * bs <= plen {
            let toks = &prompt[k * bs..(k + 1) * bs];
            let nh = chain_hash(h, toks);
            let b = p.tables[i][k];
            let cache = p.prefix.as_mut().expect("checked above");
            if !cache.by_hash.contains_key(&nh) && cache.meta[b].is_none() {
                cache.register(b, h, nh, toks.to_vec());
            }
            h = nh;
            k += 1;
        }
    }

    /// Slot `i`'s commit chunk (its policy's commit width).
    pub fn chunk_of(&self, i: usize) -> usize {
        self.chunks[i]
    }

    /// Record `accepted + 1` new cached positions after a verify step.
    /// Returns false when the slot can no longer fit another chunk (the
    /// engine must finish the request — FinishReason::CacheFull).
    /// Shorthand for [`begin_spec`](Self::begin_spec) +
    /// [`commit_spec`](Self::commit_spec) (the chain path, where the chunk
    /// prefix is the accepted path by construction).
    pub fn advance(&mut self, i: usize, emitted: usize) -> bool {
        self.begin_spec(i);
        self.commit_spec(i, emitted)
    }

    /// Open the speculative scratch region of slot `i`: a verify call is
    /// about to write `chunk` fresh positions at `len .. len + chunk`. The
    /// region is invisible to [`len`](Self::len)/[`cache_len_i32`](Self::cache_len_i32)
    /// until committed — attention masks everything at or beyond `cache_len`,
    /// so an uncommitted (or rolled-back) region is inert garbage. In paged
    /// mode the scratch blocks are already owned (the coverage invariant),
    /// so this never touches the free list.
    pub fn begin_spec(&mut self, i: usize) {
        debug_assert!(self.active[i]);
        debug_assert!(!self.specing[i], "slot {i}: speculation already open");
        debug_assert!(self.lens[i] + self.write_width <= self.s_max);
        if let Some(p) = &self.paged {
            debug_assert!(
                p.tables[i].len() * p.block_size >= self.lens[i] + self.chunks[i],
                "slot {i}: scratch blocks not reserved"
            );
        }
        self.specing[i] = true;
    }

    /// Commit the accepted prefix of slot `i`'s scratch region: `kept`
    /// positions (root + accepted draft nodes, already contiguous — the
    /// paged tree path rewires/copies blocks first, see
    /// [`commit planning`](crate::runtime::kv_blocks::plan_path_commit))
    /// become part of the valid cache. Returns false when the slot can no
    /// longer fit another chunk — because the logical window is exhausted
    /// or, in paged mode, because the free list cannot supply the next
    /// chunk's scratch blocks (the engine must finish the request —
    /// FinishReason::CacheFull).
    pub fn commit_spec(&mut self, i: usize, kept: usize) -> bool {
        debug_assert!(self.specing[i], "slot {i}: commit without begin_spec");
        debug_assert!(kept <= self.chunks[i]);
        self.specing[i] = false;
        self.lens[i] += kept;
        if self.lens[i] + self.write_width > self.s_max {
            return false;
        }
        let need = self.blocks_for(self.lens[i] + self.chunks[i]);
        if let Some(p) = &mut self.paged {
            while p.tables[i].len() < need {
                // alloc() evicts cached-idle blocks on demand; when even
                // that runs dry, the partially-grown table stays with the
                // slot — every caller must release the slot on `false`
                // (pinned by commit_spec_partial_grab_then_release_…)
                match p.alloc() {
                    Some(b) => p.tables[i].push(b),
                    None => return false, // block budget exhausted
                }
            }
        }
        true
    }

    /// Abandon slot `i`'s scratch region entirely (commit nothing). The
    /// written positions stay masked and are overwritten by the next chunk;
    /// in paged mode the scratch blocks stay claimed for that reuse.
    pub fn rollback_spec(&mut self, i: usize) {
        debug_assert!(self.specing[i], "slot {i}: rollback without begin_spec");
        self.specing[i] = false;
    }

    /// Whether slot `i` has an open (uncommitted) scratch region.
    pub fn is_specing(&self, i: usize) -> bool {
        self.specing[i]
    }

    /// Free slot `i` (idempotent): paged tables drain exactly once — a
    /// second release finds an empty table and frees nothing, so no block is
    /// ever double-freed. Each drained block is *decrefed*, never freed
    /// outright: a block another table still maps keeps its refcount, and a
    /// registered block at refcount 0 parks cached-idle instead of returning
    /// to the free list.
    pub fn release(&mut self, i: usize) {
        self.active[i] = false;
        self.specing[i] = false;
        self.lens[i] = 0;
        self.chunks[i] = self.chunk;
        if let Some(p) = &mut self.paged {
            let drained = std::mem::take(&mut p.tables[i]);
            for b in drained {
                p.decref(b);
            }
        }
    }

    pub fn len(&self, i: usize) -> usize {
        self.lens[i]
    }

    pub fn is_active(&self, i: usize) -> bool {
        self.active[i]
    }

    /// Slot `i`'s block table (pool block per covered logical-block index).
    /// Empty in dense mode.
    pub fn table(&self, i: usize) -> &[usize] {
        self.paged.as_ref().map(|p| p.tables[i].as_slice()).unwrap_or(&[])
    }

    /// Swap two of slot `i`'s table entries (logical block indices) — the
    /// paged tree-commit's rewire: an accepted scratch block becomes the
    /// committed block at its destination position without copying a row,
    /// and the displaced block takes its place in the (don't-care) scratch
    /// region, so no block is ever orphaned.
    pub fn swap_blocks(&mut self, i: usize, a: usize, b: usize) {
        let p = self.paged.as_mut().expect("swap_blocks on a dense SlotManager");
        debug_assert!(self.active[i]);
        // tree path commits only rewire scratch-region blocks, which sit
        // strictly above the registered prompt prefix (registered block k
        // has (k+1)*bs <= plen; scratch starts at position >= plen), so a
        // rewire can never move a shared or content-indexed block
        debug_assert!(
            p.refcount[p.tables[i][a]] == 1 && p.refcount[p.tables[i][b]] == 1,
            "swap_blocks would rewire a shared block"
        );
        debug_assert!(
            !p.is_cached(p.tables[i][a]) && !p.is_cached(p.tables[i][b]),
            "swap_blocks would move a prefix-cached block"
        );
        p.tables[i].swap(a, b);
    }

    /// Blocks in use across all slots. Paged mode counts *distinct* mapped
    /// blocks (`capacity - free - cached-idle`): under prefix sharing the
    /// sum of table lengths can exceed the physical pool, and occupancy
    /// metrics gate on `used <= capacity`. Without sharing the two counts
    /// are identical. Dense mode reports the utilization *view* (blocks a
    /// paged cache would need).
    pub fn blocks_used(&self) -> usize {
        match &self.paged {
            Some(p) => p.capacity - p.free.len() - p.idle_cached(),
            None => self
                .lens
                .iter()
                .zip(&self.active)
                .filter(|(_, &a)| a)
                .map(|(&l, _)| l.div_ceil(BLOCK_SIZE))
                .sum(),
        }
    }

    pub fn blocks_total(&self) -> usize {
        match &self.paged {
            Some(p) => p.capacity,
            None => self.batch() * self.s_max.div_ceil(BLOCK_SIZE),
        }
    }

    pub fn free_blocks(&self) -> usize {
        match &self.paged {
            Some(p) => p.free.len(),
            None => self.blocks_total() - self.blocks_used(),
        }
    }

    pub fn utilization(&self) -> f64 {
        self.blocks_used() as f64 / self.blocks_total() as f64
    }

    /// Mapping refcount of pool block `b` (0 in dense mode, for free blocks,
    /// and for cached-idle blocks).
    pub fn refcount(&self, b: usize) -> u32 {
        self.paged.as_ref().map(|p| p.refcount[b]).unwrap_or(0)
    }

    /// Blocks currently mapped by two or more slot tables.
    pub fn shared_blocks(&self) -> usize {
        self.paged
            .as_ref()
            .map(|p| p.refcount.iter().filter(|&&r| r >= 2).count())
            .unwrap_or(0)
    }

    /// Blocks registered in the prefix index (mapped or idle).
    pub fn cached_blocks(&self) -> usize {
        self.paged
            .as_ref()
            .and_then(|p| p.prefix.as_ref())
            .map(|c| c.meta.iter().flatten().count())
            .unwrap_or(0)
    }

    /// Cumulative LRU evictions of cached-idle blocks.
    pub fn prefix_evictions(&self) -> usize {
        self.paged
            .as_ref()
            .and_then(|p| p.prefix.as_ref())
            .map(|c| c.evictions)
            .unwrap_or(0)
    }

    /// Blocks an allocation could obtain right now (free + evictable idle).
    pub fn available_blocks(&self) -> usize {
        match &self.paged {
            Some(p) => p.available(),
            None => self.free_blocks(),
        }
    }

    /// cache_len vector for the verify executable (`[B]` i32). Inactive slots
    /// report 1 (a harmless minimal prefix) so padded rows stay in-bounds.
    pub fn cache_len_i32(&self) -> Vec<i32> {
        self.lens
            .iter()
            .zip(&self.active)
            .map(|(&l, &a)| if a { l as i32 } else { 1 })
            .collect()
    }

    /// Flat `[B * (s_max / block_size)]` i32 block table for the paged
    /// verify executables; unused entries and inactive rows are padded with
    /// the null block 0. Panics in dense mode.
    pub fn block_table_i32(&self) -> Vec<i32> {
        let p = self.paged.as_ref().expect("block_table_i32 on a dense SlotManager");
        let width = self.s_max / p.block_size;
        let mut out = vec![0i32; self.batch() * width];
        for (i, t) in p.tables.iter().enumerate() {
            for (j, &b) in t.iter().enumerate() {
                out[i * width + j] = b as i32;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Case};

    #[test]
    fn claim_advance_release() {
        let mut m = SlotManager::new(2, 64, 6);
        m.claim(0, 20).unwrap();
        assert!(m.is_active(0));
        assert_eq!(m.len(0), 20);
        assert!(m.advance(0, 4));
        assert_eq!(m.len(0), 24);
        m.release(0);
        assert!(!m.is_active(0));
        assert_eq!(m.cache_len_i32(), vec![1, 1]);
    }

    #[test]
    fn rejects_oversized_prompt() {
        let mut m = SlotManager::new(1, 32, 6);
        assert!(m.claim(0, 27).is_err());
        assert!(m.claim(0, 26).is_ok());
    }

    #[test]
    fn rejects_double_claim() {
        let mut m = SlotManager::new(1, 64, 6);
        m.claim(0, 8).unwrap();
        assert!(m.claim(0, 8).is_err());
    }

    #[test]
    fn advance_signals_capacity() {
        let mut m = SlotManager::new(1, 32, 6);
        m.claim(0, 20).unwrap();
        assert!(m.advance(0, 6)); // 26 + 6 = 32 <= 32 ✓
        assert!(!m.advance(0, 1)); // 27 + 6 > 32
    }

    #[test]
    fn paged_accounting() {
        let mut m = SlotManager::new(2, 64, 6);
        m.claim(0, 17).unwrap(); // 2 blocks
        m.claim(1, 16).unwrap(); // 1 block
        assert_eq!(m.blocks_used(), 3);
        assert_eq!(m.blocks_total(), 8);
        assert!((m.utilization() - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn spec_commit_advances_by_kept_prefix() {
        let mut m = SlotManager::new(2, 64, 6);
        m.claim(0, 20).unwrap();
        m.begin_spec(0);
        assert!(m.is_specing(0));
        // scratch region is invisible until committed
        assert_eq!(m.len(0), 20);
        assert_eq!(m.cache_len_i32(), vec![20, 1]);
        assert!(m.commit_spec(0, 4));
        assert!(!m.is_specing(0));
        assert_eq!(m.len(0), 24);
    }

    #[test]
    fn spec_rollback_commits_nothing() {
        let mut m = SlotManager::new(1, 64, 6);
        m.claim(0, 20).unwrap();
        m.begin_spec(0);
        m.rollback_spec(0);
        assert!(!m.is_specing(0));
        assert_eq!(m.len(0), 20);
        // the slot is immediately reusable for the next chunk
        m.begin_spec(0);
        assert!(m.commit_spec(0, 6));
        assert_eq!(m.len(0), 26);
    }

    #[test]
    fn spec_commit_signals_capacity_like_advance() {
        let mut m = SlotManager::new(1, 32, 6);
        m.claim(0, 20).unwrap();
        m.begin_spec(0);
        assert!(m.commit_spec(0, 6)); // 26 + 6 = 32 <= 32 ✓
        m.begin_spec(0);
        assert!(!m.commit_spec(0, 1)); // 27 + 6 > 32
    }

    #[test]
    fn release_clears_open_speculation() {
        let mut m = SlotManager::new(1, 64, 6);
        m.claim(0, 8).unwrap();
        m.begin_spec(0);
        m.release(0);
        assert!(!m.is_specing(0));
        // a fresh claim starts with a clean scratch lifecycle
        m.claim(0, 8).unwrap();
        m.begin_spec(0);
        assert!(m.commit_spec(0, 2));
    }

    #[test]
    fn capacity_invariant_property() {
        // a slot that claims + advances while advance() returns true can
        // always fit one more chunk write
        check("kv-capacity", 100, |rng| {
            let s_max = 16 + rng.below(240);
            let chunk = 2 + rng.below(8);
            let mut m = SlotManager::new(1, s_max, chunk);
            let prompt = 1 + rng.below(s_max);
            if m.claim(0, prompt).is_err() {
                return Case::Pass; // correctly rejected
            }
            loop {
                if m.len(0) + chunk > s_max {
                    return Case::Fail {
                        desc: format!("len {} + chunk {chunk} > {s_max}", m.len(0)),
                        size: s_max,
                    };
                }
                let emitted = 1 + rng.below(chunk);
                if !m.advance(0, emitted) {
                    return Case::Pass;
                }
            }
        });
    }

    // --- paged allocator ---------------------------------------------------

    fn paged(batch: usize, s_max: usize, chunk: usize, bs: usize, cap: usize) -> SlotManager {
        SlotManager::new_paged(batch, s_max, chunk, bs, cap)
    }

    #[test]
    fn paged_claim_takes_covering_blocks() {
        let mut m = paged(2, 64, 6, 16, 8);
        m.claim(0, 20).unwrap(); // 20 + 6 = 26 -> 2 blocks
        assert_eq!(m.table(0).len(), 2);
        assert_eq!(m.blocks_used(), 2);
        assert_eq!(m.free_blocks(), 6);
        // block ids are 1-based (0 is the null block), handed out ascending
        assert_eq!(m.table(0), &[1, 2]);
    }

    #[test]
    fn paged_claim_refuses_without_free_blocks() {
        let mut m = paged(2, 64, 6, 16, 2);
        m.claim(0, 20).unwrap(); // takes both blocks
        let err = m.claim(1, 20).unwrap_err();
        assert!(err.contains("KV blocks"), "undescriptive error: {err}");
        assert!(!m.can_admit(20));
        assert!(m.request_fits(20)); // fits capacity, just not right now
        m.release(0);
        assert!(m.can_admit(20));
    }

    #[test]
    fn paged_commit_extends_coverage_and_signals_exhaustion() {
        // bs 4, capacity 5: prompt 6 + chunk 3 -> 3 blocks at claim
        let mut m = paged(1, 32, 3, 4, 5);
        m.claim(0, 6).unwrap();
        assert_eq!(m.table(0).len(), 3);
        // len 6 -> 9: need ceil(12/4) = 3 blocks, still covered
        assert!(m.advance(0, 3));
        assert_eq!(m.table(0).len(), 3);
        // len 9 -> 12: need ceil(15/4) = 4, takes one more
        assert!(m.advance(0, 3));
        assert_eq!(m.table(0).len(), 4);
        // len 12 -> 15: need ceil(18/4) = 5, takes the last
        assert!(m.advance(0, 3));
        assert_eq!(m.table(0).len(), 5);
        assert_eq!(m.free_blocks(), 0);
        // len 15 -> 18: need 6 blocks, free list empty -> CacheFull signal
        assert!(!m.advance(0, 3));
    }

    #[test]
    fn paged_release_is_idempotent() {
        let mut m = paged(1, 64, 6, 16, 4);
        m.claim(0, 20).unwrap();
        assert_eq!(m.free_blocks(), 2);
        m.release(0);
        assert_eq!(m.free_blocks(), 4);
        m.release(0); // second release must not double-free
        assert_eq!(m.free_blocks(), 4);
        assert_eq!(m.blocks_used(), 0);
    }

    #[test]
    fn paged_block_table_pads_with_null_block() {
        let mut m = paged(2, 64, 6, 16, 8); // table width 4 per slot
        m.claim(1, 20).unwrap(); // 2 blocks
        let t = m.block_table_i32();
        assert_eq!(t.len(), 8);
        assert_eq!(&t[..4], &[0, 0, 0, 0], "inactive row must be all null");
        assert_eq!(&t[4..6], &[1, 2]);
        assert_eq!(&t[6..], &[0, 0], "unused entries must be null");
        assert!(t.iter().all(|&b| b >= 0));
    }

    #[test]
    fn paged_swap_blocks_rewires_table() {
        let mut m = paged(1, 64, 6, 16, 4);
        m.claim(0, 40).unwrap(); // 40 + 6 -> 3 blocks [1, 2, 3]
        m.swap_blocks(0, 1, 2);
        assert_eq!(m.table(0), &[1, 3, 2]);
        // swapped tables release cleanly
        m.release(0);
        assert_eq!(m.free_blocks(), 4);
    }

    #[test]
    fn write_width_defaults_to_chunk_and_rejects_narrowing() {
        let m = SlotManager::new(1, 64, 6);
        assert_eq!(m.write_width(), 6);
        let m = SlotManager::new(1, 64, 6).with_write_width(14);
        assert_eq!(m.write_width(), 14);
        assert_eq!(m.chunk, 6);
    }

    #[test]
    #[should_panic(expected = "below commit chunk")]
    fn write_width_below_chunk_panics() {
        let _ = SlotManager::new(1, 64, 6).with_write_width(5);
    }

    #[test]
    fn write_width_governs_the_s_max_fit() {
        // dynamic tree mode: commits at most chunk=4 positions per step but
        // physically scatters 9 — the fit checks must use the wider value or
        // the dense scatter would clamp into committed cache
        let mut m = SlotManager::new(1, 32, 4).with_write_width(9);
        assert!(m.claim(0, 24).is_err()); // 24 + 9 > 32
        m.claim(0, 23).unwrap(); // 23 + 9 == 32 ✓
        m.begin_spec(0);
        assert!(!m.commit_spec(0, 1), "24 + 9 > 32 must signal CacheFull");
        m.release(0);
        assert!(!m.request_fits(24));
        assert!(m.request_fits(23));
    }

    #[test]
    fn paged_charges_blocks_by_chunk_not_write_width() {
        // THE over-reservation regression: a dynamic engine with an 8-node
        // envelope but a 3-node budget must reserve blocks for budget+1=4
        // scratch positions, not envelope+1=9. bs=4: prompt 8 + chunk 4 ->
        // 3 blocks (charging by write width 9 would take 5).
        let mut m = SlotManager::new_paged(2, 64, 4, 4, 8).with_write_width(9);
        assert!(m.can_admit(8));
        m.claim(0, 8).unwrap();
        assert_eq!(m.table(0).len(), 3, "charged by envelope, not budget");
        // a second identical request still fits the remaining 5 blocks
        assert!(m.can_admit(8));
        m.claim(1, 8).unwrap();
        assert_eq!(m.blocks_used(), 6);
        // coverage invariant stays budget-denominated across commits
        m.begin_spec(0);
        assert!(m.commit_spec(0, 4));
        assert!(m.table(0).len() * 4 >= m.len(0) + m.chunk);
    }

    #[test]
    fn mixed_chunk_paged_admission_charges_per_slot() {
        // THE per-request-budget regression (satellite of the multi-drafter
        // PR): two slots claimed with different commit chunks in the same
        // pool must each be charged by their OWN chunk — coverage, admission
        // headroom, and commit growth all follow the slot, not an
        // engine-wide constant. bs=4, write width 10 (the widest policy's
        // scatter), default chunk 6.
        let mut m = SlotManager::new_paged(3, 64, 6, 4, 12).with_write_width(10);
        // slot 0: small-budget policy (chunk 4): prompt 8 + 4 -> 3 blocks
        m.claim_with_chunk(0, 8, 4).unwrap();
        assert_eq!(m.table(0).len(), 3);
        assert_eq!(m.chunk_of(0), 4);
        // slot 1: wide policy (chunk 9): prompt 8 + 9 -> 5 blocks
        m.claim_with_chunk(1, 8, 9).unwrap();
        assert_eq!(m.table(1).len(), 5, "wide slot charged by its own chunk");
        assert_eq!(m.blocks_used(), 8);
        // 4 blocks left: a wide (chunk-9) admission needs 5 and must refuse,
        // a chunk-4 one needs 3 and fits — headroom is policy-denominated
        assert!(!m.can_admit_chunk(8, 9));
        assert!(m.can_admit_chunk(8, 4));
        // commit growth keeps each slot's OWN coverage invariant
        m.begin_spec(0);
        assert!(m.commit_spec(0, 3)); // len 11, need ceil(15/4) = 4 blocks
        assert_eq!(m.table(0).len(), 4);
        assert!(m.table(0).len() * 4 >= m.len(0) + m.chunk_of(0));
        m.begin_spec(1);
        assert!(m.commit_spec(1, 9)); // len 17, need ceil(26/4) = 7 blocks
        assert_eq!(m.table(1).len(), 7);
        assert_eq!(m.free_blocks(), 1);
        // the last free block cannot host even a 1-token chunk-4 request
        let err = m.claim_with_chunk(2, 1, 4).unwrap_err();
        assert!(err.contains("KV blocks"), "undescriptive error: {err}");
        // release restores the default chunk for the next tenant
        m.release(1);
        m.claim(1, 8).unwrap();
        assert_eq!(m.chunk_of(1), 6);
    }

    #[test]
    fn claim_with_chunk_rejects_out_of_range_chunks() {
        let mut m = SlotManager::new(1, 64, 6).with_write_width(10);
        let err = m.claim_with_chunk(0, 8, 11).unwrap_err();
        assert!(err.contains("write width"), "undescriptive error: {err}");
        assert!(m.claim_with_chunk(0, 8, 0).is_err());
        m.claim_with_chunk(0, 8, 10).unwrap();
    }

    #[test]
    fn paged_allocator_never_leaks_or_double_assigns() {
        // The satellite property: under a randomized claim / spec-commit /
        // rollback / release interleaving across slots, (a) no block is ever
        // owned twice, (b) free ∪ owned is exactly the id range, (c)
        // blocks_used() == the sum of table lengths, and (d) every active
        // slot keeps its len+chunk coverage reservation.
        check("paged-allocator", 150, |rng| {
            let bs = 1 + rng.below(8);
            let blocks_per_slot = 2 + rng.below(8);
            let s_max = bs * blocks_per_slot;
            let chunk = 1 + rng.below(s_max.min(7));
            let batch = 1 + rng.below(4);
            let cap = 1 + rng.below(batch * blocks_per_slot + 3);
            let mut m = SlotManager::new_paged(batch, s_max, chunk, bs, cap);
            for step in 0..60 {
                let i = rng.below(batch);
                match rng.below(5) {
                    0 => {
                        if !m.is_active(i) {
                            let _ = m.claim(i, 1 + rng.below(s_max));
                        }
                    }
                    1 => {
                        if m.is_active(i) && !m.is_specing(i) {
                            m.begin_spec(i);
                        }
                    }
                    2 => {
                        if m.is_specing(i) {
                            if !m.commit_spec(i, rng.below(chunk + 1)) {
                                m.release(i); // the engine evicts on CacheFull
                            }
                        }
                    }
                    3 => {
                        if m.is_specing(i) {
                            m.rollback_spec(i);
                        }
                    }
                    _ => m.release(i), // releases are legal (and idempotent) any time
                }
                // (a) + (b): free ∪ tables is a permutation of 1..=cap
                let mut seen = vec![false; cap + 1];
                let mut owned = 0usize;
                for s in 0..batch {
                    for &b in m.table(s) {
                        if b == 0 || b > cap || seen[b] {
                            return Case::Fail {
                                desc: format!("step {step}: block {b} double-assigned or out of range"),
                                size: cap,
                            };
                        }
                        seen[b] = true;
                        owned += 1;
                    }
                }
                if owned + m.free_blocks() != cap {
                    return Case::Fail {
                        desc: format!(
                            "step {step}: {} owned + {} free != capacity {cap} (leak or dup)",
                            owned,
                            m.free_blocks()
                        ),
                        size: cap,
                    };
                }
                // (c)
                if m.blocks_used() != owned {
                    return Case::Fail {
                        desc: format!("step {step}: blocks_used {} != table sum {owned}", m.blocks_used()),
                        size: cap,
                    };
                }
                // (d) coverage reservation for every live slot
                for s in 0..batch {
                    if m.is_active(s) && m.table(s).len() * bs < m.len(s) + chunk {
                        return Case::Fail {
                            desc: format!(
                                "step {step}: slot {s} coverage {} blocks < len {} + chunk {chunk}",
                                m.table(s).len(),
                                m.len(s)
                            ),
                            size: cap,
                        };
                    }
                }
            }
            Case::Pass
        });
    }

    #[test]
    fn paged_parity_with_dense_accounting_when_fully_provisioned() {
        // fully provisioned paged manager must accept/advance/refuse at
        // exactly the same points as the dense one (the engine-level
        // dense-vs-paged byte parity rests on this)
        check("paged-dense-lockstep", 100, |rng| {
            let bs = 1 + rng.below(8);
            let blocks_per_slot = 2 + rng.below(8);
            let s_max = bs * blocks_per_slot;
            let chunk = 1 + rng.below(s_max.min(7));
            let mut d = SlotManager::new(1, s_max, chunk);
            let mut p = SlotManager::new_paged(1, s_max, chunk, bs, blocks_per_slot);
            let prompt = 1 + rng.below(s_max);
            let (rd, rp) = (d.claim(0, prompt), p.claim(0, prompt));
            if rd.is_ok() != rp.is_ok() {
                return Case::Fail {
                    desc: format!("claim({prompt}) dense {rd:?} vs paged {rp:?}"),
                    size: s_max,
                };
            }
            if rd.is_err() {
                return Case::Pass;
            }
            for _ in 0..40 {
                let emitted = 1 + rng.below(chunk);
                let (ad, ap) = (d.advance(0, emitted), p.advance(0, emitted));
                if ad != ap || d.len(0) != p.len(0) {
                    return Case::Fail {
                        desc: format!(
                            "advance({emitted}): dense ({ad}, len {}) vs paged ({ap}, len {})",
                            d.len(0),
                            p.len(0)
                        ),
                        size: s_max,
                    };
                }
                if !ad {
                    return Case::Pass;
                }
            }
            Case::Pass
        });
    }

    // --- prefix cache & block sharing --------------------------------------

    /// None, or a description of the first sharing-invariant violation:
    /// refcount == table mappings, free blocks unmapped/uncached/unique,
    /// free ∪ mapped ∪ cached-idle partitions the id range, and
    /// blocks_used() counts distinct mapped blocks.
    fn sharing_violation(m: &SlotManager) -> Option<String> {
        let p = m.paged.as_ref().unwrap();
        let cap = p.capacity;
        let mut maps = vec![0u32; cap + 1];
        for t in &p.tables {
            for &b in t {
                if b == 0 || b > cap {
                    return Some(format!("block {b} out of range"));
                }
                maps[b] += 1;
            }
        }
        for b in 1..=cap {
            if p.refcount[b] != maps[b] {
                return Some(format!(
                    "block {b}: refcount {} != {} table mappings",
                    p.refcount[b], maps[b]
                ));
            }
        }
        let mut in_free = vec![false; cap + 1];
        for &b in &p.free {
            if in_free[b] {
                return Some(format!("block {b} twice on the free list"));
            }
            in_free[b] = true;
            if maps[b] != 0 {
                return Some(format!("mapped block {b} on the free list"));
            }
            if p.is_cached(b) {
                return Some(format!("cached block {b} on the free list"));
            }
        }
        let mapped_distinct = (1..=cap).filter(|&b| maps[b] > 0).count();
        let idle = (1..=cap).filter(|&b| maps[b] == 0 && p.is_cached(b)).count();
        if p.free.len() + mapped_distinct + idle != cap {
            return Some(format!(
                "partition broken: {} free + {mapped_distinct} mapped + {idle} idle != {cap}",
                p.free.len()
            ));
        }
        if m.blocks_used() != mapped_distinct {
            return Some(format!(
                "blocks_used {} != distinct mapped {mapped_distinct}",
                m.blocks_used()
            ));
        }
        None
    }

    #[test]
    fn commit_spec_partial_grab_then_release_restores_full_range() {
        // THE exhaustion-invariant pin: commit_spec pops blocks into the
        // slot's table BEFORE discovering the free list cannot cover the
        // next chunk — the no-leak story requires the partial grab to stay
        // with the slot and drain on release. bs=2, cap=6: claim covers 4
        // blocks, the failing grow pops the remaining 2, then signals false.
        let mut m = paged(1, 16, 5, 2, 6);
        m.claim(0, 3).unwrap(); // blocks_for(3+5)=4
        assert_eq!(m.table(0).len(), 4);
        assert_eq!(m.free_blocks(), 2);
        m.begin_spec(0);
        // len 8: need blocks_for(13)=7 > capacity — pops the last 2, fails
        assert!(!m.commit_spec(0, 5));
        assert_eq!(m.table(0).len(), 6, "partial grab stays with the slot");
        assert_eq!(m.free_blocks(), 0);
        assert!(sharing_violation(&m).is_none());
        // the caller contract: release on false restores the full id range
        m.release(0);
        assert_eq!(m.blocks_used(), 0);
        assert_eq!(m.free_blocks(), 6);
        let mut free = m.paged.as_ref().unwrap().free.clone();
        free.sort_unstable();
        assert_eq!(free, vec![1, 2, 3, 4, 5, 6], "free ∪ owned != id range");
        // and the slot is immediately reusable at full capacity
        m.claim(0, 3).unwrap();
        assert_eq!(m.table(0).len(), 4);
    }

    #[test]
    fn chain_hash_is_order_and_parent_sensitive() {
        let a = chain_hash(CHAIN_SEED, &[1, 2, 3, 4]);
        let b = chain_hash(CHAIN_SEED, &[2, 1, 3, 4]);
        assert_ne!(a, b, "token order must change the hash");
        let c1 = chain_hash(a, &[5, 6, 7, 8]);
        let c2 = chain_hash(b, &[5, 6, 7, 8]);
        assert_ne!(c1, c2, "identical blocks under different parents must differ");
        assert_ne!(a, chain_hash(CHAIN_SEED, &[1, 2, 3]), "length must matter");
    }

    #[test]
    fn prefix_claim_shares_full_blocks_and_increfs() {
        let mut m = paged(3, 32, 3, 4, 16).with_prefix_cache();
        assert!(m.prefix_cache_enabled());
        let a: Vec<i32> = (1..=10).collect();
        // cold claim: a miss end to end
        let c0 = m.claim_with_prefix(0, &a, 3).unwrap();
        assert_eq!(c0, PrefixClaim::default());
        m.register_prefix(0, &a); // registers blocks 0,1 ((k+1)*4 <= 10)
        assert_eq!(m.cached_blocks(), 2);
        m.register_prefix(0, &a); // idempotent
        assert_eq!(m.cached_blocks(), 2);
        // hit claim: both full blocks shared, tail blocks fresh
        let c1 = m.claim_with_prefix(1, &a, 3).unwrap();
        assert_eq!(c1.cached_len, 8);
        assert!(c1.copies.is_empty());
        assert_eq!(&m.table(1)[..2], &m.table(0)[..2], "prefix blocks shared");
        assert_ne!(m.table(1)[2], m.table(0)[2], "tail blocks private");
        assert_eq!(m.refcount(m.table(0)[0]), 2);
        assert_eq!(m.refcount(m.table(0)[1]), 2);
        assert_eq!(m.shared_blocks(), 2);
        // distinct occupancy: 4 (slot 0) + 2 private (slot 1)
        assert_eq!(m.blocks_used(), 6);
        assert!(m.blocks_used() <= m.blocks_total());
        assert!(sharing_violation(&m).is_none());
    }

    #[test]
    fn prefix_partial_match_cows_a_private_copy() {
        let mut m = paged(2, 32, 3, 4, 16).with_prefix_cache();
        let a = vec![1, 2, 3, 4, 5, 6, 7, 8];
        m.claim_with_prefix(0, &a, 3).unwrap();
        m.register_prefix(0, &a); // blocks [1,2,3,4] and [5,6,7,8]
        // b diverges inside the second block: [5,6,..] shares 2 of 4 tokens
        let b = vec![1, 2, 3, 4, 5, 6, 99, 100];
        let c = m.claim_with_prefix(1, &b, 3).unwrap();
        assert_eq!(c.cached_len, 6, "4 full + 2 sub-block positions");
        assert_eq!(c.copies.len(), 1);
        let (src, dst) = c.copies[0];
        assert_eq!(src, m.table(0)[1], "copy source is the registered block");
        assert_eq!(dst, m.table(1)[1], "copy destination is slot 1's block");
        assert_ne!(src, dst, "COW must never write the shared block");
        assert_eq!(m.refcount(src), 1, "source still owned by slot 0 only");
        assert_eq!(m.refcount(dst), 1, "destination is private");
        assert!(!m.paged.as_ref().unwrap().is_cached(dst));
        assert_eq!(m.shared_blocks(), 1, "only the first block is shared");
        assert!(sharing_violation(&m).is_none());
    }

    #[test]
    fn prefix_release_decrefs_and_keeps_shared_blocks_out_of_free() {
        let mut m = paged(2, 32, 3, 4, 16).with_prefix_cache();
        let a: Vec<i32> = (1..=10).collect();
        m.claim_with_prefix(0, &a, 3).unwrap();
        m.register_prefix(0, &a);
        m.claim_with_prefix(1, &a, 3).unwrap();
        let shared: Vec<usize> = m.table(0)[..2].to_vec();
        m.release(0);
        // shared blocks survive with refcount 1; slot 0's privates free
        for &b in &shared {
            assert_eq!(m.refcount(b), 1);
            assert!(!m.paged.as_ref().unwrap().free.contains(&b));
        }
        assert!(sharing_violation(&m).is_none());
        m.release(1);
        m.release(1); // decref must be idempotent across double release
        // registered blocks park cached-idle, never on the free list
        assert_eq!(m.cached_blocks(), 2);
        assert_eq!(m.blocks_used(), 0);
        assert_eq!(m.free_blocks(), 14);
        assert_eq!(m.available_blocks(), 16);
        for &b in &shared {
            assert_eq!(m.refcount(b), 0);
            assert!(!m.paged.as_ref().unwrap().free.contains(&b));
        }
        assert!(sharing_violation(&m).is_none());
    }

    #[test]
    fn prefix_cache_eviction_is_lru_and_counts() {
        let mut m = paged(1, 16, 2, 4, 4).with_prefix_cache();
        let a = vec![1, 2, 3, 4, 5, 6];
        let b = vec![9, 10, 11, 12, 13, 14];
        m.claim_with_prefix(0, &a, 2).unwrap();
        m.register_prefix(0, &a);
        m.release(0);
        m.claim_with_prefix(0, &b, 2).unwrap();
        m.register_prefix(0, &b);
        m.release(0);
        assert_eq!(m.cached_blocks(), 2);
        // touch a's block so b's becomes the LRU victim
        let ca = m.claim_with_prefix(0, &a, 2).unwrap();
        assert_eq!(ca.cached_len, 4, "idle cached block must still hit");
        m.release(0);
        // a 3-block claim exceeds the 2 free blocks -> evicts exactly one
        let c = vec![50; 10];
        m.claim_with_prefix(0, &c, 2).unwrap();
        assert_eq!(m.prefix_evictions(), 1);
        assert_eq!(m.cached_blocks(), 1);
        assert!(sharing_violation(&m).is_none());
        m.release(0);
        // the survivor is a's block (recently touched), b's was the LRU
        let ca = m.claim_with_prefix(0, &a, 2).unwrap();
        assert_eq!(ca.cached_len, 4, "recently-used block must survive");
        m.release(0);
        let cb = m.claim_with_prefix(0, &b, 2).unwrap();
        assert_eq!(cb.cached_len, 0, "LRU block must be gone");
    }

    #[test]
    fn can_admit_prompt_accounts_for_cached_and_evictable() {
        let mut m = paged(2, 16, 2, 4, 4).with_prefix_cache();
        let a: Vec<i32> = (1..=10).collect();
        m.claim_with_prefix(0, &a, 2).unwrap(); // 3 blocks, 1 free
        m.register_prefix(0, &a); // blocks 0,1 registered (and mapped)
        // same-prefix prompt: needs 3 blocks but hits 2, so 1 free suffices
        let mut a2 = a.clone();
        a2[8] = 77;
        a2[9] = 78;
        assert!(m.can_admit_prompt(&a2, 2));
        // length-only headroom refuses — the hit is what admits it
        assert!(!m.can_admit_chunk(10, 2));
        // a cold prompt of the same length cannot be admitted
        let cold: Vec<i32> = (20..30).collect();
        assert!(!m.can_admit_prompt(&cold, 2));
        // and the claim agrees with the check, both ways
        assert!(m.claim_with_prefix(1, &cold, 2).unwrap_err().contains("KV blocks"));
        let c = m.claim_with_prefix(1, &a2, 2).unwrap();
        assert_eq!(c.cached_len, 8);
        assert_eq!(m.shared_blocks(), 2);
        assert!(sharing_violation(&m).is_none());
    }

    #[test]
    fn prefix_claim_rolls_back_cleanly_on_exhaustion() {
        let mut m = paged(2, 32, 3, 4, 4).with_prefix_cache();
        let a: Vec<i32> = (1..=10).collect();
        m.claim_with_prefix(0, &a, 3).unwrap(); // all 4 blocks
        m.register_prefix(0, &a);
        // a hit that still needs 2 fresh blocks must fail atomically
        let err = m.claim_with_prefix(1, &a, 3).unwrap_err();
        assert!(err.contains("KV blocks"), "undescriptive error: {err}");
        assert!(!m.is_active(1));
        assert!(m.table(1).is_empty());
        assert_eq!(m.shared_blocks(), 0, "rollback must drop the shared incref");
        assert!(sharing_violation(&m).is_none());
        // slot 0 is untouched and still releases the full range
        m.release(0);
        assert_eq!(m.available_blocks(), 4);
    }

    #[test]
    fn prefix_sharing_property_suite() {
        // The satellite property suite: random claim/spec/release traffic
        // over a small pool of shared prefixes with colliding sub-block
        // tails. After EVERY op: refcount == table mappings, free blocks are
        // unmapped+uncached+unique, free ∪ mapped ∪ cached-idle partitions
        // the id range, blocks_used() is the distinct mapped count, and each
        // COW destination is private and unindexed.
        check("prefix-sharing", 100, |rng| {
            let bs = 2 + rng.below(4); // 2..=5
            let blocks_per_slot = 3 + rng.below(4);
            let s_max = bs * blocks_per_slot;
            let chunk = 1 + rng.below(3);
            let batch = 2 + rng.below(3);
            let cap = 2 + rng.below(batch * blocks_per_slot + 4);
            let mut m =
                SlotManager::new_paged(batch, s_max, chunk, bs, cap).with_prefix_cache();
            // three disjoint base prefixes of two full blocks each
            let prefixes: Vec<Vec<i32>> = (0..3)
                .map(|j| (0..2 * bs as i32).map(|t| j * 50 + t + 1).collect())
                .collect();
            for step in 0..80 {
                let i = rng.below(batch);
                match rng.below(6) {
                    0 | 1 => {
                        if !m.is_active(i) && s_max > chunk {
                            let base = &prefixes[rng.below(3)];
                            let mut prompt = base.clone();
                            // near-binary tails collide at sub-block depth,
                            // exercising the COW arm
                            for _ in 0..1 + rng.below(bs * 2) {
                                prompt.push(200 + rng.below(2) as i32);
                            }
                            prompt.truncate(s_max.saturating_sub(chunk).max(1));
                            if let Ok(c) = m.claim_with_prefix(i, &prompt, chunk) {
                                for &(src, dst) in &c.copies {
                                    let p = m.paged.as_ref().unwrap();
                                    if src == dst || p.refcount[dst] != 1 || p.is_cached(dst) {
                                        return Case::Fail {
                                            desc: format!(
                                                "step {step}: bad COW ({src} -> {dst})"
                                            ),
                                            size: cap,
                                        };
                                    }
                                }
                                m.register_prefix(i, &prompt);
                            }
                        }
                    }
                    2 => {
                        if m.is_active(i) && !m.is_specing(i) {
                            m.begin_spec(i);
                        }
                    }
                    3 => {
                        if m.is_specing(i) {
                            if !m.commit_spec(i, rng.below(chunk + 1)) {
                                m.release(i); // the engine evicts on CacheFull
                            }
                        }
                    }
                    4 => {
                        if m.is_specing(i) {
                            m.rollback_spec(i);
                        }
                    }
                    _ => {
                        m.release(i);
                        m.release(i); // double release must be idempotent
                    }
                }
                if let Some(desc) = sharing_violation(&m) {
                    return Case::Fail { desc: format!("step {step}: {desc}"), size: cap };
                }
            }
            Case::Pass
        });
    }
}
