//! KV-cache slot accounting.
//!
//! The dense engine-wide cache buffer (shape [L, 2, B, S_MAX, H, Dh]) lives
//! on the PJRT device and is threaded through verify calls; this module owns
//! the *accounting*: per-slot valid lengths with independent claim/release
//! lifecycles (slots are claimed at different prefill lengths as the stepped
//! engine admits mid-flight), capacity admission (a slot must always fit
//! prompt + chunk writes), and a vLLM-style paged utilization view
//! (BLOCK_SIZE-token blocks) used by metrics and admission policy.

pub const BLOCK_SIZE: usize = 16;

#[derive(Clone, Debug)]
pub struct SlotManager {
    pub s_max: usize,
    pub chunk: usize, // K+1: widest write a verify step performs
    lens: Vec<usize>,
    active: Vec<bool>,
}

impl SlotManager {
    pub fn new(batch: usize, s_max: usize, chunk: usize) -> SlotManager {
        SlotManager { s_max, chunk, lens: vec![0; batch], active: vec![false; batch] }
    }

    pub fn batch(&self) -> usize {
        self.lens.len()
    }

    /// Claim slot `i` for a request with `prompt_len` tokens. Fails if the
    /// prompt plus one full speculation chunk cannot fit.
    pub fn claim(&mut self, i: usize, prompt_len: usize) -> Result<(), String> {
        if self.active[i] {
            return Err(format!("slot {i} already active"));
        }
        if prompt_len + self.chunk > self.s_max {
            return Err(format!("prompt {prompt_len} + chunk {} > s_max {}", self.chunk, self.s_max));
        }
        self.active[i] = true;
        self.lens[i] = prompt_len;
        Ok(())
    }

    /// Record `accepted + 1` new cached positions after a verify step.
    /// Returns false when the slot can no longer fit another chunk (the
    /// engine must finish the request — FinishReason::CacheFull).
    pub fn advance(&mut self, i: usize, emitted: usize) -> bool {
        debug_assert!(self.active[i]);
        debug_assert!(emitted <= self.chunk);
        self.lens[i] += emitted;
        self.lens[i] + self.chunk <= self.s_max
    }

    pub fn release(&mut self, i: usize) {
        self.active[i] = false;
        self.lens[i] = 0;
    }

    pub fn len(&self, i: usize) -> usize {
        self.lens[i]
    }

    pub fn is_active(&self, i: usize) -> bool {
        self.active[i]
    }

    /// Paged-accounting view: blocks in use across all slots.
    pub fn blocks_used(&self) -> usize {
        self.lens
            .iter()
            .zip(&self.active)
            .filter(|(_, &a)| a)
            .map(|(&l, _)| l.div_ceil(BLOCK_SIZE))
            .sum()
    }

    pub fn blocks_total(&self) -> usize {
        self.batch() * self.s_max.div_ceil(BLOCK_SIZE)
    }

    pub fn utilization(&self) -> f64 {
        self.blocks_used() as f64 / self.blocks_total() as f64
    }

    /// cache_len vector for the verify executable ([B] i32). Inactive slots
    /// report 1 (a harmless minimal prefix) so padded rows stay in-bounds.
    pub fn cache_len_i32(&self) -> Vec<i32> {
        self.lens
            .iter()
            .zip(&self.active)
            .map(|(&l, &a)| if a { l as i32 } else { 1 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Case};

    #[test]
    fn claim_advance_release() {
        let mut m = SlotManager::new(2, 64, 6);
        m.claim(0, 20).unwrap();
        assert!(m.is_active(0));
        assert_eq!(m.len(0), 20);
        assert!(m.advance(0, 4));
        assert_eq!(m.len(0), 24);
        m.release(0);
        assert!(!m.is_active(0));
        assert_eq!(m.cache_len_i32(), vec![1, 1]);
    }

    #[test]
    fn rejects_oversized_prompt() {
        let mut m = SlotManager::new(1, 32, 6);
        assert!(m.claim(0, 27).is_err());
        assert!(m.claim(0, 26).is_ok());
    }

    #[test]
    fn rejects_double_claim() {
        let mut m = SlotManager::new(1, 64, 6);
        m.claim(0, 8).unwrap();
        assert!(m.claim(0, 8).is_err());
    }

    #[test]
    fn advance_signals_capacity() {
        let mut m = SlotManager::new(1, 32, 6);
        m.claim(0, 20).unwrap();
        assert!(m.advance(0, 6)); // 26 + 6 = 32 <= 32 ✓
        assert!(!m.advance(0, 1)); // 27 + 6 > 32
    }

    #[test]
    fn paged_accounting() {
        let mut m = SlotManager::new(2, 64, 6);
        m.claim(0, 17).unwrap(); // 2 blocks
        m.claim(1, 16).unwrap(); // 1 block
        assert_eq!(m.blocks_used(), 3);
        assert_eq!(m.blocks_total(), 8);
        assert!((m.utilization() - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn capacity_invariant_property() {
        // a slot that claims + advances while advance() returns true can
        // always fit one more chunk write
        check("kv-capacity", 100, |rng| {
            let s_max = 16 + rng.below(240);
            let chunk = 2 + rng.below(8);
            let mut m = SlotManager::new(1, s_max, chunk);
            let prompt = 1 + rng.below(s_max);
            if m.claim(0, prompt).is_err() {
                return Case::Pass; // correctly rejected
            }
            loop {
                if m.len(0) + chunk > s_max {
                    return Case::Fail {
                        desc: format!("len {} + chunk {chunk} > {s_max}", m.len(0)),
                        size: s_max,
                    };
                }
                let emitted = 1 + rng.below(chunk);
                if !m.advance(0, emitted) {
                    return Case::Pass;
                }
            }
        });
    }
}
