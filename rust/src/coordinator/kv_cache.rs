//! KV-cache slot accounting and (in paged mode) a real block allocator.
//!
//! Dense mode: the engine-wide cache buffer (shape `[L, 2, B, S_MAX, H, Dh]`)
//! lives on the PJRT device and is threaded through verify calls; this module
//! owns the *accounting*: per-slot valid lengths with independent
//! claim/release lifecycles (slots are claimed at different prefill lengths
//! as the stepped engine admits mid-flight), capacity admission (a slot must
//! always fit prompt + chunk writes), and a speculative scratch region with
//! an explicit commit/rollback lifecycle (tree verification keeps only the
//! accepted root path of each chunk — see
//! [`EngineCore::step`](super::engine::EngineCore::step)).
//!
//! Paged mode ([`SlotManager::new_paged`]): the physical cache is a block
//! pool `[L, 2, NB, BLOCK, H, Dh]` and this module becomes a vLLM-style
//! allocator — a free list of `block_size`-token blocks plus a per-slot
//! block table mapping logical position `q` to pool block `table[q / bs]`
//! at offset `q % bs`. Block id 0 is the reserved *null block*: it is never
//! allocated, and [`SlotManager::block_table_i32`] pads inactive rows and
//! unused table entries with it so the lowered gather/scatter stays inert
//! there. Invariant kept at all times: an active slot's table covers
//! `len + chunk` positions, so the next verify's speculative scratch is
//! *pre-reserved* — `begin_spec` never allocates, `commit_spec` extends the
//! reservation for the following chunk (returning `false`, i.e. CacheFull,
//! when the free list cannot supply it), and `rollback_spec` keeps the
//! scratch blocks for reuse. Frees happen only at [`SlotManager::release`]
//! and are idempotent. Admission is gated on free-*block* headroom
//! ([`SlotManager::can_admit`]), not just free slots.

/// Dense-mode utilization granularity, and the default paged block size
/// (must match the Python lowering's `configs.KV_BLOCK_SIZE`).
pub const BLOCK_SIZE: usize = 16;

#[derive(Clone, Debug)]
struct PagedState {
    block_size: usize,
    /// allocatable blocks (ids `1..=capacity`; 0 is the null block)
    capacity: usize,
    /// LIFO free list; initialized descending so pops hand out ascending ids
    free: Vec<usize>,
    tables: Vec<Vec<usize>>,
}

#[derive(Clone, Debug)]
pub struct SlotManager {
    pub s_max: usize,
    /// DEFAULT positions a verify step can COMMIT (accepted path + bonus
    /// root): static modes N+1; dynamic tree mode `node_budget + 1` — the
    /// charge unit for paged block coverage and admission headroom. With
    /// per-request speculation policies each slot may carry its OWN commit
    /// chunk ([`claim_with_chunk`](Self::claim_with_chunk)); this field is
    /// the default used by [`claim`](Self::claim) and by admission checks
    /// that predate knowing the request's policy.
    pub chunk: usize,
    /// Per-slot commit chunk (the slot's policy commit width); equals
    /// `chunk` unless the slot was claimed with its own.
    chunks: Vec<usize>,
    /// Positions a verify step physically WRITES (the lowered scatter
    /// width). At least the widest `chunk` any serveable policy commits:
    /// dynamic tree envelopes scatter `envelope + 1` slots but commit only
    /// `budget + 1`, and in a multi-policy engine EVERY policy bucket's
    /// verify scatters (masked garbage) into every live row, so the `s_max`
    /// fit must honor the engine-wide maximum scatter width (a dense scatter
    /// past `s_max` would clamp and corrupt committed positions) while
    /// blocks are still charged by each slot's own `chunk`.
    write_width: usize,
    lens: Vec<usize>,
    active: Vec<bool>,
    /// slots with an open speculative scratch region (positions
    /// len .. len+chunk freshly written by a verify call, not yet committed)
    specing: Vec<bool>,
    paged: Option<PagedState>,
}

impl SlotManager {
    pub fn new(batch: usize, s_max: usize, chunk: usize) -> SlotManager {
        SlotManager {
            s_max,
            chunk,
            chunks: vec![chunk; batch],
            write_width: chunk,
            lens: vec![0; batch],
            active: vec![false; batch],
            specing: vec![false; batch],
            paged: None,
        }
    }

    /// Widen the physical scatter width past the commit/charge width
    /// (dynamic tree mode: `chunk = budget + 1`, `write_width = envelope
    /// nodes + 1`). The `s_max` fit checks switch to the wider value; block
    /// charging stays on `chunk`.
    pub fn with_write_width(mut self, write_width: usize) -> SlotManager {
        assert!(
            write_width >= self.chunk,
            "write width {write_width} below commit chunk {}",
            self.chunk
        );
        self.write_width = write_width;
        self
    }

    /// Positions a verify step physically writes (>= `chunk`).
    pub fn write_width(&self) -> usize {
        self.write_width
    }

    /// Paged allocator over `capacity` blocks of `block_size` tokens.
    /// `s_max` stays the per-slot logical ceiling (the lowered table width is
    /// `s_max / block_size`); a capacity below `batch * s_max / block_size`
    /// is a real memory budget — admission and growth then compete for
    /// blocks instead of each slot owning a dense `s_max` stripe.
    pub fn new_paged(
        batch: usize,
        s_max: usize,
        chunk: usize,
        block_size: usize,
        capacity: usize,
    ) -> SlotManager {
        assert!(block_size >= 1, "block_size must be >= 1");
        assert!(
            s_max % block_size == 0,
            "s_max {s_max} not divisible by block_size {block_size}"
        );
        SlotManager {
            s_max,
            chunk,
            chunks: vec![chunk; batch],
            write_width: chunk,
            lens: vec![0; batch],
            active: vec![false; batch],
            specing: vec![false; batch],
            paged: Some(PagedState {
                block_size,
                capacity,
                free: (1..=capacity).rev().collect(),
                tables: vec![Vec::new(); batch],
            }),
        }
    }

    pub fn batch(&self) -> usize {
        self.lens.len()
    }

    pub fn is_paged(&self) -> bool {
        self.paged.is_some()
    }

    /// Paged block size; `None` in dense mode.
    pub fn block_size(&self) -> Option<usize> {
        self.paged.as_ref().map(|p| p.block_size)
    }

    /// Blocks needed to cover `tokens` logical positions (paged mode).
    fn blocks_for(&self, tokens: usize) -> usize {
        let bs = self.paged.as_ref().map(|p| p.block_size).unwrap_or(BLOCK_SIZE);
        tokens.div_ceil(bs)
    }

    /// Whether a request of `prompt_len` tokens could EVER be admitted (the
    /// full scatter fits the logical window and, in paged mode, the
    /// committable chunk fits the total block capacity). Uses the default
    /// commit chunk; policy-aware callers use
    /// [`request_fits_chunk`](Self::request_fits_chunk).
    pub fn request_fits(&self, prompt_len: usize) -> bool {
        self.request_fits_chunk(prompt_len, self.chunk)
    }

    /// [`request_fits`](Self::request_fits) with the request's own commit
    /// chunk (its policy's commit width).
    pub fn request_fits_chunk(&self, prompt_len: usize, chunk: usize) -> bool {
        prompt_len + self.write_width <= self.s_max
            && self
                .paged
                .as_ref()
                .is_none_or(|p| self.blocks_for(prompt_len + chunk) <= p.capacity)
    }

    /// Whether a request of `prompt_len` tokens can be admitted NOW: dense
    /// mode only needs the logical window; paged mode additionally needs
    /// enough free blocks to cover prompt + one committable speculation
    /// chunk (dynamic tree mode charges the node BUDGET here, not the
    /// envelope — the over-reservation fix). Uses the default commit chunk;
    /// policy-aware callers use [`can_admit_chunk`](Self::can_admit_chunk).
    pub fn can_admit(&self, prompt_len: usize) -> bool {
        self.can_admit_chunk(prompt_len, self.chunk)
    }

    /// [`can_admit`](Self::can_admit) with the request's own commit chunk.
    pub fn can_admit_chunk(&self, prompt_len: usize, chunk: usize) -> bool {
        prompt_len + self.write_width <= self.s_max
            && self
                .paged
                .as_ref()
                .is_none_or(|p| p.free.len() >= self.blocks_for(prompt_len + chunk))
    }

    /// Claim slot `i` for a request with `prompt_len` tokens at the default
    /// commit chunk. Fails if the prompt plus one full speculation chunk
    /// cannot fit — in paged mode that includes claiming the covering blocks
    /// from the free list.
    pub fn claim(&mut self, i: usize, prompt_len: usize) -> Result<(), String> {
        self.claim_with_chunk(i, prompt_len, self.chunk)
    }

    /// [`claim`](Self::claim) with the request's OWN commit chunk: the slot
    /// is charged (block coverage, commit ceiling, CacheFull signaling) by
    /// its policy's commit width for its whole lifetime — two slots with
    /// different node budgets reserve different scratch coverage in the same
    /// pool (the per-slot adaptive-budget accounting).
    pub fn claim_with_chunk(
        &mut self,
        i: usize,
        prompt_len: usize,
        chunk: usize,
    ) -> Result<(), String> {
        if self.active[i] {
            return Err(format!("slot {i} already active"));
        }
        if chunk == 0 || chunk > self.write_width {
            return Err(format!(
                "slot {i}: commit chunk {chunk} outside 1..={} (the engine write width)",
                self.write_width
            ));
        }
        if prompt_len + self.write_width > self.s_max {
            return Err(format!(
                "prompt {prompt_len} + write width {} > s_max {}",
                self.write_width, self.s_max
            ));
        }
        let need = self.blocks_for(prompt_len + chunk);
        if let Some(p) = &mut self.paged {
            if p.free.len() < need {
                return Err(format!(
                    "slot {i}: need {need} KV blocks, {} free (capacity {})",
                    p.free.len(),
                    p.capacity
                ));
            }
            debug_assert!(p.tables[i].is_empty(), "slot {i}: stale block table");
            for _ in 0..need {
                p.tables[i].push(p.free.pop().unwrap());
            }
        }
        self.active[i] = true;
        self.lens[i] = prompt_len;
        self.chunks[i] = chunk;
        Ok(())
    }

    /// Slot `i`'s commit chunk (its policy's commit width).
    pub fn chunk_of(&self, i: usize) -> usize {
        self.chunks[i]
    }

    /// Record `accepted + 1` new cached positions after a verify step.
    /// Returns false when the slot can no longer fit another chunk (the
    /// engine must finish the request — FinishReason::CacheFull).
    /// Shorthand for [`begin_spec`](Self::begin_spec) +
    /// [`commit_spec`](Self::commit_spec) (the chain path, where the chunk
    /// prefix is the accepted path by construction).
    pub fn advance(&mut self, i: usize, emitted: usize) -> bool {
        self.begin_spec(i);
        self.commit_spec(i, emitted)
    }

    /// Open the speculative scratch region of slot `i`: a verify call is
    /// about to write `chunk` fresh positions at `len .. len + chunk`. The
    /// region is invisible to [`len`](Self::len)/[`cache_len_i32`](Self::cache_len_i32)
    /// until committed — attention masks everything at or beyond `cache_len`,
    /// so an uncommitted (or rolled-back) region is inert garbage. In paged
    /// mode the scratch blocks are already owned (the coverage invariant),
    /// so this never touches the free list.
    pub fn begin_spec(&mut self, i: usize) {
        debug_assert!(self.active[i]);
        debug_assert!(!self.specing[i], "slot {i}: speculation already open");
        debug_assert!(self.lens[i] + self.write_width <= self.s_max);
        if let Some(p) = &self.paged {
            debug_assert!(
                p.tables[i].len() * p.block_size >= self.lens[i] + self.chunks[i],
                "slot {i}: scratch blocks not reserved"
            );
        }
        self.specing[i] = true;
    }

    /// Commit the accepted prefix of slot `i`'s scratch region: `kept`
    /// positions (root + accepted draft nodes, already contiguous — the
    /// paged tree path rewires/copies blocks first, see
    /// [`commit planning`](crate::runtime::kv_blocks::plan_path_commit))
    /// become part of the valid cache. Returns false when the slot can no
    /// longer fit another chunk — because the logical window is exhausted
    /// or, in paged mode, because the free list cannot supply the next
    /// chunk's scratch blocks (the engine must finish the request —
    /// FinishReason::CacheFull).
    pub fn commit_spec(&mut self, i: usize, kept: usize) -> bool {
        debug_assert!(self.specing[i], "slot {i}: commit without begin_spec");
        debug_assert!(kept <= self.chunks[i]);
        self.specing[i] = false;
        self.lens[i] += kept;
        if self.lens[i] + self.write_width > self.s_max {
            return false;
        }
        let need = self.blocks_for(self.lens[i] + self.chunks[i]);
        if let Some(p) = &mut self.paged {
            while p.tables[i].len() < need {
                match p.free.pop() {
                    Some(b) => p.tables[i].push(b),
                    None => return false, // block budget exhausted
                }
            }
        }
        true
    }

    /// Abandon slot `i`'s scratch region entirely (commit nothing). The
    /// written positions stay masked and are overwritten by the next chunk;
    /// in paged mode the scratch blocks stay claimed for that reuse.
    pub fn rollback_spec(&mut self, i: usize) {
        debug_assert!(self.specing[i], "slot {i}: rollback without begin_spec");
        self.specing[i] = false;
    }

    /// Whether slot `i` has an open (uncommitted) scratch region.
    pub fn is_specing(&self, i: usize) -> bool {
        self.specing[i]
    }

    /// Free slot `i` (idempotent): paged tables drain back to the free list
    /// exactly once — a second release finds an empty table and frees
    /// nothing, so the free list never double-holds a block.
    pub fn release(&mut self, i: usize) {
        self.active[i] = false;
        self.specing[i] = false;
        self.lens[i] = 0;
        self.chunks[i] = self.chunk;
        if let Some(p) = &mut self.paged {
            let drained = std::mem::take(&mut p.tables[i]);
            p.free.extend(drained);
        }
    }

    pub fn len(&self, i: usize) -> usize {
        self.lens[i]
    }

    pub fn is_active(&self, i: usize) -> bool {
        self.active[i]
    }

    /// Slot `i`'s block table (pool block per covered logical-block index).
    /// Empty in dense mode.
    pub fn table(&self, i: usize) -> &[usize] {
        self.paged.as_ref().map(|p| p.tables[i].as_slice()).unwrap_or(&[])
    }

    /// Swap two of slot `i`'s table entries (logical block indices) — the
    /// paged tree-commit's rewire: an accepted scratch block becomes the
    /// committed block at its destination position without copying a row,
    /// and the displaced block takes its place in the (don't-care) scratch
    /// region, so no block is ever orphaned.
    pub fn swap_blocks(&mut self, i: usize, a: usize, b: usize) {
        let p = self.paged.as_mut().expect("swap_blocks on a dense SlotManager");
        debug_assert!(self.active[i]);
        p.tables[i].swap(a, b);
    }

    /// Blocks in use across all slots. Paged mode counts actually allocated
    /// blocks (== the sum of table lengths); dense mode reports the
    /// utilization *view* (blocks a paged cache would need).
    pub fn blocks_used(&self) -> usize {
        match &self.paged {
            Some(p) => p.tables.iter().map(|t| t.len()).sum(),
            None => self
                .lens
                .iter()
                .zip(&self.active)
                .filter(|(_, &a)| a)
                .map(|(&l, _)| l.div_ceil(BLOCK_SIZE))
                .sum(),
        }
    }

    pub fn blocks_total(&self) -> usize {
        match &self.paged {
            Some(p) => p.capacity,
            None => self.batch() * self.s_max.div_ceil(BLOCK_SIZE),
        }
    }

    pub fn free_blocks(&self) -> usize {
        match &self.paged {
            Some(p) => p.free.len(),
            None => self.blocks_total() - self.blocks_used(),
        }
    }

    pub fn utilization(&self) -> f64 {
        self.blocks_used() as f64 / self.blocks_total() as f64
    }

    /// cache_len vector for the verify executable (`[B]` i32). Inactive slots
    /// report 1 (a harmless minimal prefix) so padded rows stay in-bounds.
    pub fn cache_len_i32(&self) -> Vec<i32> {
        self.lens
            .iter()
            .zip(&self.active)
            .map(|(&l, &a)| if a { l as i32 } else { 1 })
            .collect()
    }

    /// Flat `[B * (s_max / block_size)]` i32 block table for the paged
    /// verify executables; unused entries and inactive rows are padded with
    /// the null block 0. Panics in dense mode.
    pub fn block_table_i32(&self) -> Vec<i32> {
        let p = self.paged.as_ref().expect("block_table_i32 on a dense SlotManager");
        let width = self.s_max / p.block_size;
        let mut out = vec![0i32; self.batch() * width];
        for (i, t) in p.tables.iter().enumerate() {
            for (j, &b) in t.iter().enumerate() {
                out[i * width + j] = b as i32;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Case};

    #[test]
    fn claim_advance_release() {
        let mut m = SlotManager::new(2, 64, 6);
        m.claim(0, 20).unwrap();
        assert!(m.is_active(0));
        assert_eq!(m.len(0), 20);
        assert!(m.advance(0, 4));
        assert_eq!(m.len(0), 24);
        m.release(0);
        assert!(!m.is_active(0));
        assert_eq!(m.cache_len_i32(), vec![1, 1]);
    }

    #[test]
    fn rejects_oversized_prompt() {
        let mut m = SlotManager::new(1, 32, 6);
        assert!(m.claim(0, 27).is_err());
        assert!(m.claim(0, 26).is_ok());
    }

    #[test]
    fn rejects_double_claim() {
        let mut m = SlotManager::new(1, 64, 6);
        m.claim(0, 8).unwrap();
        assert!(m.claim(0, 8).is_err());
    }

    #[test]
    fn advance_signals_capacity() {
        let mut m = SlotManager::new(1, 32, 6);
        m.claim(0, 20).unwrap();
        assert!(m.advance(0, 6)); // 26 + 6 = 32 <= 32 ✓
        assert!(!m.advance(0, 1)); // 27 + 6 > 32
    }

    #[test]
    fn paged_accounting() {
        let mut m = SlotManager::new(2, 64, 6);
        m.claim(0, 17).unwrap(); // 2 blocks
        m.claim(1, 16).unwrap(); // 1 block
        assert_eq!(m.blocks_used(), 3);
        assert_eq!(m.blocks_total(), 8);
        assert!((m.utilization() - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn spec_commit_advances_by_kept_prefix() {
        let mut m = SlotManager::new(2, 64, 6);
        m.claim(0, 20).unwrap();
        m.begin_spec(0);
        assert!(m.is_specing(0));
        // scratch region is invisible until committed
        assert_eq!(m.len(0), 20);
        assert_eq!(m.cache_len_i32(), vec![20, 1]);
        assert!(m.commit_spec(0, 4));
        assert!(!m.is_specing(0));
        assert_eq!(m.len(0), 24);
    }

    #[test]
    fn spec_rollback_commits_nothing() {
        let mut m = SlotManager::new(1, 64, 6);
        m.claim(0, 20).unwrap();
        m.begin_spec(0);
        m.rollback_spec(0);
        assert!(!m.is_specing(0));
        assert_eq!(m.len(0), 20);
        // the slot is immediately reusable for the next chunk
        m.begin_spec(0);
        assert!(m.commit_spec(0, 6));
        assert_eq!(m.len(0), 26);
    }

    #[test]
    fn spec_commit_signals_capacity_like_advance() {
        let mut m = SlotManager::new(1, 32, 6);
        m.claim(0, 20).unwrap();
        m.begin_spec(0);
        assert!(m.commit_spec(0, 6)); // 26 + 6 = 32 <= 32 ✓
        m.begin_spec(0);
        assert!(!m.commit_spec(0, 1)); // 27 + 6 > 32
    }

    #[test]
    fn release_clears_open_speculation() {
        let mut m = SlotManager::new(1, 64, 6);
        m.claim(0, 8).unwrap();
        m.begin_spec(0);
        m.release(0);
        assert!(!m.is_specing(0));
        // a fresh claim starts with a clean scratch lifecycle
        m.claim(0, 8).unwrap();
        m.begin_spec(0);
        assert!(m.commit_spec(0, 2));
    }

    #[test]
    fn capacity_invariant_property() {
        // a slot that claims + advances while advance() returns true can
        // always fit one more chunk write
        check("kv-capacity", 100, |rng| {
            let s_max = 16 + rng.below(240);
            let chunk = 2 + rng.below(8);
            let mut m = SlotManager::new(1, s_max, chunk);
            let prompt = 1 + rng.below(s_max);
            if m.claim(0, prompt).is_err() {
                return Case::Pass; // correctly rejected
            }
            loop {
                if m.len(0) + chunk > s_max {
                    return Case::Fail {
                        desc: format!("len {} + chunk {chunk} > {s_max}", m.len(0)),
                        size: s_max,
                    };
                }
                let emitted = 1 + rng.below(chunk);
                if !m.advance(0, emitted) {
                    return Case::Pass;
                }
            }
        });
    }

    // --- paged allocator ---------------------------------------------------

    fn paged(batch: usize, s_max: usize, chunk: usize, bs: usize, cap: usize) -> SlotManager {
        SlotManager::new_paged(batch, s_max, chunk, bs, cap)
    }

    #[test]
    fn paged_claim_takes_covering_blocks() {
        let mut m = paged(2, 64, 6, 16, 8);
        m.claim(0, 20).unwrap(); // 20 + 6 = 26 -> 2 blocks
        assert_eq!(m.table(0).len(), 2);
        assert_eq!(m.blocks_used(), 2);
        assert_eq!(m.free_blocks(), 6);
        // block ids are 1-based (0 is the null block), handed out ascending
        assert_eq!(m.table(0), &[1, 2]);
    }

    #[test]
    fn paged_claim_refuses_without_free_blocks() {
        let mut m = paged(2, 64, 6, 16, 2);
        m.claim(0, 20).unwrap(); // takes both blocks
        let err = m.claim(1, 20).unwrap_err();
        assert!(err.contains("KV blocks"), "undescriptive error: {err}");
        assert!(!m.can_admit(20));
        assert!(m.request_fits(20)); // fits capacity, just not right now
        m.release(0);
        assert!(m.can_admit(20));
    }

    #[test]
    fn paged_commit_extends_coverage_and_signals_exhaustion() {
        // bs 4, capacity 5: prompt 6 + chunk 3 -> 3 blocks at claim
        let mut m = paged(1, 32, 3, 4, 5);
        m.claim(0, 6).unwrap();
        assert_eq!(m.table(0).len(), 3);
        // len 6 -> 9: need ceil(12/4) = 3 blocks, still covered
        assert!(m.advance(0, 3));
        assert_eq!(m.table(0).len(), 3);
        // len 9 -> 12: need ceil(15/4) = 4, takes one more
        assert!(m.advance(0, 3));
        assert_eq!(m.table(0).len(), 4);
        // len 12 -> 15: need ceil(18/4) = 5, takes the last
        assert!(m.advance(0, 3));
        assert_eq!(m.table(0).len(), 5);
        assert_eq!(m.free_blocks(), 0);
        // len 15 -> 18: need 6 blocks, free list empty -> CacheFull signal
        assert!(!m.advance(0, 3));
    }

    #[test]
    fn paged_release_is_idempotent() {
        let mut m = paged(1, 64, 6, 16, 4);
        m.claim(0, 20).unwrap();
        assert_eq!(m.free_blocks(), 2);
        m.release(0);
        assert_eq!(m.free_blocks(), 4);
        m.release(0); // second release must not double-free
        assert_eq!(m.free_blocks(), 4);
        assert_eq!(m.blocks_used(), 0);
    }

    #[test]
    fn paged_block_table_pads_with_null_block() {
        let mut m = paged(2, 64, 6, 16, 8); // table width 4 per slot
        m.claim(1, 20).unwrap(); // 2 blocks
        let t = m.block_table_i32();
        assert_eq!(t.len(), 8);
        assert_eq!(&t[..4], &[0, 0, 0, 0], "inactive row must be all null");
        assert_eq!(&t[4..6], &[1, 2]);
        assert_eq!(&t[6..], &[0, 0], "unused entries must be null");
        assert!(t.iter().all(|&b| b >= 0));
    }

    #[test]
    fn paged_swap_blocks_rewires_table() {
        let mut m = paged(1, 64, 6, 16, 4);
        m.claim(0, 40).unwrap(); // 40 + 6 -> 3 blocks [1, 2, 3]
        m.swap_blocks(0, 1, 2);
        assert_eq!(m.table(0), &[1, 3, 2]);
        // swapped tables release cleanly
        m.release(0);
        assert_eq!(m.free_blocks(), 4);
    }

    #[test]
    fn write_width_defaults_to_chunk_and_rejects_narrowing() {
        let m = SlotManager::new(1, 64, 6);
        assert_eq!(m.write_width(), 6);
        let m = SlotManager::new(1, 64, 6).with_write_width(14);
        assert_eq!(m.write_width(), 14);
        assert_eq!(m.chunk, 6);
    }

    #[test]
    #[should_panic(expected = "below commit chunk")]
    fn write_width_below_chunk_panics() {
        let _ = SlotManager::new(1, 64, 6).with_write_width(5);
    }

    #[test]
    fn write_width_governs_the_s_max_fit() {
        // dynamic tree mode: commits at most chunk=4 positions per step but
        // physically scatters 9 — the fit checks must use the wider value or
        // the dense scatter would clamp into committed cache
        let mut m = SlotManager::new(1, 32, 4).with_write_width(9);
        assert!(m.claim(0, 24).is_err()); // 24 + 9 > 32
        m.claim(0, 23).unwrap(); // 23 + 9 == 32 ✓
        m.begin_spec(0);
        assert!(!m.commit_spec(0, 1), "24 + 9 > 32 must signal CacheFull");
        m.release(0);
        assert!(!m.request_fits(24));
        assert!(m.request_fits(23));
    }

    #[test]
    fn paged_charges_blocks_by_chunk_not_write_width() {
        // THE over-reservation regression: a dynamic engine with an 8-node
        // envelope but a 3-node budget must reserve blocks for budget+1=4
        // scratch positions, not envelope+1=9. bs=4: prompt 8 + chunk 4 ->
        // 3 blocks (charging by write width 9 would take 5).
        let mut m = SlotManager::new_paged(2, 64, 4, 4, 8).with_write_width(9);
        assert!(m.can_admit(8));
        m.claim(0, 8).unwrap();
        assert_eq!(m.table(0).len(), 3, "charged by envelope, not budget");
        // a second identical request still fits the remaining 5 blocks
        assert!(m.can_admit(8));
        m.claim(1, 8).unwrap();
        assert_eq!(m.blocks_used(), 6);
        // coverage invariant stays budget-denominated across commits
        m.begin_spec(0);
        assert!(m.commit_spec(0, 4));
        assert!(m.table(0).len() * 4 >= m.len(0) + m.chunk);
    }

    #[test]
    fn mixed_chunk_paged_admission_charges_per_slot() {
        // THE per-request-budget regression (satellite of the multi-drafter
        // PR): two slots claimed with different commit chunks in the same
        // pool must each be charged by their OWN chunk — coverage, admission
        // headroom, and commit growth all follow the slot, not an
        // engine-wide constant. bs=4, write width 10 (the widest policy's
        // scatter), default chunk 6.
        let mut m = SlotManager::new_paged(3, 64, 6, 4, 12).with_write_width(10);
        // slot 0: small-budget policy (chunk 4): prompt 8 + 4 -> 3 blocks
        m.claim_with_chunk(0, 8, 4).unwrap();
        assert_eq!(m.table(0).len(), 3);
        assert_eq!(m.chunk_of(0), 4);
        // slot 1: wide policy (chunk 9): prompt 8 + 9 -> 5 blocks
        m.claim_with_chunk(1, 8, 9).unwrap();
        assert_eq!(m.table(1).len(), 5, "wide slot charged by its own chunk");
        assert_eq!(m.blocks_used(), 8);
        // 4 blocks left: a wide (chunk-9) admission needs 5 and must refuse,
        // a chunk-4 one needs 3 and fits — headroom is policy-denominated
        assert!(!m.can_admit_chunk(8, 9));
        assert!(m.can_admit_chunk(8, 4));
        // commit growth keeps each slot's OWN coverage invariant
        m.begin_spec(0);
        assert!(m.commit_spec(0, 3)); // len 11, need ceil(15/4) = 4 blocks
        assert_eq!(m.table(0).len(), 4);
        assert!(m.table(0).len() * 4 >= m.len(0) + m.chunk_of(0));
        m.begin_spec(1);
        assert!(m.commit_spec(1, 9)); // len 17, need ceil(26/4) = 7 blocks
        assert_eq!(m.table(1).len(), 7);
        assert_eq!(m.free_blocks(), 1);
        // the last free block cannot host even a 1-token chunk-4 request
        let err = m.claim_with_chunk(2, 1, 4).unwrap_err();
        assert!(err.contains("KV blocks"), "undescriptive error: {err}");
        // release restores the default chunk for the next tenant
        m.release(1);
        m.claim(1, 8).unwrap();
        assert_eq!(m.chunk_of(1), 6);
    }

    #[test]
    fn claim_with_chunk_rejects_out_of_range_chunks() {
        let mut m = SlotManager::new(1, 64, 6).with_write_width(10);
        let err = m.claim_with_chunk(0, 8, 11).unwrap_err();
        assert!(err.contains("write width"), "undescriptive error: {err}");
        assert!(m.claim_with_chunk(0, 8, 0).is_err());
        m.claim_with_chunk(0, 8, 10).unwrap();
    }

    #[test]
    fn paged_allocator_never_leaks_or_double_assigns() {
        // The satellite property: under a randomized claim / spec-commit /
        // rollback / release interleaving across slots, (a) no block is ever
        // owned twice, (b) free ∪ owned is exactly the id range, (c)
        // blocks_used() == the sum of table lengths, and (d) every active
        // slot keeps its len+chunk coverage reservation.
        check("paged-allocator", 150, |rng| {
            let bs = 1 + rng.below(8);
            let blocks_per_slot = 2 + rng.below(8);
            let s_max = bs * blocks_per_slot;
            let chunk = 1 + rng.below(s_max.min(7));
            let batch = 1 + rng.below(4);
            let cap = 1 + rng.below(batch * blocks_per_slot + 3);
            let mut m = SlotManager::new_paged(batch, s_max, chunk, bs, cap);
            for step in 0..60 {
                let i = rng.below(batch);
                match rng.below(5) {
                    0 => {
                        if !m.is_active(i) {
                            let _ = m.claim(i, 1 + rng.below(s_max));
                        }
                    }
                    1 => {
                        if m.is_active(i) && !m.is_specing(i) {
                            m.begin_spec(i);
                        }
                    }
                    2 => {
                        if m.is_specing(i) {
                            if !m.commit_spec(i, rng.below(chunk + 1)) {
                                m.release(i); // the engine evicts on CacheFull
                            }
                        }
                    }
                    3 => {
                        if m.is_specing(i) {
                            m.rollback_spec(i);
                        }
                    }
                    _ => m.release(i), // releases are legal (and idempotent) any time
                }
                // (a) + (b): free ∪ tables is a permutation of 1..=cap
                let mut seen = vec![false; cap + 1];
                let mut owned = 0usize;
                for s in 0..batch {
                    for &b in m.table(s) {
                        if b == 0 || b > cap || seen[b] {
                            return Case::Fail {
                                desc: format!("step {step}: block {b} double-assigned or out of range"),
                                size: cap,
                            };
                        }
                        seen[b] = true;
                        owned += 1;
                    }
                }
                if owned + m.free_blocks() != cap {
                    return Case::Fail {
                        desc: format!(
                            "step {step}: {} owned + {} free != capacity {cap} (leak or dup)",
                            owned,
                            m.free_blocks()
                        ),
                        size: cap,
                    };
                }
                // (c)
                if m.blocks_used() != owned {
                    return Case::Fail {
                        desc: format!("step {step}: blocks_used {} != table sum {owned}", m.blocks_used()),
                        size: cap,
                    };
                }
                // (d) coverage reservation for every live slot
                for s in 0..batch {
                    if m.is_active(s) && m.table(s).len() * bs < m.len(s) + chunk {
                        return Case::Fail {
                            desc: format!(
                                "step {step}: slot {s} coverage {} blocks < len {} + chunk {chunk}",
                                m.table(s).len(),
                                m.len(s)
                            ),
                            size: cap,
                        };
                    }
                }
            }
            Case::Pass
        });
    }

    #[test]
    fn paged_parity_with_dense_accounting_when_fully_provisioned() {
        // fully provisioned paged manager must accept/advance/refuse at
        // exactly the same points as the dense one (the engine-level
        // dense-vs-paged byte parity rests on this)
        check("paged-dense-lockstep", 100, |rng| {
            let bs = 1 + rng.below(8);
            let blocks_per_slot = 2 + rng.below(8);
            let s_max = bs * blocks_per_slot;
            let chunk = 1 + rng.below(s_max.min(7));
            let mut d = SlotManager::new(1, s_max, chunk);
            let mut p = SlotManager::new_paged(1, s_max, chunk, bs, blocks_per_slot);
            let prompt = 1 + rng.below(s_max);
            let (rd, rp) = (d.claim(0, prompt), p.claim(0, prompt));
            if rd.is_ok() != rp.is_ok() {
                return Case::Fail {
                    desc: format!("claim({prompt}) dense {rd:?} vs paged {rp:?}"),
                    size: s_max,
                };
            }
            if rd.is_err() {
                return Case::Pass;
            }
            for _ in 0..40 {
                let emitted = 1 + rng.below(chunk);
                let (ad, ap) = (d.advance(0, emitted), p.advance(0, emitted));
                if ad != ap || d.len(0) != p.len(0) {
                    return Case::Fail {
                        desc: format!(
                            "advance({emitted}): dense ({ad}, len {}) vs paged ({ap}, len {})",
                            d.len(0),
                            p.len(0)
                        ),
                        size: s_max,
                    };
                }
                if !ad {
                    return Case::Pass;
                }
            }
            Case::Pass
        });
    }
}
