//! Threaded serving front-end: a submission channel + a worker thread that
//! owns the ModelRuntime and drains the scheduler. This is the process
//! shape of the vLLM-style deployment — request producers never touch PJRT.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::Result;

use super::engine::EngineConfig;
use super::metrics::EngineMetrics;
use super::request::{RequestResult, RequestSpec};
use super::scheduler::Scheduler;
use crate::runtime::ModelRuntime;

pub enum ServerMsg {
    Submit(RequestSpec),
    /// Flush: run all queued requests, reply when drained.
    Drain,
    Shutdown,
}

pub struct ServerHandle {
    pub tx: mpsc::Sender<ServerMsg>,
    pub results_rx: mpsc::Receiver<RequestResult>,
    join: Option<JoinHandle<EngineMetrics>>,
}

impl ServerHandle {
    pub fn submit(&self, r: RequestSpec) {
        let _ = self.tx.send(ServerMsg::Submit(r));
    }

    pub fn drain(&self) {
        let _ = self.tx.send(ServerMsg::Drain);
    }

    /// Shut down and return the engine metrics.
    pub fn shutdown(mut self) -> EngineMetrics {
        let _ = self.tx.send(ServerMsg::Shutdown);
        self.join.take().map(|j| j.join().unwrap_or_default()).unwrap_or_default()
    }
}

/// Spawn the serving worker. `artifacts_root` is loaded inside the worker so
/// the PJRT client lives entirely on that thread.
pub fn spawn(artifacts_root: String, cfg: EngineConfig, buckets: Vec<usize>) -> Result<ServerHandle> {
    let (tx, rx) = mpsc::channel::<ServerMsg>();
    let (res_tx, results_rx) = mpsc::channel::<RequestResult>();
    let join = std::thread::Builder::new()
        .name("p-eagle-engine".into())
        .spawn(move || {
            let mut mr = match ModelRuntime::load(&artifacts_root) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("engine worker failed to load artifacts: {e:#}");
                    return EngineMetrics::default();
                }
            };
            let mut sched = Scheduler::new(cfg, buckets);
            loop {
                match rx.recv() {
                    Ok(ServerMsg::Submit(r)) => sched.submit(r),
                    Ok(ServerMsg::Drain) => {
                        if let Err(e) = sched.run_to_completion(&mut mr) {
                            eprintln!("engine error: {e:#}");
                        }
                        for r in sched.results.drain(..) {
                            let _ = res_tx.send(r);
                        }
                    }
                    Ok(ServerMsg::Shutdown) | Err(_) => break,
                }
            }
            // final drain on shutdown
            let _ = sched.run_to_completion(&mut mr);
            for r in sched.results.drain(..) {
                let _ = res_tx.send(r);
            }
            sched.metrics
        })?;
    Ok(ServerHandle { tx, results_rx, join: Some(join) })
}
