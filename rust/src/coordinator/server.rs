//! Threaded serving front-end: a submission channel + a worker thread that
//! owns the ModelRuntime and steps an `EngineCore`, streaming per-token and
//! per-request events back as they happen. This is the process shape of the
//! vLLM-style deployment — request producers never touch PJRT, and results
//! stream out at iteration granularity instead of batch drains.
//!
//! Startup is a ready/error handshake: `spawn()` only returns once the
//! worker has loaded the artifacts and compiled the engine executables, and
//! propagates any load failure as an error to the caller instead of a dead
//! channel.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::engine::{EngineConfig, EngineCore, EngineEvent};
use super::metrics::EngineMetrics;
use super::request::{Request, RequestResult};
use crate::runtime::ModelRuntime;

pub enum ServerMsg {
    Submit(Request),
    /// Abort a queued or in-flight request by id.
    Abort(u64),
    /// Finish everything in flight/queued, then stop the worker.
    Shutdown,
}

/// Streamed serving events, in engine emission order.
#[derive(Clone, Debug)]
pub enum ServerEvent {
    /// Request was admitted into KV slot `slot`.
    Admitted { id: u64, slot: usize },
    /// Tokens emitted for `id` this step (the streaming payload).
    Tokens { id: u64, tokens: Vec<i32> },
    /// Request finished (including aborts — see `RequestResult::finish`).
    Finished(RequestResult),
    /// A submission was rejected at validation (bad prompt length etc.).
    Rejected { id: u64, error: String },
    /// The engine hit a fatal error; the worker stops after sending this.
    EngineError(String),
}

pub struct ServerHandle {
    pub tx: mpsc::Sender<ServerMsg>,
    pub events_rx: mpsc::Receiver<ServerEvent>,
    join: Option<JoinHandle<EngineMetrics>>,
}

impl ServerHandle {
    pub fn submit(&self, r: Request) {
        let _ = self.tx.send(ServerMsg::Submit(r));
    }

    pub fn abort(&self, id: u64) {
        let _ = self.tx.send(ServerMsg::Abort(id));
    }

    /// Finish outstanding work, shut down, and return the engine metrics.
    pub fn shutdown(mut self) -> EngineMetrics {
        let _ = self.tx.send(ServerMsg::Shutdown);
        self.join.take().map(|j| j.join().unwrap_or_default()).unwrap_or_default()
    }
}

/// Spawn the serving worker. `artifacts_root` is loaded inside the worker so
/// the PJRT client lives entirely on that thread; the engine runs at width
/// `cfg.batch`. Blocks until the worker is ready (artifacts loaded, engine
/// executables compiled) and returns its startup error if that fails.
pub fn spawn(artifacts_root: String, cfg: EngineConfig) -> Result<ServerHandle> {
    let (tx, rx) = mpsc::channel::<ServerMsg>();
    let (evt_tx, events_rx) = mpsc::channel::<ServerEvent>();
    let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
    let join = std::thread::Builder::new()
        .name("p-eagle-engine".into())
        .spawn(move || {
            let (mut mr, mut core) = match ModelRuntime::load(&artifacts_root)
                .and_then(|mut mr| {
                    let core = EngineCore::new(&mut mr, cfg)?;
                    Ok((mr, core))
                }) {
                Ok(v) => {
                    let _ = ready_tx.send(Ok(()));
                    v
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return EngineMetrics::default();
                }
            };

            let mut shutting_down = false;
            loop {
                // block for work only when idle; otherwise poll between steps
                if core.is_idle() {
                    if shutting_down {
                        break;
                    }
                    match rx.recv() {
                        Ok(m) => handle(&mut core, m, &evt_tx, &mut shutting_down),
                        Err(_) => break,
                    }
                }
                while let Ok(m) = rx.try_recv() {
                    handle(&mut core, m, &evt_tx, &mut shutting_down);
                }
                if core.is_idle() {
                    continue;
                }
                let t_step = std::time::Instant::now();
                match core.step(&mut mr) {
                    Ok(report) => {
                        core.metrics.wall_time += t_step.elapsed();
                        for ev in report.events {
                            let _ = evt_tx.send(match ev {
                                EngineEvent::Admitted { id, slot } => {
                                    ServerEvent::Admitted { id, slot }
                                }
                                EngineEvent::Tokens { id, tokens } => {
                                    ServerEvent::Tokens { id, tokens }
                                }
                                EngineEvent::Finished(r) => ServerEvent::Finished(r),
                            });
                        }
                    }
                    Err(e) => {
                        let _ = evt_tx.send(ServerEvent::EngineError(format!("{e:#}")));
                        break;
                    }
                }
            }
            core.into_metrics()
        })?;

    match ready_rx.recv() {
        Ok(Ok(())) => Ok(ServerHandle { tx, events_rx, join: Some(join) }),
        Ok(Err(msg)) => {
            let _ = join.join();
            Err(anyhow!("engine worker failed to start: {msg}"))
        }
        Err(_) => {
            let _ = join.join();
            Err(anyhow!("engine worker died before signalling readiness"))
        }
    }
}

fn handle(
    core: &mut EngineCore,
    msg: ServerMsg,
    evt_tx: &mpsc::Sender<ServerEvent>,
    shutting_down: &mut bool,
) {
    match msg {
        ServerMsg::Submit(r) => {
            let id = r.id;
            if let Err(e) = core.add_request(r) {
                let _ = evt_tx.send(ServerEvent::Rejected { id, error: format!("{e:#}") });
            }
        }
        ServerMsg::Abort(id) => {
            if let Some(res) = core.abort(id) {
                let _ = evt_tx.send(ServerEvent::Finished(res));
            }
        }
        ServerMsg::Shutdown => *shutting_down = true,
    }
}
