//! Adaptive speculation controller: the sense → decide → act layer that
//! closes the loop between live engine signal and the speculation policy
//! surface (drafter × chain/tree/dynamic shape × node budget).
//!
//! The paper's speedups hold only while verify FLOPs don't crowd out batch
//! throughput — at saturated occupancy, speculation must throttle itself
//! toward plain decoding (the Meta-at-scale observation), and EAGLE-3
//! motivates steering node budgets by *observed* acceptance instead of
//! static config. Every actuator already exists in this engine: the
//! policy-keyed executable registry (choose among the allowlist probed at
//! `EngineCore::new`), and the `Dynamic` node budget (deliberately excluded
//! from [`SpecPolicy::exec_key`], so per-step budget moves need no new
//! executables). This module adds the missing half — the sensing and the
//! decision:
//!
//! * **Sense** — [`SpecController::observe`] snapshots the engine's
//!   cumulative [`EngineMetrics`] each step and pushes *per-step deltas*
//!   through the windowed primitives in [`crate::util::stats`] ([`Ewma`]
//!   over slot/block occupancy and admission pressure, a [`RingWindow`] +
//!   per-policy EWMAs over acceptance length). Cumulative counters are
//!   useless to a control loop; windows are what it acts on.
//! * **Decide** — [`decide`] is a PURE function of
//!   ([`ControllerConfig`], [`Signals`]): no engine state, no clock, no
//!   randomness. Hysteresis lives in the `Signals` snapshot itself
//!   (breach-streak counters and an action cooldown maintained by
//!   `observe`), so single-step blips provably cannot flap a decision and
//!   the whole policy is unit-testable without an engine.
//! * **Act** — [`SpecController::assign`] gives each incoming request its
//!   [`SpecPolicy`] at admission (the policy is FIXED for the request's
//!   lifetime); [`SpecController::budget_target`] re-tunes in-flight
//!   `Dynamic` budgets per step. The throttle ladder degrades
//!   `Dynamic → Tree → Chain → Off` as occupancy saturates, where `Off` is
//!   the cheapest allowlisted policy at the minimum node budget (a literal
//!   k=0 chain has no lowered executables — see `SpecPolicy::validate`).
//!
//! # Invariants (ARCHITECTURE.md "Adaptive speculation")
//!
//! * A request's policy (drafter, shape, executables) never changes after
//!   admission — only `Dynamic` budgets move in flight.
//! * In-flight budget moves stay within `[budget_min, admitted budget]`:
//!   never above the commit width the slot's KV chunk was claimed for at
//!   admission, so allocator accounting and the scheduler's admission floor
//!   can never go stale upward.

use std::collections::BTreeMap;

use crate::util::stats::{Ewma, RingWindow};

use super::metrics::EngineMetrics;
use super::request::SpecPolicy;

/// Tuning knobs for the controller. Defaults are deliberately conservative:
/// thresholds form a dead band (saturate well above relief, deep well above
/// shallow), and hysteresis + cooldown mean a decision needs sustained
/// evidence and decisions are rate-limited.
#[derive(Clone, Debug, PartialEq)]
pub struct ControllerConfig {
    /// EWMA half-life, in engine steps, for occupancy/pressure smoothing
    /// and the per-policy acceptance-length tracks
    pub half_life: f64,
    /// sliding-window capacity (steps) for the global AL window
    pub window: usize,
    /// smoothed slot/block occupancy at or above this → saturation breach
    pub saturate_occupancy: f64,
    /// smoothed occupancy at or below this (with no admission pressure) →
    /// relief breach; the (relief, saturate) gap is the dead band
    pub relief_occupancy: f64,
    /// windowed AL fraction of the current ceiling at or above this →
    /// deep-acceptance breach (the drafter is worth more nodes)
    pub deep_al_frac: f64,
    /// windowed AL fraction at or below this → shallow-acceptance breach
    pub shallow_al_frac: f64,
    /// consecutive breach steps required before a decision fires
    pub hysteresis_steps: usize,
    /// minimum steps between decisions (rate limit)
    pub cooldown_steps: usize,
    /// floor for dynamic node budgets (assignment and in-flight retunes)
    pub budget_min: usize,
    /// budget increment/decrement per decision
    pub budget_step: usize,
}

impl Default for ControllerConfig {
    fn default() -> ControllerConfig {
        ControllerConfig {
            half_life: 8.0,
            window: 32,
            saturate_occupancy: 0.90,
            relief_occupancy: 0.55,
            deep_al_frac: 0.60,
            shallow_al_frac: 0.25,
            hysteresis_steps: 3,
            cooldown_steps: 6,
            budget_min: 2,
            budget_step: 2,
        }
    }
}

/// `PEAGLE_ADAPTIVE=1` (the CI adaptive job): run every engine with the
/// adaptive controller on at default tuning — same env-gating pattern as
/// `paged_from_env` and friends in [`super::engine`].
pub fn adaptive_from_env() -> Option<ControllerConfig> {
    (std::env::var("PEAGLE_ADAPTIVE").ok().as_deref() == Some("1"))
        .then(ControllerConfig::default)
}

/// One rung of the throttle ladder, richest speculation first. `Off` is the
/// terminal degrade: the cheapest allowlisted policy at the minimum node
/// budget (k=0 is not a lowered executable shape, so "stop speculating"
/// means "spend as little verify width as the allowlist permits").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Dynamic,
    Tree,
    Chain,
    Off,
}

impl Tier {
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Dynamic => "dyn",
            Tier::Tree => "tree",
            Tier::Chain => "chain",
            Tier::Off => "off",
        }
    }
}

/// What [`decide`] can tell the engine to do. Tier moves redirect FUTURE
/// admissions only; budget moves also re-tune in-flight `Dynamic` slots
/// (within each slot's admitted cap).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    Hold,
    /// degrade one ladder rung (Dynamic → Tree → Chain → Off)
    ThrottleDown,
    /// recover one ladder rung
    ThrottleUp,
    /// raise the dynamic node-budget target by `budget_step`
    BudgetUp,
    /// lower the dynamic node-budget target by `budget_step`
    BudgetDown,
}

/// A pure snapshot of everything [`decide`] is allowed to look at. The
/// controller maintains it in [`SpecController::observe`]; tests construct
/// it directly. Hysteresis state (streaks, cooldown) is IN the snapshot so
/// the decision function itself stays stateless.
#[derive(Clone, Debug, Default)]
pub struct Signals {
    /// smoothed slot occupancy (None until the first step — cold start)
    pub occupancy: Option<f64>,
    /// smoothed paged block occupancy (None in dense mode)
    pub block_occupancy: Option<f64>,
    /// smoothed admissions-blocked-per-step (paged admission pressure)
    pub admission_pressure: Option<f64>,
    /// windowed acceptance length as a fraction of the current tier's
    /// AL ceiling (None until a live iteration lands in the window)
    pub al_frac: Option<f64>,
    /// consecutive steps the saturation predicate held
    pub saturate_streak: usize,
    /// consecutive steps the relief predicate held
    pub relief_streak: usize,
    /// consecutive steps the deep-acceptance predicate held
    pub deep_streak: usize,
    /// consecutive steps the shallow-acceptance predicate held
    pub shallow_streak: usize,
    /// steps since the last non-`Hold` decision
    pub cooldown: usize,
    /// ladder room below the current tier
    pub can_throttle_down: bool,
    /// ladder room above the current tier
    pub can_throttle_up: bool,
    /// current tier assigns `Dynamic` policies and the budget target is
    /// below its ceiling
    pub can_budget_up: bool,
    /// current tier assigns `Dynamic` policies and the budget target is
    /// above `budget_min`
    pub can_budget_down: bool,
}

/// THE decision function — pure in (config, signals), no engine state.
///
/// Priority order: saturation (protect batch throughput) beats relief
/// (recover speculation) beats acceptance-driven budget tuning. Every arm
/// requires its breach streak to reach `hysteresis_steps` AND the cooldown
/// to have expired, so a single-step signal blip can never flap a decision.
/// Under saturation the response ratchets: shrink dynamic budgets first
/// (mild, keeps the executables), drop a ladder rung once budgets are
/// floored.
pub fn decide(cfg: &ControllerConfig, s: &Signals) -> Action {
    if s.cooldown < cfg.cooldown_steps {
        return Action::Hold;
    }
    let h = cfg.hysteresis_steps.max(1);
    if s.saturate_streak >= h {
        if s.can_budget_down {
            return Action::BudgetDown;
        }
        if s.can_throttle_down {
            return Action::ThrottleDown;
        }
        return Action::Hold;
    }
    if s.relief_streak >= h && s.can_throttle_up {
        return Action::ThrottleUp;
    }
    if s.deep_streak >= h && s.can_budget_up {
        return Action::BudgetUp;
    }
    if s.shallow_streak >= h && s.can_budget_down {
        return Action::BudgetDown;
    }
    Action::Hold
}

/// Cumulative-counter snapshot from the previous `observe` — what turns the
/// engine's monotone metrics into per-step deltas.
#[derive(Clone, Debug, Default)]
struct Snapshot {
    slot_occupied: usize,
    slot_total: usize,
    block_used: usize,
    block_total: usize,
    admissions_blocked: usize,
    /// per policy-identity: (iterations, accepted_sum)
    per_policy: BTreeMap<String, (usize, usize)>,
}

/// The controller subsystem: owns the windowed-signal layer and the ladder
/// position, hands the engine a policy per admission and a budget target
/// per step. Deterministic — same metrics sequence, same decisions.
#[derive(Clone, Debug)]
pub struct SpecController {
    cfg: ControllerConfig,
    /// the engine allowlist, default policy first (assignment candidates)
    candidates: Vec<SpecPolicy>,
    /// throttle ladder actually available given the allowlist: rungs in
    /// degrade order, each with the candidate indices it assigns from
    ladder: Vec<(Tier, Vec<usize>)>,
    tier_idx: usize,
    /// current dynamic node-budget target (assignment + in-flight retune)
    budget: usize,
    budget_max: usize,
    sig: Signals,
    occ: Ewma,
    block: Ewma,
    pressure: Ewma,
    al_window: RingWindow,
    /// windowed AL per policy identity (exec_key) — the drafter-choice signal
    per_policy_al: BTreeMap<String, Ewma>,
    prev: Snapshot,
    /// non-`Hold` decisions taken (observability)
    pub actions_taken: usize,
}

impl SpecController {
    /// Build from the engine's probed policy allowlist (`default` first —
    /// the cold-start assignment). Errors on an empty candidate list.
    pub fn new(cfg: ControllerConfig, candidates: Vec<SpecPolicy>) -> Result<SpecController, String> {
        if candidates.is_empty() {
            return Err("adaptive controller needs at least one allowlisted policy".into());
        }
        if cfg.budget_min == 0 || cfg.budget_step == 0 {
            return Err("adaptive controller: budget_min and budget_step must be >= 1".into());
        }
        if !(cfg.relief_occupancy < cfg.saturate_occupancy) {
            return Err(format!(
                "adaptive controller: relief occupancy {} must sit below saturate occupancy {}",
                cfg.relief_occupancy, cfg.saturate_occupancy
            ));
        }
        let mut ladder: Vec<(Tier, Vec<usize>)> = Vec::new();
        for tier in [Tier::Dynamic, Tier::Tree, Tier::Chain] {
            let idxs: Vec<usize> = candidates
                .iter()
                .enumerate()
                .filter(|(_, p)| p.mode_name() == tier.name())
                .map(|(i, _)| i)
                .collect();
            if !idxs.is_empty() {
                ladder.push((tier, idxs));
            }
        }
        // the terminal rung always exists: every candidate, assigned at the
        // cheapest commit width the ladder can reach
        ladder.push((Tier::Off, (0..candidates.len()).collect()));
        let budget_max = candidates
            .iter()
            .filter_map(|p| match p {
                SpecPolicy::Dynamic { envelope, .. } => Some(envelope.len()),
                _ => None,
            })
            .max()
            .unwrap_or(cfg.budget_min);
        let budget = candidates
            .iter()
            .filter_map(|p| match p {
                SpecPolicy::Dynamic { budget, .. } => Some(*budget),
                _ => None,
            })
            .max()
            .unwrap_or(cfg.budget_min)
            .clamp(cfg.budget_min.min(budget_max), budget_max);
        let sig = Signals { cooldown: cfg.cooldown_steps, ..Signals::default() };
        let occ = Ewma::with_half_life(cfg.half_life);
        let al_window = RingWindow::new(cfg.window);
        Ok(SpecController {
            candidates,
            ladder,
            tier_idx: 0,
            budget,
            budget_max,
            sig,
            block: occ.clone(),
            pressure: occ.clone(),
            occ,
            al_window,
            per_policy_al: BTreeMap::new(),
            prev: Snapshot::default(),
            actions_taken: 0,
            cfg,
        })
    }

    pub fn config(&self) -> &ControllerConfig {
        &self.cfg
    }

    /// The current `Signals` snapshot (what the next [`decide`] will see).
    pub fn signals(&self) -> &Signals {
        &self.sig
    }

    pub fn tier(&self) -> Tier {
        self.ladder[self.tier_idx].0
    }

    /// Current dynamic node-budget target. The engine clamps it per slot to
    /// `[budget_min, admitted budget]` when re-tuning in flight.
    pub fn budget_target(&self) -> usize {
        self.budget
    }

    /// Sense: fold one step's cumulative [`EngineMetrics`] into the
    /// windowed-signal layer and advance the hysteresis state.
    pub fn observe(&mut self, m: &EngineMetrics) {
        // per-step deltas of the cumulative counters
        let d_occ = m.slot_steps_occupied - self.prev.slot_occupied;
        let d_occ_total = m.slot_steps_total - self.prev.slot_total;
        if d_occ_total > 0 {
            self.occ.push(d_occ as f64 / d_occ_total as f64);
        }
        let d_blk = m.block_steps_used - self.prev.block_used;
        let d_blk_total = m.block_steps_total - self.prev.block_total;
        if d_blk_total > 0 {
            self.block.push(d_blk as f64 / d_blk_total as f64);
        }
        self.pressure
            .push((m.admissions_blocked - self.prev.admissions_blocked) as f64);
        let (mut d_iters, mut d_acc) = (0usize, 0usize);
        for (key, pm) in &m.per_policy {
            let (pi, pa) = self.prev.per_policy.get(key).copied().unwrap_or((0, 0));
            let (di, da) = (pm.iterations - pi, pm.accepted_sum - pa);
            d_iters += di;
            d_acc += da;
            if di > 0 {
                self.per_policy_al
                    .entry(key.clone())
                    .or_insert_with(|| Ewma::with_half_life(self.cfg.half_life))
                    .push(da as f64 / di as f64);
            }
        }
        if d_iters > 0 {
            self.al_window.push(d_acc as f64 / d_iters as f64);
        }
        self.prev = Snapshot {
            slot_occupied: m.slot_steps_occupied,
            slot_total: m.slot_steps_total,
            block_used: m.block_steps_used,
            block_total: m.block_steps_total,
            admissions_blocked: m.admissions_blocked,
            per_policy: m
                .per_policy
                .iter()
                .map(|(k, pm)| (k.clone(), (pm.iterations, pm.accepted_sum)))
                .collect(),
        };

        // refresh the snapshot decide() sees
        self.sig.occupancy = self.occ.value();
        self.sig.block_occupancy = self.block.value();
        self.sig.admission_pressure = self.pressure.value();
        self.sig.al_frac = self
            .al_window
            .mean()
            .map(|al| al / self.al_ceiling() as f64);
        self.sig.cooldown = self.sig.cooldown.saturating_add(1);

        // breach streaks: saturation when EITHER occupancy view crosses the
        // high threshold or paged admission is visibly blocking; relief when
        // everything sits below the low threshold. The band between resets
        // both — that dead band plus the streaks is the hysteresis.
        let occ = self.sig.occupancy.unwrap_or(0.0);
        let blk = self.sig.block_occupancy.unwrap_or(0.0);
        let press = self.sig.admission_pressure.unwrap_or(0.0);
        let saturated =
            occ >= self.cfg.saturate_occupancy || blk >= self.cfg.saturate_occupancy || press >= 0.5;
        let relieved = self.sig.occupancy.is_some()
            && occ <= self.cfg.relief_occupancy
            && blk <= self.cfg.relief_occupancy
            && press < 0.5;
        if saturated {
            self.sig.saturate_streak += 1;
            self.sig.relief_streak = 0;
        } else if relieved {
            self.sig.relief_streak += 1;
            self.sig.saturate_streak = 0;
        } else {
            self.sig.saturate_streak = 0;
            self.sig.relief_streak = 0;
        }
        match self.sig.al_frac {
            Some(f) if f >= self.cfg.deep_al_frac => {
                self.sig.deep_streak += 1;
                self.sig.shallow_streak = 0;
            }
            Some(f) if f <= self.cfg.shallow_al_frac => {
                self.sig.shallow_streak += 1;
                self.sig.deep_streak = 0;
            }
            _ => {
                self.sig.deep_streak = 0;
                self.sig.shallow_streak = 0;
            }
        }

        // actuator room, recomputed from the ladder position
        self.sig.can_throttle_down = self.tier_idx + 1 < self.ladder.len();
        self.sig.can_throttle_up = self.tier_idx > 0;
        let dyn_tier = self.tier() == Tier::Dynamic;
        self.sig.can_budget_up = dyn_tier && self.budget < self.budget_max;
        self.sig.can_budget_down = dyn_tier && self.budget > self.cfg.budget_min;
    }

    /// Sense + decide + act for one engine step: returns the decision taken
    /// (already applied to the ladder position / budget target).
    pub fn step(&mut self, m: &EngineMetrics) -> Action {
        self.observe(m);
        let action = decide(&self.cfg, &self.sig);
        self.apply(action);
        action
    }

    fn apply(&mut self, action: Action) {
        match action {
            Action::Hold => return,
            Action::ThrottleDown => self.tier_idx += 1,
            Action::ThrottleUp => self.tier_idx -= 1,
            Action::BudgetUp => {
                self.budget = (self.budget + self.cfg.budget_step).min(self.budget_max)
            }
            Action::BudgetDown => {
                self.budget = self
                    .budget
                    .saturating_sub(self.cfg.budget_step)
                    .max(self.cfg.budget_min.min(self.budget_max))
            }
        }
        self.actions_taken += 1;
        // a decision resets the evidence: the next one needs fresh streaks
        // AND a full cooldown
        self.sig.saturate_streak = 0;
        self.sig.relief_streak = 0;
        self.sig.deep_streak = 0;
        self.sig.shallow_streak = 0;
        self.sig.cooldown = 0;
    }

    /// Act (admission): the policy the controller assigns an incoming
    /// request right now. Cold start — no signal observed yet — is the
    /// engine default; otherwise the current tier's candidate with the best
    /// windowed AL (unseen candidates explore first, in allowlist order).
    /// The assigned policy is FIXED for the request's lifetime.
    pub fn assign(&self) -> SpecPolicy {
        if self.occ.is_empty() && self.al_window.is_empty() {
            return self.candidates[0].clone();
        }
        let (tier, idxs) = &self.ladder[self.tier_idx];
        if *tier == Tier::Off {
            // cheapest verified width the allowlist can spend, dynamic
            // budgets floored
            let i = idxs
                .iter()
                .copied()
                .min_by_key(|&i| self.min_commit_width_of(&self.candidates[i]))
                .expect("ladder rungs are non-empty");
            return self.with_budget(self.candidates[i].clone(), self.cfg.budget_min);
        }
        let mut best: Option<usize> = None;
        for &i in idxs {
            let key = self.candidates[i].exec_key();
            match self.per_policy_al.get(&key).and_then(Ewma::value) {
                // no signal for this candidate yet: explore it first
                None => return self.with_budget(self.candidates[i].clone(), self.budget),
                Some(al) => {
                    let better = match best {
                        None => true,
                        Some(b) => {
                            let bal = self.per_policy_al[&self.candidates[b].exec_key()]
                                .value()
                                .unwrap_or(0.0);
                            al > bal
                        }
                    };
                    if better {
                        best = Some(i);
                    }
                }
            }
        }
        let i = best.expect("ladder rungs are non-empty");
        self.with_budget(self.candidates[i].clone(), self.budget)
    }

    /// One-line state readout for serve/bench logs.
    pub fn summary(&self) -> String {
        format!(
            "tier={} budget={} actions={} occ={:.2} al_frac={:.2}",
            self.tier().name(),
            self.budget,
            self.actions_taken,
            self.sig.occupancy.unwrap_or(0.0),
            self.sig.al_frac.unwrap_or(0.0),
        )
    }

    /// Commit width of `p` with dynamic budgets floored — what the `Off`
    /// rung (and the scheduler's admission floor) costs a policy at.
    fn min_commit_width_of(&self, p: &SpecPolicy) -> usize {
        match p {
            SpecPolicy::Dynamic { envelope, budget, .. } => {
                self.cfg.budget_min.min(*budget).min(envelope.len()) + 1
            }
            _ => p.commit_width(),
        }
    }

    fn with_budget(&self, mut p: SpecPolicy, target: usize) -> SpecPolicy {
        if let SpecPolicy::Dynamic { envelope, budget, .. } = &mut p {
            *budget = target.clamp(self.cfg.budget_min.min(envelope.len()), envelope.len());
        }
        p
    }

    /// AL ceiling (accepted drafts + bonus) of the current tier's
    /// candidates at the current budget target — the denominator of
    /// `Signals::al_frac`.
    fn al_ceiling(&self) -> usize {
        let (_, idxs) = &self.ladder[self.tier_idx];
        idxs.iter()
            .map(|&i| match &self.candidates[i] {
                SpecPolicy::Dynamic { envelope, .. } => {
                    envelope.max_depth().min(self.budget.max(1))
                }
                p => p.al_max(),
            })
            .max()
            .unwrap_or(1)
            + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::masking::TreeTopology;

    fn cfg() -> ControllerConfig {
        ControllerConfig::default()
    }

    fn ready(streaks: impl Fn(&mut Signals)) -> Signals {
        let mut s = Signals { cooldown: cfg().cooldown_steps, ..Signals::default() };
        streaks(&mut s);
        s
    }

    // ---- decide(): the pure unit suite (no artifacts, no engine) ---------

    #[test]
    fn cold_start_holds() {
        // no signal, no streaks → Hold; admission-side cold start (default
        // policy) is covered in controller_cold_start_assigns_default
        let s = ready(|_| {});
        assert_eq!(decide(&cfg(), &s), Action::Hold);
    }

    #[test]
    fn saturation_throttles_down_the_ladder() {
        let s = ready(|s| {
            s.saturate_streak = cfg().hysteresis_steps;
            s.can_throttle_down = true;
        });
        assert_eq!(decide(&cfg(), &s), Action::ThrottleDown);
    }

    #[test]
    fn saturation_shrinks_budget_before_dropping_a_rung() {
        let s = ready(|s| {
            s.saturate_streak = cfg().hysteresis_steps;
            s.can_throttle_down = true;
            s.can_budget_down = true;
        });
        assert_eq!(decide(&cfg(), &s), Action::BudgetDown, "mild response first");
    }

    #[test]
    fn saturation_at_the_terminal_rung_holds() {
        let s = ready(|s| s.saturate_streak = 99);
        assert_eq!(decide(&cfg(), &s), Action::Hold, "no room left to degrade");
    }

    #[test]
    fn deep_acceptance_raises_the_budget() {
        let s = ready(|s| {
            s.deep_streak = cfg().hysteresis_steps;
            s.can_budget_up = true;
        });
        assert_eq!(decide(&cfg(), &s), Action::BudgetUp);
    }

    #[test]
    fn shallow_acceptance_lowers_the_budget() {
        let s = ready(|s| {
            s.shallow_streak = cfg().hysteresis_steps;
            s.can_budget_down = true;
        });
        assert_eq!(decide(&cfg(), &s), Action::BudgetDown);
    }

    #[test]
    fn relief_recovers_a_rung_and_outranks_budget_moves() {
        let s = ready(|s| {
            s.relief_streak = cfg().hysteresis_steps;
            s.deep_streak = cfg().hysteresis_steps;
            s.can_throttle_up = true;
            s.can_budget_up = true;
        });
        assert_eq!(decide(&cfg(), &s), Action::ThrottleUp);
    }

    #[test]
    fn saturation_outranks_everything() {
        let s = ready(|s| {
            s.saturate_streak = cfg().hysteresis_steps;
            s.relief_streak = cfg().hysteresis_steps; // impossible live, but priority is pinned
            s.deep_streak = cfg().hysteresis_steps;
            s.can_throttle_down = true;
            s.can_throttle_up = true;
            s.can_budget_up = true;
        });
        assert_eq!(decide(&cfg(), &s), Action::ThrottleDown);
    }

    #[test]
    fn hysteresis_a_single_step_blip_cannot_flap() {
        // one breach step < hysteresis_steps → Hold, every arm
        let c = cfg();
        assert!(c.hysteresis_steps > 1);
        for f in [
            (|s: &mut Signals| {
                s.saturate_streak = 1;
                s.can_throttle_down = true;
            }) as fn(&mut Signals),
            |s| {
                s.relief_streak = 1;
                s.can_throttle_up = true;
            },
            |s| {
                s.deep_streak = 1;
                s.can_budget_up = true;
            },
            |s| {
                s.shallow_streak = 1;
                s.can_budget_down = true;
            },
        ] {
            let s = ready(f);
            assert_eq!(decide(&c, &s), Action::Hold);
        }
    }

    #[test]
    fn cooldown_rate_limits_decisions() {
        let mut s = ready(|s| {
            s.saturate_streak = 99;
            s.can_throttle_down = true;
        });
        s.cooldown = cfg().cooldown_steps - 1;
        assert_eq!(decide(&cfg(), &s), Action::Hold, "cooldown not expired");
        s.cooldown = cfg().cooldown_steps;
        assert_eq!(decide(&cfg(), &s), Action::ThrottleDown);
    }

    #[test]
    fn decide_is_pure() {
        let s = ready(|s| {
            s.saturate_streak = cfg().hysteresis_steps;
            s.can_throttle_down = true;
        });
        let a = decide(&cfg(), &s);
        for _ in 0..3 {
            assert_eq!(decide(&cfg(), &s), a, "same snapshot, same decision");
        }
    }

    // ---- SpecController: deterministic closed-loop behavior --------------

    fn candidates() -> Vec<SpecPolicy> {
        vec![
            SpecPolicy::dynamic("pe", TreeTopology::from_widths(&[4, 4, 2, 2, 1]), 8),
            SpecPolicy::tree("pe", TreeTopology::from_widths(&[3, 2, 1, 1, 1])),
            SpecPolicy::chain("pe", 4),
            SpecPolicy::chain("ar", 5),
        ]
    }

    /// Drive `steps` controller steps over a synthetic metrics stream with
    /// the given per-step occupancy and AL.
    fn drive(ctl: &mut SpecController, m: &mut EngineMetrics, steps: usize, occ: (usize, usize), al: usize) {
        for _ in 0..steps {
            m.record_occupancy(occ.0, occ.1);
            m.policy_mut("pe/dyn:w4x4x2x2x1", 8).record_iteration(al, al.saturating_sub(1));
            ctl.step(m);
        }
    }

    #[test]
    fn controller_cold_start_assigns_default() {
        let ctl = SpecController::new(cfg(), candidates()).unwrap();
        assert_eq!(ctl.assign(), candidates()[0], "no signal yet → engine default");
        assert_eq!(ctl.tier(), Tier::Dynamic);
    }

    #[test]
    fn controller_rejects_empty_allowlist_and_bad_band() {
        assert!(SpecController::new(cfg(), vec![]).is_err());
        let bad = ControllerConfig { relief_occupancy: 0.95, ..cfg() };
        assert!(SpecController::new(bad, candidates()).is_err());
        let bad = ControllerConfig { budget_min: 0, ..cfg() };
        assert!(SpecController::new(bad, candidates()).is_err());
    }

    #[test]
    fn sustained_saturation_walks_down_the_ladder() {
        let c = cfg();
        let mut ctl = SpecController::new(c.clone(), candidates()).unwrap();
        let mut m = EngineMetrics::new(8);
        // saturated batch, decent AL: first responses shrink the budget to
        // the floor, then rungs drop dyn → tree → chain → off
        let enough = (c.hysteresis_steps + c.cooldown_steps) * 16;
        drive(&mut ctl, &mut m, enough, (4, 4), 3);
        assert_eq!(ctl.tier(), Tier::Off, "sustained saturation reaches the terminal rung");
        assert_eq!(ctl.budget_target(), c.budget_min);
        // terminal-rung assignment: the cheapest commit width in the
        // allowlist — the floored dyn policy commits at budget_min+1 = 3,
        // beating chain:4 (5), chain:5 (6), and the static tree (9)
        assert_eq!(
            ctl.assign(),
            SpecPolicy::dynamic("pe", TreeTopology::from_widths(&[4, 4, 2, 2, 1]), c.budget_min)
        );
    }

    #[test]
    fn relief_after_saturation_recovers_the_ladder() {
        let c = cfg();
        let mut ctl = SpecController::new(c.clone(), candidates()).unwrap();
        let mut m = EngineMetrics::new(8);
        let enough = (c.hysteresis_steps + c.cooldown_steps) * 16;
        drive(&mut ctl, &mut m, enough, (4, 4), 3);
        assert_eq!(ctl.tier(), Tier::Off);
        // idle batch at moderate AL → climbs back to the richest rung
        drive(&mut ctl, &mut m, enough, (1, 4), 3);
        assert_eq!(ctl.tier(), Tier::Dynamic);
    }

    #[test]
    fn deep_acceptance_raises_budget_until_the_envelope() {
        let c = cfg();
        let mut ctl = SpecController::new(c.clone(), candidates()).unwrap();
        let mut m = EngineMetrics::new(8);
        let b0 = ctl.budget_target();
        // comfortable occupancy, AL pinned at the ceiling → budget climbs
        drive(&mut ctl, &mut m, (c.hysteresis_steps + c.cooldown_steps) * 8, (3, 4), 6);
        assert!(ctl.budget_target() > b0, "deep acceptance must raise the budget");
        assert!(ctl.budget_target() <= 13, "never beyond the envelope node count");
        // and the raised budget shows up in fresh dynamic assignments
        match ctl.assign() {
            SpecPolicy::Dynamic { budget, .. } => assert_eq!(budget, ctl.budget_target()),
            p => panic!("expected a dynamic assignment, got {}", p.id()),
        }
    }

    #[test]
    fn single_blip_does_not_move_the_controller() {
        let c = cfg();
        let mut ctl = SpecController::new(c.clone(), candidates()).unwrap();
        let mut m = EngineMetrics::new(8);
        // settle into a calm steady state (middle occupancy, middle AL)
        drive(&mut ctl, &mut m, c.cooldown_steps * 4, (3, 4), 3);
        let (tier, budget, acted) = (ctl.tier(), ctl.budget_target(), ctl.actions_taken);
        // ONE saturated step, then calm again
        drive(&mut ctl, &mut m, 1, (4, 4), 3);
        drive(&mut ctl, &mut m, 1, (3, 4), 3);
        assert_eq!(ctl.tier(), tier);
        assert_eq!(ctl.budget_target(), budget);
        assert_eq!(ctl.actions_taken, acted, "a single-step blip must not decide");
    }

    #[test]
    fn assignment_prefers_the_best_windowed_al_and_explores_unseen() {
        let c = cfg();
        let mut ctl = SpecController::new(c.clone(), candidates()).unwrap();
        let mut m = EngineMetrics::new(8);
        // comfortable occupancy; only the dyn policy has signal so far —
        // with sustained saturation ruled out the tier stays Dynamic and the
        // single dyn candidate is both "unseen-explored" and best
        drive(&mut ctl, &mut m, 4, (2, 4), 4);
        let p = ctl.assign();
        assert_eq!(p.mode_name(), "dyn");
        assert_eq!(p.drafter(), "pe");
    }

    #[test]
    fn observe_is_delta_based_not_cumulative() {
        let c = cfg();
        let mut ctl = SpecController::new(c.clone(), candidates()).unwrap();
        let mut m = EngineMetrics::new(8);
        // two steps at 50% occupancy: the EWMA must read 0.5, not the
        // cumulative ratio of a growing counter pair
        drive(&mut ctl, &mut m, 2, (2, 4), 3);
        let occ = ctl.signals().occupancy.unwrap();
        assert!((occ - 0.5).abs() < 1e-9, "per-step delta occupancy, got {occ}");
        // AL window carries per-step AL (3), not a cumulative sum
        let al = ctl.al_window.mean().unwrap();
        assert!((al - 3.0).abs() < 1e-9, "windowed per-step AL, got {al}");
    }

    #[test]
    fn budget_clamps_respect_envelope_and_floor() {
        let ctl = SpecController::new(cfg(), candidates()).unwrap();
        let p = ctl.with_budget(candidates()[0].clone(), 99);
        match p {
            SpecPolicy::Dynamic { budget, .. } => assert_eq!(budget, 13, "envelope cap"),
            _ => unreachable!(),
        }
        let p = ctl.with_budget(candidates()[0].clone(), 0);
        match p {
            SpecPolicy::Dynamic { budget, .. } => {
                assert_eq!(budget, ctl.cfg.budget_min, "floor")
            }
            _ => unreachable!(),
        }
        // non-dynamic policies pass through untouched
        assert_eq!(ctl.with_budget(candidates()[2].clone(), 1), candidates()[2]);
    }

    #[test]
    fn chain_only_allowlist_has_a_two_rung_ladder() {
        let ctl =
            SpecController::new(cfg(), vec![SpecPolicy::chain("ar", 5)]).unwrap();
        assert_eq!(ctl.ladder.len(), 2, "chain + terminal off");
        assert_eq!(ctl.tier(), Tier::Chain);
    }

    #[test]
    fn env_gate_parses() {
        // covers the wiring contract, not the env itself (tests must not
        // mutate process env): absent/other values mean off
        assert!(adaptive_from_env().is_none() || std::env::var("PEAGLE_ADAPTIVE").as_deref() == Ok("1"));
    }
}
