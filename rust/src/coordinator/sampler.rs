//! Token sampling + the speculative acceptance rules (chain and tree).
//!
//! # Sampling modes and filters
//!
//! [`Sampling`] picks greedy (argmax) or temperature decoding;
//! [`SampleConfig`] adds the serving filters — top-k and nucleus (top-p) —
//! with **filtered-softmax** semantics: softmax the logits at the request's
//! temperature, apply top-k, then top-p, renormalize ([`filtered_probs`]).
//! Greedy never draws from the rng; a temperature draw consumes exactly ONE
//! `rng.f64()` ([`sample_filtered`]). The temperature floor (`t.max(1e-4)`)
//! exists only to keep the softmax finite: at `t -> 0` the filtered softmax
//! degenerates to a point mass at the argmax, so `Temperature(0.0)` emits
//! the argmax token — while still consuming its one draw, unlike `Greedy`
//! (tested below). [`argmax`] tie-breaking is FIRST MAX WINS (the lowest
//! index among equal maxima), also pinned by a directed test — the rejection
//! path leans on both edge behaviors.
//!
//! # Acceptance rules
//!
//! Two families, selected per request by the engine:
//!
//! * **Greedy** requests use the exact-match-on-argmax walk
//!   ([`accept_chain`] / [`accept_tree`] / [`accept_tree_subset`]): byte
//!   reproducible, zero rng draws, the paper's AL metric setting.
//! * **Temperature** requests use SpecInfer/EAGLE-style multi-branch
//!   **rejection sampling** ([`accept_chain_rejection`] /
//!   [`accept_tree_rejection`] / [`accept_tree_subset_rejection`]): at each
//!   node, try the drafted children in ascending slot order; child `d` is
//!   accepted with probability `min(1, p(d)/q(d))` where `p` is the
//!   filtered target distribution and `q` the draft proposal; on rejection
//!   the target residual `max(0, p - q)` is renormalized before the next
//!   sibling (and `q` is residualized without the tried token); if no child
//!   accepts, the correction token is sampled from the final residual, and
//!   at a leaf the bonus comes from the full filtered target row. One
//!   `rng.f64()` per TRIED child plus one draw for the stop token — the
//!   per-request rng stream contract the parity tests pin.
//!
//! The engine drafts **deterministically** (each node takes a fixed top-k
//! rank), so its proposal is a point mass: `q(d) = 1`, the acceptance
//! probability is `p(d)` itself, and the residual just zeroes the tried
//! token (`q_rows = None` below). That point-mass rule is exactly lossless
//! for deterministic drafts — and, notably, coincides IN DISTRIBUTION with
//! the old exact-match-on-sample rule (both emit every token from the
//! target conditional; they differ in rng consumption and in honoring the
//! request's top-k/top-p filters, which the old rule ignored). The general
//! `q_rows = Some(..)` form is the full SpecInfer rule for drafts SAMPLED
//! from a known per-node proposal; the statistical suite below proves it
//! lossless, and proves that misusing the drafter's model confidence as a
//! scalar `q` for deterministic drafts is biased — which is why the engine
//! threads drafter confidence into calibration metrics, never into
//! acceptance.
//!
//! [`accept_tree_subset_rejection`] is the base implementation; chain and
//! static tree delegate/mirror it, and a chain-shaped parent array
//! reproduces the chain rule token-for-token INCLUDING rng consumption
//! (property-tested below, extending the PR 2/4 parity pattern).

use crate::masking::TreeTopology;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    Greedy,
    Temperature(f32),
}

/// Full per-draw sampling configuration: mode plus the serving filters.
/// `top_p = 1.0` and `top_k = 0` disable the respective filter (the
/// defaults), which makes the temperature path byte-identical to the
/// unfiltered softmax sampler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleConfig {
    pub mode: Sampling,
    /// nucleus filter: keep the smallest top-probability prefix with
    /// cumulative mass >= top_p (always at least one token); 1.0 = off
    pub top_p: f32,
    /// keep only the top_k most probable tokens (ties keep the lowest
    /// index); 0 = off
    pub top_k: usize,
}

impl SampleConfig {
    pub fn greedy() -> SampleConfig {
        SampleConfig { mode: Sampling::Greedy, top_p: 1.0, top_k: 0 }
    }

    pub fn temperature(t: f32) -> SampleConfig {
        SampleConfig { mode: Sampling::Temperature(t), top_p: 1.0, top_k: 0 }
    }

    pub fn with_top_p(mut self, top_p: f32) -> SampleConfig {
        self.top_p = top_p;
        self
    }

    pub fn with_top_k(mut self, top_k: usize) -> SampleConfig {
        self.top_k = top_k;
        self
    }

    pub fn is_greedy(&self) -> bool {
        matches!(self.mode, Sampling::Greedy)
    }
}

/// Argmax over one logits row. Tie-breaking: FIRST max wins (the lowest
/// index among equal maxima) — `x > bv`, never `>=` — so greedy decode and
/// the `t -> 0` temperature limit agree bit-for-bit.
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in row.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

/// Sample a token from one logits row (unfiltered; kept for the legacy call
/// sites and the exact-match acceptance walk). The temperature floor
/// `t.max(1e-4)` keeps `(x - m)/t` finite; at the floor the softmax is a
/// point mass at the argmax, so `Temperature(0.0)` IS argmax — but it still
/// consumes its one categorical draw, unlike `Greedy` (tested below).
pub fn sample(row: &[f32], s: Sampling, rng: &mut Rng) -> i32 {
    match s {
        Sampling::Greedy => argmax(row),
        Sampling::Temperature(t) => {
            let t = t.max(1e-4);
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f32> = row.iter().map(|&x| ((x - m) / t).exp()).collect();
            rng.categorical(&weights) as i32
        }
    }
}

/// The filtered-softmax target distribution for one logits row: softmax at
/// the configured temperature, then top-k, then top-p, renormalized to sum
/// to 1. Greedy (and the `t -> 0` floor limit) degenerate to a point mass
/// at the argmax. This is the `p` (and `q`) every rejection-sampling rule
/// below scores against — the single source of the serving semantics for
/// `--temperature/--top-p/--top-k`.
pub fn filtered_probs(row: &[f32], cfg: &SampleConfig) -> Vec<f32> {
    let t = match cfg.mode {
        Sampling::Greedy => {
            let mut p = vec![0.0; row.len()];
            p[argmax(row) as usize] = 1.0;
            return p;
        }
        Sampling::Temperature(t) => t.max(1e-4),
    };
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut p: Vec<f32> = row.iter().map(|&x| ((x - m) / t).exp()).collect();
    let total: f32 = p.iter().sum();
    for x in p.iter_mut() {
        *x /= total;
    }
    // rank once (prob desc, index asc — deterministic under ties), shared by
    // both filters
    let needs_k = cfg.top_k > 0 && cfg.top_k < row.len();
    let needs_p = cfg.top_p > 0.0 && cfg.top_p < 1.0;
    if needs_k || needs_p {
        let mut order: Vec<usize> = (0..p.len()).collect();
        order.sort_by(|&a, &b| p[b].partial_cmp(&p[a]).unwrap().then(a.cmp(&b)));
        let mut keep = if needs_k { cfg.top_k } else { p.len() };
        if needs_p {
            let mut cum = 0.0f32;
            let mut nucleus = 0usize;
            for &i in order.iter().take(keep) {
                cum += p[i];
                nucleus += 1;
                if cum >= cfg.top_p {
                    break;
                }
            }
            keep = keep.min(nucleus.max(1));
        }
        for &i in order.iter().skip(keep) {
            p[i] = 0.0;
        }
        let total: f32 = p.iter().sum();
        if total > 0.0 {
            for x in p.iter_mut() {
                *x /= total;
            }
        }
    }
    p
}

/// Sample a token under the full [`SampleConfig`]: greedy = argmax (zero
/// rng draws); temperature = ONE categorical draw over [`filtered_probs`].
/// With the filters off this emits exactly what [`sample`] emits for the
/// same rng state (normalizing the weights does not move the categorical
/// walk), so default-parameter requests stay byte-identical.
pub fn sample_filtered(row: &[f32], cfg: &SampleConfig, rng: &mut Rng) -> i32 {
    match cfg.mode {
        Sampling::Greedy => argmax(row),
        Sampling::Temperature(_) => rng.categorical(&filtered_probs(row, cfg)) as i32,
    }
}

/// Renormalize `p` in place; if the mass vanished (float edge: residual of
/// a near-deterministic row), fall back to a point mass at the original
/// row's argmax — deterministic, never NaN.
fn renormalize(p: &mut [f32], fallback_row: &[f32]) {
    let total: f32 = p.iter().sum();
    if total > 0.0 && total.is_finite() {
        for x in p.iter_mut() {
            *x /= total;
        }
    } else {
        for x in p.iter_mut() {
            *x = 0.0;
        }
        p[argmax(fallback_row) as usize] = 1.0;
    }
}

/// Outcome of verifying one slot's draft chunk.
#[derive(Clone, Debug, PartialEq)]
pub struct Acceptance {
    /// number of draft tokens accepted (prefix match), 0..=K
    pub n_accepted: usize,
    /// tokens to emit this iteration: accepted drafts + 1 bonus token
    pub emitted: Vec<i32>,
}

/// Chain-drafting acceptance (exact-match walk — the greedy rule): target
/// logits row i is the distribution for the token *after* chunk position i.
/// Draft token `d[i]` is accepted while it matches the target's token for
/// that position; the first mismatch (or the end of the chain) contributes
/// the target's own token as the bonus.
pub fn accept_chain(
    drafts: &[i32],
    target_rows: &[&[f32]], // K+1 rows
    s: Sampling,
    rng: &mut Rng,
) -> Acceptance {
    assert_eq!(target_rows.len(), drafts.len() + 1);
    let mut emitted = Vec::with_capacity(drafts.len() + 1);
    let mut n_accepted = 0;
    for (i, &d) in drafts.iter().enumerate() {
        let t = sample(target_rows[i], s, rng);
        if d == t {
            emitted.push(d);
            n_accepted += 1;
        } else {
            emitted.push(t); // correction token from the target
            return Acceptance { n_accepted, emitted };
        }
    }
    // all drafts accepted: bonus token from the last target row
    emitted.push(sample(target_rows[drafts.len()], s, rng));
    Acceptance { n_accepted, emitted }
}

/// Outcome of verifying one slot's draft TREE.
#[derive(Clone, Debug, PartialEq)]
pub struct TreeAcceptance {
    /// accepted node ids, root-path order (ids ascend; empty if the first
    /// sampled target token matched no depth-1 node)
    pub accepted_path: Vec<usize>,
    /// tokens to emit this iteration: accepted path tokens + 1 bonus token
    pub emitted: Vec<i32>,
}

impl TreeAcceptance {
    pub fn n_accepted(&self) -> usize {
        self.accepted_path.len()
    }
}

/// Tree acceptance (exact-match walk): walk the longest accepted root path.
///
/// `drafts[i - 1]` is the token drafted at tree node `i`; `target_rows[j]`
/// (N+1 rows, chunk-slot order) is the target's distribution for the token
/// *after* chunk slot `j`. Starting at the root, sample the target's token
/// for the current node and descend into the child drafted with that exact
/// token; where no child matches (or at a leaf) the target's own sample is
/// emitted as the correction/bonus. Node tokens are distinct within a level
/// (the drafter assigns distinct top-k ranks), so at most one child can
/// match.
pub fn accept_tree(
    tree: &TreeTopology,
    drafts: &[i32],
    target_rows: &[&[f32]], // N+1 rows
    s: Sampling,
    rng: &mut Rng,
) -> TreeAcceptance {
    assert_eq!(drafts.len(), tree.len());
    let parents: Vec<usize> = (1..=tree.len()).map(|i| tree.parent(i)).collect();
    accept_tree_subset(&parents, drafts, target_rows, s, rng)
}

/// Tree acceptance (exact-match walk) over an arbitrary (compacted)
/// subtree, described by a parent array instead of a width-profile topology
/// — the dynamic-tree engine's acceptance rule
/// ([`crate::masking::dynamic`] compacts the per-step selected subtree into
/// slots `1..=m`, which is a valid level-major tree but not a round-robin
/// width profile).
///
/// `parents[i - 1]` is the chunk slot of node `i`'s parent (0 = root;
/// parents precede children); `drafts[i - 1]` its token; `target_rows` has
/// `parents.len() + 1` rows in chunk-slot order. Children are scanned in
/// ascending slot order, exactly like [`TreeTopology::children`], so
/// [`accept_tree`] (which delegates here) is unchanged token-for-token AND
/// rng-draw-for-rng-draw — and a chain-shaped parent array `[0, 1, 2, ..]`
/// reproduces [`accept_chain`] the same way (property-tested below).
pub fn accept_tree_subset(
    parents: &[usize],
    drafts: &[i32],
    target_rows: &[&[f32]], // parents.len() + 1 rows
    s: Sampling,
    rng: &mut Rng,
) -> TreeAcceptance {
    assert_eq!(drafts.len(), parents.len());
    assert_eq!(target_rows.len(), parents.len() + 1);
    debug_assert!(parents.iter().enumerate().all(|(i, &p)| p <= i), "parents must precede children");
    let mut accepted_path = Vec::new();
    let mut emitted = Vec::new();
    let mut cur = 0usize; // chunk slot of the current path head (0 = root)
    loop {
        let t = sample(target_rows[cur], s, rng);
        emitted.push(t);
        let next =
            (1..=parents.len()).find(|&c| parents[c - 1] == cur && drafts[c - 1] == t);
        match next {
            Some(c) => {
                accepted_path.push(c);
                cur = c;
            }
            // mismatch or leaf: the sampled token stands as correction/bonus
            None => return TreeAcceptance { accepted_path, emitted },
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-branch rejection sampling (temperature requests)
// ---------------------------------------------------------------------------

/// `min(1, p(d)/q(d))` for one drafted child: `q_cur = None` is the
/// point-mass proposal of deterministic drafting (`q(d) = 1`, ratio =
/// `p(d)`); an out-of-support `q(d) = 0` accepts iff the target gives the
/// token any mass at all.
fn accept_ratio(p: &[f32], q_cur: Option<&[f32]>, d: usize) -> f32 {
    let pd = p.get(d).copied().unwrap_or(0.0);
    match q_cur {
        None => pd,
        Some(q) => {
            let qd = q.get(d).copied().unwrap_or(0.0);
            if qd <= 0.0 {
                if pd > 0.0 {
                    1.0
                } else {
                    0.0
                }
            } else {
                (pd / qd).min(1.0)
            }
        }
    }
}

/// After rejecting child token `d`: target residual `p <- norm(max(0,
/// p - q))` and proposal residual `q <- norm(q \ {d})` (the next sibling
/// was drafted without replacement). Point-mass proposal (`q_cur = None`):
/// the residual just zeroes the tried token.
fn reject_residual(p: &mut [f32], q_cur: &mut Option<Vec<f32>>, d: usize, fallback_row: &[f32]) {
    match q_cur {
        Some(q) => {
            for (pi, qi) in p.iter_mut().zip(q.iter()) {
                *pi = (*pi - *qi).max(0.0);
            }
            if d < q.len() {
                q[d] = 0.0;
            }
            renormalize(q, fallback_row);
        }
        None => {
            if d < p.len() {
                p[d] = 0.0;
            }
        }
    }
    renormalize(p, fallback_row);
}

/// Chain rejection-sampling acceptance: the lossless temperature rule.
///
/// For each draft position i: `p` = filtered target row i, accept draft
/// `d` with probability `min(1, p(d)/q(d))` (one `rng.f64()` per tried
/// draft); on rejection sample the correction from the renormalized
/// residual (one more draw) and stop; after a full acceptance the bonus is
/// one [`sample_filtered`] draw from the last row. `q_rows = None` is the
/// deterministic-draft point-mass proposal (accept w.p. `p(d)`, residual
/// zeroes `d`); `q_rows = Some(..)` are per-position draft logits for
/// drafts actually SAMPLED from the proposal (filtered with the same
/// config). Token-for-token and draw-for-draw identical to
/// [`accept_tree_subset_rejection`] on a chain parent array
/// (property-tested below).
pub fn accept_chain_rejection(
    drafts: &[i32],
    target_rows: &[&[f32]], // K+1 rows
    q_rows: Option<&[&[f32]]>,
    cfg: &SampleConfig,
    rng: &mut Rng,
) -> Acceptance {
    assert_eq!(target_rows.len(), drafts.len() + 1);
    if let Some(q) = q_rows {
        assert_eq!(q.len(), target_rows.len());
    }
    let mut emitted = Vec::with_capacity(drafts.len() + 1);
    let mut n_accepted = 0;
    for (i, &dtok) in drafts.iter().enumerate() {
        let mut p = filtered_probs(target_rows[i], cfg);
        let mut q_cur = q_rows.map(|q| filtered_probs(q[i], cfg));
        let d = dtok as usize;
        if (rng.f64() as f32) < accept_ratio(&p, q_cur.as_deref(), d) {
            emitted.push(dtok);
            n_accepted += 1;
            continue;
        }
        reject_residual(&mut p, &mut q_cur, d, target_rows[i]);
        emitted.push(rng.categorical(&p) as i32); // correction from residual
        return Acceptance { n_accepted, emitted };
    }
    emitted.push(sample_filtered(target_rows[drafts.len()], cfg, rng)); // bonus
    Acceptance { n_accepted, emitted }
}

/// Tree rejection-sampling acceptance over a width-profile topology —
/// delegates to [`accept_tree_subset_rejection`] exactly like
/// [`accept_tree`] delegates to [`accept_tree_subset`].
pub fn accept_tree_rejection(
    tree: &TreeTopology,
    drafts: &[i32],
    target_rows: &[&[f32]], // N+1 rows
    q_rows: Option<&[&[f32]]>,
    cfg: &SampleConfig,
    rng: &mut Rng,
) -> TreeAcceptance {
    assert_eq!(drafts.len(), tree.len());
    let parents: Vec<usize> = (1..=tree.len()).map(|i| tree.parent(i)).collect();
    accept_tree_subset_rejection(&parents, drafts, target_rows, q_rows, cfg, rng)
}

/// Multi-branch rejection sampling over an arbitrary (compacted) subtree —
/// the base implementation every temperature acceptance inherits (chain,
/// static tree, and dynamic subsets, via the same delegation the
/// exact-match family uses).
///
/// At each node: `p` = filtered target row; the drafted children are tried
/// in ascending slot order, child `d` accepted with `min(1, p(d)/q(d))`
/// (one `rng.f64()` per tried child). On rejection the target residual
/// `max(0, p - q)` is renormalized and the proposal residualized before
/// the next sibling. If no child accepts, ONE categorical draw from the
/// final residual emits the correction; at a leaf the same draw over the
/// full filtered row emits the bonus. `q_rows = None` (the engine's
/// deterministic top-k drafting) is the point-mass proposal: acceptance
/// probability `p(d)`, residual zeroes `d` — provably lossless for
/// deterministic drafts, pinned by the statistical suite below.
pub fn accept_tree_subset_rejection(
    parents: &[usize],
    drafts: &[i32],
    target_rows: &[&[f32]], // parents.len() + 1 rows
    q_rows: Option<&[&[f32]]>,
    cfg: &SampleConfig,
    rng: &mut Rng,
) -> TreeAcceptance {
    assert_eq!(drafts.len(), parents.len());
    assert_eq!(target_rows.len(), parents.len() + 1);
    if let Some(q) = q_rows {
        assert_eq!(q.len(), target_rows.len());
    }
    debug_assert!(parents.iter().enumerate().all(|(i, &p)| p <= i), "parents must precede children");
    let mut accepted_path = Vec::new();
    let mut emitted = Vec::new();
    let mut cur = 0usize; // chunk slot of the current path head (0 = root)
    loop {
        let mut p = filtered_probs(target_rows[cur], cfg);
        let mut q_cur = q_rows.map(|q| filtered_probs(q[cur], cfg));
        let mut descended = false;
        for c in 1..=parents.len() {
            if parents[c - 1] != cur {
                continue;
            }
            let d = drafts[c - 1] as usize;
            if (rng.f64() as f32) < accept_ratio(&p, q_cur.as_deref(), d) {
                accepted_path.push(c);
                emitted.push(drafts[c - 1]);
                cur = c;
                descended = true;
                break;
            }
            reject_residual(&mut p, &mut q_cur, d, target_rows[cur]);
        }
        if !descended {
            // correction (some child tried) or bonus (leaf): one draw from
            // the residual — the full filtered row at a leaf
            emitted.push(rng.categorical(&p) as i32);
            return TreeAcceptance { accepted_path, emitted };
        }
    }
}

// ---------------------------------------------------------------------------
// Per-request dispatch (what the engine calls)
// ---------------------------------------------------------------------------

/// Engine dispatch: greedy requests keep the exact-match argmax walk (byte
/// identical to the pre-rejection engine, zero rng draws); temperature
/// requests use chain rejection sampling with the point-mass proposal
/// (deterministic engine drafts).
pub fn accept_chain_sampled(
    drafts: &[i32],
    target_rows: &[&[f32]],
    cfg: &SampleConfig,
    rng: &mut Rng,
) -> Acceptance {
    match cfg.mode {
        Sampling::Greedy => accept_chain(drafts, target_rows, Sampling::Greedy, rng),
        Sampling::Temperature(_) => accept_chain_rejection(drafts, target_rows, None, cfg, rng),
    }
}

/// Engine dispatch for static trees — see [`accept_chain_sampled`].
pub fn accept_tree_sampled(
    tree: &TreeTopology,
    drafts: &[i32],
    target_rows: &[&[f32]],
    cfg: &SampleConfig,
    rng: &mut Rng,
) -> TreeAcceptance {
    match cfg.mode {
        Sampling::Greedy => accept_tree(tree, drafts, target_rows, Sampling::Greedy, rng),
        Sampling::Temperature(_) => {
            accept_tree_rejection(tree, drafts, target_rows, None, cfg, rng)
        }
    }
}

/// Engine dispatch for dynamic (compacted-subset) trees — see
/// [`accept_chain_sampled`].
pub fn accept_tree_subset_sampled(
    parents: &[usize],
    drafts: &[i32],
    target_rows: &[&[f32]],
    cfg: &SampleConfig,
    rng: &mut Rng,
) -> TreeAcceptance {
    match cfg.mode {
        Sampling::Greedy => accept_tree_subset(parents, drafts, target_rows, Sampling::Greedy, rng),
        Sampling::Temperature(_) => {
            accept_tree_subset_rejection(parents, drafts, target_rows, None, cfg, rng)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{goodness_of_fit, GofReport};

    fn onehot(v: usize, n: usize) -> Vec<f32> {
        let mut row = vec![0.0; n];
        row[v] = 10.0;
        row
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn argmax_tie_breaking_first_max_wins() {
        // the rejection path's point-mass fallback leans on stable
        // tie-breaking: the LOWEST index among equal maxima, always
        assert_eq!(argmax(&[5.0, 5.0, 1.0]), 0);
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 5.0]), 1);
        assert_eq!(argmax(&[2.0, 2.0, 2.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        // and filtered_probs' greedy/t->0 point mass lands on the same index
        let cfg = SampleConfig::temperature(0.0);
        let p = filtered_probs(&[3.0, 7.0, 7.0], &cfg);
        assert_eq!(p, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn temperature_floor_t_to_zero_is_argmax_with_one_draw() {
        // t.max(1e-4) documents the t -> 0 limit: the softmax degenerates to
        // a point mass at the argmax, so Temperature(0.0) emits the argmax —
        // but unlike Greedy it still consumes exactly ONE rng draw
        let mut rows_rng = Rng::new(0xF100);
        for _ in 0..100 {
            let row: Vec<f32> =
                (0..12).map(|_| rows_rng.below(1000) as f32 / 100.0).collect();
            let mut rng = Rng::new(77);
            assert_eq!(sample(&row, Sampling::Temperature(0.0), &mut rng), argmax(&row));
            // one draw consumed: the state matches a control that drew once
            let mut control = Rng::new(77);
            control.f64();
            assert_eq!(rng.next_u64(), control.next_u64(), "t=0 must consume one draw");
            // greedy consumes zero
            let mut g = Rng::new(77);
            assert_eq!(sample(&row, Sampling::Greedy, &mut g), argmax(&row));
            assert_eq!(g.next_u64(), Rng::new(77).next_u64(), "greedy must consume none");
        }
    }

    #[test]
    fn filtered_probs_default_is_plain_softmax() {
        let row = vec![1.0, 2.0, 0.5, -1.0];
        let cfg = SampleConfig::temperature(0.7);
        let p = filtered_probs(&row, &cfg);
        let m = 2.0f32;
        let w: Vec<f32> = row.iter().map(|&x| ((x - m) / 0.7).exp()).collect();
        let tot: f32 = w.iter().sum();
        for (a, b) in p.iter().zip(w.iter()) {
            assert!((a - b / tot).abs() < 1e-6);
        }
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn filtered_probs_top_k_and_top_p_semantics() {
        // logits = ln(p) at t=1 give exact probabilities to filter
        let row: Vec<f32> = [0.4f32, 0.3, 0.2, 0.1].iter().map(|p| p.ln()).collect();
        let t1 = SampleConfig::temperature(1.0);

        let p = filtered_probs(&row, &t1.with_top_k(2));
        assert!((p[0] - 4.0 / 7.0).abs() < 1e-5);
        assert!((p[1] - 3.0 / 7.0).abs() < 1e-5);
        assert_eq!(&p[2..], &[0.0, 0.0]);

        // nucleus: smallest prefix with cumulative mass >= top_p
        let p = filtered_probs(&row, &t1.with_top_p(0.65));
        assert!(p[0] > 0.0 && p[1] > 0.0, "0.4 + 0.3 covers 0.65");
        assert_eq!(&p[2..], &[0.0, 0.0]);
        let p = filtered_probs(&row, &t1.with_top_p(0.4));
        assert_eq!(p, vec![1.0, 0.0, 0.0, 0.0], "0.4 alone covers 0.4");
        // always at least one token even for tiny top_p
        let p = filtered_probs(&row, &t1.with_top_p(1e-6));
        assert_eq!(p, vec![1.0, 0.0, 0.0, 0.0]);

        // top-k ties keep the LOWEST indices (deterministic)
        let p = filtered_probs(&[1.0, 1.0, 1.0, 1.0], &t1.with_top_k(2));
        assert_eq!(p, vec![0.5, 0.5, 0.0, 0.0]);

        // filters compose: top-k first, then top-p inside the survivors
        let p = filtered_probs(&row, &t1.with_top_k(3).with_top_p(0.45));
        assert!((p[0] - 4.0 / 7.0).abs() < 1e-5, "top-p 0.45 needs 0.4+0.3 of the top-3");
        assert!((p[1] - 3.0 / 7.0).abs() < 1e-5);
        assert_eq!(&p[2..], &[0.0, 0.0]);
    }

    #[test]
    fn sample_filtered_matches_sample_at_default_params() {
        // normalizing the softmax weights must not move the categorical walk
        let mut rows_rng = Rng::new(0xBEEF);
        for _ in 0..50 {
            let row: Vec<f32> =
                (0..10).map(|_| rows_rng.below(1000) as f32 / 100.0).collect();
            let seed = rows_rng.next_u64();
            let a = sample(&row, Sampling::Temperature(0.8), &mut Rng::new(seed));
            let b = sample_filtered(&row, &SampleConfig::temperature(0.8), &mut Rng::new(seed));
            assert_eq!(a, b);
        }
    }

    #[test]
    fn full_acceptance_adds_bonus() {
        let rows: Vec<Vec<f32>> =
            vec![onehot(4, 8), onehot(5, 8), onehot(6, 8), onehot(7, 8)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_chain(&[4, 5, 6], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.n_accepted, 3);
        assert_eq!(a.emitted, vec![4, 5, 6, 7]);
    }

    #[test]
    fn mismatch_truncates_with_correction() {
        let rows: Vec<Vec<f32>> = vec![onehot(4, 8), onehot(5, 8), onehot(6, 8)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_chain(&[4, 1], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.n_accepted, 1);
        assert_eq!(a.emitted, vec![4, 5]); // correction = target argmax
    }

    #[test]
    fn zero_acceptance_still_emits_one() {
        let rows: Vec<Vec<f32>> = vec![onehot(2, 8), onehot(3, 8)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_chain(&[7], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.n_accepted, 0);
        assert_eq!(a.emitted, vec![2]);
    }

    #[test]
    fn temperature_zeroish_matches_greedy() {
        let row = vec![0.0, 1.0, 8.0, 2.0];
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            assert_eq!(sample(&row, Sampling::Temperature(0.01), &mut rng), 2);
        }
    }

    #[test]
    fn tree_accepts_longest_matching_root_path() {
        // widths [2, 1]: nodes 1,2 at depth 1 (parents 0,0), node 3 at
        // depth 2 (parent 1). Target greedy path: 5 then 9.
        let t = TreeTopology::from_widths(&[2, 1]);
        let rows: Vec<Vec<f32>> =
            vec![onehot(5, 16), onehot(9, 16), onehot(7, 16), onehot(1, 16)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        // drafts: node1=4 (miss), node2=5 (hit via the rank-1 sibling!),
        // node3 is a child of node1 so it is off the accepted path
        let a = accept_tree(&t, &[4, 5, 3], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.accepted_path, vec![2]);
        // node 2's target row is rows[2] -> correction 7
        assert_eq!(a.emitted, vec![5, 7]);
    }

    #[test]
    fn tree_mismatch_everywhere_still_emits_one() {
        let t = TreeTopology::from_widths(&[3]);
        let rows: Vec<Vec<f32>> =
            vec![onehot(9, 16), onehot(1, 16), onehot(2, 16), onehot(3, 16)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_tree(&t, &[4, 5, 6], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.accepted_path, Vec::<usize>::new());
        assert_eq!(a.emitted, vec![9]);
    }

    #[test]
    fn tree_full_depth_adds_bonus_from_leaf_row() {
        let t = TreeTopology::from_widths(&[2, 2]);
        // accepted path 0 -> 2 -> 4 (node 4 is the depth-2 rank-0 child of
        // node 2 under round-robin? parents of 3,4 are 1,2 — so child of 2
        // is node 4). drafts: node2=6, node4=8.
        let mut rows = vec![onehot(6, 16); 5];
        rows[2] = onehot(8, 16); // after node 2, target wants 8
        rows[4] = onehot(3, 16); // after node 4: bonus 3
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_tree(&t, &[1, 6, 7, 8], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.accepted_path, vec![2, 4]);
        assert_eq!(a.emitted, vec![6, 8, 3]);
    }

    fn rand_rows(rng: &mut Rng, n: usize, vocab: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..vocab).map(|_| rng.below(1000) as f32 / 100.0).collect())
            .collect()
    }

    #[test]
    fn tree_chain_topology_matches_accept_chain_exactly() {
        // the degenerate chain tree must reproduce accept_chain
        // token-for-token, including rng consumption, for random logits and
        // random drafts under both sampling modes
        use crate::util::prop::{check, Case};
        check("tree-chain-parity", 120, |rng| {
            let k = 1 + rng.below(7);
            let vocab = 4 + rng.below(12);
            let rows = rand_rows(rng, k + 1, vocab);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            // drafts partially agree with the greedy path to exercise both
            // acceptance and mismatch branches
            let drafts: Vec<i32> = refs
                .iter()
                .take(k)
                .map(|r| {
                    if rng.below(2) == 0 {
                        argmax(r)
                    } else {
                        rng.below(vocab) as i32
                    }
                })
                .collect();
            let s = if rng.below(2) == 0 {
                Sampling::Greedy
            } else {
                Sampling::Temperature(0.7)
            };
            let seed = rng.next_u64();
            let chain = accept_chain(&drafts, &refs, s, &mut Rng::new(seed));
            let tree = accept_tree(
                &TreeTopology::chain(k),
                &drafts,
                &refs,
                s,
                &mut Rng::new(seed),
            );
            if tree.emitted != chain.emitted || tree.n_accepted() != chain.n_accepted {
                return Case::Fail {
                    desc: format!(
                        "k={k} chain {:?}/{} vs tree {:?}/{}",
                        chain.emitted,
                        chain.n_accepted,
                        tree.emitted,
                        tree.n_accepted()
                    ),
                    size: k,
                };
            }
            Case::Pass
        });
    }

    #[test]
    fn tree_accepted_path_is_always_a_root_prefix() {
        // whatever the logits and drafts, the accepted path must be a
        // connected root path: node m's parent is node m-1 of the path (or
        // the root), depths ascend 1,2,3,..., and emitted = path + bonus
        use crate::util::prop::{check, Case};
        check("tree-root-prefix", 120, |rng| {
            let levels = 1 + rng.below(4);
            let widths: Vec<usize> = (0..levels).map(|_| 1 + rng.below(3)).collect();
            let t = TreeTopology::from_widths(&widths);
            let vocab = 4 + rng.below(8);
            let rows = rand_rows(rng, t.len() + 1, vocab);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            // bias drafts toward the greedy continuation so paths get deep
            let drafts: Vec<i32> = (1..=t.len())
                .map(|_| {
                    if rng.below(3) == 0 {
                        rng.below(vocab) as i32
                    } else {
                        argmax(refs[rng.below(t.len() + 1)])
                    }
                })
                .collect();
            let a = accept_tree(&t, &drafts, &refs, Sampling::Greedy, &mut rng.clone());
            if a.emitted.len() != a.n_accepted() + 1 {
                return Case::Fail {
                    desc: format!("emitted {} != path {} + 1", a.emitted.len(), a.n_accepted()),
                    size: t.len(),
                };
            }
            let mut prev = 0usize;
            for (m, &node) in a.accepted_path.iter().enumerate() {
                if t.parent(node) != prev || t.depth(node) != m + 1 {
                    return Case::Fail {
                        desc: format!("path {:?} not a root prefix ({widths:?})", a.accepted_path),
                        size: t.len(),
                    };
                }
                if a.emitted[m] != drafts[node - 1] {
                    return Case::Fail {
                        desc: format!("emitted[{m}] != draft of node {node}"),
                        size: t.len(),
                    };
                }
                prev = node;
            }
            Case::Pass
        });
    }

    #[test]
    fn tree_subset_chain_prefix_matches_accept_chain_exactly() {
        // the dynamic-tree chain-equivalence satellite: selecting the first
        // b nodes of a chain envelope (what confidence selection always does
        // on a chain — one node per depth) must reproduce accept_chain over
        // the truncated draft, token-for-token INCLUDING rng consumption,
        // under both sampling modes
        use crate::util::prop::{check, Case};
        check("tree-subset-chain-parity", 120, |rng| {
            let k = 1 + rng.below(7);
            let b = 1 + rng.below(k); // selected chain prefix depth
            let vocab = 4 + rng.below(12);
            let rows = rand_rows(rng, b + 1, vocab);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let drafts: Vec<i32> = refs
                .iter()
                .take(b)
                .map(|r| {
                    if rng.below(2) == 0 {
                        argmax(r)
                    } else {
                        rng.below(vocab) as i32
                    }
                })
                .collect();
            let s = if rng.below(2) == 0 {
                Sampling::Greedy
            } else {
                Sampling::Temperature(0.7)
            };
            let seed = rng.next_u64();
            let chain = accept_chain(&drafts, &refs, s, &mut Rng::new(seed));
            let parents: Vec<usize> = (0..b).collect(); // compacted chain prefix
            let sub = accept_tree_subset(&parents, &drafts, &refs, s, &mut Rng::new(seed));
            if sub.emitted != chain.emitted || sub.n_accepted() != chain.n_accepted {
                return Case::Fail {
                    desc: format!(
                        "k={k} b={b} chain {:?}/{} vs subset {:?}/{}",
                        chain.emitted,
                        chain.n_accepted,
                        sub.emitted,
                        sub.n_accepted()
                    ),
                    size: k,
                };
            }
            Case::Pass
        });
    }

    #[test]
    fn tree_subset_full_selection_matches_accept_tree() {
        // degenerate selection (every node active) must be accept_tree
        // exactly — the identity relabeling changes nothing
        use crate::util::prop::{check, Case};
        check("tree-subset-full-parity", 100, |rng| {
            let levels = 1 + rng.below(4);
            let widths: Vec<usize> = (0..levels).map(|_| 1 + rng.below(3)).collect();
            let t = TreeTopology::from_widths(&widths);
            let vocab = 4 + rng.below(8);
            let rows = rand_rows(rng, t.len() + 1, vocab);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let drafts: Vec<i32> = (0..t.len())
                .map(|_| {
                    if rng.below(3) == 0 {
                        rng.below(vocab) as i32
                    } else {
                        argmax(refs[rng.below(t.len() + 1)])
                    }
                })
                .collect();
            let seed = rng.next_u64();
            let a = accept_tree(&t, &drafts, &refs, Sampling::Greedy, &mut Rng::new(seed));
            let parents: Vec<usize> = (1..=t.len()).map(|i| t.parent(i)).collect();
            let b = accept_tree_subset(
                &parents,
                &drafts,
                &refs,
                Sampling::Greedy,
                &mut Rng::new(seed),
            );
            if a.emitted != b.emitted || a.accepted_path != b.accepted_path {
                return Case::Fail {
                    desc: format!("{:?} vs {:?} ({widths:?})", a, b),
                    size: t.len(),
                };
            }
            Case::Pass
        });
    }

    #[test]
    fn al_equals_accepted_plus_one() {
        // paper convention: AL counts accepted drafts + bonus, max K+1
        let rows: Vec<Vec<f32>> = (0..6).map(|i| onehot(i, 8)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(3);
        let a = accept_chain(&[0, 1, 2, 3, 4], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.emitted.len(), a.n_accepted + 1);
        assert_eq!(a.emitted.len(), 6); // K+1 = theoretical max (paper: 6.0)
    }

    // -----------------------------------------------------------------------
    // rejection-sampling properties (satellite 1 + greedy regression)
    // -----------------------------------------------------------------------

    /// Random SampleConfig for property tests: temperature in (0.3, 1.3),
    /// filters on or off.
    fn rand_cfg(rng: &mut Rng, vocab: usize) -> SampleConfig {
        let mut cfg = SampleConfig::temperature(0.3 + rng.below(100) as f32 / 100.0);
        if rng.below(2) == 0 {
            cfg = cfg.with_top_k(1 + rng.below(vocab));
        }
        if rng.below(2) == 0 {
            cfg = cfg.with_top_p(0.5 + rng.below(50) as f32 / 100.0);
        }
        cfg
    }

    #[test]
    fn chain_rejection_matches_tree_subset_rejection_on_chain_incl_rng() {
        // THE satellite parity property: the chain rejection rule and the
        // tree-subset rejection rule on a chain parent array [0,1,2,..] are
        // the same algorithm — token-for-token AND rng-draw-for-rng-draw
        // (the post-run rng states must coincide), with and without explicit
        // q proposals, under greedy and temperature dispatch
        use crate::util::prop::{check, Case};
        check("chain-rejection-parity", 150, |rng| {
            let k = 1 + rng.below(7);
            let vocab = 4 + rng.below(12);
            let rows = rand_rows(rng, k + 1, vocab);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let qrows = rand_rows(rng, k + 1, vocab);
            let qrefs: Vec<&[f32]> = qrows.iter().map(|r| r.as_slice()).collect();
            let use_q = rng.below(2) == 0;
            let q: Option<&[&[f32]]> = use_q.then_some(&qrefs[..]);
            let drafts: Vec<i32> = refs
                .iter()
                .take(k)
                .map(|r| {
                    if rng.below(2) == 0 {
                        argmax(r)
                    } else {
                        rng.below(vocab) as i32
                    }
                })
                .collect();
            let cfg = rand_cfg(rng, vocab);
            let seed = rng.next_u64();
            let parents: Vec<usize> = (0..k).collect();
            let mut rng_a = Rng::new(seed);
            let chain = accept_chain_rejection(&drafts, &refs, q, &cfg, &mut rng_a);
            let mut rng_b = Rng::new(seed);
            let sub =
                accept_tree_subset_rejection(&parents, &drafts, &refs, q, &cfg, &mut rng_b);
            if sub.emitted != chain.emitted
                || sub.n_accepted() != chain.n_accepted
                || rng_a.next_u64() != rng_b.next_u64()
            {
                return Case::Fail {
                    desc: format!(
                        "k={k} use_q={use_q} chain {:?}/{} vs subset {:?}/{} (cfg {cfg:?})",
                        chain.emitted,
                        chain.n_accepted,
                        sub.emitted,
                        sub.n_accepted()
                    ),
                    size: k,
                };
            }
            Case::Pass
        });
    }

    #[test]
    fn sampled_dispatch_greedy_is_byte_identical_and_draw_free() {
        // greedy regression (satellite): the per-request dispatch must route
        // greedy requests through the exact-match walk unchanged — identical
        // outputs AND an untouched rng (zero draws), chain and tree-subset
        use crate::util::prop::{check, Case};
        check("greedy-dispatch-regression", 120, |rng| {
            let levels = 1 + rng.below(4);
            let widths: Vec<usize> = (0..levels).map(|_| 1 + rng.below(3)).collect();
            let t = TreeTopology::from_widths(&widths);
            let vocab = 4 + rng.below(8);
            let rows = rand_rows(rng, t.len() + 1, vocab);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let drafts: Vec<i32> = (0..t.len())
                .map(|_| {
                    if rng.below(3) == 0 {
                        rng.below(vocab) as i32
                    } else {
                        argmax(refs[rng.below(t.len() + 1)])
                    }
                })
                .collect();
            let parents: Vec<usize> = (1..=t.len()).map(|i| t.parent(i)).collect();
            let cfg = SampleConfig::greedy();
            let seed = rng.next_u64();
            let mut rng_a = Rng::new(seed);
            let a = accept_tree_subset_sampled(&parents, &drafts, &refs, &cfg, &mut rng_a);
            let b = accept_tree_subset(
                &parents,
                &drafts,
                &refs,
                Sampling::Greedy,
                &mut Rng::new(seed),
            );
            let draw_free = rng_a.next_u64() == Rng::new(seed).next_u64();
            if a.emitted != b.emitted || a.accepted_path != b.accepted_path || !draw_free {
                return Case::Fail {
                    desc: format!("greedy dispatch diverged: {a:?} vs {b:?} draw_free={draw_free}"),
                    size: t.len(),
                };
            }
            // chain side too
            let kc = 1 + rng.below(5);
            let crows = rand_rows(rng, kc + 1, vocab);
            let crefs: Vec<&[f32]> = crows.iter().map(|r| r.as_slice()).collect();
            let cdrafts: Vec<i32> = (0..kc).map(|i| argmax(crefs[i])).collect();
            let mut rng_c = Rng::new(seed);
            let c = accept_chain_sampled(&cdrafts, &crefs, &cfg, &mut rng_c);
            let d = accept_chain(&cdrafts, &crefs, Sampling::Greedy, &mut Rng::new(seed));
            if c != d || rng_c.next_u64() != Rng::new(seed).next_u64() {
                return Case::Fail { desc: format!("chain greedy dispatch: {c:?} vs {d:?}"), size: kc };
            }
            Case::Pass
        });
    }

    #[test]
    fn rejection_accepted_path_is_root_prefix_and_emits_drafts() {
        // structural invariant under rejection: the accepted path is a
        // connected root path whose emitted tokens are the drafted tokens,
        // plus exactly one stop token
        use crate::util::prop::{check, Case};
        check("rejection-root-prefix", 120, |rng| {
            let levels = 1 + rng.below(4);
            let widths: Vec<usize> = (0..levels).map(|_| 1 + rng.below(3)).collect();
            let t = TreeTopology::from_widths(&widths);
            let vocab = 4 + rng.below(8);
            let rows = rand_rows(rng, t.len() + 1, vocab);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let drafts: Vec<i32> = (0..t.len())
                .map(|_| {
                    if rng.below(3) == 0 {
                        rng.below(vocab) as i32
                    } else {
                        argmax(refs[rng.below(t.len() + 1)])
                    }
                })
                .collect();
            let cfg = rand_cfg(rng, vocab);
            let a = accept_tree_rejection(&t, &drafts, &refs, None, &cfg, &mut rng.clone());
            if a.emitted.len() != a.n_accepted() + 1 {
                return Case::Fail {
                    desc: format!("emitted {} != path {} + 1", a.emitted.len(), a.n_accepted()),
                    size: t.len(),
                };
            }
            let mut prev = 0usize;
            for (m, &node) in a.accepted_path.iter().enumerate() {
                if t.parent(node) != prev || a.emitted[m] != drafts[node - 1] {
                    return Case::Fail {
                        desc: format!("path {:?} invalid under {widths:?}", a.accepted_path),
                        size: t.len(),
                    };
                }
                prev = node;
            }
            Case::Pass
        });
    }

    // -----------------------------------------------------------------------
    // the statistical acceptance suite (satellite 2) — pre-registered
    // thresholds, fixed seeds, no PJRT. The `rust-sampling` CI job runs
    // exactly these.
    // -----------------------------------------------------------------------

    /// Pre-registered test parameters: 12k trials on a 12-token vocab; the
    /// chi-square level is alpha = 0.001 (deterministic seeds make this a
    /// fixed PASS/FAIL, not a flake rate) and the TVD tolerance 0.03 sits
    /// ~3x above the expected sampling noise at n = 12_000 while the
    /// deliberately-biased controls land at TVD > 0.05 by construction.
    const TRIALS: usize = 12_000;
    const ALPHA: f64 = 0.001;
    const TVD_TOL: f64 = 0.03;
    const STAT_SEED: u64 = 0x5A7_1571C;

    /// Fixed synthetic target: 4 chunk-slot rows (tree parents [0,0,1]) over
    /// a 12-token vocab, logits in [0, 3) so the temperature-0.7 softmax has
    /// real spread without collapsing to a point mass.
    fn stat_rows() -> Vec<Vec<f32>> {
        let mut rng = Rng::new(0x7A26E7);
        (0..4)
            .map(|_| (0..12).map(|_| rng.below(300) as f32 / 100.0).collect())
            .collect()
    }

    fn stat_cfg() -> SampleConfig {
        SampleConfig::temperature(0.7).with_top_k(8)
    }

    /// Deterministic drafts for the [0,0,1] tree: the target's two most
    /// likely first tokens (distinct within the level), then the most likely
    /// continuation under node 1 — realistic top-k drafting, decent
    /// acceptance mass.
    fn stat_drafts(rows: &[Vec<f32>]) -> Vec<i32> {
        let d1 = argmax(&rows[0]);
        let mut second = rows[0].clone();
        second[d1 as usize] = f32::NEG_INFINITY;
        vec![d1, argmax(&second), argmax(&rows[1])]
    }

    fn expected_probs(row: &[f32], cfg: &SampleConfig) -> Vec<f64> {
        filtered_probs(row, cfg).iter().map(|&x| x as f64).collect()
    }

    fn assert_gof(rep: &GofReport, should_pass: bool, label: &str) {
        assert_eq!(
            rep.passes(TVD_TOL),
            should_pass,
            "{label}: tvd {:.4} (tol {TVD_TOL}), chi2 {:.1} (crit {:.1}, df {}), \
             impossible bins {}",
            rep.tvd,
            rep.chi2,
            rep.chi2_crit,
            rep.df,
            rep.impossible_bins,
        );
    }

    #[test]
    fn rejection_first_token_marginal_matches_direct_target_sampling() {
        // LOSSLESSNESS: over 12k seeded trials, the first emitted token of
        // the tree rejection rule (point-mass proposal, deterministic
        // drafts) is distributed exactly like direct sampling from the
        // request's filtered target distribution
        let rows = stat_rows();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let drafts = stat_drafts(&rows);
        let cfg = stat_cfg();
        let parents = [0usize, 0, 1];
        let mut counts = vec![0u64; 12];
        for trial in 0..TRIALS {
            let mut rng = Rng::new(STAT_SEED ^ (trial as u64).wrapping_mul(0x9E37_79B9));
            let a = accept_tree_subset_rejection(&parents, &drafts, &refs, None, &cfg, &mut rng);
            counts[a.emitted[0] as usize] += 1;
        }
        let rep = goodness_of_fit(&counts, &expected_probs(&rows[0], &cfg), ALPHA);
        assert_gof(&rep, true, "rejection first-token marginal");
    }

    #[test]
    fn rejection_conditional_continuation_matches_target() {
        // LOSSLESSNESS one level down: conditioned on descending into node
        // 1, the SECOND emitted token must follow node 1's filtered target
        // row — the walk's residual machinery must not leak into accepted
        // branches
        let rows = stat_rows();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let drafts = stat_drafts(&rows);
        let cfg = stat_cfg();
        let parents = [0usize, 0, 1];
        let mut counts = vec![0u64; 12];
        for trial in 0..TRIALS {
            let mut rng = Rng::new(STAT_SEED ^ (trial as u64).wrapping_mul(0x9E37_79B9));
            let a = accept_tree_subset_rejection(&parents, &drafts, &refs, None, &cfg, &mut rng);
            if a.accepted_path.first() == Some(&1) {
                counts[a.emitted[1] as usize] += 1;
            }
        }
        let n: u64 = counts.iter().sum();
        assert!(n >= 2_000, "need conditional mass to test against ({n} trials descended)");
        let rep = goodness_of_fit(&counts, &expected_probs(&rows[1], &cfg), ALPHA);
        assert_gof(&rep, true, "rejection conditional continuation");
    }

    #[test]
    fn sampled_drafts_with_explicit_q_rows_stay_lossless() {
        // the GENERAL min(1, p/q) rule: drafts SAMPLED from a known proposal
        // q (chain of depth 2, fresh drafts every trial from an independent
        // stream), q_rows threaded into acceptance. The emitted first token
        // must still follow the filtered TARGET distribution — speculative
        // sampling's defining property
        let rows = stat_rows();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut qrng = Rng::new(0x0DD_D12AF7);
        let qrows: Vec<Vec<f32>> =
            (0..3).map(|_| (0..12).map(|_| qrng.below(300) as f32 / 100.0).collect()).collect();
        let qrefs: Vec<&[f32]> = qrows.iter().map(|r| r.as_slice()).collect();
        let cfg = stat_cfg();
        let parents = [0usize, 1];
        let mut counts = vec![0u64; 12];
        for trial in 0..TRIALS {
            let t64 = trial as u64;
            let mut draft_rng = Rng::new(0xD4AF7 ^ t64.wrapping_mul(0x2545_F491));
            let drafts = vec![
                sample_filtered(&qrows[0], &cfg, &mut draft_rng),
                sample_filtered(&qrows[1], &cfg, &mut draft_rng),
            ];
            let mut rng = Rng::new(STAT_SEED ^ t64.wrapping_mul(0x9E37_79B9));
            let a = accept_tree_subset_rejection(
                &parents,
                &drafts,
                &refs[..3],
                Some(&qrefs[..3]),
                &cfg,
                &mut rng,
            );
            counts[a.emitted[0] as usize] += 1;
        }
        let rep = goodness_of_fit(&counts, &expected_probs(&rows[0], &cfg), ALPHA);
        assert_gof(&rep, true, "sampled-draft min(1,p/q) marginal");
    }

    #[test]
    fn exact_match_control_at_temperature_one_fails_the_check() {
        // POWER (the ISSUE's pre-registered control): verification that
        // ignores the request's sampling parameters — the old exact-match
        // rule run at raw temperature 1.0 with no filters, against a request
        // that asked for temperature 0.7 + top-k 8 — must FAIL the same
        // marginal check the rejection rule passes. This is precisely the
        // pre-PR serving gap (the engine sampled at the raw temperature and
        // ignored top-k/top-p), so the suite demonstrably detects it.
        let rows = stat_rows();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let drafts = stat_drafts(&rows);
        let cfg = stat_cfg(); // what the request ASKED for
        let parents = [0usize, 0, 1];
        let mut counts = vec![0u64; 12];
        for trial in 0..TRIALS {
            let mut rng = Rng::new(STAT_SEED ^ (trial as u64).wrapping_mul(0x9E37_79B9));
            let a = accept_tree_subset(
                &parents,
                &drafts,
                &refs,
                Sampling::Temperature(1.0), // what the control DELIVERS
                &mut rng,
            );
            counts[a.emitted[0] as usize] += 1;
        }
        let rep = goodness_of_fit(&counts, &expected_probs(&rows[0], &cfg), ALPHA);
        assert_gof(&rep, false, "exact-match@T=1.0 control");
        assert!(
            rep.tvd > 0.05,
            "control should fail by a wide margin, not at the threshold edge: tvd {:.4}",
            rep.tvd
        );
    }

    #[test]
    fn scalar_confidence_q_on_deterministic_drafts_is_biased() {
        // POWER + a design pin: reusing the drafter's model confidence as
        // the rejection q while the drafts are DETERMINISTIC top-k picks is
        // provably biased (the true proposal of a deterministic draft is a
        // point mass, not the model distribution) — which is why the engine
        // threads drafter confidence into calibration metrics only. Worked
        // example: p = (.2, .3, .5), q = (.6, .3, .1), drafts = top-2 of q:
        // the q-threaded rule emits (1/3, 0, 2/3) — TVD 0.3 from p — while
        // the point-mass rule stays exactly p.
        let pad = |v: &[f32]| -> Vec<f32> {
            let mut row: Vec<f32> = v.iter().map(|p| p.ln()).collect();
            row.extend(std::iter::repeat(-30.0).take(8 - v.len()));
            row
        };
        let p_row = pad(&[0.2, 0.3, 0.5]);
        let q_row = pad(&[0.6, 0.3, 0.1]);
        let bonus_row = pad(&[0.5, 0.5]); // any row; the walk rarely gets there
        let refs: Vec<&[f32]> = vec![&p_row, &bonus_row, &bonus_row];
        let qrefs: Vec<&[f32]> = vec![&q_row, &bonus_row, &bonus_row];
        let cfg = SampleConfig::temperature(1.0);
        let parents = [0usize, 0]; // two depth-1 siblings
        let drafts = [0i32, 1]; // deterministic top-2 of q — NOT sampled
        let expected = expected_probs(&p_row, &cfg);

        let mut biased = vec![0u64; 8];
        let mut lossless = vec![0u64; 8];
        for trial in 0..TRIALS {
            let seed = STAT_SEED ^ (trial as u64).wrapping_mul(0x9E37_79B9);
            let a = accept_tree_subset_rejection(
                &parents,
                &drafts,
                &refs,
                Some(&qrefs),
                &cfg,
                &mut Rng::new(seed),
            );
            biased[a.emitted[0] as usize] += 1;
            let b = accept_tree_subset_rejection(
                &parents,
                &drafts,
                &refs,
                None,
                &cfg,
                &mut Rng::new(seed),
            );
            lossless[b.emitted[0] as usize] += 1;
        }
        let rep_biased = goodness_of_fit(&biased, &expected, ALPHA);
        assert_gof(&rep_biased, false, "model-confidence-q control");
        assert!(rep_biased.tvd > 0.1, "expected ~0.3 TVD, got {:.4}", rep_biased.tvd);
        let rep_lossless = goodness_of_fit(&lossless, &expected, ALPHA);
        assert_gof(&rep_lossless, true, "point-mass rule on the same setup");
    }
}
