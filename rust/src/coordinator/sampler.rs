//! Token sampling + the speculative acceptance rule.
//!
//! The engine runs greedy (argmax) verification — the paper's acceptance
//! length metric is defined under chain drafting with greedy target
//! decoding. Temperature sampling is provided for the serving API; under
//! temperature > 0 acceptance uses the standard exact-match-on-sample rule
//! (draft accepted iff it equals the sampled target token), which preserves
//! the target distribution for greedy and is the chain special case of
//! rejection sampling.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub enum Sampling {
    Greedy,
    Temperature(f32),
}

/// Argmax over one logits row.
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in row.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

/// Sample a token from one logits row.
pub fn sample(row: &[f32], s: Sampling, rng: &mut Rng) -> i32 {
    match s {
        Sampling::Greedy => argmax(row),
        Sampling::Temperature(t) => {
            let t = t.max(1e-4);
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f32> = row.iter().map(|&x| ((x - m) / t).exp()).collect();
            rng.categorical(&weights) as i32
        }
    }
}

/// Outcome of verifying one slot's draft chunk.
#[derive(Clone, Debug, PartialEq)]
pub struct Acceptance {
    /// number of draft tokens accepted (prefix match), 0..=K
    pub n_accepted: usize,
    /// tokens to emit this iteration: accepted drafts + 1 bonus token
    pub emitted: Vec<i32>,
}

/// Chain-drafting acceptance: target logits row i is the distribution for
/// the token *after* chunk position i. Draft token d[i] is accepted while it
/// matches the target's token for that position; the first mismatch (or the
/// end of the chain) contributes the target's own token as the bonus.
pub fn accept_chain(
    drafts: &[i32],
    target_rows: &[&[f32]], // K+1 rows
    s: Sampling,
    rng: &mut Rng,
) -> Acceptance {
    assert_eq!(target_rows.len(), drafts.len() + 1);
    let mut emitted = Vec::with_capacity(drafts.len() + 1);
    let mut n_accepted = 0;
    for (i, &d) in drafts.iter().enumerate() {
        let t = sample(target_rows[i], s, rng);
        if d == t {
            emitted.push(d);
            n_accepted += 1;
        } else {
            emitted.push(t); // correction token from the target
            return Acceptance { n_accepted, emitted };
        }
    }
    // all drafts accepted: bonus token from the last target row
    emitted.push(sample(target_rows[drafts.len()], s, rng));
    Acceptance { n_accepted, emitted }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn onehot(v: usize, n: usize) -> Vec<f32> {
        let mut row = vec![0.0; n];
        row[v] = 10.0;
        row
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn full_acceptance_adds_bonus() {
        let rows: Vec<Vec<f32>> =
            vec![onehot(4, 8), onehot(5, 8), onehot(6, 8), onehot(7, 8)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_chain(&[4, 5, 6], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.n_accepted, 3);
        assert_eq!(a.emitted, vec![4, 5, 6, 7]);
    }

    #[test]
    fn mismatch_truncates_with_correction() {
        let rows: Vec<Vec<f32>> = vec![onehot(4, 8), onehot(5, 8), onehot(6, 8)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_chain(&[4, 1], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.n_accepted, 1);
        assert_eq!(a.emitted, vec![4, 5]); // correction = target argmax
    }

    #[test]
    fn zero_acceptance_still_emits_one() {
        let rows: Vec<Vec<f32>> = vec![onehot(2, 8), onehot(3, 8)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_chain(&[7], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.n_accepted, 0);
        assert_eq!(a.emitted, vec![2]);
    }

    #[test]
    fn temperature_zeroish_matches_greedy() {
        let row = vec![0.0, 1.0, 8.0, 2.0];
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            assert_eq!(sample(&row, Sampling::Temperature(0.01), &mut rng), 2);
        }
    }

    #[test]
    fn al_equals_accepted_plus_one() {
        // paper convention: AL counts accepted drafts + bonus, max K+1
        let rows: Vec<Vec<f32>> = (0..6).map(|i| onehot(i, 8)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(3);
        let a = accept_chain(&[0, 1, 2, 3, 4], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.emitted.len(), a.n_accepted + 1);
        assert_eq!(a.emitted.len(), 6); // K+1 = theoretical max (paper: 6.0)
    }
}
