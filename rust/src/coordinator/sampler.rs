//! Token sampling + the speculative acceptance rules (chain and tree).
//!
//! The engine runs greedy (argmax) verification — the paper's acceptance
//! length metric is defined under chain drafting with greedy target
//! decoding. Temperature sampling is provided for the serving API; under
//! temperature > 0 acceptance uses the standard exact-match-on-sample rule
//! (draft accepted iff it equals the sampled target token), which preserves
//! the target distribution for greedy and is the chain special case of
//! rejection sampling.
//!
//! [`accept_tree`] generalizes [`accept_chain`] to tree-structured drafts
//! (EAGLE-3-style): it walks the longest root path of the draft tree whose
//! node tokens match the target's sampled continuation, emitting the
//! target's own token as the correction/bonus where the walk stops. A
//! chain-shaped [`TreeTopology`] reproduces `accept_chain` token-for-token
//! (property-tested below), which is what lets the engine treat the chain
//! as the degenerate tree.

use crate::masking::TreeTopology;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    Greedy,
    Temperature(f32),
}

/// Argmax over one logits row.
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in row.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

/// Sample a token from one logits row.
pub fn sample(row: &[f32], s: Sampling, rng: &mut Rng) -> i32 {
    match s {
        Sampling::Greedy => argmax(row),
        Sampling::Temperature(t) => {
            let t = t.max(1e-4);
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let weights: Vec<f32> = row.iter().map(|&x| ((x - m) / t).exp()).collect();
            rng.categorical(&weights) as i32
        }
    }
}

/// Outcome of verifying one slot's draft chunk.
#[derive(Clone, Debug, PartialEq)]
pub struct Acceptance {
    /// number of draft tokens accepted (prefix match), 0..=K
    pub n_accepted: usize,
    /// tokens to emit this iteration: accepted drafts + 1 bonus token
    pub emitted: Vec<i32>,
}

/// Chain-drafting acceptance: target logits row i is the distribution for
/// the token *after* chunk position i. Draft token `d[i]` is accepted while it
/// matches the target's token for that position; the first mismatch (or the
/// end of the chain) contributes the target's own token as the bonus.
pub fn accept_chain(
    drafts: &[i32],
    target_rows: &[&[f32]], // K+1 rows
    s: Sampling,
    rng: &mut Rng,
) -> Acceptance {
    assert_eq!(target_rows.len(), drafts.len() + 1);
    let mut emitted = Vec::with_capacity(drafts.len() + 1);
    let mut n_accepted = 0;
    for (i, &d) in drafts.iter().enumerate() {
        let t = sample(target_rows[i], s, rng);
        if d == t {
            emitted.push(d);
            n_accepted += 1;
        } else {
            emitted.push(t); // correction token from the target
            return Acceptance { n_accepted, emitted };
        }
    }
    // all drafts accepted: bonus token from the last target row
    emitted.push(sample(target_rows[drafts.len()], s, rng));
    Acceptance { n_accepted, emitted }
}

/// Outcome of verifying one slot's draft TREE.
#[derive(Clone, Debug, PartialEq)]
pub struct TreeAcceptance {
    /// accepted node ids, root-path order (ids ascend; empty if the first
    /// sampled target token matched no depth-1 node)
    pub accepted_path: Vec<usize>,
    /// tokens to emit this iteration: accepted path tokens + 1 bonus token
    pub emitted: Vec<i32>,
}

impl TreeAcceptance {
    pub fn n_accepted(&self) -> usize {
        self.accepted_path.len()
    }
}

/// Tree acceptance: walk the longest accepted root path.
///
/// `drafts[i - 1]` is the token drafted at tree node `i`; `target_rows[j]`
/// (N+1 rows, chunk-slot order) is the target's distribution for the token
/// *after* chunk slot `j`. Starting at the root, sample the target's token
/// for the current node and descend into the child drafted with that exact
/// token; where no child matches (or at a leaf) the target's own sample is
/// emitted as the correction/bonus. Node tokens are distinct within a level
/// (the drafter assigns distinct top-k ranks), so at most one child can
/// match.
pub fn accept_tree(
    tree: &TreeTopology,
    drafts: &[i32],
    target_rows: &[&[f32]], // N+1 rows
    s: Sampling,
    rng: &mut Rng,
) -> TreeAcceptance {
    assert_eq!(drafts.len(), tree.len());
    let parents: Vec<usize> = (1..=tree.len()).map(|i| tree.parent(i)).collect();
    accept_tree_subset(&parents, drafts, target_rows, s, rng)
}

/// Tree acceptance over an arbitrary (compacted) subtree, described by a
/// parent array instead of a width-profile topology — the dynamic-tree
/// engine's acceptance rule ([`crate::masking::dynamic`] compacts the
/// per-step selected subtree into slots `1..=m`, which is a valid level-major
/// tree but not a round-robin width profile).
///
/// `parents[i - 1]` is the chunk slot of node `i`'s parent (0 = root;
/// parents precede children); `drafts[i - 1]` its token; `target_rows` has
/// `parents.len() + 1` rows in chunk-slot order. Children are scanned in
/// ascending slot order, exactly like [`TreeTopology::children`], so
/// [`accept_tree`] (which delegates here) is unchanged token-for-token AND
/// rng-draw-for-rng-draw — and a chain-shaped parent array `[0, 1, 2, ..]`
/// reproduces [`accept_chain`] the same way (property-tested below).
pub fn accept_tree_subset(
    parents: &[usize],
    drafts: &[i32],
    target_rows: &[&[f32]], // parents.len() + 1 rows
    s: Sampling,
    rng: &mut Rng,
) -> TreeAcceptance {
    assert_eq!(drafts.len(), parents.len());
    assert_eq!(target_rows.len(), parents.len() + 1);
    debug_assert!(parents.iter().enumerate().all(|(i, &p)| p <= i), "parents must precede children");
    let mut accepted_path = Vec::new();
    let mut emitted = Vec::new();
    let mut cur = 0usize; // chunk slot of the current path head (0 = root)
    loop {
        let t = sample(target_rows[cur], s, rng);
        emitted.push(t);
        let next =
            (1..=parents.len()).find(|&c| parents[c - 1] == cur && drafts[c - 1] == t);
        match next {
            Some(c) => {
                accepted_path.push(c);
                cur = c;
            }
            // mismatch or leaf: the sampled token stands as correction/bonus
            None => return TreeAcceptance { accepted_path, emitted },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn onehot(v: usize, n: usize) -> Vec<f32> {
        let mut row = vec![0.0; n];
        row[v] = 10.0;
        row
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn full_acceptance_adds_bonus() {
        let rows: Vec<Vec<f32>> =
            vec![onehot(4, 8), onehot(5, 8), onehot(6, 8), onehot(7, 8)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_chain(&[4, 5, 6], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.n_accepted, 3);
        assert_eq!(a.emitted, vec![4, 5, 6, 7]);
    }

    #[test]
    fn mismatch_truncates_with_correction() {
        let rows: Vec<Vec<f32>> = vec![onehot(4, 8), onehot(5, 8), onehot(6, 8)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_chain(&[4, 1], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.n_accepted, 1);
        assert_eq!(a.emitted, vec![4, 5]); // correction = target argmax
    }

    #[test]
    fn zero_acceptance_still_emits_one() {
        let rows: Vec<Vec<f32>> = vec![onehot(2, 8), onehot(3, 8)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_chain(&[7], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.n_accepted, 0);
        assert_eq!(a.emitted, vec![2]);
    }

    #[test]
    fn temperature_zeroish_matches_greedy() {
        let row = vec![0.0, 1.0, 8.0, 2.0];
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            assert_eq!(sample(&row, Sampling::Temperature(0.01), &mut rng), 2);
        }
    }

    #[test]
    fn tree_accepts_longest_matching_root_path() {
        // widths [2, 1]: nodes 1,2 at depth 1 (parents 0,0), node 3 at
        // depth 2 (parent 1). Target greedy path: 5 then 9.
        let t = TreeTopology::from_widths(&[2, 1]);
        let rows: Vec<Vec<f32>> =
            vec![onehot(5, 16), onehot(9, 16), onehot(7, 16), onehot(1, 16)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        // drafts: node1=4 (miss), node2=5 (hit via the rank-1 sibling!),
        // node3 is a child of node1 so it is off the accepted path
        let a = accept_tree(&t, &[4, 5, 3], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.accepted_path, vec![2]);
        // node 2's target row is rows[2] -> correction 7
        assert_eq!(a.emitted, vec![5, 7]);
    }

    #[test]
    fn tree_mismatch_everywhere_still_emits_one() {
        let t = TreeTopology::from_widths(&[3]);
        let rows: Vec<Vec<f32>> =
            vec![onehot(9, 16), onehot(1, 16), onehot(2, 16), onehot(3, 16)];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_tree(&t, &[4, 5, 6], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.accepted_path, Vec::<usize>::new());
        assert_eq!(a.emitted, vec![9]);
    }

    #[test]
    fn tree_full_depth_adds_bonus_from_leaf_row() {
        let t = TreeTopology::from_widths(&[2, 2]);
        // accepted path 0 -> 2 -> 4 (node 4 is the depth-2 rank-0 child of
        // node 2 under round-robin? parents of 3,4 are 1,2 — so child of 2
        // is node 4). drafts: node2=6, node4=8.
        let mut rows = vec![onehot(6, 16); 5];
        rows[2] = onehot(8, 16); // after node 2, target wants 8
        rows[4] = onehot(3, 16); // after node 4: bonus 3
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(1);
        let a = accept_tree(&t, &[1, 6, 7, 8], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.accepted_path, vec![2, 4]);
        assert_eq!(a.emitted, vec![6, 8, 3]);
    }

    fn rand_rows(rng: &mut Rng, n: usize, vocab: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..vocab).map(|_| rng.below(1000) as f32 / 100.0).collect())
            .collect()
    }

    #[test]
    fn tree_chain_topology_matches_accept_chain_exactly() {
        // the degenerate chain tree must reproduce accept_chain
        // token-for-token, including rng consumption, for random logits and
        // random drafts under both sampling modes
        use crate::util::prop::{check, Case};
        check("tree-chain-parity", 120, |rng| {
            let k = 1 + rng.below(7);
            let vocab = 4 + rng.below(12);
            let rows = rand_rows(rng, k + 1, vocab);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            // drafts partially agree with the greedy path to exercise both
            // acceptance and mismatch branches
            let drafts: Vec<i32> = refs
                .iter()
                .take(k)
                .map(|r| {
                    if rng.below(2) == 0 {
                        argmax(r)
                    } else {
                        rng.below(vocab) as i32
                    }
                })
                .collect();
            let s = if rng.below(2) == 0 {
                Sampling::Greedy
            } else {
                Sampling::Temperature(0.7)
            };
            let seed = rng.next_u64();
            let chain = accept_chain(&drafts, &refs, s, &mut Rng::new(seed));
            let tree = accept_tree(
                &TreeTopology::chain(k),
                &drafts,
                &refs,
                s,
                &mut Rng::new(seed),
            );
            if tree.emitted != chain.emitted || tree.n_accepted() != chain.n_accepted {
                return Case::Fail {
                    desc: format!(
                        "k={k} chain {:?}/{} vs tree {:?}/{}",
                        chain.emitted,
                        chain.n_accepted,
                        tree.emitted,
                        tree.n_accepted()
                    ),
                    size: k,
                };
            }
            Case::Pass
        });
    }

    #[test]
    fn tree_accepted_path_is_always_a_root_prefix() {
        // whatever the logits and drafts, the accepted path must be a
        // connected root path: node m's parent is node m-1 of the path (or
        // the root), depths ascend 1,2,3,..., and emitted = path + bonus
        use crate::util::prop::{check, Case};
        check("tree-root-prefix", 120, |rng| {
            let levels = 1 + rng.below(4);
            let widths: Vec<usize> = (0..levels).map(|_| 1 + rng.below(3)).collect();
            let t = TreeTopology::from_widths(&widths);
            let vocab = 4 + rng.below(8);
            let rows = rand_rows(rng, t.len() + 1, vocab);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            // bias drafts toward the greedy continuation so paths get deep
            let drafts: Vec<i32> = (1..=t.len())
                .map(|_| {
                    if rng.below(3) == 0 {
                        rng.below(vocab) as i32
                    } else {
                        argmax(refs[rng.below(t.len() + 1)])
                    }
                })
                .collect();
            let a = accept_tree(&t, &drafts, &refs, Sampling::Greedy, &mut rng.clone());
            if a.emitted.len() != a.n_accepted() + 1 {
                return Case::Fail {
                    desc: format!("emitted {} != path {} + 1", a.emitted.len(), a.n_accepted()),
                    size: t.len(),
                };
            }
            let mut prev = 0usize;
            for (m, &node) in a.accepted_path.iter().enumerate() {
                if t.parent(node) != prev || t.depth(node) != m + 1 {
                    return Case::Fail {
                        desc: format!("path {:?} not a root prefix ({widths:?})", a.accepted_path),
                        size: t.len(),
                    };
                }
                if a.emitted[m] != drafts[node - 1] {
                    return Case::Fail {
                        desc: format!("emitted[{m}] != draft of node {node}"),
                        size: t.len(),
                    };
                }
                prev = node;
            }
            Case::Pass
        });
    }

    #[test]
    fn tree_subset_chain_prefix_matches_accept_chain_exactly() {
        // the dynamic-tree chain-equivalence satellite: selecting the first
        // b nodes of a chain envelope (what confidence selection always does
        // on a chain — one node per depth) must reproduce accept_chain over
        // the truncated draft, token-for-token INCLUDING rng consumption,
        // under both sampling modes
        use crate::util::prop::{check, Case};
        check("tree-subset-chain-parity", 120, |rng| {
            let k = 1 + rng.below(7);
            let b = 1 + rng.below(k); // selected chain prefix depth
            let vocab = 4 + rng.below(12);
            let rows = rand_rows(rng, b + 1, vocab);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let drafts: Vec<i32> = refs
                .iter()
                .take(b)
                .map(|r| {
                    if rng.below(2) == 0 {
                        argmax(r)
                    } else {
                        rng.below(vocab) as i32
                    }
                })
                .collect();
            let s = if rng.below(2) == 0 {
                Sampling::Greedy
            } else {
                Sampling::Temperature(0.7)
            };
            let seed = rng.next_u64();
            let chain = accept_chain(&drafts, &refs, s, &mut Rng::new(seed));
            let parents: Vec<usize> = (0..b).collect(); // compacted chain prefix
            let sub = accept_tree_subset(&parents, &drafts, &refs, s, &mut Rng::new(seed));
            if sub.emitted != chain.emitted || sub.n_accepted() != chain.n_accepted {
                return Case::Fail {
                    desc: format!(
                        "k={k} b={b} chain {:?}/{} vs subset {:?}/{}",
                        chain.emitted,
                        chain.n_accepted,
                        sub.emitted,
                        sub.n_accepted()
                    ),
                    size: k,
                };
            }
            Case::Pass
        });
    }

    #[test]
    fn tree_subset_full_selection_matches_accept_tree() {
        // degenerate selection (every node active) must be accept_tree
        // exactly — the identity relabeling changes nothing
        use crate::util::prop::{check, Case};
        check("tree-subset-full-parity", 100, |rng| {
            let levels = 1 + rng.below(4);
            let widths: Vec<usize> = (0..levels).map(|_| 1 + rng.below(3)).collect();
            let t = TreeTopology::from_widths(&widths);
            let vocab = 4 + rng.below(8);
            let rows = rand_rows(rng, t.len() + 1, vocab);
            let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
            let drafts: Vec<i32> = (0..t.len())
                .map(|_| {
                    if rng.below(3) == 0 {
                        rng.below(vocab) as i32
                    } else {
                        argmax(refs[rng.below(t.len() + 1)])
                    }
                })
                .collect();
            let seed = rng.next_u64();
            let a = accept_tree(&t, &drafts, &refs, Sampling::Greedy, &mut Rng::new(seed));
            let parents: Vec<usize> = (1..=t.len()).map(|i| t.parent(i)).collect();
            let b = accept_tree_subset(
                &parents,
                &drafts,
                &refs,
                Sampling::Greedy,
                &mut Rng::new(seed),
            );
            if a.emitted != b.emitted || a.accepted_path != b.accepted_path {
                return Case::Fail {
                    desc: format!("{:?} vs {:?} ({widths:?})", a, b),
                    size: t.len(),
                };
            }
            Case::Pass
        });
    }

    #[test]
    fn al_equals_accepted_plus_one() {
        // paper convention: AL counts accepted drafts + bonus, max K+1
        let rows: Vec<Vec<f32>> = (0..6).map(|i| onehot(i, 8)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut rng = Rng::new(3);
        let a = accept_chain(&[0, 1, 2, 3, 4], &refs, Sampling::Greedy, &mut rng);
        assert_eq!(a.emitted.len(), a.n_accepted + 1);
        assert_eq!(a.emitted.len(), 6); // K+1 = theoretical max (paper: 6.0)
    }
}
