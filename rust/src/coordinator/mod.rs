//! L3 coordinator: the serving engine (the paper's vLLM integration,
//! §5.3) — wave-batched speculative decoding with swappable AR / P-EAGLE
//! drafter executables, KV slot management, sampling/acceptance, metrics,
//! and a threaded server front-end.

pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod request;
pub mod sampler;
pub mod scheduler;
pub mod server;

pub use engine::{run_wave, EngineConfig};
pub use metrics::EngineMetrics;
pub use request::{FinishReason, RequestResult, RequestSpec};
pub use sampler::Sampling;
pub use scheduler::{run_closed_loop, Scheduler};
