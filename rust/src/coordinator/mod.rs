//! L3 coordinator: the serving engine (the paper's vLLM integration,
//! §5.3) — a stepped, continuously batched speculative-decoding core
//! (`EngineCore`) with swappable AR / P-EAGLE drafter executables, per-slot
//! KV lifecycles, sampling/acceptance, occupancy/TTFT metrics, a thin
//! bucket-admission scheduler, and a threaded streaming server front-end.

pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod request;
pub mod sampler;
pub mod scheduler;
pub mod server;

pub use engine::{
    paged_from_env, tree_dyn_from_env, EngineConfig, EngineCore, EngineEvent, PagedKvConfig,
    StepReport,
};
pub use metrics::EngineMetrics;
pub use request::{FinishReason, RequestResult, RequestSpec};
pub use sampler::Sampling;
pub use scheduler::{run_closed_loop, Scheduler};
pub use server::{ServerEvent, ServerHandle, ServerMsg};
