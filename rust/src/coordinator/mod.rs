//! L3 coordinator: the serving engine (the paper's vLLM integration,
//! §5.3) — a stepped, continuously batched speculative-decoding core
//! (`EngineCore`) with PER-REQUEST speculation policies (each [`Request`]
//! may name its own drafter + chain/tree/dynamic shape via [`SpecPolicy`];
//! the step loop groups slots by policy and runs one pass per bucket over
//! that policy's own executables), per-slot KV lifecycles, per-request
//! sampling/acceptance, occupancy/TTFT and per-policy metrics, a thin
//! bucket-admission scheduler, a feedback-driven adaptive speculation
//! controller ([`SpecController`]), and a threaded streaming server
//! front-end.

pub mod controller;
pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod request;
pub mod sampler;
pub mod scheduler;
pub mod server;

pub use controller::{
    adaptive_from_env, decide, Action, ControllerConfig, Signals, SpecController, Tier,
};
pub use engine::{
    device_commit_from_env, multi_drafter_from_env, paged_from_env, prefix_cache_from_env,
    tree_dyn_from_env, EngineConfig, EngineCore, EngineEvent, PagedKvConfig, StepReport,
};
pub use metrics::{EngineMetrics, PolicyMetrics};
pub use request::{
    FinishReason, Request, RequestResult, RequestSpec, SamplingParams, SpecPolicy,
};
pub use sampler::{SampleConfig, Sampling};
pub use scheduler::{run_closed_loop, run_open_loop, Scheduler};
pub use server::{ServerEvent, ServerHandle, ServerMsg};
