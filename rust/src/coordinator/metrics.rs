//! Engine metrics: OTPS, acceptance length, latency percentiles, per-phase
//! timing, and — for the stepped engine — slot-occupancy and time-to-first-
//! token tracking. Everything the Table 9/10 benches report comes from here.
//!
//! With per-request speculation policies a single engine batch can mix
//! drafters AND speculation shapes, so the aggregate AL no longer
//! identifies who earned it: [`PolicyMetrics`] keeps an AL histogram, an
//! acceptance-by-depth histogram, and step/iteration counts PER POLICY
//! IDENTITY — the `exec_key` string (`drafter/mode`, e.g.
//! `target-m-pe4/chain:4` vs `target-m-pe4/dyn:w4x4x2x2x1`), recorded at
//! acceptance time by the policy-grouped step and printed by `bench-otps`.
//! Chain vs tree vs dyn rows of the same drafter are therefore separable
//! signal (what the adaptive controller steers by);
//! [`EngineMetrics::per_drafter`] rolls the map back up to drafter names
//! for display.

use std::collections::BTreeMap;
use std::time::Duration;

/// Per-policy slice of the engine metrics (keyed by policy identity —
/// the `exec_key` string — in [`EngineMetrics::per_policy`]): enough to
/// compare policies served side by side in one batch — AL, acceptance by
/// depth, and how many bucket passes / slot-iterations each one ran.
#[derive(Clone, Debug, Default)]
pub struct PolicyMetrics {
    /// policy-grouped verify passes that included this drafter (each engine
    /// step runs one pass per distinct policy bucket)
    pub steps: usize,
    /// live slot-iterations (one per occupied slot per pass)
    pub iterations: usize,
    /// tokens emitted (accepted drafts + bonus), summed
    pub accepted_sum: usize,
    /// histogram over per-iteration acceptance length (index = emitted)
    pub al_histogram: Vec<usize>,
    /// raw accepted-path depth histogram (same convention as
    /// [`EngineMetrics::accepted_by_depth`]); index 0 unused
    pub accepted_by_depth: Vec<usize>,
    /// drafter-calibration accumulators (dynamic policies): the drafter's
    /// conditional confidence `q` of each selected node, split by whether
    /// the node ended up on the accepted path. A well-calibrated drafter
    /// has mean-q(accepted) near its per-node acceptance rate and
    /// mean-q(rejected) well below it; q is NEVER an acceptance input (see
    /// [`conditional_q`](crate::masking::dynamic::conditional_q)).
    pub q_accepted_sum: f64,
    pub q_accepted_n: usize,
    pub q_rejected_sum: f64,
    pub q_rejected_n: usize,
}

impl PolicyMetrics {
    fn sized(al_max: usize) -> PolicyMetrics {
        PolicyMetrics {
            al_histogram: vec![0; al_max + 2],
            accepted_by_depth: vec![0; al_max + 1],
            ..Default::default()
        }
    }

    /// Record one live slot-iteration: `emitted` tokens kept, raw accepted
    /// path `depth` nodes deep.
    pub fn record_iteration(&mut self, emitted: usize, depth: usize) {
        self.iterations += 1;
        self.accepted_sum += emitted;
        if emitted > 0 {
            let bin = emitted.min(self.al_histogram.len().saturating_sub(1));
            self.al_histogram[bin] += 1;
        }
        if self.accepted_by_depth.len() > 1 {
            let max_d = self.accepted_by_depth.len() - 1;
            for d in 1..=depth.min(max_d) {
                self.accepted_by_depth[d] += 1;
            }
        }
    }

    /// Mean acceptance length for this drafter alone.
    pub fn acceptance_length(&self) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.accepted_sum as f64 / self.iterations as f64
        }
    }

    /// Per-depth acceptance rates for this drafter
    /// (`accepted_by_depth[d] / iterations`), depths `1..`.
    pub fn depth_acceptance_rates(&self) -> Vec<f64> {
        if self.iterations == 0 {
            return Vec::new();
        }
        self.accepted_by_depth[1..]
            .iter()
            .map(|&c| c as f64 / self.iterations as f64)
            .collect()
    }

    /// Record one drafted node's conditional confidence `q` against its
    /// acceptance outcome (calibration signal only).
    pub fn record_draft_q(&mut self, q: f32, accepted: bool) {
        if accepted {
            self.q_accepted_sum += q as f64;
            self.q_accepted_n += 1;
        } else {
            self.q_rejected_sum += q as f64;
            self.q_rejected_n += 1;
        }
    }

    /// Mean drafter confidence over nodes that were accepted (0.0 if none).
    pub fn mean_q_accepted(&self) -> f64 {
        if self.q_accepted_n == 0 {
            0.0
        } else {
            self.q_accepted_sum / self.q_accepted_n as f64
        }
    }

    /// Mean drafter confidence over nodes that were rejected (0.0 if none).
    pub fn mean_q_rejected(&self) -> f64 {
        if self.q_rejected_n == 0 {
            0.0
        } else {
            self.q_rejected_sum / self.q_rejected_n as f64
        }
    }

    fn merge(&mut self, other: &PolicyMetrics) {
        self.steps += other.steps;
        self.iterations += other.iterations;
        self.accepted_sum += other.accepted_sum;
        self.q_accepted_sum += other.q_accepted_sum;
        self.q_accepted_n += other.q_accepted_n;
        self.q_rejected_sum += other.q_rejected_sum;
        self.q_rejected_n += other.q_rejected_n;
        if self.al_histogram.len() < other.al_histogram.len() {
            self.al_histogram.resize(other.al_histogram.len(), 0);
        }
        for (i, &c) in other.al_histogram.iter().enumerate() {
            self.al_histogram[i] += c;
        }
        if self.accepted_by_depth.len() < other.accepted_by_depth.len() {
            self.accepted_by_depth.resize(other.accepted_by_depth.len(), 0);
        }
        for (i, &c) in other.accepted_by_depth.iter().enumerate() {
            self.accepted_by_depth[i] += c;
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    pub requests_finished: usize,
    pub requests_aborted: usize,
    /// requests admitted into a KV slot (per-slot prefill runs)
    pub admissions: usize,
    pub tokens_emitted: usize,
    pub iterations: usize,
    pub accepted_sum: usize,
    /// histogram over acceptance length (index = accepted drafts + bonus)
    pub al_histogram: Vec<usize>,
    /// per-depth acceptance histogram: `accepted_by_depth[d]` counts the
    /// slot-iterations whose raw accepted path reached depth `d` (one
    /// accepted draft node at that depth), before EOS/length truncation —
    /// the signal for tuning tree envelopes and node budgets (a depth whose
    /// count is near zero is wasted draft width). Index 0 is unused.
    pub accepted_by_depth: Vec<usize>,
    /// tree modes: draft nodes activated per slot-iteration, summed (static
    /// trees: the topology size; dynamic trees: the node budget actually
    /// selected). Zero in chain mode.
    pub active_node_sum: usize,
    /// slot-iterations contributing to `active_node_sum`
    pub active_node_steps: usize,
    /// slot-steps with a live request, over all slot-steps the engine ran.
    /// occupied / total is the continuous-batching utilization of the fixed
    /// executable width (1.0 = every row does useful work every step).
    pub slot_steps_occupied: usize,
    pub slot_steps_total: usize,
    /// paged mode: KV blocks actually allocated per step, over the block
    /// budget — TRUE cache occupancy (tokens held, not slots held). Zero in
    /// dense mode.
    pub block_steps_used: usize,
    pub block_steps_total: usize,
    /// paged mode: peak blocks allocated at any step
    pub blocks_peak: usize,
    /// paged preemption pressure: steps where the queue head had a free slot
    /// but not enough free KV blocks to admit
    pub admissions_blocked: usize,
    /// paged tree commits resolved by pure block-table swaps (no data moved)
    pub block_rewires: usize,
    /// paged tree-mode accepted paths committed via the block planner
    /// (rewires and/or block-confined copies — never `compact_kv_path`)
    pub paged_path_commits: usize,
    /// dense tree-mode accepted paths committed via host compaction
    /// (`compact_kv_path`); must stay 0 when paged mode is on
    pub dense_compactions: usize,
    /// prefix cache: admissions whose prompt matched at least one cached
    /// token (shared blocks and/or a copy-on-write sub-block hit)
    pub prefix_hits: usize,
    /// prefix cache: admissions that matched nothing (cold prompts); stays
    /// 0 when the cache is off, so `hits + misses > 0` gates the summary
    pub prefix_misses: usize,
    /// prefix cache: prompt tokens served from cached KV instead of being
    /// prefilled, summed over hits (the TTFT-collapse numerator)
    pub prefix_tokens_cached: usize,
    /// prefix cache: sub-block hits materialized as a private block copy
    pub cow_copies: usize,
    /// prefix cache: cached-idle blocks reclaimed by LRU eviction under
    /// free-list pressure (synced by assignment from the allocator, so
    /// merge SUMS engine-disjoint counts)
    pub prefix_evictions: usize,
    /// prefix cache: peak simultaneously-shared (refcount >= 2) blocks
    pub shared_blocks_peak: usize,
    /// host-transfer accounting, diffed from the runtime-boundary counters
    /// ([`Runtime::transfer_snapshot`](crate::runtime::executable::Runtime))
    /// around each decode step: every upload (tokens, tables, plans, host
    /// args) and download (logits, feats, caches) the step performed.
    /// `transfer_steps` counts the measured steps, so `downloads /
    /// transfer_steps` is the per-step rate the bench suite reports.
    pub transfer_steps: usize,
    pub uploads: u64,
    pub upload_bytes: u64,
    pub downloads: u64,
    pub download_bytes: u64,
    /// downloads of the engine-wide KV state specifically (dense cache or
    /// block pool) during decode steps — the commit-arm host round trips.
    /// The device-resident decode invariant: steady-state paged decode keeps
    /// this at ZERO (logits/feats downloads are per-verify outputs and
    /// unavoidable; the cache itself must never leave the device).
    pub kv_downloads: u64,
    pub kv_uploads: u64,
    /// paged accepted paths committed ON DEVICE via the `commit-path-paged`
    /// executable (subset of `paged_path_commits`; the rest were host
    /// copies or pure table rewires)
    pub device_path_commits: usize,
    pub draft_time: Duration,
    pub verify_time: Duration,
    /// per-slot admission overhead: batch-1 prefill + KV row splice
    pub admission_time: Duration,
    /// tree-mode accepted-path KV compaction (shared host round trip per
    /// step when some slot's accepted path is non-contiguous; always zero
    /// for chain decoding and chain-shaped trees)
    pub commit_time: Duration,
    pub host_time: Duration,
    pub wall_time: Duration,
    pub request_latencies: Vec<Duration>,
    /// submit -> first emitted token, per request (includes queue wait)
    pub ttfts: Vec<Duration>,
    /// per-token inter-token gaps (TPOT): a slot-iteration that emits `m`
    /// tokens after a gap `g` since that slot's previous emission records
    /// `m` samples of `g / m` — so a speculative chunk's burst is amortized
    /// over the tokens it delivered and the quantiles stay comparable to a
    /// one-token-per-step decoder. Samples are per TOKEN (not per request):
    /// `tpot_quantile` answers "what gap does the p-th output token see".
    pub tpots: Vec<Duration>,
    /// per-policy breakdown keyed by policy identity (the `exec_key`
    /// string, `drafter/mode`; singleton for a homogeneous batch) — see
    /// [`PolicyMetrics`]; [`per_drafter`](Self::per_drafter) rolls it up
    pub per_policy: BTreeMap<String, PolicyMetrics>,
}

impl EngineMetrics {
    pub fn new(k: usize) -> EngineMetrics {
        EngineMetrics {
            al_histogram: vec![0; k + 2],
            accepted_by_depth: vec![0; k + 1],
            ..Default::default()
        }
    }

    /// Record one slot-iteration's raw accepted-path depth (`depth` accepted
    /// draft nodes before truncation): every depth `1..=depth` gained one
    /// accepted node. Depths beyond the histogram clamp into the last bin.
    pub fn record_accepted_depth(&mut self, depth: usize) {
        if self.accepted_by_depth.len() <= 1 {
            return;
        }
        let max_d = self.accepted_by_depth.len() - 1;
        for d in 1..=depth.min(max_d) {
            self.accepted_by_depth[d] += 1;
        }
    }

    /// The per-policy slice for identity `key` (an `exec_key` string,
    /// `drafter/mode`), created (sized for `al_max` accepted drafts) on
    /// first touch. Distinct policies get distinct entries even when they
    /// share a drafter; merged streams may still fold entries with
    /// different AL ceilings, so the histograms grow whenever a deeper
    /// toucher arrives — first-touch sizing must never clamp later counts.
    pub fn policy_mut(&mut self, key: &str, al_max: usize) -> &mut PolicyMetrics {
        let pm = self
            .per_policy
            .entry(key.to_string())
            .or_insert_with(|| PolicyMetrics::sized(al_max));
        if pm.al_histogram.len() < al_max + 2 {
            pm.al_histogram.resize(al_max + 2, 0);
            pm.accepted_by_depth.resize(al_max + 1, 0);
        }
        pm
    }

    /// Roll the policy-identity map back up to DRAFTER names (the display
    /// view `serve`/`bench-otps` keep for compatibility): entries whose
    /// keys share the drafter segment before the first `/` merge. A key
    /// without a `/` (pre-identity data folded in via [`merge`](Self::merge))
    /// rolls up under itself.
    pub fn per_drafter(&self) -> BTreeMap<String, PolicyMetrics> {
        let mut out: BTreeMap<String, PolicyMetrics> = BTreeMap::new();
        for (key, pm) in &self.per_policy {
            let drafter = key.split('/').next().unwrap_or(key);
            out.entry(drafter.to_string()).or_default().merge(pm);
        }
        out
    }

    /// Record one tree-mode slot-iteration's active draft-node count.
    pub fn record_active_nodes(&mut self, nodes: usize) {
        self.active_node_sum += nodes;
        self.active_node_steps += 1;
    }

    /// Mean draft nodes activated per slot-iteration (tree modes; 0.0 for
    /// chain decoding).
    pub fn mean_active_nodes(&self) -> f64 {
        if self.active_node_steps == 0 {
            0.0
        } else {
            self.active_node_sum as f64 / self.active_node_steps as f64
        }
    }

    /// Per-depth acceptance rates (`accepted_by_depth[d] / live iterations`)
    /// for depths `1..`, the bench-otps tuning printout.
    pub fn depth_acceptance_rates(&self) -> Vec<f64> {
        let iters: usize = self.al_histogram.iter().sum();
        if iters == 0 {
            return Vec::new();
        }
        self.accepted_by_depth[1..]
            .iter()
            .map(|&c| c as f64 / iters as f64)
            .collect()
    }

    pub fn record_iteration(&mut self, emitted_per_slot: &[usize]) {
        self.iterations += 1;
        for &e in emitted_per_slot {
            if e > 0 {
                self.tokens_emitted += e;
                self.accepted_sum += e;
                if e < self.al_histogram.len() {
                    self.al_histogram[e] += 1;
                } else {
                    let n = self.al_histogram.len();
                    self.al_histogram[n - 1] += 1;
                }
            }
        }
    }

    /// Record one engine step's slot occupancy (`occupied` live rows out of
    /// `width` executable rows).
    pub fn record_occupancy(&mut self, occupied: usize, width: usize) {
        debug_assert!(occupied <= width);
        self.slot_steps_occupied += occupied;
        self.slot_steps_total += width;
    }

    /// Mean slot occupancy over all steps: the fraction of executable rows
    /// that carried a live request (1.0 = no masked/idle rows).
    pub fn mean_occupancy(&self) -> f64 {
        if self.slot_steps_total == 0 {
            0.0
        } else {
            self.slot_steps_occupied as f64 / self.slot_steps_total as f64
        }
    }

    /// Record one paged step's true block occupancy (`used` allocated blocks
    /// out of a `budget`-block pool).
    pub fn record_block_occupancy(&mut self, used: usize, budget: usize) {
        debug_assert!(used <= budget);
        self.block_steps_used += used;
        self.block_steps_total += budget;
        self.blocks_peak = self.blocks_peak.max(used);
    }

    /// Mean fraction of the paged block budget actually allocated per step —
    /// the occupancy the dense cache cannot report (it always holds
    /// `B * S_MAX` tokens' worth). 0.0 in dense mode.
    pub fn mean_block_occupancy(&self) -> f64 {
        if self.block_steps_total == 0 {
            0.0
        } else {
            self.block_steps_used as f64 / self.block_steps_total as f64
        }
    }

    /// Record one decode step's host-transfer delta: `before`/`after` are
    /// [`Runtime::transfer_snapshot`](crate::runtime::executable::Runtime)
    /// tuples `(uploads, upload_bytes, downloads, download_bytes)` taken
    /// around the step.
    pub fn record_step_transfers(
        &mut self,
        before: (u64, u64, u64, u64),
        after: (u64, u64, u64, u64),
    ) {
        self.transfer_steps += 1;
        self.uploads += after.0 - before.0;
        self.upload_bytes += after.1 - before.1;
        self.downloads += after.2 - before.2;
        self.download_bytes += after.3 - before.3;
    }

    /// Mean host downloads per measured decode step (0.0 before any step).
    pub fn downloads_per_step(&self) -> f64 {
        if self.transfer_steps == 0 {
            0.0
        } else {
            self.downloads as f64 / self.transfer_steps as f64
        }
    }

    /// Mean host uploads per measured decode step (0.0 before any step).
    pub fn uploads_per_step(&self) -> f64 {
        if self.transfer_steps == 0 {
            0.0
        } else {
            self.uploads as f64 / self.transfer_steps as f64
        }
    }

    /// Mean acceptance length (accepted drafts + bonus per live iteration).
    pub fn acceptance_length(&self) -> f64 {
        let n: usize = self.al_histogram.iter().sum();
        if n == 0 {
            return 0.0;
        }
        self.al_histogram
            .iter()
            .enumerate()
            .map(|(al, &c)| al * c)
            .sum::<usize>() as f64
            / n as f64
    }

    /// Output tokens per second over the measured wall time (the paper's
    /// OTPS: total across all concurrent requests).
    pub fn otps(&self) -> f64 {
        let s = self.wall_time.as_secs_f64();
        if s == 0.0 {
            0.0
        } else {
            self.tokens_emitted as f64 / s
        }
    }

    pub fn latency_quantile(&self, p: f64) -> Duration {
        quantile(&self.request_latencies, p)
    }

    /// Time-to-first-token quantile (submit -> first token, queue included).
    pub fn ttft_quantile(&self, p: f64) -> Duration {
        quantile(&self.ttfts, p)
    }

    /// Time-per-output-token quantile over the recorded inter-token gaps
    /// (see [`tpots`](Self::tpots)). [`Duration::ZERO`] when no decode
    /// iterations ran — an empty bench cell is a value, not a panic.
    pub fn tpot_quantile(&self, p: f64) -> Duration {
        quantile(&self.tpots, p)
    }

    /// Record one slot-iteration's emission burst for TPOT: `emitted` tokens
    /// delivered `gap` after the slot's previous emission.
    pub fn record_tpot(&mut self, emitted: usize, gap: Duration) {
        if emitted == 0 {
            return;
        }
        let per = gap / emitted as u32;
        for _ in 0..emitted {
            self.tpots.push(per);
        }
    }

    /// Fold another metrics block into this one (e.g. per-EngineCore metrics
    /// accumulated by a scheduler across widths). Wall times add.
    pub fn merge(&mut self, other: &EngineMetrics) {
        self.requests_finished += other.requests_finished;
        self.requests_aborted += other.requests_aborted;
        self.admissions += other.admissions;
        self.tokens_emitted += other.tokens_emitted;
        self.iterations += other.iterations;
        self.accepted_sum += other.accepted_sum;
        if self.al_histogram.len() < other.al_histogram.len() {
            self.al_histogram.resize(other.al_histogram.len(), 0);
        }
        for (i, &c) in other.al_histogram.iter().enumerate() {
            self.al_histogram[i] += c;
        }
        if self.accepted_by_depth.len() < other.accepted_by_depth.len() {
            self.accepted_by_depth.resize(other.accepted_by_depth.len(), 0);
        }
        for (i, &c) in other.accepted_by_depth.iter().enumerate() {
            self.accepted_by_depth[i] += c;
        }
        self.active_node_sum += other.active_node_sum;
        self.active_node_steps += other.active_node_steps;
        self.slot_steps_occupied += other.slot_steps_occupied;
        self.slot_steps_total += other.slot_steps_total;
        self.block_steps_used += other.block_steps_used;
        self.block_steps_total += other.block_steps_total;
        self.blocks_peak = self.blocks_peak.max(other.blocks_peak);
        self.admissions_blocked += other.admissions_blocked;
        self.block_rewires += other.block_rewires;
        self.paged_path_commits += other.paged_path_commits;
        self.dense_compactions += other.dense_compactions;
        self.prefix_hits += other.prefix_hits;
        self.prefix_misses += other.prefix_misses;
        self.prefix_tokens_cached += other.prefix_tokens_cached;
        self.cow_copies += other.cow_copies;
        self.prefix_evictions += other.prefix_evictions;
        self.shared_blocks_peak = self.shared_blocks_peak.max(other.shared_blocks_peak);
        self.transfer_steps += other.transfer_steps;
        self.uploads += other.uploads;
        self.upload_bytes += other.upload_bytes;
        self.downloads += other.downloads;
        self.download_bytes += other.download_bytes;
        self.kv_downloads += other.kv_downloads;
        self.kv_uploads += other.kv_uploads;
        self.device_path_commits += other.device_path_commits;
        self.draft_time += other.draft_time;
        self.verify_time += other.verify_time;
        self.admission_time += other.admission_time;
        self.commit_time += other.commit_time;
        self.host_time += other.host_time;
        self.wall_time += other.wall_time;
        self.request_latencies.extend_from_slice(&other.request_latencies);
        self.ttfts.extend_from_slice(&other.ttfts);
        self.tpots.extend_from_slice(&other.tpots);
        for (name, pm) in &other.per_policy {
            self.per_policy.entry(name.clone()).or_default().merge(pm);
        }
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "req={} tok={} iters={} AL={:.2} OTPS={:.0} occ={:.2} \
             p50TPOT={:?} draft={:?} verify={:?} admit={:?} commit={:?}",
            self.requests_finished,
            self.tokens_emitted,
            self.iterations,
            self.acceptance_length(),
            self.otps(),
            self.mean_occupancy(),
            self.tpot_quantile(0.5),
            self.draft_time,
            self.verify_time,
            self.admission_time,
            self.commit_time,
        );
        if self.block_steps_total > 0 {
            s.push_str(&format!(
                " blkocc={:.2} blkpeak={} blocked={} rewires={}",
                self.mean_block_occupancy(),
                self.blocks_peak,
                self.admissions_blocked,
                self.block_rewires,
            ));
        }
        if self.transfer_steps > 0 {
            s.push_str(&format!(
                " dl/step={:.1} dlMB={:.1} ul/step={:.1} ulMB={:.1} \
                 kvdl={} kvul={} devcommits={}",
                self.downloads_per_step(),
                self.download_bytes as f64 / 1e6,
                self.uploads_per_step(),
                self.upload_bytes as f64 / 1e6,
                self.kv_downloads,
                self.kv_uploads,
                self.device_path_commits,
            ));
        }
        if self.prefix_hits + self.prefix_misses > 0 {
            s.push_str(&format!(
                " pfxhit={}/{} pfxtok={} cow={} pfxevict={} sharedpeak={}",
                self.prefix_hits,
                self.prefix_hits + self.prefix_misses,
                self.prefix_tokens_cached,
                self.cow_copies,
                self.prefix_evictions,
                self.shared_blocks_peak,
            ));
        }
        s
    }
}

/// Empirical quantile over duration samples. Total on ANY input: an empty
/// sample set returns [`Duration::ZERO`] (the smoke-sized bench matrix
/// legitimately produces empty cells — a zero-requests cell must serialize,
/// not panic), and `p` outside `[0, 1]` (or NaN) clamps into range via the
/// index arithmetic (`as usize` saturates).
fn quantile(v: &[Duration], p: f64) -> Duration {
    if v.is_empty() {
        return Duration::ZERO;
    }
    let mut v = v.to_vec();
    v.sort();
    v[((p * v.len() as f64) as usize).min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn al_and_otps() {
        let mut m = EngineMetrics::new(5);
        m.record_iteration(&[3, 5]);
        m.record_iteration(&[1, 0]);
        assert_eq!(m.tokens_emitted, 9);
        assert_eq!(m.iterations, 2);
        // live slot-iterations: 3 (AL entries 3, 5, 1)
        assert!((m.acceptance_length() - 3.0).abs() < 1e-9);
        m.wall_time = Duration::from_secs(3);
        assert!((m.otps() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_clamps() {
        let mut m = EngineMetrics::new(2); // histogram len 4
        m.record_iteration(&[10]);
        assert_eq!(m.al_histogram[3], 1);
    }

    #[test]
    fn latency_quantiles() {
        let mut m = EngineMetrics::new(2);
        for ms in [10u64, 20, 30, 40, 50] {
            m.request_latencies.push(Duration::from_millis(ms));
        }
        assert_eq!(m.latency_quantile(0.0), Duration::from_millis(10));
        assert_eq!(m.latency_quantile(0.99), Duration::from_millis(50));
    }

    #[test]
    fn occupancy_tracking() {
        let mut m = EngineMetrics::new(2);
        assert_eq!(m.mean_occupancy(), 0.0);
        m.record_occupancy(4, 4);
        m.record_occupancy(2, 4);
        m.record_occupancy(1, 4);
        assert!((m.mean_occupancy() - 7.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn ttft_quantiles() {
        let mut m = EngineMetrics::new(2);
        for ms in [5u64, 15, 25] {
            m.ttfts.push(Duration::from_millis(ms));
        }
        assert_eq!(m.ttft_quantile(0.0), Duration::from_millis(5));
        assert_eq!(m.ttft_quantile(0.99), Duration::from_millis(25));
    }

    #[test]
    fn tpot_quantiles() {
        // mirrors ttft_quantiles: direct samples, quantile lookups
        let mut m = EngineMetrics::new(2);
        for ms in [2u64, 4, 6, 8] {
            m.tpots.push(Duration::from_millis(ms));
        }
        assert_eq!(m.tpot_quantile(0.0), Duration::from_millis(2));
        assert_eq!(m.tpot_quantile(0.5), Duration::from_millis(6));
        assert_eq!(m.tpot_quantile(0.99), Duration::from_millis(8));
        assert!(m.summary().contains("p50TPOT"));
    }

    #[test]
    fn tpot_burst_amortizes_over_emitted_tokens() {
        // a 3-token speculative burst 9ms after the previous emission is
        // three 3ms gaps, not one 9ms gap — AL-independent quantiles
        let mut m = EngineMetrics::new(5);
        m.record_tpot(3, Duration::from_millis(9));
        m.record_tpot(1, Duration::from_millis(5));
        m.record_tpot(0, Duration::from_millis(100)); // no tokens, no sample
        assert_eq!(m.tpots.len(), 4);
        assert_eq!(m.tpot_quantile(0.0), Duration::from_millis(3));
        assert_eq!(m.tpot_quantile(0.99), Duration::from_millis(5));
    }

    #[test]
    fn tpot_merges() {
        let mut a = EngineMetrics::new(2);
        a.tpots.push(Duration::from_millis(1));
        let mut b = EngineMetrics::new(2);
        b.record_tpot(2, Duration::from_millis(4));
        a.merge(&b);
        assert_eq!(a.tpots.len(), 3);
        assert_eq!(a.tpot_quantile(1.0), Duration::from_millis(2));
    }

    #[test]
    fn empty_samples_are_values_not_panics() {
        // the smoke bench matrix produces legitimately empty cells: every
        // quantile and ratio helper must return zero, never divide or index
        let m = EngineMetrics::new(3);
        assert_eq!(m.ttft_quantile(0.5), Duration::ZERO);
        assert_eq!(m.tpot_quantile(0.99), Duration::ZERO);
        assert_eq!(m.latency_quantile(0.5), Duration::ZERO);
        assert_eq!(m.otps(), 0.0); // zero wall time
        assert_eq!(m.acceptance_length(), 0.0);
        assert_eq!(m.mean_occupancy(), 0.0);
        assert_eq!(m.mean_block_occupancy(), 0.0);
        assert_eq!(m.mean_active_nodes(), 0.0);
        assert!(m.depth_acceptance_rates().is_empty());
        let pm = PolicyMetrics::default();
        assert_eq!(pm.acceptance_length(), 0.0);
        assert!(pm.depth_acceptance_rates().is_empty());
        // out-of-range quantile args clamp instead of indexing out of bounds
        let mut m = EngineMetrics::new(3);
        m.ttfts.push(Duration::from_millis(7));
        assert_eq!(m.ttft_quantile(2.0), Duration::from_millis(7));
        assert_eq!(m.ttft_quantile(-1.0), Duration::from_millis(7));
        assert_eq!(m.ttft_quantile(f64::NAN), Duration::from_millis(7));
    }

    #[test]
    fn block_occupancy_tracking() {
        let mut m = EngineMetrics::new(2);
        assert_eq!(m.mean_block_occupancy(), 0.0); // dense engines report 0
        m.record_block_occupancy(3, 8);
        m.record_block_occupancy(5, 8);
        assert!((m.mean_block_occupancy() - 8.0 / 16.0).abs() < 1e-12);
        assert_eq!(m.blocks_peak, 5);
        let mut other = EngineMetrics::new(2);
        other.record_block_occupancy(7, 8);
        other.admissions_blocked = 2;
        other.block_rewires = 1;
        other.paged_path_commits = 4;
        m.merge(&other);
        assert_eq!(m.blocks_peak, 7);
        assert_eq!(m.admissions_blocked, 2);
        assert_eq!(m.block_rewires, 1);
        assert_eq!(m.paged_path_commits, 4);
        assert!(m.summary().contains("blkocc"));
    }

    #[test]
    fn transfer_counters_record_merge_and_summarize() {
        let m = EngineMetrics::new(2);
        assert!(!m.summary().contains("dl/step"), "unmeasured engines stay silent");
        assert_eq!(m.downloads_per_step(), 0.0);
        assert_eq!(m.uploads_per_step(), 0.0);
        let mut a = EngineMetrics::new(2);
        // two steps: (3 ul / 1 kB, 2 dl / 2 kB) then (1 ul, 4 dl)
        a.record_step_transfers((0, 0, 0, 0), (3, 1000, 2, 2000));
        a.record_step_transfers((3, 1000, 2, 2000), (4, 1500, 6, 9000));
        a.kv_downloads = 1;
        a.kv_uploads = 1;
        a.device_path_commits = 2;
        assert_eq!(a.transfer_steps, 2);
        assert_eq!(a.uploads, 4);
        assert_eq!(a.upload_bytes, 1500);
        assert_eq!(a.downloads, 6);
        assert_eq!(a.download_bytes, 9000);
        assert!((a.downloads_per_step() - 3.0).abs() < 1e-12);
        assert!((a.uploads_per_step() - 2.0).abs() < 1e-12);
        let mut b = EngineMetrics::new(2);
        b.record_step_transfers((10, 0, 10, 0), (12, 100, 10, 0));
        a.merge(&b);
        assert_eq!(a.transfer_steps, 3);
        assert_eq!(a.uploads, 6);
        assert_eq!(a.downloads, 6, "zero-download steps merge as zeros");
        let s = a.summary();
        assert!(s.contains("dl/step=2.0"), "{s}");
        assert!(s.contains("kvdl=1"), "{s}");
        assert!(s.contains("devcommits=2"), "{s}");
    }

    #[test]
    fn prefix_cache_counters_merge_and_summarize() {
        let m = EngineMetrics::new(2);
        assert!(!m.summary().contains("pfxhit"), "cache-off engines stay silent");
        let mut a = EngineMetrics::new(2);
        a.prefix_hits = 3;
        a.prefix_misses = 1;
        a.prefix_tokens_cached = 96;
        a.cow_copies = 2;
        a.prefix_evictions = 1;
        a.shared_blocks_peak = 4;
        let mut b = EngineMetrics::new(2);
        b.prefix_misses = 2;
        b.shared_blocks_peak = 6;
        a.merge(&b);
        assert_eq!(a.prefix_hits, 3);
        assert_eq!(a.prefix_misses, 3);
        assert_eq!(a.prefix_tokens_cached, 96);
        assert_eq!(a.cow_copies, 2);
        assert_eq!(a.prefix_evictions, 1);
        assert_eq!(a.shared_blocks_peak, 6, "peaks max, not sum");
        let s = a.summary();
        assert!(s.contains("pfxhit=3/6"), "{s}");
        assert!(s.contains("pfxtok=96"), "{s}");
    }

    #[test]
    fn depth_histogram_and_active_nodes() {
        let mut m = EngineMetrics::new(5); // depths 1..=5
        m.record_iteration(&[3, 1]); // 2 live iterations
        m.record_accepted_depth(2); // depths 1, 2
        m.record_accepted_depth(0); // nothing
        assert_eq!(m.accepted_by_depth, vec![0, 1, 1, 0, 0, 0]);
        m.record_accepted_depth(9); // clamps into 1..=5
        assert_eq!(m.accepted_by_depth, vec![0, 2, 2, 1, 1, 1]);
        let rates = m.depth_acceptance_rates();
        assert_eq!(rates.len(), 5);
        assert!((rates[0] - 1.0).abs() < 1e-12); // 2 of 2 iterations hit depth 1
        assert!((rates[4] - 0.5).abs() < 1e-12);
        assert_eq!(m.mean_active_nodes(), 0.0);
        m.record_active_nodes(8);
        m.record_active_nodes(6);
        assert!((m.mean_active_nodes() - 7.0).abs() < 1e-12);
        // merge folds both
        let mut o = EngineMetrics::new(7);
        o.record_accepted_depth(6);
        o.record_active_nodes(4);
        m.merge(&o);
        assert_eq!(m.accepted_by_depth.len(), 8);
        assert_eq!(m.accepted_by_depth[6], 1);
        assert_eq!(m.active_node_steps, 3);
    }

    #[test]
    fn per_policy_breakdown_tracks_each_policy_identity() {
        // satellite: AL, acceptance-by-depth, and step counts keyed by
        // POLICY IDENTITY (exec_key strings), so chain vs dyn rows of the
        // same drafter are separable signal, folded by merge
        let mut m = EngineMetrics::new(5);
        {
            let pe = m.policy_mut("target-m-pe4/dyn:w3x2x1x1x1", 5);
            pe.steps += 1;
            pe.record_iteration(3, 2);
            pe.record_iteration(6, 5);
        }
        {
            let ar = m.policy_mut("target-m-ar/chain:5", 5);
            ar.steps += 1;
            ar.record_iteration(1, 0);
        }
        let pe = &m.per_policy["target-m-pe4/dyn:w3x2x1x1x1"];
        assert_eq!(pe.iterations, 2);
        assert!((pe.acceptance_length() - 4.5).abs() < 1e-12);
        assert_eq!(pe.al_histogram[3], 1);
        assert_eq!(pe.al_histogram[6], 1);
        assert_eq!(pe.accepted_by_depth, vec![0, 2, 2, 1, 1, 1]);
        let rates = pe.depth_acceptance_rates();
        assert!((rates[0] - 1.0).abs() < 1e-12);
        assert!((rates[4] - 0.5).abs() < 1e-12);
        let ar = &m.per_policy["target-m-ar/chain:5"];
        assert_eq!(ar.iterations, 1);
        assert!((ar.acceptance_length() - 1.0).abs() < 1e-12);
        assert_eq!(ar.accepted_by_depth, vec![0, 0, 0, 0, 0, 0]);
        // emitted beyond the histogram clamps into the last bin
        let mut tiny = EngineMetrics::new(1);
        tiny.policy_mut("d/chain:1", 1).record_iteration(9, 9);
        assert_eq!(tiny.per_policy["d/chain:1"].al_histogram, vec![0, 0, 1]);
        assert_eq!(tiny.per_policy["d/chain:1"].accepted_by_depth, vec![0, 1]);
        // a deeper later toucher of the SAME key must grow the entry, not
        // get clamped by whoever touched it first (merged streams)
        tiny.policy_mut("d/chain:1", 5).record_iteration(6, 5);
        assert_eq!(tiny.per_policy["d/chain:1"].al_histogram.len(), 7);
        assert_eq!(tiny.per_policy["d/chain:1"].al_histogram[6], 1);
        assert_eq!(tiny.per_policy["d/chain:1"].accepted_by_depth, vec![0, 2, 1, 1, 1, 1]);
        // a shallower later touch never shrinks it
        tiny.policy_mut("d/chain:1", 1);
        assert_eq!(tiny.per_policy["d/chain:1"].al_histogram.len(), 7);
        // merge folds per-policy slices (and creates missing ones)
        let mut o = EngineMetrics::new(5);
        o.policy_mut("target-m-pe4/dyn:w3x2x1x1x1", 5).record_iteration(2, 1);
        o.policy_mut("target-m-pe2/chain:4", 5).record_iteration(4, 3);
        m.merge(&o);
        assert_eq!(m.per_policy["target-m-pe4/dyn:w3x2x1x1x1"].iterations, 3);
        assert_eq!(m.per_policy.len(), 3);
        assert_eq!(m.per_policy["target-m-pe2/chain:4"].accepted_sum, 4);
    }

    #[test]
    fn per_drafter_rolls_policy_identities_up() {
        // the display-compatibility rollup: two policies of one drafter
        // merge into a single per-drafter row, distinct drafters stay apart
        let mut m = EngineMetrics::new(5);
        m.policy_mut("pe/chain:4", 5).record_iteration(3, 2);
        m.policy_mut("pe/dyn:w3x2x1", 5).record_iteration(5, 4);
        m.policy_mut("ar/chain:5", 5).record_iteration(1, 0);
        let rolled = m.per_drafter();
        assert_eq!(rolled.len(), 2);
        assert_eq!(rolled["pe"].iterations, 2);
        assert_eq!(rolled["pe"].accepted_sum, 8);
        assert!((rolled["pe"].acceptance_length() - 4.0).abs() < 1e-12);
        assert_eq!(rolled["ar"].iterations, 1);
        // depth histograms fold too
        assert_eq!(rolled["pe"].accepted_by_depth, vec![0, 2, 2, 1, 1, 0]);
        // a bare (pre-identity) key rolls up under itself
        m.policy_mut("legacy", 5).record_iteration(2, 1);
        assert_eq!(m.per_drafter()["legacy"].iterations, 1);
    }

    #[test]
    fn draft_q_calibration_accumulates_and_merges() {
        let mut m = EngineMetrics::new(5);
        let pm = m.policy_mut("pe", 5);
        assert_eq!(pm.mean_q_accepted(), 0.0);
        assert_eq!(pm.mean_q_rejected(), 0.0);
        pm.record_draft_q(0.8, true);
        pm.record_draft_q(0.6, true);
        pm.record_draft_q(0.2, false);
        assert!((pm.mean_q_accepted() - 0.7).abs() < 1e-6);
        assert!((pm.mean_q_rejected() - 0.2).abs() < 1e-6);
        let mut o = EngineMetrics::new(5);
        o.policy_mut("pe", 5).record_draft_q(0.4, false);
        m.merge(&o);
        let pm = &m.per_policy["pe"];
        assert_eq!(pm.q_rejected_n, 2);
        assert!((pm.mean_q_rejected() - 0.3).abs() < 1e-6);
        assert_eq!(pm.q_accepted_n, 2);
    }

    #[test]
    fn merge_sums_and_extends() {
        let mut a = EngineMetrics::new(2);
        a.record_iteration(&[2]);
        a.record_occupancy(1, 2);
        a.requests_finished = 1;
        a.wall_time = Duration::from_secs(1);
        let mut b = EngineMetrics::new(5); // longer histogram
        b.record_iteration(&[5]);
        b.record_occupancy(2, 2);
        b.requests_finished = 2;
        b.wall_time = Duration::from_secs(2);
        b.ttfts.push(Duration::from_millis(7));
        a.merge(&b);
        assert_eq!(a.requests_finished, 3);
        assert_eq!(a.tokens_emitted, 7);
        assert_eq!(a.al_histogram.len(), 7);
        assert_eq!(a.al_histogram[2], 1);
        assert_eq!(a.al_histogram[5], 1);
        assert_eq!(a.wall_time, Duration::from_secs(3));
        assert!((a.mean_occupancy() - 3.0 / 4.0).abs() < 1e-12);
        assert_eq!(a.ttfts.len(), 1);
    }
}
