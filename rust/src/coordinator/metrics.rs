//! Engine metrics: OTPS, acceptance length, latency percentiles, per-phase
//! timing. Everything the Table 9/10 benches report comes from here.

use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    pub requests_finished: usize,
    pub tokens_emitted: usize,
    pub iterations: usize,
    pub accepted_sum: usize,
    /// histogram over acceptance length (index = accepted drafts + bonus)
    pub al_histogram: Vec<usize>,
    pub draft_time: Duration,
    pub verify_time: Duration,
    pub prefill_time: Duration,
    pub host_time: Duration,
    pub wall_time: Duration,
    pub request_latencies: Vec<Duration>,
}

impl EngineMetrics {
    pub fn new(k: usize) -> EngineMetrics {
        EngineMetrics { al_histogram: vec![0; k + 2], ..Default::default() }
    }

    pub fn record_iteration(&mut self, emitted_per_slot: &[usize]) {
        self.iterations += 1;
        for &e in emitted_per_slot {
            if e > 0 {
                self.tokens_emitted += e;
                self.accepted_sum += e;
                if e < self.al_histogram.len() {
                    self.al_histogram[e] += 1;
                } else {
                    let n = self.al_histogram.len();
                    self.al_histogram[n - 1] += 1;
                }
            }
        }
    }

    /// Mean acceptance length (accepted drafts + bonus per live iteration).
    pub fn acceptance_length(&self) -> f64 {
        let n: usize = self.al_histogram.iter().sum();
        if n == 0 {
            return 0.0;
        }
        self.al_histogram
            .iter()
            .enumerate()
            .map(|(al, &c)| al * c)
            .sum::<usize>() as f64
            / n as f64
    }

    /// Output tokens per second over the measured wall time (the paper's
    /// OTPS: total across all concurrent requests).
    pub fn otps(&self) -> f64 {
        let s = self.wall_time.as_secs_f64();
        if s == 0.0 {
            0.0
        } else {
            self.tokens_emitted as f64 / s
        }
    }

    pub fn latency_quantile(&self, p: f64) -> Duration {
        if self.request_latencies.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.request_latencies.clone();
        v.sort();
        v[((p * v.len() as f64) as usize).min(v.len() - 1)]
    }

    pub fn summary(&self) -> String {
        format!(
            "req={} tok={} iters={} AL={:.2} OTPS={:.0} draft={:?} verify={:?} prefill={:?}",
            self.requests_finished,
            self.tokens_emitted,
            self.iterations,
            self.acceptance_length(),
            self.otps(),
            self.draft_time,
            self.verify_time,
            self.prefill_time,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn al_and_otps() {
        let mut m = EngineMetrics::new(5);
        m.record_iteration(&[3, 5]);
        m.record_iteration(&[1, 0]);
        assert_eq!(m.tokens_emitted, 9);
        assert_eq!(m.iterations, 2);
        // live slot-iterations: 3 (AL entries 3, 5, 1)
        assert!((m.acceptance_length() - 3.0).abs() < 1e-9);
        m.wall_time = Duration::from_secs(3);
        assert!((m.otps() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_clamps() {
        let mut m = EngineMetrics::new(2); // histogram len 4
        m.record_iteration(&[10]);
        assert_eq!(m.al_histogram[3], 1);
    }

    #[test]
    fn latency_quantiles() {
        let mut m = EngineMetrics::new(2);
        for ms in [10u64, 20, 30, 40, 50] {
            m.request_latencies.push(Duration::from_millis(ms));
        }
        assert_eq!(m.latency_quantile(0.0), Duration::from_millis(10));
        assert_eq!(m.latency_quantile(0.99), Duration::from_millis(50));
    }
}
