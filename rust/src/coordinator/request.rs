//! Request lifecycle types: what a request IS once it leaves the workload
//! generator ([`RequestSpec`], re-exported from `workload::arrivals`), why
//! it stopped ([`FinishReason`]), and what the engine hands back
//! ([`RequestResult`], including the per-request acceptance-length
//! accounting the paper's AL metric is computed from). Everything here is
//! engine-agnostic data — the serving server, scheduler, benches, and tests
//! all speak these types.

pub use crate::workload::RequestSpec;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// EOS token sampled/accepted.
    Eos,
    /// Hit max_new_tokens.
    Length,
    /// KV slot capacity (S_MAX) reached.
    CacheFull,
    /// Evicted by `EngineCore::abort` (partial tokens are returned).
    Aborted,
}

#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub prompt_len: usize,
    /// generated tokens (excluding the prompt)
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// spec-decode iterations this request was live for
    pub iterations: usize,
    /// sum of acceptance lengths (accepted drafts + bonus) over iterations
    pub accepted_sum: usize,
    /// wall-clock from submission to finish (queue wait included — the
    /// serving latency a client observes, not just slot residency)
    pub latency: std::time::Duration,
}

impl RequestResult {
    /// Mean acceptance length (the paper's AL: accepted draft tokens + the
    /// bonus token per iteration; max K+1).
    pub fn acceptance_length(&self) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.accepted_sum as f64 / self.iterations as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn al_math() {
        let r = RequestResult {
            id: 0,
            prompt_len: 8,
            tokens: vec![1; 20],
            finish: FinishReason::Length,
            iterations: 5,
            accepted_sum: 20,
            latency: std::time::Duration::from_millis(10),
        };
        assert_eq!(r.acceptance_length(), 4.0);
    }

    #[test]
    fn al_zero_iterations() {
        let r = RequestResult {
            id: 0,
            prompt_len: 1,
            tokens: vec![],
            finish: FinishReason::Eos,
            iterations: 0,
            accepted_sum: 0,
            latency: std::time::Duration::ZERO,
        };
        assert_eq!(r.acceptance_length(), 0.0);
    }
}
