//! The first-class request API: what a request IS ([`Request`] — prompt,
//! generation budget, per-request [`SamplingParams`] and speculation
//! [`SpecPolicy`]), why it stopped ([`FinishReason`]), and what the engine
//! hands back ([`RequestResult`], including the per-request
//! acceptance-length accounting the paper's AL metric is computed from).
//! Everything here is engine-agnostic data — the serving server, scheduler,
//! benches, and tests all speak these types.
//!
//! # Per-request speculation policies
//!
//! EAGLE-3 shows acceptance length varies sharply by workload, so the right
//! drafter / speculation shape / node budget is a property of the *request*,
//! not of the deployment. [`SpecPolicy`] names a manifest drafter plus a
//! speculation mode (`Chain` / `Tree` / `Dynamic`); a request that carries
//! one is drafted and verified with that policy's own executables inside the
//! same continuously-batched engine step as everyone else (the engine groups
//! occupied slots by policy — see
//! [`EngineCore::step`](super::engine::EngineCore::step)). A request that
//! carries `None` uses the engine's
//! [`default_policy`](super::engine::EngineConfig::default_policy).
//!
//! # Migration note (engine-wide → per-request)
//!
//! `RequestSpec` (formerly defined in `workload::arrivals`) was promoted to
//! [`Request`]; the old name remains as a type alias. The engine-wide
//! `EngineConfig` fields `drafter` / `k` / `tree` / `tree_dynamic` /
//! `sampling` collapsed into [`SpecPolicy`] + [`SamplingParams`] carried
//! here — see the [`EngineConfig`](super::engine::EngineConfig) rustdoc for
//! the field-by-field mapping.

use crate::masking::{DynamicTreeConfig, TreeTopology};

use super::sampler::{SampleConfig, Sampling};

/// Per-request sampling configuration: the mode (greedy or temperature), the
/// serving filters (top-p nucleus / top-k, `1.0` / `0` = off), and the seed
/// of the request's private rng stream. Greedy never draws from the rng, so
/// greedy requests are bit-reproducible regardless of seed or batch
/// placement; temperature requests are reproducible for a fixed
/// (engine seed, request seed) pair. The filters define the request's target
/// distribution with filtered-softmax semantics
/// ([`filtered_probs`](super::sampler::filtered_probs)): softmax at the
/// temperature, top-k, then top-p, renormalized — honored by both direct
/// sampling (prefill first token, bonus tokens) and the rejection-sampling
/// acceptance rules.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    pub mode: Sampling,
    /// nucleus filter; 1.0 = off
    pub top_p: f32,
    /// top-k filter; 0 = off
    pub top_k: usize,
    pub seed: u64,
}

impl SamplingParams {
    pub fn greedy() -> SamplingParams {
        SamplingParams { mode: Sampling::Greedy, top_p: 1.0, top_k: 0, seed: 0 }
    }

    pub fn temperature(t: f32, seed: u64) -> SamplingParams {
        SamplingParams { mode: Sampling::Temperature(t), top_p: 1.0, top_k: 0, seed }
    }

    pub fn with_top_p(mut self, top_p: f32) -> SamplingParams {
        self.top_p = top_p;
        self
    }

    pub fn with_top_k(mut self, top_k: usize) -> SamplingParams {
        self.top_k = top_k;
        self
    }

    /// The per-draw sampler view of these params (everything but the seed —
    /// the seed picks the rng STREAM, the config shapes each draw).
    pub fn config(&self) -> SampleConfig {
        SampleConfig { mode: self.mode, top_p: self.top_p, top_k: self.top_k }
    }

    /// Validate CLI/API input descriptively (the sampler itself clamps
    /// defensively; serving should reject nonsense at the boundary).
    pub fn validate(&self) -> Result<(), String> {
        if let Sampling::Temperature(t) = self.mode {
            if !(t.is_finite() && t >= 0.0) {
                return Err(format!("temperature {t} must be a finite number >= 0"));
            }
        }
        if !(self.top_p.is_finite() && self.top_p > 0.0 && self.top_p <= 1.0) {
            return Err(format!("top-p {} must be in (0, 1]", self.top_p));
        }
        Ok(())
    }
}

impl Default for SamplingParams {
    fn default() -> SamplingParams {
        SamplingParams::greedy()
    }
}

/// A per-request speculation policy: which manifest drafter drafts for this
/// request, and in which shape it speculates.
///
/// Two policies that differ only in the `Dynamic` node `budget` share the
/// same lowered executables (the budget is per-step runtime data, not an
/// executable shape) — [`exec_key`](Self::exec_key) is identical — so a
/// single engine batch can mix budgets freely. Everything else (drafter,
/// chain depth, topology, envelope) is baked into the lowered HLO and keys a
/// distinct executable group.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpecPolicy {
    /// Linear K-token chain speculation (classic EAGLE serving).
    Chain { drafter: String, k: usize },
    /// Static draft-tree speculation: the whole `topology` is drafted and
    /// verified in one pass every step.
    Tree { drafter: String, topology: TreeTopology },
    /// Dynamic confidence-driven tree speculation inside a max-shape
    /// `envelope`: each step activates the `budget` envelope nodes the
    /// drafter is most confident in ([`crate::masking::dynamic`]). The
    /// budget is runtime data — per-request adaptive budgets ride on the
    /// same executables.
    Dynamic { drafter: String, envelope: TreeTopology, budget: usize },
}

impl SpecPolicy {
    pub fn chain(drafter: impl Into<String>, k: usize) -> SpecPolicy {
        SpecPolicy::Chain { drafter: drafter.into(), k }
    }

    pub fn tree(drafter: impl Into<String>, topology: TreeTopology) -> SpecPolicy {
        SpecPolicy::Tree { drafter: drafter.into(), topology }
    }

    pub fn dynamic(
        drafter: impl Into<String>,
        envelope: TreeTopology,
        budget: usize,
    ) -> SpecPolicy {
        SpecPolicy::Dynamic { drafter: drafter.into(), envelope, budget }
    }

    /// The serving default for `drafter` from a [`DynamicTreeConfig`].
    pub fn from_dynamic_config(drafter: impl Into<String>, d: &DynamicTreeConfig) -> SpecPolicy {
        SpecPolicy::Dynamic {
            drafter: drafter.into(),
            envelope: d.envelope.clone(),
            budget: d.node_budget,
        }
    }

    /// Manifest drafter this policy speculates with.
    pub fn drafter(&self) -> &str {
        match self {
            SpecPolicy::Chain { drafter, .. }
            | SpecPolicy::Tree { drafter, .. }
            | SpecPolicy::Dynamic { drafter, .. } => drafter,
        }
    }

    /// Manifest capability name of this policy's mode: `chain` / `tree` /
    /// `dyn` (what python `configs.drafter_modes` records per drafter).
    pub fn mode_name(&self) -> &'static str {
        match self {
            SpecPolicy::Chain { .. } => "chain",
            SpecPolicy::Tree { .. } => "tree",
            SpecPolicy::Dynamic { .. } => "dyn",
        }
    }

    /// Draft width per step: chain depth K, or tree/envelope node count N
    /// (the drafter executable's output width).
    pub fn n_draft(&self) -> usize {
        match self {
            SpecPolicy::Chain { k, .. } => *k,
            SpecPolicy::Tree { topology, .. } => topology.len(),
            SpecPolicy::Dynamic { envelope, .. } => envelope.len(),
        }
    }

    /// Positions a verify step physically WRITES for this policy (the
    /// lowered scatter width, `n_draft + 1`) — what the dense `s_max` fit
    /// must honor.
    pub fn chunk_width(&self) -> usize {
        self.n_draft() + 1
    }

    /// Positions a verify step can COMMIT (accepted path + bonus root):
    /// chain/tree `n_draft + 1`, dynamic `budget + 1` — the per-slot charge
    /// unit for paged block coverage and admission headroom.
    pub fn commit_width(&self) -> usize {
        match self {
            SpecPolicy::Dynamic { envelope, budget, .. } => (*budget).min(envelope.len()) + 1,
            _ => self.n_draft() + 1,
        }
    }

    /// Acceptance-length ceiling (accepted drafts, excluding the bonus):
    /// chain K, tree max depth, dynamic min(envelope depth, budget).
    pub fn al_max(&self) -> usize {
        match self {
            SpecPolicy::Chain { k, .. } => *k,
            SpecPolicy::Tree { topology, .. } => topology.max_depth(),
            SpecPolicy::Dynamic { envelope, budget, .. } => {
                envelope.max_depth().min(*budget)
            }
        }
    }

    /// Executable-group key: requests whose policies share this key run in
    /// the same policy bucket on the same loaded executables. The `Dynamic`
    /// budget is deliberately EXCLUDED (it is runtime data); chain depth and
    /// topology/envelope ids are included (they are baked into the HLO).
    pub fn exec_key(&self) -> String {
        match self {
            SpecPolicy::Chain { drafter, k } => format!("{drafter}/chain:k{k}"),
            SpecPolicy::Tree { drafter, topology } => {
                format!("{drafter}/tree:{}", topology.id())
            }
            SpecPolicy::Dynamic { drafter, envelope, .. } => {
                format!("{drafter}/dyn:{}", envelope.id())
            }
        }
    }

    /// Display id (includes the dynamic budget, unlike
    /// [`exec_key`](Self::exec_key)).
    pub fn id(&self) -> String {
        match self {
            SpecPolicy::Chain { drafter, k } => format!("{drafter}/chain:{k}"),
            SpecPolicy::Tree { drafter, topology } => {
                format!("{drafter}/tree:{}", topology.id())
            }
            SpecPolicy::Dynamic { drafter, envelope, budget } => {
                format!("{drafter}/dyn:{}@{budget}", envelope.id())
            }
        }
    }

    /// Shape validation (no manifest access — drafter existence and
    /// capability are checked by the runtime registry,
    /// [`ModelRuntime::validate_policy`](crate::runtime::ModelRuntime::validate_policy)).
    pub fn validate(&self) -> Result<(), String> {
        match self {
            SpecPolicy::Chain { k, .. } => {
                if *k == 0 {
                    return Err("chain policy needs k >= 1".into());
                }
            }
            SpecPolicy::Tree { topology, .. } => {
                if topology.is_empty() {
                    return Err("tree policy needs a non-empty topology".into());
                }
            }
            SpecPolicy::Dynamic { envelope, budget, .. } => {
                // reuse the DynamicTreeConfig ceilings so CLI/API errors stay
                // descriptive and consistent with PR 4's validation
                DynamicTreeConfig::new(envelope.clone(), *budget)?;
            }
        }
        Ok(())
    }

    /// Parse a CLI mode spec for `drafter`:
    ///
    /// * `chain:K` — linear chain of depth K;
    /// * `tree:<topo>` — static tree, `<topo>` in
    ///   [`TreeTopology::parse`] syntax (`chain:K` or `w:3,2,1,..`);
    /// * `dyn:<envelope>@B` — dynamic selection of B nodes per step inside
    ///   `<envelope>`.
    ///
    /// Untrusted-input safe: every branch funnels through the validated
    /// parsers, so oversized or malformed specs fail with descriptive errors.
    pub fn parse(drafter: &str, mode_spec: &str) -> Result<SpecPolicy, String> {
        let p = if let Some(rest) = mode_spec.strip_prefix("tree:") {
            SpecPolicy::Tree {
                drafter: drafter.into(),
                topology: TreeTopology::parse(rest)?,
            }
        } else if let Some(rest) = mode_spec.strip_prefix("dyn:") {
            let (env, budget) = rest
                .rsplit_once('@')
                .ok_or_else(|| format!("dyn policy {rest:?} needs an `@<budget>` suffix"))?;
            let budget: usize = budget
                .parse()
                .map_err(|_| format!("dyn policy budget {budget:?} is not a number"))?;
            let d = DynamicTreeConfig::parse(env, budget)?;
            SpecPolicy::Dynamic { drafter: drafter.into(), envelope: d.envelope, budget }
        } else if let Some(k) = mode_spec.strip_prefix("chain:") {
            let k: usize =
                k.parse().map_err(|_| format!("chain policy depth {k:?} is not a number"))?;
            SpecPolicy::Chain { drafter: drafter.into(), k }
        } else {
            return Err(format!(
                "unknown policy spec {mode_spec:?} (expected chain:K, tree:<topo>, or \
                 dyn:<envelope>@<budget>)"
            ));
        };
        p.validate()?;
        Ok(p)
    }
}

/// A serving request: prompt + generation budget, plus its own sampling
/// parameters and (optionally) its own speculation policy. `policy: None`
/// uses the engine's default — a stream of policy-free requests behaves
/// exactly like the old engine-wide configuration.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// arrival offset in seconds (0 for closed-loop)
    pub arrival_s: f64,
    pub sampling: SamplingParams,
    /// `None` → the engine's [`default_policy`](super::engine::EngineConfig::default_policy)
    pub policy: Option<SpecPolicy>,
}

/// Migration alias: `RequestSpec` was promoted from `workload::arrivals`
/// into this first-class [`Request`]. Existing code keeps compiling; new
/// code should say [`Request`].
pub type RequestSpec = Request;

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            arrival_s: 0.0,
            sampling: SamplingParams::greedy(),
            policy: None,
        }
    }

    pub fn with_policy(mut self, policy: SpecPolicy) -> Request {
        self.policy = Some(policy);
        self
    }

    pub fn with_sampling(mut self, sampling: SamplingParams) -> Request {
        self.sampling = sampling;
        self
    }

    pub fn with_arrival(mut self, arrival_s: f64) -> Request {
        self.arrival_s = arrival_s;
        self
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// EOS token sampled/accepted.
    Eos,
    /// Hit max_new_tokens.
    Length,
    /// KV slot capacity (S_MAX) reached.
    CacheFull,
    /// Evicted by `EngineCore::abort` (partial tokens are returned).
    Aborted,
}

#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub prompt_len: usize,
    /// generated tokens (excluding the prompt)
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// spec-decode iterations this request was live for
    pub iterations: usize,
    /// sum of acceptance lengths (accepted drafts + bonus) over iterations
    pub accepted_sum: usize,
    /// wall-clock from submission to finish (queue wait included — the
    /// serving latency a client observes, not just slot residency)
    pub latency: std::time::Duration,
}

impl RequestResult {
    /// Mean acceptance length (the paper's AL: accepted draft tokens + the
    /// bonus token per iteration; max K+1).
    pub fn acceptance_length(&self) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.accepted_sum as f64 / self.iterations as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn al_math() {
        let r = RequestResult {
            id: 0,
            prompt_len: 8,
            tokens: vec![1; 20],
            finish: FinishReason::Length,
            iterations: 5,
            accepted_sum: 20,
            latency: std::time::Duration::from_millis(10),
        };
        assert_eq!(r.acceptance_length(), 4.0);
    }

    #[test]
    fn al_zero_iterations() {
        let r = RequestResult {
            id: 0,
            prompt_len: 1,
            tokens: vec![],
            finish: FinishReason::Eos,
            iterations: 0,
            accepted_sum: 0,
            latency: std::time::Duration::ZERO,
        };
        assert_eq!(r.acceptance_length(), 0.0);
    }

    #[test]
    fn policy_widths() {
        let c = SpecPolicy::chain("d", 5);
        assert_eq!(c.n_draft(), 5);
        assert_eq!(c.chunk_width(), 6);
        assert_eq!(c.commit_width(), 6);
        assert_eq!(c.al_max(), 5);

        let t = SpecPolicy::tree("d", TreeTopology::from_widths(&[3, 2, 1, 1, 1]));
        assert_eq!(t.n_draft(), 8);
        assert_eq!(t.chunk_width(), 9);
        assert_eq!(t.commit_width(), 9);
        assert_eq!(t.al_max(), 5);

        let d = SpecPolicy::dynamic("d", TreeTopology::from_widths(&[4, 4, 2, 2, 1]), 3);
        assert_eq!(d.n_draft(), 13);
        assert_eq!(d.chunk_width(), 14, "dynamic scatters the whole envelope");
        assert_eq!(d.commit_width(), 4, "but commits only budget + 1");
        assert_eq!(d.al_max(), 3);
    }

    #[test]
    fn exec_key_ignores_dynamic_budget_only() {
        let env = TreeTopology::from_widths(&[4, 4, 2, 2, 1]);
        let a = SpecPolicy::dynamic("d", env.clone(), 3);
        let b = SpecPolicy::dynamic("d", env.clone(), 8);
        assert_eq!(a.exec_key(), b.exec_key(), "budgets share executables");
        assert_ne!(a.id(), b.id(), "but display ids differ");

        let c5 = SpecPolicy::chain("d", 5);
        let c7 = SpecPolicy::chain("d", 7);
        assert_ne!(c5.exec_key(), c7.exec_key(), "chain depth is baked into the HLO");
        let other = SpecPolicy::dynamic("e", env, 3);
        assert_ne!(a.exec_key(), other.exec_key(), "drafter is part of the key");
        assert_ne!(
            SpecPolicy::chain("d", 5).exec_key(),
            SpecPolicy::tree("d", TreeTopology::chain(5)).exec_key(),
            "chain-k and chain-shaped tree use different executables"
        );
    }

    #[test]
    fn policy_parse_round_trips() {
        let c = SpecPolicy::parse("d", "chain:5").unwrap();
        assert_eq!(c, SpecPolicy::chain("d", 5));
        let t = SpecPolicy::parse("d", "tree:w:3,2,1,1,1").unwrap();
        assert_eq!(t, SpecPolicy::tree("d", TreeTopology::from_widths(&[3, 2, 1, 1, 1])));
        let t2 = SpecPolicy::parse("d", "tree:chain:4").unwrap();
        assert_eq!(t2, SpecPolicy::tree("d", TreeTopology::chain(4)));
        let y = SpecPolicy::parse("d", "dyn:w:4,4,2,2,1@8").unwrap();
        assert_eq!(
            y,
            SpecPolicy::dynamic("d", TreeTopology::from_widths(&[4, 4, 2, 2, 1]), 8)
        );
    }

    #[test]
    fn policy_parse_rejects_malformed_specs_descriptively() {
        for (spec, needle) in [
            ("chain:x", "not a number"),
            ("chain:0", "k >= 1"),
            ("tree:w:", "width profile"),
            ("dyn:w:2,1", "@<budget>"),
            ("dyn:w:2,1@x", "not a number"),
            ("dyn:w:2,1@0", ">= 1"),
            ("dyn:w:2,1@9", "exceeds"),
            ("banana", "unknown policy spec"),
        ] {
            let err = SpecPolicy::parse("d", spec).unwrap_err();
            assert!(err.contains(needle), "{spec:?}: undescriptive error {err:?}");
        }
    }

    #[test]
    fn request_builders() {
        let r = Request::new(7, vec![1, 2, 3], 16)
            .with_policy(SpecPolicy::chain("d", 5))
            .with_sampling(SamplingParams::temperature(0.8, 42))
            .with_arrival(1.5);
        assert_eq!(r.id, 7);
        assert_eq!(r.max_new_tokens, 16);
        assert_eq!(r.arrival_s, 1.5);
        assert_eq!(r.sampling.seed, 42);
        assert_eq!(r.policy.as_ref().unwrap().drafter(), "d");
        let plain = Request::new(0, vec![1], 8);
        assert!(plain.policy.is_none());
        assert_eq!(plain.sampling, SamplingParams::greedy());
    }

    #[test]
    fn sampling_params_filters_and_config() {
        let sp = SamplingParams::temperature(0.7, 42).with_top_p(0.9).with_top_k(8);
        assert_eq!(sp.top_p, 0.9);
        assert_eq!(sp.top_k, 8);
        assert_eq!(sp.seed, 42);
        let cfg = sp.config();
        assert_eq!(cfg.mode, Sampling::Temperature(0.7));
        assert_eq!((cfg.top_p, cfg.top_k), (0.9, 8));
        // defaults mean "filters off"
        let g = SamplingParams::greedy();
        assert_eq!((g.top_p, g.top_k), (1.0, 0));
        assert!(g.config().is_greedy());
    }

    #[test]
    fn sampling_params_validation_is_descriptive() {
        assert!(SamplingParams::greedy().validate().is_ok());
        assert!(SamplingParams::temperature(0.7, 0).with_top_p(0.5).validate().is_ok());
        let err = SamplingParams::temperature(-1.0, 0).validate().unwrap_err();
        assert!(err.contains("temperature"), "{err}");
        let err = SamplingParams::temperature(f32::NAN, 0).validate().unwrap_err();
        assert!(err.contains("temperature"), "{err}");
        let err = SamplingParams::greedy().with_top_p(0.0).validate().unwrap_err();
        assert!(err.contains("top-p"), "{err}");
        let err = SamplingParams::greedy().with_top_p(1.5).validate().unwrap_err();
        assert!(err.contains("top-p"), "{err}");
    }
}
