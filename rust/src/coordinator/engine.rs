//! The stepped speculative-decoding engine core.
//!
//! `EngineCore` is a vLLM-v1-style iteration-level engine: callers
//! `add_request` at any time, and each `step()` performs exactly one
//! {draft -> verify -> accept} iteration across all occupied KV slots.
//! Finished requests are evicted *immediately* and their slots refilled from
//! the admission queue at the start of the next step (per-slot batch-1
//! prefill spliced into the shared KV buffer — see
//! `ModelRuntime::prefill_into_slot`), so a long request never stalls the
//! batch behind it and freed rows never idle. Rows without a live request
//! are masked (inert inputs, outputs ignored) instead of running cloned
//! padding requests.
//!
//! Drafting strategy is data: the `drafter` executable named in the config
//! is either an AR EAGLE-3 scan (K sequential passes inside the HLO) or a
//! P-EAGLE single-pass parallel drafter — the engine logic is identical,
//! which is exactly the paper's deployment story (a drop-in drafter swap in
//! vLLM's continuously batched engine).
//!
//! Speculation *shape* is data too: with [`EngineConfig::tree`] set, each
//! step drafts a static N-node token tree and verifies it in ONE target
//! pass using the precomputed cross-node ancestor mask
//! ([`crate::masking::tree`]). Acceptance generalizes from prefix-of-chain
//! to longest-accepted-root-path ([`super::sampler::accept_tree`]), and the
//! KV cache commits only the accepted path: tree chunks scatter K/V at
//! `base + chunk_slot`, so a non-contiguous accepted path is compacted
//! through the host ([`crate::runtime::compact_kv_path`], one shared
//! download/upload per step, tracked as `EngineMetrics::commit_time`). The
//! chain-shaped topology (`TreeTopology::chain(k)`) takes the exact same
//! code path but never needs compaction, and is byte-identical to classic
//! chain decoding (`tree: None`).
//!
//! Speculation shape can also be *per-step data*: with
//! [`EngineConfig::tree_dynamic`] set, one executable pair is lowered for a
//! max-shape ENVELOPE and each step activates only the `node_budget`
//! envelope nodes the drafter is most confident in
//! ([`crate::masking::dynamic`]): the scored drafter returns per-node joint
//! log-probabilities, selection is greedy frontier expansion (provably the
//! top-budget ancestor-closed subset), and the selected subtree is
//! compacted into the leading chunk slots with its subset mask and RoPE
//! depth offsets passed as per-batch runtime inputs. Acceptance walks the
//! selected subtree ([`super::sampler::accept_tree_subset`]), and the
//! allocator charges speculative scratch and paged admission headroom by
//! the node BUDGET (`SlotManager::chunk`) while the `s_max` fit honors the
//! envelope-wide scatter (`SlotManager::write_width`). A budget equal to
//! the envelope size is byte-identical to the static-topology path.
//!
//! The KV cache *layout* is a config choice too: with [`EngineConfig::paged`]
//! set, the device cache is a block pool addressed through per-slot block
//! tables ([`SlotManager`] becomes a real allocator), admission is gated on
//! free-block headroom, and the tree accepted-path commit becomes
//! block-table rewires plus block-confined copies
//! ([`crate::runtime::kv_blocks`]) instead of the dense host-side
//! compaction. A fully provisioned paged engine is byte-identical to the
//! dense one; a constrained block budget trades queueing (tracked as
//! `admissions_blocked`) for a KV footprint that scales with tokens held.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

use super::kv_cache::SlotManager;
use super::metrics::EngineMetrics;
use super::request::{FinishReason, RequestResult, RequestSpec};
use super::sampler::{accept_chain, accept_tree, accept_tree_subset, sample, Sampling};
use crate::masking::dynamic::{
    compacted_depths_i32, compacted_parents, select_nodes, subset_mask_i32,
};
use crate::masking::{DynamicTreeConfig, TreeMask, TreeTopology};
use crate::runtime::{
    apply_path_copies, compact_kv_path, plan_path_commit, splice_kv_row,
    splice_kv_row_blocks, DraftExec, HostTensor, ModelRuntime, TargetExec,
};
use crate::util::rng::Rng;

/// Block-paged KV cache configuration ([`EngineConfig::paged`]).
///
/// `block_size`: `None` (the default) uses the manifest's `kv_block_size` —
/// the pool layout is baked into the lowered paged executables, so there is
/// exactly one right answer; `Some(bs)` additionally *asserts* that the
/// manifest agrees (a guard against serving stale artifacts). `num_blocks`
/// caps the *logical* block budget the allocator may hand out — `None`
/// means fully provisioned (`batch * s_max / block_size`, byte-identical
/// behavior to the dense cache), smaller values create real admission
/// pressure (requests queue on free blocks, tracked as
/// `EngineMetrics::admissions_blocked`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PagedKvConfig {
    pub block_size: Option<usize>,
    pub num_blocks: Option<usize>,
}

/// `PEAGLE_PAGED=1` flips engines built by the test helpers / benches into
/// paged mode (the CI paged job sets it); anything else returns `None`.
pub fn paged_from_env() -> Option<PagedKvConfig> {
    (std::env::var("PEAGLE_PAGED").ok().as_deref() == Some("1")).then(PagedKvConfig::default)
}

/// `PEAGLE_TREE_DYN=1` flips engines built by the test helpers / benches
/// into dynamic tree mode (the CI `rust-tree-dyn` job sets it): the
/// serving-default envelope + budget
/// ([`DynamicTreeConfig::serving_default`] — the budget equals the static
/// serving tree's node count, so AL comparisons stay apples-to-apples).
/// Anything else returns `None`.
pub fn tree_dyn_from_env() -> Option<DynamicTreeConfig> {
    (std::env::var("PEAGLE_TREE_DYN").ok().as_deref() == Some("1"))
        .then(DynamicTreeConfig::serving_default)
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub target: String,
    /// manifest drafter name (e.g. "target-m-pe4" or "target-m-ar")
    pub drafter: String,
    /// chain speculation depth (ignored when `tree` is set)
    pub k: usize,
    /// engine width == executable batch size (KV slots)
    pub batch: usize,
    /// engine-wide cap; each request also honors its own
    /// `RequestSpec::max_new_tokens` (the lower bound wins)
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    pub seed: u64,
    /// tree-structured speculation: draft/verify this static topology each
    /// step instead of a linear K-chain. `None` = classic chain decoding;
    /// `Some(TreeTopology::chain(k))` is the degenerate tree and must emit
    /// byte-identical tokens (integration-tested).
    pub tree: Option<TreeTopology>,
    /// dynamic confidence-driven tree speculation: one executable per
    /// max-shape ENVELOPE, with a per-step per-slot node subset picked from
    /// the drafter's joint log-probabilities ([`crate::masking::dynamic`]).
    /// Mutually exclusive with `tree`; `node_budget == envelope.len()` is
    /// the degenerate case and must emit byte-identical tokens to the
    /// static topology path (integration-tested).
    pub tree_dynamic: Option<DynamicTreeConfig>,
    /// block-paged KV cache: the device cache becomes a block pool addressed
    /// through per-slot block tables and admission is gated on free-block
    /// headroom. `None` = the dense `[L, 2, B, S_MAX, H, Dh]` cache. A fully
    /// provisioned paged engine must emit byte-identical tokens to the dense
    /// one (integration-tested for chain and tree modes).
    pub paged: Option<PagedKvConfig>,
}

/// One streamed engine occurrence, in emission order within a step.
#[derive(Clone, Debug)]
pub enum EngineEvent {
    /// Request left the queue and owns KV slot `slot` (prefill done, first
    /// token sampled).
    Admitted { id: u64, slot: usize },
    /// Tokens emitted for `id` this step (first token at admission, then one
    /// acceptance chain per step).
    Tokens { id: u64, tokens: Vec<i32> },
    /// Request finished and its slot was freed. Carries the full result.
    Finished(RequestResult),
}

/// What one `step()` did.
#[derive(Debug, Default)]
pub struct StepReport {
    pub events: Vec<EngineEvent>,
    /// requests admitted at the start of this step
    pub admitted: usize,
    /// slots that held a live request during this step's iteration
    pub occupied: usize,
}

impl StepReport {
    /// Results of requests that finished during this step.
    pub fn finished(&self) -> impl Iterator<Item = &RequestResult> {
        self.events.iter().filter_map(|e| match e {
            EngineEvent::Finished(r) => Some(r),
            _ => None,
        })
    }

    pub fn into_finished(self) -> Vec<RequestResult> {
        self.events
            .into_iter()
            .filter_map(|e| match e {
                EngineEvent::Finished(r) => Some(r),
                _ => None,
            })
            .collect()
    }
}

/// Per-slot decode state for one in-flight request.
struct ActiveSlot {
    spec: RequestSpec,
    finished: Option<FinishReason>,
    generated: Vec<i32>,
    last_tok: i32,
    /// rolling drafter context: tokens at consecutive positions
    ctx_tokens: Vec<i32>,
    /// features at those positions minus one, flattened [C * fdim]
    ctx_feats: Vec<f32>,
    /// absolute position of `last_tok`
    pos_last: usize,
    /// effective generation budget: min(spec, engine config)
    max_new: usize,
    iterations: usize,
    accepted_sum: usize,
    t_submit: Instant,
}

impl ActiveSlot {
    fn push_ctx(&mut self, token: i32, feat: &[f32], fdim: usize) {
        self.ctx_tokens.rotate_left(1);
        *self.ctx_tokens.last_mut().unwrap() = token;
        self.ctx_feats.copy_within(fdim.., 0);
        let off = self.ctx_feats.len() - fdim;
        self.ctx_feats[off..].copy_from_slice(feat);
    }

    fn result(self, reason: FinishReason) -> RequestResult {
        RequestResult {
            id: self.spec.id,
            prompt_len: self.spec.prompt.len(),
            tokens: self.generated,
            finish: reason,
            iterations: self.iterations,
            accepted_sum: self.accepted_sum,
            latency: self.t_submit.elapsed(),
        }
    }
}

/// The stepped engine core: fixed executable width, continuous admission.
pub struct EngineCore {
    pub cfg: EngineConfig,
    te: TargetExec,
    te1: TargetExec, // batch-1 prefill executable for per-slot admission
    de: DraftExec,
    /// reusable zeroed batch-1 KV input for admission prefills (PJRT does
    /// not donate inputs, so one buffer serves every admission)
    kv1_zero: xla::PjRtBuffer,
    // manifest-derived shape constants
    fdim: usize,
    ctx: usize,
    p_pad: usize,
    vocab: usize,
    pad_id: i32,
    eos_id: i32,
    kv: xla::PjRtBuffer,
    /// draft width per step: tree/envelope node count N, or chain depth K
    n_draft: usize,
    /// precomputed cross-node ancestor mask ([N+1, N+1] i32), static tree
    /// mode only
    tree_mask: Option<HostTensor>,
    /// dynamic mode: the envelope's bit-packed ancestor mask, gathered into
    /// per-slot subset masks each step
    envelope_mask: Option<TreeMask>,
    slots: Vec<Option<ActiveSlot>>,
    slotmgr: SlotManager,
    queue: VecDeque<(RequestSpec, Instant)>,
    rng: Rng,
    pub metrics: EngineMetrics,
}

impl EngineCore {
    /// Build an engine of width `cfg.batch`: loads/compiles exactly the
    /// executables the step loop runs (batch-wide verify, batch-1 admission
    /// prefill, batch-wide drafter — the tree-shaped variants when
    /// `cfg.tree` is set), allocates the shared zeroed KV buffer, and in
    /// tree mode builds the cross-node ancestor mask ONCE for the engine's
    /// lifetime.
    pub fn new(mr: &mut ModelRuntime, cfg: EngineConfig) -> Result<EngineCore> {
        let b = cfg.batch;
        if b == 0 {
            bail!("engine width must be >= 1");
        }
        if let Some(p) = cfg.paged {
            let bs = mr.manifest.kv_block_size;
            if let Some(want) = p.block_size {
                if want != bs {
                    bail!(
                        "paged block_size {want} != manifest kv_block_size {bs} (the pool \
                         layout is baked into the lowered paged executables)"
                    );
                }
            }
            if mr.manifest.s_max % bs != 0 {
                bail!("s_max {} not divisible by kv_block_size {bs}", mr.manifest.s_max);
            }
        }
        if cfg.tree.is_some() && cfg.tree_dynamic.is_some() {
            bail!(
                "EngineConfig::tree and EngineConfig::tree_dynamic are mutually \
                 exclusive (the dynamic envelope IS the topology)"
            );
        }
        let (te, de, n_draft, tree_mask, envelope_mask) =
            match (&cfg.tree, &cfg.tree_dynamic, cfg.paged) {
                (Some(tree), None, paged) => {
                    let te = match paged {
                        Some(_) => mr.ensure_verify_tree_paged(&cfg.target, b, tree)?,
                        None => mr.ensure_verify_tree(&cfg.target, b, tree)?,
                    };
                    let de = mr.ensure_drafter_tree(&cfg.drafter, b, tree)?;
                    let m = tree.build_mask();
                    let mask = HostTensor::i32(&[m.n, m.n], m.to_i32());
                    (te, de, tree.len(), Some(mask), None)
                }
                (None, Some(dync), paged) => {
                    let env = &dync.envelope;
                    let te = match paged {
                        Some(_) => mr.ensure_verify_tree_dyn_paged(&cfg.target, b, env)?,
                        None => mr.ensure_verify_tree_dyn(&cfg.target, b, env)?,
                    };
                    let de = mr.ensure_drafter_tree_scored(&cfg.drafter, b, env)?;
                    (te, de, env.len(), None, Some(env.build_mask()))
                }
                (None, None, Some(_)) => (
                    mr.ensure_verify_paged(&cfg.target, b, cfg.k)?,
                    mr.ensure_drafter(&cfg.drafter, b, cfg.k)?,
                    cfg.k,
                    None,
                    None,
                ),
                (None, None, None) => (
                    mr.ensure_verify(&cfg.target, b, cfg.k)?,
                    mr.ensure_drafter(&cfg.drafter, b, cfg.k)?,
                    cfg.k,
                    None,
                    None,
                ),
                (Some(_), Some(_), _) => unreachable!("rejected above"),
            };
        let te1 = mr.ensure_prefill(&cfg.target, 1)?;
        let info = mr.manifest.target(&cfg.target)?;
        let fdim = info.feature_dim;
        // paged: the physical pool matches the lowered executable; the
        // allocator's logical budget may be smaller (block 0 stays reserved
        // as the null block either way)
        // dynamic tree mode splits the accounting: blocks/admission charge
        // the COMMITTABLE chunk (node budget + 1 — the over-reservation
        // fix), while the s_max fit keeps honoring the envelope-wide scatter
        // the lowered executable performs (write_width).
        let write_width = n_draft + 1;
        let commit_chunk = cfg
            .tree_dynamic
            .as_ref()
            .map(|d| d.active_nodes() + 1)
            .unwrap_or(write_width);
        let (kv, slotmgr) = match cfg.paged {
            Some(p) => {
                let bs = mr.manifest.kv_block_size;
                let phys = te
                    .num_blocks
                    .ok_or_else(|| anyhow::anyhow!("paged executable carries no num_blocks"))?;
                let budget = p.num_blocks.unwrap_or(phys - 1).min(phys - 1);
                (
                    mr.zero_kv_pool(&cfg.target, phys, bs)?,
                    SlotManager::new_paged(b, mr.manifest.s_max, commit_chunk, bs, budget)
                        .with_write_width(write_width),
                )
            }
            None => (
                mr.zero_kv(&cfg.target, b)?,
                SlotManager::new(b, mr.manifest.s_max, commit_chunk)
                    .with_write_width(write_width),
            ),
        };
        let kv1_zero = mr.zero_kv(&cfg.target, 1)?;
        let mut slots = Vec::with_capacity(b);
        slots.resize_with(b, || None);
        // AL ceiling = max accepted path + bonus: tree depth (or K) + 1;
        // dynamic mode can accept at most budget nodes, and never deeper
        // than the envelope
        let al_max = match (&cfg.tree, &cfg.tree_dynamic) {
            (Some(t), _) => t.max_depth(),
            (_, Some(d)) => d.envelope.max_depth().min(d.active_nodes()),
            _ => cfg.k,
        };
        Ok(EngineCore {
            rng: Rng::new(cfg.seed ^ 0xE4617E),
            metrics: EngineMetrics::new(al_max),
            te,
            te1,
            de,
            kv1_zero,
            fdim,
            ctx: mr.manifest.ctx_window,
            p_pad: mr.manifest.prompt_pad,
            vocab: mr.manifest.vocab,
            pad_id: mr.manifest.pad_id,
            eos_id: mr.manifest.eos_id,
            kv,
            n_draft,
            tree_mask,
            envelope_mask,
            slots,
            slotmgr,
            queue: VecDeque::new(),
            cfg,
        })
    }

    /// Enqueue a request. Validation happens here (not mid-flight): the
    /// prompt must fit the prefill pad, cover the drafter context window,
    /// and leave room for at least one speculation chunk in the KV slot.
    pub fn add_request(&mut self, spec: RequestSpec) -> Result<()> {
        let plen = spec.prompt.len();
        if plen > self.p_pad {
            bail!("request {}: prompt len {plen} > prompt_pad {}", spec.id, self.p_pad);
        }
        if plen < self.ctx {
            bail!("request {}: prompt len {plen} < ctx_window {}", spec.id, self.ctx);
        }
        if plen + self.slotmgr.write_width() > self.slotmgr.s_max {
            bail!(
                "request {}: prompt len {plen} + write width {} > s_max {}",
                spec.id,
                self.slotmgr.write_width(),
                self.slotmgr.s_max
            );
        }
        if !self.slotmgr.request_fits(plen) {
            bail!(
                "request {}: prompt len {plen} + chunk {} needs more KV blocks than \
                 the paged pool's {} total",
                spec.id,
                self.slotmgr.chunk,
                self.slotmgr.blocks_total()
            );
        }
        self.queue.push_back((spec, Instant::now()));
        Ok(())
    }

    /// Abort a queued or in-flight request. Returns its (partial) result —
    /// `None` if the id is unknown. In-flight aborts free the slot
    /// immediately; the next `step()` refills it from the queue.
    pub fn abort(&mut self, id: u64) -> Option<RequestResult> {
        if let Some(qi) = self.queue.iter().position(|(s, _)| s.id == id) {
            let (spec, _) = self.queue.remove(qi).unwrap();
            self.metrics.requests_aborted += 1;
            return Some(RequestResult {
                id: spec.id,
                prompt_len: spec.prompt.len(),
                tokens: Vec::new(),
                finish: FinishReason::Aborted,
                iterations: 0,
                accepted_sum: 0,
                latency: std::time::Duration::ZERO,
            });
        }
        let i = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|s| s.spec.id == id))?;
        let slot = self.slots[i].take().unwrap();
        self.slotmgr.release(i);
        self.metrics.requests_aborted += 1;
        Some(slot.result(FinishReason::Aborted))
    }

    pub fn capacity(&self) -> usize {
        self.cfg.batch
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Queued + in-slot requests (the closed-loop drivers keep this at C).
    pub fn in_flight(&self) -> usize {
        self.occupied() + self.queued()
    }

    pub fn is_idle(&self) -> bool {
        self.in_flight() == 0
    }

    /// Consume the engine and return its accumulated metrics.
    pub fn into_metrics(self) -> EngineMetrics {
        self.metrics
    }

    /// Admit queued requests into free slots: one batch-1 prefill per
    /// request, spliced into the shared KV buffer, first token sampled from
    /// the prefill logits.
    ///
    /// The prefill HLO scatters K/V for *every* row at offset 0, so a
    /// batch-wide prefill mid-flight would clobber occupied slots. Instead
    /// each fresh row is computed alone (rows are independent) and spliced
    /// in through the host — the shared cache makes ONE download/upload
    /// round trip per step no matter how many slots fill, and the whole
    /// admission cost is tracked as `EngineMetrics::admission_time`.
    fn admit_pending(
        &mut self,
        mr: &mut ModelRuntime,
        events: &mut Vec<EngineEvent>,
    ) -> Result<usize> {
        let mut admitted = 0;
        if self.queue.is_empty() {
            return Ok(admitted);
        }
        let mut shared_host: Option<HostTensor> = None; // lazy: skip if no free slot
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                continue;
            }
            // paged gating: a free SLOT is not enough — the queue head also
            // needs free BLOCKS for prompt + one speculation chunk. FIFO: a
            // blocked head defers the whole queue (no head-of-line bypass),
            // counted as preemption pressure. Requests that could never fit
            // were rejected at add_request, so blocks freed by evictions
            // always unblock the head eventually.
            if let Some((front, _)) = self.queue.front() {
                if !self.slotmgr.can_admit(front.prompt.len()) {
                    self.metrics.admissions_blocked += 1;
                    break;
                }
            }
            let Some((spec, t_submit)) = self.queue.pop_front() else { break };
            let t0 = Instant::now();
            let plen = spec.prompt.len();
            self.slotmgr.claim(i, plen).map_err(|e| anyhow::anyhow!(e))?;

            let mut tok_buf = vec![self.pad_id; self.p_pad];
            tok_buf[..plen].copy_from_slice(&spec.prompt);
            let pre = mr.prefill(
                &self.te1,
                &HostTensor::i32(&[1, self.p_pad], tok_buf),
                &HostTensor::i32(&[1], vec![plen as i32]),
                &self.kv1_zero,
            )?;
            let row = mr.rt.download(&pre.kv)?;
            if shared_host.is_none() {
                shared_host = Some(mr.rt.download(&self.kv)?);
            }
            if self.slotmgr.is_paged() {
                splice_kv_row_blocks(shared_host.as_mut().unwrap(), &row, self.slotmgr.table(i), plen)?;
            } else {
                splice_kv_row(shared_host.as_mut().unwrap(), &row, i)?;
            }

            let pre_logits = pre.last_logits.as_f32()?;
            let pre_feats = pre.feats.as_f32()?;
            let t_first = sample(&pre_logits[..self.vocab], self.cfg.sampling, &mut self.rng);

            // seed the drafter's rolling (token, feature) context from the
            // prompt tail; entry j covers position plen - ctx + 1 + j
            let mut ctx_tokens = Vec::with_capacity(self.ctx);
            let mut ctx_feats = vec![0f32; self.ctx * self.fdim];
            for j in 0..self.ctx {
                let p = plen - self.ctx + 1 + j;
                let token = if p < plen { spec.prompt[p] } else { t_first };
                ctx_tokens.push(token);
                let off = (p - 1) * self.fdim;
                ctx_feats[j * self.fdim..(j + 1) * self.fdim]
                    .copy_from_slice(&pre_feats[off..off + self.fdim]);
            }

            let max_new = spec.max_new_tokens.min(self.cfg.max_new_tokens).max(1);
            let mut slot = ActiveSlot {
                finished: None,
                generated: vec![t_first],
                last_tok: t_first,
                ctx_tokens,
                ctx_feats,
                pos_last: plen,
                max_new,
                iterations: 0,
                accepted_sum: 0,
                t_submit,
                spec,
            };
            if t_first == self.eos_id {
                slot.finished = Some(FinishReason::Eos);
            } else if slot.generated.len() >= slot.max_new {
                slot.finished = Some(FinishReason::Length);
            }

            self.metrics.admissions += 1;
            self.metrics.admission_time += t0.elapsed();
            // the prefill's own sampled token counts toward throughput, and
            // defines TTFT (measured from submit, so queue wait is included)
            self.metrics.tokens_emitted += 1;
            self.metrics.ttfts.push(t_submit.elapsed());
            events.push(EngineEvent::Admitted { id: slot.spec.id, slot: i });
            events.push(EngineEvent::Tokens { id: slot.spec.id, tokens: vec![t_first] });
            self.slots[i] = Some(slot);
            admitted += 1;
        }
        if let Some(h) = shared_host {
            let t_up = Instant::now();
            self.kv = mr.rt.upload(&h)?;
            self.metrics.admission_time += t_up.elapsed();
        }
        Ok(admitted)
    }

    /// Evict every slot whose request finished; emits `Finished` events.
    fn evict_finished(&mut self, events: &mut Vec<EngineEvent>) {
        for i in 0..self.slots.len() {
            let done = self.slots[i]
                .as_ref()
                .and_then(|s| s.finished)
                .is_some();
            if !done {
                continue;
            }
            let slot = self.slots[i].take().unwrap();
            self.slotmgr.release(i);
            let reason = slot.finished.unwrap();
            let res = slot.result(reason);
            self.metrics.requests_finished += 1;
            self.metrics.request_latencies.push(res.latency);
            events.push(EngineEvent::Finished(res));
        }
    }

    /// One engine iteration: admit into free slots, then a single
    /// {draft -> verify -> accept} pass over all occupied slots, then evict
    /// whatever finished. Free rows run inert masked inputs and are skipped
    /// on the host side; their outputs are ignored and their KV rows are
    /// fully overwritten at the next admission.
    ///
    /// In tree mode the drafter emits N node tokens, verification scores
    /// the whole tree in one pass against the precomputed ancestor mask,
    /// and only the longest accepted root path is committed to the KV cache
    /// (non-contiguous paths are compacted through the host — ONE shared
    /// download/upload per step regardless of how many slots need it).
    pub fn step(&mut self, mr: &mut ModelRuntime) -> Result<StepReport> {
        let mut events = Vec::new();
        let admitted = self.admit_pending(mr, &mut events)?;
        // a request can finish at admission (EOS / max_new == 1)
        self.evict_finished(&mut events);

        let b = self.cfg.batch;
        let n = self.n_draft; // tree nodes, or chain depth K
        let occupied = self.occupied();
        if occupied == 0 {
            return Ok(StepReport { events, admitted, occupied });
        }
        self.metrics.record_occupancy(occupied, b);
        if self.slotmgr.is_paged() {
            self.metrics
                .record_block_occupancy(self.slotmgr.blocks_used(), self.slotmgr.blocks_total());
        }

        // --- draft inputs (masked rows: PAD tokens, zero feats, pos 0) ----
        let th = Instant::now();
        let (c, fdim) = (self.ctx, self.fdim);
        let mut ctx_tok_buf = vec![self.pad_id; b * c];
        let mut ctx_feat_buf = vec![0f32; b * c * fdim];
        let mut pos_buf = vec![0i32; b];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                ctx_tok_buf[i * c..(i + 1) * c].copy_from_slice(&s.ctx_tokens);
                ctx_feat_buf[i * c * fdim..(i + 1) * c * fdim].copy_from_slice(&s.ctx_feats);
                pos_buf[i] = (s.pos_last - 1) as i32; // row space = token pos - 1
            }
        }
        self.metrics.host_time += th.elapsed();

        let t1 = Instant::now();
        let ct_t = HostTensor::i32(&[b, c], ctx_tok_buf);
        let cf_t = HostTensor::f32(&[b, c, fdim], ctx_feat_buf);
        let p0_t = HostTensor::i32(&[b], pos_buf);
        let (drafts, draft_logp) = if self.cfg.tree_dynamic.is_some() {
            let (t, l) = mr.draft_tree_scored(&self.de, &ct_t, &cf_t, &p0_t)?;
            (t, Some(l))
        } else {
            (mr.draft(&self.de, &ct_t, &cf_t, &p0_t)?, None)
        };
        self.metrics.draft_time += t1.elapsed();
        let draft_toks = drafts.as_i32()?;

        // --- dynamic mode: per-slot confidence-driven node selection -------
        // The drafter scored every envelope node; each occupied slot keeps
        // its top-budget ancestor-closed subset, compacted into the first
        // chunk slots (masking::dynamic).
        let th_sel = Instant::now();
        let mut selections: Vec<Option<Vec<usize>>> = vec![None; b];
        if let Some(dync) = &self.cfg.tree_dynamic {
            let logp = draft_logp.as_ref().unwrap().as_f32()?;
            for (i, s) in self.slots.iter().enumerate() {
                if s.is_some() {
                    let row = &logp[i * n..(i + 1) * n];
                    selections[i] = Some(select_nodes(&dync.envelope, row, dync.node_budget));
                }
            }
        }
        self.metrics.host_time += th_sel.elapsed();

        // --- verify chunk = [last_tok, node_1..node_N]; masked rows PAD ---
        // (dynamic: [last_tok, selected nodes.., PAD..] in compacted layout)
        let mut chunk_buf = vec![self.pad_id; b * (n + 1)];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                chunk_buf[i * (n + 1)] = s.last_tok;
                match &selections[i] {
                    Some(sel) => {
                        for (j, &id) in sel.iter().enumerate() {
                            chunk_buf[i * (n + 1) + 1 + j] = draft_toks[i * n + id - 1];
                        }
                    }
                    None => chunk_buf[i * (n + 1) + 1..(i + 1) * (n + 1)]
                        .copy_from_slice(&draft_toks[i * n..(i + 1) * n]),
                }
                self.slotmgr.begin_spec(i); // chunk KV lands in scratch
            }
        }
        let cache_len = self.slotmgr.cache_len_i32();
        let t2 = Instant::now();
        let chunk_t = HostTensor::i32(&[b, n + 1], chunk_buf);
        let clen_t = HostTensor::i32(&[b], cache_len.clone());
        // paged: the per-slot block tables are an executable input each step
        // (scratch blocks are already reserved — the allocator's coverage
        // invariant — so the chunk scatter always lands in owned blocks)
        let table_t = self.slotmgr.is_paged().then(|| {
            let bs = self.slotmgr.block_size().unwrap();
            let width = self.slotmgr.s_max / bs;
            HostTensor::i32(&[b, width], self.slotmgr.block_table_i32())
        });
        let ver = if let Some(dync) = &self.cfg.tree_dynamic {
            // per-slot subset mask + depth offsets are runtime inputs each
            // step (inactive rows stay all-zero: attend only the committed
            // cache, attended by nobody)
            let env_mask = self.envelope_mask.as_ref().expect("dynamic engine without mask");
            let w = n + 1;
            let mut mask_buf = vec![0i32; b * w * w];
            let mut depth_buf = vec![0i32; b * w];
            for (i, sel) in selections.iter().enumerate() {
                if let Some(sel) = sel {
                    mask_buf[i * w * w..(i + 1) * w * w]
                        .copy_from_slice(&subset_mask_i32(env_mask, sel, w));
                    depth_buf[i * w..(i + 1) * w]
                        .copy_from_slice(&compacted_depths_i32(&dync.envelope, sel, w));
                }
            }
            let mask_t = HostTensor::i32(&[b, w, w], mask_buf);
            let depth_t = HostTensor::i32(&[b, w], depth_buf);
            match &table_t {
                Some(table) => mr.verify_tree_dyn_paged(
                    &self.te, &chunk_t, &clen_t, &mask_t, &depth_t, table, &self.kv,
                )?,
                None => {
                    mr.verify_tree_dyn(&self.te, &chunk_t, &clen_t, &mask_t, &depth_t, &self.kv)?
                }
            }
        } else {
            match (&self.tree_mask, &table_t) {
                (Some(mask), Some(table)) => {
                    mr.verify_tree_paged(&self.te, &chunk_t, &clen_t, mask, table, &self.kv)?
                }
                (Some(mask), None) => {
                    mr.verify_tree(&self.te, &chunk_t, &clen_t, mask, &self.kv)?
                }
                (None, Some(table)) => {
                    mr.verify_paged(&self.te, &chunk_t, &clen_t, table, &self.kv)?
                }
                (None, None) => mr.verify(&self.te, &chunk_t, &clen_t, &self.kv)?,
            }
        };
        self.metrics.verify_time += t2.elapsed();
        self.kv = ver.kv;
        let logits = ver.logits.as_f32()?;
        let feats = ver.feats.as_f32()?;

        // --- acceptance per occupied slot ---------------------------------
        let th2 = Instant::now();
        let vocab = self.vocab;
        let mut emitted_now = vec![0usize; b];
        // slots whose committed path is non-contiguous: (slot, base, path)
        let mut to_compact: Vec<(usize, usize, Vec<usize>)> = Vec::new();
        for (i, s) in self.slots.iter_mut().enumerate() {
            let Some(s) = s.as_mut() else { continue };
            let rows: Vec<&[f32]> = (0..=n)
                .map(|j| {
                    let off = (i * (n + 1) + j) * vocab;
                    &logits[off..off + vocab]
                })
                .collect();
            let slot_drafts = &draft_toks[i * n..(i + 1) * n];
            // accepted path as chunk-slot ids (chain: the identity prefix;
            // dynamic: COMPACTED chunk slots — the walk is confined to the
            // selected subtree)
            let (path, emitted) = if let Some(dync) = &self.cfg.tree_dynamic {
                let sel = selections[i].as_ref().expect("occupied slot without selection");
                let parents = compacted_parents(&dync.envelope, sel);
                let compacted: Vec<i32> =
                    sel.iter().map(|&id| slot_drafts[id - 1]).collect();
                let a = accept_tree_subset(
                    &parents,
                    &compacted,
                    &rows[..=sel.len()],
                    self.cfg.sampling,
                    &mut self.rng,
                );
                (a.accepted_path, a.emitted)
            } else {
                match &self.cfg.tree {
                    Some(tree) => {
                        let a = accept_tree(
                            tree, slot_drafts, &rows, self.cfg.sampling, &mut self.rng,
                        );
                        (a.accepted_path, a.emitted)
                    }
                    None => {
                        let a =
                            accept_chain(slot_drafts, &rows, self.cfg.sampling, &mut self.rng);
                        ((1..=a.n_accepted).collect(), a.emitted)
                    }
                }
            };
            let q = cache_len[i] as usize; // chunk start = pos of last_tok
            s.iterations += 1;
            s.accepted_sum += emitted.len();
            // raw (pre-truncation) acceptance depth: the envelope/budget
            // tuning signal printed by bench-otps
            self.metrics.record_accepted_depth(path.len());
            if self.cfg.tree.is_some() || self.cfg.tree_dynamic.is_some() {
                let active = selections[i].as_ref().map(|sel| sel.len()).unwrap_or(n);
                self.metrics.record_active_nodes(active);
            }

            let mut step_toks = Vec::with_capacity(emitted.len());
            for (m, &tok) in emitted.iter().enumerate() {
                let p = q + m + 1; // absolute (compacted) position
                s.generated.push(tok);
                step_toks.push(tok);
                // features of this token's predecessor: the accepted node
                // that drafted position p - 1 (the root for m == 0)
                let pred = if m == 0 { 0 } else { path[m - 1] };
                let foff = (i * (n + 1) + pred) * fdim;
                s.push_ctx(tok, &feats[foff..foff + fdim], fdim);
                s.last_tok = tok;
                s.pos_last = p;
                if tok == self.eos_id {
                    s.finished = Some(FinishReason::Eos);
                    break;
                }
                if s.generated.len() >= s.max_new {
                    s.finished = Some(FinishReason::Length);
                    break;
                }
            }
            emitted_now[i] = step_toks.len();
            // commit root + the accepted nodes actually kept (truncation at
            // EOS/length only happens when the request finishes)
            if !self.slotmgr.commit_spec(i, step_toks.len()) && s.finished.is_none() {
                s.finished = Some(FinishReason::CacheFull);
            }
            if s.finished.is_none() {
                let kept = step_toks.len().saturating_sub(1).min(path.len());
                if !path[..kept].iter().enumerate().all(|(j, &node)| node == j + 1) {
                    to_compact.push((i, q, path[..kept].to_vec()));
                }
            }
            events.push(EngineEvent::Tokens { id: s.spec.id, tokens: step_toks });
        }
        self.metrics.host_time += th2.elapsed();
        self.metrics.record_iteration(&emitted_now);

        // --- accepted-path KV commit (tree mode, non-contiguous paths) -----
        // Dense: compact rows through one shared host round trip
        // (compact_kv_path). Paged: NEVER calls compact_kv_path — each path
        // gets a block-granular plan: table-entry swaps (pure pointer
        // surgery, no pool round trip) when the path is a block-aligned
        // uniform shift, position copies confined to the chunk's blocks
        // otherwise; the pool round-trips through the host only when some
        // plan actually has copies.
        if !to_compact.is_empty() {
            let tc = Instant::now();
            if self.slotmgr.is_paged() {
                let bs = self.slotmgr.block_size().unwrap();
                let mut copy_jobs: Vec<(usize, Vec<(usize, usize)>)> = Vec::new();
                for (slot, base, path) in &to_compact {
                    let plan = plan_path_commit(*base, path, bs);
                    self.metrics.block_rewires += plan.swaps.len();
                    for &(a, c) in &plan.swaps {
                        self.slotmgr.swap_blocks(*slot, a, c);
                    }
                    if !plan.copies.is_empty() {
                        copy_jobs.push((*slot, plan.copies));
                    }
                }
                self.metrics.paged_path_commits += to_compact.len();
                if !copy_jobs.is_empty() {
                    let mut host = mr.rt.download(&self.kv)?;
                    for (slot, copies) in &copy_jobs {
                        apply_path_copies(&mut host, self.slotmgr.table(*slot), copies)?;
                    }
                    self.kv = mr.rt.upload(&host)?;
                }
            } else {
                self.metrics.dense_compactions += to_compact.len();
                let mut host = mr.rt.download(&self.kv)?;
                for (slot, base, path) in &to_compact {
                    compact_kv_path(&mut host, *slot, *base, path)?;
                }
                self.kv = mr.rt.upload(&host)?;
            }
            self.metrics.commit_time += tc.elapsed();
        }

        self.evict_finished(&mut events);
        Ok(StepReport { events, admitted, occupied })
    }

    /// Drive `step()` until queue and slots are empty; returns all results
    /// in finish order. (Small convenience used by the thin scheduler and
    /// the drain paths; streaming callers consume `step()` directly.)
    pub fn run_until_idle(&mut self, mr: &mut ModelRuntime) -> Result<Vec<RequestResult>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step(mr)?.into_finished());
        }
        Ok(out)
    }
}
