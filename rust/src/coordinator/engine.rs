//! The speculative-decoding engine: drives one *wave* (a fixed-batch group
//! of requests sharing a KV buffer) through prefill → {draft → verify →
//! accept} → finish.
//!
//! Drafting strategy is data: the `drafter` executable named in the config
//! is either an AR EAGLE-3 scan (K sequential passes inside the HLO) or a
//! P-EAGLE single-pass parallel drafter — the engine logic is identical,
//! which is exactly the paper's deployment story (a drop-in drafter swap in
//! vLLM).

use std::time::Instant;

use anyhow::{bail, Result};

use super::kv_cache::SlotManager;
use super::metrics::EngineMetrics;
use super::request::{FinishReason, RequestResult, RequestSpec};
use super::sampler::{accept_chain, sample, Sampling};
use crate::runtime::{HostTensor, ModelRuntime};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub target: String,
    /// manifest drafter name (e.g. "target-m-pe4" or "target-m-ar")
    pub drafter: String,
    pub k: usize,
    /// wave width == executable batch size
    pub batch: usize,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
    pub seed: u64,
}

struct WaveSlot {
    spec: RequestSpec,
    /// false for padding rows that fill the fixed batch
    real: bool,
    finished: Option<FinishReason>,
    generated: Vec<i32>,
    last_tok: i32,
    /// rolling drafter context: tokens at consecutive positions
    ctx_tokens: Vec<i32>,
    /// features at those positions minus one, flattened [C * fdim]
    ctx_feats: Vec<f32>,
    /// absolute position of `last_tok`
    pos_last: usize,
    iterations: usize,
    accepted_sum: usize,
    t_start: Instant,
}

impl WaveSlot {
    fn push_ctx(&mut self, token: i32, feat: &[f32], fdim: usize) {
        self.ctx_tokens.rotate_left(1);
        *self.ctx_tokens.last_mut().unwrap() = token;
        self.ctx_feats.copy_within(fdim.., 0);
        let off = self.ctx_feats.len() - fdim;
        self.ctx_feats[off..].copy_from_slice(feat);
    }
}

/// Process one wave of at most `cfg.batch` requests to completion.
pub fn run_wave(
    mr: &mut ModelRuntime,
    cfg: &EngineConfig,
    requests: Vec<RequestSpec>,
    metrics: &mut EngineMetrics,
) -> Result<Vec<RequestResult>> {
    let b = cfg.batch;
    let k = cfg.k;
    assert!(!requests.is_empty() && requests.len() <= b);
    let n_real = requests.len();

    let te = mr.ensure_target(&cfg.target, b, k)?;
    let de = mr.ensure_drafter(&cfg.drafter, b, k)?;
    let fdim = mr.manifest.target(&cfg.target)?.feature_dim;
    let c = mr.manifest.ctx_window;
    let p_pad = mr.manifest.prompt_pad;
    let s_max = mr.manifest.s_max;
    let (pad_id, eos_id) = (mr.manifest.pad_id, mr.manifest.eos_id);
    let mut rng = Rng::new(cfg.seed ^ 0xE4617E);

    // --- assemble the padded wave -------------------------------------
    let mut specs = requests;
    while specs.len() < b {
        // padding rows recycle the first request's prompt; results discarded
        let mut pad = specs[0].clone();
        pad.id = u64::MAX;
        specs.push(pad);
    }
    for s in &specs {
        if s.prompt.len() > p_pad {
            bail!("prompt len {} > prompt_pad {p_pad}", s.prompt.len());
        }
        if s.prompt.len() < c {
            bail!("prompt len {} < ctx_window {c}", s.prompt.len());
        }
    }

    // --- prefill --------------------------------------------------------
    let mut tok_buf = vec![pad_id; b * p_pad];
    let mut len_buf = vec![0i32; b];
    for (i, s) in specs.iter().enumerate() {
        tok_buf[i * p_pad..i * p_pad + s.prompt.len()].copy_from_slice(&s.prompt);
        len_buf[i] = s.prompt.len() as i32;
    }
    let kv0 = mr.zero_kv(&cfg.target, b)?;
    let t0 = Instant::now();
    let pre = mr.prefill(
        &te,
        &HostTensor::i32(&[b, p_pad], tok_buf),
        &HostTensor::i32(&[b], len_buf),
        &kv0,
    )?;
    metrics.prefill_time += t0.elapsed();
    let mut kv = pre.kv;

    let vocab = mr.manifest.vocab;
    let mut slots: Vec<WaveSlot> = Vec::with_capacity(b);
    let mut slotmgr = SlotManager::new(b, s_max, k + 1);
    let pre_feats = pre.feats.as_f32()?;
    let pre_logits = pre.last_logits.as_f32()?;
    for (i, spec) in specs.iter().enumerate() {
        let plen = spec.prompt.len();
        let t_first = sample(&pre_logits[i * vocab..(i + 1) * vocab], cfg.sampling, &mut rng);
        let mut ctx_tokens = Vec::with_capacity(c);
        let mut ctx_feats = vec![0f32; c * fdim];
        for j in 0..c {
            let p = plen - c + 1 + j; // token position of ctx entry j
            let token = if p < plen { spec.prompt[p] } else { t_first };
            ctx_tokens.push(token);
            // feature at position p-1 from the prefill features [B, P, fdim]
            let off = (i * p_pad + (p - 1)) * fdim;
            ctx_feats[j * fdim..(j + 1) * fdim].copy_from_slice(&pre_feats[off..off + fdim]);
        }
        slotmgr.claim(i, plen).map_err(|e| anyhow::anyhow!(e))?;
        let real = i < n_real;
        let mut slot = WaveSlot {
            spec: spec.clone(),
            real,
            finished: None,
            generated: vec![t_first],
            last_tok: t_first,
            ctx_tokens,
            ctx_feats,
            pos_last: plen,
            iterations: 0,
            accepted_sum: 0,
            t_start: Instant::now(),
        };
        if t_first == eos_id {
            slot.finished = Some(FinishReason::Eos);
        } else if slot.generated.len() >= cfg.max_new_tokens {
            slot.finished = Some(FinishReason::Length);
        }
        if real {
            // the prefill's own sampled token counts toward throughput
            metrics.tokens_emitted += 1;
        }
        slots.push(slot);
    }

    // --- spec-decode loop -------------------------------------------------
    let max_iters = cfg.max_new_tokens * 2 + 8;
    let mut ctx_tok_buf = vec![0i32; b * c];
    let mut ctx_feat_buf = vec![0f32; b * c * fdim];
    let mut pos_buf = vec![0i32; b];
    let mut chunk_buf = vec![0i32; b * (k + 1)];
    let mut emitted_now = vec![0usize; b];

    for _iter in 0..max_iters {
        if slots.iter().all(|s| s.finished.is_some()) {
            break;
        }
        // draft inputs
        let th = Instant::now();
        for (i, s) in slots.iter().enumerate() {
            ctx_tok_buf[i * c..(i + 1) * c].copy_from_slice(&s.ctx_tokens);
            ctx_feat_buf[i * c * fdim..(i + 1) * c * fdim].copy_from_slice(&s.ctx_feats);
            pos_buf[i] = (s.pos_last - 1) as i32; // row space = token pos - 1
        }
        metrics.host_time += th.elapsed();

        let t1 = Instant::now();
        let drafts = mr.draft(
            &de,
            &HostTensor::i32(&[b, c], ctx_tok_buf.clone()),
            &HostTensor::f32(&[b, c, fdim], ctx_feat_buf.clone()),
            &HostTensor::i32(&[b], pos_buf.clone()),
        )?;
        metrics.draft_time += t1.elapsed();
        let draft_toks = drafts.as_i32()?;

        // verify chunk = [last_tok, d_1..d_K]
        for (i, s) in slots.iter().enumerate() {
            chunk_buf[i * (k + 1)] = s.last_tok;
            chunk_buf[i * (k + 1) + 1..(i + 1) * (k + 1)]
                .copy_from_slice(&draft_toks[i * k..(i + 1) * k]);
        }
        let cache_len = slotmgr.cache_len_i32();
        let t2 = Instant::now();
        let ver = mr.verify(
            &te,
            &HostTensor::i32(&[b, k + 1], chunk_buf.clone()),
            &HostTensor::i32(&[b], cache_len.clone()),
            &kv,
        )?;
        metrics.verify_time += t2.elapsed();
        kv = ver.kv;
        let logits = ver.logits.as_f32()?;
        let feats = ver.feats.as_f32()?;

        // acceptance per live slot
        let th2 = Instant::now();
        for e in emitted_now.iter_mut() {
            *e = 0;
        }
        for (i, s) in slots.iter_mut().enumerate() {
            if s.finished.is_some() {
                continue;
            }
            let rows: Vec<&[f32]> = (0..=k)
                .map(|j| {
                    let off = (i * (k + 1) + j) * vocab;
                    &logits[off..off + vocab]
                })
                .collect();
            let acc = accept_chain(
                &draft_toks[i * k..(i + 1) * k],
                &rows,
                cfg.sampling,
                &mut rng,
            );
            let q = cache_len[i] as usize; // chunk start = pos of last_tok
            s.iterations += 1;
            s.accepted_sum += acc.emitted.len();

            let mut n_emit = 0usize;
            for (m, &tok) in acc.emitted.iter().enumerate() {
                let p = q + m + 1; // absolute position of this token
                s.generated.push(tok);
                n_emit += 1;
                let foff = (i * (k + 1) + m) * fdim;
                s.push_ctx(tok, &feats[foff..foff + fdim], fdim);
                s.last_tok = tok;
                s.pos_last = p;
                if tok == eos_id {
                    s.finished = Some(FinishReason::Eos);
                    break;
                }
                if s.generated.len() >= cfg.max_new_tokens {
                    s.finished = Some(FinishReason::Length);
                    break;
                }
            }
            emitted_now[i] = if s.real { n_emit } else { 0 };
            if !slotmgr.advance(i, n_emit) && s.finished.is_none() {
                s.finished = Some(FinishReason::CacheFull);
            }
        }
        metrics.host_time += th2.elapsed();
        metrics.record_iteration(&emitted_now);
    }

    // --- results -----------------------------------------------------------
    let mut out = Vec::with_capacity(n_real);
    for (i, s) in slots.into_iter().enumerate() {
        if !s.real {
            continue;
        }
        let finish = s.finished.unwrap_or(FinishReason::Length);
        metrics.requests_finished += 1;
        let latency = s.t_start.elapsed();
        metrics.request_latencies.push(latency);
        slotmgr.release(i);
        out.push(RequestResult {
            id: s.spec.id,
            prompt_len: s.spec.prompt.len(),
            tokens: s.generated,
            finish,
            iterations: s.iterations,
            accepted_sum: s.accepted_sum,
            latency,
        });
    }
    Ok(out)
}
