//! The stepped speculative-decoding engine core.
//!
//! `EngineCore` is a vLLM-v1-style iteration-level engine: callers
//! `add_request` at any time, and each `step()` performs exactly one
//! {draft -> verify -> accept} iteration across all occupied KV slots.
//! Finished requests are evicted *immediately* and their slots refilled from
//! the admission queue at the start of the next step (per-slot batch-1
//! prefill spliced into the shared KV buffer — see
//! `ModelRuntime::prefill_into_slot`), so a long request never stalls the
//! batch behind it and freed rows never idle. Rows without a live request
//! are masked (inert inputs, outputs ignored) instead of running cloned
//! padding requests.
//!
//! Drafting strategy is **per-request data**: every request resolves to a
//! [`SpecPolicy`] — a manifest drafter plus a speculation shape (linear
//! chain, static tree, or dynamic confidence-selected subtree of a
//! max-shape envelope) — either its own or the engine's
//! [`default_policy`](EngineConfig::default_policy). One engine batch can
//! mix an AR chain drafter, a parallel static-tree drafter, and a
//! dynamic-envelope drafter: `step()` groups occupied slots by policy
//! ([`SpecPolicy::exec_key`]) and runs one {draft -> verify -> accept ->
//! commit} pass per bucket over that policy's own executables (loaded on
//! first use from the [`ModelRuntime`] policy registry; all buckets share
//! one target's weights and one KV cache). Acceptance, sampling (per-request
//! [`SamplingParams`](super::request::SamplingParams) with a private rng
//! stream), and KV commit stay
//! per-slot.
//!
//! **Why mixed buckets are safe**: every bucket's verify executable
//! scatters chunk K/V into *every* row (masked rows get PAD chunks), so a
//! bucket's pass writes garbage into the speculative-scratch region of live
//! rows belonging to other buckets. Two invariants make that inert: (1)
//! each live row's scatter always lands at `[len, len + write_width)` where
//! `len` is its *current committed* length (the bucket passes rebuild
//! `cache_len` from the allocator after every bucket's commits) and
//! `write_width` is the engine-wide maximum chunk width over all serveable
//! policies (the `s_max` fit honors it — [`SlotManager`]'s `write_width` vs
//! per-slot `chunk` split), and (2) buckets run *sequentially to
//! completion* — a slot's own verify rewrites its scratch after any earlier
//! bucket's garbage, and its accepted-path commit (including dense
//! compaction / paged block surgery) happens before any later bucket
//! writes. Every committed position is therefore freshly written by the
//! slot's own policy executables in its committing step. A homogeneous
//! batch is exactly one bucket and is byte-identical to the old engine-wide
//! configuration (integration-tested for chain, static tree, and dynamic
//! modes, dense and paged).
//!
//! Speculation shape per policy matches PR 2-4's modes: `Tree` drafts a
//! static N-node token tree and verifies it in ONE target pass against the
//! precomputed cross-node ancestor mask ([`crate::masking::tree`]);
//! `Dynamic` lowers one executable pair per max-shape ENVELOPE and each
//! step activates only the `budget` envelope nodes the drafter is most
//! confident in ([`crate::masking::dynamic`]) — and because the budget is
//! *runtime data*, every request may carry its own (per-slot adaptive
//! budgets: the allocator charges each slot's paged blocks and admission
//! headroom by `budget + 1` while the `s_max` fit honors the envelope-wide
//! scatter). The KV cache *layout* stays an engine-wide choice
//! ([`EngineConfig::paged`]): a block pool addressed through per-slot block
//! tables, admission gated on free-block headroom, accepted-path commits as
//! block-table rewires plus block-confined copies.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use anyhow::{bail, Result};

use super::controller::{ControllerConfig, SpecController};
use super::kv_cache::SlotManager;
use super::metrics::EngineMetrics;
use super::request::{FinishReason, Request, RequestResult, SpecPolicy};
use super::sampler::{
    accept_chain_sampled, accept_tree_sampled, accept_tree_subset_sampled, sample_filtered,
};
use crate::masking::dynamic::{
    compacted_depths_i32, compacted_parents, conditional_q, select_nodes, subset_mask_i32,
};
use crate::masking::{DynamicTreeConfig, TreeMask, TreeTopology};
use crate::runtime::{
    apply_path_copies, compact_kv_path, copy_pool_block, gather_kv_row_blocks,
    physical_copy_rows, plan_path_commit, splice_kv_row, splice_kv_row_blocks_range,
    DraftExec, HostTensor, ModelRuntime, TargetExec,
};
use crate::util::rng::Rng;

/// Block-paged KV cache configuration ([`EngineConfig::paged`]).
///
/// `block_size`: `None` (the default) uses the manifest's `kv_block_size` —
/// the pool layout is baked into the lowered paged executables, so there is
/// exactly one right answer; `Some(bs)` additionally *asserts* that the
/// manifest agrees (a guard against serving stale artifacts). `num_blocks`
/// caps the *logical* block budget the allocator may hand out — `None`
/// means fully provisioned (`batch * s_max / block_size`, byte-identical
/// behavior to the dense cache), smaller values create real admission
/// pressure (requests queue on free blocks, tracked as
/// `EngineMetrics::admissions_blocked`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PagedKvConfig {
    pub block_size: Option<usize>,
    pub num_blocks: Option<usize>,
    /// automatic prefix caching: committed prompt blocks are
    /// content-addressed (chained hash over their token ids) and later
    /// admissions map matching prefix blocks *shared* (copy-on-write),
    /// prefilling only the unique prompt tail. Token output stays
    /// byte-identical to a cold engine (integration-tested); TTFT on
    /// shared-prefix workloads collapses to the tail cost.
    pub prefix_cache: bool,
}

/// `PEAGLE_PAGED=1` flips engines built by the test helpers / benches into
/// paged mode (the CI paged job sets it); anything else returns `None`.
pub fn paged_from_env() -> Option<PagedKvConfig> {
    (std::env::var("PEAGLE_PAGED").ok().as_deref() == Some("1")).then(PagedKvConfig::default)
}

/// `PEAGLE_PREFIX_CACHE=1` flips engines built by the test helpers / benches
/// into paged mode WITH the automatic prefix cache (the CI
/// `rust-prefix-cache` job sets it); anything else defers to
/// [`paged_from_env`], so the helper composes with the paged job unchanged.
pub fn prefix_cache_from_env() -> Option<PagedKvConfig> {
    if std::env::var("PEAGLE_PREFIX_CACHE").ok().as_deref() == Some("1") {
        Some(PagedKvConfig { prefix_cache: true, ..PagedKvConfig::default() })
    } else {
        paged_from_env()
    }
}

/// `PEAGLE_TREE_DYN=1` flips engines built by the test helpers / benches
/// into dynamic tree mode (the CI `rust-tree-dyn` job sets it): the
/// serving-default envelope + budget
/// ([`DynamicTreeConfig::serving_default`] — the budget equals the static
/// serving tree's node count, so AL comparisons stay apples-to-apples).
/// Anything else returns `None`.
pub fn tree_dyn_from_env() -> Option<DynamicTreeConfig> {
    (std::env::var("PEAGLE_TREE_DYN").ok().as_deref() == Some("1"))
        .then(DynamicTreeConfig::serving_default)
}

/// `PEAGLE_MULTI_DRAFTER=1` (the CI `rust-multidrafter` job) makes the test
/// helpers widen their engine configs with extra allowlisted policies
/// (typically the AR chain drafter + the serving static tree), so the whole
/// suite runs with the multi-policy surface active — write-width maxing,
/// per-slot chunk accounting, allowlist validation — while requests still
/// use the default policy, which must stay byte-identical.
pub fn multi_drafter_from_env() -> bool {
    std::env::var("PEAGLE_MULTI_DRAFTER").ok().as_deref() == Some("1")
}

/// `PEAGLE_DEVICE_COMMIT=1` (the CI `rust-device-commit` job) flips the test
/// helpers / benches into paged mode, same as [`paged_from_env`] — the knob
/// exists so a dedicated job exercises the device commit arm end to end.
/// The engine itself needs no flag: whenever the manifest carries the
/// `commit-path-paged` executables (`commit_plan_rows > 0`) a paged engine
/// commits accepted paths on device and only falls back to host copies when
/// the executable is absent or a step's combined plan overflows the lowered
/// row budget.
pub fn device_commit_from_env() -> Option<PagedKvConfig> {
    if std::env::var("PEAGLE_DEVICE_COMMIT").ok().as_deref() == Some("1") {
        Some(PagedKvConfig::default())
    } else {
        prefix_cache_from_env()
    }
}

/// Engine configuration: one target, one executable width, a default
/// speculation policy, and an allowlist of additional serveable policies.
///
/// # Migration (engine-wide speculation -> per-request policies)
///
/// The old engine-wide fields collapsed into [`SpecPolicy`] /
/// [`SamplingParams`](super::request::SamplingParams):
///
/// * `drafter` + `k` -> `default_policy: SpecPolicy::Chain { drafter, k }`;
/// * `drafter` + `tree: Some(t)` -> `SpecPolicy::Tree { drafter, topology: t }`;
/// * `drafter` + `tree_dynamic: Some(d)` ->
///   `SpecPolicy::Dynamic { drafter, envelope: d.envelope, budget: d.node_budget }`;
/// * `sampling` -> per-request [`Request::sampling`] (greedy by default;
///   each request owns a private rng stream seeded from
///   `engine seed ^ request sampling seed`, so greedy output is unchanged
///   and temperature runs are reproducible per request instead of
///   batch-order dependent).
///
/// Requests that carry `policy: None` use `default_policy` — a stream of
/// policy-free requests behaves exactly like the old engine-wide
/// configuration (integration-tested byte parity). Requests may instead
/// carry any policy whose [`SpecPolicy::exec_key`] matches an allowlisted
/// one (`default_policy` or `policies`); dynamic-budget variations share an
/// exec key, so per-request budgets need no extra allowlist entries.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub target: String,
    /// engine width == executable batch size (KV slots)
    pub batch: usize,
    /// engine-wide cap; each request also honors its own
    /// `Request::max_new_tokens` (the lower bound wins)
    pub max_new_tokens: usize,
    /// policy applied to requests that don't carry their own
    pub default_policy: SpecPolicy,
    /// additional serveable policies (the allowlist); the default is always
    /// serveable. Entries are validated against the manifest (drafter
    /// exists, serves `target`, supports the mode) at engine construction;
    /// their executables are loaded lazily on first use.
    pub policies: Vec<SpecPolicy>,
    pub seed: u64,
    /// block-paged KV cache: the device cache becomes a block pool addressed
    /// through per-slot block tables and admission is gated on free-block
    /// headroom. `None` = the dense `[L, 2, B, S_MAX, H, Dh]` cache. A fully
    /// provisioned paged engine must emit byte-identical tokens to the dense
    /// one (integration-tested for every speculation mode).
    pub paged: Option<PagedKvConfig>,
    /// adaptive speculation: a [`SpecController`] assigns every policy-free
    /// request its policy from live windowed signal and re-tunes in-flight
    /// `Dynamic` budgets per step (within each slot's admitted chunk).
    /// Requests that carry their own policy bypass the controller entirely.
    pub adaptive: Option<ControllerConfig>,
}

impl EngineConfig {
    pub fn new(
        target: impl Into<String>,
        default_policy: SpecPolicy,
        batch: usize,
        max_new_tokens: usize,
    ) -> EngineConfig {
        EngineConfig {
            target: target.into(),
            batch,
            max_new_tokens,
            default_policy,
            policies: Vec::new(),
            seed: 0,
            paged: None,
            adaptive: None,
        }
    }

    pub fn with_adaptive(mut self, adaptive: Option<ControllerConfig>) -> EngineConfig {
        self.adaptive = adaptive;
        self
    }

    pub fn with_policies(mut self, policies: Vec<SpecPolicy>) -> EngineConfig {
        self.policies = policies;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> EngineConfig {
        self.seed = seed;
        self
    }

    pub fn with_paged(mut self, paged: Option<PagedKvConfig>) -> EngineConfig {
        self.paged = paged;
        self
    }

    /// Enable the automatic prefix cache (implies paged KV: the cache is a
    /// property of the block allocator, so a dense config is promoted to
    /// the default paged one).
    pub fn with_prefix_cache(mut self) -> EngineConfig {
        let mut p = self.paged.unwrap_or_default();
        p.prefix_cache = true;
        self.paged = Some(p);
        self
    }

    /// Default + allowlisted policies, deduplicated by executable key.
    pub fn allowed_policies(&self) -> Vec<&SpecPolicy> {
        let mut out: Vec<&SpecPolicy> = vec![&self.default_policy];
        for p in &self.policies {
            if !out.iter().any(|a| a.exec_key() == p.exec_key()) {
                out.push(p);
            }
        }
        out
    }

    /// Engine-wide physical scatter width: the widest chunk any serveable
    /// policy writes. Every bucket's verify scatters this far into every
    /// live row (masked garbage for non-members), so the `s_max` fit and
    /// admission checks must honor the maximum.
    pub fn max_write_width(&self) -> usize {
        self.allowed_policies().iter().map(|p| p.chunk_width()).max().unwrap()
    }

    /// Smallest commit width any serveable policy charges — the minimal
    /// per-request paged footprint the scheduler's bucket pick reasons with.
    /// Deliberately scans default + allowlist WITHOUT the exec-key dedup:
    /// dynamic-budget variants share an exec key but charge differently, and
    /// a listed low-budget variant is exactly the footprint the engine's own
    /// per-request gate would admit.
    ///
    /// With the adaptive controller on, every CURRENTLY-ASSIGNABLE policy is
    /// in scope, not just the listed budget variants: the controller may
    /// floor any `Dynamic` policy's budget to `budget_min` (new assignments
    /// AND in-flight retunes), so the static listed budgets would overstate
    /// the floor and `Scheduler::pick_bucket` would queue work a real slot
    /// could serve. Dynamic widths therefore fold the controller floor. The
    /// floor never goes stale in the OTHER direction: in-flight budget moves
    /// are clamped to each slot's admitted chunk ([`EngineCore::step`]), and
    /// assignments above a listed budget only raise per-request widths, not
    /// the minimum.
    pub fn min_commit_width(&self) -> usize {
        let floor = self.adaptive.as_ref().map(|a| a.budget_min);
        std::iter::once(&self.default_policy)
            .chain(self.policies.iter())
            .map(|p| match (p, floor) {
                (SpecPolicy::Dynamic { envelope, budget, .. }, Some(bmin)) => {
                    bmin.min(*budget).min(envelope.len()) + 1
                }
                _ => p.commit_width(),
            })
            .min()
            .unwrap()
    }

    /// Acceptance-length ceiling across serveable policies (metrics
    /// histogram sizing). Dynamic policies use their envelope's depth, the
    /// ceiling over every per-request budget.
    pub fn al_max(&self) -> usize {
        self.allowed_policies().iter().map(|p| al_ceiling(p)).max().unwrap()
    }
}

/// AL ceiling of one policy over every runtime budget it may carry.
fn al_ceiling(p: &SpecPolicy) -> usize {
    match p {
        SpecPolicy::Dynamic { envelope, .. } => envelope.max_depth(),
        _ => p.al_max(),
    }
}

/// One streamed engine occurrence, in emission order within a step.
#[derive(Clone, Debug)]
pub enum EngineEvent {
    /// Request left the queue and owns KV slot `slot` (prefill done, first
    /// token sampled).
    Admitted { id: u64, slot: usize },
    /// Tokens emitted for `id` this step (first token at admission, then one
    /// acceptance chain per step).
    Tokens { id: u64, tokens: Vec<i32> },
    /// Request finished and its slot was freed. Carries the full result.
    Finished(RequestResult),
}

/// What one `step()` did.
#[derive(Debug, Default)]
pub struct StepReport {
    pub events: Vec<EngineEvent>,
    /// requests admitted at the start of this step
    pub admitted: usize,
    /// slots that held a live request during this step's iteration
    pub occupied: usize,
}

impl StepReport {
    /// Results of requests that finished during this step.
    pub fn finished(&self) -> impl Iterator<Item = &RequestResult> {
        self.events.iter().filter_map(|e| match e {
            EngineEvent::Finished(r) => Some(r),
            _ => None,
        })
    }

    pub fn into_finished(self) -> Vec<RequestResult> {
        self.events
            .into_iter()
            .filter_map(|e| match e {
                EngineEvent::Finished(r) => Some(r),
                _ => None,
            })
            .collect()
    }
}

/// Per-slot decode state for one in-flight request.
struct ActiveSlot {
    req: Request,
    /// resolved policy (the request's own, or the engine default) — carries
    /// the per-request dynamic budget
    policy: SpecPolicy,
    /// cached `policy.exec_key()` (the bucket this slot steps with)
    key: String,
    /// the request's private sampling stream (greedy never draws)
    rng: Rng,
    finished: Option<FinishReason>,
    generated: Vec<i32>,
    last_tok: i32,
    /// rolling drafter context: tokens at consecutive positions
    ctx_tokens: Vec<i32>,
    /// features at those positions minus one, flattened [C * fdim]
    ctx_feats: Vec<f32>,
    /// absolute position of `last_tok`
    pos_last: usize,
    /// effective generation budget: min(request, engine config)
    max_new: usize,
    iterations: usize,
    accepted_sum: usize,
    t_submit: Instant,
    /// instant of this slot's most recent token emission (the prefill token
    /// at admission, then reset on every step that commits tokens) — the
    /// inter-token gaps between these feed [`EngineMetrics::record_tpot`]
    t_last_emit: Instant,
}

impl ActiveSlot {
    fn push_ctx(&mut self, token: i32, feat: &[f32], fdim: usize) {
        self.ctx_tokens.rotate_left(1);
        *self.ctx_tokens.last_mut().unwrap() = token;
        self.ctx_feats.copy_within(fdim.., 0);
        let off = self.ctx_feats.len() - fdim;
        self.ctx_feats[off..].copy_from_slice(feat);
    }

    fn result(self, reason: FinishReason) -> RequestResult {
        RequestResult {
            id: self.req.id,
            prompt_len: self.req.prompt.len(),
            tokens: self.generated,
            finish: reason,
            iterations: self.iterations,
            accepted_sum: self.accepted_sum,
            latency: self.t_submit.elapsed(),
        }
    }
}

/// One policy bucket's loaded runtime state: the executable pair plus the
/// masks the policy's verify passes need (built once per group lifetime).
struct PolicyGroup {
    /// the allowlisted archetype this group was loaded for (per-slot dynamic
    /// budgets come from each slot's own policy, not from here)
    archetype: SpecPolicy,
    te: TargetExec,
    de: DraftExec,
    /// draft width per step: tree/envelope node count N, or chain depth K
    n_draft: usize,
    /// static-tree mode: precomputed cross-node ancestor mask ([N+1, N+1])
    tree_mask: Option<HostTensor>,
    /// dynamic mode: the envelope's bit-packed ancestor mask, gathered into
    /// per-slot subset masks each step
    envelope_mask: Option<TreeMask>,
}

/// The stepped engine core: fixed executable width, continuous admission,
/// per-request speculation policies.
pub struct EngineCore {
    pub cfg: EngineConfig,
    /// policy buckets by exec key, loaded on first use (the default policy
    /// eagerly at construction). BTreeMap => deterministic bucket order.
    groups: BTreeMap<String, PolicyGroup>,
    /// validated archetypes (default + allowlist), for admission checks
    allowed: Vec<SpecPolicy>,
    te1: TargetExec, // batch-1 prefill executable for per-slot admission
    /// batch-1 tail-only prefill for prefix-cache hits; `None` when the
    /// cache is off or the manifest predates the `prefill-cached`
    /// executables (hits then dedup memory but still pay a full prefill)
    te_cached: Option<TargetExec>,
    /// device-side accepted-path commit over the paged pool
    /// (`commit-path-paged`); `None` when the engine is dense or the
    /// manifest predates device commit — non-aligned paths then fall back
    /// to the host download/copy/upload round trip
    te_commit: Option<TargetExec>,
    /// reusable zeroed batch-1 KV input for admission prefills (PJRT does
    /// not donate inputs, so one buffer serves every admission)
    kv1_zero: xla::PjRtBuffer,
    // manifest-derived shape constants
    /// token operand width of `prefill-cached` (manifest `prefix_tail_pad`)
    tail_pad: usize,
    fdim: usize,
    ctx: usize,
    p_pad: usize,
    vocab: usize,
    pad_id: i32,
    eos_id: i32,
    kv: xla::PjRtBuffer,
    /// physical block-pool size the paged executables were lowered with
    phys_blocks: Option<usize>,
    slots: Vec<Option<ActiveSlot>>,
    slotmgr: SlotManager,
    queue: VecDeque<(Request, SpecPolicy, Instant)>,
    /// adaptive speculation controller ([`EngineConfig::adaptive`]): assigns
    /// policy-free admissions and re-tunes in-flight dynamic budgets
    controller: Option<SpecController>,
    pub metrics: EngineMetrics,
}

impl EngineCore {
    /// Build an engine of width `cfg.batch`: validates every serveable
    /// policy against the manifest (drafter exists, serves the target,
    /// supports the mode — descriptive errors at startup, not mid-flight),
    /// eagerly loads the default policy's executables (allowlisted ones load
    /// on first use), allocates the shared zeroed KV buffer, and sizes the
    /// allocator: per-slot commit chunks by each request's policy, the
    /// engine-wide write width by the widest serveable policy.
    pub fn new(mr: &mut ModelRuntime, cfg: EngineConfig) -> Result<EngineCore> {
        let b = cfg.batch;
        if b == 0 {
            bail!("engine width must be >= 1");
        }
        if let Some(p) = cfg.paged {
            let bs = mr.manifest.kv_block_size;
            if let Some(want) = p.block_size {
                if want != bs {
                    bail!(
                        "paged block_size {want} != manifest kv_block_size {bs} (the pool \
                         layout is baked into the lowered paged executables)"
                    );
                }
            }
            if mr.manifest.s_max % bs != 0 {
                bail!("s_max {} not divisible by kv_block_size {bs}", mr.manifest.s_max);
            }
        }
        let allowed: Vec<SpecPolicy> =
            cfg.allowed_policies().into_iter().cloned().collect();
        for p in &allowed {
            // capability gate AND executable-existence probe (pure manifest
            // lookups): a policy lowered at the wrong batch width fails HERE
            // with the descriptive find_exec error, never mid-flight — only
            // the compile/load of non-default policies stays lazy.
            mr.probe_policy_execs(&cfg.target, p, b, cfg.paged.is_some())?;
        }
        let write_width = cfg.max_write_width();
        let al_max = cfg.al_max();
        // the controller chooses among exactly the probed allowlist (default
        // first — its cold-start assignment), so it can never assign a
        // policy the registry can't serve
        let controller = cfg
            .adaptive
            .as_ref()
            .map(|c| SpecController::new(c.clone(), allowed.clone()))
            .transpose()
            .map_err(|e| anyhow::anyhow!(e))?;

        // the default policy drives immediate serving — load it now so a
        // missing executable fails at construction, and (paged) so the
        // physical pool size is known before allocating the pool
        let mut groups = BTreeMap::new();
        let default_group =
            load_group(mr, &cfg.target, &cfg.default_policy, b, cfg.paged.is_some())?;
        let te1 = mr.ensure_prefill(&cfg.target, 1)?;
        let info = mr.manifest.target(&cfg.target)?;
        let fdim = info.feature_dim;
        // per-slot commit chunks are claimed per request; the constructor
        // default covers the default policy. write_width is engine-wide: in
        // a multi-policy batch EVERY bucket's verify scatters (masked
        // garbage) into every live row, so the s_max fit honors the maximum.
        let commit_default = cfg.default_policy.commit_width();
        let (kv, slotmgr, phys_blocks) = match cfg.paged {
            Some(p) => {
                let bs = mr.manifest.kv_block_size;
                let phys = default_group
                    .te
                    .num_blocks
                    .ok_or_else(|| anyhow::anyhow!("paged executable carries no num_blocks"))?;
                let budget = p.num_blocks.unwrap_or(phys - 1).min(phys - 1);
                let mut sm =
                    SlotManager::new_paged(b, mr.manifest.s_max, commit_default, bs, budget)
                        .with_write_width(write_width);
                if p.prefix_cache {
                    sm = sm.with_prefix_cache();
                }
                (mr.zero_kv_pool(&cfg.target, phys, bs)?, sm, Some(phys))
            }
            None => (
                mr.zero_kv(&cfg.target, b)?,
                SlotManager::new(b, mr.manifest.s_max, commit_default)
                    .with_write_width(write_width),
                None,
            ),
        };
        // the tail-only prefill is an optimization, not a capability: an
        // artifact set lowered before it still serves (with full prefills)
        let te_cached = match cfg.paged {
            Some(p) if p.prefix_cache => mr.ensure_prefill_cached(&cfg.target).ok(),
            _ => None,
        };
        // like the tail prefill: device commit is an optimization the engine
        // uses whenever the artifacts carry it, never a capability callers
        // must opt into — older manifests just keep the host commit path
        let te_commit = match cfg.paged {
            Some(_) if mr.manifest.commit_plan_rows > 0 => {
                mr.ensure_commit_path_paged(&cfg.target, b).ok()
            }
            _ => None,
        };
        let kv1_zero = mr.zero_kv(&cfg.target, 1)?;
        let mut slots = Vec::with_capacity(b);
        slots.resize_with(b, || None);
        groups.insert(cfg.default_policy.exec_key(), default_group);
        Ok(EngineCore {
            metrics: EngineMetrics::new(al_max),
            groups,
            allowed,
            te1,
            te_cached,
            te_commit,
            kv1_zero,
            tail_pad: mr.manifest.prefix_tail_pad,
            fdim,
            ctx: mr.manifest.ctx_window,
            p_pad: mr.manifest.prompt_pad,
            vocab: mr.manifest.vocab,
            pad_id: mr.manifest.pad_id,
            eos_id: mr.manifest.eos_id,
            kv,
            phys_blocks,
            slots,
            slotmgr,
            queue: VecDeque::new(),
            controller,
            cfg,
        })
    }

    /// The adaptive controller, when [`EngineConfig::adaptive`] is on
    /// (serve/bench status lines read its [`SpecController::summary`]).
    pub fn controller(&self) -> Option<&SpecController> {
        self.controller.as_ref()
    }

    /// Drop the device commit executable: accepted-path copies then take
    /// the host download/copy/upload fallback. The parity baseline for the
    /// device path (integration_device_commit.rs) and a debugging escape
    /// hatch — byte-identical output either way.
    pub fn force_host_commit(&mut self) {
        self.te_commit = None;
    }

    /// Whether the device commit arm is armed (paged engine + manifest
    /// carries `commit-path-paged` at this width).
    pub fn device_commit_armed(&self) -> bool {
        self.te_commit.is_some()
    }

    /// Load a policy bucket's executables on first use (the registry caches
    /// by exec key, so re-creating an engine is cheap). Paged groups must
    /// address the same physical pool the engine allocated.
    fn ensure_group(&mut self, mr: &mut ModelRuntime, policy: &SpecPolicy) -> Result<()> {
        let key = policy.exec_key();
        if self.groups.contains_key(&key) {
            return Ok(());
        }
        let group =
            load_group(mr, &self.cfg.target, policy, self.cfg.batch, self.cfg.paged.is_some())?;
        if let Some(phys) = self.phys_blocks {
            if group.te.num_blocks != Some(phys) {
                bail!(
                    "policy {}: paged executable lowered for {:?} blocks, engine pool has \
                     {phys} (stale artifacts?)",
                    policy.id(),
                    group.te.num_blocks
                );
            }
        }
        self.groups.insert(key, group);
        Ok(())
    }

    /// Enqueue a request. Validation happens here (not mid-flight): the
    /// prompt must fit the prefill pad, cover the drafter context window,
    /// and leave room for at least one speculation chunk in the KV slot; the
    /// request's policy (or the engine default) must be serveable — its
    /// [`SpecPolicy::exec_key`] must match an allowlisted policy's (dynamic
    /// budgets vary freely within one key).
    pub fn add_request(&mut self, req: Request) -> Result<()> {
        let plen = req.prompt.len();
        if plen > self.p_pad {
            bail!("request {}: prompt len {plen} > prompt_pad {}", req.id, self.p_pad);
        }
        if plen < self.ctx {
            bail!("request {}: prompt len {plen} < ctx_window {}", req.id, self.ctx);
        }
        if plen + self.slotmgr.write_width() > self.slotmgr.s_max {
            bail!(
                "request {}: prompt len {plen} + write width {} > s_max {}",
                req.id,
                self.slotmgr.write_width(),
                self.slotmgr.s_max
            );
        }
        // policy-free requests go through the adaptive controller when it is
        // on (cold start = engine default); explicit policies bypass it
        let policy = match (&req.policy, &self.controller) {
            (Some(p), _) => p.clone(),
            (None, Some(ctl)) => ctl.assign(),
            (None, None) => self.cfg.default_policy.clone(),
        };
        policy
            .validate()
            .map_err(|e| anyhow::anyhow!("request {}: invalid policy: {e}", req.id))?;
        let key = policy.exec_key();
        if !self.allowed.iter().any(|a| a.exec_key() == key) {
            let serveable: Vec<String> = self.allowed.iter().map(|a| a.id()).collect();
            bail!(
                "request {}: policy {} is not serveable by this engine (allowlist: [{}]) — \
                 add it to EngineConfig::policies or serve with --drafters/--policy",
                req.id,
                policy.id(),
                serveable.join(", ")
            );
        }
        if !self.slotmgr.request_fits_chunk(plen, policy.commit_width()) {
            bail!(
                "request {}: prompt len {plen} + chunk {} needs more KV blocks than \
                 the paged pool's {} total",
                req.id,
                policy.commit_width(),
                self.slotmgr.blocks_total()
            );
        }
        self.queue.push_back((req, policy, Instant::now()));
        Ok(())
    }

    /// Abort a queued or in-flight request. Returns its (partial) result —
    /// `None` if the id is unknown. In-flight aborts free the slot
    /// immediately; the next `step()` refills it from the queue.
    pub fn abort(&mut self, id: u64) -> Option<RequestResult> {
        if let Some(qi) = self.queue.iter().position(|(r, _, _)| r.id == id) {
            let (req, _, _) = self.queue.remove(qi).unwrap();
            self.metrics.requests_aborted += 1;
            return Some(RequestResult {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                finish: FinishReason::Aborted,
                iterations: 0,
                accepted_sum: 0,
                latency: std::time::Duration::ZERO,
            });
        }
        let i = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|s| s.req.id == id))?;
        let slot = self.slots[i].take().unwrap();
        self.slotmgr.release(i);
        self.metrics.requests_aborted += 1;
        Some(slot.result(FinishReason::Aborted))
    }

    pub fn capacity(&self) -> usize {
        self.cfg.batch
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Queued + in-slot requests (the closed-loop drivers keep this at C).
    pub fn in_flight(&self) -> usize {
        self.occupied() + self.queued()
    }

    pub fn is_idle(&self) -> bool {
        self.in_flight() == 0
    }

    /// Consume the engine and return its accumulated metrics.
    pub fn into_metrics(self) -> EngineMetrics {
        self.metrics
    }

    /// Admit queued requests into free slots: one batch-1 prefill per
    /// request, spliced into the shared KV buffer, first token sampled from
    /// the prefill logits with the request's own sampling params.
    ///
    /// The prefill HLO scatters K/V for *every* row at offset 0, so a
    /// batch-wide prefill mid-flight would clobber occupied slots. Instead
    /// each fresh row is computed alone (rows are independent) and spliced
    /// in through the host — the shared cache makes ONE download/upload
    /// round trip per step no matter how many slots fill, and the whole
    /// admission cost is tracked as `EngineMetrics::admission_time`.
    fn admit_pending(
        &mut self,
        mr: &mut ModelRuntime,
        events: &mut Vec<EngineEvent>,
    ) -> Result<usize> {
        let mut admitted = 0;
        if self.queue.is_empty() {
            return Ok(admitted);
        }
        let prefix_on = self.slotmgr.prefix_cache_enabled();
        let mut shared_host: Option<HostTensor> = None; // lazy: skip if no free slot
        let mut admitted_slots: Vec<usize> = Vec::new();
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                continue;
            }
            // paged gating: a free SLOT is not enough — the queue head also
            // needs free BLOCKS for prompt + one speculation chunk (charged
            // by the head's OWN policy commit width). With the prefix cache
            // on, full-block prefix hits map shared and reduce the need —
            // the prompt-aware check mirrors claim_with_prefix exactly.
            // FIFO: a blocked head defers the whole queue (no head-of-line
            // bypass), counted as preemption pressure. Requests that could
            // never fit were rejected at add_request, so blocks freed by
            // evictions always unblock the head eventually.
            if let Some((front, front_policy, _)) = self.queue.front() {
                let fits = if prefix_on {
                    self.slotmgr.can_admit_prompt(&front.prompt, front_policy.commit_width())
                } else {
                    self.slotmgr.can_admit_chunk(front.prompt.len(), front_policy.commit_width())
                };
                if !fits {
                    self.metrics.admissions_blocked += 1;
                    break;
                }
            }
            let Some((req, policy, t_submit)) = self.queue.pop_front() else { break };
            let t0 = Instant::now();
            let plen = req.prompt.len();
            // with the cache off this is exactly the old claim_with_chunk
            // (a zero-length hit, no copies)
            let claim = self
                .slotmgr
                .claim_with_prefix(i, &req.prompt, policy.commit_width())
                .map_err(|e| anyhow::anyhow!(e))?;

            // COW copies and the prefix gather both need the current pool
            // bytes on the host — force the shared download early on a hit
            if (claim.cached_len > 0 || !claim.copies.is_empty()) && shared_host.is_none() {
                shared_host = Some(mr.rt.download(&self.kv)?);
            }
            // materialize sub-block hits BEFORE anything writes through the
            // table: the private dst must hold the shared src's prefix bytes
            for &(src, dst) in &claim.copies {
                copy_pool_block(shared_host.as_mut().unwrap(), src, dst)?;
            }
            self.metrics.cow_copies += claim.copies.len();

            // Three prefill shapes, all bitwise-equivalent on the prompt
            // range (pinned python-side by tests/test_prefix_cache.py):
            //   miss            -> full batch-1 prefill, splice [0, plen)
            //   hit, short tail -> gather cached rows, tail-only prefill,
            //                      splice [cached_len, plen)
            //   hit, long tail  -> full prefill (tail exceeds the lowered
            //                      PREFIX_TAIL_PAD, or no prefill-cached
            //                      executable): memory dedup without the
            //                      FLOP savings, splice [cached_len, plen)
            // compute_start is capped at plen - ctx so the drafter context
            // seed below always has computed feats for its window.
            let (pre, compute_start) = if claim.cached_len == 0 {
                if prefix_on {
                    self.metrics.prefix_misses += 1;
                }
                let mut tok_buf = vec![self.pad_id; self.p_pad];
                tok_buf[..plen].copy_from_slice(&req.prompt);
                let pre = mr.prefill(
                    &self.te1,
                    &HostTensor::i32(&[1, self.p_pad], tok_buf),
                    &HostTensor::i32(&[1], vec![plen as i32]),
                    &self.kv1_zero,
                )?;
                (pre, 0)
            } else {
                self.metrics.prefix_hits += 1;
                self.metrics.prefix_tokens_cached += claim.cached_len;
                let start = claim.cached_len.min(plen - self.ctx);
                let tail = plen - start;
                if tail <= self.tail_pad && self.te_cached.is_some() {
                    let seed = gather_kv_row_blocks(
                        shared_host.as_ref().unwrap(),
                        self.slotmgr.table(i),
                        start,
                        self.slotmgr.s_max,
                    )?;
                    let seed_buf = mr.rt.upload(&seed)?;
                    let mut tail_buf = vec![self.pad_id; self.tail_pad];
                    tail_buf[..tail].copy_from_slice(&req.prompt[start..]);
                    let te = self.te_cached.as_ref().unwrap();
                    let pre = mr.prefill_cached(
                        te,
                        &HostTensor::i32(&[1, self.tail_pad], tail_buf),
                        &HostTensor::i32(&[1], vec![plen as i32]),
                        &HostTensor::i32(&[1], vec![start as i32]),
                        &seed_buf,
                    )?;
                    (pre, start)
                } else {
                    let mut tok_buf = vec![self.pad_id; self.p_pad];
                    tok_buf[..plen].copy_from_slice(&req.prompt);
                    let pre = mr.prefill(
                        &self.te1,
                        &HostTensor::i32(&[1, self.p_pad], tok_buf),
                        &HostTensor::i32(&[1], vec![plen as i32]),
                        &self.kv1_zero,
                    )?;
                    (pre, 0)
                }
            };
            let row = mr.rt.download(&pre.kv)?;
            if shared_host.is_none() {
                shared_host = Some(mr.rt.download(&self.kv)?);
            }
            if self.slotmgr.is_paged() {
                // only the un-cached range is written: positions before
                // cached_len live in shared (possibly refcount > 1) blocks
                // that already hold exactly these bytes
                splice_kv_row_blocks_range(
                    shared_host.as_mut().unwrap(),
                    &row,
                    self.slotmgr.table(i),
                    0,
                    claim.cached_len,
                    plen,
                )?;
                // index this prompt's fully-committed blocks so later
                // admissions (including ones later in this same loop) share
                self.slotmgr.register_prefix(i, &req.prompt);
            } else {
                splice_kv_row(shared_host.as_mut().unwrap(), &row, i)?;
            }

            let pre_logits = pre.last_logits.as_f32()?;
            let pre_feats = pre.feats.as_f32()?;
            // the request's private sampling stream: greedy never draws, so
            // greedy output is independent of seeds and batch placement; the
            // first token honors the request's temperature/top-p/top-k
            let mut rng = Rng::new(self.cfg.seed ^ 0xE4617E ^ req.sampling.seed);
            let t_first =
                sample_filtered(&pre_logits[..self.vocab], &req.sampling.config(), &mut rng);

            // seed the drafter's rolling (token, feature) context from the
            // prompt tail; entry j covers position plen - ctx + 1 + j. The
            // prefill feats row r holds position compute_start + r (a full
            // prefill is compute_start == 0), and compute_start <= plen - ctx
            // guarantees the whole window was computed.
            let mut ctx_tokens = Vec::with_capacity(self.ctx);
            let mut ctx_feats = vec![0f32; self.ctx * self.fdim];
            for j in 0..self.ctx {
                let p = plen - self.ctx + 1 + j;
                let token = if p < plen { req.prompt[p] } else { t_first };
                ctx_tokens.push(token);
                let off = (p - 1 - compute_start) * self.fdim;
                ctx_feats[j * self.fdim..(j + 1) * self.fdim]
                    .copy_from_slice(&pre_feats[off..off + self.fdim]);
            }

            let max_new = req.max_new_tokens.min(self.cfg.max_new_tokens).max(1);
            let key = policy.exec_key();
            let mut slot = ActiveSlot {
                finished: None,
                generated: vec![t_first],
                last_tok: t_first,
                ctx_tokens,
                ctx_feats,
                pos_last: plen,
                max_new,
                iterations: 0,
                accepted_sum: 0,
                t_submit,
                t_last_emit: Instant::now(),
                rng,
                key,
                policy,
                req,
            };
            if t_first == self.eos_id {
                slot.finished = Some(FinishReason::Eos);
            } else if slot.generated.len() >= slot.max_new {
                slot.finished = Some(FinishReason::Length);
            }

            self.metrics.admissions += 1;
            self.metrics.admission_time += t0.elapsed();
            // the prefill's own sampled token counts toward throughput, and
            // defines TTFT (measured from submit, so queue wait is included)
            self.metrics.tokens_emitted += 1;
            self.metrics.ttfts.push(t_submit.elapsed());
            events.push(EngineEvent::Admitted { id: slot.req.id, slot: i });
            events.push(EngineEvent::Tokens { id: slot.req.id, tokens: vec![t_first] });
            self.slots[i] = Some(slot);
            admitted_slots.push(i);
            admitted += 1;
        }
        if let Some(h) = shared_host {
            let t_up = Instant::now();
            self.kv = mr.rt.upload(&h)?;
            self.metrics.admission_time += t_up.elapsed();
        }
        // TPOT epoch fix: each slot's t_last_emit was provisionally stamped
        // when its own prefill token was sampled, but LATER admissions in
        // this same pass (their prefills) and the single shared KV upload
        // all run before any of them can decode — the provisional stamp
        // would bill that work to the slot's first inter-token gap,
        // skewing TPOT up for early-admitted slots. Decode for everyone
        // starts after the upload, so that is the honest epoch.
        restamp_admission_emits(&mut self.slots, &admitted_slots, Instant::now());
        if prefix_on {
            self.metrics.prefix_evictions = self.slotmgr.prefix_evictions();
            self.metrics.shared_blocks_peak =
                self.metrics.shared_blocks_peak.max(self.slotmgr.shared_blocks());
        }
        Ok(admitted)
    }

    /// Evict every slot whose request finished; emits `Finished` events.
    fn evict_finished(&mut self, events: &mut Vec<EngineEvent>) {
        for i in 0..self.slots.len() {
            let done = self.slots[i]
                .as_ref()
                .and_then(|s| s.finished)
                .is_some();
            if !done {
                continue;
            }
            let slot = self.slots[i].take().unwrap();
            self.slotmgr.release(i);
            let reason = slot.finished.unwrap();
            let res = slot.result(reason);
            self.metrics.requests_finished += 1;
            self.metrics.request_latencies.push(res.latency);
            events.push(EngineEvent::Finished(res));
        }
    }

    /// One engine iteration: admit into free slots, then one
    /// {draft -> verify -> accept -> commit} pass per POLICY BUCKET over the
    /// occupied slots (deterministic bucket order; a homogeneous batch is
    /// one bucket and byte-identical to the old engine-wide path), then
    /// evict whatever finished. Rows outside the running bucket carry
    /// masked inputs; their outputs are ignored and their scratch-region
    /// scatter garbage is rewritten by their own bucket before anything is
    /// committed from it (see the module docs for why that ordering is the
    /// safety argument).
    pub fn step(&mut self, mr: &mut ModelRuntime) -> Result<StepReport> {
        let mut events = Vec::new();
        let admitted = self.admit_pending(mr, &mut events)?;
        // a request can finish at admission (EOS / max_new == 1)
        self.evict_finished(&mut events);

        let b = self.cfg.batch;
        let occupied = self.occupied();
        if occupied == 0 {
            return Ok(StepReport { events, admitted, occupied });
        }
        self.metrics.record_occupancy(occupied, b);
        if self.slotmgr.is_paged() {
            self.metrics
                .record_block_occupancy(self.slotmgr.blocks_used(), self.slotmgr.blocks_total());
        }

        // distinct policy buckets among occupied slots, deterministic order
        let mut keys: Vec<String> = self
            .slots
            .iter()
            .filter_map(|s| s.as_ref().map(|s| s.key.clone()))
            .collect();
        keys.sort();
        keys.dedup();

        let mut emitted_now = vec![0usize; b];
        // boundary accounting: everything the decode pass moves across the
        // host/device boundary (chunk/table uploads, logits/feats downloads,
        // any KV round trips) lands in the per-step transfer counters —
        // the zero-download steady-state invariant is measured HERE
        let transfers_before = mr.rt.transfer_snapshot();
        for key in keys {
            // lazy-load the bucket's executables on first use
            let policy = self
                .slots
                .iter()
                .find_map(|s| {
                    s.as_ref().filter(|s| s.key == key).map(|s| s.policy.clone())
                })
                .expect("bucket key without slot");
            self.ensure_group(mr, &policy)?;
            self.step_bucket(mr, &key, &mut events, &mut emitted_now)?;
        }
        self.metrics.record_step_transfers(transfers_before, mr.rt.transfer_snapshot());
        self.metrics.record_iteration(&emitted_now);

        // adaptive closed loop: sense this step's metrics, decide, and sync
        // every in-flight Dynamic slot's budget to the (possibly moved)
        // target. The clamp is the safety invariant: never above the budget
        // the slot's KV chunk was admitted for (`chunk_of(i) - 1` — the
        // allocator's accounting anchor), never below the controller floor.
        // Budgets are per-slot runtime data read fresh by the next
        // step_bucket pass, so the move takes effect next step with no
        // executable or allocator churn.
        if let Some(ctl) = self.controller.as_mut() {
            ctl.step(&self.metrics);
            let (target, bmin) = (ctl.budget_target(), ctl.config().budget_min);
            for (i, s) in self.slots.iter_mut().enumerate() {
                let Some(s) = s else { continue };
                if let SpecPolicy::Dynamic { envelope, budget, .. } = &mut s.policy {
                    let admitted = self.slotmgr.chunk_of(i).saturating_sub(1);
                    let cap = admitted.min(envelope.len()).max(1);
                    *budget = target.clamp(bmin.min(cap), cap);
                }
            }
        }

        self.evict_finished(&mut events);
        Ok(StepReport { events, admitted, occupied })
    }

    /// One policy bucket's {draft -> verify -> accept -> commit} pass at
    /// full engine width. Member slots (same exec key) carry real inputs;
    /// every other row is masked. The accepted-path KV commit (dense
    /// compaction or paged block surgery) happens HERE, before the next
    /// bucket's verify — later buckets' masked scatter then lands strictly
    /// beyond each slot's updated committed length.
    fn step_bucket(
        &mut self,
        mr: &mut ModelRuntime,
        key: &str,
        events: &mut Vec<EngineEvent>,
        emitted_now: &mut [usize],
    ) -> Result<()> {
        let group = &self.groups[key];
        let b = self.cfg.batch;
        let n = group.n_draft;
        let vocab = self.vocab;
        let dynamic = matches!(group.archetype, SpecPolicy::Dynamic { .. });
        let envelope: Option<&TreeTopology> = match &group.archetype {
            SpecPolicy::Dynamic { envelope, .. } => Some(envelope),
            _ => None,
        };

        // --- draft inputs (masked rows: PAD tokens, zero feats, pos 0) ----
        let th = Instant::now();
        let (c, fdim) = (self.ctx, self.fdim);
        let mut ctx_tok_buf = vec![self.pad_id; b * c];
        let mut ctx_feat_buf = vec![0f32; b * c * fdim];
        let mut pos_buf = vec![0i32; b];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                if s.key == key {
                    ctx_tok_buf[i * c..(i + 1) * c].copy_from_slice(&s.ctx_tokens);
                    ctx_feat_buf[i * c * fdim..(i + 1) * c * fdim]
                        .copy_from_slice(&s.ctx_feats);
                    pos_buf[i] = (s.pos_last - 1) as i32; // row space = token pos - 1
                }
            }
        }
        self.metrics.host_time += th.elapsed();

        let t1 = Instant::now();
        let ct_t = HostTensor::i32(&[b, c], ctx_tok_buf);
        let cf_t = HostTensor::f32(&[b, c, fdim], ctx_feat_buf);
        let p0_t = HostTensor::i32(&[b], pos_buf);
        let (drafts, draft_logp) = if dynamic {
            let (t, l) = mr.draft_tree_scored(&group.de, &ct_t, &cf_t, &p0_t)?;
            (t, Some(l))
        } else {
            (mr.draft(&group.de, &ct_t, &cf_t, &p0_t)?, None)
        };
        self.metrics.draft_time += t1.elapsed();
        let draft_toks = drafts.as_i32()?;

        // --- dynamic mode: per-slot confidence-driven node selection -------
        // The drafter scored every envelope node; each member slot keeps its
        // top-budget ancestor-closed subset — the budget is the SLOT's own
        // (per-request adaptive budgets), compacted into the first chunk
        // slots (masking::dynamic).
        let th_sel = Instant::now();
        let mut selections: Vec<Option<Vec<usize>>> = vec![None; b];
        if let Some(env) = envelope {
            let logp = draft_logp.as_ref().unwrap().as_f32()?;
            for (i, s) in self.slots.iter().enumerate() {
                if let Some(s) = s {
                    if s.key == key {
                        let budget = match &s.policy {
                            SpecPolicy::Dynamic { budget, .. } => *budget,
                            _ => unreachable!("dynamic bucket with non-dynamic slot"),
                        };
                        let row = &logp[i * n..(i + 1) * n];
                        selections[i] = Some(select_nodes(env, row, budget));
                    }
                }
            }
        }
        self.metrics.host_time += th_sel.elapsed();

        // --- verify chunk = [last_tok, node_1..node_N]; masked rows PAD ---
        // (dynamic: [last_tok, selected nodes.., PAD..] in compacted layout)
        let mut chunk_buf = vec![self.pad_id; b * (n + 1)];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                if s.key == key {
                    chunk_buf[i * (n + 1)] = s.last_tok;
                    match &selections[i] {
                        Some(sel) => {
                            for (j, &id) in sel.iter().enumerate() {
                                chunk_buf[i * (n + 1) + 1 + j] = draft_toks[i * n + id - 1];
                            }
                        }
                        None => chunk_buf[i * (n + 1) + 1..(i + 1) * (n + 1)]
                            .copy_from_slice(&draft_toks[i * n..(i + 1) * n]),
                    }
                    self.slotmgr.begin_spec(i); // chunk KV lands in scratch
                }
            }
        }
        // cache_len is rebuilt from the allocator EVERY bucket pass: live
        // rows outside this bucket report their current committed length, so
        // this bucket's masked scatter lands in their scratch region, never
        // over committed cache (the multi-policy safety invariant).
        let cache_len = self.slotmgr.cache_len_i32();
        let t2 = Instant::now();
        let chunk_t = HostTensor::i32(&[b, n + 1], chunk_buf);
        let clen_t = HostTensor::i32(&[b], cache_len.clone());
        // paged: the per-slot block tables are an executable input each step
        // (scratch blocks are already reserved — the allocator's coverage
        // invariant — so the chunk scatter always lands in owned blocks, and
        // non-member rows' tail scatter lands in the null block)
        let table_t = self.slotmgr.is_paged().then(|| {
            let bs = self.slotmgr.block_size().unwrap();
            let width = self.slotmgr.s_max / bs;
            HostTensor::i32(&[b, width], self.slotmgr.block_table_i32())
        });
        let ver = if let Some(env_mask) = &group.envelope_mask {
            // per-slot subset mask + depth offsets are runtime inputs each
            // step (inactive rows stay all-zero: attend only the committed
            // cache, attended by nobody)
            let env = envelope.expect("dynamic group without envelope");
            let w = n + 1;
            let mut mask_buf = vec![0i32; b * w * w];
            let mut depth_buf = vec![0i32; b * w];
            for (i, sel) in selections.iter().enumerate() {
                if let Some(sel) = sel {
                    mask_buf[i * w * w..(i + 1) * w * w]
                        .copy_from_slice(&subset_mask_i32(env_mask, sel, w));
                    depth_buf[i * w..(i + 1) * w]
                        .copy_from_slice(&compacted_depths_i32(env, sel, w));
                }
            }
            let mask_t = HostTensor::i32(&[b, w, w], mask_buf);
            let depth_t = HostTensor::i32(&[b, w], depth_buf);
            match &table_t {
                Some(table) => mr.verify_tree_dyn_paged(
                    &group.te, &chunk_t, &clen_t, &mask_t, &depth_t, table, &self.kv,
                )?,
                None => mr.verify_tree_dyn(
                    &group.te, &chunk_t, &clen_t, &mask_t, &depth_t, &self.kv,
                )?,
            }
        } else {
            match (&group.tree_mask, &table_t) {
                (Some(mask), Some(table)) => {
                    mr.verify_tree_paged(&group.te, &chunk_t, &clen_t, mask, table, &self.kv)?
                }
                (Some(mask), None) => {
                    mr.verify_tree(&group.te, &chunk_t, &clen_t, mask, &self.kv)?
                }
                (None, Some(table)) => {
                    mr.verify_paged(&group.te, &chunk_t, &clen_t, table, &self.kv)?
                }
                (None, None) => mr.verify(&group.te, &chunk_t, &clen_t, &self.kv)?,
            }
        };
        self.metrics.verify_time += t2.elapsed();
        self.kv = ver.kv;
        let logits = ver.logits.as_f32()?;
        let feats = ver.feats.as_f32()?;
        // dynamic drafters scored every envelope node: keep the joint logp
        // around to turn acceptance outcomes into drafter-calibration signal
        let joint_all: Option<&[f32]> = match &draft_logp {
            Some(l) => Some(l.as_f32()?),
            None => None,
        };

        // --- acceptance per member slot ------------------------------------
        let th2 = Instant::now();
        // per-policy metrics are keyed by policy identity (the bucket's exec
        // key), so chain vs tree vs dyn rows of one drafter stay separate
        // signal — EngineMetrics::per_drafter() re-rolls them for display
        let group_al = al_ceiling(&group.archetype);
        self.metrics.policy_mut(key, group_al).steps += 1;
        // slots whose committed path is non-contiguous: (slot, base, path)
        let mut to_compact: Vec<(usize, usize, Vec<usize>)> = Vec::new();
        for (i, s) in self.slots.iter_mut().enumerate() {
            let Some(s) = s.as_mut() else { continue };
            if s.key != key {
                continue;
            }
            let rows: Vec<&[f32]> = (0..=n)
                .map(|j| {
                    let off = (i * (n + 1) + j) * vocab;
                    &logits[off..off + vocab]
                })
                .collect();
            let slot_drafts = &draft_toks[i * n..(i + 1) * n];
            // greedy requests keep the exact-match walk (byte-identical, no
            // rng draws); temperature requests get lossless multi-branch
            // rejection sampling against the request's filtered target
            // (sampler::accept_*_sampled dispatch)
            let scfg = s.req.sampling.config();
            // accepted path as chunk-slot ids (chain: the identity prefix;
            // dynamic: COMPACTED chunk slots — the walk is confined to the
            // selected subtree)
            let (path, emitted) = match (&s.policy, envelope) {
                (SpecPolicy::Dynamic { .. }, Some(env)) => {
                    let sel = selections[i].as_ref().expect("member slot without selection");
                    let parents = compacted_parents(env, sel);
                    let compacted: Vec<i32> =
                        sel.iter().map(|&id| slot_drafts[id - 1]).collect();
                    let a = accept_tree_subset_sampled(
                        &parents,
                        &compacted,
                        &rows[..=sel.len()],
                        &scfg,
                        &mut s.rng,
                    );
                    // calibration signal: the drafter's conditional
                    // confidence q per selected node vs whether the node was
                    // accepted — metrics only, NEVER acceptance (a scalar
                    // model-confidence q on deterministic drafts would bias
                    // the output; see sampler.rs's statistical suite)
                    if let Some(joint) = joint_all {
                        let qs = conditional_q(env, &joint[i * n..(i + 1) * n], sel);
                        let pm = self.metrics.policy_mut(key, group_al);
                        for (j, &qv) in qs.iter().enumerate() {
                            pm.record_draft_q(qv, a.accepted_path.contains(&(j + 1)));
                        }
                    }
                    (a.accepted_path, a.emitted)
                }
                (SpecPolicy::Tree { topology, .. }, _) => {
                    let a =
                        accept_tree_sampled(topology, slot_drafts, &rows, &scfg, &mut s.rng);
                    (a.accepted_path, a.emitted)
                }
                (SpecPolicy::Chain { .. }, _) => {
                    let a = accept_chain_sampled(slot_drafts, &rows, &scfg, &mut s.rng);
                    ((1..=a.n_accepted).collect(), a.emitted)
                }
                (SpecPolicy::Dynamic { .. }, None) => {
                    unreachable!("dynamic slot in non-dynamic bucket")
                }
            };
            let q = cache_len[i] as usize; // chunk start = pos of last_tok
            s.iterations += 1;
            s.accepted_sum += emitted.len();
            // raw (pre-truncation) acceptance depth: the envelope/budget
            // tuning signal printed by bench-otps
            self.metrics.record_accepted_depth(path.len());
            if !matches!(s.policy, SpecPolicy::Chain { .. }) {
                let active = selections[i].as_ref().map(|sel| sel.len()).unwrap_or(n);
                self.metrics.record_active_nodes(active);
            }

            let mut step_toks = Vec::with_capacity(emitted.len());
            for (m, &tok) in emitted.iter().enumerate() {
                let p = q + m + 1; // absolute (compacted) position
                s.generated.push(tok);
                step_toks.push(tok);
                // features of this token's predecessor: the accepted node
                // that drafted position p - 1 (the root for m == 0)
                let pred = if m == 0 { 0 } else { path[m - 1] };
                let foff = (i * (n + 1) + pred) * fdim;
                s.push_ctx(tok, &feats[foff..foff + fdim], fdim);
                s.last_tok = tok;
                s.pos_last = p;
                if tok == self.eos_id {
                    s.finished = Some(FinishReason::Eos);
                    break;
                }
                if s.generated.len() >= s.max_new {
                    s.finished = Some(FinishReason::Length);
                    break;
                }
            }
            emitted_now[i] = step_toks.len();
            if !step_toks.is_empty() {
                let gap = s.t_last_emit.elapsed();
                self.metrics.record_tpot(step_toks.len(), gap);
                s.t_last_emit = Instant::now();
            }
            self.metrics
                .policy_mut(key, group_al)
                .record_iteration(step_toks.len(), path.len());
            // commit root + the accepted nodes actually kept (truncation at
            // EOS/length only happens when the request finishes)
            if !self.slotmgr.commit_spec(i, step_toks.len()) && s.finished.is_none() {
                s.finished = Some(FinishReason::CacheFull);
            }
            if s.finished.is_none() {
                let kept = step_toks.len().saturating_sub(1).min(path.len());
                if !path[..kept].iter().enumerate().all(|(j, &node)| node == j + 1) {
                    to_compact.push((i, q, path[..kept].to_vec()));
                }
            }
            events.push(EngineEvent::Tokens { id: s.req.id, tokens: step_toks });
        }
        self.metrics.host_time += th2.elapsed();

        // --- accepted-path KV commit (tree modes, non-contiguous paths) ----
        // Applied per BUCKET, before the next bucket's verify (whose masked
        // scatter must land beyond the just-committed lengths). Dense:
        // compact all of the bucket's rows through ONE shared host round
        // trip (compact_kv_path) — never one download per slot. Paged:
        // NEVER calls compact_kv_path — each path gets a block-granular
        // plan: table-entry swaps (pure pointer surgery, no pool traffic)
        // when the path is a block-aligned uniform shift, position copies
        // confined to the chunk's blocks otherwise. Copies run ON DEVICE
        // through the `commit-path-paged` executable (logical copies
        // translated through the post-swap tables into one physical
        // gather/scatter plan — cross-slot blocks are disjoint, so the
        // combined plan stays sequential-equivalent); the pool round-trips
        // through the host only when the executable is absent from the
        // manifest or the combined plan overflows its lowered row budget.
        if !to_compact.is_empty() {
            let tc = Instant::now();
            if self.slotmgr.is_paged() {
                let bs = self.slotmgr.block_size().unwrap();
                let mut copy_jobs: Vec<(usize, Vec<(usize, usize)>)> = Vec::new();
                for (slot, base, path) in &to_compact {
                    let plan = plan_path_commit(*base, path, bs);
                    self.metrics.block_rewires += plan.swaps.len();
                    for &(a, c) in &plan.swaps {
                        self.slotmgr.swap_blocks(*slot, a, c);
                    }
                    if !plan.copies.is_empty() {
                        copy_jobs.push((*slot, plan.copies));
                    }
                }
                self.metrics.paged_path_commits += to_compact.len();
                if !copy_jobs.is_empty() {
                    let plan_rows = mr.manifest.commit_plan_rows;
                    let rows_needed: usize = copy_jobs.iter().map(|(_, c)| c.len()).sum();
                    if self.te_commit.is_some() && rows_needed <= plan_rows {
                        let phys = self.phys_blocks.expect("paged engine without pool size");
                        let mut rows: Vec<i32> = Vec::with_capacity(plan_rows * 4);
                        for (slot, copies) in &copy_jobs {
                            physical_copy_rows(
                                self.slotmgr.table(*slot),
                                copies,
                                bs,
                                phys,
                                &mut rows,
                            )?;
                        }
                        // pad with (0,0,0,0): inert self-copies into the
                        // reserved null block
                        rows.resize(plan_rows * 4, 0);
                        let plan_t = HostTensor::i32(&[plan_rows, 4], rows);
                        let te = self.te_commit.as_ref().unwrap();
                        self.kv = mr.commit_path_paged(te, &plan_t, &self.kv)?;
                        self.metrics.device_path_commits += 1;
                    } else {
                        self.metrics.kv_downloads += 1;
                        let mut host = mr.rt.download(&self.kv)?;
                        for (slot, copies) in &copy_jobs {
                            apply_path_copies(&mut host, self.slotmgr.table(*slot), copies)?;
                        }
                        self.metrics.kv_uploads += 1;
                        self.kv = mr.rt.upload(&host)?;
                    }
                }
            } else {
                self.metrics.dense_compactions += to_compact.len();
                self.metrics.kv_downloads += 1;
                let mut host = mr.rt.download(&self.kv)?;
                for (slot, base, path) in &to_compact {
                    compact_kv_path(&mut host, *slot, *base, path)?;
                }
                self.metrics.kv_uploads += 1;
                self.kv = mr.rt.upload(&host)?;
            }
            self.metrics.commit_time += tc.elapsed();
        }
        Ok(())
    }

    /// Drive `step()` until queue and slots are empty; returns all results
    /// in finish order. (Small convenience used by the thin scheduler and
    /// the drain paths; streaming callers consume `step()` directly.)
    pub fn run_until_idle(&mut self, mr: &mut ModelRuntime) -> Result<Vec<RequestResult>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step(mr)?.into_finished());
        }
        Ok(out)
    }
}

/// Reset the TPOT epoch of freshly admitted slots to `now` — the instant
/// the admission pass's shared KV upload completed. See the call site in
/// [`EngineCore::admit_pending`] for the skew this removes; split out as a
/// free function so the fix is unit-testable without a runtime.
fn restamp_admission_emits(slots: &mut [Option<ActiveSlot>], admitted: &[usize], now: Instant) {
    for &i in admitted {
        if let Some(s) = slots[i].as_mut() {
            s.t_last_emit = now;
        }
    }
}

/// Load one policy's executable pair from the runtime registry and build
/// the masks its verify passes need.
fn load_group(
    mr: &mut ModelRuntime,
    target: &str,
    policy: &SpecPolicy,
    batch: usize,
    paged: bool,
) -> Result<PolicyGroup> {
    let pe = mr.ensure_policy_execs(target, policy, batch, paged)?;
    let (tree_mask, envelope_mask) = match policy {
        SpecPolicy::Chain { .. } => (None, None),
        SpecPolicy::Tree { topology, .. } => {
            let m = topology.build_mask();
            (Some(HostTensor::i32(&[m.n, m.n], m.to_i32())), None)
        }
        SpecPolicy::Dynamic { envelope, .. } => (None, Some(envelope.build_mask())),
    };
    Ok(PolicyGroup {
        archetype: policy.clone(),
        n_draft: policy.n_draft(),
        te: pe.te,
        de: pe.de,
        tree_mask,
        envelope_mask,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;

    #[test]
    fn config_widths_span_the_allowlist() {
        let env = TreeTopology::from_widths(&[4, 4, 2, 2, 1]);
        let cfg = EngineConfig::new("t", SpecPolicy::chain("d", 5), 2, 64).with_policies(vec![
            SpecPolicy::tree("d", TreeTopology::from_widths(&[3, 2, 1, 1, 1])),
            SpecPolicy::dynamic("d", env.clone(), 3),
            SpecPolicy::chain("d", 5), // duplicate exec key, deduped
        ]);
        assert_eq!(cfg.allowed_policies().len(), 3);
        assert_eq!(cfg.max_write_width(), 14, "widest scatter = envelope + 1");
        assert_eq!(cfg.min_commit_width(), 4, "smallest charge = budget + 1");
        assert_eq!(cfg.al_max(), 5);
        // dynamic-only engine: AL ceiling is the envelope depth, not the
        // (runtime-variable) budget
        let solo = EngineConfig::new("t", SpecPolicy::dynamic("d", env, 2), 1, 8);
        assert_eq!(solo.al_max(), 5);
        assert_eq!(solo.max_write_width(), 14);
        assert_eq!(solo.min_commit_width(), 3);
    }

    /// The satellite bugfix: with the adaptive controller on, the
    /// scheduler-facing commit-width floor must reflect what the controller
    /// can actually assign (any Dynamic policy floored to `budget_min`), not
    /// just the statically listed budget variants — otherwise
    /// `Scheduler::pick_bucket` reasons with a stale floor once budgets are
    /// re-tuned at runtime.
    #[test]
    fn adaptive_floor_folds_into_min_commit_width() {
        let env = TreeTopology::from_widths(&[4, 4, 2, 2, 1]);
        let cfg = EngineConfig::new("t", SpecPolicy::chain("d", 5), 2, 64)
            .with_policies(vec![SpecPolicy::dynamic("d", env.clone(), 8)]);
        assert_eq!(cfg.min_commit_width(), 6, "static floor: chain k=5 wins");
        let adaptive = ControllerConfig { budget_min: 2, ..ControllerConfig::default() };
        let cfg = cfg.with_adaptive(Some(adaptive.clone()));
        assert_eq!(
            cfg.min_commit_width(),
            3,
            "adaptive floor: the dyn policy may be assigned at budget_min"
        );
        // the fold clamps to the envelope and the LISTED budget (a variant
        // listed below budget_min keeps its own, smaller charge)
        let tiny = EngineConfig::new("t", SpecPolicy::dynamic("d", env, 1), 1, 8)
            .with_adaptive(Some(adaptive));
        assert_eq!(tiny.min_commit_width(), 2, "listed budget below the floor wins");
    }

    #[test]
    fn sampling_defaults_are_greedy() {
        assert_eq!(SamplingParams::default(), SamplingParams::greedy());
    }

    fn dummy_slot(id: u64, t: Instant) -> ActiveSlot {
        let policy = SpecPolicy::chain("d", 5);
        ActiveSlot {
            key: policy.exec_key(),
            policy,
            rng: Rng::new(id),
            finished: None,
            generated: vec![1],
            last_tok: 1,
            ctx_tokens: vec![1; 4],
            ctx_feats: vec![0.0; 8],
            pos_last: 10,
            max_new: 4,
            iterations: 0,
            accepted_sum: 0,
            t_submit: t,
            t_last_emit: t,
            req: Request::new(id, vec![1; 10], 4),
        }
    }

    /// Pin the admission TPOT-skew fix: every slot admitted in one
    /// `admit_pending` pass has its inter-token epoch reset to the shared
    /// upload instant, so the first TPOT gap cannot be charged for later
    /// requests' prefills; slots that were already decoding keep theirs.
    #[test]
    fn admission_restamps_tpot_epoch_only_for_admitted_slots() {
        let old = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let mut slots: Vec<Option<ActiveSlot>> = vec![
            Some(dummy_slot(0, old)), // pre-existing, decoding
            Some(dummy_slot(1, old)), // admitted earlier in this pass
            None,                     // free
            Some(dummy_slot(3, old)), // admitted later in this pass
        ];
        let now = Instant::now();
        assert!(now > old);
        restamp_admission_emits(&mut slots, &[1, 3], now);
        assert_eq!(slots[0].as_ref().unwrap().t_last_emit, old, "non-admitted slot restamped");
        assert_eq!(slots[1].as_ref().unwrap().t_last_emit, now);
        assert_eq!(slots[3].as_ref().unwrap().t_last_emit, now);
        // t_submit (TTFT base) is never touched — only the TPOT epoch moves
        assert_eq!(slots[1].as_ref().unwrap().t_submit, old);
        // a stale index into a freed slot is a no-op, not a panic
        restamp_admission_emits(&mut slots, &[2], Instant::now());
    }
}
