//! Wave scheduler: admission queue + bucketed batch formation.
//!
//! Requests queue up and are grouped into waves of the largest available
//! executable batch size ≤ the ready count (buckets {1, 2, 4} from the
//! manifest). A wave runs to completion on one KV buffer, then the next
//! forms — iteration-level batching with wave refill. For the paper's
//! closed-loop concurrency benchmark (Table 10), the driver keeps C
//! requests in flight so waves are always width C.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::engine::{run_wave, EngineConfig};
use super::metrics::EngineMetrics;
use super::request::{RequestResult, RequestSpec};
use crate::runtime::ModelRuntime;

pub struct Scheduler {
    pub cfg: EngineConfig,
    pub buckets: Vec<usize>,
    queue: VecDeque<RequestSpec>,
    pub results: Vec<RequestResult>,
    pub metrics: EngineMetrics,
}

impl Scheduler {
    pub fn new(cfg: EngineConfig, buckets: Vec<usize>) -> Scheduler {
        let mut b = buckets;
        b.sort_unstable();
        let metrics = EngineMetrics::new(cfg.k);
        Scheduler { cfg, buckets: b, queue: VecDeque::new(), results: Vec::new(), metrics }
    }

    pub fn submit(&mut self, r: RequestSpec) {
        self.queue.push_back(r);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Largest bucket ≤ n (falls back to the smallest bucket).
    pub fn pick_bucket(&self, n: usize) -> usize {
        self.buckets
            .iter()
            .rev()
            .find(|&&b| b <= n)
            .copied()
            .unwrap_or(self.buckets[0])
    }

    /// Form and run one wave. Returns how many requests completed.
    pub fn step_wave(&mut self, mr: &mut ModelRuntime) -> Result<usize> {
        if self.queue.is_empty() {
            return Ok(0);
        }
        let width = self.pick_bucket(self.queue.len());
        let take = width.min(self.queue.len());
        let wave: Vec<RequestSpec> = self.queue.drain(..take).collect();
        let mut cfg = self.cfg.clone();
        cfg.batch = width;
        let t0 = Instant::now();
        let res = run_wave(mr, &cfg, wave, &mut self.metrics)?;
        self.metrics.wall_time += t0.elapsed();
        let n = res.len();
        self.results.extend(res);
        Ok(n)
    }

    /// Drain the whole queue.
    pub fn run_to_completion(&mut self, mr: &mut ModelRuntime) -> Result<()> {
        while !self.queue.is_empty() {
            self.step_wave(mr)?;
        }
        Ok(())
    }
}

/// Closed-loop driver at fixed concurrency C (the Table 10 client): keeps C
/// requests in flight until `total` have completed.
pub fn run_closed_loop(
    mr: &mut ModelRuntime,
    cfg: &EngineConfig,
    concurrency: usize,
    total: usize,
    mut next_request: impl FnMut() -> RequestSpec,
) -> Result<(Vec<RequestResult>, EngineMetrics)> {
    let mut cfgc = cfg.clone();
    cfgc.batch = concurrency;
    let mut metrics = EngineMetrics::new(cfg.k);
    let mut results = Vec::with_capacity(total);
    let t0 = Instant::now();
    while results.len() < total {
        let take = concurrency.min(total - results.len());
        let wave: Vec<RequestSpec> = (0..take).map(|_| next_request()).collect();
        let res = run_wave(mr, &cfgc, wave, &mut metrics)?;
        results.extend(res);
    }
    metrics.wall_time = t0.elapsed();
    Ok((results, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sampler::Sampling;

    fn cfg() -> EngineConfig {
        EngineConfig {
            target: "t".into(),
            drafter: "d".into(),
            k: 5,
            batch: 4,
            max_new_tokens: 32,
            sampling: Sampling::Greedy,
            seed: 0,
        }
    }

    #[test]
    fn bucket_selection() {
        let s = Scheduler::new(cfg(), vec![1, 2, 4]);
        assert_eq!(s.pick_bucket(1), 1);
        assert_eq!(s.pick_bucket(2), 2);
        assert_eq!(s.pick_bucket(3), 2);
        assert_eq!(s.pick_bucket(4), 4);
        assert_eq!(s.pick_bucket(9), 4);
    }

    #[test]
    fn queue_accounting() {
        let mut s = Scheduler::new(cfg(), vec![1, 2, 4]);
        for i in 0..5 {
            s.submit(RequestSpec {
                id: i,
                prompt: vec![1; 16],
                max_new_tokens: 8,
                arrival_s: 0.0,
            });
        }
        assert_eq!(s.pending(), 5);
    }
}
