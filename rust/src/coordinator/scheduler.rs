//! Admission policy over the stepped `EngineCore`.
//!
//! With the engine itself handling iteration-level batching (immediate
//! eviction + mid-flight refill), the scheduler shrinks to a *policy* layer:
//! it buffers submissions, picks the executable width (bucket) to spin the
//! core up at, and feeds the core's queue. Unlike the old wave scheduler it
//! never runs padded batches to completion — an undersized backlog admits
//! into the smallest bucket and the core masks the empty rows. Requests may
//! carry their own [`SpecPolicy`](super::request::SpecPolicy); the width
//! pick reasons with the engine's allowlist (the cheapest serveable
//! policy's footprint), and the core charges each admitted slot by its own
//! policy.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::engine::{EngineConfig, EngineCore};
use super::metrics::EngineMetrics;
use super::request::{Request, RequestResult};
use crate::runtime::ModelRuntime;

pub struct Scheduler {
    pub cfg: EngineConfig,
    /// available executable widths, sorted ascending (manifest batch_sizes)
    pub buckets: Vec<usize>,
    queue: VecDeque<Request>,
    pub results: Vec<RequestResult>,
    pub metrics: EngineMetrics,
}

impl Scheduler {
    pub fn new(cfg: EngineConfig, buckets: Vec<usize>) -> Scheduler {
        let mut b = buckets;
        b.sort_unstable();
        b.dedup();
        assert!(!b.is_empty(), "scheduler needs at least one width bucket");
        let metrics = EngineMetrics::new(cfg.al_max());
        Scheduler { cfg, buckets: b, queue: VecDeque::new(), results: Vec::new(), metrics }
    }

    pub fn submit(&mut self, r: Request) {
        self.queue.push_back(r);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Engine width for a backlog of `n` requests: the largest bucket that
    /// `n` can fill, or — when `n` is smaller than every bucket — the
    /// smallest bucket, explicitly undersized (the core masks the empty
    /// rows; nothing is padded with fake requests). `None` iff `n == 0`:
    /// an empty backlog never spins up an engine.
    ///
    /// Paged mode with a finite block budget additionally consults
    /// free-block headroom: width beyond `budget / blocks_per_request` slots
    /// can never be concurrently admitted (the engine would gate them on
    /// free blocks anyway), so the pick is capped there, and a budget that
    /// cannot host even ONE minimal request (a single chunk + bonus root)
    /// refuses outright — spinning up an engine whose every admission must
    /// fail helps nobody.
    pub fn pick_bucket(&self, n: usize) -> Option<usize> {
        if n == 0 {
            return None;
        }
        let mut want = n;
        if let Some(p) = self.cfg.paged {
            if let Some(budget) = p.num_blocks {
                // floor per request: the smallest admissible footprint is a
                // 1-token prompt + one COMMITTABLE speculation chunk of
                // scratch — the CHEAPEST serveable policy's commit width
                // (chain K+1, tree N+1, or — dynamic — the per-step node
                // BUDGET + 1; the envelope's tail scatter lands in the null
                // block and is never charged). A block_size left to
                // default-from-manifest is estimated at the dense
                // BLOCK_SIZE; the engine's own admission gate re-checks
                // with exact per-request numbers.
                let commit = self.cfg.min_commit_width();
                let bs = p.block_size.unwrap_or(crate::coordinator::kv_cache::BLOCK_SIZE);
                let per_req = (commit + 1).div_ceil(bs).max(1);
                if budget < per_req {
                    return None;
                }
                want = want.min(budget / per_req);
            }
        }
        Some(
            self.buckets
                .iter()
                .rev()
                .find(|&&b| b <= want)
                .copied()
                .unwrap_or(self.buckets[0]),
        )
    }

    /// Drain the backlog: spin up one `EngineCore` sized for the current
    /// backlog, hand it every queued request (the core admits into freed
    /// slots mid-flight), and step it until idle. Returns how many requests
    /// completed.
    ///
    /// A request that fails admission validation stops the handoff: the
    /// requests already accepted still run to completion (their results land
    /// in `self.results`), the rest stay queued for the next call, and only
    /// the invalid request is dropped — its error is returned.
    pub fn run_to_completion(&mut self, mr: &mut ModelRuntime) -> Result<usize> {
        let Some(width) = self.pick_bucket(self.queue.len()) else {
            return Ok(0);
        };
        let mut cfg = self.cfg.clone();
        cfg.batch = width;
        let mut core = EngineCore::new(mr, cfg)?;
        let mut rejected = None;
        while let Some(r) = self.queue.pop_front() {
            if let Err(e) = core.add_request(r) {
                rejected = Some(e);
                break;
            }
        }
        let t0 = Instant::now();
        let res = core.run_until_idle(mr)?;
        let n = res.len();
        self.results.extend(res);
        let mut m = core.into_metrics();
        m.wall_time = t0.elapsed();
        self.metrics.merge(&m);
        match rejected {
            Some(e) => Err(e),
            None => Ok(n),
        }
    }
}

/// Closed-loop driver at fixed concurrency C (the Table 10 client): keeps C
/// requests in flight on a width-C core until `total` have completed. Each
/// eviction immediately admits the next request — no wave barriers, so a
/// short request never waits on a long one finishing the batch.
pub fn run_closed_loop(
    mr: &mut ModelRuntime,
    cfg: &EngineConfig,
    concurrency: usize,
    total: usize,
    mut next_request: impl FnMut() -> Request,
) -> Result<(Vec<RequestResult>, EngineMetrics)> {
    let mut cfgc = cfg.clone();
    cfgc.batch = concurrency;
    let mut core = EngineCore::new(mr, cfgc)?;
    let mut results = Vec::with_capacity(total);
    let mut submitted = 0usize;
    let t0 = Instant::now();
    while results.len() < total {
        while submitted < total && core.in_flight() < concurrency {
            core.add_request(next_request())?;
            submitted += 1;
        }
        let report = core.step(mr)?;
        if report.occupied == 0 && report.admitted == 0 && core.is_idle() && submitted >= total
        {
            // defensive: nothing live and nothing left to submit
            return Err(anyhow!("closed loop stalled at {}/{total} results", results.len()));
        }
        results.extend(report.into_finished());
    }
    let mut metrics = core.into_metrics();
    metrics.wall_time = t0.elapsed();
    Ok((results, metrics))
}

/// Open-loop driver: requests arrive on their own wall-clock schedule
/// (`Request::arrival_s`, seconds from driver start) regardless of how many
/// are already in flight — the latency-under-load client. A request whose
/// arrival time has passed is admitted as soon as a slot frees; TTFT measured
/// from submit therefore includes genuine queueing delay, which is the point
/// of the open-loop experiment. `requests` must be sorted by `arrival_s`
/// (as [`ArrivalProcess::take_poisson`] produces them).
///
/// The engine only runs while work exists: with no requests in flight and the
/// next arrival still in the future, the driver sleeps (capped at 50ms per
/// nap so a coarse schedule still polls responsively).
pub fn run_open_loop(
    mr: &mut ModelRuntime,
    cfg: &EngineConfig,
    concurrency: usize,
    requests: Vec<Request>,
) -> Result<(Vec<RequestResult>, EngineMetrics)> {
    let total = requests.len();
    let mut cfgc = cfg.clone();
    cfgc.batch = concurrency;
    let mut core = EngineCore::new(mr, cfgc)?;
    let mut results = Vec::with_capacity(total);
    let mut pending = requests.into_iter().peekable();
    let t0 = Instant::now();
    while results.len() < total {
        let now_s = t0.elapsed().as_secs_f64();
        while admit_due(
            core.in_flight(),
            concurrency,
            pending.peek().is_some_and(|r| r.arrival_s <= now_s),
        ) {
            core.add_request(pending.next().unwrap())?;
        }
        if core.is_idle() {
            match pending.peek() {
                // nothing live, nothing due: nap until the next arrival
                Some(r) => {
                    let wait = (r.arrival_s - t0.elapsed().as_secs_f64()).max(0.0);
                    if wait > 0.0 {
                        std::thread::sleep(std::time::Duration::from_secs_f64(
                            wait.min(0.05),
                        ));
                    }
                    continue;
                }
                None => {
                    return Err(anyhow!(
                        "open loop stalled at {}/{total} results",
                        results.len()
                    ))
                }
            }
        }
        let report = core.step(mr)?;
        results.extend(report.into_finished());
    }
    let mut metrics = core.into_metrics();
    metrics.wall_time = t0.elapsed();
    Ok((results, metrics))
}

/// The open-loop admission gate, one decision per due arrival: admit only
/// while the engine's QUEUED + OCCUPIED count stays strictly below
/// `concurrency`. `in_flight` must be re-read from the engine after every
/// admission (each `add_request` enqueues immediately), so a clustered burst
/// of simultaneous arrivals can never over-enqueue past the cap — the excess
/// stays in the driver's own pending list until in-flight work drains.
/// Factored out of [`run_open_loop`] so the bound is unit-testable without a
/// runtime.
fn admit_due(in_flight: usize, concurrency: usize, next_due: bool) -> bool {
    next_due && in_flight < concurrency
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SpecPolicy;

    /// Satellite regression: N simultaneous arrivals (identical arrival_s,
    /// all due the instant the driver starts) must admit exactly
    /// `concurrency` requests before the first step — the gate counts the
    /// engine queue, not just occupied slots, so there is no window where a
    /// burst over-enqueues. Draining one in-flight unit re-admits exactly
    /// one more.
    #[test]
    fn open_loop_burst_cannot_over_enqueue() {
        let concurrency = 4;
        let due = 10; // clustered arrivals, all due NOW
        let mut in_flight = 0; // engine-side queued + occupied
        let mut admitted = 0;
        while admit_due(in_flight, concurrency, admitted < due) {
            in_flight += 1; // add_request enqueues immediately
            admitted += 1;
        }
        assert_eq!(admitted, concurrency, "burst admitted past the cap");
        // one request finishes: exactly one replacement admits, no more
        in_flight -= 1;
        let mut extra = 0;
        while admit_due(in_flight, concurrency, admitted + extra < due) {
            in_flight += 1;
            extra += 1;
        }
        assert_eq!(extra, 1);
        // and an empty schedule admits nothing regardless of headroom
        assert!(!admit_due(0, concurrency, false));
    }

    fn cfg() -> EngineConfig {
        EngineConfig::new("t", SpecPolicy::chain("d", 5), 4, 32)
    }

    #[test]
    fn bucket_selection() {
        let s = Scheduler::new(cfg(), vec![1, 2, 4]);
        assert_eq!(s.pick_bucket(1), Some(1));
        assert_eq!(s.pick_bucket(2), Some(2));
        assert_eq!(s.pick_bucket(3), Some(2));
        assert_eq!(s.pick_bucket(4), Some(4));
        assert_eq!(s.pick_bucket(9), Some(4));
    }

    #[test]
    fn empty_backlog_picks_nothing() {
        // the old API silently fell back to the smallest bucket here, which
        // spun up a padded width-1 engine for zero requests
        let s = Scheduler::new(cfg(), vec![1, 2, 4]);
        assert_eq!(s.pick_bucket(0), None);
    }

    #[test]
    fn undersized_backlog_is_explicit_smallest_bucket() {
        // n below every bucket: admit undersized into the smallest width —
        // the core masks the empty rows (no fake padding requests)
        let s = Scheduler::new(cfg(), vec![2, 4]);
        assert_eq!(s.pick_bucket(1), Some(2));
        assert_eq!(s.pick_bucket(0), None);
    }

    #[test]
    fn paged_bucket_consults_block_headroom() {
        use crate::coordinator::engine::PagedKvConfig;
        // K=5, block_size 4 => a minimal request needs ceil(7/4) = 2 blocks
        let paged = |num_blocks| {
            let c = cfg().with_paged(Some(PagedKvConfig {
                block_size: Some(4),
                num_blocks: Some(num_blocks),
                prefix_cache: false,
            }));
            Scheduler::new(c, vec![1, 2, 4])
        };
        // the refusal case: a 1-block budget cannot host ANY request — no
        // engine width is admissible even with a deep backlog
        assert_eq!(paged(1).pick_bucket(4), None);
        // 5 blocks host at most 2 concurrent requests: width capped at 2
        assert_eq!(paged(5).pick_bucket(4), Some(2));
        // an ample budget changes nothing vs the slot-only policy
        assert_eq!(paged(64).pick_bucket(4), Some(4));
        assert_eq!(paged(64).pick_bucket(0), None);
        // unlimited (fully provisioned) budget: slot-only policy
        let c = cfg()
            .with_paged(Some(PagedKvConfig { block_size: Some(4), num_blocks: None, prefix_cache: false }));
        assert_eq!(Scheduler::new(c, vec![1, 2, 4]).pick_bucket(3), Some(2));
    }

    #[test]
    fn paged_bucket_uses_tree_chunk_width_not_k() {
        use crate::coordinator::engine::PagedKvConfig;
        use crate::masking::TreeTopology;
        // tree w:3,2,1,1,1 = 8 nodes -> minimal footprint ceil(10/4) = 3
        // blocks. A 2-block budget must refuse (every add_request would bail
        // on capacity).
        let tree = SpecPolicy::tree("d", TreeTopology::from_widths(&[3, 2, 1, 1, 1]));
        let mut c = EngineConfig::new("t", tree, 4, 32);
        c.paged = Some(PagedKvConfig { block_size: Some(4), num_blocks: Some(2), prefix_cache: false });
        assert_eq!(Scheduler::new(c.clone(), vec![1, 2, 4]).pick_bucket(4), None);
        c.paged = Some(PagedKvConfig { block_size: Some(4), num_blocks: Some(7), prefix_cache: false });
        assert_eq!(Scheduler::new(c, vec![1, 2, 4]).pick_bucket(4), Some(2));
    }

    #[test]
    fn paged_bucket_charges_dynamic_trees_by_budget_not_envelope() {
        use crate::coordinator::engine::PagedKvConfig;
        use crate::masking::TreeTopology;
        // THE over-reservation regression: envelope w:4,4,2,2,1 has 13
        // nodes, but a 3-node budget commits at most 4 scratch positions.
        // block_size 4 => per-request floor ceil(5/4) = 2 blocks, NOT the
        // envelope's ceil(15/4) = 4.
        let dynp = SpecPolicy::dynamic("d", TreeTopology::from_widths(&[4, 4, 2, 2, 1]), 3);
        let mut c = EngineConfig::new("t", dynp, 4, 32);
        c.paged = Some(PagedKvConfig { block_size: Some(4), num_blocks: Some(5), prefix_cache: false });
        // 5 blocks at 2 per request host 2 concurrent requests: width 2.
        // Charging by the envelope (4 per request) would cap this at 1.
        assert_eq!(Scheduler::new(c.clone(), vec![1, 2, 4]).pick_bucket(4), Some(2));
        // and a budget the envelope could never fit still admits: 3 blocks
        // host one 2-block request (envelope charging would refuse at < 4)
        c.paged = Some(PagedKvConfig { block_size: Some(4), num_blocks: Some(3), prefix_cache: false });
        assert_eq!(Scheduler::new(c, vec![1, 2, 4]).pick_bucket(4), Some(1));
    }

    #[test]
    fn paged_bucket_floor_uses_cheapest_allowed_policy() {
        use crate::coordinator::engine::PagedKvConfig;
        use crate::masking::TreeTopology;
        // multi-policy allowlist: chain K=5 (commit 6) + dynamic budget 2
        // (commit 3). The width pick floors at the CHEAPEST serveable
        // footprint — ceil(4/4) = 1 block — so a tight budget still spins up
        // an engine the small-budget requests can use.
        let mut c = EngineConfig::new("t", SpecPolicy::chain("d", 5), 4, 32).with_policies(
            vec![SpecPolicy::dynamic("d", TreeTopology::from_widths(&[4, 4, 2, 2, 1]), 2)],
        );
        c.paged = Some(PagedKvConfig { block_size: Some(4), num_blocks: Some(1), prefix_cache: false });
        // chain-only would refuse (needs 2 blocks); the dyn@2 policy fits
        assert_eq!(Scheduler::new(c, vec![1, 2, 4]).pick_bucket(4), Some(1));

        // same-EXEC-KEY budget variants must count too: dyn@8 default with a
        // listed dyn@2 variant (identical executables, different charge) —
        // the floor is the @2 footprint (ceil(4/4) = 1 block), because the
        // engine's own per-request gate WOULD admit those requests. The
        // exec-key dedup of allowed_policies() must not hide it.
        let env = TreeTopology::from_widths(&[4, 4, 2, 2, 1]);
        let mut c = EngineConfig::new("t", SpecPolicy::dynamic("d", env.clone(), 8), 4, 32)
            .with_policies(vec![SpecPolicy::dynamic("d", env, 2)]);
        c.paged = Some(PagedKvConfig { block_size: Some(4), num_blocks: Some(1), prefix_cache: false });
        assert_eq!(Scheduler::new(c, vec![1, 2, 4]).pick_bucket(4), Some(1));
    }

    /// Regression for the adaptive-controller staleness bug: the width
    /// pick's commit-width floor is recomputed from currently-ASSIGNABLE
    /// policies via `EngineConfig::min_commit_width`, which folds the
    /// controller's `budget_min` for Dynamic policies — a floor frozen from
    /// the static policy list would refuse block budgets the adaptive engine
    /// can genuinely serve once it floors budgets at runtime. (Staleness in
    /// the other direction cannot happen: in-flight retunes never exceed a
    /// slot's admitted chunk — see `EngineCore::step`.)
    #[test]
    fn paged_bucket_floor_tracks_adaptive_budget_min() {
        use crate::coordinator::controller::ControllerConfig;
        use crate::coordinator::engine::PagedKvConfig;
        use crate::masking::TreeTopology;
        // dyn@8 default: static commit 9 -> ceil(11/4) = 3 blocks/request
        let dynp = SpecPolicy::dynamic("d", TreeTopology::from_widths(&[4, 4, 2, 2, 1]), 8);
        let mut c = EngineConfig::new("t", dynp, 4, 32);
        c.paged = Some(PagedKvConfig { block_size: Some(4), num_blocks: Some(2), prefix_cache: false });
        // static floor: a 2-block budget cannot host any request — refuse
        assert_eq!(Scheduler::new(c.clone(), vec![1, 2, 4]).pick_bucket(4), None);
        // adaptive floor: the controller may assign dyn@2 (commit 3 ->
        // ceil(5/4) = 2 blocks), so the same budget hosts one request
        c.adaptive = Some(ControllerConfig { budget_min: 2, ..ControllerConfig::default() });
        assert_eq!(Scheduler::new(c, vec![1, 2, 4]).pick_bucket(4), Some(1));
    }

    #[test]
    fn buckets_sorted_and_deduped() {
        let s = Scheduler::new(cfg(), vec![4, 1, 2, 2]);
        assert_eq!(s.buckets, vec![1, 2, 4]);
    }

    #[test]
    fn queue_accounting() {
        let mut s = Scheduler::new(cfg(), vec![1, 2, 4]);
        for i in 0..5 {
            s.submit(Request::new(i, vec![1; 16], 8));
        }
        assert_eq!(s.pending(), 5);
    }
}
