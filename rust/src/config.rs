//! Typed view over artifacts/manifest.json — the contract between the
//! Python build path (python/compile/aot.py) and the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;
use crate::workload::PhraseRegime;

#[derive(Clone, Debug)]
pub struct TargetInfo {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub feature_dim: usize,
    pub vocab: usize,
    pub weights: String,
    pub param_order: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct DrafterInfo {
    pub name: String,
    pub target: String,
    pub kind: String, // peagle | ar | parallelspec
    pub n_layers: usize,
    pub hidden_mode: String,
    pub weights: String,
    pub param_order: Vec<String>,
    /// Speculation modes this drafter's executables were lowered for
    /// (python `configs.drafter_modes`): `chain` always; `tree` / `dyn` for
    /// parallel drafters (the AR scan has no single-pass tree draft).
    /// Manifests predating the capability field fall back to the kind rule.
    pub modes: Vec<String>,
}

impl DrafterInfo {
    /// Whether this drafter supports the given speculation mode
    /// (`SpecPolicy::mode_name`): the policy registry's capability gate.
    pub fn supports(&self, mode: &str) -> bool {
        self.modes.iter().any(|m| m == mode)
    }
}

/// Capability fallback for manifests predating the `modes` field: the AR
/// scan drafts chains only; parallel drafters draft every shape.
fn default_modes(kind: &str) -> Vec<String> {
    match kind {
        "ar" => vec!["chain".into()],
        _ => vec!["chain".into(), "tree".into(), "dyn".into()],
    }
}

#[derive(Clone, Debug)]
pub struct ExecutableInfo {
    pub name: String,
    pub path: String,
    /// prefill | prefill-cached | verify | verify-paged | draft |
    /// verify-tree | verify-tree-paged | draft-tree | verify-tree-dyn |
    /// verify-tree-dyn-paged | draft-tree-logp | selftest
    pub kind: String,
    pub model: Option<String>,
    pub drafter: Option<String>,
    pub batch: Option<usize>,
    /// chain depth K for chain executables; node count N for tree ones
    pub k: Option<usize>,
    /// static tree topology id (e.g. "chain5", "w3x2x1") for *-tree kinds
    pub topology: Option<String>,
    /// *-paged kinds: token width of one KV pool block (baked into the HLO)
    pub block_size: Option<usize>,
    /// *-paged kinds: physical pool size the executable was lowered with
    pub num_blocks: Option<usize>,
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub vocab: usize,
    pub s_max: usize,
    /// token width of one paged-KV pool block (python `configs.KV_BLOCK_SIZE`;
    /// 16 when the manifest predates paged lowering)
    pub kv_block_size: usize,
    /// token operand width of the `prefill-cached` executables (python
    /// `configs.PREFIX_TAIL_PAD`; 32 when the manifest predates them)
    pub prefix_tail_pad: usize,
    /// Whether the paged verify families were lowered on the in-place
    /// Pallas paged-attention kernel (aot.py default) rather than the legacy
    /// `paged_gather` densification (`PEAGLE_PAGED_GATHER=1`). Informational
    /// for reporting — both lowerings are bitwise-equal and share names.
    /// False when the manifest predates the capability.
    pub paged_inplace: bool,
    /// Plan-operand row count of the `commit-path-paged` executables
    /// (python `configs.COMMIT_PLAN_ROWS`; 0 when the manifest predates
    /// device commit — the engine then falls back to host copies).
    pub commit_plan_rows: usize,
    pub prompt_pad: usize,
    pub ctx_window: usize,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
    pub mask_id: i32,
    pub spec_depths: Vec<usize>,
    pub batch_sizes: Vec<usize>,
    pub default_k: usize,
    pub targets: BTreeMap<String, TargetInfo>,
    pub drafters: BTreeMap<String, DrafterInfo>,
    pub executables: Vec<ExecutableInfo>,
    pub regimes: BTreeMap<String, PhraseRegime>,
    pub eval_prompts: BTreeMap<String, String>,
    pub training_logs: Json,
    pub table1_contexts: BTreeMap<usize, String>,
}

impl Manifest {
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parse {path:?}: {e}"))?;

        let usize_arr = |key: &str| -> Vec<usize> {
            v.req(key).as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect()
        };

        let mut targets = BTreeMap::new();
        for (name, t) in v.req("targets").as_obj().unwrap() {
            targets.insert(
                name.clone(),
                TargetInfo {
                    name: name.clone(),
                    d_model: t.usize_of("d_model"),
                    n_layers: t.usize_of("n_layers"),
                    n_heads: t.usize_of("n_heads"),
                    head_dim: t.usize_of("head_dim"),
                    feature_dim: t.usize_of("feature_dim"),
                    vocab: t.usize_of("vocab"),
                    weights: t.str_of("weights"),
                    param_order: t
                        .req("param_order")
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|x| x.as_str().unwrap().to_string())
                        .collect(),
                },
            );
        }

        let mut drafters = BTreeMap::new();
        for (name, d) in v.req("drafters").as_obj().unwrap() {
            let kind = d.str_or("kind", "peagle");
            let modes = d
                .get("modes")
                .and_then(|x| x.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str())
                        .map(String::from)
                        .collect::<Vec<_>>()
                })
                .unwrap_or_else(|| default_modes(&kind));
            drafters.insert(
                name.clone(),
                DrafterInfo {
                    name: name.clone(),
                    target: d.str_of("target"),
                    kind,
                    n_layers: d.usize_of("n_layers"),
                    hidden_mode: d.str_or("hidden_mode", "shared"),
                    weights: d.str_of("weights"),
                    param_order: d
                        .req("param_order")
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|x| x.as_str().unwrap().to_string())
                        .collect(),
                    modes,
                },
            );
        }

        let executables = v
            .req("executables")
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| ExecutableInfo {
                name: e.str_of("name"),
                path: e.str_of("path"),
                kind: e.str_of("kind"),
                model: e.get("model").and_then(|x| x.as_str()).map(String::from),
                drafter: e.get("drafter").and_then(|x| x.as_str()).map(String::from),
                batch: e.get("batch").and_then(|x| x.as_usize()),
                k: e.get("k").and_then(|x| x.as_usize()),
                topology: e.get("topology").and_then(|x| x.as_str()).map(String::from),
                block_size: e.get("block_size").and_then(|x| x.as_usize()),
                num_blocks: e.get("num_blocks").and_then(|x| x.as_usize()),
            })
            .collect();

        let mut regimes = BTreeMap::new();
        for (name, r) in v.req("regimes").as_obj().unwrap() {
            regimes.insert(name.clone(), PhraseRegime::from_json(r));
        }

        let mut eval_prompts = BTreeMap::new();
        for (name, p) in v.req("eval_prompts").as_obj().unwrap() {
            eval_prompts.insert(name.clone(), p.as_str().unwrap().to_string());
        }

        let mut table1_contexts = BTreeMap::new();
        if let Some(tc) = v.get("table1_contexts").and_then(|x| x.as_obj()) {
            for (k, lbl) in tc {
                table1_contexts
                    .insert(k.parse().unwrap_or(0), lbl.as_str().unwrap_or("").to_string());
            }
        }

        Ok(Manifest {
            root,
            vocab: v.usize_of("vocab"),
            s_max: v.usize_of("s_max"),
            kv_block_size: v.get("kv_block_size").and_then(|x| x.as_usize()).unwrap_or(16),
            prefix_tail_pad: v.get("prefix_tail_pad").and_then(|x| x.as_usize()).unwrap_or(32),
            paged_inplace: v.get("paged_inplace").and_then(|x| x.as_bool()).unwrap_or(false),
            commit_plan_rows: v.get("commit_plan_rows").and_then(|x| x.as_usize()).unwrap_or(0),
            prompt_pad: v.usize_of("prompt_pad"),
            ctx_window: v.usize_of("ctx_window"),
            pad_id: v.usize_of("pad_id") as i32,
            bos_id: v.usize_of("bos_id") as i32,
            eos_id: v.usize_of("eos_id") as i32,
            mask_id: v.usize_of("mask_id") as i32,
            spec_depths: usize_arr("spec_depths"),
            batch_sizes: usize_arr("batch_sizes"),
            default_k: v.usize_of("default_k"),
            targets,
            drafters,
            executables,
            regimes,
            eval_prompts,
            training_logs: v.get("training_logs").cloned().unwrap_or(Json::Obj(vec![])),
            table1_contexts,
        })
    }

    pub fn target(&self, name: &str) -> Result<&TargetInfo> {
        self.targets.get(name).ok_or_else(|| anyhow!("unknown target {name}"))
    }

    pub fn drafter(&self, name: &str) -> Result<&DrafterInfo> {
        self.drafters.get(name).ok_or_else(|| anyhow!("unknown drafter {name}"))
    }

    pub fn find_exec(
        &self,
        kind: &str,
        model: Option<&str>,
        drafter: Option<&str>,
        batch: Option<usize>,
        k: Option<usize>,
    ) -> Result<&ExecutableInfo> {
        self.executables
            .iter()
            .find(|e| {
                e.kind == kind
                    && (model.is_none() || e.model.as_deref() == model)
                    && (drafter.is_none() || e.drafter.as_deref() == drafter)
                    && (batch.is_none() || e.batch == batch)
                    && (k.is_none() || e.k == k)
            })
            .ok_or_else(|| {
                anyhow!("no executable kind={kind} model={model:?} drafter={drafter:?} b={batch:?} k={k:?}")
            })
    }

    /// Tree executables carry an extra `topology` id next to the usual keys
    /// (the static tree is baked into the lowered HLO; the id must match the
    /// [`TreeTopology::id`](crate::masking::TreeTopology::id) the engine was
    /// configured with).
    pub fn find_exec_tree(
        &self,
        kind: &str,
        model: Option<&str>,
        drafter: Option<&str>,
        batch: Option<usize>,
        topology: &str,
    ) -> Result<&ExecutableInfo> {
        self.executables
            .iter()
            .find(|e| {
                e.kind == kind
                    && (model.is_none() || e.model.as_deref() == model)
                    && (drafter.is_none() || e.drafter.as_deref() == drafter)
                    && (batch.is_none() || e.batch == batch)
                    && e.topology.as_deref() == Some(topology)
            })
            .ok_or_else(|| {
                anyhow!(
                    "no executable kind={kind} model={model:?} drafter={drafter:?} \
                     b={batch:?} topology={topology:?} — rebuild artifacts with tree \
                     lowering (python/compile/aot.py, TREE_TOPOLOGIES / TREE_DYN_ENVELOPES)"
                )
            })
    }

    pub fn abs(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    /// Serving drafter name for (target, method) where method ∈ {ar, pe4, pe2}.
    pub fn serving_drafter(&self, target: &str, method: &str) -> String {
        format!("{target}-{method}")
    }
}
