//! Analytical training-memory model at PAPER scale — classifies the
//! OOM / "Infeas." cells of Table 1 from first principles.
//!
//! The paper trains on 8×H200 (141 GB HBM each) with micro-batch 1, and
//! names exactly two failure modes, which are what we model:
//!
//! * **OOM — attention memory.** The cross-depth COD mask is irregular, so
//!   the baselines materialize per-head score matrices for the backward
//!   pass: bytes ≈ rows × keys × heads × layers × 2 (bf16). ParallelSpec
//!   extends every sequence to n·K rows (no COD); PARD reduces rows to
//!   L = n·(1-r^K)/(1-r) but retains 4 layers; P-EAGLE partitions rows into
//!   S segments (peak rows/S × (rows/S + n cumulative keys)).
//! * **Infeasible — data loading.** PARD builds an O(L²)-predicate mask per
//!   example inside the loader. Throughput is calibrated against the
//!   paper's own Table 2 measurement (718.5 s / 128 examples at n=2048,
//!   K=8 ⇒ ~5.1e7 predicate evals/s); >10 h/epoch on UltraChat (200K
//!   examples) is the paper's "Infeas." bound.
//!
//! Everything else (optimizer states, weights, framework overhead) is folded
//! into the activation budget fraction. The *comparative* classification is
//! the deliverable; `benches/table1_context_scaling.rs` prints it next to
//! the measured mini-scale acceptance lengths.

/// H200 HBM per GPU, bytes (the paper's hardware, Appendix A).
pub const H200_BYTES: f64 = 141e9;
/// Fraction of HBM available to activations after weights/optimizer/runtime.
pub const ACT_FRACTION: f64 = 0.6;
/// Bytes per activation element (bf16).
pub const BYTES_EL: f64 = 2.0;
/// Drafter width at paper scale (EAGLE drafters use the target's d_model).
pub const D_MODEL: f64 = 4096.0;
/// Retained d-wide activation copies per layer (qkv/o/mlp backward).
pub const LINEAR_COPIES: f64 = 8.0;
/// Per-example mask-construction throughput, predicate evals/s, calibrated
/// to the paper's Table 2: 718.5 s for 128 examples at n=2048, K=8 where
/// L ≈ 2048·4.16 ⇒ L² ≈ 7.3e7 evals/example.
pub const MASK_EVALS_PER_SEC: f64 = 1.3e7;
/// UltraChat examples per epoch (paper Table 2).
pub const EPOCH_EXAMPLES: usize = 200_000;
/// Parallel dataloader workers on the 8×H200 node (mask construction is
/// loader-side work; the single-stream measurement in Table 2 is divided
/// across workers for epoch projections).
pub const LOADER_WORKERS: f64 = 64.0;
/// The paper's practicality bound for Table 1 ("10+h per epoch").
pub const INFEASIBLE_HOURS: f64 = 10.0;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Feasibility {
    Ok,
    /// Per-epoch data-loading wall clock exceeds the 10 h bound.
    Infeasible,
    /// Peak activation memory exceeds the HBM budget.
    Oom,
}

impl Feasibility {
    pub fn label(&self) -> &'static str {
        match self {
            Feasibility::Ok => "ok",
            Feasibility::Infeasible => "Infeas.",
            Feasibility::Oom => "OOM",
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct TrainSetup {
    pub n: usize,
    pub k: usize,
    pub cod_ratio: f64,
    pub layers: usize,
    pub heads: usize,
    pub segments: usize,
    /// per-example mask predicate evaluations (0 = amortized/static mask)
    pub mask_evals_per_example: f64,
}

impl TrainSetup {
    /// ParallelSpec: 1 layer, no COD (full n·K expansion), static mask
    /// (no per-example construction — its expansion is data-independent).
    pub fn parallelspec(n: usize, k: usize) -> TrainSetup {
        TrainSetup {
            n, k, cod_ratio: 1.0, layers: 1, heads: 32, segments: 1,
            mask_evals_per_example: 0.0,
        }
    }

    /// PARD + EAGLE-3: 4 layers, COD 0.8, per-example mask construction.
    pub fn pard(n: usize, k: usize) -> TrainSetup {
        let l = total_rows(n, k, 0.8);
        TrainSetup {
            n, k, cod_ratio: 0.8, layers: 4, heads: 32, segments: 1,
            mask_evals_per_example: l * l,
        }
    }

    /// P-EAGLE: 4 layers, COD 0.8, amortized masks, sequence partitioning
    /// with S chosen by the framework (one segment per ~2K context).
    pub fn peagle(n: usize, k: usize) -> TrainSetup {
        TrainSetup {
            n, k, cod_ratio: 0.8, layers: 4, heads: 32,
            segments: (n / 2048).max(1),
            mask_evals_per_example: 0.0,
        }
    }
}

/// Total extended positions L (paper §3.2 closed form).
pub fn total_rows(n: usize, k: usize, ratio: f64) -> f64 {
    if (ratio - 1.0).abs() < 1e-9 {
        (n * k) as f64
    } else {
        n as f64 * (1.0 - ratio.powi(k as i32)) / (1.0 - ratio)
    }
}

/// Peak activation bytes for one micro-batch (micro-batch 1, paper App. A).
pub fn peak_activation_bytes(s: &TrainSetup) -> f64 {
    let l = total_rows(s.n, s.k, s.cod_ratio);
    let (rows, keys) = if s.segments > 1 {
        let seg = l / s.segments as f64;
        (seg, seg + s.n as f64) // Phase-3 cumulative depth-0 keys
    } else {
        (l, l)
    };
    let score = rows * keys * s.heads as f64;
    let linear = rows * D_MODEL * LINEAR_COPIES;
    (score + linear) * s.layers as f64 * BYTES_EL
}

/// Single-stream loading seconds for a fixed example count (the Table 2
/// "Load (128 ex.)" measurement shape).
pub fn loading_seconds(s: &TrainSetup, examples: usize) -> f64 {
    s.mask_evals_per_example * examples as f64 / MASK_EVALS_PER_SEC
}

/// Data-loading hours per epoch with the node's parallel loader workers.
pub fn epoch_loading_hours(s: &TrainSetup, examples: usize) -> f64 {
    loading_seconds(s, examples) / LOADER_WORKERS / 3600.0
}

/// Table 1 classification for a method at context length n.
pub fn classify(s: &TrainSetup, examples: usize) -> Feasibility {
    if peak_activation_bytes(s) > H200_BYTES * ACT_FRACTION {
        return Feasibility::Oom;
    }
    if epoch_loading_hours(s, examples) > INFEASIBLE_HOURS {
        return Feasibility::Infeasible;
    }
    Feasibility::Ok
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_parallelspec_row() {
        // ParallelSpec: ok at 1K/4K, OOM at 8K and 20K (quadratic attention)
        assert_eq!(classify(&TrainSetup::parallelspec(1024, 8), EPOCH_EXAMPLES), Feasibility::Ok);
        assert_eq!(classify(&TrainSetup::parallelspec(4096, 8), EPOCH_EXAMPLES), Feasibility::Ok);
        assert_eq!(classify(&TrainSetup::parallelspec(8192, 8), EPOCH_EXAMPLES), Feasibility::Oom);
        assert_eq!(classify(&TrainSetup::parallelspec(20480, 8), EPOCH_EXAMPLES), Feasibility::Oom);
    }

    #[test]
    fn table1_pard_row() {
        // PARD: ok at 1K, infeasible at 4K (mask construction), OOM at 8K+
        assert_eq!(classify(&TrainSetup::pard(1024, 8), EPOCH_EXAMPLES), Feasibility::Ok);
        assert_eq!(classify(&TrainSetup::pard(4096, 8), EPOCH_EXAMPLES), Feasibility::Infeasible);
        assert_eq!(classify(&TrainSetup::pard(8192, 8), EPOCH_EXAMPLES), Feasibility::Oom);
        assert_eq!(classify(&TrainSetup::pard(20480, 8), EPOCH_EXAMPLES), Feasibility::Oom);
    }

    #[test]
    fn table1_peagle_row() {
        // P-EAGLE: ok through 20K (amortized masks + partitioning)
        for n in [1024usize, 4096, 8192, 20480] {
            assert_eq!(
                classify(&TrainSetup::peagle(n, 8), EPOCH_EXAMPLES),
                Feasibility::Ok,
                "n={n}: peak {:.1} GB",
                peak_activation_bytes(&TrainSetup::peagle(n, 8)) / 1e9
            );
        }
    }

    #[test]
    fn rows_closed_form() {
        assert!((total_rows(8192, 8, 0.8) - 34000.0).abs() < 1500.0);
        assert_eq!(total_rows(100, 4, 1.0), 400.0);
    }

    #[test]
    fn partitioning_reduces_peak() {
        let base = TrainSetup { segments: 1, ..TrainSetup::peagle(20480, 8) };
        let part = TrainSetup::peagle(20480, 8);
        assert!(part.segments > 1);
        assert!(peak_activation_bytes(&part) < peak_activation_bytes(&base) / 2.0);
    }

    #[test]
    fn table2_loading_calibration() {
        // PARD at n=2048, K=8, 128 examples ⇒ near the paper's 718.5 s.
        let s = TrainSetup::pard(2048, 8);
        let secs = loading_seconds(&s, 128);
        assert!((secs - 718.5).abs() / 718.5 < 0.25, "{secs}");
    }

    #[test]
    fn oom_boundary_monotone() {
        // feasibility can only get worse as n grows, for every method
        for mk in [TrainSetup::parallelspec as fn(usize, usize) -> TrainSetup,
                   TrainSetup::pard, TrainSetup::peagle] {
            let mut worst = 0u8;
            for n in [512usize, 1024, 2048, 4096, 8192, 16384, 20480, 40960] {
                let c = match classify(&mk(n, 8), EPOCH_EXAMPLES) {
                    Feasibility::Ok => 0,
                    Feasibility::Infeasible => 1,
                    Feasibility::Oom => 2,
                };
                assert!(c >= worst, "feasibility improved at n={n}");
                worst = worst.max(c);
            }
        }
    }
}
