//! Execute the workload matrix against a loaded [`ModelRuntime`] and fill a
//! [`BenchReport`].
//!
//! The runner is a thin loop over [`suite`](super::suite)'s matrix: resolve
//! the target's drafters from the manifest, probe each (shape, cache,
//! drafter, load) cell for serveability (pure manifest lookups — a drafter
//! lowered chain-only simply drops out of the tree/dyn rows, counted in the
//! report's `note`), and run the survivors through the same
//! `report::bench_otps`/`bench_otps_open` entry points the CLI benches use —
//! the trajectory measures the real serving path, not a parallel harness.
//! The adaptive-controller column rides after the static matrix: one
//! `bench_otps_adaptive` cell per cache mode, drafter "auto".

use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{anyhow, ensure, Result};

use crate::coordinator::{ControllerConfig, PagedKvConfig, SamplingParams};
use crate::masking::{DynamicTreeConfig, TreeTopology};
use crate::report::{self, OtpsRun};
use crate::runtime::ModelRuntime;

use super::schema::{
    BenchReport, CellConfig, CellMetrics, CellRecord, CellTiming, PolicyCell, SCHEMA_VERSION,
};
use super::suite::{policy_for, Load, SuiteSpec, CACHES, SHAPES, SHARED_PREFIX_TOKENS, TREE_SPEC};

/// `git rev-parse --short HEAD`, or "unknown" (no git, not a repo, …) — the
/// header is provenance, never load-bearing.
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

fn unix_now() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0)
}

/// Run the full matrix for `spec`; `pr` tags the report (file naming stays
/// with the caller). Cells whose executables are not lowered are skipped and
/// counted in the report `note` — an EMPTY matrix is an error (wrong target
/// or missing artifacts), a partial one is information.
pub fn run_suite(mr: &mut ModelRuntime, spec: &SuiteSpec, pr: &str) -> Result<BenchReport> {
    let k = mr.manifest.default_k;
    let drafters: Vec<String> = mr
        .manifest
        .drafters
        .values()
        .filter(|d| d.target == spec.target)
        .map(|d| d.name.clone())
        .collect();
    ensure!(!drafters.is_empty(), "no drafters serve target {}", spec.target);

    let tree_topo = TreeTopology::parse(TREE_SPEC).map_err(|e| anyhow!(e))?;
    let dyn_cfg = DynamicTreeConfig::serving_default();
    let mut cells = Vec::new();
    let mut skipped = 0usize;
    for shape in SHAPES {
        let (tree, dynamic) = match shape {
            "tree" => (Some(&tree_topo), None),
            "dyn" => (None, Some(&dyn_cfg)),
            _ => (None, None),
        };
        for cache in CACHES {
            // "prefix" = paged + automatic prefix cache, shared-prefix
            // workload; it serves from the same paged executables
            let paged_on = cache != "dense";
            let prefix_on = cache == "prefix";
            for drafter in &drafters {
                let policy = policy_for(shape, drafter, k).map_err(|e| anyhow!(e))?;
                for load in spec.loads() {
                    if prefix_on && !load.deterministic() {
                        // the prefix column is closed-loop by definition (see
                        // suite::CACHES) — not a lowering gap, so not `skipped`
                        continue;
                    }
                    let conc = load.concurrency();
                    if mr.probe_policy_execs(&spec.target, &policy, conc, paged_on).is_err() {
                        skipped += 1;
                        continue;
                    }
                    let paged = paged_on.then(|| PagedKvConfig {
                        block_size: None,
                        num_blocks: spec.kv_blocks,
                        prefix_cache: prefix_on,
                    });
                    let run = match load {
                        // the trajectory pins greedy serving: cross-PR OTPS
                        // deltas must never fold in sampling-path variance
                        Load::Closed { .. } if prefix_on => report::bench_otps_prefix(
                            mr, drafter, &spec.dataset, k, conc, spec.requests, spec.max_new,
                            spec.seed, tree, dynamic, paged, SamplingParams::greedy(),
                            SHARED_PREFIX_TOKENS,
                        )?,
                        Load::Closed { .. } => report::bench_otps(
                            mr, drafter, &spec.dataset, k, conc, spec.requests, spec.max_new,
                            spec.seed, false, tree, dynamic, paged, SamplingParams::greedy(),
                        )?,
                        Load::Open { rate_rps, .. } => report::bench_otps_open(
                            mr, drafter, &spec.dataset, k, conc, spec.requests, spec.max_new,
                            spec.seed, false, tree, dynamic, paged, SamplingParams::greedy(),
                            rate_rps,
                        )?,
                    };
                    cells.push(cell_record(spec, shape, cache, drafter, &policy.id(), load, &run));
                }
            }
        }
    }
    // the adaptive-controller column: one cell per cache mode (dense,
    // paged), NOT per (shape, drafter) — the controller owns both choices,
    // so the cell's drafter is "auto" and its policy is "adaptive". The
    // prefix column is skipped: its workload (shared-prefix, closed-loop)
    // measures prefill reuse, not speculation policy.
    for cache in ["dense", "paged"] {
        let paged_on = cache != "dense";
        for load in spec.adaptive_loads() {
            let conc = load.concurrency();
            if report::adaptive_allowlist(mr, &spec.target, conc, k, paged_on).is_empty() {
                skipped += 1;
                continue;
            }
            let paged = paged_on.then(|| PagedKvConfig {
                block_size: None,
                num_blocks: spec.kv_blocks,
                prefix_cache: false,
            });
            let run = report::bench_otps_adaptive(
                mr, &spec.target, &spec.dataset, k, conc, spec.requests, spec.max_new,
                spec.seed, false, paged, SamplingParams::greedy(), Some(load.rate_rps()),
                ControllerConfig::default(),
            )?;
            cells.push(cell_record(spec, "adaptive", cache, "auto", "adaptive", load, &run));
        }
    }
    ensure!(
        !cells.is_empty(),
        "every matrix cell was skipped — no lowered executables for target {}",
        spec.target
    );
    Ok(BenchReport {
        schema_version: SCHEMA_VERSION,
        pr: pr.to_string(),
        git_rev: git_rev(),
        created_unix: unix_now(),
        suite: spec.suite_name().to_string(),
        target: spec.target.clone(),
        dataset: spec.dataset.clone(),
        seed: spec.seed,
        note: if skipped == 0 {
            String::new()
        } else {
            format!("{skipped} matrix cells skipped (executables not lowered)")
        },
        cells,
    })
}

fn cell_record(
    spec: &SuiteSpec,
    shape: &str,
    cache: &str,
    drafter: &str,
    policy_id: &str,
    load: Load,
    run: &OtpsRun,
) -> CellRecord {
    let m = &run.metrics;
    let config = CellConfig {
        shape: shape.to_string(),
        cache: cache.to_string(),
        drafter: drafter.to_string(),
        policy: policy_id.to_string(),
        load: load.name().to_string(),
        concurrency: load.concurrency(),
        rate_rps: load.rate_rps(),
        requests: spec.requests,
        max_new: spec.max_new,
        seed: spec.seed,
        deterministic: load.deterministic(),
    };
    CellRecord {
        id: config.id(),
        metrics: CellMetrics {
            requests_finished: m.requests_finished,
            tokens_emitted: m.tokens_emitted,
            iterations: m.iterations,
            acceptance_length: m.acceptance_length(),
            mean_occupancy: m.mean_occupancy(),
            mean_block_occupancy: m.mean_block_occupancy(),
            blocks_peak: m.blocks_peak,
            admissions_blocked: m.admissions_blocked,
            mean_active_nodes: m.mean_active_nodes(),
            downloads_per_step: m.downloads_per_step(),
            uploads_per_step: m.uploads_per_step(),
            download_bytes: m.download_bytes as usize,
            upload_bytes: m.upload_bytes as usize,
            kv_downloads: m.kv_downloads as usize,
            kv_uploads: m.kv_uploads as usize,
            device_path_commits: m.device_path_commits,
            per_policy: m
                .per_policy
                .iter()
                .map(|(name, pm)| PolicyCell {
                    policy: name.clone(),
                    iterations: pm.iterations,
                    acceptance_length: pm.acceptance_length(),
                })
                .collect(),
        },
        timing: CellTiming {
            otps: m.otps(),
            ttft_p50_us: m.ttft_quantile(0.5).as_micros() as u64,
            ttft_p99_us: m.ttft_quantile(0.99).as_micros() as u64,
            tpot_p50_us: m.tpot_quantile(0.5).as_micros() as u64,
            tpot_p99_us: m.tpot_quantile(0.99).as_micros() as u64,
            latency_p50_us: m.latency_quantile(0.5).as_micros() as u64,
            latency_p99_us: m.latency_quantile(0.99).as_micros() as u64,
            wall_ms: m.wall_time.as_millis() as u64,
        },
        config,
    }
}

/// Strip the wall-clock payloads from a report for determinism comparison:
/// zero `created_unix` and every cell's `timing`. Two same-seed smoke runs
/// must agree exactly on what remains (deterministic cells' configs +
/// metrics); the integration test and ARCHITECTURE.md state this contract.
pub fn deterministic_view(r: &BenchReport) -> BenchReport {
    let mut out = r.clone();
    out.created_unix = 0;
    out.git_rev = "-".into();
    for c in &mut out.cells {
        c.timing = CellTiming::default();
        if !c.config.deterministic {
            // open-loop cells: admission interleaving is wall-clock too
            c.metrics = CellMetrics::default();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn git_rev_never_panics() {
        // in this repo it's a short hash; elsewhere "unknown" — total either way
        let r = git_rev();
        assert!(!r.is_empty());
    }

    #[test]
    fn deterministic_view_strips_wall_clock() {
        let mut r = BenchReport {
            schema_version: SCHEMA_VERSION,
            pr: "6".into(),
            git_rev: "abc".into(),
            created_unix: 123,
            suite: "smoke".into(),
            target: "t".into(),
            dataset: "mono".into(),
            seed: 11,
            note: String::new(),
            cells: vec![],
        };
        let closed = CellConfig {
            shape: "chain".into(),
            cache: "dense".into(),
            drafter: "d".into(),
            policy: "d/chain:4".into(),
            load: "closed".into(),
            concurrency: 2,
            rate_rps: 0.0,
            requests: 6,
            max_new: 24,
            seed: 11,
            deterministic: true,
        };
        let mut open = closed.clone();
        open.load = "open".into();
        open.rate_rps = 8.0;
        open.deterministic = false;
        let metrics = CellMetrics { tokens_emitted: 100, ..CellMetrics::default() };
        let timing = CellTiming { otps: 50.0, wall_ms: 10, ..CellTiming::default() };
        r.cells = vec![
            CellRecord { id: closed.id(), config: closed, metrics: metrics.clone(), timing: timing.clone() },
            CellRecord { id: open.id(), config: open, metrics, timing },
        ];
        let v = deterministic_view(&r);
        assert_eq!(v.created_unix, 0);
        // every cell's timing is zeroed
        assert!(v.cells.iter().all(|c| c.timing == CellTiming::default()));
        // deterministic cells keep their metrics, open-loop cells don't
        assert_eq!(v.cells[0].metrics.tokens_emitted, 100);
        assert_eq!(v.cells[1].metrics.tokens_emitted, 0);
        // configs (the coverage) always survive
        assert_eq!(v.cells[1].config.rate_rps, 8.0);
    }
}
