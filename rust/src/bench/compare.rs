//! Cell-by-cell trajectory comparison — the piece that turns `BENCH_*.json`
//! files into a regression GATE.
//!
//! Semantics (documented in ARCHITECTURE.md; change both together):
//!
//! - cells are matched by id; the header (git rev, timestamps) never gates
//! - a cell in OLD but not NEW is a **regression** (coverage loss — a
//!   drafter or mode silently dropping out of the matrix is exactly the
//!   failure this catches)
//! - a cell in NEW but not OLD passes (`new-cell`) — growing the matrix is
//!   never punished
//! - a matched cell regresses when OTPS drops more than `otps_frac` OR
//!   p99 TTFT grows more than `ttft_frac` (both relative); it is `improved`
//!   when OTPS grows more than `otps_frac` with TTFT inside threshold
//! - a zero baseline value skips that ratio check: a hand-authored skeleton
//!   (all-zero timing) gates nothing until a real run replaces it, which is
//!   what lets the advisory CI compare run against a placeholder baseline

use crate::util::bench::Table;

use super::schema::{BenchReport, CellRecord};

#[derive(Clone, Copy, Debug)]
pub struct Thresholds {
    /// max tolerated relative OTPS drop (0.10 = -10%)
    pub otps_frac: f64,
    /// max tolerated relative p99 TTFT growth (0.20 = +20%)
    pub ttft_frac: f64,
}

impl Default for Thresholds {
    fn default() -> Thresholds {
        Thresholds { otps_frac: 0.10, ttft_frac: 0.20 }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum CellStatus {
    Pass,
    Improved,
    /// human-readable reasons, e.g. `OTPS -23.1% (limit -10%)`
    Regressed(Vec<String>),
    NewCell,
    MissingCell,
}

impl CellStatus {
    pub fn is_regression(&self) -> bool {
        matches!(self, CellStatus::Regressed(_) | CellStatus::MissingCell)
    }

    fn label(&self) -> &'static str {
        match self {
            CellStatus::Pass => "pass",
            CellStatus::Improved => "improved",
            CellStatus::Regressed(_) => "REGRESSED",
            CellStatus::NewCell => "new-cell",
            CellStatus::MissingCell => "MISSING",
        }
    }
}

#[derive(Clone, Debug)]
pub struct CellDiff {
    pub id: String,
    pub status: CellStatus,
    /// (old, new); None on the missing side
    pub otps: (Option<f64>, Option<f64>),
    pub ttft_p99_us: (Option<u64>, Option<u64>),
}

#[derive(Clone, Debug)]
pub struct CompareReport {
    pub thresholds: Thresholds,
    pub diffs: Vec<CellDiff>,
}

impl CompareReport {
    pub fn regressions(&self) -> usize {
        self.diffs.iter().filter(|d| d.status.is_regression()).count()
    }

    pub fn has_regressions(&self) -> bool {
        self.regressions() > 0
    }

    /// The regression table the CLI prints: one row per cell, worst first
    /// (regressions top), then a one-line verdict.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["cell", "status", "OTPS old→new", "p99 TTFT old→new", "detail"]);
        let mut rows: Vec<&CellDiff> = self.diffs.iter().collect();
        rows.sort_by_key(|d| !d.status.is_regression());
        for d in rows {
            let detail = match &d.status {
                CellStatus::Regressed(reasons) => reasons.join("; "),
                _ => String::new(),
            };
            t.row(vec![
                d.id.clone(),
                d.status.label().to_string(),
                pair(d.otps.0.map(|x| format!("{x:.0}")), d.otps.1.map(|x| format!("{x:.0}"))),
                pair(
                    d.ttft_p99_us.0.map(|x| format!("{x}µs")),
                    d.ttft_p99_us.1.map(|x| format!("{x}µs")),
                ),
                detail,
            ]);
        }
        let mut out = t.render();
        let n = self.regressions();
        out.push_str(&format!(
            "{} cells compared: {} regressed (thresholds: OTPS -{:.0}%, p99 TTFT +{:.0}%)\n",
            self.diffs.len(),
            n,
            self.thresholds.otps_frac * 100.0,
            self.thresholds.ttft_frac * 100.0,
        ));
        out
    }
}

fn pair(old: Option<String>, new: Option<String>) -> String {
    format!(
        "{} → {}",
        old.unwrap_or_else(|| "-".into()),
        new.unwrap_or_else(|| "-".into())
    )
}

/// Diff two trajectory files cell-by-cell. Pure on the parsed reports —
/// callers decide what an exit code means (the CLI gates, CI may run
/// advisory).
pub fn compare(old: &BenchReport, new: &BenchReport, th: Thresholds) -> CompareReport {
    let mut diffs = Vec::new();
    for oc in &old.cells {
        match new.cells.iter().find(|nc| nc.id == oc.id) {
            None => diffs.push(CellDiff {
                id: oc.id.clone(),
                status: CellStatus::MissingCell,
                otps: (Some(oc.timing.otps), None),
                ttft_p99_us: (Some(oc.timing.ttft_p99_us), None),
            }),
            Some(nc) => diffs.push(diff_cell(oc, nc, th)),
        }
    }
    for nc in &new.cells {
        if !old.cells.iter().any(|oc| oc.id == nc.id) {
            diffs.push(CellDiff {
                id: nc.id.clone(),
                status: CellStatus::NewCell,
                otps: (None, Some(nc.timing.otps)),
                ttft_p99_us: (None, Some(nc.timing.ttft_p99_us)),
            });
        }
    }
    CompareReport { thresholds: th, diffs }
}

fn diff_cell(oc: &CellRecord, nc: &CellRecord, th: Thresholds) -> CellDiff {
    let mut reasons = Vec::new();
    let (o_otps, n_otps) = (oc.timing.otps, nc.timing.otps);
    // zero baselines gate nothing (skeleton files; cells that emitted no
    // tokens measure nothing worth ratio-ing)
    if o_otps > 0.0 && n_otps < o_otps * (1.0 - th.otps_frac) {
        reasons.push(format!(
            "OTPS {:+.1}% (limit -{:.0}%)",
            (n_otps / o_otps - 1.0) * 100.0,
            th.otps_frac * 100.0
        ));
    }
    let (o_ttft, n_ttft) = (oc.timing.ttft_p99_us as f64, nc.timing.ttft_p99_us as f64);
    if o_ttft > 0.0 && n_ttft > o_ttft * (1.0 + th.ttft_frac) {
        reasons.push(format!(
            "p99 TTFT {:+.1}% (limit +{:.0}%)",
            (n_ttft / o_ttft - 1.0) * 100.0,
            th.ttft_frac * 100.0
        ));
    }
    let status = if !reasons.is_empty() {
        CellStatus::Regressed(reasons)
    } else if o_otps > 0.0 && n_otps > o_otps * (1.0 + th.otps_frac) {
        CellStatus::Improved
    } else {
        CellStatus::Pass
    };
    CellDiff {
        id: oc.id.clone(),
        status,
        otps: (Some(o_otps), Some(n_otps)),
        ttft_p99_us: (Some(oc.timing.ttft_p99_us), Some(nc.timing.ttft_p99_us)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::schema::SCHEMA_VERSION;

    /// Hand-built golden fixture: a two-cell trajectory with round numbers
    /// (OTPS 1000 / p99 TTFT 1000µs) so the threshold arithmetic reads off
    /// the test directly.
    fn golden(cells: &[(&str, f64, u64)]) -> BenchReport {
        let body: Vec<String> = cells
            .iter()
            .map(|(drafter, otps, ttft)| {
                format!(
                    r#"{{"id": "chain/dense/{d}/closed-c2",
                        "config": {{"shape": "chain", "cache": "dense",
                          "drafter": "{d}", "policy": "{d}/chain:4",
                          "load": "closed", "concurrency": 2, "rate_rps": 0,
                          "requests": 6, "max_new": 24, "seed": 11,
                          "deterministic": true}},
                        "metrics": {{"requests_finished": 6, "tokens_emitted": 100,
                          "iterations": 25, "acceptance_length": 4.0,
                          "mean_occupancy": 0.9, "mean_block_occupancy": 0,
                          "blocks_peak": 0, "admissions_blocked": 0,
                          "mean_active_nodes": 0, "downloads_per_step": 0,
                          "uploads_per_step": 0, "download_bytes": 0,
                          "upload_bytes": 0, "kv_downloads": 0,
                          "kv_uploads": 0, "device_path_commits": 0,
                          "per_policy": []}},
                        "timing": {{"otps": {otps}, "ttft_p50_us": 500,
                          "ttft_p99_us": {ttft}, "tpot_p50_us": 100,
                          "tpot_p99_us": 200, "latency_p50_us": 5000,
                          "latency_p99_us": 9000, "wall_ms": 100}}}}"#,
                    d = drafter,
                )
            })
            .collect();
        let s = format!(
            r#"{{"schema_version": {SCHEMA_VERSION}, "pr": "6", "git_rev": "test",
                "created_unix": 0, "suite": "smoke", "target": "target-m",
                "dataset": "mono", "seed": 11, "note": "",
                "cells": [{}]}}"#,
            body.join(",")
        );
        BenchReport::parse(&s).expect("golden fixture must be schema-valid")
    }

    fn status_of<'a>(r: &'a CompareReport, id_part: &str) -> &'a CellStatus {
        &r.diffs.iter().find(|d| d.id.contains(id_part)).unwrap().status
    }

    #[test]
    fn pass_improved_regressed_new_missing() {
        // all five statuses from one golden pair
        let old = golden(&[("a", 1000.0, 1000), ("b", 1000.0, 1000), ("gone", 1000.0, 1000)]);
        let new = golden(&[
            ("a", 950.0, 1100),  // -5% OTPS, +10% TTFT: inside thresholds
            ("b", 1200.0, 900),  // +20% OTPS: improved
            ("fresh", 500.0, 1000), // only in new
        ]);
        let r = compare(&old, &new, Thresholds::default());
        assert_eq!(*status_of(&r, "/a/"), CellStatus::Pass);
        assert_eq!(*status_of(&r, "/b/"), CellStatus::Improved);
        assert_eq!(*status_of(&r, "/gone/"), CellStatus::MissingCell);
        assert_eq!(*status_of(&r, "/fresh/"), CellStatus::NewCell);
        // missing cell counts as a regression; new cell does not
        assert_eq!(r.regressions(), 1);
        assert!(r.has_regressions());

        let worse = golden(&[("a", 850.0, 1000), ("b", 1000.0, 1300), ("gone", 1000.0, 1000)]);
        let r = compare(&old, &worse, Thresholds::default());
        match status_of(&r, "/a/") {
            CellStatus::Regressed(reasons) => assert!(reasons[0].contains("OTPS"), "{reasons:?}"),
            s => panic!("expected OTPS regression, got {s:?}"),
        }
        match status_of(&r, "/b/") {
            CellStatus::Regressed(reasons) => assert!(reasons[0].contains("TTFT"), "{reasons:?}"),
            s => panic!("expected TTFT regression, got {s:?}"),
        }
        assert_eq!(r.regressions(), 2); // a (OTPS) and b (TTFT); gone is present here
    }

    #[test]
    fn thresholds_are_strict_inequalities_at_the_boundary() {
        let old = golden(&[("a", 1000.0, 1000)]);
        // exactly -10% / +20%: NOT a regression (limits are inclusive)
        let at = golden(&[("a", 900.0, 1200)]);
        assert!(!compare(&old, &at, Thresholds::default()).has_regressions());
        // a hair beyond: regression
        let past = golden(&[("a", 899.0, 1000)]);
        assert!(compare(&old, &past, Thresholds::default()).has_regressions());
        // custom thresholds move the line
        let loose = Thresholds { otps_frac: 0.50, ttft_frac: 0.50 };
        assert!(!compare(&old, &past, loose).has_regressions());
    }

    #[test]
    fn zero_baseline_gates_nothing() {
        // the skeleton-baseline rule: an all-zero old cell passes any new
        // numbers (and identical files trivially pass)
        let skeleton = golden(&[("a", 0.0, 0)]);
        let real = golden(&[("a", 123.0, 456)]);
        assert!(!compare(&skeleton, &real, Thresholds::default()).has_regressions());
        assert!(!compare(&skeleton, &skeleton, Thresholds::default()).has_regressions());
        // but a real baseline against a zeroed new run DOES regress
        assert!(compare(&real, &skeleton, Thresholds::default()).has_regressions());
    }

    #[test]
    fn render_lists_every_cell_and_the_verdict() {
        let old = golden(&[("a", 1000.0, 1000), ("gone", 1000.0, 1000)]);
        let new = golden(&[("a", 500.0, 1000)]);
        let r = compare(&old, &new, Thresholds::default());
        let s = r.render();
        assert!(s.contains("REGRESSED"), "{s}");
        assert!(s.contains("MISSING"), "{s}");
        assert!(s.contains("2 regressed"), "{s}");
        // regressions sort to the top of the table
        let first_row = s.lines().nth(2).unwrap();
        assert!(first_row.contains("REGRESSED") || first_row.contains("MISSING"), "{s}");
    }
}
