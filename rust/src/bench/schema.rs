//! The `BENCH_<pr>.json` perf-trajectory schema.
//!
//! One report = one suite run: a self-describing header (schema version, PR
//! tag, git revision, suite flavor, base workload config) plus one record
//! per matrix cell. Records split into three payloads with different
//! comparison semantics:
//!
//! - `config`  — what the cell measured (identity; derives the cell id)
//! - `metrics` — deterministic counters (identical across same-seed runs of
//!               a `deterministic` cell; the determinism test compares these)
//! - `timing`  — wall-clock-derived numbers (OTPS, TTFT/TPOT/latency
//!               quantiles); never expected to be bit-stable, gated only
//!               through the comparator's relative thresholds
//!
//! Serialization uses a FIXED key order, so serialize → parse → re-serialize
//! is byte-identical (the round-trip test pins this): trajectory diffs in
//! git stay minimal and the comparator can treat files as canonical.

use crate::util::json::Json;

/// Bump when a field is added/renamed/retyped. The parser REJECTS other
/// versions — a trajectory file is an interchange format, not a best-effort
/// guess.
///
/// v2: host-transfer accounting columns in `metrics` (`downloads_per_step`,
/// `uploads_per_step`, `download_bytes`, `upload_bytes`, `kv_downloads`,
/// `kv_uploads`, `device_path_commits`) — the device-resident-decode
/// trajectory: steady-state paged cells must hold `kv_downloads` at 0.
///
/// v3: the adaptive-controller column. `shape`/`load` admit "adaptive"
/// (controller-assigned policies under open-loop arrivals; `drafter` is
/// "auto" — no single drafter owns the cell), and `per_policy` rows are
/// keyed by full POLICY IDENTITY (`drafter/mode:shape`) under the renamed
/// `policy` key — an adaptive cell legitimately runs several shapes of one
/// drafter, which drafter-keyed rows could not distinguish.
pub const SCHEMA_VERSION: usize = 3;

#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    pub schema_version: usize,
    /// PR tag the file is named for (`BENCH_<pr>.json`)
    pub pr: String,
    /// `git rev-parse --short HEAD` at run time ("unknown" outside a repo)
    pub git_rev: String,
    /// unix seconds at run time (0 for hand-authored skeletons)
    pub created_unix: u64,
    /// "smoke" | "full"
    pub suite: String,
    pub target: String,
    pub dataset: String,
    /// base workload seed (every cell derives from it deterministically)
    pub seed: u64,
    /// free-form provenance note ("" = none)
    pub note: String,
    pub cells: Vec<CellRecord>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct CellRecord {
    /// derived from `config` (see [`CellConfig::id`]); stored redundantly so
    /// the file is greppable, re-checked on parse
    pub id: String,
    pub config: CellConfig,
    pub metrics: CellMetrics,
    pub timing: CellTiming,
}

#[derive(Clone, Debug, PartialEq)]
pub struct CellConfig {
    /// speculation shape: "chain" | "tree" | "dyn" | "adaptive" (the
    /// controller picks the shape per request — no static value fits)
    pub shape: String,
    /// KV cache mode: "dense" | "paged" | "prefix" (paged + automatic
    /// prefix cache on a shared-prefix workload)
    pub cache: String,
    /// drafter name; "auto" for adaptive cells (controller-assigned)
    pub drafter: String,
    /// full policy id (e.g. `target-m-pe4/tree:w3x2x1x1x1`); "adaptive"
    /// for adaptive cells
    pub policy: String,
    /// arrival mode: "closed" | "open" | "adaptive" (open-loop Poisson
    /// arrivals under the adaptive controller)
    pub load: String,
    pub concurrency: usize,
    /// open-loop/adaptive Poisson rate (req/s); 0.0 for closed loop
    pub rate_rps: f64,
    pub requests: usize,
    pub max_new: usize,
    pub seed: u64,
    /// whether same-seed re-runs must reproduce `metrics` exactly
    /// (closed-loop cells: yes; open-loop/adaptive cells admit by wall
    /// clock: no)
    pub deterministic: bool,
}

impl CellConfig {
    /// Canonical cell id: `shape/cache/drafter/closed-cC`,
    /// `shape/cache/drafter/open-cC-rRATE`, or
    /// `adaptive/cache/auto/adaptive-cC-rRATE`.
    pub fn id(&self) -> String {
        match self.load.as_str() {
            "open" => format!(
                "{}/{}/{}/open-c{}-r{}",
                self.shape, self.cache, self.drafter, self.concurrency, self.rate_rps
            ),
            "adaptive" => format!(
                "{}/{}/{}/adaptive-c{}-r{}",
                self.shape, self.cache, self.drafter, self.concurrency, self.rate_rps
            ),
            _ => format!(
                "{}/{}/{}/closed-c{}",
                self.shape, self.cache, self.drafter, self.concurrency
            ),
        }
    }
}

/// Deterministic counters (same-seed reproducible for `deterministic` cells).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct CellMetrics {
    pub requests_finished: usize,
    pub tokens_emitted: usize,
    pub iterations: usize,
    pub acceptance_length: f64,
    pub mean_occupancy: f64,
    /// paged cells only (0.0 in dense mode)
    pub mean_block_occupancy: f64,
    pub blocks_peak: usize,
    pub admissions_blocked: usize,
    /// tree/dyn cells only (0.0 in chain mode)
    pub mean_active_nodes: f64,
    /// host→device/device→host transfer counts per decode step (runtime
    /// boundary accounting): deterministic for closed-loop cells — the
    /// count is a function of the step sequence, not the wall clock
    pub downloads_per_step: f64,
    pub uploads_per_step: f64,
    /// total boundary traffic over the cell (bytes, exact)
    pub download_bytes: usize,
    pub upload_bytes: usize,
    /// engine KV-state round trips during decode steps — the
    /// device-resident-decode headline: 0 for steady-state paged cells
    pub kv_downloads: usize,
    pub kv_uploads: usize,
    /// accepted-path commits executed on device (`commit-path-paged`)
    pub device_path_commits: usize,
    /// per-policy breakdown keyed by policy identity (`drafter/mode:shape`;
    /// singleton for single-policy cells — adaptive cells carry one row per
    /// policy the controller actually served)
    pub per_policy: Vec<PolicyCell>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct PolicyCell {
    /// policy-identity key (`drafter/mode:shape` — v2's `drafter` column,
    /// renamed when the engine re-keyed its per-policy metrics)
    pub policy: String,
    pub iterations: usize,
    pub acceptance_length: f64,
}

/// Wall-clock-derived numbers (never bit-stable; threshold-compared only).
/// Durations are integer microseconds — coarse enough to serialize exactly,
/// fine enough for sub-millisecond TPOT.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct CellTiming {
    pub otps: f64,
    pub ttft_p50_us: u64,
    pub ttft_p99_us: u64,
    pub tpot_p50_us: u64,
    pub tpot_p99_us: u64,
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
    pub wall_ms: u64,
}

// ---- serialization (fixed key order — the round-trip contract) -----------

impl BenchReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::num(self.schema_version as f64)),
            ("pr", Json::s(&self.pr)),
            ("git_rev", Json::s(&self.git_rev)),
            ("created_unix", Json::num(self.created_unix as f64)),
            ("suite", Json::s(&self.suite)),
            ("target", Json::s(&self.target)),
            ("dataset", Json::s(&self.dataset)),
            ("seed", Json::num(self.seed as f64)),
            ("note", Json::s(&self.note)),
            ("cells", Json::Arr(self.cells.iter().map(CellRecord::to_json).collect())),
        ])
    }

    /// Canonical file content: pretty JSON + one trailing newline.
    pub fn to_file_string(&self) -> String {
        let mut s = self.to_json().to_string_pretty();
        s.push('\n');
        s
    }

    /// Parse AND validate: schema version, required keys/types, cell-id
    /// consistency. Everything the `--validate` CLI mode checks lives here.
    pub fn parse(s: &str) -> Result<BenchReport, String> {
        Self::from_json(&Json::parse(s)?)
    }

    pub fn from_json(j: &Json) -> Result<BenchReport, String> {
        let ver = int(j, "schema_version")?;
        if ver != SCHEMA_VERSION {
            return Err(format!(
                "schema_version {ver} unsupported (this build reads {SCHEMA_VERSION})"
            ));
        }
        let cells = j
            .get("cells")
            .and_then(Json::as_arr)
            .ok_or("cells: expected an array")?
            .iter()
            .enumerate()
            .map(|(i, c)| CellRecord::from_json(c).map_err(|e| format!("cells[{i}]: {e}")))
            .collect::<Result<Vec<_>, _>>()?;
        let mut seen = std::collections::BTreeSet::new();
        for c in &cells {
            if !seen.insert(&c.id) {
                return Err(format!("duplicate cell id {:?}", c.id));
            }
        }
        Ok(BenchReport {
            schema_version: ver,
            pr: string(j, "pr")?,
            git_rev: string(j, "git_rev")?,
            created_unix: int(j, "created_unix")? as u64,
            suite: string(j, "suite")?,
            target: string(j, "target")?,
            dataset: string(j, "dataset")?,
            seed: int(j, "seed")? as u64,
            note: string(j, "note")?,
            cells,
        })
    }
}

impl CellRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::s(&self.id)),
            ("config", self.config.to_json()),
            ("metrics", self.metrics.to_json()),
            ("timing", self.timing.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CellRecord, String> {
        let config = CellConfig::from_json(j.get("config").ok_or("missing config")?)
            .map_err(|e| format!("config: {e}"))?;
        let id = string(j, "id")?;
        if id != config.id() {
            return Err(format!("id {:?} != derived {:?}", id, config.id()));
        }
        Ok(CellRecord {
            id,
            config,
            metrics: CellMetrics::from_json(j.get("metrics").ok_or("missing metrics")?)
                .map_err(|e| format!("metrics: {e}"))?,
            timing: CellTiming::from_json(j.get("timing").ok_or("missing timing")?)
                .map_err(|e| format!("timing: {e}"))?,
        })
    }
}

impl CellConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shape", Json::s(&self.shape)),
            ("cache", Json::s(&self.cache)),
            ("drafter", Json::s(&self.drafter)),
            ("policy", Json::s(&self.policy)),
            ("load", Json::s(&self.load)),
            ("concurrency", Json::num(self.concurrency as f64)),
            ("rate_rps", Json::num(self.rate_rps)),
            ("requests", Json::num(self.requests as f64)),
            ("max_new", Json::num(self.max_new as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("deterministic", Json::Bool(self.deterministic)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CellConfig, String> {
        let shape = string(j, "shape")?;
        let cache = string(j, "cache")?;
        let load = string(j, "load")?;
        if !matches!(shape.as_str(), "chain" | "tree" | "dyn" | "adaptive") {
            return Err(format!("shape {shape:?} not one of chain|tree|dyn|adaptive"));
        }
        if !matches!(cache.as_str(), "dense" | "paged" | "prefix") {
            return Err(format!("cache {cache:?} not one of dense|paged|prefix"));
        }
        if !matches!(load.as_str(), "closed" | "open" | "adaptive") {
            return Err(format!("load {load:?} not one of closed|open|adaptive"));
        }
        // the adaptive column is one coherent thing, not a free mix: an
        // adaptive load means controller-assigned policies (shape/drafter/
        // policy have no static value) and vice versa
        if (shape == "adaptive") != (load == "adaptive") {
            return Err(format!(
                "shape {shape:?} / load {load:?}: adaptive cells set both"
            ));
        }
        Ok(CellConfig {
            shape,
            cache,
            drafter: string(j, "drafter")?,
            policy: string(j, "policy")?,
            load,
            concurrency: int(j, "concurrency")?,
            rate_rps: float(j, "rate_rps")?,
            requests: int(j, "requests")?,
            max_new: int(j, "max_new")?,
            seed: int(j, "seed")? as u64,
            deterministic: boolean(j, "deterministic")?,
        })
    }
}

impl CellMetrics {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests_finished", Json::num(self.requests_finished as f64)),
            ("tokens_emitted", Json::num(self.tokens_emitted as f64)),
            ("iterations", Json::num(self.iterations as f64)),
            ("acceptance_length", Json::num(self.acceptance_length)),
            ("mean_occupancy", Json::num(self.mean_occupancy)),
            ("mean_block_occupancy", Json::num(self.mean_block_occupancy)),
            ("blocks_peak", Json::num(self.blocks_peak as f64)),
            ("admissions_blocked", Json::num(self.admissions_blocked as f64)),
            ("mean_active_nodes", Json::num(self.mean_active_nodes)),
            ("downloads_per_step", Json::num(self.downloads_per_step)),
            ("uploads_per_step", Json::num(self.uploads_per_step)),
            ("download_bytes", Json::num(self.download_bytes as f64)),
            ("upload_bytes", Json::num(self.upload_bytes as f64)),
            ("kv_downloads", Json::num(self.kv_downloads as f64)),
            ("kv_uploads", Json::num(self.kv_uploads as f64)),
            ("device_path_commits", Json::num(self.device_path_commits as f64)),
            (
                "per_policy",
                Json::Arr(
                    self.per_policy
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("policy", Json::s(&p.policy)),
                                ("iterations", Json::num(p.iterations as f64)),
                                ("acceptance_length", Json::num(p.acceptance_length)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CellMetrics, String> {
        let per_policy = j
            .get("per_policy")
            .and_then(Json::as_arr)
            .ok_or("per_policy: expected an array")?
            .iter()
            .map(|p| {
                Ok(PolicyCell {
                    policy: string(p, "policy")?,
                    iterations: int(p, "iterations")?,
                    acceptance_length: float(p, "acceptance_length")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(CellMetrics {
            requests_finished: int(j, "requests_finished")?,
            tokens_emitted: int(j, "tokens_emitted")?,
            iterations: int(j, "iterations")?,
            acceptance_length: float(j, "acceptance_length")?,
            mean_occupancy: float(j, "mean_occupancy")?,
            mean_block_occupancy: float(j, "mean_block_occupancy")?,
            blocks_peak: int(j, "blocks_peak")?,
            admissions_blocked: int(j, "admissions_blocked")?,
            mean_active_nodes: float(j, "mean_active_nodes")?,
            downloads_per_step: float(j, "downloads_per_step")?,
            uploads_per_step: float(j, "uploads_per_step")?,
            download_bytes: int(j, "download_bytes")?,
            upload_bytes: int(j, "upload_bytes")?,
            kv_downloads: int(j, "kv_downloads")?,
            kv_uploads: int(j, "kv_uploads")?,
            device_path_commits: int(j, "device_path_commits")?,
            per_policy,
        })
    }
}

impl CellTiming {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("otps", Json::num(self.otps)),
            ("ttft_p50_us", Json::num(self.ttft_p50_us as f64)),
            ("ttft_p99_us", Json::num(self.ttft_p99_us as f64)),
            ("tpot_p50_us", Json::num(self.tpot_p50_us as f64)),
            ("tpot_p99_us", Json::num(self.tpot_p99_us as f64)),
            ("latency_p50_us", Json::num(self.latency_p50_us as f64)),
            ("latency_p99_us", Json::num(self.latency_p99_us as f64)),
            ("wall_ms", Json::num(self.wall_ms as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CellTiming, String> {
        Ok(CellTiming {
            otps: float(j, "otps")?,
            ttft_p50_us: int(j, "ttft_p50_us")? as u64,
            ttft_p99_us: int(j, "ttft_p99_us")? as u64,
            tpot_p50_us: int(j, "tpot_p50_us")? as u64,
            tpot_p99_us: int(j, "tpot_p99_us")? as u64,
            latency_p50_us: int(j, "latency_p50_us")? as u64,
            latency_p99_us: int(j, "latency_p99_us")? as u64,
            wall_ms: int(j, "wall_ms")? as u64,
        })
    }
}

// ---- typed accessors with error messages (no panicking req/str_of here —
// a malformed trajectory file must surface as a CLI error, not a panic) ----

fn string(j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("{key}: expected a string"))
}

fn float(j: &Json, key: &str) -> Result<f64, String> {
    let x = j
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{key}: expected a number"))?;
    if !x.is_finite() {
        return Err(format!("{key}: not finite"));
    }
    Ok(x)
}

fn int(j: &Json, key: &str) -> Result<usize, String> {
    let x = float(j, key)?;
    if x < 0.0 || x.fract() != 0.0 {
        return Err(format!("{key}: expected a non-negative integer, got {x}"));
    }
    Ok(x as usize)
}

fn boolean(j: &Json, key: &str) -> Result<bool, String> {
    j.get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| format!("{key}: expected a bool"))
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample_report() -> BenchReport {
        BenchReport {
            schema_version: SCHEMA_VERSION,
            pr: "6".into(),
            git_rev: "abc1234".into(),
            created_unix: 1754000000,
            suite: "smoke".into(),
            target: "target-m".into(),
            dataset: "mono".into(),
            seed: 11,
            note: "".into(),
            cells: vec![
                CellRecord {
                    id: "chain/dense/target-m-pe4/closed-c2".into(),
                    config: CellConfig {
                        shape: "chain".into(),
                        cache: "dense".into(),
                        drafter: "target-m-pe4".into(),
                        policy: "target-m-pe4/chain:4".into(),
                        load: "closed".into(),
                        concurrency: 2,
                        rate_rps: 0.0,
                        requests: 8,
                        max_new: 32,
                        seed: 11,
                        deterministic: true,
                    },
                    metrics: CellMetrics {
                        requests_finished: 8,
                        tokens_emitted: 256,
                        iterations: 64,
                        acceptance_length: 3.5,
                        mean_occupancy: 0.9,
                        mean_block_occupancy: 0.0,
                        blocks_peak: 0,
                        admissions_blocked: 0,
                        mean_active_nodes: 0.0,
                        downloads_per_step: 2.5,
                        uploads_per_step: 4.0,
                        download_bytes: 1048576,
                        upload_bytes: 2097152,
                        kv_downloads: 64,
                        kv_uploads: 64,
                        device_path_commits: 0,
                        per_policy: vec![PolicyCell {
                            policy: "target-m-pe4/chain:k4".into(),
                            iterations: 64,
                            acceptance_length: 3.5,
                        }],
                    },
                    timing: CellTiming {
                        otps: 1234.5,
                        ttft_p50_us: 800,
                        ttft_p99_us: 2000,
                        tpot_p50_us: 150,
                        tpot_p99_us: 400,
                        latency_p50_us: 9000,
                        latency_p99_us: 15000,
                        wall_ms: 210,
                    },
                },
                CellRecord {
                    id: "tree/paged/target-m-pe4/open-c2-r8".into(),
                    config: CellConfig {
                        shape: "tree".into(),
                        cache: "paged".into(),
                        drafter: "target-m-pe4".into(),
                        policy: "target-m-pe4/tree:w3x2x1x1x1".into(),
                        load: "open".into(),
                        concurrency: 2,
                        rate_rps: 8.0,
                        requests: 8,
                        max_new: 32,
                        seed: 11,
                        deterministic: false,
                    },
                    metrics: CellMetrics {
                        mean_block_occupancy: 0.4,
                        blocks_peak: 12,
                        mean_active_nodes: 8.0,
                        device_path_commits: 9,
                        per_policy: vec![],
                        ..CellMetrics::default()
                    },
                    timing: CellTiming::default(),
                },
                CellRecord {
                    id: "adaptive/dense/auto/adaptive-c2-r8".into(),
                    config: CellConfig {
                        shape: "adaptive".into(),
                        cache: "dense".into(),
                        drafter: "auto".into(),
                        policy: "adaptive".into(),
                        load: "adaptive".into(),
                        concurrency: 2,
                        rate_rps: 8.0,
                        requests: 8,
                        max_new: 32,
                        seed: 11,
                        deterministic: false,
                    },
                    metrics: CellMetrics {
                        // the controller served two shapes of one drafter —
                        // exactly what policy-identity rows exist to record
                        per_policy: vec![
                            PolicyCell {
                                policy: "target-m-pe4/chain:k4".into(),
                                iterations: 10,
                                acceptance_length: 3.1,
                            },
                            PolicyCell {
                                policy: "target-m-pe4/dyn:w4x4x2x2x1".into(),
                                iterations: 30,
                                acceptance_length: 4.2,
                            },
                        ],
                        ..CellMetrics::default()
                    },
                    timing: CellTiming::default(),
                },
            ],
        }
    }

    #[test]
    fn round_trip_is_byte_identical() {
        // THE schema contract: serialize → parse → re-serialize reproduces
        // the exact bytes (fixed key order + shortest-repr numerics)
        let r = sample_report();
        let s1 = r.to_file_string();
        let parsed = BenchReport::parse(&s1).unwrap();
        assert_eq!(parsed, r);
        assert_eq!(parsed.to_file_string(), s1);
    }

    #[test]
    fn cell_ids_derive_from_config() {
        let r = sample_report();
        assert_eq!(r.cells[0].config.id(), "chain/dense/target-m-pe4/closed-c2");
        assert_eq!(r.cells[1].config.id(), "tree/paged/target-m-pe4/open-c2-r8");
        assert_eq!(r.cells[2].config.id(), "adaptive/dense/auto/adaptive-c2-r8");
    }

    #[test]
    fn rejects_wrong_version() {
        let mut s = sample_report().to_file_string();
        s = s.replace("\"schema_version\": 3", "\"schema_version\": 99");
        let e = BenchReport::parse(&s).unwrap_err();
        assert!(e.contains("schema_version 99"), "{e}");
    }

    #[test]
    fn rejects_half_adaptive_cells() {
        // an adaptive load with a static shape (or the reverse) is a
        // malformed cell, not a new kind of coverage
        let s = sample_report()
            .to_file_string()
            .replace("\"shape\": \"adaptive\"", "\"shape\": \"dyn\"");
        let e = BenchReport::parse(&s).unwrap_err();
        assert!(e.contains("adaptive cells set both"), "{e}");
    }

    #[test]
    fn rejects_id_config_mismatch() {
        let s = sample_report()
            .to_file_string()
            .replace("chain/dense/target-m-pe4/closed-c2", "chain/dense/WRONG/closed-c2");
        // replaces the stored id (and only the id — the config spells the
        // drafter on its own line), so derivation catches the mismatch
        let e = BenchReport::parse(&s).unwrap_err();
        assert!(e.contains("derived"), "{e}");
    }

    #[test]
    fn rejects_duplicate_ids() {
        let mut r = sample_report();
        let dup = r.cells[0].clone();
        r.cells.push(dup);
        let e = BenchReport::parse(&r.to_file_string()).unwrap_err();
        assert!(e.contains("duplicate"), "{e}");
    }

    #[test]
    fn rejects_bad_enums_and_types() {
        let base = sample_report();
        let s = base.to_file_string().replace("\"cache\": \"dense\"", "\"cache\": \"flat\"");
        assert!(BenchReport::parse(&s).unwrap_err().contains("cache"));
        let s = base
            .to_file_string()
            .replace("\"iterations\": 64", "\"iterations\": -3");
        assert!(BenchReport::parse(&s).unwrap_err().contains("iterations"));
        let s = base.to_file_string().replace("\"pr\": \"6\"", "\"pr\": 6");
        assert!(BenchReport::parse(&s).unwrap_err().contains("pr"));
    }
}
