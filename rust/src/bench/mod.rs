//! Perf-trajectory subsystem: the workload-matrix bench harness behind the
//! `bench-suite` CLI subcommand and the `BENCH_<pr>.json` files at the repo
//! root.
//!
//! Three pieces, deliberately separable:
//!
//! - [`suite`] — the matrix DEFINITION: {chain, tree, dyn} × {dense, paged}
//!   × serveable drafters × {closed-loop, open-loop} arrival loads, as plain
//!   data (no manifest, no runtime)
//! - [`runner`] — executes the matrix against a loaded `ModelRuntime` via
//!   the same `report::bench_otps`/`bench_otps_open` paths the CLI benches
//!   use, producing a [`schema::BenchReport`]
//! - [`schema`] + [`compare`] — the versioned on-disk format and the
//!   cell-by-cell regression gate over two files; both are pure (CI runs
//!   them with no artifacts present)
//!
//! Every subsequent perf PR runs `bench-suite`, commits the new
//! `BENCH_<pr>.json`, and gates with
//! `bench-suite --compare BENCH_<prev>.json --new BENCH_<pr>.json`.

pub mod compare;
pub mod runner;
pub mod schema;
pub mod suite;

/// The PR tag new reports default to (`BENCH_<CURRENT_PR>.json`). Bumped by
/// each PR that re-records the trajectory.
pub const CURRENT_PR: &str = "6";

pub use compare::{compare, CellStatus, CompareReport, Thresholds};
pub use runner::{deterministic_view, run_suite};
pub use schema::{BenchReport, CellRecord, SCHEMA_VERSION};
pub use suite::{Load, SuiteSpec};
