//! The fixed workload matrix the perf trajectory tracks:
//! {chain, tree, dyn} × {dense, paged, prefix} × (serveable drafters) × loads.
//!
//! The matrix is DEFINED here as data (shapes, caches, loads, and the policy
//! each shape maps to); the runner resolves it against a manifest (which
//! drafters exist and which cells their lowered executables can actually
//! serve) and executes the surviving cells. Keeping the definition
//! manifest-free means the comparator and the tests can reason about
//! expected coverage without artifacts on disk.

use crate::masking::{DynamicTreeConfig, TreeTopology};
use crate::coordinator::SpecPolicy;

/// Speculation shapes, in matrix order.
pub const SHAPES: [&str; 3] = ["chain", "tree", "dyn"];

/// KV cache modes, in matrix order. `prefix` is the paged cache with the
/// automatic prefix cache on, measured on a shared-prefix workload (every
/// prompt opens with the same [`SHARED_PREFIX_TOKENS`]-token header) — the
/// TTFT-collapse column. It runs closed-loop only: the collapse it tracks is
/// prefill cost, and open-loop admission interleaving is wall-clock anyway.
pub const CACHES: [&str; 3] = ["dense", "paged", "prefix"];

/// Shared-prefix length (tokens) the `prefix` cache column stamps onto every
/// prompt — 2.5 KV blocks at the testbed's block size 16, so the hit path
/// exercises both whole-block mapping and the partial-tail COW claim.
pub const SHARED_PREFIX_TOKENS: usize = 40;

/// The static tree every `tree` cell drafts (the repo's standard comparison
/// topology — 8 nodes, depth 5, embeds the rank-0 chain).
pub const TREE_SPEC: &str = "w:3,2,1,1,1";

/// One arrival-load column of the matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Load {
    /// fixed concurrency, next request admitted on completion
    Closed { concurrency: usize },
    /// Poisson arrivals at `rate_rps`, slot cap `concurrency`
    Open { concurrency: usize, rate_rps: f64 },
    /// Poisson arrivals served by the adaptive speculation controller:
    /// requests arrive policy-free and the controller assigns drafter,
    /// shape, and budget from live engine signal. Its own load variant
    /// (not a shape) because it replaces the whole (shape × drafter)
    /// cross-product with one cell per cache mode.
    Adaptive { concurrency: usize, rate_rps: f64 },
}

impl Load {
    pub fn concurrency(&self) -> usize {
        match *self {
            Load::Closed { concurrency }
            | Load::Open { concurrency, .. }
            | Load::Adaptive { concurrency, .. } => concurrency,
        }
    }

    pub fn rate_rps(&self) -> f64 {
        match *self {
            Load::Closed { .. } => 0.0,
            Load::Open { rate_rps, .. } | Load::Adaptive { rate_rps, .. } => rate_rps,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Load::Closed { .. } => "closed",
            Load::Open { .. } => "open",
            Load::Adaptive { .. } => "adaptive",
        }
    }

    /// Closed-loop cells replay exactly given the seed; open-loop and
    /// adaptive admission depends on wall-clock service times (and the
    /// controller's decisions depend on wall-clock-shaped signal windows).
    pub fn deterministic(&self) -> bool {
        matches!(self, Load::Closed { .. })
    }
}

/// What a suite run measures: the workload knobs shared by every cell.
/// `smoke` shrinks the load columns and the per-cell budgets to CI scale.
#[derive(Clone, Debug)]
pub struct SuiteSpec {
    pub smoke: bool,
    pub target: String,
    pub dataset: String,
    /// requests per cell
    pub requests: usize,
    pub max_new: usize,
    pub seed: u64,
    /// paged cells: block budget (None = fully provisioned)
    pub kv_blocks: Option<usize>,
}

impl SuiteSpec {
    pub fn new(smoke: bool) -> SuiteSpec {
        SuiteSpec {
            smoke,
            target: "target-m".into(),
            dataset: "mtbench".into(),
            requests: if smoke { 6 } else { 16 },
            max_new: if smoke { 24 } else { 48 },
            seed: 11,
            kv_blocks: None,
        }
    }

    pub fn suite_name(&self) -> &'static str {
        if self.smoke {
            "smoke"
        } else {
            "full"
        }
    }

    /// The arrival-load columns this suite runs per (shape, cache, drafter).
    pub fn loads(&self) -> Vec<Load> {
        if self.smoke {
            vec![Load::Closed { concurrency: 2 }, Load::Open { concurrency: 2, rate_rps: 8.0 }]
        } else {
            vec![
                Load::Closed { concurrency: 2 },
                Load::Closed { concurrency: 4 },
                Load::Open { concurrency: 4, rate_rps: 8.0 },
            ]
        }
    }

    /// The adaptive-controller columns — run ONCE per cache mode (dense,
    /// paged), not per (shape, drafter): the controller owns both choices.
    pub fn adaptive_loads(&self) -> Vec<Load> {
        if self.smoke {
            vec![Load::Adaptive { concurrency: 2, rate_rps: 8.0 }]
        } else {
            vec![Load::Adaptive { concurrency: 4, rate_rps: 8.0 }]
        }
    }
}

/// The [`SpecPolicy`] a matrix shape maps a drafter onto: chain at the
/// manifest's default K, the standard static tree, or the default dynamic
/// envelope/budget. The single source of "what does a `tree` cell run".
pub fn policy_for(shape: &str, drafter: &str, default_k: usize) -> Result<SpecPolicy, String> {
    match shape {
        "chain" => Ok(SpecPolicy::chain(drafter, default_k)),
        "tree" => Ok(SpecPolicy::tree(drafter, TreeTopology::parse(TREE_SPEC)?)),
        "dyn" => {
            Ok(SpecPolicy::from_dynamic_config(drafter, &DynamicTreeConfig::serving_default()))
        }
        other => Err(format!("unknown shape {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_and_full_load_columns() {
        let smoke = SuiteSpec::new(true);
        assert_eq!(smoke.suite_name(), "smoke");
        assert_eq!(smoke.loads().len(), 2);
        assert!(smoke.loads().iter().any(|l| !l.deterministic()));
        let full = SuiteSpec::new(false);
        assert_eq!(full.suite_name(), "full");
        assert_eq!(full.loads().len(), 3);
        // every suite covers both arrival modes
        for s in [&smoke, &full] {
            assert!(s.loads().iter().any(|l| l.name() == "closed"));
            assert!(s.loads().iter().any(|l| l.name() == "open"));
        }
    }

    #[test]
    fn load_accessors() {
        let c = Load::Closed { concurrency: 4 };
        assert_eq!((c.concurrency(), c.rate_rps(), c.name()), (4, 0.0, "closed"));
        assert!(c.deterministic());
        let o = Load::Open { concurrency: 2, rate_rps: 8.0 };
        assert_eq!((o.concurrency(), o.rate_rps(), o.name()), (2, 8.0, "open"));
        assert!(!o.deterministic());
        let a = Load::Adaptive { concurrency: 2, rate_rps: 8.0 };
        assert_eq!((a.concurrency(), a.rate_rps(), a.name()), (2, 8.0, "adaptive"));
        assert!(!a.deterministic());
    }

    #[test]
    fn adaptive_columns_per_suite() {
        // one adaptive column per suite flavor, always non-deterministic
        for smoke in [true, false] {
            let loads = SuiteSpec::new(smoke).adaptive_loads();
            assert_eq!(loads.len(), 1);
            assert!(loads.iter().all(|l| l.name() == "adaptive" && !l.deterministic()));
        }
    }

    #[test]
    fn shape_policies() {
        assert_eq!(policy_for("chain", "d", 4).unwrap().id(), "d/chain:4");
        assert_eq!(policy_for("tree", "d", 4).unwrap().id(), "d/tree:w3x2x1x1x1");
        let dynp = policy_for("dyn", "d", 4).unwrap();
        assert_eq!(dynp.mode_name(), "dyn");
        assert!(policy_for("ring", "d", 4).is_err());
    }
}
