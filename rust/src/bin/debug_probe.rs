//! debug-probe — runtime diagnostics for the AOT artifacts.
//!
//!     cargo run --release --bin compile_probe -- [artifacts] [--exec NAME]
//!
//! Checks every manifest executable parses + compiles, cross-checks entry
//! parameter counts against the manifest's param_order (the keep_unused and
//! elided-constant failure modes documented in DESIGN.md §Interchange
//! gotchas), and spot-runs the engine on one request per serving drafter.

use p_eagle::config::Manifest;
use p_eagle::coordinator::{run_closed_loop, EngineConfig, Request, SpecPolicy};
use p_eagle::runtime::{ModelRuntime, Runtime};
use p_eagle::util::cli::Args;
use p_eagle::workload::corpus::load_eval_prompts;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let root = args.positional.first().cloned().unwrap_or_else(|| "artifacts".into());
    let manifest = Manifest::load(&root)?;
    let mut rt = Runtime::cpu()?;

    let only = args.get("exec");
    let mut bad = 0;
    for e in &manifest.executables {
        if let Some(name) = only {
            if e.name != name {
                continue;
            }
        }
        let path = manifest.abs(&e.path);
        let text = std::fs::read_to_string(&path)?;
        // gotcha #1: elided constants parse as zeros
        if text.contains("{...}") {
            println!("FAIL {}: elided constant in HLO text", e.name);
            bad += 1;
            continue;
        }
        // gotcha #2: pruned parameters shift the weight argument order
        let header = text.lines().next().unwrap_or_default();
        let args_part = header.split("->").next().unwrap_or_default();
        let n_args = args_part.matches("f32[").count()
            + args_part.matches("s32[").count()
            + args_part.matches("pred[").count();
        let expected_weights = match e.kind.as_str() {
            "prefill" | "verify" => Some(
                manifest.target(e.model.as_deref().unwrap())?.param_order.len() + 3,
            ),
            "draft" => Some(
                manifest.drafter(e.drafter.as_deref().unwrap())?.param_order.len() + 3,
            ),
            _ => None,
        };
        if let Some(want) = expected_weights {
            if n_args != want {
                println!("FAIL {}: {} entry args, manifest implies {}", e.name, n_args, want);
                bad += 1;
                continue;
            }
        }
        if let Err(err) = rt.load(&e.name, &path) {
            println!("FAIL {}: compile: {err:#}", e.name);
            bad += 1;
        }
    }
    println!(
        "checked {} executables: {} ok, {bad} failed (compile time {:?})",
        rt.loaded_count() + bad,
        rt.loaded_count(),
        rt.compile_time
    );
    anyhow::ensure!(bad == 0, "{bad} executables failed validation");

    // engine spot-run per serving drafter
    drop(rt);
    let mut mr = ModelRuntime::load(&root)?;
    let pool = load_eval_prompts(&mr.manifest.abs("eval/humaneval.json"))?;
    for target in ["target-l", "target-m", "target-s"] {
        for method in ["ar", "pe4"] {
            let drafter = format!("{target}-{method}");
            let cfg =
                EngineConfig::new(target, SpecPolicy::chain(&drafter, 5), 1, 16).with_seed(5);
            let mut g = Some(Request::new(0, pool[0].clone(), 16));
            let (res, _) = run_closed_loop(&mut mr, &cfg, 1, 1, || g.take().unwrap())?;
            println!("spot {drafter}: AL {:.2}, {} tokens", res[0].acceptance_length(), res[0].tokens.len());
        }
    }
    Ok(())
}
