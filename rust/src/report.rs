//! Shared evaluation + report routines used by the CLI and the bench
//! binaries: acceptance-length evaluation (Tables 1/3-9/11), OTPS sweeps
//! (Table 10, chain or tree speculation), the chain-vs-tree comparison, and
//! the Figure 1 / Figure 5 reports.

use anyhow::{anyhow, Result};

use crate::config::Manifest;
use crate::coordinator::{
    run_closed_loop, run_open_loop, ControllerConfig, EngineConfig, EngineCore, EngineMetrics,
    PagedKvConfig, RequestResult, SamplingParams, SpecPolicy,
};
use crate::masking::{DynamicTreeConfig, TreeTopology};
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;
use crate::workload::{corpus::load_eval_prompts, ArrivalProcess, LengthModel};

/// Build the [`SpecPolicy`] one legacy (engine-wide) knob set describes:
/// `tree_dynamic` wins over `tree` wins over the chain depth `k` — the same
/// precedence the old `EngineConfig` fields had. The single place the
/// report/bench surface maps its historical arguments onto the per-request
/// policy API.
pub fn legacy_policy(
    drafter: &str,
    k: usize,
    tree: Option<&TreeTopology>,
    tree_dynamic: Option<&DynamicTreeConfig>,
) -> SpecPolicy {
    match (tree_dynamic, tree) {
        (Some(d), _) => SpecPolicy::from_dynamic_config(drafter, d),
        (None, Some(t)) => SpecPolicy::tree(drafter, t.clone()),
        (None, None) => SpecPolicy::chain(drafter, k),
    }
}

/// Closed-loop arrival stream for one manifest dataset, with prompts sized
/// to satisfy engine admission (>= ctx_window; 16 keeps the paper's fixed
/// prompt budget for benchmark comparability). The single source of that
/// sizing rule for the CLI and the benches.
pub fn closed_loop_arrivals(
    manifest: &Manifest,
    dataset: &str,
    max_new: usize,
    seed: u64,
) -> Result<ArrivalProcess> {
    let regime = manifest
        .regimes
        .get(dataset)
        .ok_or_else(|| anyhow!("unknown dataset {dataset}"))?
        .clone();
    let prompt_len = 16.max(manifest.ctx_window + 1);
    Ok(ArrivalProcess::closed_loop(regime, prompt_len, max_new, seed))
}

/// Acceptance-length evaluation of one drafter on one regime's OOD prompt
/// set (the paper's AL metric: accepted drafts + bonus per iteration).
pub struct AlEval {
    pub drafter: String,
    pub dataset: String,
    pub k: usize,
    pub requests: usize,
    pub acceptance_length: f64,
    pub results: Vec<RequestResult>,
}

pub fn eval_acceptance(
    mr: &mut ModelRuntime,
    drafter: &str,
    dataset: &str,
    k: usize,
    n_requests: usize,
    max_new: usize,
) -> Result<AlEval> {
    let info = mr.manifest.drafter(drafter)?.clone();
    let prompts_rel = mr
        .manifest
        .eval_prompts
        .get(dataset)
        .ok_or_else(|| anyhow!("unknown dataset {dataset}"))?
        .clone();
    let pool = load_eval_prompts(&mr.manifest.abs(&prompts_rel))?;
    let reqs = ArrivalProcess::from_pool(&pool, n_requests, max_new);

    let cfg = EngineConfig::new(&info.target, SpecPolicy::chain(drafter, k), 1, max_new)
        .with_seed(42);
    let mut queue = reqs.into_iter();
    let (results, _m) = run_closed_loop(mr, &cfg, 1, n_requests, || queue.next().unwrap())?;
    let (mut acc, mut iters) = (0usize, 0usize);
    for r in &results {
        acc += r.accepted_sum;
        iters += r.iterations;
    }
    Ok(AlEval {
        drafter: drafter.to_string(),
        dataset: dataset.to_string(),
        k,
        requests: n_requests,
        acceptance_length: if iters == 0 { 0.0 } else { acc as f64 / iters as f64 },
        results,
    })
}

/// One OTPS measurement (a Table 10 cell): closed loop at concurrency C.
pub struct OtpsRun {
    pub drafter: String,
    pub dataset: String,
    pub k: usize,
    pub concurrency: usize,
    /// tree topology id when this run used tree speculation
    pub topology: Option<String>,
    /// open-loop Poisson arrival rate (req/s); `None` for closed loop
    pub rate_rps: Option<f64>,
    pub otps: f64,
    pub acceptance_length: f64,
    /// mean fraction of engine rows doing useful work per step
    pub mean_occupancy: f64,
    pub metrics: EngineMetrics,
}

/// Closed-loop OTPS at concurrency C. With `mixed_lengths`, each request
/// draws its own generation budget from the paper's Figure-1 length
/// distribution (testbed-scaled, capped at `max_new`) — the workload where
/// iteration-level batching matters: short requests evict early and freed
/// slots re-admit mid-flight instead of idling behind the longest request.
/// With `tree` set, the engine drafts/verifies that static topology instead
/// of a K-chain (`k` is then ignored); with `tree_dynamic` set (mutually
/// exclusive with `tree`), it activates a per-step confidence-selected node
/// subset inside the given envelope; the same workload seed makes
/// chain-vs-tree(-vs-dynamic) runs directly comparable. With `paged` set,
/// the engine serves from the block-paged KV cache (same workload seed ⇒
/// directly comparable to the dense run, and byte-identical when fully
/// provisioned). `sampling` is applied to every request (seed re-stamped
/// per request from the workload seed, so each request keeps a private rng
/// stream); greedy keeps the historical bit-reproducible benchmark setting.
#[allow(clippy::too_many_arguments)]
pub fn bench_otps(
    mr: &mut ModelRuntime,
    drafter: &str,
    dataset: &str,
    k: usize,
    concurrency: usize,
    total_requests: usize,
    max_new: usize,
    seed: u64,
    mixed_lengths: bool,
    tree: Option<&TreeTopology>,
    tree_dynamic: Option<&DynamicTreeConfig>,
    paged: Option<PagedKvConfig>,
    sampling: SamplingParams,
) -> Result<OtpsRun> {
    bench_otps_inner(
        mr, drafter, dataset, k, concurrency, total_requests, max_new, seed,
        mixed_lengths, tree, tree_dynamic, paged, sampling, None, None,
    )
}

/// Closed-loop OTPS on a SHARED-PREFIX workload: every request's prompt
/// starts with the same seed-derived `shared_prefix_tokens`-token prefix
/// (think system prompt / few-shot header), followed by that request's own
/// unique tail. This is the workload where automatic prefix caching pays:
/// with `paged.prefix_cache` on, every admission after the first maps the
/// prefix blocks shared and prefills only the tail, so TTFT collapses
/// toward the tail cost; with it off, the same seed measures the baseline —
/// the pair is directly comparable and must emit byte-identical tokens.
#[allow(clippy::too_many_arguments)]
pub fn bench_otps_prefix(
    mr: &mut ModelRuntime,
    drafter: &str,
    dataset: &str,
    k: usize,
    concurrency: usize,
    total_requests: usize,
    max_new: usize,
    seed: u64,
    tree: Option<&TreeTopology>,
    tree_dynamic: Option<&DynamicTreeConfig>,
    paged: Option<PagedKvConfig>,
    sampling: SamplingParams,
    shared_prefix_tokens: usize,
) -> Result<OtpsRun> {
    bench_otps_inner(
        mr, drafter, dataset, k, concurrency, total_requests, max_new, seed,
        false, tree, tree_dynamic, paged, sampling, None, Some(shared_prefix_tokens),
    )
}

/// Open-loop OTPS/latency at Poisson arrival rate `rate_rps` req/s with a
/// slot cap of `concurrency`: the latency-under-load experiment. Unlike the
/// closed loop, TTFT here includes real queueing delay (a request whose
/// arrival outpaces slot turnover waits), so p99 TTFT under a given rate is
/// the headline number. The arrival SCHEDULE is a pure function of the seed,
/// but admission interleaving depends on wall-clock service times — open-loop
/// runs are deliberately not bit-deterministic.
#[allow(clippy::too_many_arguments)]
pub fn bench_otps_open(
    mr: &mut ModelRuntime,
    drafter: &str,
    dataset: &str,
    k: usize,
    concurrency: usize,
    total_requests: usize,
    max_new: usize,
    seed: u64,
    mixed_lengths: bool,
    tree: Option<&TreeTopology>,
    tree_dynamic: Option<&DynamicTreeConfig>,
    paged: Option<PagedKvConfig>,
    sampling: SamplingParams,
    rate_rps: f64,
) -> Result<OtpsRun> {
    bench_otps_inner(
        mr, drafter, dataset, k, concurrency, total_requests, max_new, seed,
        mixed_lengths, tree, tree_dynamic, paged, sampling, Some(rate_rps), None,
    )
}

#[allow(clippy::too_many_arguments)]
fn bench_otps_inner(
    mr: &mut ModelRuntime,
    drafter: &str,
    dataset: &str,
    k: usize,
    concurrency: usize,
    total_requests: usize,
    max_new: usize,
    seed: u64,
    mixed_lengths: bool,
    tree: Option<&TreeTopology>,
    tree_dynamic: Option<&DynamicTreeConfig>,
    paged: Option<PagedKvConfig>,
    sampling: SamplingParams,
    rate_rps: Option<f64>,
    shared_prefix: Option<usize>,
) -> Result<OtpsRun> {
    let info = mr.manifest.drafter(drafter)?.clone();
    let mut arr = closed_loop_arrivals(&mr.manifest, dataset, max_new, seed)?;
    let lens = LengthModel::testbed(max_new.max(8));
    let mut lrng = Rng::new(seed ^ 0x1E46);
    let policy = legacy_policy(drafter, k, tree, tree_dynamic);
    let cfg = EngineConfig::new(&info.target, policy, concurrency, max_new)
        .with_seed(seed)
        .with_paged(paged);
    // warmup: compile/load the executables + weights outside the timed loop
    // (one throwaway 2-token request, like the paper's benchmark warmup)
    {
        let mut cfg_w = cfg.clone();
        cfg_w.max_new_tokens = 2;
        let mut warm = EngineCore::new(mr, cfg_w)?;
        warm.add_request(arr.next())?;
        warm.run_until_idle(mr)?;
    }
    // shared-prefix workload: one fixed seed-derived token prefix stamped
    // onto every prompt (the unique dataset tail keeps requests distinct,
    // and at least 4 tail tokens survive so every prompt still diverges)
    let shared_toks: Vec<i32> = {
        let mut r = Rng::new(seed ^ 0x5A12);
        (0..shared_prefix.unwrap_or(0)).map(|_| (r.below(246) + 4) as i32).collect()
    };
    let mut next = move || {
        let mut spec = arr.next();
        if let Some(n) = shared_prefix {
            let n = n.min(spec.prompt.len().saturating_sub(4));
            spec.prompt[..n].copy_from_slice(&shared_toks[..n]);
        }
        if mixed_lengths {
            spec.max_new_tokens = lens.sample(&mut lrng).clamp(4, max_new);
        }
        // per-request private rng stream: same mode/filters for the whole
        // run, the seed derived from (workload seed, request id)
        spec.sampling = SamplingParams { seed: seed ^ spec.id, ..sampling };
        spec
    };
    let (_results, metrics) = match rate_rps {
        None => run_closed_loop(mr, &cfg, concurrency, total_requests, &mut next)?,
        Some(rate) => {
            // re-stamp the closed-loop requests onto a Poisson schedule: the
            // prompts/budgets stay seed-identical to the closed-loop cell,
            // only the arrival clock differs
            let mut sched_rng = Rng::new(seed ^ 0x09E7);
            let mut clock = 0.0f64;
            let reqs: Vec<_> = (0..total_requests)
                .map(|_| {
                    clock += sched_rng.exponential(rate);
                    next().with_arrival(clock)
                })
                .collect();
            run_open_loop(mr, &cfg, concurrency, reqs)?
        }
    };
    Ok(OtpsRun {
        drafter: drafter.to_string(),
        dataset: dataset.to_string(),
        k,
        concurrency,
        topology: tree.map(|t| t.id()).or_else(|| tree_dynamic.map(|d| d.id())),
        rate_rps,
        otps: metrics.otps(),
        acceptance_length: metrics.acceptance_length(),
        mean_occupancy: metrics.mean_occupancy(),
        metrics,
    })
}

/// Chain / static-tree / (optionally) dynamic-tree comparison on the SAME
/// workload seed (and the same mixed-length setting): one K-chain run
/// (K = the static tree's max depth, so per-step depth budgets match), one
/// static tree run, and — when `dynamic` is set — one dynamic run. The
/// acceptance-length deltas are the whole point: a static tree that embeds
/// the rank-0 chain can only match or beat the chain's AL per iteration,
/// and a dynamic budget equal to the static tree's node count spends the
/// SAME verified-node budget where the drafter is confident instead of
/// where the width profile was frozen at lowering time.
#[allow(clippy::too_many_arguments)]
pub fn compare_chain_tree(
    mr: &mut ModelRuntime,
    drafter: &str,
    dataset: &str,
    tree: &TreeTopology,
    dynamic: Option<&DynamicTreeConfig>,
    concurrency: usize,
    total_requests: usize,
    max_new: usize,
    seed: u64,
    mixed_lengths: bool,
    paged: Option<PagedKvConfig>,
    sampling: SamplingParams,
) -> Result<(OtpsRun, OtpsRun, Option<OtpsRun>)> {
    let k = tree.max_depth();
    let chain = bench_otps(
        mr, drafter, dataset, k, concurrency, total_requests, max_new, seed,
        mixed_lengths, None, None, paged, sampling,
    )?;
    let treed = bench_otps(
        mr, drafter, dataset, k, concurrency, total_requests, max_new, seed,
        mixed_lengths, Some(tree), None, paged, sampling,
    )?;
    let dyned = match dynamic {
        Some(d) => Some(bench_otps(
            mr, drafter, dataset, k, concurrency, total_requests, max_new, seed,
            mixed_lengths, None, Some(d), paged, sampling,
        )?),
        None => None,
    };
    Ok((chain, treed, dyned))
}

/// Names of every drafter serveable for `target` at `(batch, k)` chain
/// speculation: the manifest carries a draft executable at that shape.
/// (Snapshot / ablation drafters lowered only at batch 1 drop out at wider
/// engine widths — the probe is the single source of "serveable".)
pub fn serveable_drafters(mr: &ModelRuntime, target: &str, batch: usize, k: usize) -> Vec<String> {
    mr.manifest
        .drafters
        .values()
        .filter(|d| d.target == target)
        .filter(|d| {
            mr.manifest
                .find_exec("draft", None, Some(&d.name), Some(batch), Some(k))
                .is_ok()
        })
        .map(|d| d.name.clone())
        .collect()
}

/// `bench-otps --sweep-drafters`: one closed-loop OTPS run PER serveable
/// drafter of `target`, in-process — one `ModelRuntime` serves every run,
/// so target weights upload once and each drafter's executables join the
/// shared registry (the multi-drafter manifest in action). Identical
/// workload seed per run => the rows are directly comparable.
#[allow(clippy::too_many_arguments)]
pub fn sweep_drafters(
    mr: &mut ModelRuntime,
    target: &str,
    dataset: &str,
    k: usize,
    concurrency: usize,
    total_requests: usize,
    max_new: usize,
    seed: u64,
    mixed_lengths: bool,
    paged: Option<PagedKvConfig>,
    sampling: SamplingParams,
) -> Result<Vec<OtpsRun>> {
    let names = serveable_drafters(mr, target, concurrency, k);
    if names.is_empty() {
        return Err(anyhow!(
            "no serveable drafters for target {target} at batch {concurrency}, k {k}"
        ));
    }
    let mut out = Vec::with_capacity(names.len());
    for name in names {
        out.push(bench_otps(
            mr, &name, dataset, k, concurrency, total_requests, max_new, seed,
            mixed_lengths, None, None, paged, sampling,
        )?);
    }
    Ok(out)
}

/// The adaptive controller's policy surface for `target` at engine width
/// `batch`: every serveable drafter's chain policy at `k`, plus the serving
/// static tree / dynamic envelope for drafters whose manifest `modes` carry
/// the capability — filtered through the SAME executable probe
/// `EngineCore::new` runs, so the controller only ever chooses among
/// policies the registry can serve at this width. Ordered dyn → tree →
/// chain so the strongest available policy leads (the controller's
/// cold-start default).
pub fn adaptive_allowlist(
    mr: &ModelRuntime,
    target: &str,
    batch: usize,
    k: usize,
    paged: bool,
) -> Vec<SpecPolicy> {
    let serving_tree = TreeTopology::from_widths(&[3, 2, 1, 1, 1]);
    let dyn_cfg = DynamicTreeConfig::serving_default();
    let mut out = Vec::new();
    for mode in ["dyn", "tree", "chain"] {
        for d in mr.manifest.drafters.values().filter(|d| d.target == target) {
            let p = match mode {
                "dyn" => SpecPolicy::from_dynamic_config(&d.name, &dyn_cfg),
                "tree" => SpecPolicy::tree(&d.name, serving_tree.clone()),
                _ => SpecPolicy::chain(&d.name, k),
            };
            if d.supports(mode) && mr.probe_policy_execs(target, &p, batch, paged).is_ok() {
                out.push(p);
            }
        }
    }
    out
}

/// OTPS under the adaptive speculation controller: requests carry NO policy
/// — the [`SpecController`](crate::coordinator::SpecController) assigns each
/// admission a (drafter × shape × budget) from live windowed signal and
/// re-tunes in-flight dynamic budgets per step. The workload (prompts,
/// budgets, sampling seeds, arrival schedule) is seed-identical to the
/// static `bench_otps`/`sweep_drafters` cells, so the adaptive row is
/// directly comparable to every static row — the ROADMAP acceptance
/// criterion is exactly "adaptive ≥ every static row on a mixed workload".
/// `rate_rps` selects the open-loop Poisson client (the mixed-load regime
/// the controller is for); `None` is the closed loop.
#[allow(clippy::too_many_arguments)]
pub fn bench_otps_adaptive(
    mr: &mut ModelRuntime,
    target: &str,
    dataset: &str,
    k: usize,
    concurrency: usize,
    total_requests: usize,
    max_new: usize,
    seed: u64,
    mixed_lengths: bool,
    paged: Option<PagedKvConfig>,
    sampling: SamplingParams,
    rate_rps: Option<f64>,
    adaptive: ControllerConfig,
) -> Result<OtpsRun> {
    let mut allow = adaptive_allowlist(mr, target, concurrency, k, paged.is_some());
    if allow.is_empty() {
        return Err(anyhow!(
            "no serveable policies for target {target} at batch {concurrency}, k {k} — \
             cannot run the adaptive controller"
        ));
    }
    let default = allow.remove(0);
    let cfg = EngineConfig::new(target, default, concurrency, max_new)
        .with_policies(allow)
        .with_seed(seed)
        .with_paged(paged)
        .with_adaptive(Some(adaptive));
    let mut arr = closed_loop_arrivals(&mr.manifest, dataset, max_new, seed)?;
    let lens = LengthModel::testbed(max_new.max(8));
    let mut lrng = Rng::new(seed ^ 0x1E46);
    // warmup compiles the DEFAULT policy's executables; the controller's
    // other candidates load lazily on first assignment (mid-run, like any
    // allowlisted policy)
    {
        let mut cfg_w = cfg.clone();
        cfg_w.max_new_tokens = 2;
        let mut warm = EngineCore::new(mr, cfg_w)?;
        warm.add_request(arr.next())?;
        warm.run_until_idle(mr)?;
    }
    let mut next = move || {
        let mut spec = arr.next();
        if mixed_lengths {
            spec.max_new_tokens = lens.sample(&mut lrng).clamp(4, max_new);
        }
        spec.sampling = SamplingParams { seed: seed ^ spec.id, ..sampling };
        spec // policy: None — the controller assigns at admission
    };
    let (_results, metrics) = match rate_rps {
        None => run_closed_loop(mr, &cfg, concurrency, total_requests, &mut next)?,
        Some(rate) => {
            let mut sched_rng = Rng::new(seed ^ 0x09E7);
            let mut clock = 0.0f64;
            let reqs: Vec<_> = (0..total_requests)
                .map(|_| {
                    clock += sched_rng.exponential(rate);
                    next().with_arrival(clock)
                })
                .collect();
            run_open_loop(mr, &cfg, concurrency, reqs)?
        }
    };
    Ok(OtpsRun {
        drafter: "auto".to_string(),
        dataset: dataset.to_string(),
        k,
        concurrency,
        topology: Some("adaptive".to_string()),
        rate_rps,
        otps: metrics.otps(),
        acceptance_length: metrics.acceptance_length(),
        mean_occupancy: metrics.mean_occupancy(),
        metrics,
    })
}

/// Figure 1: sequence-length distribution report (paper-scale quantiles +
/// log-binned histogram rendered as ASCII).
pub fn fig1_report(samples: usize) -> String {
    let mut rng = Rng::new(1);
    let model = LengthModel::paper();
    let q = model.quantiles(samples, &mut rng);
    let hist = model.histogram(samples, 28, &mut rng);
    let max_c = hist.iter().map(|(_, c)| *c).max().unwrap_or(1);
    let mut out = String::new();
    out.push_str("Figure 1 — sequence length (prompt + generation) distribution\n");
    out.push_str("paper (UltraChat × GPT-OSS 120B): median 3891, P90 10800, P99 20000\n");
    out.push_str(&format!(
        "model fit:                         median {:>5}, P90 {:>5}, P99 {:>5}\n\n",
        q.median, q.p90, q.p99
    ));
    for (center, count) in hist {
        let bar = "#".repeat(count * 48 / max_c);
        out.push_str(&format!("{center:>7} tok | {bar}\n"));
    }
    out
}

/// Figure 5: the regularized variant's learnable alpha trajectory + MTP
/// accuracy comparison, read from the training logs in the manifest.
pub fn fig5_report(mr: &ModelRuntime) -> String {
    let logs = &mr.manifest.training_logs;
    let mut out = String::new();
    out.push_str("Figure 5 — regularized NTP-hidden variant (target-m-hs-reg)\n");
    out.push_str("paper: alpha decays 0.1 -> 0.029 (-71%); baseline MTP acc beats regularized\n\n");
    let reg = logs.get("target-m-hs-reg");
    let base = logs.get("target-m-pe4");
    match (reg, base) {
        (Some(reg), Some(base)) => {
            let alphas: Vec<f64> = reg
                .get("alpha")
                .and_then(|a| a.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
                .unwrap_or_default();
            if let (Some(first), Some(last)) = (alphas.first(), alphas.last()) {
                out.push_str(&format!(
                    "alpha: {:.4} -> {:.4} ({:+.0}%)\n",
                    first,
                    last,
                    (last - first) / first * 100.0
                ));
                let maxa = alphas.iter().cloned().fold(f64::MIN, f64::max);
                for (i, a) in alphas.iter().enumerate() {
                    let bar = "#".repeat((a / maxa * 40.0) as usize);
                    out.push_str(&format!("  log[{i:>2}] alpha {a:.4} | {bar}\n"));
                }
            }
            let mtp = |l: &crate::util::json::Json| -> Option<f64> {
                l.get("mtp_acc")?.as_arr()?.last()?.as_f64()
            };
            if let (Some(mb), Some(mrg)) = (mtp(base), mtp(reg)) {
                out.push_str(&format!(
                    "\nfinal MTP accuracy: baseline {:.1}% vs regularized {:.1}% ({})\n",
                    mb * 100.0,
                    mrg * 100.0,
                    if mb >= mrg { "baseline wins — matches paper" } else { "regularized wins — differs from paper" }
                ));
            }
            let ntp = |l: &crate::util::json::Json| -> Option<f64> {
                l.get("ntp_acc")?.as_arr()?.last()?.as_f64()
            };
            if let (Some(nb), Some(nr), Some(mb), Some(mrg)) =
                (ntp(base), ntp(reg), mtp(base), mtp(reg))
            {
                out.push_str(&format!(
                    "NTP-MTP gap: baseline {:.1}% vs regularized {:.1}%\n",
                    (nb - mb) * 100.0,
                    (nr - mrg) * 100.0
                ));
            }
        }
        _ => out.push_str("(training logs missing — rebuild artifacts)\n"),
    }
    out
}
