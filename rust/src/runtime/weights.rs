//! PEW weight-file reader (the Python writer is python/compile/pew.py).
//!
//! Binary layout (little-endian):
//!   magic b"PEW1"; u32 count; per tensor: u16 name_len + name, u8 dtype
//!   (0=f32, 1=i32), u8 ndim, u32*ndim dims, raw data.

use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("{}: not f32", self.name),
        }
    }
}

pub fn read_pew(path: &Path) -> Result<Vec<Tensor>> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"PEW1" {
        bail!("{path:?}: bad magic {magic:?}");
    }
    let count = read_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let nlen = read_u16(&mut f)? as usize;
        let mut name = vec![0u8; nlen];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let mut hdr = [0u8; 2];
        f.read_exact(&mut hdr)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut f)? as usize);
        }
        let numel: usize = dims.iter().product::<usize>().max(1);
        let mut raw = vec![0u8; numel * 4];
        f.read_exact(&mut raw)?;
        let data = match dtype {
            0 => TensorData::F32(
                raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            1 => TensorData::I32(
                raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            other => bail!("{path:?}: unknown dtype {other}"),
        };
        out.push(Tensor { name, dims, data });
    }
    Ok(out)
}

/// Check a weight file against the manifest's declared parameter order.
pub fn check_order(tensors: &[Tensor], expected: &[String]) -> Result<()> {
    if tensors.len() != expected.len() {
        bail!("weight count {} != manifest {}", tensors.len(), expected.len());
    }
    for (t, e) in tensors.iter().zip(expected) {
        if &t.name != e {
            return Err(anyhow!("weight order mismatch: file {:?} vs manifest {:?}", t.name, e));
        }
    }
    Ok(())
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(f: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    f.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_test_pew(path: &Path) {
        // mirror of the python writer for a 2-tensor file
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"PEW1").unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        // "a": f32 [2,3]
        f.write_all(&1u16.to_le_bytes()).unwrap();
        f.write_all(b"a").unwrap();
        f.write_all(&[0u8, 2u8]).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        for i in 0..6 {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
        // "b": i32 scalar-ish [1]
        f.write_all(&1u16.to_le_bytes()).unwrap();
        f.write_all(b"b").unwrap();
        f.write_all(&[1u8, 1u8]).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&42i32.to_le_bytes()).unwrap();
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("pew_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.pew");
        write_test_pew(&path);
        let ts = read_pew(&path).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "a");
        assert_eq!(ts[0].dims, vec![2, 3]);
        assert_eq!(ts[0].f32s().unwrap(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(ts[1].name, "b");
        match &ts[1].data {
            TensorData::I32(v) => assert_eq!(v, &[42]),
            _ => panic!("wrong dtype"),
        }
        check_order(&ts, &["a".into(), "b".into()]).unwrap();
        assert!(check_order(&ts, &["b".into(), "a".into()]).is_err());
        assert!(check_order(&ts, &["a".into()]).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("pew_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.pew");
        std::fs::write(&path, b"NOPE\x00\x00\x00\x00").unwrap();
        assert!(read_pew(&path).is_err());
    }
}
