//! Host-side tensor views + PJRT buffer marshalling helpers.

use anyhow::{bail, Result};

/// A host tensor (f32 or i32) with explicit dims — the runtime's lingua
/// franca between the coordinator's Rust-owned state and PJRT buffers.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: HostData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum HostData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(dims: &[usize], data: Vec<f32>) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dims: dims.to_vec(), data: HostData::F32(data) }
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { dims: dims.to_vec(), data: HostData::I32(data) }
    }

    pub fn zeros_f32(dims: &[usize]) -> HostTensor {
        HostTensor::f32(dims, vec![0.0; dims.iter().product()])
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            HostData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            HostData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Row-major offset for an index tuple.
    pub fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.dims.len());
        let mut off = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.dims).enumerate() {
            assert!(x < d, "index {x} out of dim {d} at axis {i}");
            off = off * d + x;
        }
        off
    }
}

/// Convert an xla Literal (already untupled) into a HostTensor.
pub fn literal_to_host(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(HostTensor::f32(&dims, lit.to_vec::<f32>()?)),
        xla::ElementType::S32 => Ok(HostTensor::i32(&dims, lit.to_vec::<i32>()?)),
        other => bail!("unsupported element type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offsets_row_major() {
        let t = HostTensor::zeros_f32(&[2, 3, 4]);
        assert_eq!(t.offset(&[0, 0, 0]), 0);
        assert_eq!(t.offset(&[0, 0, 3]), 3);
        assert_eq!(t.offset(&[0, 1, 0]), 4);
        assert_eq!(t.offset(&[1, 2, 3]), 23);
    }

    #[test]
    #[should_panic]
    fn offset_bounds_checked() {
        let t = HostTensor::zeros_f32(&[2, 2]);
        t.offset(&[2, 0]);
    }

    #[test]
    fn constructors_check_len() {
        let t = HostTensor::i32(&[3], vec![1, 2, 3]);
        assert_eq!(t.as_i32().unwrap(), &[1, 2, 3]);
        assert!(t.as_f32().is_err());
    }
}
