//! PJRT runtime: load AOT HLO-text artifacts, compile once on the CPU
//! client, execute with device-resident weights/KV buffers.
//!
//! Layering: `weights` reads .pew files, `tensors` marshals host data,
//! `executable` owns the client + compiled-executable registry, and
//! `models` assembles them into typed prefill/verify/draft invocations the
//! coordinator uses.

pub mod executable;
pub mod kv_blocks;
pub mod models;
pub mod tensors;
pub mod weights;

pub use executable::{Arg, Runtime};
pub use kv_blocks::{
    apply_path_copies, copy_pool_block, gather_kv_row_blocks, physical_copy_rows,
    plan_path_commit, splice_kv_row_blocks, splice_kv_row_blocks_range, PathCommitPlan,
};
pub use models::{compact_kv_path, splice_kv_row, DraftExec, ModelRuntime, PolicyExecs, TargetExec};
pub use tensors::{HostData, HostTensor};
