//! Typed model invocations over the executable registry: prefill / verify /
//! tree-verify for targets, chain or tree draft for drafters. Weights are
//! uploaded once per model as device-resident buffers and shared across
//! every executable that uses them; KV caches round-trip as device buffers
//! between verify calls.
//!
//! The registry is **policy-keyed** for the multi-drafter engine:
//! [`ModelRuntime::ensure_policy_execs`] resolves one
//! [`SpecPolicy`](crate::coordinator::request::SpecPolicy) to its
//! verify/draft executable pair, loading on first use and caching per
//! `(exec key, batch, paged)` — so one engine (or several across a
//! process) serves many drafters and speculation shapes over one uploaded
//! copy of the target weights. [`ModelRuntime::validate_policy`] gates
//! policies on the manifest's per-drafter capability record (`modes`).
//!
//! Tree executables (`verify-tree` / `draft-tree` manifest kinds) bake a
//! static [`TreeTopology`](crate::masking::TreeTopology) into the lowered
//! HLO; the cross-node ancestor mask is NOT baked — the engine precomputes
//! it once and passes it as a runtime input to [`ModelRuntime::verify_tree`]
//! (see `masking::tree`). [`compact_kv_path`] is the host half of the
//! accepted-path commit: tree chunks scatter KV at `base + node_id`, and
//! only the accepted root path survives, compacted to contiguous positions.
//!
//! Paged twins (`verify-paged` / `verify-tree-paged` kinds) address a block
//! pool `[L, 2, NB, BS, H, Dh]` through a per-slot block table passed as a
//! runtime input; their host-side surgery (admission splice, accepted-path
//! rewire/copy) lives in [`super::kv_blocks`].
//!
//! Dynamic-tree executables (`verify-tree-dyn` / `verify-tree-dyn-paged` /
//! `draft-tree-logp` kinds) are lowered once per max-shape ENVELOPE: the
//! cross-node mask *and* the per-slot RoPE depth offsets become per-batch
//! runtime inputs (each slot activates a different confidence-selected node
//! subset — see [`crate::masking::dynamic`]), and the scored drafter returns
//! per-node joint log-probabilities next to the node tokens so the engine
//! can do the selecting.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use super::executable::{Arg, Runtime};
use super::tensors::HostTensor;
use super::weights::{check_order, read_pew, TensorData};
use crate::config::Manifest;
use crate::coordinator::request::SpecPolicy;
use crate::masking::TreeTopology;

pub struct ModelRuntime {
    pub rt: Runtime,
    pub manifest: Manifest,
    /// weight-set name (target or drafter) -> uploaded parameter buffers
    weights: HashMap<String, Vec<xla::PjRtBuffer>>,
    /// policy-keyed executable registry: (SpecPolicy::exec_key, batch,
    /// paged) -> the loaded verify/draft executable pair. Entries are
    /// created on first use ([`Self::ensure_policy_execs`]); target weights
    /// are uploaded once per model and shared across every entry.
    policy_execs: HashMap<(String, usize, bool), PolicyExecs>,
}

/// The executable pair one policy bucket steps with: the target-side verify
/// (chain / tree / dynamic, dense or paged) and the drafter executable
/// (chain, tree, or scored-tree). Handed out by
/// [`ModelRuntime::ensure_policy_execs`]; cheap to clone (name + shape
/// metadata only — the compiled executables live in the runtime registry).
#[derive(Clone, Debug)]
pub struct PolicyExecs {
    pub te: TargetExec,
    pub de: DraftExec,
}

/// Outputs of a target prefill call.
pub struct PrefillOut {
    pub last_logits: HostTensor, // [B, V]
    pub feats: HostTensor,       // [B, P, 3d]
    pub kv: xla::PjRtBuffer,     // device-resident cache
}

/// Outputs of a target verify call.
pub struct VerifyOut {
    pub logits: HostTensor, // [B, K+1, V]
    pub feats: HostTensor,  // [B, K+1, 3d]
    pub kv: xla::PjRtBuffer,
}

/// Identifies a loaded target executable pair.
#[derive(Clone, Debug)]
pub struct TargetExec {
    pub target: String,
    pub batch: usize,
    /// chain depth K (chunk = K+1), or node count N for tree executables
    pub k: usize,
    /// set iff this is a tree-verify executable for that topology id
    pub topo: Option<String>,
    /// set iff this is a block-paged verify executable
    pub paged: bool,
    /// set iff this is a dynamic-tree (max-shape envelope) verify
    /// executable: mask AND depth offsets are per-batch runtime inputs
    pub dynamic: bool,
    /// physical pool size the paged executable was lowered with
    pub num_blocks: Option<usize>,
}

/// Identifies a loaded drafter executable.
#[derive(Clone, Debug)]
pub struct DraftExec {
    pub drafter: String,
    pub batch: usize,
    /// chain depth K, or node count N for tree executables
    pub k: usize,
    /// set iff this is a tree drafter executable for that topology id
    pub topo: Option<String>,
    /// set iff this is a scored tree drafter (`draft-tree-logp`): returns
    /// per-node joint log-probabilities next to the node tokens
    pub scored: bool,
}

impl ModelRuntime {
    pub fn load(artifacts_root: impl Into<PathBuf>) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifacts_root.into())?;
        let rt = Runtime::cpu()?;
        Ok(ModelRuntime {
            rt,
            manifest,
            weights: HashMap::new(),
            policy_execs: HashMap::new(),
        })
    }

    /// Validate a [`SpecPolicy`] against the manifest WITHOUT loading
    /// anything: the drafter must exist, serve `target` (all of an engine's
    /// policies share one target's weights and KV cache), and have been
    /// lowered with the policy's speculation mode (the per-drafter
    /// capability record python `aot.py` writes). Errors are descriptive —
    /// this is the gate that turns "no such executable" into "that drafter
    /// cannot tree-draft".
    pub fn validate_policy(&self, target: &str, policy: &SpecPolicy) -> Result<()> {
        policy.validate().map_err(|e| anyhow::anyhow!(e))?;
        let d = self.manifest.drafter(policy.drafter())?;
        if d.target != target {
            bail!(
                "policy {}: drafter {} serves target {} but the engine serves {target} \
                 (one engine shares one target's weights and KV cache)",
                policy.id(),
                d.name,
                d.target
            );
        }
        if !d.supports(policy.mode_name()) {
            bail!(
                "policy {}: drafter {} (kind {}) does not support {} speculation \
                 (capabilities: [{}]) — pick a parallel drafter or rebuild artifacts \
                 with the mode lowered (python/compile/configs.py drafter_modes)",
                policy.id(),
                d.name,
                d.kind,
                policy.mode_name(),
                d.modes.join(", ")
            );
        }
        Ok(())
    }

    /// Cheap existence probe: would [`ensure_policy_execs`](Self::ensure_policy_execs)
    /// find lowered executables for this policy at this width? Pure manifest
    /// lookups — nothing is read, compiled, or uploaded. The engine probes
    /// every allowlisted policy at construction so a policy lowered at the
    /// wrong batch width fails at startup with the descriptive
    /// `find_exec`/`find_exec_tree` error instead of killing the engine
    /// mid-flight when its first request arrives.
    pub fn probe_policy_execs(
        &self,
        target: &str,
        policy: &SpecPolicy,
        batch: usize,
        paged: bool,
    ) -> Result<()> {
        self.validate_policy(target, policy)?;
        let m = &self.manifest;
        match policy {
            SpecPolicy::Chain { drafter, k } => {
                let kind = if paged { "verify-paged" } else { "verify" };
                m.find_exec(kind, Some(target), None, Some(batch), Some(*k))?;
                m.find_exec("draft", None, Some(drafter), Some(batch), Some(*k))?;
            }
            SpecPolicy::Tree { drafter, topology } => {
                let id = topology.id();
                let kind = if paged { "verify-tree-paged" } else { "verify-tree" };
                m.find_exec_tree(kind, Some(target), None, Some(batch), &id)?;
                m.find_exec_tree("draft-tree", None, Some(drafter), Some(batch), &id)?;
            }
            SpecPolicy::Dynamic { drafter, envelope, .. } => {
                let id = envelope.id();
                let kind = if paged { "verify-tree-dyn-paged" } else { "verify-tree-dyn" };
                m.find_exec_tree(kind, Some(target), None, Some(batch), &id)?;
                m.find_exec_tree("draft-tree-logp", None, Some(drafter), Some(batch), &id)?;
            }
        }
        Ok(())
    }

    /// Load (or fetch from the registry) the executable pair for one policy
    /// at one engine width. First use per (policy executables, batch, paged)
    /// compiles/loads the verify + draft executables; every later call is a
    /// map lookup. Policies differing only in the `Dynamic` node budget
    /// share an entry ([`SpecPolicy::exec_key`] excludes the budget — it is
    /// runtime data). Target weights are shared across all entries of the
    /// same target, drafter weights across all entries of the same drafter.
    pub fn ensure_policy_execs(
        &mut self,
        target: &str,
        policy: &SpecPolicy,
        batch: usize,
        paged: bool,
    ) -> Result<PolicyExecs> {
        let key = (policy.exec_key(), batch, paged);
        if let Some(pe) = self.policy_execs.get(&key) {
            return Ok(pe.clone());
        }
        self.validate_policy(target, policy)?;
        let pe = match policy {
            SpecPolicy::Chain { drafter, k } => {
                let te = if paged {
                    self.ensure_verify_paged(target, batch, *k)?
                } else {
                    self.ensure_verify(target, batch, *k)?
                };
                let de = self.ensure_drafter(drafter, batch, *k)?;
                PolicyExecs { te, de }
            }
            SpecPolicy::Tree { drafter, topology } => {
                let te = if paged {
                    self.ensure_verify_tree_paged(target, batch, topology)?
                } else {
                    self.ensure_verify_tree(target, batch, topology)?
                };
                let de = self.ensure_drafter_tree(drafter, batch, topology)?;
                PolicyExecs { te, de }
            }
            SpecPolicy::Dynamic { drafter, envelope, .. } => {
                let te = if paged {
                    self.ensure_verify_tree_dyn_paged(target, batch, envelope)?
                } else {
                    self.ensure_verify_tree_dyn(target, batch, envelope)?
                };
                let de = self.ensure_drafter_tree_scored(drafter, batch, envelope)?;
                PolicyExecs { te, de }
            }
        };
        self.policy_execs.insert(key, pe.clone());
        Ok(pe)
    }

    /// Upload a weight set (target or drafter) once; validates the file's
    /// tensor order against the manifest's lowering order.
    fn ensure_weights(&mut self, name: &str, rel_path: &str, order: &[String]) -> Result<()> {
        if self.weights.contains_key(name) {
            return Ok(());
        }
        let tensors = read_pew(&self.manifest.abs(rel_path))
            .with_context(|| format!("weights for {name}"))?;
        check_order(&tensors, order)?;
        let mut bufs = Vec::with_capacity(tensors.len());
        for t in &tensors {
            let host = match &t.data {
                TensorData::F32(v) => HostTensor::f32(&t.dims, v.clone()),
                TensorData::I32(v) => HostTensor::i32(&t.dims, v.clone()),
            };
            bufs.push(self.rt.upload(&host)?);
        }
        self.weights.insert(name.to_string(), bufs);
        Ok(())
    }

    pub fn ensure_target(&mut self, target: &str, batch: usize, k: usize) -> Result<TargetExec> {
        let info = self.manifest.target(target)?.clone();
        self.ensure_weights(target, &info.weights, &info.param_order)?;
        let pre = self
            .manifest
            .find_exec("prefill", Some(target), None, Some(batch), None)?
            .clone();
        let ver = self
            .manifest
            .find_exec("verify", Some(target), None, Some(batch), Some(k))?
            .clone();
        self.rt.load(&pre.name, &self.manifest.abs(&pre.path))?;
        self.rt.load(&ver.name, &self.manifest.abs(&ver.path))?;
        Ok(TargetExec { target: target.to_string(), batch, k, topo: None, paged: false, dynamic: false, num_blocks: None })
    }

    pub fn ensure_drafter(&mut self, drafter: &str, batch: usize, k: usize) -> Result<DraftExec> {
        let info = self.manifest.drafter(drafter)?.clone();
        self.ensure_weights(drafter, &info.weights, &info.param_order)?;
        let d = self
            .manifest
            .find_exec("draft", None, Some(drafter), Some(batch), Some(k))?
            .clone();
        self.rt.load(&d.name, &self.manifest.abs(&d.path))?;
        Ok(DraftExec { drafter: drafter.to_string(), batch, k, topo: None, scored: false })
    }

    /// Load the tree-verify executable for `target` at `batch` and the given
    /// static topology. `TargetExec::k` carries the node count N (the chunk
    /// is N+1 wide).
    pub fn ensure_verify_tree(
        &mut self,
        target: &str,
        batch: usize,
        tree: &TreeTopology,
    ) -> Result<TargetExec> {
        let info = self.manifest.target(target)?.clone();
        self.ensure_weights(target, &info.weights, &info.param_order)?;
        let id = tree.id();
        let ver = self
            .manifest
            .find_exec_tree("verify-tree", Some(target), None, Some(batch), &id)?
            .clone();
        self.rt.load(&ver.name, &self.manifest.abs(&ver.path))?;
        Ok(TargetExec {
            target: target.to_string(),
            batch,
            k: tree.len(),
            topo: Some(id),
            paged: false,
            dynamic: false,
            num_blocks: None,
        })
    }

    /// Load the tree drafter executable for `drafter` at `batch` and the
    /// given static topology (node tokens per level are the level's top-w
    /// tokens of that depth's distribution — see python/compile/drafter.py
    /// `draft_pe_tree`).
    pub fn ensure_drafter_tree(
        &mut self,
        drafter: &str,
        batch: usize,
        tree: &TreeTopology,
    ) -> Result<DraftExec> {
        let info = self.manifest.drafter(drafter)?.clone();
        self.ensure_weights(drafter, &info.weights, &info.param_order)?;
        let id = tree.id();
        let d = self
            .manifest
            .find_exec_tree("draft-tree", None, Some(drafter), Some(batch), &id)?
            .clone();
        self.rt.load(&d.name, &self.manifest.abs(&d.path))?;
        Ok(DraftExec { drafter: drafter.to_string(), batch, k: tree.len(), topo: Some(id), scored: false })
    }

    /// Fresh zeroed KV cache for a wave of `batch` slots.
    pub fn zero_kv(&mut self, target: &str, batch: usize) -> Result<xla::PjRtBuffer> {
        let t = self.manifest.target(target)?;
        let dims = [t.n_layers, 2, batch, self.manifest.s_max, t.n_heads, t.head_dim];
        let host = HostTensor::zeros_f32(&dims);
        self.rt.upload(&host)
    }

    /// Fresh zeroed block-pool KV cache (`[L, 2, NB, BS, H, Dh]`) for the
    /// paged executables.
    pub fn zero_kv_pool(
        &mut self,
        target: &str,
        num_blocks: usize,
        block_size: usize,
    ) -> Result<xla::PjRtBuffer> {
        let t = self.manifest.target(target)?;
        let dims = [t.n_layers, 2, num_blocks, block_size, t.n_heads, t.head_dim];
        let host = HostTensor::zeros_f32(&dims);
        self.rt.upload(&host)
    }

    /// Load the block-paged verify executable for `target` at (`batch`, `k`).
    /// `TargetExec::num_blocks` reports the physical pool size the HLO was
    /// lowered with; the engine allocates the pool to match (it may budget
    /// fewer *logical* blocks, never more).
    pub fn ensure_verify_paged(
        &mut self,
        target: &str,
        batch: usize,
        k: usize,
    ) -> Result<TargetExec> {
        let info = self.manifest.target(target)?.clone();
        self.ensure_weights(target, &info.weights, &info.param_order)?;
        let ver = self
            .manifest
            .find_exec("verify-paged", Some(target), None, Some(batch), Some(k))?
            .clone();
        self.rt.load(&ver.name, &self.manifest.abs(&ver.path))?;
        Ok(TargetExec {
            target: target.to_string(),
            batch,
            k,
            topo: None,
            paged: true,
            dynamic: false,
            num_blocks: ver.num_blocks,
        })
    }

    /// Load the block-paged tree-verify executable for `target` at `batch`
    /// and the given static topology.
    pub fn ensure_verify_tree_paged(
        &mut self,
        target: &str,
        batch: usize,
        tree: &TreeTopology,
    ) -> Result<TargetExec> {
        let info = self.manifest.target(target)?.clone();
        self.ensure_weights(target, &info.weights, &info.param_order)?;
        let id = tree.id();
        let ver = self
            .manifest
            .find_exec_tree("verify-tree-paged", Some(target), None, Some(batch), &id)?
            .clone();
        self.rt.load(&ver.name, &self.manifest.abs(&ver.path))?;
        Ok(TargetExec {
            target: target.to_string(),
            batch,
            k: tree.len(),
            topo: Some(id),
            paged: true,
            dynamic: false,
            num_blocks: ver.num_blocks,
        })
    }

    /// Load the dynamic-tree verify executable for `target` at `batch` and
    /// the given max-shape envelope: the ancestor mask AND the depth offsets
    /// are per-batch runtime inputs ([`Self::verify_tree_dyn`]).
    pub fn ensure_verify_tree_dyn(
        &mut self,
        target: &str,
        batch: usize,
        envelope: &TreeTopology,
    ) -> Result<TargetExec> {
        let info = self.manifest.target(target)?.clone();
        self.ensure_weights(target, &info.weights, &info.param_order)?;
        let id = envelope.id();
        let ver = self
            .manifest
            .find_exec_tree("verify-tree-dyn", Some(target), None, Some(batch), &id)?
            .clone();
        self.rt.load(&ver.name, &self.manifest.abs(&ver.path))?;
        Ok(TargetExec {
            target: target.to_string(),
            batch,
            k: envelope.len(),
            topo: Some(id),
            paged: false,
            dynamic: true,
            num_blocks: None,
        })
    }

    /// Block-paged twin of [`ensure_verify_tree_dyn`](Self::ensure_verify_tree_dyn).
    pub fn ensure_verify_tree_dyn_paged(
        &mut self,
        target: &str,
        batch: usize,
        envelope: &TreeTopology,
    ) -> Result<TargetExec> {
        let info = self.manifest.target(target)?.clone();
        self.ensure_weights(target, &info.weights, &info.param_order)?;
        let id = envelope.id();
        let ver = self
            .manifest
            .find_exec_tree("verify-tree-dyn-paged", Some(target), None, Some(batch), &id)?
            .clone();
        self.rt.load(&ver.name, &self.manifest.abs(&ver.path))?;
        Ok(TargetExec {
            target: target.to_string(),
            batch,
            k: envelope.len(),
            topo: Some(id),
            paged: true,
            dynamic: true,
            num_blocks: ver.num_blocks,
        })
    }

    /// Load the scored tree drafter (`draft-tree-logp`) for `drafter` at
    /// `batch` and the given envelope: same inputs as the plain tree
    /// drafter, but the outputs are (node tokens, per-node joint
    /// log-probabilities) — the confidence signal dynamic selection runs on.
    pub fn ensure_drafter_tree_scored(
        &mut self,
        drafter: &str,
        batch: usize,
        envelope: &TreeTopology,
    ) -> Result<DraftExec> {
        let info = self.manifest.drafter(drafter)?.clone();
        self.ensure_weights(drafter, &info.weights, &info.param_order)?;
        let id = envelope.id();
        let d = self
            .manifest
            .find_exec_tree("draft-tree-logp", None, Some(drafter), Some(batch), &id)?
            .clone();
        self.rt.load(&d.name, &self.manifest.abs(&d.path))?;
        Ok(DraftExec {
            drafter: drafter.to_string(),
            batch,
            k: envelope.len(),
            topo: Some(id),
            scored: true,
        })
    }

    pub fn prefill(
        &mut self,
        te: &TargetExec,
        tokens: &HostTensor,     // [B, P] i32 (padded)
        prompt_len: &HostTensor, // [B] i32
        kv: &xla::PjRtBuffer,
    ) -> Result<PrefillOut> {
        let name = format!("{}-prefill-b{}", te.target, te.batch);
        // direct field borrows keep self.weights (shared) and self.rt
        // (mutable) disjoint for the borrow checker
        let wbufs = &self.weights[&te.target];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(tokens));
        args.push(Arg::Host(prompt_len));
        args.push(Arg::Buf(kv));
        let out = self.rt.call(&name, &args)?;
        let mut it = out.into_iter();
        let last_logits = self.rt.download(&it.next().context("missing logits")?)?;
        let feats = self.rt.download(&it.next().context("missing feats")?)?;
        let kv = it.next().context("missing kv")?;
        Ok(PrefillOut { last_logits, feats, kv })
    }

    pub fn verify(
        &mut self,
        te: &TargetExec,
        chunk: &HostTensor,     // [B, K+1] i32
        cache_len: &HostTensor, // [B] i32
        kv: &xla::PjRtBuffer,
    ) -> Result<VerifyOut> {
        let name = format!("{}-verify-b{}-k{}", te.target, te.batch, te.k);
        let wbufs = &self.weights[&te.target];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(chunk));
        args.push(Arg::Host(cache_len));
        args.push(Arg::Buf(kv));
        let out = self.rt.call(&name, &args)?;
        let mut it = out.into_iter();
        let logits = self.rt.download(&it.next().context("missing logits")?)?;
        let feats = self.rt.download(&it.next().context("missing feats")?)?;
        let mut kv = it.next().context("missing kv")?;
        if std::env::var("PEAGLE_FORCE_HOST_KV").is_ok() {
            // §Perf baseline knob: emulate the pre-patch stock-crate path
            // where the KV cache round-trips through the host every verify
            // (see EXPERIMENTS.md §Perf L3 iteration 1)
            let host = self.rt.download(&kv)?;
            kv = self.rt.upload(&host)?;
        }
        Ok(VerifyOut { logits, feats, kv })
    }

    /// One-pass tree verification: score an [root, node_1 .. node_N] chunk
    /// against the cache in a single target forward.
    ///
    /// `chunk`: `[B, N+1]` i32 in chunk-slot order (slot 0 = the last
    /// committed token, slots 1..=N the draft-tree nodes, level-major);
    /// `tree_mask`: `[N+1, N+1]` i32 cross-node ancestor mask (1 = slot i
    /// may attend slot j), precomputed once per topology by
    /// [`TreeMask::to_i32`](crate::masking::TreeMask::to_i32). Each chunk
    /// slot additionally attends every committed cache position; RoPE
    /// positions follow node *depth*, not slot index (baked into the HLO
    /// from the topology), so accepted-path KV entries stay valid after
    /// [`compact_kv_path`]. Returns logits/feats rows in chunk-slot order.
    pub fn verify_tree(
        &mut self,
        te: &TargetExec,
        chunk: &HostTensor,     // [B, N+1] i32
        cache_len: &HostTensor, // [B] i32
        tree_mask: &HostTensor, // [N+1, N+1] i32
        kv: &xla::PjRtBuffer,
    ) -> Result<VerifyOut> {
        let topo = te
            .topo
            .as_deref()
            .context("verify_tree called with a non-tree TargetExec")?;
        let name = format!("{}-verify-tree-{}-b{}", te.target, topo, te.batch);
        let wbufs = &self.weights[&te.target];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(chunk));
        args.push(Arg::Host(cache_len));
        args.push(Arg::Host(tree_mask));
        args.push(Arg::Buf(kv));
        let out = self.rt.call(&name, &args)?;
        let mut it = out.into_iter();
        let logits = self.rt.download(&it.next().context("missing logits")?)?;
        let feats = self.rt.download(&it.next().context("missing feats")?)?;
        let kv = it.next().context("missing kv")?;
        Ok(VerifyOut { logits, feats, kv })
    }

    /// Block-paged twin of [`verify`](Self::verify): the cache argument is
    /// the block pool, addressed through `block_table`
    /// (`[B, s_max / block_size]` i32 pool-block ids; 0 = the reserved null
    /// block for unused entries). Returns the same outputs with the new pool
    /// as the threaded KV state.
    pub fn verify_paged(
        &mut self,
        te: &TargetExec,
        chunk: &HostTensor,       // [B, K+1] i32
        cache_len: &HostTensor,   // [B] i32
        block_table: &HostTensor, // [B, M] i32
        pool: &xla::PjRtBuffer,
    ) -> Result<VerifyOut> {
        anyhow::ensure!(te.paged, "verify_paged called with a non-paged TargetExec");
        let name = format!("{}-verify-paged-b{}-k{}", te.target, te.batch, te.k);
        let wbufs = &self.weights[&te.target];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(chunk));
        args.push(Arg::Host(cache_len));
        args.push(Arg::Host(block_table));
        args.push(Arg::Buf(pool));
        let out = self.rt.call(&name, &args)?;
        let mut it = out.into_iter();
        let logits = self.rt.download(&it.next().context("missing logits")?)?;
        let feats = self.rt.download(&it.next().context("missing feats")?)?;
        let kv = it.next().context("missing kv")?;
        Ok(VerifyOut { logits, feats, kv })
    }

    /// Block-paged twin of [`verify_tree`](Self::verify_tree); mask and
    /// depth semantics are identical, the cache is the block pool addressed
    /// through `block_table`.
    pub fn verify_tree_paged(
        &mut self,
        te: &TargetExec,
        chunk: &HostTensor,       // [B, N+1] i32
        cache_len: &HostTensor,   // [B] i32
        tree_mask: &HostTensor,   // [N+1, N+1] i32
        block_table: &HostTensor, // [B, M] i32
        pool: &xla::PjRtBuffer,
    ) -> Result<VerifyOut> {
        anyhow::ensure!(te.paged, "verify_tree_paged called with a non-paged TargetExec");
        let topo = te
            .topo
            .as_deref()
            .context("verify_tree_paged called with a non-tree TargetExec")?;
        let name = format!("{}-verify-tree-paged-{}-b{}", te.target, topo, te.batch);
        let wbufs = &self.weights[&te.target];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(chunk));
        args.push(Arg::Host(cache_len));
        args.push(Arg::Host(tree_mask));
        args.push(Arg::Host(block_table));
        args.push(Arg::Buf(pool));
        let out = self.rt.call(&name, &args)?;
        let mut it = out.into_iter();
        let logits = self.rt.download(&it.next().context("missing logits")?)?;
        let feats = self.rt.download(&it.next().context("missing feats")?)?;
        let kv = it.next().context("missing kv")?;
        Ok(VerifyOut { logits, feats, kv })
    }

    /// Dynamic-tree verification over a max-shape envelope: like
    /// [`verify_tree`](Self::verify_tree), but the mask is PER-BATCH
    /// (`[B, N+1, N+1]` — each slot activates its own compacted node subset,
    /// inactive tail rows/cols all-zero) and the RoPE depth offsets are a
    /// runtime input too (`[B, N+1]`, each compacted slot's envelope depth).
    /// The chunk carries `[root, selected nodes.., PAD..]` in compacted
    /// layout (see [`crate::masking::dynamic`]).
    pub fn verify_tree_dyn(
        &mut self,
        te: &TargetExec,
        chunk: &HostTensor,         // [B, N+1] i32 (compacted + PAD tail)
        cache_len: &HostTensor,     // [B] i32
        tree_mask: &HostTensor,     // [B, N+1, N+1] i32
        depth_offsets: &HostTensor, // [B, N+1] i32
        kv: &xla::PjRtBuffer,
    ) -> Result<VerifyOut> {
        anyhow::ensure!(te.dynamic, "verify_tree_dyn called with a static TargetExec");
        let topo = te
            .topo
            .as_deref()
            .context("verify_tree_dyn called with a non-tree TargetExec")?;
        let name = format!("{}-verify-tree-dyn-{}-b{}", te.target, topo, te.batch);
        let wbufs = &self.weights[&te.target];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(chunk));
        args.push(Arg::Host(cache_len));
        args.push(Arg::Host(tree_mask));
        args.push(Arg::Host(depth_offsets));
        args.push(Arg::Buf(kv));
        let out = self.rt.call(&name, &args)?;
        let mut it = out.into_iter();
        let logits = self.rt.download(&it.next().context("missing logits")?)?;
        let feats = self.rt.download(&it.next().context("missing feats")?)?;
        let kv = it.next().context("missing kv")?;
        Ok(VerifyOut { logits, feats, kv })
    }

    /// Block-paged twin of [`verify_tree_dyn`](Self::verify_tree_dyn); the
    /// cache is the block pool addressed through `block_table`.
    #[allow(clippy::too_many_arguments)]
    pub fn verify_tree_dyn_paged(
        &mut self,
        te: &TargetExec,
        chunk: &HostTensor,         // [B, N+1] i32
        cache_len: &HostTensor,     // [B] i32
        tree_mask: &HostTensor,     // [B, N+1, N+1] i32
        depth_offsets: &HostTensor, // [B, N+1] i32
        block_table: &HostTensor,   // [B, M] i32
        pool: &xla::PjRtBuffer,
    ) -> Result<VerifyOut> {
        anyhow::ensure!(te.paged, "verify_tree_dyn_paged called with a non-paged TargetExec");
        anyhow::ensure!(te.dynamic, "verify_tree_dyn_paged called with a static TargetExec");
        let topo = te
            .topo
            .as_deref()
            .context("verify_tree_dyn_paged called with a non-tree TargetExec")?;
        let name = format!("{}-verify-tree-dyn-paged-{}-b{}", te.target, topo, te.batch);
        let wbufs = &self.weights[&te.target];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(chunk));
        args.push(Arg::Host(cache_len));
        args.push(Arg::Host(tree_mask));
        args.push(Arg::Host(depth_offsets));
        args.push(Arg::Host(block_table));
        args.push(Arg::Buf(pool));
        let out = self.rt.call(&name, &args)?;
        let mut it = out.into_iter();
        let logits = self.rt.download(&it.next().context("missing logits")?)?;
        let feats = self.rt.download(&it.next().context("missing feats")?)?;
        let kv = it.next().context("missing kv")?;
        Ok(VerifyOut { logits, feats, kv })
    }

    /// Load the device accepted-path commit executable for `target` at
    /// `batch` (kind `commit-path-paged`). Errors when the manifest predates
    /// device commit — callers treat that as "fall back to host copies"
    /// (the [`ensure_prefill_cached`](Self::ensure_prefill_cached)
    /// precedent). No weights are involved: the executable is a pure
    /// gather/scatter over the pool driven by the uploaded plan.
    pub fn ensure_commit_path_paged(&mut self, target: &str, batch: usize) -> Result<TargetExec> {
        let exe = self
            .manifest
            .find_exec("commit-path-paged", Some(target), None, Some(batch), None)?
            .clone();
        self.rt.load(&exe.name, &self.manifest.abs(&exe.path))?;
        Ok(TargetExec {
            target: target.to_string(),
            batch,
            k: 0,
            topo: None,
            paged: true,
            dynamic: false,
            num_blocks: exe.num_blocks,
        })
    }

    /// Device accepted-path commit: apply a physical copy plan to the block
    /// pool without downloading it.
    ///
    /// `plan` `[COMMIT_PLAN_ROWS, 4]` i32 rows of
    /// `(src_block, src_off, dst_block, dst_off)` — the physical-row form of
    /// [`super::kv_blocks::PathCommitPlan`] copies (see
    /// [`super::kv_blocks::physical_copy_rows`]); unused rows are
    /// `(0, 0, 0, 0)`, an inert self-copy inside the reserved null block.
    /// The lowered HLO gathers every source row before scattering
    /// (python `model.commit_path_paged`), which matches applying the rows
    /// sequentially because `plan_path_commit` orders copies ascending with
    /// src > dst. Returns the new pool buffer — the only transfer is the
    /// tiny plan upload.
    pub fn commit_path_paged(
        &mut self,
        te: &TargetExec,
        plan: &HostTensor, // [R, 4] i32
        pool: &xla::PjRtBuffer,
    ) -> Result<xla::PjRtBuffer> {
        anyhow::ensure!(te.paged, "commit_path_paged called with a non-paged TargetExec");
        let name = format!("{}-commit-path-paged-b{}", te.target, te.batch);
        let args = [Arg::Host(plan), Arg::Buf(pool)];
        let mut out = self.rt.call(&name, &args)?;
        anyhow::ensure!(out.len() == 1, "{name}: expected 1 output, got {}", out.len());
        Ok(out.remove(0))
    }

    /// Scored tree draft: same inputs as [`draft`](Self::draft), returning
    /// `([B, N]` node tokens, `[B, N]` joint log-probabilities`)` — node
    /// `i`'s joint log-probability is the sum of the drafter's per-level
    /// log-probabilities along `i`'s root path (the dynamic-selection
    /// confidence signal).
    pub fn draft_tree_scored(
        &mut self,
        de: &DraftExec,
        ctx_tokens: &HostTensor,
        ctx_feats: &HostTensor,
        row_pos0: &HostTensor,
    ) -> Result<(HostTensor, HostTensor)> {
        anyhow::ensure!(de.scored, "draft_tree_scored called with an unscored DraftExec");
        let topo = de
            .topo
            .as_deref()
            .context("draft_tree_scored called with a non-tree DraftExec")?;
        let name = format!("{}-draft-tree-logp-{}-b{}", de.drafter, topo, de.batch);
        let wbufs = &self.weights[&de.drafter];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(ctx_tokens));
        args.push(Arg::Host(ctx_feats));
        args.push(Arg::Host(row_pos0));
        let out = self.rt.call(&name, &args)?;
        let tokens = self.rt.download(&out[0])?;
        let logp = self.rt.download(&out[1])?;
        Ok((tokens, logp))
    }

    /// Load just the prefill executable for a target at `batch` (used by the
    /// stepped engine's per-slot admission path, which never runs a verify
    /// at that width). `TargetExec::k` is irrelevant to prefill and set to 0.
    pub fn ensure_prefill(&mut self, target: &str, batch: usize) -> Result<TargetExec> {
        let info = self.manifest.target(target)?.clone();
        self.ensure_weights(target, &info.weights, &info.param_order)?;
        let pre = self
            .manifest
            .find_exec("prefill", Some(target), None, Some(batch), None)?
            .clone();
        self.rt.load(&pre.name, &self.manifest.abs(&pre.path))?;
        Ok(TargetExec { target: target.to_string(), batch, k: 0, topo: None, paged: false, dynamic: false, num_blocks: None })
    }

    /// Load the batch-1 tail-only prefill (`prefill-cached`) for a target —
    /// the prefix-cache admission path. Errors when the manifest predates
    /// the executable; callers treat that as "hits dedup memory but still
    /// pay a full prefill".
    pub fn ensure_prefill_cached(&mut self, target: &str) -> Result<TargetExec> {
        let info = self.manifest.target(target)?.clone();
        self.ensure_weights(target, &info.weights, &info.param_order)?;
        let pre = self
            .manifest
            .find_exec("prefill-cached", Some(target), None, Some(1), None)?
            .clone();
        self.rt.load(&pre.name, &self.manifest.abs(&pre.path))?;
        Ok(TargetExec { target: target.to_string(), batch: 1, k: 0, topo: None, paged: false, dynamic: false, num_blocks: None })
    }

    /// Tail-only prefill behind a cached prompt prefix (prefix-cache hit).
    ///
    /// `tokens` `[1, PREFIX_TAIL_PAD]` i32 — the prompt tail, left-aligned
    /// (slot i holds prompt position start + i); `prompt_len` `[1]` i32 (the
    /// FULL prompt length); `start` `[1]` i32 — positions `[0, start)` of
    /// the uploaded `kv` already hold the prefix rows (gathered from shared
    /// pool blocks). Outputs are bitwise-identical to the same rows of a
    /// full [`prefill`](Self::prefill): `feats` row i is prompt position
    /// start + i.
    pub fn prefill_cached(
        &mut self,
        te: &TargetExec,
        tokens: &HostTensor,     // [1, W] i32 (tail, left-aligned)
        prompt_len: &HostTensor, // [1] i32
        start: &HostTensor,      // [1] i32
        kv: &xla::PjRtBuffer,
    ) -> Result<PrefillOut> {
        let name = format!("{}-prefill-cached-b1", te.target);
        let wbufs = &self.weights[&te.target];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(tokens));
        args.push(Arg::Host(prompt_len));
        args.push(Arg::Host(start));
        args.push(Arg::Buf(kv));
        let out = self.rt.call(&name, &args)?;
        let mut it = out.into_iter();
        let last_logits = self.rt.download(&it.next().context("missing logits")?)?;
        let feats = self.rt.download(&it.next().context("missing feats")?)?;
        let kv = it.next().context("missing kv")?;
        Ok(PrefillOut { last_logits, feats, kv })
    }

    /// Load just the verify executable for a target at (`batch`, `k`) — the
    /// stepped engine's decode width never runs a prefill (admission uses
    /// the batch-1 prefill instead), so the batch-wide prefill HLO is not
    /// compiled.
    pub fn ensure_verify(&mut self, target: &str, batch: usize, k: usize) -> Result<TargetExec> {
        let info = self.manifest.target(target)?.clone();
        self.ensure_weights(target, &info.weights, &info.param_order)?;
        let ver = self
            .manifest
            .find_exec("verify", Some(target), None, Some(batch), Some(k))?
            .clone();
        self.rt.load(&ver.name, &self.manifest.abs(&ver.path))?;
        Ok(TargetExec { target: target.to_string(), batch, k, topo: None, paged: false, dynamic: false, num_blocks: None })
    }

    /// Draft K chain tokens — or N tree-node tokens when `de` was loaded by
    /// [`ensure_drafter_tree`](Self::ensure_drafter_tree) (same I/O shape:
    /// the topology is baked into the HLO, only the output width differs).
    /// ctx_tokens `[B,C]` i32, ctx_feats `[B,C,3d]` f32, row_pos0 `[B]` i32
    /// -> `[B,K]` (or `[B,N]`) i32.
    pub fn draft(
        &mut self,
        de: &DraftExec,
        ctx_tokens: &HostTensor,
        ctx_feats: &HostTensor,
        row_pos0: &HostTensor,
    ) -> Result<HostTensor> {
        let name = match &de.topo {
            Some(t) => format!("{}-draft-tree-{}-b{}", de.drafter, t, de.batch),
            None => format!("{}-draft-b{}-k{}", de.drafter, de.batch, de.k),
        };
        let wbufs = &self.weights[&de.drafter];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(ctx_tokens));
        args.push(Arg::Host(ctx_feats));
        args.push(Arg::Host(row_pos0));
        let out = self.rt.call(&name, &args)?;
        self.rt.download(&out[0])
    }
}

/// Copy the single batch row of `src` (a [L, 2, 1, S, H, Dh] KV cache) into
/// batch row `slot` of `dst` (a [L, 2, B, S, H, Dh] KV cache). Pure host
/// arithmetic over the row-major layout; shape-checked.
pub fn splice_kv_row(dst: &mut HostTensor, src: &HostTensor, slot: usize) -> Result<()> {
    anyhow::ensure!(
        dst.dims.len() == 6 && src.dims.len() == 6,
        "KV caches must be rank 6, got {:?} / {:?}",
        dst.dims,
        src.dims
    );
    anyhow::ensure!(src.dims[2] == 1, "source KV must be batch 1, got {:?}", src.dims);
    anyhow::ensure!(
        dst.dims[0] == src.dims[0]
            && dst.dims[1] == src.dims[1]
            && dst.dims[3..] == src.dims[3..],
        "KV cache shape mismatch: {:?} vs {:?}",
        dst.dims,
        src.dims
    );
    let batch = dst.dims[2];
    anyhow::ensure!(slot < batch, "slot {slot} out of batch {batch}");
    let planes = dst.dims[0] * dst.dims[1]; // L * 2
    let row: usize = dst.dims[3..].iter().product(); // S * H * Dh
    let (dst_v, src_v) = match (&mut dst.data, &src.data) {
        (super::tensors::HostData::F32(d), super::tensors::HostData::F32(s)) => (d, s),
        _ => anyhow::bail!("KV caches must both be f32"),
    };
    for p in 0..planes {
        let doff = (p * batch + slot) * row;
        let soff = p * row;
        dst_v[doff..doff + row].copy_from_slice(&src_v[soff..soff + row]);
    }
    Ok(())
}

/// Compact an accepted tree path's KV entries to contiguous positions.
///
/// A tree-verify call scatters the K/V of chunk slot `j` at sequence
/// position `base + j` of batch row `slot` (`kv` is the engine-wide
/// `[L, 2, B, S, H, Dh]` cache, `base` the slot's committed length). After
/// acceptance only the root path survives: the m-th accepted node (1-based,
/// chunk slot `path[m-1]`) must end up at position `base + m` so the cache
/// stays dense. Node ids are level-major, so `path[m-1] >= m` and copying in
/// ascending `m` never clobbers a later source. RoPE positions were applied
/// by node depth (== m), so moved entries remain valid — for a chain path
/// (`path[m-1] == m` for all m) every copy is a no-op and the caller should
/// skip the host round trip entirely.
pub fn compact_kv_path(
    kv: &mut HostTensor,
    slot: usize,
    base: usize,
    path: &[usize],
) -> Result<()> {
    anyhow::ensure!(kv.dims.len() == 6, "KV cache must be rank 6, got {:?}", kv.dims);
    let (batch, s_max) = (kv.dims[2], kv.dims[3]);
    anyhow::ensure!(slot < batch, "slot {slot} out of batch {batch}");
    let row: usize = kv.dims[4] * kv.dims[5]; // H * Dh per position
    let planes = kv.dims[0] * kv.dims[1]; // L * 2
    let v = match &mut kv.data {
        super::tensors::HostData::F32(d) => d,
        _ => anyhow::bail!("KV cache must be f32"),
    };
    for (m, &node) in path.iter().enumerate() {
        let m = m + 1; // destination chunk slot (0 is the root, never moved)
        anyhow::ensure!(node >= m, "path slot {node} precedes destination {m}");
        anyhow::ensure!(
            base + node < s_max,
            "path position {} out of cache {s_max}",
            base + node
        );
        if node == m {
            continue; // chain-shaped prefix: already in place
        }
        for p in 0..planes {
            let seq0 = ((p * batch) + slot) * s_max * row;
            let src = seq0 + (base + node) * row;
            let dst = seq0 + (base + m) * row;
            v.copy_within(src..src + row, dst);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(dims: &[usize], fill: impl Fn(usize) -> f32) -> HostTensor {
        let n: usize = dims.iter().product();
        HostTensor::f32(dims, (0..n).map(fill).collect())
    }

    #[test]
    fn splice_writes_exactly_one_row() {
        // L=2, 2, B=3, S=4, H=1, Dh=2 -> plane stride 3*8, row 8
        let mut dst = kv(&[2, 2, 3, 4, 1, 2], |_| 0.0);
        let src = kv(&[2, 2, 1, 4, 1, 2], |i| i as f32 + 1.0);
        splice_kv_row(&mut dst, &src, 1).unwrap();
        let d = dst.as_f32().unwrap();
        let row = 4 * 1 * 2;
        for p in 0..4 {
            for b in 0..3 {
                let block = &d[(p * 3 + b) * row..(p * 3 + b + 1) * row];
                if b == 1 {
                    let want: Vec<f32> =
                        (0..row).map(|j| (p * row + j) as f32 + 1.0).collect();
                    assert_eq!(block, &want[..], "plane {p}");
                } else {
                    assert!(block.iter().all(|&x| x == 0.0), "plane {p} row {b} touched");
                }
            }
        }
    }

    #[test]
    fn splice_preserves_other_rows() {
        let mut dst = kv(&[1, 2, 2, 2, 1, 1], |i| i as f32);
        let before: Vec<f32> = dst.as_f32().unwrap().to_vec();
        let src = kv(&[1, 2, 1, 2, 1, 1], |_| 99.0);
        splice_kv_row(&mut dst, &src, 0).unwrap();
        let d = dst.as_f32().unwrap();
        // layout per plane: [row0 (2 elems), row1 (2 elems)]; row1 untouched
        for p in 0..2 {
            assert_eq!(d[p * 4], 99.0);
            assert_eq!(d[p * 4 + 1], 99.0);
            assert_eq!(d[p * 4 + 2], before[p * 4 + 2]);
            assert_eq!(d[p * 4 + 3], before[p * 4 + 3]);
        }
    }

    #[test]
    fn compact_moves_path_nodes_into_place() {
        // L=1, 2, B=2, S=8, H=1, Dh=1: each position holds one element whose
        // value encodes (plane, batch, seq) so moves are easy to assert
        let mut cache = kv(&[1, 2, 2, 8, 1, 1], |i| i as f32);
        let before: Vec<f32> = cache.as_f32().unwrap().to_vec();
        // slot 1, base 2: chunk slots live at positions 2..8; accepted path
        // chunk slots [2, 5] must land at positions 3 and 4
        compact_kv_path(&mut cache, 1, 2, &[2, 5]).unwrap();
        let d = cache.as_f32().unwrap();
        for p in 0..2 {
            let seq0 = (p * 2 + 1) * 8;
            assert_eq!(d[seq0 + 3], before[seq0 + 2 + 2], "plane {p}: node 2 -> pos 3");
            assert_eq!(d[seq0 + 4], before[seq0 + 2 + 5], "plane {p}: node 5 -> pos 4");
            // root and committed prefix untouched
            for s in 0..3 {
                assert_eq!(d[seq0 + s], before[seq0 + s], "plane {p} pos {s}");
            }
            // slot 0 fully untouched
            let other = p * 2 * 8;
            for s in 0..8 {
                assert_eq!(d[other + s], before[other + s]);
            }
        }
    }

    #[test]
    fn compact_chain_path_is_identity() {
        let mut cache = kv(&[2, 2, 1, 6, 1, 2], |i| (i * 7 % 13) as f32);
        let before: Vec<f32> = cache.as_f32().unwrap().to_vec();
        compact_kv_path(&mut cache, 0, 1, &[1, 2, 3]).unwrap();
        assert_eq!(cache.as_f32().unwrap(), &before[..]);
    }

    #[test]
    fn compact_rejects_bad_paths() {
        let mut cache = kv(&[1, 2, 1, 8, 1, 1], |_| 0.0);
        // node id below its destination index (not a valid level-major path)
        assert!(compact_kv_path(&mut cache, 0, 0, &[2, 1]).is_err());
        // out of cache
        assert!(compact_kv_path(&mut cache, 0, 6, &[3]).is_err());
        // out of batch
        assert!(compact_kv_path(&mut cache, 1, 0, &[1]).is_err());
    }

    #[test]
    fn splice_shape_checked() {
        let mut dst = kv(&[1, 2, 2, 2, 1, 1], |_| 0.0);
        let src_bad_batch = kv(&[1, 2, 2, 2, 1, 1], |_| 0.0);
        assert!(splice_kv_row(&mut dst, &src_bad_batch, 0).is_err());
        let src_bad_shape = kv(&[1, 2, 1, 3, 1, 1], |_| 0.0);
        assert!(splice_kv_row(&mut dst, &src_bad_shape, 0).is_err());
        let src = kv(&[1, 2, 1, 2, 1, 1], |_| 0.0);
        assert!(splice_kv_row(&mut dst, &src, 2).is_err());
        assert!(splice_kv_row(&mut dst, &src, 1).is_ok());
    }
}
