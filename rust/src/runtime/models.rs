//! Typed model invocations over the executable registry: prefill / verify
//! for targets, draft for drafters. Weights are uploaded once per model as
//! device-resident buffers and shared across every executable that uses
//! them; KV caches round-trip as device buffers between verify calls.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use super::executable::{Arg, Runtime};
use super::tensors::HostTensor;
use super::weights::{check_order, read_pew, TensorData};
use crate::config::Manifest;

pub struct ModelRuntime {
    pub rt: Runtime,
    pub manifest: Manifest,
    /// weight-set name (target or drafter) -> uploaded parameter buffers
    weights: HashMap<String, Vec<xla::PjRtBuffer>>,
}

/// Outputs of a target prefill call.
pub struct PrefillOut {
    pub last_logits: HostTensor, // [B, V]
    pub feats: HostTensor,       // [B, P, 3d]
    pub kv: xla::PjRtBuffer,     // device-resident cache
}

/// Outputs of a target verify call.
pub struct VerifyOut {
    pub logits: HostTensor, // [B, K+1, V]
    pub feats: HostTensor,  // [B, K+1, 3d]
    pub kv: xla::PjRtBuffer,
}

/// Identifies a loaded target executable pair.
#[derive(Clone, Debug)]
pub struct TargetExec {
    pub target: String,
    pub batch: usize,
    pub k: usize,
}

/// Identifies a loaded drafter executable.
#[derive(Clone, Debug)]
pub struct DraftExec {
    pub drafter: String,
    pub batch: usize,
    pub k: usize,
}

impl ModelRuntime {
    pub fn load(artifacts_root: impl Into<PathBuf>) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifacts_root.into())?;
        let rt = Runtime::cpu()?;
        Ok(ModelRuntime { rt, manifest, weights: HashMap::new() })
    }

    /// Upload a weight set (target or drafter) once; validates the file's
    /// tensor order against the manifest's lowering order.
    fn ensure_weights(&mut self, name: &str, rel_path: &str, order: &[String]) -> Result<()> {
        if self.weights.contains_key(name) {
            return Ok(());
        }
        let tensors = read_pew(&self.manifest.abs(rel_path))
            .with_context(|| format!("weights for {name}"))?;
        check_order(&tensors, order)?;
        let mut bufs = Vec::with_capacity(tensors.len());
        for t in &tensors {
            let host = match &t.data {
                TensorData::F32(v) => HostTensor::f32(&t.dims, v.clone()),
                TensorData::I32(v) => HostTensor::i32(&t.dims, v.clone()),
            };
            bufs.push(self.rt.upload(&host)?);
        }
        self.weights.insert(name.to_string(), bufs);
        Ok(())
    }

    pub fn ensure_target(&mut self, target: &str, batch: usize, k: usize) -> Result<TargetExec> {
        let info = self.manifest.target(target)?.clone();
        self.ensure_weights(target, &info.weights, &info.param_order)?;
        let pre = self
            .manifest
            .find_exec("prefill", Some(target), None, Some(batch), None)?
            .clone();
        let ver = self
            .manifest
            .find_exec("verify", Some(target), None, Some(batch), Some(k))?
            .clone();
        self.rt.load(&pre.name, &self.manifest.abs(&pre.path))?;
        self.rt.load(&ver.name, &self.manifest.abs(&ver.path))?;
        Ok(TargetExec { target: target.to_string(), batch, k })
    }

    pub fn ensure_drafter(&mut self, drafter: &str, batch: usize, k: usize) -> Result<DraftExec> {
        let info = self.manifest.drafter(drafter)?.clone();
        self.ensure_weights(drafter, &info.weights, &info.param_order)?;
        let d = self
            .manifest
            .find_exec("draft", None, Some(drafter), Some(batch), Some(k))?
            .clone();
        self.rt.load(&d.name, &self.manifest.abs(&d.path))?;
        Ok(DraftExec { drafter: drafter.to_string(), batch, k })
    }

    /// Fresh zeroed KV cache for a wave of `batch` slots.
    pub fn zero_kv(&mut self, target: &str, batch: usize) -> Result<xla::PjRtBuffer> {
        let t = self.manifest.target(target)?;
        let dims = [t.n_layers, 2, batch, self.manifest.s_max, t.n_heads, t.head_dim];
        let host = HostTensor::zeros_f32(&dims);
        self.rt.upload(&host)
    }

    pub fn prefill(
        &mut self,
        te: &TargetExec,
        tokens: &HostTensor,     // [B, P] i32 (padded)
        prompt_len: &HostTensor, // [B] i32
        kv: &xla::PjRtBuffer,
    ) -> Result<PrefillOut> {
        let name = format!("{}-prefill-b{}", te.target, te.batch);
        // direct field borrows keep self.weights (shared) and self.rt
        // (mutable) disjoint for the borrow checker
        let wbufs = &self.weights[&te.target];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(tokens));
        args.push(Arg::Host(prompt_len));
        args.push(Arg::Buf(kv));
        let out = self.rt.call(&name, &args)?;
        let mut it = out.into_iter();
        let last_logits = self.rt.download(&it.next().context("missing logits")?)?;
        let feats = self.rt.download(&it.next().context("missing feats")?)?;
        let kv = it.next().context("missing kv")?;
        Ok(PrefillOut { last_logits, feats, kv })
    }

    pub fn verify(
        &mut self,
        te: &TargetExec,
        chunk: &HostTensor,     // [B, K+1] i32
        cache_len: &HostTensor, // [B] i32
        kv: &xla::PjRtBuffer,
    ) -> Result<VerifyOut> {
        let name = format!("{}-verify-b{}-k{}", te.target, te.batch, te.k);
        let wbufs = &self.weights[&te.target];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(chunk));
        args.push(Arg::Host(cache_len));
        args.push(Arg::Buf(kv));
        let out = self.rt.call(&name, &args)?;
        let mut it = out.into_iter();
        let logits = self.rt.download(&it.next().context("missing logits")?)?;
        let feats = self.rt.download(&it.next().context("missing feats")?)?;
        let mut kv = it.next().context("missing kv")?;
        if std::env::var("PEAGLE_FORCE_HOST_KV").is_ok() {
            // §Perf baseline knob: emulate the pre-patch stock-crate path
            // where the KV cache round-trips through the host every verify
            // (see EXPERIMENTS.md §Perf L3 iteration 1)
            let host = self.rt.download(&kv)?;
            kv = self.rt.upload(&host)?;
        }
        Ok(VerifyOut { logits, feats, kv })
    }

    /// Draft K tokens. ctx_tokens [B,C] i32, ctx_feats [B,C,3d] f32,
    /// row_pos0 [B] i32 -> [B,K] i32.
    pub fn draft(
        &mut self,
        de: &DraftExec,
        ctx_tokens: &HostTensor,
        ctx_feats: &HostTensor,
        row_pos0: &HostTensor,
    ) -> Result<HostTensor> {
        let name = format!("{}-draft-b{}-k{}", de.drafter, de.batch, de.k);
        let wbufs = &self.weights[&de.drafter];
        let mut args: Vec<Arg> = wbufs.iter().map(Arg::Buf).collect();
        args.push(Arg::Host(ctx_tokens));
        args.push(Arg::Host(ctx_feats));
        args.push(Arg::Host(row_pos0));
        let out = self.rt.call(&name, &args)?;
        self.rt.download(&out[0])
    }
}
