//! Block-granular host-side KV surgery for the paged cache — the paged
//! replacements for [`splice_kv_row`](super::models::splice_kv_row) and
//! [`compact_kv_path`](super::models::compact_kv_path).
//!
//! The paged physical cache is a block pool `[L, 2, NB, BS, H, Dh]`; logical
//! position `q` of a slot lives in pool block `table[q / BS]` at offset
//! `q % BS` (block 0 is the reserved null block — see
//! [`SlotManager`](crate::coordinator::kv_cache::SlotManager)).
//!
//! Two operations need host arithmetic:
//!
//! * **Admission** ([`splice_kv_row_blocks`]): the batch-1 prefill still
//!   produces a dense `[L, 2, 1, S, H, Dh]` row; its first `prompt_len`
//!   positions are scattered into the slot's freshly claimed blocks.
//! * **Tree accepted-path commit** ([`plan_path_commit`]): after tree
//!   verification, chunk slot `path[m-1]` (written at logical `base + path
//!   [m-1]`) must end up at logical `base + m`. Dense mode copies rows
//!   through the whole downloaded cache; paged mode first tries to *rewire*
//!   — when the accepted path is a uniform block-aligned shift, whole table
//!   entries swap places (pure pointer surgery, no pool round trip at all)
//!   — and otherwise falls back to position copies confined to the ≤ 2
//!   blocks the chunk spans. With the default `BLOCK_SIZE = 16 > chunk`,
//!   rewires only fire on smaller configured block sizes; the copies path
//!   is still block-mapped and never touches unrelated slots' data.

use anyhow::Result;

use super::tensors::{HostData, HostTensor};

/// How one accepted tree path commits into a paged cache.
///
/// `swaps` are pairs of LOGICAL block indices of the owning slot's table
/// (apply via `SlotManager::swap_blocks` — no data moves); `copies` are
/// `(src, dst)` LOGICAL positions to copy through the table
/// ([`apply_path_copies`]). A plan is either swaps-only or copies-only:
/// mixing them would let a copy read a block a swap already moved.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct PathCommitPlan {
    pub swaps: Vec<(usize, usize)>,
    pub copies: Vec<(usize, usize)>,
}

impl PathCommitPlan {
    pub fn is_noop(&self) -> bool {
        self.swaps.is_empty() && self.copies.is_empty()
    }
}

/// Plan the paged commit of an accepted tree path: the m-th accepted node
/// (1-based) sits at logical `base + path[m-1]` and must land at
/// `base + m`. `path` is strictly increasing with `path[m-1] >= m`
/// (level-major node ids along a root path), so ascending copies never
/// clobber a pending source — the same argument as the dense
/// [`compact_kv_path`](super::models::compact_kv_path).
///
/// Fast path: when the path is a uniform shift `path[m-1] == m + d` with
/// `d % block_size == 0`, and both the destination run `[base+1, base+len]`
/// and its length are block-aligned, every destination block's content is
/// exactly some scratch block's content — the plan is pure table swaps
/// (ascending, chain-safe: each swap's source entry is untouched by the
/// previous ones because sources always lie strictly ahead of
/// destinations).
pub fn plan_path_commit(base: usize, path: &[usize], block_size: usize) -> PathCommitPlan {
    let bs = block_size;
    let mut plan = PathCommitPlan::default();
    if path.is_empty() || path.iter().enumerate().all(|(m, &node)| node == m + 1) {
        return plan; // chain-shaped prefix: already in place
    }
    let d = path[0] - 1;
    let uniform = path.iter().enumerate().all(|(m, &node)| node == m + 1 + d);
    if uniform && d > 0 && d % bs == 0 && (base + 1) % bs == 0 && path.len() % bs == 0 {
        let first = (base + 1) / bs;
        for j in 0..path.len() / bs {
            plan.swaps.push((first + j, first + j + d / bs));
        }
        return plan;
    }
    for (m, &node) in path.iter().enumerate() {
        let m = m + 1;
        if node != m {
            plan.copies.push((base + node, base + m));
        }
    }
    plan
}

fn pool_dims(pool: &HostTensor) -> Result<(usize, usize, usize, usize)> {
    anyhow::ensure!(pool.dims.len() == 6, "KV pool must be rank 6, got {:?}", pool.dims);
    // [L, 2, NB, BS, H, Dh]
    let planes = pool.dims[0] * pool.dims[1];
    let nb = pool.dims[2];
    let bs = pool.dims[3];
    let elems = pool.dims[4] * pool.dims[5];
    Ok((planes, nb, bs, elems))
}

/// Physical element offset of logical position `pos` within one plane of the
/// pool (caller adds `plane * nb * bs * elems`).
fn phys_off(table: &[usize], bs: usize, elems: usize, pos: usize) -> usize {
    (table[pos / bs] * bs + pos % bs) * elems
}

/// Scatter the single batch row of `row` (a dense `[L, 2, 1, S, H, Dh]` KV
/// cache, e.g. an admission prefill output) into the pool blocks named by
/// `table`, positions `0 .. valid_len`. The paged twin of
/// [`splice_kv_row`](super::models::splice_kv_row): only the owning slot's
/// blocks are written, so no other slot can be perturbed by construction.
pub fn splice_kv_row_blocks(
    pool: &mut HostTensor,
    row: &HostTensor,
    table: &[usize],
    valid_len: usize,
) -> Result<()> {
    splice_kv_row_blocks_range(pool, row, table, 0, 0, valid_len)
}

/// [`splice_kv_row_blocks`] restricted to logical positions `from .. to`:
/// the tail splice for a prefix-cache hit, where positions below `from` are
/// already materialized in shared (or freshly copied) blocks and must not be
/// rewritten through this slot's table. `row_base` is the logical position
/// of `row`'s first entry — 0 for a full prefill row, `compute_start` for a
/// cached (tail-only) prefill row — so row index `pos - row_base` holds
/// logical position `pos`.
pub fn splice_kv_row_blocks_range(
    pool: &mut HostTensor,
    row: &HostTensor,
    table: &[usize],
    row_base: usize,
    from: usize,
    to: usize,
) -> Result<()> {
    let (planes, nb, bs, elems) = pool_dims(pool)?;
    anyhow::ensure!(row.dims.len() == 6, "KV row must be rank 6, got {:?}", row.dims);
    anyhow::ensure!(row.dims[2] == 1, "source KV must be batch 1, got {:?}", row.dims);
    anyhow::ensure!(
        pool.dims[0] == row.dims[0]
            && pool.dims[1] == row.dims[1]
            && pool.dims[4..] == row.dims[4..],
        "KV pool/row shape mismatch: {:?} vs {:?}",
        pool.dims,
        row.dims
    );
    let row_s = row.dims[3];
    anyhow::ensure!(from <= to, "splice range {from}..{to} is inverted");
    anyhow::ensure!(row_base <= from, "row base {row_base} past splice start {from}");
    anyhow::ensure!(
        to - row_base <= row_s,
        "splice end {to} past row coverage {row_base}+{row_s}"
    );
    anyhow::ensure!(
        to <= table.len() * bs,
        "splice end {to} not covered by {} blocks of {bs}",
        table.len()
    );
    anyhow::ensure!(
        table.iter().all(|&b| b > 0 && b < nb),
        "block table entry out of pool range 1..{nb}: {table:?}"
    );
    let (pool_v, row_v) = match (&mut pool.data, &row.data) {
        (HostData::F32(d), HostData::F32(s)) => (d, s),
        _ => anyhow::bail!("KV caches must both be f32"),
    };
    for p in 0..planes {
        let pool0 = p * nb * bs * elems;
        let row0 = p * row_s * elems;
        let mut pos = from;
        while pos < to {
            // contiguous run within one block
            let run = (bs - pos % bs).min(to - pos);
            let dst = pool0 + phys_off(table, bs, elems, pos);
            let src = row0 + (pos - row_base) * elems;
            pool_v[dst..dst + run * elems].copy_from_slice(&row_v[src..src + run * elems]);
            pos += run;
        }
    }
    Ok(())
}

/// Copy every position of physical pool block `src` into block `dst` across
/// all planes — the copy-on-write materialization for a sub-block prefix
/// hit: the claim's private destination block starts as an exact replica of
/// the shared source, and the tail splice then overwrites only the
/// divergent positions. The shared source is never written.
pub fn copy_pool_block(pool: &mut HostTensor, src: usize, dst: usize) -> Result<()> {
    let (planes, nb, bs, elems) = pool_dims(pool)?;
    anyhow::ensure!(
        src > 0 && src < nb && dst > 0 && dst < nb,
        "pool block copy {src}->{dst} outside 1..{nb}"
    );
    anyhow::ensure!(src != dst, "pool block copy onto itself");
    let pool_v = match &mut pool.data {
        HostData::F32(d) => d,
        _ => anyhow::bail!("KV pool must be f32"),
    };
    let span = bs * elems;
    for p in 0..planes {
        let p0 = p * nb * span;
        pool_v.copy_within(p0 + src * span..p0 + (src + 1) * span, p0 + dst * span);
    }
    Ok(())
}

/// Assemble a dense single-row KV `[L, 2, 1, s_out, H, Dh]` from the pool
/// through `table`, positions `0 .. upto`; the remaining positions are zero.
/// The cached-prefix upload for a tail-only prefill: the `prefill-cached`
/// executable attends the gathered prefix in its dense kv operand while
/// computing only the tail's queries.
pub fn gather_kv_row_blocks(
    pool: &HostTensor,
    table: &[usize],
    upto: usize,
    s_out: usize,
) -> Result<HostTensor> {
    let (planes, nb, bs, elems) = pool_dims(pool)?;
    anyhow::ensure!(upto <= s_out, "gather length {upto} > output length {s_out}");
    anyhow::ensure!(
        upto <= table.len() * bs,
        "gather length {upto} not covered by {} blocks of {bs}",
        table.len()
    );
    anyhow::ensure!(
        table.iter().all(|&b| b > 0 && b < nb),
        "block table entry out of pool range 1..{nb}: {table:?}"
    );
    let pool_v = match &pool.data {
        HostData::F32(d) => d,
        _ => anyhow::bail!("KV pool must be f32"),
    };
    let dims = [pool.dims[0], pool.dims[1], 1, s_out, pool.dims[4], pool.dims[5]];
    let mut out = vec![0.0f32; planes * s_out * elems];
    for p in 0..planes {
        let pool0 = p * nb * bs * elems;
        let out0 = p * s_out * elems;
        let mut pos = 0usize;
        while pos < upto {
            let run = (bs - pos % bs).min(upto - pos);
            let src = pool0 + phys_off(table, bs, elems, pos);
            let dst = out0 + pos * elems;
            out[dst..dst + run * elems].copy_from_slice(&pool_v[src..src + run * elems]);
            pos += run;
        }
    }
    Ok(HostTensor::f32(&dims, out))
}

/// Apply a [`PathCommitPlan`]'s position copies to the pool through `table`.
/// Copies must be ascending in destination with sources strictly ahead
/// (guaranteed by [`plan_path_commit`]); each copy moves one position's
/// `H * Dh` elements per plane, so the touched bytes are confined to the
/// blocks the chunk spans.
pub fn apply_path_copies(
    pool: &mut HostTensor,
    table: &[usize],
    copies: &[(usize, usize)],
) -> Result<()> {
    let (planes, nb, bs, elems) = pool_dims(pool)?;
    for &(src, dst) in copies {
        anyhow::ensure!(src > dst, "copy source {src} must lie ahead of destination {dst}");
        anyhow::ensure!(
            src / bs < table.len() && table[src / bs] < nb && table[dst / bs] < nb,
            "copy {src}->{dst} outside the slot's {} covered blocks",
            table.len()
        );
    }
    let pool_v = match &mut pool.data {
        HostData::F32(d) => d,
        _ => anyhow::bail!("KV pool must be f32"),
    };
    for p in 0..planes {
        let pool0 = p * nb * bs * elems;
        for &(src, dst) in copies {
            let s = pool0 + phys_off(table, bs, elems, src);
            let d = pool0 + phys_off(table, bs, elems, dst);
            pool_v.copy_within(s..s + elems, d);
        }
    }
    Ok(())
}

/// Translate a [`PathCommitPlan`]'s LOGICAL copies into physical plan rows
/// for the device `commit-path-paged` executable: each copy `(src, dst)`
/// becomes one `(src_block, src_off, dst_block, dst_off)` i32 quad appended
/// to `rows`, where `*_block` is the PHYSICAL pool block id from `table` and
/// `*_off` the in-block offset. Same validation as [`apply_path_copies`].
///
/// Rows from several slots may be appended into one plan: slots own disjoint
/// physical blocks, so the device's gather-then-scatter over the combined
/// rows still equals applying each slot's copies sequentially. The caller
/// zero-pads to the executable's fixed row count — `(0, 0, 0, 0)` is an
/// inert self-copy inside the reserved null block 0.
pub fn physical_copy_rows(
    table: &[usize],
    copies: &[(usize, usize)],
    block_size: usize,
    num_blocks: usize,
    rows: &mut Vec<i32>,
) -> Result<()> {
    let bs = block_size;
    for &(src, dst) in copies {
        anyhow::ensure!(src > dst, "copy source {src} must lie ahead of destination {dst}");
        anyhow::ensure!(
            src / bs < table.len() && table[src / bs] < num_blocks && table[dst / bs] < num_blocks,
            "copy {src}->{dst} outside the slot's {} covered blocks",
            table.len()
        );
        rows.push(table[src / bs] as i32);
        rows.push((src % bs) as i32);
        rows.push(table[dst / bs] as i32);
        rows.push((dst % bs) as i32);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Case};

    fn pool(nb: usize, bs: usize, fill: impl Fn(usize) -> f32) -> HostTensor {
        // [L=1, 2, NB, BS, H=1, Dh=1]: one element per position
        let dims = [1, 2, nb, bs, 1, 1];
        let n: usize = dims.iter().product();
        HostTensor::f32(&dims, (0..n).map(fill).collect())
    }

    /// Read logical position `pos` of plane `p` through `table`.
    fn read(t: &HostTensor, table: &[usize], p: usize, pos: usize) -> f32 {
        let (nb, bs) = (t.dims[2], t.dims[3]);
        t.as_f32().unwrap()[p * nb * bs + table[pos / bs] * bs + pos % bs]
    }

    #[test]
    fn splice_writes_only_owned_blocks() {
        let (nb, bs) = (6, 4);
        let mut pl = pool(nb, bs, |_| 0.0);
        let row_dims = [1, 2, 1, 16, 1, 1];
        let row = HostTensor::f32(&row_dims, (0..32).map(|i| i as f32 + 1.0).collect());
        let table = [2usize, 5];
        splice_kv_row_blocks(&mut pl, &row, &table, 6).unwrap();
        for p in 0..2 {
            for pos in 0..6 {
                assert_eq!(read(&pl, &table, p, pos), (p * 16 + pos) as f32 + 1.0, "plane {p} pos {pos}");
            }
            // tail of the last covered block stays zero
            for pos in 6..8 {
                assert_eq!(read(&pl, &table, p, pos), 0.0);
            }
        }
        // unowned blocks (incl. the null block 0) untouched
        let v = pl.as_f32().unwrap();
        for p in 0..2 {
            for b in [0usize, 1, 3, 4] {
                for o in 0..bs {
                    assert_eq!(v[(p * nb + b) * bs + o], 0.0, "plane {p} block {b} touched");
                }
            }
        }
    }

    #[test]
    fn splice_rejects_bad_inputs() {
        let mut pl = pool(4, 4, |_| 0.0);
        let row = HostTensor::f32(&[1, 2, 1, 16, 1, 1], vec![0.0; 32]);
        // valid_len beyond table coverage
        assert!(splice_kv_row_blocks(&mut pl, &row, &[1], 5).is_err());
        // null block in the table
        assert!(splice_kv_row_blocks(&mut pl, &row, &[0, 1], 5).is_err());
        // block id out of pool
        assert!(splice_kv_row_blocks(&mut pl, &row, &[4], 2).is_err());
        // batch > 1 source
        let bad = HostTensor::f32(&[1, 2, 2, 8, 1, 1], vec![0.0; 32]);
        assert!(splice_kv_row_blocks(&mut pl, &bad, &[1], 2).is_err());
        assert!(splice_kv_row_blocks(&mut pl, &row, &[1, 2], 6).is_ok());
    }

    #[test]
    fn plan_chain_prefix_is_noop() {
        assert!(plan_path_commit(7, &[1, 2, 3], 4).is_noop());
        assert!(plan_path_commit(0, &[], 4).is_noop());
    }

    #[test]
    fn plan_general_path_is_block_mapped_copies() {
        // path [2, 5]: node 2 -> pos base+1, node 5 -> pos base+2
        let plan = plan_path_commit(10, &[2, 5], 4);
        assert!(plan.swaps.is_empty());
        assert_eq!(plan.copies, vec![(12, 11), (15, 12)]);
    }

    #[test]
    fn plan_uniform_aligned_shift_is_pure_swaps() {
        // bs 2, base 3 => destinations 4..=7 (blocks 2, 3); path [7,8,9,10]
        // is the uniform shift d = 6 = 3 blocks: sources 10..=13 (blocks
        // 5, 6) swap into place, no data moves
        let plan = plan_path_commit(3, &[7, 8, 9, 10], 2);
        assert_eq!(plan.swaps, vec![(2, 5), (3, 6)]);
        assert!(plan.copies.is_empty());
        // same path, unaligned base: falls back to copies
        let plan = plan_path_commit(4, &[7, 8, 9, 10], 2);
        assert!(plan.swaps.is_empty());
        assert_eq!(plan.copies.len(), 4);
        // odd shift: never block-aligned
        let plan = plan_path_commit(3, &[6, 7, 8, 9], 2);
        assert!(plan.swaps.is_empty());
        assert_eq!(plan.copies.len(), 4);
    }

    /// Reference model: dense compaction over a logical array.
    fn dense_reference(vals: &mut [f32], base: usize, path: &[usize]) {
        for (m, &node) in path.iter().enumerate() {
            vals[base + m + 1] = vals[base + node];
        }
    }

    #[test]
    fn plan_apply_matches_dense_compaction_property() {
        // For random (bs, base, strictly-increasing path): applying the plan
        // (copies through the table, swaps on the table) to a paged pool
        // must leave the logical view of positions 0..=base+path.len()
        // identical to the dense reference compaction.
        check("paged-path-commit", 200, |rng| {
            let bs = 1 + rng.below(6);
            let base = rng.below(3 * bs);
            let n = 1 + rng.below(10); // draft nodes
            // strictly increasing path with path[m-1] >= m
            let mut path = Vec::new();
            let mut prev = 0usize;
            for _ in 0..1 + rng.below(n.min(5)) {
                let next = prev + 1 + rng.below(3);
                if next > n.max(5) + 5 {
                    break;
                }
                path.push(next);
                prev = next;
            }
            let span = base + path.last().copied().unwrap_or(0) + 1;
            let blocks_needed = span.div_ceil(bs);
            let nb = blocks_needed + 2;
            // offset table: logical block j -> physical 1 + j (ids are
            // opaque, the null block 0 stays out — the indirection itself is
            // what the property exercises)
            let table: Vec<usize> = (1..=blocks_needed).collect();

            // logical contents: distinct values per position
            let mut logical: Vec<f32> = (0..blocks_needed * bs).map(|i| i as f32 + 1.0).collect();
            let mut pl = pool(nb, bs, |_| 0.0);
            if let HostData::F32(v) = &mut pl.data {
                for p in 0..2 {
                    for (pos, &val) in logical.iter().enumerate() {
                        v[p * nb * bs + table[pos / bs] * bs + pos % bs] = val + (p * 1000) as f32;
                    }
                }
            }

            let plan = plan_path_commit(base, &path, bs);
            let mut table_after = table.clone();
            for &(a, b) in &plan.swaps {
                if a.max(b) >= table_after.len() {
                    return Case::Fail {
                        desc: format!("swap ({a},{b}) outside table of {}", table_after.len()),
                        size: bs,
                    };
                }
                table_after.swap(a, b);
            }
            if !plan.copies.is_empty() && !plan.swaps.is_empty() {
                return Case::Fail { desc: "mixed swap+copy plan".into(), size: bs };
            }
            if apply_path_copies(&mut pl, &table, &plan.copies).is_err() {
                return Case::Fail {
                    desc: format!("copies rejected: base {base} path {path:?} bs {bs}"),
                    size: bs,
                };
            }

            dense_reference(&mut logical, base, &path);
            for p in 0..2 {
                for pos in 0..=base + path.len() {
                    let got = read(&pl, &table_after, p, pos);
                    let want = logical[pos] + (p * 1000) as f32;
                    if got != want {
                        return Case::Fail {
                            desc: format!(
                                "plane {p} pos {pos}: {got} != {want} (base {base}, path {path:?}, bs {bs}, plan {plan:?})"
                            ),
                            size: bs + path.len(),
                        };
                    }
                }
            }
            Case::Pass
        });
    }

    #[test]
    fn physical_rows_translate_through_the_table() {
        // bs 4, table [3, 1]: logical 5 lives in table slot 1 -> physical
        // block 1 offset 1; logical 3 in table slot 0 -> block 3 offset 3
        let table = [3usize, 1];
        let mut rows = Vec::new();
        physical_copy_rows(&table, &[(5, 3), (7, 4)], 4, 8, &mut rows).unwrap();
        assert_eq!(rows, vec![1, 1, 3, 3, 1, 3, 1, 0]);
        // appending a second slot's copies extends, never rewrites
        physical_copy_rows(&[6], &[(2, 1)], 4, 8, &mut rows).unwrap();
        assert_eq!(rows.len(), 12);
        assert_eq!(&rows[8..], &[6, 2, 6, 1]);
    }

    #[test]
    fn physical_rows_reject_what_apply_rejects() {
        let mut rows = Vec::new();
        // backward move
        assert!(physical_copy_rows(&[1, 2], &[(3, 5)], 4, 8, &mut rows).is_err());
        // src beyond table coverage
        assert!(physical_copy_rows(&[1, 2], &[(9, 2)], 4, 8, &mut rows).is_err());
        // block id out of pool
        assert!(physical_copy_rows(&[9], &[(2, 1)], 4, 8, &mut rows).is_err());
        assert!(physical_copy_rows(&[1, 2], &[(5, 3)], 4, 8, &mut rows).is_ok());
    }

    #[test]
    fn apply_copies_rejects_backward_moves() {
        let mut pl = pool(4, 4, |i| i as f32);
        assert!(apply_path_copies(&mut pl, &[1, 2], &[(3, 5)]).is_err());
        assert!(apply_path_copies(&mut pl, &[1, 2], &[(9, 2)]).is_err()); // src beyond coverage
        assert!(apply_path_copies(&mut pl, &[1, 2], &[(5, 3)]).is_ok());
    }

    // --- prefix cache helpers ----------------------------------------------

    #[test]
    fn copy_pool_block_replicates_all_planes_and_nothing_else() {
        let (nb, bs) = (5, 4);
        let mut pl = pool(nb, bs, |i| i as f32);
        let before = pl.as_f32().unwrap().to_vec();
        copy_pool_block(&mut pl, 2, 4).unwrap();
        let after = pl.as_f32().unwrap();
        for p in 0..2 {
            let p0 = p * nb * bs;
            for o in 0..bs {
                assert_eq!(after[p0 + 4 * bs + o], before[p0 + 2 * bs + o], "plane {p} off {o}");
            }
            // source and unrelated blocks untouched
            for b in [0usize, 1, 2, 3] {
                for o in 0..bs {
                    assert_eq!(after[p0 + b * bs + o], before[p0 + b * bs + o]);
                }
            }
        }
        assert!(copy_pool_block(&mut pl, 0, 1).is_err(), "null block source");
        assert!(copy_pool_block(&mut pl, 1, 5).is_err(), "dst out of pool");
        assert!(copy_pool_block(&mut pl, 3, 3).is_err(), "self copy");
    }

    #[test]
    fn range_splice_writes_only_the_tail_range() {
        let (nb, bs) = (6, 4);
        let mut full = pool(nb, bs, |_| 0.0);
        let mut tail = pool(nb, bs, |_| 0.0);
        let row = HostTensor::f32(&[1, 2, 1, 16, 1, 1], (0..32).map(|i| i as f32 + 1.0).collect());
        let table = [2usize, 5, 1];
        splice_kv_row_blocks(&mut full, &row, &table, 10).unwrap();
        // pre-poison the shared-prefix region of `tail`, then splice 6..10
        // only — the prefix must keep its poison (range splice never touches
        // shared blocks below `from`)
        let poison = HostTensor::f32(&[1, 2, 1, 16, 1, 1], vec![-7.0; 32]);
        splice_kv_row_blocks(&mut tail, &poison, &table, 6).unwrap();
        splice_kv_row_blocks_range(&mut tail, &row, &table, 0, 6, 10).unwrap();
        for p in 0..2 {
            for pos in 0..6 {
                assert_eq!(read(&tail, &table, p, pos), -7.0, "prefix overwritten at {pos}");
            }
            for pos in 6..10 {
                assert_eq!(read(&tail, &table, p, pos), read(&full, &table, p, pos));
            }
        }
        // inverted and under-covered ranges are rejected
        assert!(splice_kv_row_blocks_range(&mut tail, &row, &table, 0, 8, 6).is_err());
        assert!(splice_kv_row_blocks_range(&mut tail, &row, &table, 7, 6, 10).is_err());
    }

    #[test]
    fn range_splice_honors_row_base_offset() {
        // a tail-only prefill row: row index i holds logical position 4+i
        let (nb, bs) = (4, 4);
        let mut pl = pool(nb, bs, |_| 0.0);
        let tail_row =
            HostTensor::f32(&[1, 2, 1, 4, 1, 1], (0..8).map(|i| 100.0 + i as f32).collect());
        let table = [1usize, 3];
        splice_kv_row_blocks_range(&mut pl, &tail_row, &table, 4, 4, 7).unwrap();
        for p in 0..2 {
            for (i, pos) in (4..7).enumerate() {
                assert_eq!(read(&pl, &table, p, pos), 100.0 + (p * 4 + i) as f32);
            }
            assert_eq!(read(&pl, &table, p, 7), 0.0, "past-end position written");
        }
        // the row is too short to cover past row_base + row_s
        assert!(splice_kv_row_blocks_range(&mut pl, &tail_row, &table, 4, 4, 100).is_err());
    }

    #[test]
    fn gather_round_trips_the_spliced_prefix() {
        let (nb, bs) = (6, 4);
        let mut pl = pool(nb, bs, |_| 0.0);
        let row = HostTensor::f32(&[1, 2, 1, 16, 1, 1], (0..32).map(|i| i as f32 + 1.0).collect());
        let table = [3usize, 1, 4];
        splice_kv_row_blocks(&mut pl, &row, &table, 9).unwrap();
        let dense = gather_kv_row_blocks(&pl, &table, 9, 16).unwrap();
        assert_eq!(dense.dims, row.dims);
        let (d, r) = (dense.as_f32().unwrap(), row.as_f32().unwrap());
        for p in 0..2 {
            for pos in 0..9 {
                assert_eq!(d[p * 16 + pos], r[p * 16 + pos], "plane {p} pos {pos}");
            }
            for pos in 9..16 {
                assert_eq!(d[p * 16 + pos], 0.0, "ungathered position not zeroed");
            }
        }
        assert!(gather_kv_row_blocks(&pl, &table, 13, 12).is_err(), "upto > s_out");
        assert!(gather_kv_row_blocks(&pl, &[1], 5, 16).is_err(), "under-covered");
    }
}
